//! Fig 7 — CCache with HALF the LLC vs DUP with the full LLC, input
//! sized to match the (full) LLC capacity.
//!
//! Paper: CCache still wins — 1.1x (PageRank, KV-Store), 1.19x
//! (K-Means), 1.91x (BFS) — because on-demand duplication uses LLC
//! capacity better than static duplication.
//!
//!     cargo bench --bench fig7_half_llc

use ccache::coordinator::{run_verified, scaled_config, sized_workload};
use ccache::exec::Variant;
use ccache::util::bench::Table;

fn main() {
    let full = scaled_config();
    // Route the halved geometry through the same validation path CLI
    // configs take: a base LLC whose half has a non-power-of-two set
    // count (or violates associativity) must be a diagnostic, not a
    // mis-indexed tag array. `sim/config.rs` pins the rejection cases
    // next to `half_llc_for_fig7`.
    let half = full.clone().with_llc_bytes(full.llc().size_bytes / 2);
    if let Err(e) = half.validate() {
        eprintln!("fig7: halving the LLC breaks the geometry: {e}");
        std::process::exit(2);
    }

    let mut t = Table::new(
        "Fig 7 — CCache @ half LLC vs DUP @ full LLC (ws = full LLC)",
        &["benchmark", "DUP(full) Mcyc", "CCACHE(half) Mcyc", "CCache adv", "paper"],
    );
    let panels = [
        ("kvstore", "1.1x"),
        ("kmeans", "1.19x"),
        ("pagerank-uniform", "1.1x"),
        ("bfs-rmat", "1.91x"),
    ];
    for (name, paper) in panels {
        let bench = sized_workload(name, 1.0, full.llc().size_bytes, 42);
        eprintln!("running {}...", bench.name());
        let dup = run_verified(&bench, Variant::Dup, &full);
        let cc = run_verified(&bench, Variant::CCache, &half);
        t.row(&[
            bench.name().to_string(),
            format!("{:.1}", dup.cycles() as f64 / 1e6),
            format!("{:.1}", cc.cycles() as f64 / 1e6),
            format!("{:.2}x", dup.cycles() as f64 / cc.cycles() as f64),
            paper.to_string(),
        ]);
    }
    t.print();
}
