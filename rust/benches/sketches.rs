//! Streaming-sketch panels — count-min / Bloom / HyperLogLog working-set
//! sweeps, the scenario-diversity counterpart of the Fig 6 panels: the
//! sketches' merges are natively commutative (saturating add / bitwise
//! OR / lane max), so CCache's advantage over FGL and DUP should persist
//! on aggregation structures the paper never measured.
//!
//!     cargo bench --bench sketches
//!     CCACHE_SKETCH_ZIPF=0.99 cargo bench --bench sketches   # hot keys

use ccache::coordinator::{report, run_sweep_with, scaled_config, SweepOptions};
use ccache::exec::Variant;

fn main() {
    let cfg = scaled_config();
    let zipf: f64 = std::env::var("CCACHE_SKETCH_ZIPF")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.0);
    for name in ["cms", "bloom", "hll"] {
        eprintln!("== sketch {name} ==");
        let sweep = run_sweep_with(
            name,
            &[Variant::Fgl, Variant::Dup, Variant::CCache, Variant::Atomic],
            &[0.25, 1.0, 4.0],
            cfg.clone(),
            SweepOptions {
                seed: 42,
                zipf_theta: zipf,
                ..Default::default()
            },
        );
        report::fig6_table(&sweep).print();
        for p in &sweep.points {
            if let Some(s) = p.speedup_vs_fgl(Variant::Atomic) {
                println!("  ws {:.2}: atomics speedup vs FGL {s:.2}x", p.frac);
            }
        }
        println!();
    }
}
