//! Section 4.7 — area/energy overhead of the CCache structures
//! (structural bit-count model; CACTI is closed tooling — see DESIGN.md).
//!
//!     cargo bench --bench table_overhead

use ccache::sim::config::MachineConfig;
use ccache::sim::overhead::OverheadModel;
use ccache::util::bench::Table;

fn main() {
    let mut t = Table::new(
        "Section 4.7 — CCache structural overhead",
        &["structure", "bits/core", "vs LLC bits"],
    );
    let cfg = MachineConfig::default();
    let m = OverheadModel::for_config(&cfg);
    t.row(&[
        "L1 metadata (ccache+mergeable+type)".into(),
        m.l1_extra_bits.to_string(),
        format!("{:.4}%", m.l1_extra_bits as f64 / m.llc_bits as f64 * 100.0),
    ]);
    t.row(&[
        "source buffer (8 entries)".into(),
        m.src_buf_bits.to_string(),
        format!("{:.4}%", m.src_buf_frac_of_llc() * 100.0),
    ]);
    let mut cfg32 = cfg.clone();
    cfg32.ccache.source_buffer_entries = 32;
    let m32 = OverheadModel::for_config(&cfg32);
    t.row(&[
        "source buffer (32 entries, paper's CACTI point)".into(),
        m32.src_buf_bits.to_string(),
        format!("{:.4}% (paper: ~0.1%)", m32.src_buf_frac_of_llc() * 100.0),
    ]);
    t.row(&[
        "MFRF (4 slots)".into(),
        m.mfrf_bits.to_string(),
        format!("{:.6}%", m.mfrf_bits as f64 / m.llc_bits as f64 * 100.0),
    ]);
    t.row(&[
        "merge registers (3 x 64 B)".into(),
        m.merge_reg_bits.to_string(),
        format!("{:.6}%", m.merge_reg_bits as f64 / m.llc_bits as f64 * 100.0),
    ]);
    t.print();
    println!(
        "context-switch state bound (Section 4.6): {} B per core (paper: <= 1 KB)",
        m.per_core_saved_state_bytes(&cfg)
    );
}
