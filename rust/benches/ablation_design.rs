//! Design-choice ablations beyond the paper's figures:
//!
//!  (1) source-buffer capacity — the paper fixes 8 entries (Table 2) and
//!      sizes a 32-entry buffer in Section 4.7; how sensitive are the
//!      speedups?
//!  (2) interleave quantum — simulator fidelity knob: does coarser
//!      turn-taking distort the measured contention?
//!  (3) lock backoff — FGL's spin-retry interval.
//!  (4) zipf-skewed keys — contention concentration vs the paper's
//!      uniform keys.
//!
//!     cargo bench --bench ablation_design

use ccache::coordinator::{scaled_config, sized_benchmark, BenchKind};
use ccache::exec::Variant;
use ccache::util::bench::Table;
use ccache::workloads::kvstore::{KvMerge, KvParams};
use ccache::workloads::Benchmark;

fn main() {
    let base = scaled_config();

    // ---- (1) source buffer capacity ----
    let mut t = Table::new(
        "ablation: source-buffer entries (ws = LLC)",
        &["entries", "kvstore CCache Mcyc", "kmeans CCache Mcyc"],
    );
    for entries in [4usize, 8, 16, 32] {
        let mut cfg = base;
        cfg.ccache.source_buffer_entries = entries;
        let kv = sized_benchmark(BenchKind::KvAdd, 1.0, cfg.llc.size_bytes, 42)
            .run(Variant::CCache, cfg);
        kv.assert_verified();
        let km = sized_benchmark(BenchKind::KMeans, 1.0, cfg.llc.size_bytes, 42)
            .run(Variant::CCache, cfg);
        km.assert_verified();
        t.row(&[
            entries.to_string(),
            format!("{:.1}", kv.cycles() as f64 / 1e6),
            format!("{:.1}", km.cycles() as f64 / 1e6),
        ]);
    }
    t.print();

    // ---- (2) interleave quantum ----
    let mut t = Table::new(
        "ablation: interleave quantum (kvstore, ws = 0.5 LLC)",
        &["quantum", "FGL Mcyc", "CCACHE Mcyc", "speedup"],
    );
    for quantum in [0u64, 64, 256, 1024, 4096] {
        let mut cfg = base;
        cfg.quantum = quantum;
        let bench = sized_benchmark(BenchKind::KvAdd, 0.5, cfg.llc.size_bytes, 42);
        let fgl = bench.run(Variant::Fgl, cfg);
        fgl.assert_verified();
        let cc = bench.run(Variant::CCache, cfg);
        cc.assert_verified();
        t.row(&[
            quantum.to_string(),
            format!("{:.1}", fgl.cycles() as f64 / 1e6),
            format!("{:.1}", cc.cycles() as f64 / 1e6),
            format!("{:.2}x", fgl.cycles() as f64 / cc.cycles() as f64),
        ]);
    }
    t.print();

    // ---- (3) lock backoff ----
    let mut t = Table::new(
        "ablation: FGL spin backoff (kvstore, ws = 0.5 LLC)",
        &["backoff cyc", "FGL Mcyc", "lock retries"],
    );
    for backoff in [10u64, 40, 160, 640] {
        let mut cfg = base;
        cfg.lock_backoff = backoff;
        let bench = sized_benchmark(BenchKind::KvAdd, 0.5, cfg.llc.size_bytes, 42);
        let fgl = bench.run(Variant::Fgl, cfg);
        fgl.assert_verified();
        t.row(&[
            backoff.to_string(),
            format!("{:.1}", fgl.cycles() as f64 / 1e6),
            fgl.stats.lock_retries.to_string(),
        ]);
    }
    t.print();

    // ---- (4) key skew ----
    let mut t = Table::new(
        "ablation: zipf key skew (kvstore, ws = 0.5 LLC)",
        &["theta", "FGL Mcyc", "CCACHE Mcyc", "speedup"],
    );
    for theta in [0.0f64, 0.6, 0.9, 0.99] {
        let p = KvParams {
            keys: base.llc.size_bytes / 8,
            accesses_per_key: 16,
            seed: 42,
            merge: KvMerge::Add,
            zipf_theta: theta,
        };
        let bench = Benchmark::Kv(p);
        let fgl = bench.run(Variant::Fgl, base);
        fgl.assert_verified();
        let cc = bench.run(Variant::CCache, base);
        cc.assert_verified();
        t.row(&[
            format!("{theta:.2}"),
            format!("{:.1}", fgl.cycles() as f64 / 1e6),
            format!("{:.1}", cc.cycles() as f64 / 1e6),
            format!("{:.2}x", fgl.cycles() as f64 / cc.cycles() as f64),
        ]);
    }
    t.print();
    println!(
        "skewed keys concentrate contention on hot lines: FGL serializes on\n\
         hot locks while CCache's privatized hot lines enjoy source-buffer\n\
         locality."
    );
}
