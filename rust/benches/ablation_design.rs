//! Design-choice ablations beyond the paper's figures:
//!
//!  (1) source-buffer capacity — the paper fixes 8 entries (Table 2) and
//!      sizes a 32-entry buffer in Section 4.7; how sensitive are the
//!      speedups?
//!  (2) interleave quantum — simulator fidelity knob: does coarser
//!      turn-taking distort the measured contention?
//!  (3) lock backoff — FGL's spin-retry interval.
//!  (4) zipf-skewed keys — contention concentration vs the paper's
//!      uniform keys, for both kvstore and the histogram workload.
//!
//!     cargo bench --bench ablation_design

use ccache::coordinator::{run_verified, scaled_config, sized_workload};
use ccache::exec::registry::{self, SizeSpec};
use ccache::exec::Variant;
use ccache::util::bench::Table;

fn main() {
    let base = scaled_config();

    // ---- (1) source buffer capacity ----
    let mut t = Table::new(
        "ablation: source-buffer entries (ws = LLC)",
        &["entries", "kvstore CCache Mcyc", "kmeans CCache Mcyc"],
    );
    for entries in [4usize, 8, 16, 32] {
        let mut cfg = base.clone();
        cfg.ccache.source_buffer_entries = entries;
        let kv = run_verified(
            &sized_workload("kvstore", 1.0, cfg.llc().size_bytes, 42),
            Variant::CCache,
            &cfg,
        );
        let km = run_verified(
            &sized_workload("kmeans", 1.0, cfg.llc().size_bytes, 42),
            Variant::CCache,
            &cfg,
        );
        t.row(&[
            entries.to_string(),
            format!("{:.1}", kv.cycles() as f64 / 1e6),
            format!("{:.1}", km.cycles() as f64 / 1e6),
        ]);
    }
    t.print();

    // ---- (2) interleave quantum ----
    let mut t = Table::new(
        "ablation: interleave quantum (kvstore, ws = 0.5 LLC)",
        &["quantum", "FGL Mcyc", "CCACHE Mcyc", "speedup"],
    );
    for quantum in [0u64, 64, 256, 1024, 4096] {
        let mut cfg = base.clone();
        cfg.timing.quantum = quantum;
        let bench = sized_workload("kvstore", 0.5, cfg.llc().size_bytes, 42);
        let fgl = run_verified(&bench, Variant::Fgl, &cfg);
        let cc = run_verified(&bench, Variant::CCache, &cfg);
        t.row(&[
            quantum.to_string(),
            format!("{:.1}", fgl.cycles() as f64 / 1e6),
            format!("{:.1}", cc.cycles() as f64 / 1e6),
            format!("{:.2}x", fgl.cycles() as f64 / cc.cycles() as f64),
        ]);
    }
    t.print();

    // ---- (3) lock backoff ----
    let mut t = Table::new(
        "ablation: FGL spin backoff (kvstore, ws = 0.5 LLC)",
        &["backoff cyc", "FGL Mcyc", "lock retries"],
    );
    for backoff in [10u64, 40, 160, 640] {
        let mut cfg = base.clone();
        cfg.timing.lock_backoff = backoff;
        let bench = sized_workload("kvstore", 0.5, cfg.llc().size_bytes, 42);
        let fgl = run_verified(&bench, Variant::Fgl, &cfg);
        t.row(&[
            backoff.to_string(),
            format!("{:.1}", fgl.cycles() as f64 / 1e6),
            fgl.stats.lock_retries.to_string(),
        ]);
    }
    t.print();

    // ---- (4) key skew ----
    let mut t = Table::new(
        "ablation: zipf key skew (ws = 0.5 LLC)",
        &["benchmark", "theta", "FGL Mcyc", "CCACHE Mcyc", "speedup"],
    );
    for name in ["kvstore", "histogram"] {
        for theta in [0.0f64, 0.6, 0.9, 0.99] {
            let size = SizeSpec::new(0.5, base.llc().size_bytes, 42).with_zipf(theta);
            let bench = registry::build(name, &size).expect("registered");
            let fgl = run_verified(&bench, Variant::Fgl, &base);
            let cc = run_verified(&bench, Variant::CCache, &base);
            t.row(&[
                name.to_string(),
                format!("{theta:.2}"),
                format!("{:.1}", fgl.cycles() as f64 / 1e6),
                format!("{:.1}", cc.cycles() as f64 / 1e6),
                format!("{:.2}x", fgl.cycles() as f64 / cc.cycles() as f64),
            ]);
        }
    }
    t.print();
    println!(
        "skewed keys concentrate contention on hot lines: FGL serializes on\n\
         hot locks while CCache's privatized hot lines enjoy source-buffer\n\
         locality."
    );
}
