//! Fig 8 — characterization: the coherence/memory-system event rates
//! that explain the Fig 6 speedups.
//!
//!  (a) PageRank (random graph): directory accesses per 1k cycles —
//!      CCache far below FGL; DUP's grows with working set.
//!  (b) KV store: L3 misses per 1k cycles — CCache 2.5-3x fewer at
//!      ws = LLC.
//!  (c) BFS: invalidations per 1k cycles — FGL/atomics high, DUP/CCache
//!      low.
//!  (d) K-Means: invalidations per 1k cycles — CCache < DUP < FGL.
//!
//!     cargo bench --bench fig8_characterization

use ccache::coordinator::{report, run_sweep, scaled_config};
use ccache::exec::Variant;

fn main() {
    let cfg = scaled_config();
    let fracs = [0.25, 1.0, 4.0];
    let main3 = [Variant::Fgl, Variant::Dup, Variant::CCache];

    // (a) PageRank directory accesses
    eprintln!("== fig 8a: pagerank-uniform ==");
    let s = run_sweep("pagerank-uniform", &main3, &fracs, cfg.clone(), 42);
    report::fig8_table(&s, "directory accesses", |r| r.stats.dir_msgs_per_kc()).print();

    // (b) KV store L3 misses
    eprintln!("== fig 8b: kvstore ==");
    let s = run_sweep("kvstore", &main3, &fracs, cfg.clone(), 42);
    report::fig8_table(&s, "L3 misses", |r| r.stats.llc_misses_per_kc()).print();

    // (c) BFS invalidations (including the atomics variant)
    eprintln!("== fig 8c: bfs-rmat ==");
    let s = run_sweep(
        "bfs-rmat",
        &[Variant::Fgl, Variant::Dup, Variant::CCache, Variant::Atomic],
        &fracs,
        cfg.clone(),
        42,
    );
    report::fig8_table(&s, "invalidations", |r| r.stats.invalidations_per_kc()).print();

    // (d) K-Means invalidations
    eprintln!("== fig 8d: kmeans ==");
    let s = run_sweep("kmeans", &main3, &fracs, cfg, 42);
    report::fig8_table(&s, "invalidations", |r| r.stats.invalidations_per_kc()).print();
}
