//! Table 3 — peak memory overhead of FGL and DUP normalized to CCache,
//! input sized to LLC capacity.
//!
//! Paper: KV 12x/8x, PageRank 1.91x/1.09x, K-Means 1x/1x, BFS 5.2x/4.9x
//! (FGL/DUP vs CCache). We report bytes allocated in simulated memory by
//! each variant, normalized the same way.
//!
//!     cargo bench --bench table3_memory

use ccache::coordinator::{run_verified, scaled_config, sized_workload};
use ccache::exec::Variant;
use ccache::util::bench::Table;

fn main() {
    let cfg = scaled_config();
    let mut t = Table::new(
        "Table 3 — memory overhead normalized to CCache",
        &["benchmark", "FGL", "DUP", "CCACHE", "paper FGL/DUP"],
    );
    let panels = [
        ("kvstore", "12x / 8x"),
        ("pagerank-uniform", "1.91x / 1.09x"),
        ("kmeans", "1x / 1x"),
        ("bfs-rmat", "5.2x / 4.9x"),
    ];
    for (name, paper) in panels {
        let bench = sized_workload(name, 1.0, cfg.llc().size_bytes, 42);
        eprintln!("running {}...", bench.name());
        let get_bytes =
            |v: Variant| run_verified(&bench, v, &cfg).stats.bytes_allocated as f64;
        let cc = get_bytes(Variant::CCache);
        let fgl = get_bytes(Variant::Fgl);
        let dup = get_bytes(Variant::Dup);
        t.row(&[
            bench.name().to_string(),
            format!("{:.2}x", fgl / cc),
            format!("{:.2}x", dup / cc),
            "1x".into(),
            paper.to_string(),
        ]);
    }
    t.print();
    println!(
        "note: ratios cover ALL simulated allocations (graph CSR included),\n\
         so structure-only ratios like the paper's KV 12x appear damped\n\
         where a large read-only input dominates (PR/BFS)."
    );
}
