//! Simulator hot-path microbenchmarks (the EXPERIMENTS.md §Perf
//! instrument): wall-clock throughput of the protocol engine and the
//! machine interleaver, plus PJRT merge-batch dispatch cost.
//!
//!     cargo bench --bench perf_hotpath

use std::time::Instant;

use ccache::merge::batch::{BatchExecutor, MergeItem, NativeExecutor};
use ccache::merge::funcs::AddU32;
use ccache::merge::handle;
use ccache::sim::addr::Addr;
use ccache::sim::config::MachineConfig;
use ccache::sim::machine::{CoreCtx, Machine};
use ccache::sim::memsys::MemSystem;

fn ops_per_sec(n: u64, secs: f64) -> String {
    format!("{:.2} Mops/s", n as f64 / secs / 1e6)
}

fn main() {
    // 1. raw memsys: coherent read hit path
    let mut cfg = MachineConfig::default();
    cfg.cores = 8;
    let mut s = MemSystem::new(cfg).expect("valid config");
    let a = s.alloc_lines(64 * 1024);
    let n = 4_000_000u64;
    let t0 = Instant::now();
    let mut acc = 0u64;
    for i in 0..n {
        let (v, c) = s.read(0, Addr(a.0 + (i % 1024) * 64)).unwrap();
        acc = acc.wrapping_add(v as u64 + c);
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("memsys read (L1-hit mix):        {}", ops_per_sec(n, dt));

    // 2. raw memsys: COp + merge path
    s.merge_init(0, 0, handle(AddU32));
    let t0 = Instant::now();
    for i in 0..n / 4 {
        let addr = Addr(a.0 + (i % 1024) * 64);
        let (v, _) = s.c_read(0, addr, 0).unwrap();
        s.c_write(0, addr, v + 1, 0).unwrap();
        s.soft_merge(0).unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("memsys COp update (+soft_merge): {}", ops_per_sec(n / 4 * 3, dt));
    std::hint::black_box(acc);

    // 3. machine interleaver: 8 threads, mixed ops
    let cfg = MachineConfig::default();
    let machine = Machine::new(cfg).expect("valid config");
    let region = machine.setup(|mem| mem.alloc_lines(64 * 8192));
    let per_core = 250_000u64;
    let t0 = Instant::now();
    let programs: Vec<Box<dyn FnOnce(&mut CoreCtx) + Send + '_>> = (0..8)
        .map(|core| {
            let f: Box<dyn FnOnce(&mut CoreCtx) + Send + '_> = Box::new(move |ctx| {
                let mut x = core as u64 + 1;
                for _ in 0..per_core {
                    x = x.wrapping_mul(6364136223846793005).wrapping_add(97);
                    let k = (x >> 33) % 8192;
                    if x & 1 == 0 {
                        ctx.read_u32(region.add(k * 64));
                    } else {
                        ctx.write_u32(region.add(k * 64), x as u32);
                    }
                }
            });
            f
        })
        .collect();
    machine.run(programs);
    let dt = t0.elapsed().as_secs_f64();
    println!("machine 8-core interleaved ops:  {}", ops_per_sec(8 * per_core, dt));

    // 4. merge batch executors
    let items: Vec<MergeItem> = (0..4096)
        .map(|i| MergeItem {
            src: [i as u32; 16],
            upd: [(i + 7) as u32; 16],
            mem: [1000; 16],
            drop_update: false,
        })
        .collect();
    let t0 = Instant::now();
    let reps = 200;
    for _ in 0..reps {
        std::hint::black_box(NativeExecutor.execute(&AddU32, &items));
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "native merge batch (4096 lines):  {:.1} us/batch",
        dt / reps as f64 * 1e6
    );

    if ccache::runtime::artifacts::artifacts_available() {
        let mut pjrt = ccache::runtime::PjrtMergeExecutor::load_default().unwrap();
        // warm-up compile
        pjrt.execute(&AddU32, &items[..256]);
        let t0 = Instant::now();
        let reps = 20;
        for _ in 0..reps {
            std::hint::black_box(pjrt.execute(&AddU32, &items));
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "pjrt merge batch (4096 lines):    {:.1} us/batch",
            dt / reps as f64 * 1e6
        );
    } else {
        println!("pjrt merge batch: skipped (run `make artifacts`)");
    }
}
