//! Simulator hot-path microbenchmarks (the EXPERIMENTS.md §Perf
//! instrument), now a thin wrapper over the shared suite in
//! `coordinator::perf` — the same scenarios the `ccache bench`
//! subcommand runs, including the fast/slow twin runs and the COp
//! miss/re-type and merge-on-evict stress loops.
//!
//!     cargo bench --bench perf_hotpath [-- --quick] [-- --json OUT]

use ccache::coordinator::perf::{run_suite, SuiteOptions};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let quick = argv.iter().any(|a| a == "--quick");
    let json_path = argv
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned();

    let report = run_suite(&SuiteOptions {
        quick,
        bench_id: "dev".into(),
    });
    report.table().print();
    println!(
        "(suite wall clock {:.1} s{})",
        report.wall_clock_secs,
        if report.quick { ", quick mode" } else { "" }
    );
    if let Some(path) = json_path {
        std::fs::write(&path, report.to_json()).expect("write bench json");
        eprintln!("wrote {path}");
    }
}
