//! Fig 9 — the merge-on-evict optimization's reduction in source-buffer
//! evictions.
//!
//! Paper: 2.2x fewer evictions for BFS, 409.9x for K-Means (whose
//! cluster accumulators have enormous reuse), with KV-store and PageRank
//! in between.
//!
//!     cargo bench --bench fig9_merge_on_evict

use ccache::coordinator::{scaled_config, sized_benchmark, BenchKind};
use ccache::exec::Variant;
use ccache::util::bench::Table;
use ccache::workloads::graph::GraphKind;

fn main() {
    let base = scaled_config();
    let mut no_opt = base;
    no_opt.ccache.merge_on_evict = false;

    let mut t = Table::new(
        "Fig 9 — source-buffer evictions: no-opt / merge-on-evict",
        &["benchmark", "evictions (no opt)", "evictions (opt)", "reduction", "paper"],
    );
    let panels = [
        (BenchKind::KvAdd, "~1x"),
        (BenchKind::KMeans, "409.9x"),
        (BenchKind::PageRank(GraphKind::Uniform), "-"),
        (BenchKind::Bfs(GraphKind::Rmat), "2.2x"),
    ];
    for (kind, paper) in panels {
        let bench = sized_benchmark(kind, 1.0, base.llc.size_bytes, 42);
        eprintln!("running {}...", bench.name());
        let with = bench.run(Variant::CCache, base);
        with.assert_verified();
        let without = bench.run(Variant::CCache, no_opt);
        without.assert_verified();
        let ratio = without.stats.src_buf_evictions as f64
            / with.stats.src_buf_evictions.max(1) as f64;
        t.row(&[
            bench.name(),
            without.stats.src_buf_evictions.to_string(),
            with.stats.src_buf_evictions.to_string(),
            format!("{ratio:.1}x"),
            paper.to_string(),
        ]);
    }
    t.print();
}
