//! Fig 9 — the merge-on-evict optimization's reduction in source-buffer
//! evictions.
//!
//! Paper: 2.2x fewer evictions for BFS, 409.9x for K-Means (whose
//! cluster accumulators have enormous reuse), with KV-store and PageRank
//! in between.
//!
//!     cargo bench --bench fig9_merge_on_evict

use ccache::coordinator::{run_verified, scaled_config, sized_workload};
use ccache::exec::Variant;
use ccache::util::bench::Table;

fn main() {
    let base = scaled_config();
    let mut no_opt = base.clone();
    no_opt.ccache.merge_on_evict = false;

    let mut t = Table::new(
        "Fig 9 — source-buffer evictions: no-opt / merge-on-evict",
        &["benchmark", "evictions (no opt)", "evictions (opt)", "reduction", "paper"],
    );
    let panels = [
        ("kvstore", "~1x"),
        ("kmeans", "409.9x"),
        ("pagerank-uniform", "-"),
        ("bfs-rmat", "2.2x"),
    ];
    for (name, paper) in panels {
        let bench = sized_workload(name, 1.0, base.llc().size_bytes, 42);
        eprintln!("running {}...", bench.name());
        let with = run_verified(&bench, Variant::CCache, &base);
        let without = run_verified(&bench, Variant::CCache, &no_opt);
        let ratio = without.stats.src_buf_evictions as f64
            / with.stats.src_buf_evictions.max(1) as f64;
        t.row(&[
            bench.name().to_string(),
            without.stats.src_buf_evictions.to_string(),
            with.stats.src_buf_evictions.to_string(),
            format!("{ratio:.1}x"),
            paper.to_string(),
        ]);
    }
    t.print();
}
