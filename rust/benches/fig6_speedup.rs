//! Fig 6 — performance comparison of CCache and DUP relative to FGL
//! across working-set sizes (25%..400% of LLC capacity), for every
//! benchmark panel including the Section 6.3 merge-function variants.
//!
//! Paper shape to match: CCache up to ~3.2x over FGL; DUP above FGL at
//! small working sets for KV/PR/KMeans but degrading at larger ones;
//! CCache's advantage growing with working-set size.
//!
//!     cargo bench --bench fig6_speedup            # core panels
//!     CCACHE_FIG6_ALL=1 cargo bench --bench fig6_speedup   # all panels
//!     CCACHE_FIG6_FRACS=0.25,0.5,1,2,4 ...                 # full x-axis

use ccache::coordinator::{report, run_sweep, scaled_config};
use ccache::exec::registry;
use ccache::exec::Variant;

fn fracs() -> Vec<f64> {
    match std::env::var("CCACHE_FIG6_FRACS") {
        Ok(s) => s
            .split(',')
            .map(|x| x.parse().expect("bad frac"))
            .collect(),
        Err(_) => vec![0.25, 1.0, 4.0],
    }
}

fn main() {
    let cfg = scaled_config();
    let panels: Vec<&str> = if std::env::var("CCACHE_FIG6_ALL").is_ok() {
        registry::fig6_panels().iter().map(|s| s.name).collect()
    } else {
        vec![
            "kvstore",
            "kmeans",
            "pagerank-rmat",
            "bfs-rmat",
            "kvstore-sat",
            "kvstore-cmul",
            "kmeans-approx",
        ]
    };
    let fracs = fracs();
    for name in panels {
        eprintln!("== panel {name} ==");
        // atomics cells only materialize where the workload supports
        // them (BFS, histogram) — the sweep skips the rest
        let variants = [Variant::Fgl, Variant::Dup, Variant::CCache, Variant::Atomic];
        let sweep = run_sweep(name, &variants, &fracs, cfg.clone(), 42);
        report::fig6_table(&sweep).print();
        // atomics column (Section 6.2's BFS comparison)
        for p in &sweep.points {
            if let Some(s) = p.speedup_vs_fgl(Variant::Atomic) {
                println!("  ws {:.2}: atomics speedup vs FGL {s:.2}x", p.frac);
            }
        }
        println!();
    }
}
