//! Fig 6 — performance comparison of CCache and DUP relative to FGL
//! across working-set sizes (25%..400% of LLC capacity), for every
//! benchmark panel including the Section 6.3 merge-function variants.
//!
//! Paper shape to match: CCache up to ~3.2x over FGL; DUP above FGL at
//! small working sets for KV/PR/KMeans but degrading at larger ones;
//! CCache's advantage growing with working-set size.
//!
//!     cargo bench --bench fig6_speedup            # core panels
//!     CCACHE_FIG6_ALL=1 cargo bench --bench fig6_speedup   # all panels
//!     CCACHE_FIG6_FRACS=0.25,0.5,1,2,4 ...                 # full x-axis

use ccache::coordinator::{report, run_sweep, scaled_config, BenchKind};
use ccache::exec::Variant;
use ccache::workloads::graph::GraphKind;

fn fracs() -> Vec<f64> {
    match std::env::var("CCACHE_FIG6_FRACS") {
        Ok(s) => s
            .split(',')
            .map(|x| x.parse().expect("bad frac"))
            .collect(),
        Err(_) => vec![0.25, 1.0, 4.0],
    }
}

fn main() {
    let cfg = scaled_config();
    let panels = if std::env::var("CCACHE_FIG6_ALL").is_ok() {
        BenchKind::fig6_panels()
    } else {
        vec![
            BenchKind::KvAdd,
            BenchKind::KMeans,
            BenchKind::PageRank(GraphKind::Rmat),
            BenchKind::Bfs(GraphKind::Rmat),
            BenchKind::KvSat,
            BenchKind::KvCmul,
            BenchKind::KMeansApprox,
        ]
    };
    let fracs = fracs();
    for kind in panels {
        eprintln!("== panel {} ==", kind.name());
        let mut variants = vec![Variant::Fgl, Variant::Dup, Variant::CCache];
        if matches!(kind, BenchKind::Bfs(_)) {
            variants.push(Variant::Atomic);
        }
        let sweep = run_sweep(kind, &variants, &fracs, cfg, 42);
        report::fig6_table(&sweep).print();
        if matches!(kind, BenchKind::Bfs(_)) {
            // atomics column (Section 6.2's BFS comparison)
            for p in &sweep.points {
                if let Some(s) = p.speedup_vs_fgl(Variant::Atomic) {
                    println!("  ws {:.2}: atomics speedup vs FGL {s:.2}x", p.frac);
                }
            }
        }
        println!();
    }
}
