//! Section 6.4 (second half) — the dirty-merge optimization: silently
//! dropping clean CData lines at merge time.
//!
//! Paper: no benefit for update-heavy benchmarks (K-Means, KV, BFS), but
//! PageRank — where much CData is read and never updated — saw 24x fewer
//! merges.
//!
//!     cargo bench --bench ablation_dirty_merge

use ccache::coordinator::{run_verified, scaled_config, sized_workload};
use ccache::exec::Variant;
use ccache::util::bench::Table;

fn main() {
    let base = scaled_config();
    let mut no_dirty = base.clone();
    no_dirty.ccache.dirty_merge = false;

    let mut t = Table::new(
        "dirty-merge ablation — merges executed: no-opt / opt",
        &["benchmark", "merges (no opt)", "merges (opt)", "silent drops", "reduction"],
    );
    for name in ["kvstore", "kmeans", "pagerank-uniform", "bfs-rmat"] {
        let bench = sized_workload(name, 1.0, base.llc().size_bytes, 42);
        eprintln!("running {}...", bench.name());
        let with = run_verified(&bench, Variant::CCache, &base);
        let without = run_verified(&bench, Variant::CCache, &no_dirty);
        let ratio = without.stats.merges as f64 / with.stats.merges.max(1) as f64;
        t.row(&[
            bench.name().to_string(),
            without.stats.merges.to_string(),
            with.stats.merges.to_string(),
            with.stats.silent_drops.to_string(),
            format!("{ratio:.1}x"),
        ]);
    }
    t.print();
    println!(
        "paper: PageRank benefits most (24x fewer merges) because its\n\
         CData includes lines that are read but never updated."
    );
}
