//! Section 6.4 (second half) — the dirty-merge optimization: silently
//! dropping clean CData lines at merge time.
//!
//! Paper: no benefit for update-heavy benchmarks (K-Means, KV, BFS), but
//! PageRank — where much CData is read and never updated — saw 24x fewer
//! merges.
//!
//!     cargo bench --bench ablation_dirty_merge

use ccache::coordinator::{scaled_config, sized_benchmark, BenchKind};
use ccache::exec::Variant;
use ccache::util::bench::Table;
use ccache::workloads::graph::GraphKind;

fn main() {
    let base = scaled_config();
    let mut no_dirty = base;
    no_dirty.ccache.dirty_merge = false;

    let mut t = Table::new(
        "dirty-merge ablation — merges executed: no-opt / opt",
        &["benchmark", "merges (no opt)", "merges (opt)", "silent drops", "reduction"],
    );
    for kind in [
        BenchKind::KvAdd,
        BenchKind::KMeans,
        BenchKind::PageRank(GraphKind::Uniform),
        BenchKind::Bfs(GraphKind::Rmat),
    ] {
        let bench = sized_benchmark(kind, 1.0, base.llc.size_bytes, 42);
        eprintln!("running {}...", bench.name());
        let with = bench.run(Variant::CCache, base);
        with.assert_verified();
        let without = bench.run(Variant::CCache, no_dirty);
        without.assert_verified();
        let ratio = without.stats.merges as f64 / with.stats.merges.max(1) as f64;
        t.row(&[
            bench.name(),
            without.stats.merges.to_string(),
            with.stats.merges.to_string(),
            with.stats.silent_drops.to_string(),
            format!("{ratio:.1}x"),
        ]);
    }
    t.print();
    println!(
        "paper: PageRank benefits most (24x fewer merges) because its\n\
         CData includes lines that are read but never updated."
    );
}
