//! The kvserve staleness-vs-throughput frontier at paper scale: the
//! full `serve` sweep (skew x merge-deadline x variant) on the
//! Table-2-shaped hierarchy, printed as the ASCII table the `ccache
//! serve` subcommand emits plus the headline frontier.
//!
//!     cargo bench --bench serve_frontier

use ccache::coordinator::{run_serve, ServeOptions};
use ccache::util::bench::time;

fn main() {
    let (res, secs) = time(|| {
        run_serve(ServeOptions {
            jobs: 0,
            ..ServeOptions::default()
        })
    });

    res.table().print();

    println!("staleness-vs-throughput frontier (ccache cells):");
    for c in res.frontier() {
        println!(
            "  skew {:.2}  deadline {:>4}  stale max {:>4} mean {:>7.2}  {:.3} ops/kcyc",
            c.skew,
            c.deadline,
            c.staleness_max,
            c.staleness_mean,
            c.ops_per_kcycle()
        );
    }
    println!(
        "ccache beats atomic at {}/{} grid points; native check: {:?}; {:.1}s",
        res.ccache_wins_vs_atomic(),
        res.grid_points().len(),
        res.native_verified,
        secs
    );
}
