//! ccache — CLI for the CCache reproduction.
//!
//! Subcommands:
//!   run      — run one benchmark/variant, print stats + verification
//!              (`--backend native` executes on real OS threads;
//!              `--partition-ways`/`--partition-policy` fence the LLC
//!              merge region, `--corun N` adds a streaming co-runner)
//!   sweep    — working-set sweep (Fig 6-style table) for one benchmark
//!   partsweep— LLC capacity x partition x co-runner grid for the
//!              CCache variant (`--quick` for CI smoke, `--json` for
//!              the schema-checked record)
//!   protosweep— coherence protocol x variant x benchmark grid
//!              (mesi/dragon/partial; `--quick` for CI smoke, `--json`
//!              for the schema-checked record)
//!   serve    — kvserve serving sweep: merge-deadline x skew x variant
//!              staleness-vs-throughput frontier (`--tenants`,
//!              `--shards`, `--mix r:u:s`, `--skew-drift`,
//!              `--merge-deadline` pin the tier; composes with
//!              `--corun` and `--partition-ways`)
//!   bench    — perf_hotpath suite: engine throughput with fast/slow
//!              speedups; `--json BENCH_<n>.json` writes the
//!              perf-trajectory record (`--quick` for CI smoke)
//!   xval     — cross-validate the sim and native backends: every
//!              registered workload x variant on both, same goldens
//!   overhead — Section 4.7 structural overhead report
//!   runtime  — PJRT artifact smoke check (loads + executes merge_add)
//!   list     — enumerate registered benchmarks and their variants
//!
//! Benchmarks resolve through the workload registry
//! (`exec::registry`); merge functions resolve through the open merge
//! registry (`merge::registry`): `--list-merges` enumerates what is
//! installed, and `--merge name[:param]` overrides the merge function a
//! `run` installs in every MFRF slot (the caller vouches the override
//! matches the workload's update semantics — golden verification still
//! runs). There is no per-benchmark or per-merge dispatch here.
//! The machine is configurable: `--levels` picks the hierarchy depth
//! (2 = L1+LLC, 3 = the Table 2 shape, 4 = adds an L3) and
//! `--llc-kb`/`--l2-kb` resize levels; `--protocol` selects the
//! coherence protocol (`--list-protocols` enumerates the registry); an
//! illegal geometry — or a merge fault raised by the simulated machine
//! — prints a diagnostic and exits 2 instead of panicking.
//!
//! The streaming-sketch family (`cms`, `bloom`, `hll`) takes geometry
//! flags (`--cms-depth`, `--bloom-hashes`, `--hll-p`); its `max_u8x64`
//! merge function is registered *here*, through the public registry API
//! (`workloads::sketch::register_sketch_merges`) — consumer-side
//! registration, exactly what a downstream crate would do.
//!
//! Examples:
//!   ccache run --bench kvstore --variant ccache
//!   ccache run --bench histogram --variant atomic --backend native
//!   ccache xval --cores 4
//!   ccache run --bench kvstore --variant ccache --merge sat_add_u32:100
//!   ccache run --bench histogram --variant ccache --zipf 0.9
//!   ccache run --bench cms --variant ccache --zipf 0.99 --cms-depth 4
//!   ccache run --bench hll --variant ccache --hll-p 12
//!   ccache run --bench kvstore --variant ccache --levels 2 --llc-kb 512
//!   ccache run --bench kvstore --partition-ways 4 --partition-policy reuse --corun 2
//!   ccache sweep --bench bloom --jobs 8 --json bloom_sweep.json
//!   ccache partsweep --quick --json partsweep.json
//!   ccache run --bench kvstore --variant ccache --protocol dragon
//!   ccache protosweep --quick --json protosweep.json
//!   ccache --list-protocols
//!   ccache serve --quick --json serve.json
//!   ccache serve --tenants 8 --mix 80:15:5 --merge-deadline 32 --corun 2
//!   ccache run --bench kvserve --variant ccache --tenants 8 --skew-drift 0.3
//!   ccache --list-workloads
//!   ccache bench --quick --json BENCH_smoke.json
//!   ccache --list-merges
//!   ccache runtime

use ccache::coordinator::partsweep::{PART_CORUN_CORES, PART_WORK_CORES};
use ccache::coordinator::protosweep::PROTO_WORK_CORES;
use ccache::coordinator::serve::SERVE_WORK_CORES;
use ccache::coordinator::{
    perf, report, run_partsweep_on, run_protosweep_on, run_serve_on, run_sweep_with, run_xval,
    scaled_config, PartsweepOptions, ProtosweepOptions, ServeOptions, SweepOptions, XvalOptions,
    WS_FRACTIONS,
};
use ccache::exec::registry::{self, ServeSpec, SizeSpec, SketchSpec};
use ccache::exec::{Backend, CorunSpec, ExecError, Variant, WorkloadSpec};
use ccache::merge;
use ccache::merge::MergeRegistry;
use ccache::sim::config::MachineConfig;
use ccache::sim::hierarchy::level::PartitionPolicy;
use ccache::sim::hierarchy::protocol::ProtocolKind;
use ccache::sim::overhead::OverheadModel;
use ccache::util::cli::Args;
use ccache::workloads::sketch::register_sketch_merges;
use ccache::workloads::traffic::Mix;

fn fail(msg: impl std::fmt::Display) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2);
}

/// The CLI's merge registry: the built-ins + extensions, plus the
/// workload-layer sketch merges — registered through the same public
/// `register` call any downstream user gets (no `merge/` edits).
fn merge_registry() -> MergeRegistry {
    let mut reg = merge::default_registry();
    register_sketch_merges(&mut reg);
    reg
}

/// Reject a --zipf theta the benchmark would ignore or the sampler
/// cannot handle (Zipf requires theta > 0 and != 1).
fn check_zipf(spec: &WorkloadSpec, theta: f64) {
    if theta == 0.0 {
        return;
    }
    if !spec.key_skew {
        fail(format!(
            "--zipf only applies to workloads with a key distribution; {} has none",
            spec.name
        ));
    }
    if theta < 0.0 || theta == 1.0 {
        fail(format!(
            "--zipf must be > 0 and != 1 (theta=1 is unsupported; use 0.99), got {theta}"
        ));
    }
}

fn main() {
    let args = Args::new("ccache — CCache paper reproduction CLI")
        .opt("bench", "kvstore", "benchmark name or alias (see `ccache list`)")
        .opt("variant", "ccache", "cgl|fgl|dup|ccache|atomic")
        .opt("backend", "sim", "run/xval: execution backend, sim|native")
        .opt("frac", "1.0", "working set as a fraction of LLC capacity")
        .opt("seed", "42", "workload RNG seed")
        .opt("cores", "0", "override core count (0 = config default)")
        .opt("zipf", "0.0", "zipf key-skew theta for keyed workloads (0 = uniform)")
        .opt("cms-depth", "0", "count-min hash rows (0 = default 4)")
        .opt("bloom-hashes", "0", "Bloom probes per key (0 = default 4)")
        .opt("hll-p", "0", "HyperLogLog precision, registers = 2^p (0 = derived)")
        .opt("levels", "3", "hierarchy depth: 2 (L1+LLC), 3 (Table 2), 4 (adds an L3)")
        .opt("llc-kb", "0", "override shared LLC size in KiB (0 = config default)")
        .opt("l2-kb", "0", "override L2 size in KiB (0 = default; needs --levels >= 3)")
        .opt("partition-ways", "0", "run: LLC ways reserved for the merge region (0 = off)")
        .opt("partition-policy", "static", "run: static|reuse (reuse-aware resizing)")
        .opt("protocol", "mesi", "run/sweep: coherence protocol, mesi|dragon|partial")
        .opt("corun", "0", "streaming co-runner cores (run: 0 = none; partsweep: 0 = default 2)")
        .opt("jobs", "0", "sweep: parallel worker threads (0 = all host cores)")
        .opt("json", "", "sweep/bench: also write machine-readable results to this path")
        .opt("merge", "", "override the installed merge function: name[:param]")
        .opt("bench-id", "dev", "bench: trajectory label for the JSON record (BENCH_<id>.json)")
        .opt("tenants", "0", "kvserve: tenants in the serving tier (0 = default 4)")
        .opt("shards", "0", "kvserve: shards tenants map onto (0 = one per tenant)")
        .opt("mix", "", "kvserve: read:update:scan weights, e.g. 70:25:5 (default)")
        .opt("skew-drift", "-1", "kvserve: per-epoch skew drift amplitude (-1 = default 0.2)")
        .opt("merge-deadline", "0", "kvserve: soft-merge deadline, in updates (0 = default)")
        .flag("quick", "bench/partsweep/serve: trim the workload grid (CI smoke mode)")
        .flag("list-merges", "list registered merge functions and exit")
        .flag("list-workloads", "list registered workloads (variants, native support) and exit")
        .flag("list-protocols", "list registered coherence protocols and exit")
        .flag("full-size", "use the paper's full Table 2 geometry")
        .flag("no-merge-on-evict", "disable the merge-on-evict optimization")
        .flag("no-dirty-merge", "disable the dirty-merge optimization")
        .flag("verbose", "print full stats")
        .parse();

    if args.has("list-merges") {
        println!("merge functions (name — idempotent — summary):");
        for spec in merge_registry().iter() {
            let idem = spec
                .build(None)
                .map(|f| if f.idempotent() { "yes" } else { "no " })
                .unwrap_or("?  ");
            println!("  {:<18} {idem}  {}", spec.name, spec.summary);
        }
        println!("(select with --merge name[:param]; extend via merge::MergeRegistry)");
        return;
    }

    if args.has("list-protocols") {
        println!("coherence protocols (name — variants — summary):");
        for p in ProtocolKind::ALL {
            println!(
                "  {:<10} {:<24} {}",
                p.name(),
                p.supported_variants().join(" "),
                p.description()
            );
        }
        println!("(select with --protocol <name>; cross them all with `ccache protosweep`)");
        return;
    }

    if args.has("list-workloads") {
        println!("workloads (name — variants — native backend):");
        for spec in registry::registry() {
            let variants: Vec<&str> = spec.variants.iter().map(|v| v.name()).collect();
            println!(
                "  {:<14} {:<28} native={}",
                spec.name,
                variants.join(" "),
                if spec.native { "yes" } else { "no" }
            );
        }
        println!("(run one with `ccache run --bench <name>`; aliases via `ccache list`)");
        return;
    }

    let cmd = args
        .positional()
        .first()
        .cloned()
        .unwrap_or_else(|| "run".to_string());

    let mut cfg: MachineConfig = if args.has("full-size") {
        MachineConfig::default()
    } else {
        scaled_config()
    };
    if args.has("no-merge-on-evict") {
        cfg.ccache.merge_on_evict = false;
    }
    if args.has("no-dirty-merge") {
        cfg.ccache.dirty_merge = false;
    }
    let cores = args.get_usize("cores");
    if cores > 0 {
        cfg.cores = cores;
    }
    let levels = args.get_usize("levels");
    if levels != cfg.depth() {
        cfg = match cfg.with_depth(levels) {
            Ok(c) => c,
            Err(e) => fail(e),
        };
    }
    let llc_kb = args.get_usize("llc-kb");
    if llc_kb > 0 {
        cfg.llc_mut().size_bytes = llc_kb << 10;
    }
    let l2_kb = args.get_usize("l2-kb");
    if l2_kb > 0 {
        if cfg.depth() < 3 {
            fail("--l2-kb needs a hierarchy with an L2 (--levels 3 or 4)");
        }
        cfg.level_mut(1).size_bytes = l2_kb << 10;
    }
    let part_ways = args.get_usize("partition-ways");
    let part_policy = match args.get("partition-policy").as_str() {
        "static" => PartitionPolicy::Static,
        "reuse" | "reuse-aware" => PartitionPolicy::ReuseAware,
        other => fail(format!(
            "unknown --partition-policy '{other}'; use static|reuse"
        )),
    };
    match ProtocolKind::parse(&args.get("protocol")) {
        Some(p) => cfg.protocol = p,
        None => fail(format!(
            "unknown --protocol '{}'; use mesi|dragon|partial (see --list-protocols)",
            args.get("protocol")
        )),
    }
    let corun_cores = args.get_usize("corun");
    let zipf_theta = args.get_f64("zipf");
    let hll_p = args.get_usize("hll-p");
    if hll_p != 0 && !(4..=16).contains(&hll_p) {
        fail(format!("--hll-p must be 0 (derived) or in 4..=16, got {hll_p}"));
    }
    let sketch = SketchSpec {
        cms_depth: args.get_usize("cms-depth"),
        bloom_hashes: args.get_usize("bloom-hashes"),
        hll_precision: hll_p,
    };
    let mix = match args.get("mix").as_str() {
        "" => Mix::default(),
        s => match Mix::parse(s) {
            Ok(m) => m,
            Err(e) => fail(e),
        },
    };
    let serve_spec = ServeSpec {
        tenants: args.get_usize("tenants"),
        shards: args.get_usize("shards"),
        mix: (mix.read, mix.update, mix.scan),
        skew_drift: args.get_f64("skew-drift"),
        merge_deadline: args.get_usize("merge-deadline"),
    };

    match cmd.as_str() {
        "run" => {
            let variant = match Variant::parse(&args.get("variant")) {
                Some(v) => v,
                None => fail(ExecError::UnknownVariant {
                    name: args.get("variant"),
                }),
            };
            let spec = match registry::lookup(&args.get("bench")) {
                Ok(s) => s,
                Err(e) => fail(e),
            };
            check_zipf(spec, zipf_theta);
            let merge_override = match args.get("merge").as_str() {
                "" => None,
                spec_str => {
                    if variant != Variant::CCache {
                        // only the CCache variant installs merge functions;
                        // silently ignoring the override would misreport
                        fail(format!(
                            "--merge only applies to the ccache variant (got '{}')",
                            variant.name()
                        ));
                    }
                    match merge_registry().build(spec_str) {
                        Ok(f) => Some(f),
                        Err(e) => fail(e), // unknown merge / bad param -> exit 2
                    }
                }
            };
            let backend = match Backend::parse(&args.get("backend")) {
                Some(b) => b,
                None => fail(format!(
                    "unknown backend '{}'; use sim|native",
                    args.get("backend")
                )),
            };
            let size =
                SizeSpec::new(args.get_f64("frac"), cfg.llc().size_bytes, args.get_u64("seed"))
                    .with_zipf(zipf_theta)
                    .with_sketch(sketch)
                    .with_serve(serve_spec);
            let bench = spec.build(&size);
            if part_ways > 0 {
                cfg = cfg.with_partition(part_ways, part_policy);
                if let Err(e) = cfg.validate() {
                    fail(e); // e.g. ways >= LLC associativity -> exit 2
                }
            }
            let corun = (corun_cores > 0).then(|| CorunSpec::new(corun_cores));
            eprintln!(
                "running {} / {} ({} backend) on {}...",
                bench.name(),
                variant.name(),
                backend.name(),
                cfg.describe()
            );
            let r = match bench.run_on_with_corun(backend, variant, cfg.clone(), merge_override, corun)
            {
                Ok(r) => r,
                // unsupported variant / invalid config / merge fault /
                // co-runner on the native backend -> exit 2
                Err(e) => fail(e),
            };
            let work = match r.wall_secs {
                // native: measured ops + wall-clock throughput
                Some(secs) => format!(
                    "{} ops in {:.3} ms ({:.2} Mops/s)",
                    r.ops_total(),
                    secs * 1e3,
                    r.native_mops().unwrap_or(0.0)
                ),
                // sim: the model's currency is cycles
                None => format!("{} cycles", r.cycles()),
            };
            println!(
                "{}/{}: {}, verified={}{}{}",
                r.benchmark,
                r.variant.name(),
                work,
                r.verified,
                if r.merge_fns.is_empty() {
                    String::new()
                } else {
                    format!(", merges=[{}]", r.merge_fns.join(", "))
                },
                r.quality
                    .map(|q| format!(", quality degradation {:.1}%", q * 100.0))
                    .unwrap_or_default()
            );
            if args.has("verbose") {
                print!("{}", r.stats);
            }
            if !r.verified {
                std::process::exit(1);
            }
        }
        "sweep" => {
            let spec = match registry::lookup(&args.get("bench")) {
                Ok(s) => s,
                Err(e) => fail(e),
            };
            check_zipf(spec, zipf_theta);
            if !args.get("merge").is_empty() {
                fail("--merge applies to `run` only (sweeps install each workload's own merges)");
            }
            if part_ways > 0 || corun_cores > 0 {
                // a partition starves the non-CCache variants' ordinary
                // ways and a co-runner skews every baseline — the
                // partition experiment is `partsweep`
                fail("--partition-ways/--corun apply to `run` and `partsweep`, not `sweep`");
            }
            if let Some(v) = Variant::MAIN.iter().find(|v| !cfg.protocol.supports(v.name())) {
                // the sweep grid crosses every main variant, so a
                // protocol that rejects one cannot run it — the
                // cross-protocol experiment is `protosweep`
                fail(format!(
                    "sweep crosses the {} variant, which the {} protocol cannot run \
                     (use `ccache protosweep`)",
                    v.name(),
                    cfg.protocol.name()
                ));
            }
            if let Err(e) = cfg.validate() {
                fail(e);
            }
            let sweep = run_sweep_with(
                spec.name,
                &Variant::MAIN,
                &WS_FRACTIONS,
                cfg.clone(),
                SweepOptions {
                    seed: args.get_u64("seed"),
                    zipf_theta,
                    jobs: args.get_usize("jobs"),
                    sketch,
                },
            );
            report::fig6_table(&sweep).print();
            println!(
                "({} cells in {:.0} ms on {} jobs)",
                sweep.points.iter().map(|p| p.results.len()).sum::<usize>(),
                sweep.wall_clock_ms,
                sweep.jobs
            );
            let json_path = args.get("json");
            if !json_path.is_empty() {
                let payload = report::sweep_json(&sweep, &cfg);
                match std::fs::write(&json_path, payload) {
                    Ok(()) => eprintln!("wrote {json_path}"),
                    Err(e) => fail(format!("writing {json_path}: {e}")),
                }
            }
        }
        "partsweep" => {
            if part_ways > 0 {
                fail("partsweep crosses its own partition modes; --partition-ways applies to `run`");
            }
            if cores == 0 {
                cfg.cores = PART_WORK_CORES;
            }
            if let Err(e) = cfg.validate() {
                fail(e);
            }
            let opts = PartsweepOptions {
                quick: args.has("quick"),
                jobs: args.get_usize("jobs"),
                seed: args.get_u64("seed"),
                corun_cores: if corun_cores == 0 {
                    PART_CORUN_CORES
                } else {
                    corun_cores
                },
            };
            eprintln!(
                "partition sweep on {} ({} workload cores{})...",
                cfg.describe(),
                cfg.cores,
                if opts.quick { ", quick grid" } else { "" }
            );
            let r = run_partsweep_on(cfg.clone(), opts);
            r.table().print();
            println!(
                "({} cells in {:.0} ms on {} jobs; reuse-aware beats no-partition on {} \
                 co-runner cell(s))",
                r.cells.len(),
                r.wall_clock_ms,
                r.jobs,
                r.reuse_wins_under_corun().len()
            );
            let json_path = args.get("json");
            if !json_path.is_empty() {
                match std::fs::write(&json_path, r.to_json()) {
                    Ok(()) => eprintln!("wrote {json_path}"),
                    Err(e) => fail(format!("writing {json_path}: {e}")),
                }
            }
        }
        "protosweep" => {
            if cfg.protocol != ProtocolKind::Mesi {
                fail("protosweep crosses every protocol itself; --protocol applies to `run`/`sweep`");
            }
            if part_ways > 0 || corun_cores > 0 {
                fail("--partition-ways/--corun do not apply to `protosweep`");
            }
            if cores == 0 {
                cfg.cores = PROTO_WORK_CORES;
            }
            if let Err(e) = cfg.validate() {
                fail(e);
            }
            let opts = ProtosweepOptions {
                quick: args.has("quick"),
                jobs: args.get_usize("jobs"),
                seed: args.get_u64("seed"),
            };
            eprintln!(
                "protocol sweep on {} ({} workload cores{})...",
                cfg.describe(),
                cfg.cores,
                if opts.quick { ", quick grid" } else { "" }
            );
            let r = run_protosweep_on(cfg.clone(), opts);
            r.table().print();
            let wins: Vec<String> = r
                .ccache_wins_by_protocol()
                .iter()
                .map(|(p, n)| format!("{p}={n}"))
                .collect();
            println!(
                "({} cells in {:.0} ms on {} jobs; ccache outright wins by protocol: {}; \
                 {} cell(s) diverge from mesi)",
                r.cells.len(),
                r.wall_clock_ms,
                r.jobs,
                wins.join(" "),
                r.divergent_cells().len()
            );
            let json_path = args.get("json");
            if !json_path.is_empty() {
                match std::fs::write(&json_path, r.to_json()) {
                    Ok(()) => eprintln!("wrote {json_path}"),
                    Err(e) => fail(format!("writing {json_path}: {e}")),
                }
            }
        }
        "serve" => {
            if !args.get("merge").is_empty() {
                fail("--merge applies to `run` only (serve installs kvserve's own merges)");
            }
            if cores == 0 {
                cfg.cores = SERVE_WORK_CORES;
            }
            if let Err(e) = cfg.validate() {
                fail(e);
            }
            let opts = ServeOptions {
                quick: args.has("quick"),
                jobs: args.get_usize("jobs"),
                seed: args.get_u64("seed"),
                tenants: args.get_usize("tenants"),
                shards: args.get_usize("shards"),
                mix,
                skew_drift: {
                    let d = args.get_f64("skew-drift");
                    if d < 0.0 { 0.2 } else { d }
                },
                deadline: args.get_usize("merge-deadline"),
                corun_cores,
                partition_ways: part_ways,
                native_check: true,
            };
            eprintln!(
                "serving sweep on {} ({} front-end cores{}{}{})...",
                cfg.describe(),
                cfg.cores,
                if opts.quick { ", quick grid" } else { "" },
                if opts.corun_cores > 0 {
                    ", with co-runner"
                } else {
                    ""
                },
                if opts.partition_ways > 0 {
                    ", reuse-aware partition"
                } else {
                    ""
                }
            );
            let r = run_serve_on(cfg.clone(), opts);
            r.table().print();
            println!(
                "({} cells in {:.0} ms on {} jobs; ccache >= atomic on {}/{} grid points; \
                 native check: {})",
                r.cells.len(),
                r.wall_clock_ms,
                r.jobs,
                r.ccache_wins_vs_atomic(),
                r.grid_points().len(),
                match r.native_verified {
                    Some(true) => "verified",
                    Some(false) => "FAILED",
                    None => "skipped",
                }
            );
            let json_path = args.get("json");
            if !json_path.is_empty() {
                match std::fs::write(&json_path, r.to_json()) {
                    Ok(()) => eprintln!("wrote {json_path}"),
                    Err(e) => fail(format!("writing {json_path}: {e}")),
                }
            }
            if r.native_verified == Some(false) {
                std::process::exit(1);
            }
        }
        "bench" => {
            let bench_report = perf::run_suite(&perf::SuiteOptions {
                quick: args.has("quick"),
                bench_id: args.get("bench-id"),
            });
            bench_report.table().print();
            bench_report.native_table().print();
            bench_report.partition_table().print();
            bench_report.serve_table().print();
            bench_report.proto_table().print();
            println!(
                "(suite wall clock {:.1} s{})",
                bench_report.wall_clock_secs,
                if bench_report.quick { ", quick mode" } else { "" }
            );
            let json_path = args.get("json");
            if !json_path.is_empty() {
                match std::fs::write(&json_path, bench_report.to_json()) {
                    Ok(()) => eprintln!("wrote {json_path}"),
                    Err(e) => fail(format!("writing {json_path}: {e}")),
                }
            }
        }
        "xval" => {
            // the grid always runs both backends; --backend here would
            // suggest otherwise, so reject anything but the default
            if args.get("backend") != "sim" {
                fail("xval always runs both backends; --backend does not apply");
            }
            let opts = XvalOptions {
                cores: if cores > 0 { cores } else { 4 },
                frac: args.get_f64("frac").min(1.0),
                seed: args.get_u64("seed"),
                only: Vec::new(),
            };
            eprintln!(
                "cross-validating sim vs native: full registry, {} cores, frac {}...",
                opts.cores, opts.frac
            );
            let xr = run_xval(&opts);
            xr.table().print();
            println!("({} cells in {:.1} s)", xr.cells.len(), xr.wall_clock_secs);
            if !xr.all_verified() {
                eprintln!("cross-validation FAILED: {}", xr.failures().join(", "));
                std::process::exit(1);
            }
        }
        "overhead" => {
            let m = OverheadModel::for_config(&cfg);
            println!("CCache structural overhead (Section 4.7):");
            println!("  machine            : {}", cfg.describe());
            println!("  L1 extra bits/line : {}", m.l1_extra_bits_per_line);
            println!("  L1 extra bits total: {}", m.l1_extra_bits);
            println!("  source buffer bits : {}", m.src_buf_bits);
            println!("  MFRF bits          : {}", m.mfrf_bits);
            println!("  merge reg bits     : {}", m.merge_reg_bits);
            println!(
                "  src buffer / LLC   : {:.4}% (paper: ~0.1% for 32 entries)",
                m.src_buf_frac_of_llc() * 100.0
            );
            println!(
                "  ctx-switch state   : {} B (paper: <= 1 KB)",
                m.per_core_saved_state_bytes(&cfg)
            );
        }
        "runtime" => match ccache::runtime::Engine::load_default() {
            Ok(mut e) => {
                println!("PJRT platform: {}", e.platform());
                let entries: Vec<String> = e.manifest().entries.keys().cloned().collect();
                for entry in entries {
                    match e.executable(&entry) {
                        Ok(_) => println!("  {entry}: compiled OK"),
                        Err(err) => {
                            println!("  {entry}: FAILED: {err:#}");
                            std::process::exit(1);
                        }
                    }
                }
                println!("all artifacts loadable");
            }
            Err(e) => {
                eprintln!("runtime unavailable: {e:#}\n(run `make artifacts`)");
                std::process::exit(1);
            }
        },
        "list" => {
            println!("benchmarks (name [aliases] — variants):");
            for spec in registry::registry() {
                let variants: Vec<&str> = spec.variants.iter().map(|v| v.name()).collect();
                let aliases = if spec.aliases.is_empty() {
                    String::new()
                } else {
                    format!(" [{}]", spec.aliases.join(", "))
                };
                println!(
                    "  {:<18}{aliases:<24} {:<28} {}",
                    spec.name,
                    variants.join(" "),
                    spec.summary
                );
            }
        }
        other => {
            eprintln!(
                "unknown command {other}; use run|sweep|partsweep|protosweep|serve|bench|xval|overhead|runtime|list"
            );
            std::process::exit(2);
        }
    }
}
