//! ccache — CLI for the CCache reproduction.
//!
//! Subcommands:
//!   run      — run one benchmark/variant, print stats + verification
//!   sweep    — working-set sweep (Fig 6-style table) for one benchmark
//!   overhead — Section 4.7 structural overhead report
//!   runtime  — PJRT artifact smoke check (loads + executes merge_add)
//!   list     — enumerate benchmarks and variants
//!
//! Examples:
//!   ccache run --bench kvstore --variant ccache --keys 65536
//!   ccache sweep --bench pagerank-rmat
//!   ccache runtime

use ccache::coordinator::{report, run_sweep, scaled_config, sized_benchmark, BenchKind, WS_FRACTIONS};
use ccache::exec::Variant;
use ccache::sim::config::MachineConfig;
use ccache::sim::overhead::OverheadModel;
use ccache::util::cli::Args;
use ccache::workloads::graph::GraphKind;

fn parse_bench(name: &str) -> Option<BenchKind> {
    match name {
        "kvstore" | "kv" => Some(BenchKind::KvAdd),
        "kvstore-sat" => Some(BenchKind::KvSat),
        "kvstore-cmul" => Some(BenchKind::KvCmul),
        "kmeans" => Some(BenchKind::KMeans),
        "kmeans-approx" => Some(BenchKind::KMeansApprox),
        _ => {
            if let Some(g) = name.strip_prefix("pagerank-") {
                GraphKind::parse(g).map(BenchKind::PageRank)
            } else if let Some(g) = name.strip_prefix("bfs-") {
                GraphKind::parse(g).map(BenchKind::Bfs)
            } else if name == "pagerank" {
                Some(BenchKind::PageRank(GraphKind::Uniform))
            } else if name == "bfs" {
                Some(BenchKind::Bfs(GraphKind::Rmat))
            } else {
                None
            }
        }
    }
}

fn main() {
    let args = Args::new("ccache — CCache paper reproduction CLI")
        .opt("bench", "kvstore", "benchmark: kvstore[-sat|-cmul], kmeans[-approx], pagerank-<rmat|ssca|uniform>, bfs-<rmat|uniform>")
        .opt("variant", "ccache", "cgl|fgl|dup|ccache|atomic")
        .opt("frac", "1.0", "working set as a fraction of LLC capacity")
        .opt("seed", "42", "workload RNG seed")
        .opt("cores", "0", "override core count (0 = config default)")
        .flag("full-size", "use the paper's full Table 2 geometry")
        .flag("no-merge-on-evict", "disable the merge-on-evict optimization")
        .flag("no-dirty-merge", "disable the dirty-merge optimization")
        .flag("verbose", "print full stats")
        .parse();

    let cmd = args
        .positional()
        .first()
        .cloned()
        .unwrap_or_else(|| "run".to_string());

    let mut cfg: MachineConfig = if args.has("full-size") {
        MachineConfig::default()
    } else {
        scaled_config()
    };
    if args.has("no-merge-on-evict") {
        cfg.ccache.merge_on_evict = false;
    }
    if args.has("no-dirty-merge") {
        cfg.ccache.dirty_merge = false;
    }
    let cores = args.get_usize("cores");
    if cores > 0 {
        cfg.cores = cores;
    }

    match cmd.as_str() {
        "run" => {
            let kind = parse_bench(&args.get("bench"))
                .unwrap_or_else(|| panic!("unknown benchmark {}", args.get("bench")));
            let variant = Variant::parse(&args.get("variant"))
                .unwrap_or_else(|| panic!("unknown variant {}", args.get("variant")));
            let bench = sized_benchmark(
                kind,
                args.get_f64("frac"),
                cfg.llc.size_bytes,
                args.get_u64("seed"),
            );
            eprintln!(
                "running {} / {} on {} cores (LLC {} KiB)...",
                bench.name(),
                variant.name(),
                cfg.cores,
                cfg.llc.size_bytes / 1024
            );
            let r = bench.run(variant, cfg);
            println!(
                "{}/{}: {} cycles, verified={}{}",
                r.benchmark,
                r.variant.name(),
                r.cycles(),
                r.verified,
                r.quality
                    .map(|q| format!(", quality degradation {:.1}%", q * 100.0))
                    .unwrap_or_default()
            );
            if args.has("verbose") {
                print!("{}", r.stats);
            }
            if !r.verified {
                std::process::exit(1);
            }
        }
        "sweep" => {
            let kind = parse_bench(&args.get("bench"))
                .unwrap_or_else(|| panic!("unknown benchmark {}", args.get("bench")));
            let sweep = run_sweep(
                kind,
                &Variant::MAIN,
                &WS_FRACTIONS,
                cfg,
                args.get_u64("seed"),
            );
            report::fig6_table(&sweep).print();
        }
        "overhead" => {
            let m = OverheadModel::for_config(&cfg);
            println!("CCache structural overhead (Section 4.7):");
            println!("  L1 extra bits/line : {}", m.l1_extra_bits_per_line);
            println!("  L1 extra bits total: {}", m.l1_extra_bits);
            println!("  source buffer bits : {}", m.src_buf_bits);
            println!("  MFRF bits          : {}", m.mfrf_bits);
            println!("  merge reg bits     : {}", m.merge_reg_bits);
            println!(
                "  src buffer / LLC   : {:.4}% (paper: ~0.1% for 32 entries)",
                m.src_buf_frac_of_llc() * 100.0
            );
            println!(
                "  ctx-switch state   : {} B (paper: <= 1 KB)",
                m.per_core_saved_state_bytes(&cfg)
            );
        }
        "runtime" => match ccache::runtime::Engine::load_default() {
            Ok(mut e) => {
                println!("PJRT platform: {}", e.platform());
                let entries: Vec<String> =
                    e.manifest().entries.keys().cloned().collect();
                for entry in entries {
                    match e.executable(&entry) {
                        Ok(_) => println!("  {entry}: compiled OK"),
                        Err(err) => {
                            println!("  {entry}: FAILED: {err:#}");
                            std::process::exit(1);
                        }
                    }
                }
                println!("all artifacts loadable");
            }
            Err(e) => {
                eprintln!("runtime unavailable: {e:#}\n(run `make artifacts`)");
                std::process::exit(1);
            }
        },
        "list" => {
            println!("benchmarks:");
            for k in BenchKind::fig6_panels() {
                println!("  {}", k.name());
            }
            println!("variants: cgl fgl dup ccache atomic");
        }
        other => {
            eprintln!("unknown command {other}; use run|sweep|overhead|runtime|list");
            std::process::exit(2);
        }
    }
}
