//! Typed execution errors: the driver and registry report unsupported
//! variants and unknown benchmark names as values instead of panicking,
//! so the CLI can print a clean message and sweeps can skip a cell.

use std::fmt;

use crate::sim::config::ConfigError;

use super::Variant;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExecError {
    /// The benchmark does not implement this execution variant (e.g. the
    /// paper only evaluates atomics for BFS).
    UnsupportedVariant {
        benchmark: String,
        variant: Variant,
        supported: Vec<Variant>,
    },
    /// No registered workload matches this name or alias.
    UnknownBenchmark { name: String, known: Vec<String> },
    /// Not one of [`Variant::ALL`].
    UnknownVariant { name: String },
    /// The machine configuration failed validation (bad geometry,
    /// malformed hierarchy, ...). Carries the simulator's typed error so
    /// the CLI prints the diagnostic and exits instead of panicking.
    InvalidConfig(ConfigError),
}

impl From<ConfigError> for ExecError {
    fn from(e: ConfigError) -> Self {
        ExecError::InvalidConfig(e)
    }
}

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecError::UnsupportedVariant {
                benchmark,
                variant,
                supported,
            } => {
                let names: Vec<&str> = supported.iter().map(|v| v.name()).collect();
                write!(
                    f,
                    "{benchmark} does not support variant '{}' (supported: {})",
                    variant.name(),
                    names.join(" ")
                )
            }
            ExecError::UnknownBenchmark { name, known } => {
                write!(
                    f,
                    "unknown benchmark '{name}' (known: {})",
                    known.join(" ")
                )
            }
            ExecError::UnknownVariant { name } => {
                let names: Vec<&str> = Variant::ALL.iter().map(|v| v.name()).collect();
                write!(f, "unknown variant '{name}' (use {})", names.join("|"))
            }
            ExecError::InvalidConfig(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for ExecError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_are_actionable() {
        let e = ExecError::UnsupportedVariant {
            benchmark: "kmeans".into(),
            variant: Variant::Atomic,
            supported: vec![Variant::Fgl, Variant::Dup, Variant::CCache],
        };
        let msg = e.to_string();
        assert!(msg.contains("kmeans"));
        assert!(msg.contains("atomic"));
        assert!(msg.contains("fgl dup ccache"));

        let e = ExecError::UnknownBenchmark {
            name: "nope".into(),
            known: vec!["kvstore".into(), "histogram".into()],
        };
        assert!(e.to_string().contains("kvstore histogram"));
    }

    #[test]
    fn invalid_config_wraps_the_sim_diagnostic() {
        let mut cfg = crate::sim::config::MachineConfig::default();
        cfg.l1_mut().size_bytes = 1000;
        let sim_err = cfg.validate().unwrap_err();
        let e: ExecError = sim_err.clone().into();
        assert_eq!(e, ExecError::InvalidConfig(sim_err.clone()));
        assert_eq!(e.to_string(), sim_err.to_string());
    }
}
