//! Typed execution errors: the driver and registry report unsupported
//! variants and unknown benchmark names as values instead of panicking,
//! so the CLI can print a clean message and sweeps can skip a cell.

use std::fmt;

use crate::sim::config::ConfigError;
use crate::sim::invariant::InvariantViolation;
use crate::sim::mfrf::MergeFault;

use super::Variant;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExecError {
    /// The benchmark does not implement this execution variant (e.g. the
    /// paper only evaluates atomics for BFS).
    UnsupportedVariant {
        benchmark: String,
        variant: Variant,
        supported: Vec<Variant>,
    },
    /// The configured coherence protocol cannot run this execution
    /// variant — partial coherence has no coherent RMWs, so the
    /// lock-based and atomics variants are rejected before the machine
    /// is built.
    UnsupportedProtocol {
        benchmark: String,
        protocol: &'static str,
        variant: Variant,
        supported: Vec<Variant>,
    },
    /// No registered workload matches this name or alias.
    UnknownBenchmark { name: String, known: Vec<String> },
    /// Not one of [`Variant::ALL`].
    UnknownVariant { name: String },
    /// The machine configuration failed validation (bad geometry,
    /// malformed hierarchy, ...). Carries the simulator's typed error so
    /// the CLI prints the diagnostic and exits instead of panicking.
    InvalidConfig(ConfigError),
    /// A core used a merge type whose MFRF slot holds no merge function
    /// — the simulated machine faulted. Carries the typed fault so the
    /// CLI prints the diagnostic and exits 2 instead of panicking.
    MergeFault(MergeFault),
    /// The post-run consistency sweep found the simulated machine in an
    /// inconsistent state (directory bookkeeping, source-buffer/L1
    /// bindings). Carries the structured violation so stress-suite
    /// failures name the structure, core and line instead of a bare
    /// string.
    Invariant(InvariantViolation),
    /// The co-runner stressor cannot be applied to this run (native
    /// backend, or more stressor cores than the machine can add).
    Corun { reason: String },
}

impl From<ConfigError> for ExecError {
    fn from(e: ConfigError) -> Self {
        ExecError::InvalidConfig(e)
    }
}

impl From<MergeFault> for ExecError {
    fn from(f: MergeFault) -> Self {
        ExecError::MergeFault(f)
    }
}

impl From<InvariantViolation> for ExecError {
    fn from(v: InvariantViolation) -> Self {
        ExecError::Invariant(v)
    }
}

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecError::UnsupportedVariant {
                benchmark,
                variant,
                supported,
            } => {
                let names: Vec<&str> = supported.iter().map(|v| v.name()).collect();
                write!(
                    f,
                    "{benchmark} does not support variant '{}' (supported: {})",
                    variant.name(),
                    names.join(" ")
                )
            }
            ExecError::UnsupportedProtocol {
                benchmark,
                protocol,
                variant,
                supported,
            } => {
                let names: Vec<&str> = supported.iter().map(|v| v.name()).collect();
                write!(
                    f,
                    "the {protocol} protocol cannot run {benchmark} variant '{}' \
                     (it needs coherent RMWs; supported under {protocol}: {})",
                    variant.name(),
                    names.join(" ")
                )
            }
            ExecError::UnknownBenchmark { name, known } => {
                write!(
                    f,
                    "unknown benchmark '{name}' (known: {})",
                    known.join(" ")
                )
            }
            ExecError::UnknownVariant { name } => {
                let names: Vec<&str> = Variant::ALL.iter().map(|v| v.name()).collect();
                write!(f, "unknown variant '{name}' (use {})", names.join("|"))
            }
            ExecError::InvalidConfig(e) => write!(f, "{e}"),
            ExecError::MergeFault(fault) => write!(f, "{fault}"),
            ExecError::Invariant(v) => write!(f, "{v}"),
            ExecError::Corun { reason } => write!(f, "co-runner stressor: {reason}"),
        }
    }
}

impl std::error::Error for ExecError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_are_actionable() {
        let e = ExecError::UnsupportedVariant {
            benchmark: "kmeans".into(),
            variant: Variant::Atomic,
            supported: vec![Variant::Fgl, Variant::Dup, Variant::CCache],
        };
        let msg = e.to_string();
        assert!(msg.contains("kmeans"));
        assert!(msg.contains("atomic"));
        assert!(msg.contains("fgl dup ccache"));

        let e = ExecError::UnknownBenchmark {
            name: "nope".into(),
            known: vec!["kvstore".into(), "histogram".into()],
        };
        assert!(e.to_string().contains("kvstore histogram"));
    }

    #[test]
    fn protocol_rejection_names_protocol_variant_and_alternatives() {
        let e = ExecError::UnsupportedProtocol {
            benchmark: "kvstore".into(),
            protocol: "partial",
            variant: Variant::Fgl,
            supported: vec![Variant::Dup, Variant::CCache],
        };
        let msg = e.to_string();
        assert!(msg.contains("partial"), "{msg}");
        assert!(msg.contains("kvstore"), "{msg}");
        assert!(msg.contains("'fgl'"), "{msg}");
        assert!(msg.contains("dup ccache"), "{msg}");
    }

    #[test]
    fn merge_fault_wraps_the_machine_diagnostic() {
        let fault = MergeFault {
            core: 3,
            slot: 2,
            slots: 4,
        };
        let e: ExecError = fault.clone().into();
        assert_eq!(e, ExecError::MergeFault(fault.clone()));
        assert_eq!(e.to_string(), fault.to_string());
        assert!(e.to_string().contains("merge_init"));
    }

    #[test]
    fn invariant_violation_wraps_the_sim_diagnostic() {
        let v = InvariantViolation::engine(1, 0xc0, "CData line lacks src-buf entry");
        let e: ExecError = v.clone().into();
        assert_eq!(e, ExecError::Invariant(v.clone()));
        assert_eq!(e.to_string(), v.to_string());
        assert!(e.to_string().contains("core 1"));
    }

    #[test]
    fn corun_rejection_names_the_reason() {
        let e = ExecError::Corun {
            reason: "the native backend has no cycle-accurate co-runner model".into(),
        };
        assert!(e.to_string().starts_with("co-runner stressor:"), "{e}");
        assert!(e.to_string().contains("native backend"), "{e}");
    }

    #[test]
    fn invalid_config_wraps_the_sim_diagnostic() {
        let mut cfg = crate::sim::config::MachineConfig::default();
        cfg.l1_mut().size_bytes = 1000;
        let sim_err = cfg.validate().unwrap_err();
        let e: ExecError = sim_err.clone().into();
        assert_eq!(e, ExecError::InvalidConfig(sim_err.clone()));
        assert_eq!(e.to_string(), sim_err.to_string());
    }
}
