//! The [`Workload`] trait: the one interface every benchmark implements.
//!
//! A workload owns its parameters and (host-side) input data and
//! describes four things to the generic driver:
//! memory [`setup`](Workload::setup), the per-core
//! [`program`](Workload::program), the sequential
//! [`golden`](Workload::golden) reference, and final-state
//! [`verify`](Workload::verify)cation. Everything else — machine
//! construction, merge-region registration (`merge_init`), running one
//! program per core, stats collection — lives in
//! [`driver::run`](super::driver::run), so a new benchmark is a single
//! trait impl (see `workloads::histogram` for the template).

use std::sync::Arc;

use crate::merge::MergeHandle;
use crate::sim::config::MachineConfig;
use crate::sim::memsys::MemSystem;

use super::ctx::ExecCtx;
use super::error::ExecError;
use super::{Backend, CorunSpec, RunResult, Variant};

pub trait Workload: Send + Sync {
    /// Simulated-memory layout produced by [`Workload::setup`] and handed
    /// to every core's program; cheap to clone (addresses and strides).
    type Layout: Clone + Send + Sync;
    /// Result of the sequential golden run, consumed by verification.
    type Golden: Send + Sync;

    /// Display name; becomes [`RunResult::benchmark`].
    fn name(&self) -> String;

    /// The execution variants this benchmark implements. The driver
    /// rejects anything else with [`ExecError::UnsupportedVariant`]
    /// before touching the machine.
    fn supported_variants(&self) -> Vec<Variant>;

    /// Working-set bytes of the contended structure (the Fig 6 x-axis).
    fn footprint(&self) -> u64;

    /// Merge functions to install in each core's MFRF under the CCache
    /// variant: `(slot, handle)` pairs. The driver issues the
    /// `merge_init` COps so programs never have to. Any
    /// [`MergeHandle`] works here — built-in, registry-built, or a
    /// user-defined [`MergeFn`](crate::merge::MergeFn) impl.
    fn merge_slots(&self) -> Vec<(usize, MergeHandle)> {
        Vec::new()
    }

    /// Allocate and initialize simulated memory, including per-variant
    /// scaffolding (lock arrays, DUP copies — see
    /// [`super::scaffold`]).
    fn setup(&self, mem: &mut MemSystem, variant: Variant, cores: usize) -> Self::Layout;

    /// The program core `core` of `cores` executes. Generic over the
    /// execution context ([`ExecCtx`]): the same body runs on the
    /// simulator's `CoreCtx` and the native backend's `NativeCtx`.
    fn program<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        cores: usize,
        variant: Variant,
        layout: &Self::Layout,
    );

    /// Native-backend entry point: what one OS thread runs under
    /// [`Backend::Native`]. Defaults to the same per-core
    /// [`program`](Workload::program) — override only if a workload
    /// needs backend-specific behavior (none of the built-ins do; the
    /// point of [`ExecCtx`] is that they don't have to).
    fn native_program<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        cores: usize,
        variant: Variant,
        layout: &Self::Layout,
    ) {
        self.program(ctx, core, cores, variant, layout);
    }

    /// Sequential golden run (host-side, untimed).
    fn golden(&self, cores: usize) -> Self::Golden;

    /// Compare the final simulated-memory state against the golden run;
    /// returns `(verified, quality)` where `quality` is an optional
    /// degradation metric for approximate variants.
    fn verify(
        &self,
        mem: &mut MemSystem,
        layout: &Self::Layout,
        golden: &Self::Golden,
        cores: usize,
    ) -> (bool, Option<f64>);
}

/// A type-erased, ready-to-run workload: what the registry hands to the
/// CLI, the coordinator and the sweep machinery. Construction captures a
/// concrete [`Workload`] impl; every run goes through
/// [`driver::run`](super::driver::run).
pub struct WorkloadHandle {
    name: String,
    variants: Vec<Variant>,
    footprint: u64,
    runner: Box<
        dyn Fn(
                Backend,
                Variant,
                MachineConfig,
                Option<MergeHandle>,
                Option<CorunSpec>,
            ) -> Result<RunResult, ExecError>
            + Send
            + Sync,
    >,
}

impl WorkloadHandle {
    pub fn new<W: Workload + 'static>(workload: W) -> Self {
        let name = workload.name();
        let variants = workload.supported_variants();
        let footprint = workload.footprint();
        let workload = Arc::new(workload);
        Self {
            name,
            variants,
            footprint,
            runner: Box::new(move |backend, variant, cfg, merge, corun| {
                match backend {
                    Backend::Sim => {
                        super::driver::run_sim(&*workload, variant, cfg, merge, corun)
                    }
                    Backend::Native => {
                        if corun.is_some_and(|c| c.cores > 0) {
                            return Err(ExecError::Corun {
                                reason: "the native backend has no cycle-accurate \
                                         co-runner model (use --backend sim)"
                                    .to_string(),
                            });
                        }
                        super::driver::run_native_with_merge(&*workload, variant, cfg, merge)
                    }
                }
            }),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn supported_variants(&self) -> &[Variant] {
        &self.variants
    }

    pub fn supports(&self, variant: Variant) -> bool {
        self.variants.contains(&variant)
    }

    /// Working-set bytes of the contended structure.
    pub fn footprint(&self) -> u64 {
        self.footprint
    }

    pub fn run(&self, variant: Variant, cfg: MachineConfig) -> Result<RunResult, ExecError> {
        (self.runner)(Backend::Sim, variant, cfg, None, None)
    }

    /// Simulated run with an optional cache-hostile co-runner
    /// ([`CorunSpec`]): the `--corun N` CLI flag and the partsweep's
    /// with-co-runner cells. `None` (or zero stressor cores) is
    /// byte-identical to [`run`](WorkloadHandle::run).
    pub fn run_corun(
        &self,
        variant: Variant,
        cfg: MachineConfig,
        corun: Option<CorunSpec>,
    ) -> Result<RunResult, ExecError> {
        (self.runner)(Backend::Sim, variant, cfg, None, corun)
    }

    /// Run with every MFRF slot's merge function replaced by `merge`
    /// (the CLI's `--merge name[:param]` override and the extension
    /// path of `examples/custom_merge.rs`). The caller vouches that the
    /// override is compatible with the workload's update semantics —
    /// golden verification still runs and reports divergence.
    pub fn run_with_merge(
        &self,
        variant: Variant,
        cfg: MachineConfig,
        merge: Option<MergeHandle>,
    ) -> Result<RunResult, ExecError> {
        (self.runner)(Backend::Sim, variant, cfg, merge, None)
    }

    /// Run on an explicit [`Backend`] (`--backend native` takes this
    /// path); goldens and verification are backend-independent.
    pub fn run_on(
        &self,
        backend: Backend,
        variant: Variant,
        cfg: MachineConfig,
    ) -> Result<RunResult, ExecError> {
        (self.runner)(backend, variant, cfg, None, None)
    }

    /// The general form: backend, merge override and co-runner all
    /// explicit (the CLI `run` path). A co-runner on the native backend
    /// is rejected with [`ExecError::Corun`].
    pub fn run_on_with_corun(
        &self,
        backend: Backend,
        variant: Variant,
        cfg: MachineConfig,
        merge: Option<MergeHandle>,
        corun: Option<CorunSpec>,
    ) -> Result<RunResult, ExecError> {
        (self.runner)(backend, variant, cfg, merge, corun)
    }

    /// [`run_on`](WorkloadHandle::run_on) with a merge override.
    pub fn run_on_with_merge(
        &self,
        backend: Backend,
        variant: Variant,
        cfg: MachineConfig,
        merge: Option<MergeHandle>,
    ) -> Result<RunResult, ExecError> {
        (self.runner)(backend, variant, cfg, merge, None)
    }
}
