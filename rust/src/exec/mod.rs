//! The execution layer: variants, the [`Workload`] trait, the generic
//! [`driver`], and the workload [`registry`].
//!
//! Every benchmark is implemented in up to five variants over the *same*
//! simulated machine:
//! * [`Variant::Cgl`] — coarse-grained locking (one lock for the shared
//!   structure; Figure 1 baseline, used in ablations)
//! * [`Variant::Fgl`] — fine-grained locking (lock per element/word)
//! * [`Variant::Dup`] — static data duplication + reduction at phase end
//! * [`Variant::CCache`] — the paper's system: COps + merge functions
//! * [`Variant::Atomic`] — HW atomic RMW (BFS + histogram)
//!
//! Each workload implements the [`Workload`] trait (setup / program /
//! golden / verify); [`driver::run`] owns the rest of the skeleton —
//! machine construction, merge-region registration, stats collection and
//! golden verification — and returns a [`RunResult`] whose `verified`
//! flag is the paper's Section 3 serializability check. Variants a
//! workload doesn't implement surface as
//! [`ExecError::UnsupportedVariant`] instead of panicking.

pub mod ctx;
pub mod driver;
pub mod error;
pub mod registry;
pub mod scaffold;
pub mod workload;

pub use ctx::ExecCtx;
pub use error::ExecError;
pub use registry::{SizeSpec, SketchSpec, WorkloadSpec};
pub use workload::{Workload, WorkloadHandle};

use crate::sim::stats::Stats;

/// Which machine carries out a workload program.
///
/// * [`Backend::Sim`] — the execution-driven simulator: deterministic
///   logical-core interleaving over the modeled hierarchy; results are
///   cycle counts.
/// * [`Backend::Native`] — real OS threads over `AtomicU32` shared
///   memory ([`runtime::native`](crate::runtime::native)); results are
///   wall-clock measurements, verified against the *same* goldens.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Backend {
    #[default]
    Sim,
    Native,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Sim => "sim",
            Backend::Native => "native",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sim" | "simulator" => Some(Backend::Sim),
            "native" | "threads" => Some(Backend::Native),
            _ => None,
        }
    }
}

/// A cache-hostile co-runner riding next to a simulated workload: extra
/// cores running a streaming coherent scan over a buffer larger than
/// the LLC, evicting the workload's shared-level footprint for as long
/// as the workload runs. The stressor behind the `partsweep`
/// with-co-runner cells and the CLI `--corun` flag; only the simulator
/// backend supports it (native runs measure wall-clock on real cores,
/// where a synthetic scanner would just measure host scheduling).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CorunSpec {
    /// Cores added to the machine for the scanner (the workload keeps
    /// its own cores; reported cycles cover workload cores only).
    pub cores: usize,
    /// Scan working set in cache lines; 0 derives 2x the LLC's line
    /// capacity, enough to defeat any LRU retention.
    pub lines: u64,
}

impl CorunSpec {
    pub fn new(cores: usize) -> Self {
        Self { cores, lines: 0 }
    }

    /// The scan footprint in lines for a machine whose LLC holds
    /// `llc_lines` lines.
    pub fn effective_lines(&self, llc_lines: u64) -> u64 {
        if self.lines == 0 {
            llc_lines * 2
        } else {
            self.lines
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    Cgl,
    Fgl,
    Dup,
    CCache,
    Atomic,
}

impl Variant {
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Cgl => "cgl",
            Variant::Fgl => "fgl",
            Variant::Dup => "dup",
            Variant::CCache => "ccache",
            Variant::Atomic => "atomic",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "cgl" => Some(Variant::Cgl),
            "fgl" => Some(Variant::Fgl),
            "dup" => Some(Variant::Dup),
            "ccache" => Some(Variant::CCache),
            "atomic" | "atomics" => Some(Variant::Atomic),
            _ => None,
        }
    }

    /// The trio every figure compares.
    pub const MAIN: [Variant; 3] = [Variant::Fgl, Variant::Dup, Variant::CCache];

    /// Every variant, in display order.
    pub const ALL: [Variant; 5] = [
        Variant::Cgl,
        Variant::Fgl,
        Variant::Dup,
        Variant::CCache,
        Variant::Atomic,
    ];
}

/// Outcome of one benchmark run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub benchmark: String,
    pub variant: Variant,
    pub stats: Stats,
    /// Did the final memory state match the sequential golden run?
    pub verified: bool,
    /// Optional quality metric (approximate K-Means reports intra-cluster
    /// distance degradation here).
    pub quality: Option<f64>,
    /// Stable names of the merge functions actually installed in the
    /// MFRF for this run (CCache variant; empty otherwise) — the merge
    /// identity reports and `sweep --json` emit.
    pub merge_fns: Vec<String>,
    /// Wall-clock seconds of the parallel section under
    /// [`Backend::Native`] (`None` for simulated runs, whose currency is
    /// cycles). Native runs repurpose `stats.core_cycles` as per-core
    /// *operation* counts, so `ops_total / wall_secs` is the measured
    /// throughput the cross-validation reports.
    pub wall_secs: Option<f64>,
}

impl RunResult {
    pub fn cycles(&self) -> u64 {
        self.stats.total_cycles()
    }

    pub fn assert_verified(&self) -> &Self {
        assert!(
            self.verified,
            "{}/{}: final state diverged from sequential golden run",
            self.benchmark,
            self.variant.name()
        );
        self
    }

    /// Total operations across cores (native runs; for simulated runs
    /// this sums per-core cycle counts instead).
    pub fn ops_total(&self) -> u64 {
        self.stats.core_cycles.iter().sum()
    }

    /// Measured native throughput in Mops/s (`None` for simulated runs).
    pub fn native_mops(&self) -> Option<f64> {
        self.wall_secs
            .filter(|&s| s > 0.0)
            .map(|s| self.ops_total() as f64 / s / 1e6)
    }
}

/// Speedup of `other` relative to `base` (cycles ratio, >1 = faster).
pub fn speedup(base: &RunResult, other: &RunResult) -> f64 {
    base.cycles() as f64 / other.cycles() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_names_roundtrip() {
        for b in [Backend::Sim, Backend::Native] {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
        assert_eq!(Backend::parse("threads"), Some(Backend::Native));
        assert_eq!(Backend::parse("nope"), None);
        assert_eq!(Backend::default(), Backend::Sim);
    }

    #[test]
    fn variant_names_roundtrip() {
        for v in [
            Variant::Cgl,
            Variant::Fgl,
            Variant::Dup,
            Variant::CCache,
            Variant::Atomic,
        ] {
            assert_eq!(Variant::parse(v.name()), Some(v));
        }
        assert_eq!(Variant::parse("nope"), None);
    }

    #[test]
    fn speedup_ratio() {
        let mk = |cyc: u64| RunResult {
            benchmark: "b".into(),
            variant: Variant::Fgl,
            stats: {
                let mut s = Stats::new(1, 3);
                s.core_cycles = vec![cyc];
                s
            },
            verified: true,
            quality: None,
            merge_fns: Vec::new(),
            wall_secs: None,
        };
        assert_eq!(speedup(&mk(200), &mk(100)), 2.0);
    }
}
