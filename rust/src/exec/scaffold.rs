//! Per-variant memory scaffolding shared by all workloads: lock arrays
//! for CGL/FGL and per-core private copies for DUP. Keeping the layout
//! math here (padded strides, line alignment) means every workload's
//! Table 3 footprint comes from the same rules.

use crate::exec::ExecCtx;
use crate::sim::addr::Addr;
use crate::sim::memsys::MemSystem;

/// A pthread-mutex-sized lock object (40 B), the FGL footprint unit the
/// paper's Table 3 measures for the KV store.
pub const PTHREAD_LOCK_BYTES: u64 = 40;

/// An array of `n` spin locks at a fixed byte stride. Stride choices:
/// [`PTHREAD_LOCK_BYTES`] for mutex-sized locks, 64 for one padded lock
/// per line, 4 for packed word locks.
#[derive(Clone, Copy, Debug)]
pub struct LockArray {
    base: Addr,
    stride: u64,
}

impl LockArray {
    pub fn alloc(mem: &mut MemSystem, n: u64, stride: u64) -> Self {
        Self {
            base: mem.alloc_lines(n * stride),
            stride,
        }
    }

    /// Placeholder for variants that allocate no locks.
    pub fn none() -> Self {
        Self {
            base: Addr(0),
            stride: 0,
        }
    }

    pub fn addr(&self, i: u64) -> Addr {
        self.base.add(i * self.stride)
    }

    pub fn lock<C: ExecCtx>(&self, ctx: &mut C, i: u64) {
        ctx.lock(self.addr(i));
    }

    pub fn unlock<C: ExecCtx>(&self, ctx: &mut C, i: u64) {
        ctx.unlock(self.addr(i));
    }
}

/// Per-core private copies of a structure (the DUP variant): `cores`
/// copies of `bytes` each, strides padded to whole cache lines so
/// copies never false-share.
#[derive(Clone, Copy, Debug)]
pub struct DupSpace {
    base: Addr,
    stride: u64,
}

impl DupSpace {
    pub fn alloc(mem: &mut MemSystem, bytes_per_copy: u64, cores: usize) -> Self {
        let stride = bytes_per_copy.next_multiple_of(64);
        Self {
            base: mem.alloc_lines(stride * cores as u64),
            stride,
        }
    }

    /// Placeholder for variants that duplicate nothing.
    pub fn none() -> Self {
        Self {
            base: Addr(0),
            stride: 0,
        }
    }

    /// Base address of `core`'s private copy.
    pub fn copy_base(&self, core: usize) -> Addr {
        self.base.add(core as u64 * self.stride)
    }

    /// Byte stride between consecutive copies.
    pub fn stride(&self) -> u64 {
        self.stride
    }

    /// End-of-phase reduction for u32 add: fold word range `[lo, hi)` of
    /// every core's copy into the master array (both arrays indexed by
    /// 4-byte words). The caller partitions ranges across cores and
    /// places barriers.
    pub fn reduce_add_u32<C: ExecCtx>(
        &self,
        ctx: &mut C,
        master: Addr,
        cores: usize,
        lo: u64,
        hi: u64,
    ) {
        for k in lo..hi {
            let a = master.add(k * 4);
            let mut acc = ctx.read_u32(a);
            for c in 0..cores {
                let v = ctx.read_u32(self.copy_base(c).add(k * 4));
                acc = acc.wrapping_add(v);
                ctx.compute(1);
            }
            ctx.write_u32(a, acc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::MachineConfig;

    #[test]
    fn lock_array_strides() {
        let mut mem = MemSystem::new(MachineConfig::test_small()).unwrap();
        let locks = LockArray::alloc(&mut mem, 8, PTHREAD_LOCK_BYTES);
        assert_eq!(locks.addr(0).0 % 64, 0, "array starts line-aligned");
        assert_eq!(locks.addr(3).0 - locks.addr(0).0, 3 * PTHREAD_LOCK_BYTES);
    }

    #[test]
    fn dup_space_pads_copies_to_lines() {
        let mut mem = MemSystem::new(MachineConfig::test_small()).unwrap();
        let dup = DupSpace::alloc(&mut mem, 100, 4);
        assert_eq!(dup.stride(), 128);
        assert_eq!(dup.copy_base(2).0 - dup.copy_base(0).0, 256);
        assert_eq!(dup.copy_base(0).0 % 64, 0);
    }
}
