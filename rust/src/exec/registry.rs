//! The workload registry: the single enumeration of every benchmark the
//! crate knows, replacing the old triplicated `BenchKind` /
//! `Benchmark` / `parse_bench` lists. The CLI (`list`/`run`/`sweep`),
//! the coordinator and the figure benches all resolve names here and
//! run through [`WorkloadHandle`]s, so adding a benchmark is one
//! [`Workload`](super::workload::Workload) impl plus one [`WorkloadSpec`]
//! row.

use crate::workloads::graph::GraphKind;
use crate::workloads::kvstore::KvMerge;
use crate::workloads::{bfs, bloom, cms, histogram, hll, kmeans, kvserve, kvstore, pagerank};

use super::error::ExecError;
use super::workload::WorkloadHandle;
use super::Variant;

/// Geometry knobs for the streaming-sketch workloads, carried alongside
/// the size spec so sweeps and the CLI reshape sketches without new
/// plumbing. `0` means "derive the default from the size spec".
#[derive(Clone, Copy, Debug, Default)]
pub struct SketchSpec {
    /// Count-min hash rows (`--cms-depth`; default 4).
    pub cms_depth: usize,
    /// Bloom probes per key (`--bloom-hashes`; default 4).
    pub bloom_hashes: usize,
    /// HyperLogLog precision `p`, registers = 2^p (`--hll-p`; default:
    /// derived from the target working set, 1 byte per register).
    pub hll_precision: usize,
}

/// Geometry knobs for the `kvserve` serving tier, carried alongside the
/// size spec like [`SketchSpec`]. Sentinels mean "derive the default":
/// `0` for the integer knobs, a negative `skew_drift`, an all-zero mix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeSpec {
    /// Tenants in the tier (`--tenants`; default 4).
    pub tenants: usize,
    /// Shards the tenants map onto (`--shards`; default: one per
    /// tenant).
    pub shards: usize,
    /// Read:update:scan weights (`--mix r:u:s`; default 70:25:5).
    pub mix: (u32, u32, u32),
    /// Skew-drift amplitude (`--skew-drift`; `< 0` = default 0.2).
    pub skew_drift: f64,
    /// Soft-merge deadline in unmerged updates (`--merge-deadline`;
    /// default 64).
    pub merge_deadline: usize,
}

impl Default for ServeSpec {
    fn default() -> Self {
        Self {
            tenants: 0,
            shards: 0,
            mix: (0, 0, 0),
            skew_drift: -1.0,
            merge_deadline: 0,
        }
    }
}

/// How to size a workload instance: the working set of its contended
/// structure targets `frac` x the LLC capacity (the paper's Section 6.1
/// sweep axis), plus the RNG seed, the key-skew ablation knob and the
/// sketch geometry knobs.
#[derive(Clone, Copy, Debug)]
pub struct SizeSpec {
    pub frac: f64,
    pub llc_bytes: usize,
    pub seed: u64,
    /// 0.0 = uniform keys (the paper); >0 = zipf-skewed keys for the
    /// workloads with a key distribution (kvstore, histogram, and the
    /// sketch family's key/item streams).
    pub zipf_theta: f64,
    /// Sketch geometry (ignored by non-sketch workloads).
    pub sketch: SketchSpec,
    /// Serving-tier geometry (ignored by everything but `kvserve`).
    pub serve: ServeSpec,
}

impl SizeSpec {
    pub fn new(frac: f64, llc_bytes: usize, seed: u64) -> Self {
        Self {
            frac,
            llc_bytes,
            seed,
            zipf_theta: 0.0,
            sketch: SketchSpec::default(),
            serve: ServeSpec::default(),
        }
    }

    pub fn with_zipf(mut self, theta: f64) -> Self {
        self.zipf_theta = theta;
        self
    }

    pub fn with_sketch(mut self, sketch: SketchSpec) -> Self {
        self.sketch = sketch;
        self
    }

    pub fn with_serve(mut self, serve: ServeSpec) -> Self {
        self.serve = serve;
        self
    }

    /// Target working-set bytes.
    pub fn target_bytes(&self) -> u64 {
        (self.frac * self.llc_bytes as f64) as u64
    }
}

/// One registry row: name, CLI aliases, and how to build a sized
/// instance.
pub struct WorkloadSpec {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub summary: &'static str,
    /// Variants the workload implements (mirrors the trait impl's
    /// `supported_variants`, kept static so `list` needs no build).
    pub variants: &'static [Variant],
    /// Has a key distribution the `SizeSpec::zipf_theta` knob skews
    /// (kvstore, histogram); others reject a non-zero theta at the CLI.
    pub key_skew: bool,
    /// Member of the paper's Fig 6 panel set.
    pub fig6: bool,
    /// One of the four core paper benchmarks.
    pub core: bool,
    /// Runs on the native-thread backend (`Backend::Native`);
    /// `--list-workloads` reports it.
    pub native: bool,
    pub build: fn(&SizeSpec) -> WorkloadHandle,
}

impl WorkloadSpec {
    pub fn build(&self, spec: &SizeSpec) -> WorkloadHandle {
        (self.build)(spec)
    }
}

fn build_kv_add(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(kvstore::KvWorkload::sized(KvMerge::Add, s))
}

fn build_kv_sat(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(kvstore::KvWorkload::sized(KvMerge::Sat { max: 12 }, s))
}

fn build_kv_cmul(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(kvstore::KvWorkload::sized(KvMerge::Cmul, s))
}

fn build_kmeans(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(kmeans::KmWorkload::sized(false, s))
}

fn build_kmeans_approx(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(kmeans::KmWorkload::sized(true, s))
}

fn build_pagerank_rmat(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(pagerank::PrWorkload::sized(GraphKind::Rmat, s))
}

fn build_pagerank_ssca(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(pagerank::PrWorkload::sized(GraphKind::Ssca, s))
}

fn build_pagerank_uniform(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(pagerank::PrWorkload::sized(GraphKind::Uniform, s))
}

fn build_bfs_rmat(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(bfs::BfsWorkload::sized(GraphKind::Rmat, s))
}

fn build_bfs_ssca(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(bfs::BfsWorkload::sized(GraphKind::Ssca, s))
}

fn build_bfs_uniform(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(bfs::BfsWorkload::sized(GraphKind::Uniform, s))
}

fn build_histogram(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(histogram::HgWorkload::sized(s))
}

fn build_cms(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(cms::CmsWorkload::sized(s))
}

fn build_bloom(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(bloom::BloomWorkload::sized(s))
}

fn build_hll(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(hll::HllWorkload::sized(s))
}

fn build_kvserve(s: &SizeSpec) -> WorkloadHandle {
    WorkloadHandle::new(kvserve::KvServeWorkload::sized(s))
}

static REGISTRY: &[WorkloadSpec] = &[
    WorkloadSpec {
        name: "kvstore",
        aliases: &["kv", "kvstore-add"],
        summary: "random-access KV store, commutative increments",
        variants: &kvstore::VARIANTS,
        key_skew: true,
        fig6: true,
        core: true,
        native: true,
        build: build_kv_add,
    },
    WorkloadSpec {
        name: "kvstore-sat",
        aliases: &[],
        summary: "KV store with saturating-add merge (Section 6.3)",
        variants: &kvstore::VARIANTS,
        key_skew: true,
        fig6: true,
        core: false,
        native: true,
        build: build_kv_sat,
    },
    WorkloadSpec {
        name: "kvstore-cmul",
        aliases: &[],
        summary: "KV store with complex-multiply merge (Section 6.3)",
        variants: &kvstore::VARIANTS,
        key_skew: true,
        fig6: true,
        core: false,
        native: true,
        build: build_kv_cmul,
    },
    WorkloadSpec {
        name: "kmeans",
        aliases: &[],
        summary: "Lloyd's K-Means, CData cluster accumulators",
        variants: &kmeans::VARIANTS,
        key_skew: false,
        fig6: true,
        core: true,
        native: true,
        build: build_kmeans,
    },
    WorkloadSpec {
        name: "kmeans-approx",
        aliases: &[],
        summary: "K-Means with approximate (update-dropping) merge",
        variants: &kmeans::VARIANTS,
        key_skew: false,
        fig6: true,
        core: false,
        native: true,
        build: build_kmeans_approx,
    },
    WorkloadSpec {
        name: "pagerank-rmat",
        aliases: &["pagerank-kron"],
        summary: "push/pull PageRank on an RMAT graph",
        variants: &pagerank::VARIANTS,
        key_skew: false,
        fig6: true,
        core: false,
        native: true,
        build: build_pagerank_rmat,
    },
    WorkloadSpec {
        name: "pagerank-ssca",
        aliases: &[],
        summary: "push/pull PageRank on an SSCA graph",
        variants: &pagerank::VARIANTS,
        key_skew: false,
        fig6: true,
        core: false,
        native: true,
        build: build_pagerank_ssca,
    },
    WorkloadSpec {
        name: "pagerank-uniform",
        aliases: &["pagerank", "pagerank-random"],
        summary: "push/pull PageRank on a uniform random graph",
        variants: &pagerank::VARIANTS,
        key_skew: false,
        fig6: true,
        core: true,
        native: true,
        build: build_pagerank_uniform,
    },
    WorkloadSpec {
        name: "bfs-rmat",
        aliases: &["bfs", "bfs-kron"],
        summary: "level-synchronous bitmap BFS on an RMAT graph",
        variants: &bfs::VARIANTS,
        key_skew: false,
        fig6: true,
        core: true,
        native: true,
        build: build_bfs_rmat,
    },
    WorkloadSpec {
        name: "bfs-ssca",
        aliases: &[],
        summary: "level-synchronous bitmap BFS on an SSCA graph",
        variants: &bfs::VARIANTS,
        key_skew: false,
        fig6: false,
        core: false,
        native: true,
        build: build_bfs_ssca,
    },
    WorkloadSpec {
        name: "bfs-uniform",
        aliases: &["bfs-random"],
        summary: "level-synchronous bitmap BFS on a uniform graph",
        variants: &bfs::VARIANTS,
        key_skew: false,
        fig6: true,
        core: false,
        native: true,
        build: build_bfs_uniform,
    },
    WorkloadSpec {
        name: "histogram",
        aliases: &["hist"],
        summary: "streaming binned counts — the classic privatization workload",
        variants: &histogram::VARIANTS,
        key_skew: true,
        fig6: false,
        core: false,
        native: true,
        build: build_histogram,
    },
    WorkloadSpec {
        name: "cms",
        aliases: &["count-min", "countmin"],
        summary: "count-min sketch ingest, saturating per-cell counters",
        variants: &cms::VARIANTS,
        key_skew: true,
        fig6: false,
        core: false,
        native: true,
        build: build_cms,
    },
    WorkloadSpec {
        name: "bloom",
        aliases: &["bloomfilter"],
        summary: "Bloom-filter ingest, bitwise-OR merged bit array",
        variants: &bloom::VARIANTS,
        key_skew: true,
        fig6: false,
        core: false,
        native: true,
        build: build_bloom,
    },
    WorkloadSpec {
        name: "hll",
        aliases: &["hyperloglog"],
        summary: "HyperLogLog cardinality, lane-max merged registers",
        variants: &hll::VARIANTS,
        key_skew: true,
        fig6: false,
        core: false,
        native: true,
        build: build_hll,
    },
    WorkloadSpec {
        name: "kvserve",
        aliases: &["serve", "kv-serve"],
        summary: "multi-tenant KV serving tier, staleness-bounded soft-merges",
        variants: &kvserve::VARIANTS,
        key_skew: true,
        fig6: false,
        core: false,
        native: true,
        build: build_kvserve,
    },
];

/// Every registered workload, in display order.
pub fn registry() -> &'static [WorkloadSpec] {
    REGISTRY
}

/// The paper's Fig 6 panel set (baselines + Section 6.3 merge variants).
pub fn fig6_panels() -> Vec<&'static WorkloadSpec> {
    REGISTRY.iter().filter(|s| s.fig6).collect()
}

/// The four core paper benchmarks.
pub fn core_panels() -> Vec<&'static WorkloadSpec> {
    REGISTRY.iter().filter(|s| s.core).collect()
}

/// Resolve a benchmark name or alias.
pub fn lookup(name: &str) -> Result<&'static WorkloadSpec, ExecError> {
    let lower = name.to_ascii_lowercase();
    REGISTRY
        .iter()
        .find(|s| s.name == lower || s.aliases.contains(&lower.as_str()))
        .ok_or_else(|| ExecError::UnknownBenchmark {
            name: name.to_string(),
            known: REGISTRY.iter().map(|s| s.name.to_string()).collect(),
        })
}

/// Resolve and build in one step.
pub fn build(name: &str, spec: &SizeSpec) -> Result<WorkloadHandle, ExecError> {
    Ok(lookup(name)?.build(spec))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_and_aliases_resolve() {
        let mut seen = std::collections::HashSet::new();
        for s in registry() {
            assert!(seen.insert(s.name), "duplicate name {}", s.name);
            for &a in s.aliases {
                assert!(seen.insert(a), "alias {a} collides");
            }
        }
        assert_eq!(lookup("kv").unwrap().name, "kvstore");
        assert_eq!(lookup("BFS").unwrap().name, "bfs-rmat");
        assert_eq!(lookup("pagerank").unwrap().name, "pagerank-uniform");
        assert_eq!(lookup("hist").unwrap().name, "histogram");
        assert_eq!(lookup("count-min").unwrap().name, "cms");
        assert_eq!(lookup("hyperloglog").unwrap().name, "hll");
        assert_eq!(lookup("serve").unwrap().name, "kvserve");
        assert!(matches!(
            lookup("nope"),
            Err(ExecError::UnknownBenchmark { .. })
        ));
    }

    #[test]
    fn key_skew_marks_exactly_the_keyed_workloads() {
        for s in registry() {
            let expect = s.name.starts_with("kvstore")
                || matches!(s.name, "histogram" | "cms" | "bloom" | "hll" | "kvserve");
            assert_eq!(s.key_skew, expect, "{}: key_skew flag wrong", s.name);
        }
    }

    #[test]
    fn panel_sets() {
        assert_eq!(fig6_panels().len(), 10);
        assert_eq!(core_panels().len(), 4);
        assert!(
            registry().len() >= 16,
            "histogram, the sketch family and kvserve must be registered"
        );
        assert!(
            registry().iter().all(|s| s.native),
            "every workload runs on the native backend"
        );
    }

    #[test]
    fn handles_report_spec_variants() {
        let spec = SizeSpec::new(0.01, 1 << 16, 1);
        for s in registry() {
            let h = s.build(&spec);
            assert_eq!(
                h.supported_variants(),
                s.variants,
                "{}: spec/impl variant mismatch",
                s.name
            );
            assert!(h.footprint() > 0, "{}: zero footprint", s.name);
        }
    }
}
