//! The execution-context abstraction: the operation surface a workload
//! program runs against, independent of *what machine* carries it out.
//!
//! [`ExecCtx`] captures exactly the instruction set the paper's core
//! programs use — timed compute, coherent loads/stores, atomic RMWs,
//! COps (`c_read`/`c_write`), merge control (`merge_init`, `soft_merge`,
//! `merge`), locks and barriers. Two backends implement it:
//!
//! * the simulator's [`CoreCtx`](crate::sim::machine::CoreCtx) — logical
//!   cores interleaved deterministically over the modeled cache
//!   hierarchy, producing cycle counts;
//! * the native backend's [`NativeCtx`](crate::runtime::native::NativeCtx)
//!   — real OS threads over `AtomicU32` shared memory, producing
//!   wall-clock time.
//!
//! `Workload::program` is generic over this trait, so every registry
//! workload is *simultaneously* a simulation input and an actual
//! parallel program; the driver cross-validates the two against the same
//! goldens ([`coordinator::xval`](crate::coordinator::xval)).

use crate::merge::MergeHandle;
use crate::sim::addr::Addr;

/// The operation surface of one core's program.
///
/// Semantics (both backends honor these):
///
/// * `read/write/cas/fetch_or` are ordinary coherent memory operations;
///   on the native backend they are real `AtomicU32` accesses.
/// * `c_read/c_write` are COps: they operate on an on-demand private
///   copy of the line, tagged with MFRF slot `ty`; concurrent updates by
///   other cores are reconciled only by merging.
/// * `soft_merge` marks this core's private CData evictable
///   (merge-on-evict); `merge` forces every private line through its
///   registered merge function into shared memory.
/// * `lock`/`unlock` implement a spinlock over the word at `addr`
///   (0 = free); `barrier` is a full-machine phase barrier.
/// * `compute(n)` models `n` cycles of pure computation (a no-op
///   natively beyond operation accounting).
///
/// The f32 operations have default implementations over the u32 ones
/// (bit-level transmute), so a backend only implements the u32 core.
pub trait ExecCtx {
    /// This core's index in `0..cores`.
    fn core_id(&self) -> usize;

    /// Cycles elapsed on this core (native: operations executed).
    fn cycles(&mut self) -> u64;

    /// Model `n` cycles of pure (memory-free) computation.
    fn compute(&mut self, n: u64);

    /// Coherent 32-bit load.
    fn read_u32(&mut self, addr: Addr) -> u32;

    /// Coherent 32-bit store.
    fn write_u32(&mut self, addr: Addr, val: u32);

    /// Coherent f32 load (bit-cast of [`ExecCtx::read_u32`]).
    fn read_f32(&mut self, addr: Addr) -> f32 {
        f32::from_bits(self.read_u32(addr))
    }

    /// Coherent f32 store (bit-cast of [`ExecCtx::write_u32`]).
    fn write_f32(&mut self, addr: Addr, val: f32) {
        self.write_u32(addr, val.to_bits());
    }

    /// Atomic compare-and-swap; returns whether the swap happened.
    fn cas_u32(&mut self, addr: Addr, expected: u32, new: u32) -> bool;

    /// Atomic fetch-or; returns the previous value.
    fn fetch_or_u32(&mut self, addr: Addr, bits: u32) -> u32;

    /// Install merge function `f` in this core's MFRF slot `slot`.
    fn merge_init(&mut self, slot: usize, f: MergeHandle);

    /// COp load from a private copy of `addr`'s line (slot `ty`).
    fn c_read_u32(&mut self, addr: Addr, ty: u8) -> u32;

    /// COp store to a private copy of `addr`'s line (slot `ty`).
    fn c_write_u32(&mut self, addr: Addr, val: u32, ty: u8);

    /// COp f32 load (bit-cast of [`ExecCtx::c_read_u32`]).
    fn c_read_f32(&mut self, addr: Addr, ty: u8) -> f32 {
        f32::from_bits(self.c_read_u32(addr, ty))
    }

    /// COp f32 store (bit-cast of [`ExecCtx::c_write_u32`]).
    fn c_write_f32(&mut self, addr: Addr, val: f32, ty: u8) {
        self.c_write_u32(addr, val.to_bits(), ty);
    }

    /// Mark this core's private CData mergeable (evictable).
    fn soft_merge(&mut self);

    /// Merge every private line through its registered merge function.
    fn merge(&mut self);

    /// Acquire the spinlock at `addr` (0 = free, 1 = held).
    fn lock(&mut self, addr: Addr);

    /// Release the spinlock at `addr`.
    fn unlock(&mut self, addr: Addr);

    /// Full-machine phase barrier.
    fn barrier(&mut self);
}
