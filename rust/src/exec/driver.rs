//! The generic execution driver: one code path from a [`Workload`] impl
//! to a verified [`RunResult`], shared by all benchmarks.
//!
//! Owns the skeleton every benchmark used to hand-roll: variant
//! gating, machine construction, memory setup, CCache merge-region
//! registration (`merge_init` per MFRF slot), spawning one program per
//! core, stats collection, and golden-run verification.

use crate::sim::config::MachineConfig;
use crate::sim::machine::{CoreCtx, Machine};

use super::error::ExecError;
use super::workload::Workload;
use super::{RunResult, Variant};

pub fn run<W: Workload>(
    workload: &W,
    variant: Variant,
    cfg: MachineConfig,
) -> Result<RunResult, ExecError> {
    let supported = workload.supported_variants();
    if !supported.contains(&variant) {
        return Err(ExecError::UnsupportedVariant {
            benchmark: workload.name(),
            variant,
            supported,
        });
    }

    let cores = cfg.cores;
    // a malformed machine config surfaces as a typed error, not a panic
    let machine = Machine::new(cfg).map_err(ExecError::from)?;
    let layout = machine.setup(|mem| workload.setup(mem, variant, cores));
    let merge_slots = workload.merge_slots();

    let programs: Vec<Box<dyn FnOnce(&mut CoreCtx) + Send + '_>> = (0..cores)
        .map(|core| {
            let layout = layout.clone();
            let merge_slots = merge_slots.clone();
            let f: Box<dyn FnOnce(&mut CoreCtx) + Send + '_> = Box::new(move |ctx| {
                if variant == Variant::CCache {
                    for &(slot, kind) in &merge_slots {
                        ctx.merge_init(slot, kind);
                    }
                }
                workload.program(ctx, core, cores, variant, &layout);
            });
            f
        })
        .collect();
    let stats = machine.run(programs);

    let golden = workload.golden(cores);
    let (verified, quality) =
        machine.setup(|mem| workload.verify(mem, &layout, &golden, cores));

    Ok(RunResult {
        benchmark: workload.name(),
        variant,
        stats,
        verified,
        quality,
    })
}
