//! The generic execution driver: one code path from a [`Workload`] impl
//! to a verified [`RunResult`], shared by all benchmarks.
//!
//! Owns the skeleton every benchmark used to hand-roll: variant
//! gating, machine construction, memory setup, CCache merge-region
//! registration (`merge_init` per MFRF slot, optionally overridden with
//! a registry-built or user-defined merge function), spawning one
//! program per core, stats collection, golden-run verification, and
//! machine-fault recovery (a COp on an uninitialized MFRF slot surfaces
//! as [`ExecError::MergeFault`], not a panic).

use crate::merge::MergeHandle;
use crate::runtime::native::{NativeCtx, NativeMachine};
use crate::sim::addr::LINE_BYTES;
use crate::sim::config::MachineConfig;
use crate::sim::machine::{CoreCtx, Machine};
use crate::sim::memsys::MemSystem;
use crate::sim::stats::Stats;

use super::ctx::ExecCtx;
use super::error::ExecError;
use super::workload::Workload;
use super::{Backend, CorunSpec, RunResult, Variant};

/// Lines each co-runner core streams through between polls of the
/// workload's done counter: long enough that the scan dominates the
/// scanner's traffic, short enough that scanners retire promptly once
/// the workload finishes.
const CORUN_SCAN_BATCH: usize = 64;

pub fn run<W: Workload>(
    workload: &W,
    variant: Variant,
    cfg: MachineConfig,
) -> Result<RunResult, ExecError> {
    run_with_merge(workload, variant, cfg, None)
}

/// Run on an explicit [`Backend`]: the simulator or the native-thread
/// machine. Variant gating, merge registration, goldens and
/// verification are identical on both paths.
pub fn run_on<W: Workload>(
    workload: &W,
    backend: Backend,
    variant: Variant,
    cfg: MachineConfig,
) -> Result<RunResult, ExecError> {
    run_on_with_merge(workload, backend, variant, cfg, None)
}

/// [`run_on`] with a merge override.
pub fn run_on_with_merge<W: Workload>(
    workload: &W,
    backend: Backend,
    variant: Variant,
    cfg: MachineConfig,
    merge_override: Option<MergeHandle>,
) -> Result<RunResult, ExecError> {
    match backend {
        Backend::Sim => run_with_merge(workload, variant, cfg, merge_override),
        Backend::Native => run_native_with_merge(workload, variant, cfg, merge_override),
    }
}

/// Run on real OS threads ([`Backend::Native`]); see
/// [`run_native_with_merge`].
pub fn run_native<W: Workload>(
    workload: &W,
    variant: Variant,
    cfg: MachineConfig,
) -> Result<RunResult, ExecError> {
    run_native_with_merge(workload, variant, cfg, None)
}

/// [`run`] with the workload's merge functions optionally replaced by
/// `merge_override` in every MFRF slot (CCache variant only; other
/// variants never install merge functions).
pub fn run_with_merge<W: Workload>(
    workload: &W,
    variant: Variant,
    cfg: MachineConfig,
    merge_override: Option<MergeHandle>,
) -> Result<RunResult, ExecError> {
    run_sim(workload, variant, cfg, merge_override, None)
}

/// The simulator path, optionally with a cache-hostile co-runner.
///
/// With `corun = Some(spec)` the machine grows `spec.cores` extra cores
/// that stream a coherent read scan over a buffer larger than the LLC
/// (allocated *after* the workload's own setup, so workload addresses
/// are unchanged) for as long as any workload core is still running.
/// Termination handshake: each workload core bumps a shared done
/// counter (CAS loop) after its program returns; scanners poll the
/// counter between scan batches and retire once it reaches the workload
/// core count. A merge fault on a workload core aborts the machine and
/// unwinds the scanners with it — the usual sibling-panic recovery path
/// applies unchanged.
///
/// Reported results cover the *workload* cores only: scanner entries
/// are truncated from `stats.core_cycles`, so `RunResult::cycles()`
/// (max over cores) measures how much the interference slowed the
/// workload down, not how long the scanners spun. Without a co-runner
/// (`None` or zero cores) this is byte-identical to the plain
/// [`run_with_merge`] path — no extra allocations, no done counter.
pub fn run_sim<W: Workload>(
    workload: &W,
    variant: Variant,
    cfg: MachineConfig,
    merge_override: Option<MergeHandle>,
    corun: Option<CorunSpec>,
) -> Result<RunResult, ExecError> {
    let supported = workload.supported_variants();
    if !supported.contains(&variant) {
        return Err(ExecError::UnsupportedVariant {
            benchmark: workload.name(),
            variant,
            supported,
        });
    }
    // protocol gating: partial coherence has no coherent RMWs, so
    // variants built on locks/atomics are typed-rejected up front
    if !cfg.protocol.supports(variant.name()) {
        return Err(ExecError::UnsupportedProtocol {
            benchmark: workload.name(),
            protocol: cfg.protocol.name(),
            variant,
            supported: supported
                .into_iter()
                .filter(|v| cfg.protocol.supports(v.name()))
                .collect(),
        });
    }

    let corun = corun.filter(|c| c.cores > 0);
    let work_cores = cfg.cores;
    let llc_lines = cfg.llc().size_bytes as u64 / LINE_BYTES;
    let mut cfg = cfg;
    if let Some(c) = corun {
        // scanner cores ride on top of the workload's; an over-wide
        // machine fails MachineConfig validation below as usual
        cfg.cores = work_cores + c.cores;
    }
    let total_cores = cfg.cores;
    // a malformed machine config surfaces as a typed error, not a panic
    let machine = Machine::new(cfg).map_err(ExecError::from)?;
    let layout = machine.setup(|mem| workload.setup(mem, variant, work_cores));
    // co-runner scaffolding: the scan buffer and the done counter, laid
    // out after the workload footprint (scan addr, scan lines, done addr)
    let corun_layout = corun.map(|c| {
        machine.setup(|mem| {
            let lines = c.effective_lines(llc_lines).max(1);
            let scan = mem.alloc_lines(lines * LINE_BYTES);
            let done = mem.alloc_lines(LINE_BYTES);
            (scan, lines, done)
        })
    });
    let mut merge_slots = workload.merge_slots();
    if let Some(m) = merge_override {
        for (_, slot_fn) in merge_slots.iter_mut() {
            *slot_fn = m.clone();
        }
    }
    // the merge identity of this run, for reports (installed only under
    // the CCache variant; other variants merge in software, if at all)
    let merge_fns: Vec<String> = if variant == Variant::CCache {
        merge_slots.iter().map(|(_, f)| f.name().to_string()).collect()
    } else {
        Vec::new()
    };

    let programs: Vec<Box<dyn FnOnce(&mut CoreCtx) + Send + '_>> = (0..total_cores)
        .map(|core| {
            let layout = layout.clone();
            let merge_slots = merge_slots.clone();
            let f: Box<dyn FnOnce(&mut CoreCtx) + Send + '_> = Box::new(move |ctx| {
                if core < work_cores {
                    if variant == Variant::CCache {
                        for (slot, f) in merge_slots {
                            ctx.merge_init(slot, f);
                        }
                    }
                    workload.program(ctx, core, work_cores, variant, &layout);
                    if let Some((_, _, done)) = corun_layout {
                        // announce completion so the scanners can retire
                        loop {
                            let cur = ctx.read_u32(done);
                            if ctx.cas_u32(done, cur, cur + 1) {
                                break;
                            }
                        }
                    }
                } else {
                    let (scan, lines, done) = corun_layout
                        .expect("scanner cores exist only when corun is active");
                    // stagger scanner start offsets so they don't convoy
                    // on the same sets
                    let scanners = (total_cores - work_cores) as u64;
                    let mut pos = lines * (core - work_cores) as u64 / scanners;
                    loop {
                        for _ in 0..CORUN_SCAN_BATCH {
                            let _ = ctx.read_u32(scan.add(pos * LINE_BYTES));
                            pos += 1;
                            if pos >= lines {
                                pos = 0;
                            }
                        }
                        if ctx.read_u32(done) >= work_cores as u32 {
                            break;
                        }
                    }
                }
            });
            f
        })
        .collect();
    let mut stats = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        machine.run(programs)
    })) {
        Ok(stats) => stats,
        Err(payload) => {
            // machine-fault recovery: the memory system records the
            // typed fault before the core thread unwinds, so it is
            // authoritative even when a sibling core's panic is the one
            // that propagated
            if let Some(fault) = machine.setup(|mem| mem.take_fault()) {
                return Err(ExecError::MergeFault(fault));
            }
            std::panic::resume_unwind(payload);
        }
    };

    // post-run consistency sweep: the cross-structure invariants
    // (directory bookkeeping, source-buffer/L1 bindings) must hold in
    // the quiesced machine before we trust the verification pass
    machine.setup(|mem| mem.check_invariants()).map_err(ExecError::from)?;

    // scanner cores spin until the last workload core finishes, so
    // their cycle counts track the scheduler, not the workload — report
    // workload cores only
    if corun.is_some() {
        stats.core_cycles.truncate(work_cores);
    }

    let golden = workload.golden(work_cores);
    let (verified, quality) =
        machine.setup(|mem| workload.verify(mem, &layout, &golden, work_cores));

    Ok(RunResult {
        benchmark: workload.name(),
        variant,
        stats,
        verified,
        quality,
        merge_fns,
        wall_secs: None,
    })
}

/// The NativeDriver: [`run_with_merge`]'s contract carried out by the
/// [`NativeMachine`] — real threads, real atomics, wall-clock time.
///
/// The simulator's `MemSystem` still does the backend-independent work:
/// `Workload::setup` allocates and initializes the flat functional
/// memory through it, that memory image seeds the native machine's
/// `AtomicU32` array, and after the threads join the final image is
/// written back so `Workload::verify` runs against the *same* goldens as
/// a simulated run. Cycle-denominated stats don't exist here: the
/// returned `stats.core_cycles` carries per-core *operation* counts and
/// [`RunResult::wall_secs`] the measured parallel-section time.
pub fn run_native_with_merge<W: Workload>(
    workload: &W,
    variant: Variant,
    cfg: MachineConfig,
    merge_override: Option<MergeHandle>,
) -> Result<RunResult, ExecError> {
    let supported = workload.supported_variants();
    if !supported.contains(&variant) {
        return Err(ExecError::UnsupportedVariant {
            benchmark: workload.name(),
            variant,
            supported,
        });
    }
    // same protocol gate as the simulator path: the native machine's
    // real atomics cannot model a non-coherent shared level either
    if !cfg.protocol.supports(variant.name()) {
        return Err(ExecError::UnsupportedProtocol {
            benchmark: workload.name(),
            protocol: cfg.protocol.name(),
            variant,
            supported: supported
                .into_iter()
                .filter(|v| cfg.protocol.supports(v.name()))
                .collect(),
        });
    }

    let cores = cfg.cores;
    let mfrf_slots = cfg.ccache.mfrf_slots;
    let depth = cfg.depth();
    let mut mem = MemSystem::new(cfg).map_err(ExecError::from)?;
    let layout = workload.setup(&mut mem, variant, cores);
    let mut merge_slots = workload.merge_slots();
    if let Some(m) = merge_override {
        for (_, slot_fn) in merge_slots.iter_mut() {
            *slot_fn = m.clone();
        }
    }
    let merge_fns: Vec<String> = if variant == Variant::CCache {
        merge_slots.iter().map(|(_, f)| f.name().to_string()).collect()
    } else {
        Vec::new()
    };

    let native = NativeMachine::new(&mem.snapshot_mem(), cores, mfrf_slots);
    let programs: Vec<Box<dyn FnOnce(&mut NativeCtx) + Send + '_>> = (0..cores)
        .map(|core| {
            let layout = layout.clone();
            let merge_slots = merge_slots.clone();
            let f: Box<dyn FnOnce(&mut NativeCtx) + Send + '_> = Box::new(move |ctx| {
                if variant == Variant::CCache {
                    for (slot, f) in merge_slots {
                        ctx.merge_init(slot, f);
                    }
                }
                workload.native_program(ctx, core, cores, variant, &layout);
            });
            f
        })
        .collect();
    let run = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        native.run(programs)
    })) {
        Ok(run) => run,
        Err(payload) => {
            // same fault-recovery contract as the simulated machine: the
            // native machine records the typed fault before unwinding
            if let Some(fault) = native.take_fault() {
                return Err(ExecError::MergeFault(fault));
            }
            std::panic::resume_unwind(payload);
        }
    };

    // write the final native memory image back so verification reads it
    // through the ordinary MemSystem peek API
    mem.restore_mem(&native.snapshot());
    let golden = workload.golden(cores);
    let (verified, quality) = workload.verify(&mut mem, &layout, &golden, cores);

    let mut stats = Stats::new(cores, depth);
    stats.core_cycles = run.per_core_ops.clone();
    stats.cops = run.cops;
    stats.atomic_rmws = run.atomic_rmws;
    stats.lock_acquires = run.lock_acquires;
    stats.merges = run.merges;
    stats.barriers = run.barriers;

    Ok(RunResult {
        benchmark: workload.name(),
        variant,
        stats,
        verified,
        quality,
        merge_fns,
        wall_secs: Some(run.secs),
    })
}
