//! Merge-Function Register File (Section 4.2).
//!
//! Holds the registered merge functions for one core. `merge_init`
//! installs a [`MergeKind`] into a slot; each CData line's merge-type
//! field names the slot to invoke at merge time. Four slots / two
//! merge-type bits is the paper's suggested configuration.

use crate::merge::MergeKind;

pub struct Mfrf {
    slots: Vec<Option<MergeKind>>,
}

impl Mfrf {
    pub fn new(slots: usize) -> Self {
        Self {
            slots: vec![None; slots],
        }
    }

    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// `merge_init(&fn, i)` — register `kind` in slot `i`.
    pub fn install(&mut self, slot: usize, kind: MergeKind) {
        assert!(
            slot < self.slots.len(),
            "MFRF slot {slot} out of range (have {})",
            self.slots.len()
        );
        self.slots[slot] = Some(kind);
    }

    /// The merge function for a line's merge-type field. Panics on an
    /// uninitialized slot — using CData with no registered merge function
    /// is a programming error the hardware would fault on.
    pub fn get(&self, slot: u8) -> MergeKind {
        self.slots
            .get(slot as usize)
            .copied()
            .flatten()
            .unwrap_or_else(|| panic!("MFRF slot {slot} not initialized"))
    }

    pub fn try_get(&self, slot: u8) -> Option<MergeKind> {
        self.slots.get(slot as usize).copied().flatten()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_and_get() {
        let mut m = Mfrf::new(4);
        m.install(0, MergeKind::AddU32);
        m.install(3, MergeKind::BitOr);
        assert_eq!(m.get(0), MergeKind::AddU32);
        assert_eq!(m.get(3), MergeKind::BitOr);
        assert_eq!(m.try_get(1), None);
    }

    #[test]
    #[should_panic(expected = "not initialized")]
    fn uninitialized_slot_faults() {
        let m = Mfrf::new(4);
        let _ = m.get(2);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_install_faults() {
        let mut m = Mfrf::new(2);
        m.install(5, MergeKind::AddU32);
    }

    #[test]
    fn reinstall_overwrites() {
        let mut m = Mfrf::new(4);
        m.install(0, MergeKind::AddU32);
        m.install(0, MergeKind::MinF32);
        assert_eq!(m.get(0), MergeKind::MinF32);
    }
}
