//! Merge-Function Register File (Section 4.2).
//!
//! Holds the registered merge functions for one core. `merge_init`
//! installs a [`MergeHandle`] into a slot; each CData line's merge-type
//! field names the slot to invoke at merge time. Four slots / two
//! merge-type bits is the paper's suggested configuration.
//!
//! Using a merge type whose slot was never initialized is a *machine
//! fault*, not a rust panic: the protocol engine surfaces it as a typed
//! [`MergeFault`] that the execution layer converts into
//! `ExecError::MergeFault` (CLI diagnostic + exit 2).

use std::fmt;

use crate::merge::MergeHandle;

/// The machine fault raised when a COp or merge names an MFRF slot with
/// no installed merge function (the hardware analog of an undefined-
/// instruction trap).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MergeFault {
    pub core: usize,
    pub slot: u8,
    /// MFRF capacity, for the diagnostic.
    pub slots: usize,
}

impl fmt::Display for MergeFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "merge fault: core {} used merge type {} but MFRF slot {} holds no \
             merge function ({} slots; issue merge_init first)",
            self.core, self.slot, self.slot, self.slots
        )
    }
}

impl std::error::Error for MergeFault {}

pub struct Mfrf {
    slots: Vec<Option<MergeHandle>>,
}

impl Mfrf {
    pub fn new(slots: usize) -> Self {
        Self {
            slots: vec![None; slots],
        }
    }

    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// `merge_init(&fn, i)` — register `f` in slot `i`.
    pub fn install(&mut self, slot: usize, f: MergeHandle) {
        assert!(
            slot < self.slots.len(),
            "MFRF slot {slot} out of range (have {})",
            self.slots.len()
        );
        self.slots[slot] = Some(f);
    }

    /// The merge function for a line's merge-type field; `None` when the
    /// slot was never initialized (the caller raises a [`MergeFault`]).
    pub fn get(&self, slot: u8) -> Option<&MergeHandle> {
        self.slots.get(slot as usize).and_then(|s| s.as_ref())
    }

    /// The fault describing an access to `slot` on `core`.
    pub fn fault(&self, core: usize, slot: u8) -> MergeFault {
        MergeFault {
            core,
            slot,
            slots: self.slots.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::funcs::{AddU32, BitOr, MinF32};
    use crate::merge::handle;

    #[test]
    fn install_and_get() {
        let mut m = Mfrf::new(4);
        m.install(0, handle(AddU32));
        m.install(3, handle(BitOr));
        assert_eq!(m.get(0).unwrap().name(), "add_u32");
        assert_eq!(m.get(3).unwrap().name(), "bitor");
        assert!(m.get(1).is_none());
    }

    #[test]
    fn uninitialized_slot_is_a_typed_fault() {
        let m = Mfrf::new(4);
        assert!(m.get(2).is_none());
        let fault = m.fault(1, 2);
        assert_eq!(fault.core, 1);
        assert_eq!(fault.slot, 2);
        let msg = fault.to_string();
        assert!(msg.contains("merge fault"), "{msg}");
        assert!(msg.contains("merge_init"), "{msg}");
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_install_faults() {
        let mut m = Mfrf::new(2);
        m.install(5, handle(AddU32));
    }

    #[test]
    fn reinstall_overwrites() {
        let mut m = Mfrf::new(4);
        m.install(0, handle(AddU32));
        m.install(0, handle(MinF32));
        assert_eq!(m.get(0).unwrap().name(), "min_f32");
    }
}
