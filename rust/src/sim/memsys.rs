//! The memory-system protocol engine: coherent MESI accesses through a
//! configurable hierarchy, plus the CCache commutative-access path.
//!
//! Timing model (Table 2 defaults): an access is charged the hit latency
//! of every level it touches (L1 4, +L2 10, +LLC 70, +memory 300); any
//! coherence transaction (upgrade, remote fetch, RFO) charges one extra
//! shared-level round trip because the directory lives at the shared
//! level. Merges charge the paper's flat 170 cycles per line. Waiting on
//! locked LLC lines is not modeled, exactly as in the paper (Section 5).
//!
//! Structure: the hierarchy walk, fills and recalls live in
//! [`AccessPath`](super::hierarchy::path::AccessPath) — an arbitrary
//! stack of private levels plus one shared level, built from
//! [`MachineConfig::levels`]. This file keeps the CCache engine state
//! (source buffers, MFRF, private updated copies, the background merge
//! engine) and the merge execution, with the merge/merge-on-evict/
//! dirty-merge decisions behind the
//! [`MergePolicy`](super::hierarchy::merge_policy::MergePolicy) trait.
//!
//! Functional model: one flat `u32` memory is authoritative for coherent
//! data (the workloads synchronize their racy accesses, so a single copy
//! observes every serialization the protocol would produce). CData is
//! different: each core's privatized *updated copy* lives in a per-core
//! side table and its *source copy* in the source buffer, so merge
//! functions compute real values — final memory contents are checked
//! against sequential golden runs in the integration tests.

use std::collections::HashMap;

use super::addr::{Addr, Line};
use super::cache::Cache;
use super::config::{ConfigError, MachineConfig};
use super::directory::Directory;
use super::hierarchy::merge_policy::{self, MergeDecision, MergePolicy};
use super::hierarchy::path::AccessPath;
use super::mfrf::{MergeFault, Mfrf};
use super::source_buffer::SourceBuffer;
use super::stats::Stats;
use crate::merge::batch::MergeItem;
use crate::merge::{LineData, MergeHandle, LINE_WORDS};
use crate::util::rng::Rng;

/// A recorded merge (for PJRT batch validation / deferred execution).
#[derive(Clone)]
pub struct MergeRecord {
    pub merge: MergeHandle,
    pub line: Line,
    pub item: MergeItem,
}

pub struct MemSystem {
    pub cfg: MachineConfig,
    /// The cache hierarchy + directory (structure); see module docs.
    path: AccessPath,
    /// Flat functional memory (word-addressed).
    mem: Vec<u32>,
    /// Per-core CData updated copies (the L1 data array for CData lines).
    priv_data: Vec<HashMap<u64, LineData>>,
    src_buf: Vec<SourceBuffer>,
    mfrf: Vec<Mfrf>,
    /// Background merge-engine backlog per core, in cycles of queued
    /// merge work (victim-buffer model; see CCacheConfig::merge_engine_*).
    engine_backlog: Vec<u64>,
    /// Merge timing/disposition decisions (Section 4.3) as data.
    policy: Box<dyn MergePolicy>,
    pub stats: Stats,
    alloc_cursor: u64,
    /// Deterministic stream for approximate-merge drop decisions.
    approx_rng: Rng,
    /// When set, every executed merge is also recorded for batch
    /// validation through the PJRT executor.
    pub record_merges: bool,
    pub merge_log: Vec<MergeRecord>,
    /// The first machine fault this system raised (COp on an
    /// uninitialized MFRF slot). Recorded here so the execution layer
    /// can recover the typed fault even when the raising core thread
    /// unwinds; see [`MemSystem::take_fault`].
    fault: Option<MergeFault>,
}

impl MemSystem {
    /// Build the memory system a configuration describes; a malformed
    /// configuration is a typed [`ConfigError`] (the execution layer
    /// turns it into a CLI diagnostic instead of a panic).
    pub fn new(cfg: MachineConfig) -> Result<Self, ConfigError> {
        cfg.validate()?;
        let cores = cfg.cores;
        Ok(Self {
            path: AccessPath::new(&cfg),
            mem: vec![0u32; cfg.mem_bytes / 4],
            priv_data: (0..cores).map(|_| HashMap::new()).collect(),
            src_buf: (0..cores)
                .map(|_| SourceBuffer::new(cfg.ccache.source_buffer_entries))
                .collect(),
            engine_backlog: vec![0; cores],
            mfrf: (0..cores).map(|_| Mfrf::new(cfg.ccache.mfrf_slots)).collect(),
            policy: merge_policy::from_config(&cfg.ccache),
            stats: Stats::new(cores, cfg.depth()),
            alloc_cursor: 64, // keep address 0 unused
            approx_rng: Rng::new(0xA990_05ED),
            record_merges: false,
            merge_log: Vec::new(),
            fault: None,
            cfg,
        })
    }

    /// Take the recorded machine fault, if any (execution-layer recovery
    /// path after a core thread unwound on a [`MergeFault`]).
    pub fn take_fault(&mut self) -> Option<MergeFault> {
        self.fault.take()
    }

    /// Record and return a merge fault for `core`/`slot`.
    fn merge_fault(&mut self, core: usize, slot: u8) -> MergeFault {
        let f = self.mfrf[core].fault(core, slot);
        self.fault.get_or_insert_with(|| f.clone());
        f
    }

    // ------------------------------------------------------------------
    // allocation + functional access (no timing)
    // ------------------------------------------------------------------

    /// Bump-allocate `bytes` with `align` (>= 4). Tracks Table 3 footprint.
    pub fn alloc(&mut self, bytes: u64, align: u64) -> Addr {
        assert!(align.is_power_of_two() && align >= 4);
        let base = (self.alloc_cursor + align - 1) & !(align - 1);
        self.alloc_cursor = base + bytes;
        assert!(
            (self.alloc_cursor as usize) <= self.mem.len() * 4,
            "simulated memory exhausted ({} > {} bytes)",
            self.alloc_cursor,
            self.mem.len() * 4
        );
        self.stats.bytes_allocated += bytes;
        Addr(base)
    }

    /// Line-aligned allocation — required for CData (Section 4.4).
    pub fn alloc_lines(&mut self, bytes: u64) -> Addr {
        self.alloc(bytes.next_multiple_of(64), 64)
    }

    #[inline]
    pub fn peek(&self, addr: Addr) -> u32 {
        self.mem[addr.word_index()]
    }

    #[inline]
    pub fn poke(&mut self, addr: Addr, val: u32) {
        let i = addr.word_index();
        self.mem[i] = val;
    }

    pub fn peek_f32(&self, addr: Addr) -> f32 {
        f32::from_bits(self.peek(addr))
    }

    pub fn poke_f32(&mut self, addr: Addr, val: f32) {
        self.poke(addr, val.to_bits());
    }

    fn mem_line(&self, line: Line) -> LineData {
        let base = line.word_index();
        let mut out = [0u32; LINE_WORDS];
        out.copy_from_slice(&self.mem[base..base + LINE_WORDS]);
        out
    }

    fn set_mem_line(&mut self, line: Line, data: &LineData) {
        let base = line.word_index();
        self.mem[base..base + LINE_WORDS].copy_from_slice(data);
    }

    // ------------------------------------------------------------------
    // coherent access path
    // ------------------------------------------------------------------

    /// Coherent read of one word. Returns (value, cycles).
    pub fn read(&mut self, core: usize, addr: Addr) -> Result<(u32, u64), MergeFault> {
        let cycles = self.coherent_access(core, addr.line(), false)?;
        self.drain_engine(core, cycles);
        Ok((self.mem[addr.word_index()], cycles))
    }

    /// Coherent write of one word. Returns cycles.
    pub fn write(&mut self, core: usize, addr: Addr, val: u32) -> Result<u64, MergeFault> {
        let cycles = self.coherent_access(core, addr.line(), true)?;
        self.drain_engine(core, cycles);
        let i = addr.word_index();
        self.mem[i] = val;
        Ok(cycles)
    }

    /// Atomic compare-and-swap (RFO + RMW). Returns (swapped, cycles).
    pub fn cas(
        &mut self,
        core: usize,
        addr: Addr,
        expected: u32,
        new: u32,
    ) -> Result<(bool, u64), MergeFault> {
        let cycles = self.coherent_access(core, addr.line(), true)?;
        self.drain_engine(core, cycles);
        self.stats.atomic_rmws += 1;
        let i = addr.word_index();
        if self.mem[i] == expected {
            self.mem[i] = new;
            Ok((true, cycles))
        } else {
            Ok((false, cycles))
        }
    }

    /// Atomic fetch-or on a word (BFS atomics variant).
    pub fn fetch_or(&mut self, core: usize, addr: Addr, bits: u32) -> Result<(u32, u64), MergeFault> {
        let cycles = self.coherent_access(core, addr.line(), true)?;
        self.drain_engine(core, cycles);
        self.stats.atomic_rmws += 1;
        let i = addr.word_index();
        let old = self.mem[i];
        self.mem[i] = old | bits;
        Ok((old, cycles))
    }

    /// The MESI walk for a coherent access: the path performs the walk
    /// and all outer fills; the innermost fill loops here because it may
    /// displace mergeable CData that only the engine can merge.
    fn coherent_access(&mut self, core: usize, line: Line, write: bool) -> Result<u64, MergeFault> {
        let walk = self.path.coherent_walk(core, line, write, &mut self.stats);
        if let Some(req) = walk.fill {
            loop {
                match self
                    .path
                    .try_fill_innermost(core, line, req.owned, req.dirty, &mut self.stats)
                {
                    Ok(()) => break,
                    Err(victim) => {
                        // mergeable CData chosen under pressure: merge
                        // first, then re-choose (cycles hidden behind the
                        // miss being serviced)
                        self.evict_cdata_line(core, victim, false)?;
                    }
                }
            }
        }
        Ok(walk.cycles)
    }

    // ------------------------------------------------------------------
    // CCache path (Section 4)
    // ------------------------------------------------------------------

    /// `merge_init(&fn, i)` — register a merge function.
    pub fn merge_init(&mut self, core: usize, slot: usize, f: MergeHandle) {
        self.mfrf[core].install(slot, f);
    }

    /// `c_read(CData, i)` — commutative read of one word.
    pub fn c_read(&mut self, core: usize, addr: Addr, ty: u8) -> Result<(u32, u64), MergeFault> {
        let line = addr.line();
        let cycles = self.cop_access(core, line, ty, false)?;
        self.drain_engine(core, cycles);
        let data = &self.priv_data[core][&line.0];
        Ok((data[(addr.offset() / 4) as usize], cycles))
    }

    /// `c_write(CData, v, i)` — commutative write of one word.
    pub fn c_write(
        &mut self,
        core: usize,
        addr: Addr,
        val: u32,
        ty: u8,
    ) -> Result<u64, MergeFault> {
        let line = addr.line();
        let cycles = self.cop_access(core, line, ty, true)?;
        self.drain_engine(core, cycles);
        let data = self.priv_data[core].get_mut(&line.0).unwrap();
        data[(addr.offset() / 4) as usize] = val;
        Ok(cycles)
    }

    /// Common path for c_read/c_write: hit innermost or privatize the line.
    ///
    /// A COp naming a merge type whose MFRF slot was never initialized is
    /// the hardware's undefined-instruction case: it raises a typed
    /// [`MergeFault`] before touching any structure.
    fn cop_access(&mut self, core: usize, line: Line, ty: u8, write: bool) -> Result<u64, MergeFault> {
        if self.mfrf[core].get(ty).is_none() {
            return Err(self.merge_fault(core, ty));
        }
        self.stats.cops += 1;

        if let Some(idx) = self.path.innermost_mut(core).lookup(line) {
            if self.path.innermost(core).meta(idx).ccache {
                self.stats.ccache_l1_hits += 1;
                let m = self.path.innermost_mut(core).meta_mut(idx);
                // a COp to a mergeable line resets the mergeable bit (4.3)
                m.mergeable = false;
                if write {
                    m.dirty = true;
                }
                // a COp may re-type an already-privatized line: the
                // source-buffer slot binding must follow the L1 meta, or
                // the eventual merge resolves the stale slot captured at
                // privatization (invariant 5). Re-typing is rare, so the
                // source-buffer scan is gated on an actual change.
                if m.merge_type != ty {
                    m.merge_type = ty;
                    self.src_buf[core].set_merge_type(line, ty);
                }
                return Ok(self.cfg.l1().hit_cycles);
            }
            // fall through: phase transition handled below
        }

        // Phase transition: the line may still be held *coherently* in
        // this core's private levels from a previous phase (e.g. a reset
        // pass before a merge boundary). Drop the coherent copies and the
        // directory registration before privatizing — the paper requires
        // CData lines to be exclusively COp-accessed, which holds per
        // phase; across barriers the hardware analog is a flush.
        self.path.drop_coherent(core, line, &mut self.stats);

        // ---- privatizing fill ----
        self.stats.ccache_fills += 1;
        let mut cycles = self.cfg.l1().hit_cycles + self.cfg.llc().hit_cycles;

        // fetch current shared value (shared level or memory), no
        // coherence actions
        if !self.path.fetch_shared(line, &mut self.stats) {
            cycles += self.cfg.timing.mem_cycles;
        }

        // source buffer capacity: merge the LRU entry first (Fig 9 metric)
        if self.src_buf[core].is_full() {
            let victim = self.src_buf[core].lru_entry().unwrap().line;
            self.stats.src_buf_evictions += 1;
            cycles += self.evict_cdata_line(core, victim, false)?;
        }

        // innermost way: may itself merge-evict a mergeable CData line
        let way = loop {
            match self.path.try_cdata_way(core, line, &mut self.stats) {
                Ok(way) => break way,
                Err(victim) => {
                    self.stats.src_buf_evictions += 1;
                    cycles += self.evict_cdata_line(core, victim, false)?;
                }
            }
        };

        // copy into the innermost level (updated copy) and source buffer
        // (source copy), in parallel (Section 4.1) — one latency charged
        let value = self.mem_line(line);
        self.priv_data[core].insert(line.0, value);
        self.src_buf[core].insert(line, value, ty);
        let m = self.path.innermost_mut(core).install(way, line);
        m.ccache = true;
        m.merge_type = ty;
        m.dirty = write;
        Ok(cycles)
    }

    /// `soft_merge` — mark every valid source-buffer entry's line
    /// mergeable (merge-on-evict). Without the optimization this is a
    /// full merge (the Fig 9 baseline) — the policy decides.
    pub fn soft_merge(&mut self, core: usize) -> Result<u64, MergeFault> {
        let entries = self.src_buf[core].valid_entries();
        // an empty source buffer makes soft_merge a no-op in both policy
        // paths: nothing to mark (or flush), so it costs 0 cycles
        if entries.is_empty() {
            return Ok(0);
        }
        if !self.policy.defers_soft_merge() {
            let mut cycles = 0;
            for e in entries {
                self.stats.src_buf_evictions += 1;
                cycles += self.evict_cdata_line(core, e.line, false)?;
            }
            return Ok(cycles);
        }
        let mut marked: u64 = 0;
        for e in entries {
            if let Some(idx) = self.path.innermost(core).probe(e.line) {
                self.path.innermost_mut(core).meta_mut(idx).mergeable = true;
                marked += 1;
            }
        }
        // setting bits is a local L1 operation
        Ok(marked.max(1))
    }

    /// `merge` — merge every valid source-buffer entry now (Table 1).
    pub fn merge_all(&mut self, core: usize) -> Result<u64, MergeFault> {
        let entries = self.src_buf[core].valid_entries();
        let mut cycles = 0;
        for e in entries {
            cycles += self.evict_cdata_line(core, e.line, true)?;
        }
        Ok(cycles)
    }

    /// The core ran `cycles` of other work: the background merge engine
    /// drains in parallel.
    #[inline]
    fn drain_engine(&mut self, core: usize, cycles: u64) {
        let b = &mut self.engine_backlog[core];
        *b = b.saturating_sub(cycles);
    }

    /// Merge one CData line and remove it from the core's innermost
    /// level + source buffer. Returns the cycles charged to the core.
    ///
    /// `sync` selects the policy's timing path: the explicit `merge`
    /// instruction (Table 1) drains the engine and pays the full latency
    /// per line; eviction-triggered merges (merge-on-evict, Section 4.3)
    /// are handed to the pipelined background engine — the core stalls
    /// only when the engine's queue backs up.
    fn evict_cdata_line(&mut self, core: usize, line: Line, sync: bool) -> Result<u64, MergeFault> {
        let Some(entry) = self.src_buf[core].remove(line) else {
            return Ok(0);
        };
        let l1_meta = self.path.innermost_mut(core).invalidate(line);
        let dirty = l1_meta.map_or(true, |m| m.dirty);
        let upd = self.priv_data[core].remove(&line.0).expect("priv copy");

        // cop_access validated the slot at privatization time and
        // merge_init never uninstalls, so this holds in every reachable
        // state — but an uninitialized slot here is still a typed fault,
        // never a rust panic.
        let Some(merge) = self.mfrf[core].get(entry.merge_type).cloned() else {
            return Err(self.merge_fault(core, entry.merge_type));
        };

        match self.policy.on_evict(dirty, merge.as_ref()) {
            MergeDecision::SilentDrop => {
                self.stats.silent_drops += 1;
                return Ok(1);
            }
            MergeDecision::Execute => {}
        }
        let cost = self.policy.charge(sync, &mut self.engine_backlog[core]);

        let mem_val = self.mem_line(line);
        let drop_p = merge.drop_probability();
        let drop_update = if drop_p > 0.0 {
            let drop = self.approx_rng.bernoulli(drop_p as f64);
            if drop {
                self.stats.approx_drops += 1;
            }
            drop
        } else {
            false
        };
        let new = merge.apply(&entry.data, &upd, &mem_val, drop_update);
        self.set_mem_line(line, &new);
        if self.record_merges {
            self.merge_log.push(MergeRecord {
                merge: merge.clone(),
                line,
                item: MergeItem {
                    src: entry.data,
                    upd,
                    mem: mem_val,
                    drop_update,
                },
            });
        }
        self.stats.merges += 1;
        Ok(cost)
    }

    // ------------------------------------------------------------------
    // diagnostics / invariants (property tests)
    // ------------------------------------------------------------------

    pub fn directory(&self) -> &Directory {
        self.path.directory()
    }

    pub fn source_buffer(&self, core: usize) -> &SourceBuffer {
        &self.src_buf[core]
    }

    /// The innermost (CData-bearing) cache of `core`.
    pub fn l1_cache(&self, core: usize) -> &Cache {
        self.path.innermost(core)
    }

    /// The hierarchy this system was built with.
    pub fn hierarchy(&self) -> &AccessPath {
        &self.path
    }

    /// Cross-structure invariants (used by property tests):
    /// 1. every valid source-buffer entry has a CData line innermost;
    /// 2. every CData line has a source-buffer entry and a private copy;
    /// 3. CData lines never appear outside the innermost level;
    /// 4. the directory's internal state is consistent;
    /// 5. every source-buffer entry's merge-type slot equals its L1
    ///    meta's — a COp re-typing a privatized line must rebind both
    ///    (the merge engine resolves the source-buffer slot).
    pub fn check_invariants(&self) -> Result<(), String> {
        for core in 0..self.cfg.cores {
            for e in self.src_buf[core].valid_entries() {
                let idx = self
                    .path
                    .innermost(core)
                    .probe(e.line)
                    .ok_or(format!("core {core}: src-buf line {:#x} not in L1", e.line.0))?;
                let meta = self.path.innermost(core).meta(idx);
                if !meta.ccache {
                    return Err(format!(
                        "core {core}: src-buf line {:#x} in L1 without CCache bit",
                        e.line.0
                    ));
                }
                if meta.merge_type != e.merge_type {
                    return Err(format!(
                        "core {core}: line {:#x} merge-type skew (L1 meta slot {} \
                         vs src-buf slot {})",
                        e.line.0, meta.merge_type, e.merge_type
                    ));
                }
            }
            for slot in self.path.innermost(core).valid_slots() {
                let m = self.path.innermost(core).meta(slot);
                if m.ccache {
                    if !self.src_buf[core].contains(m.line) {
                        return Err(format!(
                            "core {core}: CData line {:#x} lacks src-buf entry",
                            m.line.0
                        ));
                    }
                    if !self.priv_data[core].contains_key(&m.line.0) {
                        return Err(format!(
                            "core {core}: CData line {:#x} lacks private copy",
                            m.line.0
                        ));
                    }
                    for lvl in 1..self.path.private_depth() {
                        if self.path.level(lvl).cache(core).probe(m.line).is_some() {
                            return Err(format!(
                                "core {core}: CData line {:#x} leaked into L{}",
                                m.line.0,
                                lvl + 1
                            ));
                        }
                    }
                }
            }
        }
        self.path.directory().check_invariants()
    }
}

// The protocol test suite lives in `rust/tests/protocol.rs` and
// `rust/tests/mesi.rs`: both exercise the 3-level and 2-level shapes
// through this public API.
