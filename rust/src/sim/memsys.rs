//! The memory-system protocol engine: coherent MESI accesses through the
//! three-level hierarchy, plus the CCache commutative-access path.
//!
//! Timing model (Table 2): an access is charged the hit latency of every
//! level it touches (L1 4, +L2 10, +LLC 70, +memory 300); any coherence
//! transaction (upgrade, remote fetch, RFO) charges one extra LLC round
//! trip because the directory lives at the LLC. Merges charge the paper's
//! flat 170 cycles per line (includes the LLC round trip). Waiting on
//! locked LLC lines is not modeled, exactly as in the paper (Section 5).
//!
//! Functional model: one flat `u32` memory is authoritative for coherent
//! data (the workloads synchronize their racy accesses, so a single copy
//! observes every serialization the protocol would produce). CData is
//! different: each core's privatized *updated copy* lives in a per-core
//! side table and its *source copy* in the source buffer, so merge
//! functions compute real values — final memory contents are checked
//! against sequential golden runs in the integration tests.

use std::collections::HashMap;

use super::addr::{Addr, Line};
use super::cache::{Cache, Victim};
use super::config::MachineConfig;
use super::directory::{CoherenceActions, Directory};
use super::mfrf::Mfrf;
use super::source_buffer::SourceBuffer;
use super::stats::Stats;
use crate::merge::batch::MergeItem;
use crate::merge::funcs::apply_line;
use crate::merge::{LineData, MergeKind, LINE_WORDS};
use crate::util::rng::Rng;

/// Outcome of a CData-line merge (Fig 9 / Section 6.4 accounting).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MergeOutcome {
    /// Merge function executed and memory updated.
    Merged,
    /// Clean line silently dropped (dirty-merge optimization).
    SilentDrop,
}

/// A recorded merge (for PJRT batch validation / deferred execution).
#[derive(Clone, Debug)]
pub struct MergeRecord {
    pub kind: MergeKind,
    pub line: Line,
    pub item: MergeItem,
}

pub struct MemSystem {
    pub cfg: MachineConfig,
    l1: Vec<Cache>,
    l2: Vec<Cache>,
    llc: Cache,
    dir: Directory,
    /// Flat functional memory (word-addressed).
    mem: Vec<u32>,
    /// Per-core CData updated copies (the L1 data array for CData lines).
    priv_data: Vec<HashMap<u64, LineData>>,
    src_buf: Vec<SourceBuffer>,
    mfrf: Vec<Mfrf>,
    /// Background merge-engine backlog per core, in cycles of queued
    /// merge work (victim-buffer model; see CCacheConfig::merge_engine_*).
    engine_backlog: Vec<u64>,
    pub stats: Stats,
    alloc_cursor: u64,
    /// Deterministic stream for approximate-merge drop decisions.
    approx_rng: Rng,
    /// When set, every executed merge is also recorded for batch
    /// validation through the PJRT executor.
    pub record_merges: bool,
    pub merge_log: Vec<MergeRecord>,
}

impl MemSystem {
    pub fn new(cfg: MachineConfig) -> Self {
        cfg.validate().expect("invalid machine config");
        let cores = cfg.cores;
        Self {
            l1: (0..cores)
                .map(|_| Cache::new(cfg.l1.sets(), cfg.l1.ways))
                .collect(),
            l2: (0..cores)
                .map(|_| Cache::new(cfg.l2.sets(), cfg.l2.ways))
                .collect(),
            llc: Cache::new(cfg.llc.sets(), cfg.llc.ways),
            dir: Directory::new(),
            mem: vec![0u32; cfg.mem_bytes / 4],
            priv_data: (0..cores).map(|_| HashMap::new()).collect(),
            src_buf: (0..cores)
                .map(|_| SourceBuffer::new(cfg.ccache.source_buffer_entries))
                .collect(),
            engine_backlog: vec![0; cores],
            mfrf: (0..cores).map(|_| Mfrf::new(cfg.ccache.mfrf_slots)).collect(),
            stats: Stats::new(cores),
            alloc_cursor: 64, // keep address 0 unused
            approx_rng: Rng::new(0xA990_05ED),
            record_merges: false,
            merge_log: Vec::new(),
            cfg,
        }
    }

    // ------------------------------------------------------------------
    // allocation + functional access (no timing)
    // ------------------------------------------------------------------

    /// Bump-allocate `bytes` with `align` (>= 4). Tracks Table 3 footprint.
    pub fn alloc(&mut self, bytes: u64, align: u64) -> Addr {
        assert!(align.is_power_of_two() && align >= 4);
        let base = (self.alloc_cursor + align - 1) & !(align - 1);
        self.alloc_cursor = base + bytes;
        assert!(
            (self.alloc_cursor as usize) <= self.mem.len() * 4,
            "simulated memory exhausted ({} > {} bytes)",
            self.alloc_cursor,
            self.mem.len() * 4
        );
        self.stats.bytes_allocated += bytes;
        Addr(base)
    }

    /// Line-aligned allocation — required for CData (Section 4.4).
    pub fn alloc_lines(&mut self, bytes: u64) -> Addr {
        self.alloc(bytes.next_multiple_of(64), 64)
    }

    #[inline]
    pub fn peek(&self, addr: Addr) -> u32 {
        self.mem[addr.word_index()]
    }

    #[inline]
    pub fn poke(&mut self, addr: Addr, val: u32) {
        let i = addr.word_index();
        self.mem[i] = val;
    }

    pub fn peek_f32(&self, addr: Addr) -> f32 {
        f32::from_bits(self.peek(addr))
    }

    pub fn poke_f32(&mut self, addr: Addr, val: f32) {
        self.poke(addr, val.to_bits());
    }

    fn mem_line(&self, line: Line) -> LineData {
        let base = line.word_index();
        let mut out = [0u32; LINE_WORDS];
        out.copy_from_slice(&self.mem[base..base + LINE_WORDS]);
        out
    }

    fn set_mem_line(&mut self, line: Line, data: &LineData) {
        let base = line.word_index();
        self.mem[base..base + LINE_WORDS].copy_from_slice(data);
    }

    // ------------------------------------------------------------------
    // coherent access path
    // ------------------------------------------------------------------

    /// Coherent read of one word. Returns (value, cycles).
    pub fn read(&mut self, core: usize, addr: Addr) -> (u32, u64) {
        let cycles = self.coherent_access(core, addr.line(), false);
        self.drain_engine(core, cycles);
        (self.mem[addr.word_index()], cycles)
    }

    /// Coherent write of one word. Returns cycles.
    pub fn write(&mut self, core: usize, addr: Addr, val: u32) -> u64 {
        let cycles = self.coherent_access(core, addr.line(), true);
        self.drain_engine(core, cycles);
        let i = addr.word_index();
        self.mem[i] = val;
        cycles
    }

    /// Atomic compare-and-swap (RFO + RMW). Returns (swapped, cycles).
    pub fn cas(&mut self, core: usize, addr: Addr, expected: u32, new: u32) -> (bool, u64) {
        let cycles = self.coherent_access(core, addr.line(), true);
        self.drain_engine(core, cycles);
        self.stats.atomic_rmws += 1;
        let i = addr.word_index();
        if self.mem[i] == expected {
            self.mem[i] = new;
            (true, cycles)
        } else {
            (false, cycles)
        }
    }

    /// Atomic fetch-or on a word (BFS atomics variant).
    pub fn fetch_or(&mut self, core: usize, addr: Addr, bits: u32) -> (u32, u64) {
        let cycles = self.coherent_access(core, addr.line(), true);
        self.drain_engine(core, cycles);
        self.stats.atomic_rmws += 1;
        let i = addr.word_index();
        let old = self.mem[i];
        self.mem[i] = old | bits;
        (old, cycles)
    }

    /// The MESI walk for a coherent access.
    fn coherent_access(&mut self, core: usize, line: Line, write: bool) -> u64 {
        let mut cycles = self.cfg.l1.hit_cycles;

        // ---- L1 ----
        if let Some(idx) = self.l1[core].lookup(line) {
            let meta = *self.l1[core].meta(idx);
            assert!(
                !meta.ccache,
                "coherent access to CData line {:#x} (paper forbids mixing; pad CData)",
                line.0
            );
            self.stats.l1.hits += 1;
            if write {
                if !meta.owned {
                    cycles += self.upgrade(core, line);
                }
                let m = self.l1[core].meta_mut(idx);
                m.dirty = true;
                m.owned = true;
                if let Some(i2) = self.l2[core].lookup(line) {
                    let m2 = self.l2[core].meta_mut(i2);
                    m2.dirty = true;
                    m2.owned = true;
                }
            }
            return cycles;
        }
        self.stats.l1.misses += 1;

        // ---- L2 ----
        cycles += self.cfg.l2.hit_cycles;
        if let Some(idx) = self.l2[core].lookup(line) {
            self.stats.l2.hits += 1;
            let mut meta = *self.l2[core].meta(idx);
            if write && !meta.owned {
                cycles += self.upgrade(core, line);
                meta.owned = true;
            }
            if write {
                meta.dirty = true;
            }
            {
                let m2 = self.l2[core].meta_mut(idx);
                m2.owned = meta.owned;
                m2.dirty = meta.dirty;
            }
            self.fill_l1(core, line, meta.owned, meta.dirty && write);
            return cycles;
        }
        self.stats.l2.misses += 1;

        // ---- LLC + directory ----
        cycles += self.cfg.llc.hit_cycles;
        let act = if write {
            self.dir.get_m(line, core)
        } else {
            self.dir.get_s(line, core)
        };
        // remote dirty owner: the directory must forward the request and
        // wait for the owner's data — one extra LLC-class round trip
        if act.owner_writeback.map_or(false, |o| o != core) {
            cycles += self.cfg.llc.hit_cycles;
        }
        self.apply_actions(core, line, &act);

        if self.llc.lookup(line).is_some() {
            self.stats.llc.hits += 1;
        } else {
            self.stats.llc.misses += 1;
            self.stats.mem_accesses += 1;
            cycles += self.cfg.mem_cycles;
            self.install_llc(line);
        }

        // owned iff the directory granted exclusivity (E on first read,
        // M on any write)
        let owned = write
            || matches!(
                self.dir.entry(line).map(|e| e.state),
                Some(super::directory::DirState::Owned { .. })
            );
        self.fill_l2(core, line, owned, write);
        self.fill_l1(core, line, owned, write);
        cycles
    }

    /// S->M upgrade: directory transaction + invalidations.
    fn upgrade(&mut self, core: usize, line: Line) -> u64 {
        let act = self.dir.get_m(line, core);
        let mut cycles = self.cfg.llc.hit_cycles;
        if act.owner_writeback.map_or(false, |o| o != core) {
            cycles += self.cfg.llc.hit_cycles;
        }
        self.apply_actions(core, line, &act);
        cycles
    }

    /// Apply a directory transaction's side effects to the other cores'
    /// private caches and the stats.
    fn apply_actions(&mut self, me: usize, line: Line, act: &CoherenceActions) {
        self.stats.directory_msgs += act.dir_msgs as u64;
        self.stats.invalidations += act.invalidations as u64;
        if let Some(owner) = act.owner_writeback {
            if owner != me {
                self.stats.writebacks += 1;
            }
        }
        let mut mask = act.inv_mask;
        while mask != 0 {
            let c = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            if c == me {
                continue;
            }
            // CData lines never match an incoming coherence message
            // (Section 4.4): leave them untouched even if the directory
            // has a stale registration for this core.
            if let Some(idx) = self.l1[c].probe(line) {
                if !self.l1[c].meta(idx).ccache {
                    self.l1[c].invalidate(line);
                }
            }
            self.l2[c].invalidate(line);
        }
        // a pure downgrade (GetS hitting an owner) leaves the owner's copy
        // in place but clears its ownership
        if act.inv_mask == 0 {
            if let Some(owner) = act.owner_writeback {
                if owner != me {
                    for cache in [&mut self.l1[owner], &mut self.l2[owner]] {
                        if let Some(idx) = cache.probe(line) {
                            let m = cache.meta_mut(idx);
                            m.owned = false;
                            m.dirty = false;
                        }
                    }
                }
            }
        }
    }

    fn fill_l1(&mut self, core: usize, line: Line, owned: bool, dirty: bool) {
        if self.l1[core].probe(line).is_some() {
            return;
        }
        let way = loop {
            match self.l1[core].choose_victim(line) {
                Victim::Free { way } => break way,
                Victim::Evict { way, meta } => {
                    if meta.ccache {
                        // mergeable CData chosen under pressure: merge first
                        self.evict_cdata_line(core, meta.line, false);
                        // the way is now invalid; loop re-chooses
                        continue;
                    } else {
                        if meta.dirty {
                            // write back into L2 (inclusion guarantees presence)
                            if let Some(i2) = self.l2[core].probe(meta.line) {
                                self.l2[core].meta_mut(i2).dirty = true;
                            }
                        }
                        self.l1[core].invalidate(meta.line);
                        break way;
                    }
                }
                Victim::Deadlock => panic!(
                    "CCache deadlock: all L1 ways in set {} hold pinned CData \
                     (w-1 rule violated, Section 4.4); insert soft_merge/merge",
                    self.l1[core].set_index(line)
                ),
            }
        };
        let m = self.l1[core].install(way, line);
        m.owned = owned;
        m.dirty = dirty;
    }

    fn fill_l2(&mut self, core: usize, line: Line, owned: bool, dirty: bool) {
        if let Some(idx) = self.l2[core].lookup(line) {
            let m = self.l2[core].meta_mut(idx);
            m.owned = owned;
            m.dirty |= dirty;
            return;
        }
        let way = match self.l2[core].choose_victim(line) {
            Victim::Free { way } => way,
            Victim::Evict { way, meta } => {
                debug_assert!(!meta.ccache, "CData never resides in L2");
                // inclusion: back-invalidate L1
                let l1_meta = self.l1[core].invalidate(meta.line);
                let dirty = meta.dirty || l1_meta.map_or(false, |m| m.dirty);
                let act = self.dir.put(meta.line, core, dirty);
                self.stats.directory_msgs += act.dir_msgs as u64;
                if dirty {
                    self.stats.writebacks += 1;
                    if let Some(i) = self.llc.probe(meta.line) {
                        self.llc.meta_mut(i).dirty = true;
                    }
                }
                way
            }
            Victim::Deadlock => unreachable!("L2 holds no CData"),
        };
        let m = self.l2[core].install(way, line);
        m.owned = owned;
        m.dirty = dirty;
    }

    fn install_llc(&mut self, line: Line) {
        if self.llc.probe(line).is_some() {
            return;
        }
        let way = match self.llc.choose_victim(line) {
            Victim::Free { way } => way,
            Victim::Evict { way, meta } => {
                // inclusive recall: kill every private copy
                let (_, act) = self.dir.recall(meta.line);
                self.stats.directory_msgs += act.dir_msgs as u64;
                self.stats.invalidations += act.invalidations as u64;
                let mut dirty = meta.dirty;
                let mut mask = act.inv_mask;
                while mask != 0 {
                    let c = mask.trailing_zeros() as usize;
                    mask &= mask - 1;
                    if let Some(m) = self.l1[c].invalidate(meta.line) {
                        dirty |= m.dirty;
                    }
                    if let Some(m) = self.l2[c].invalidate(meta.line) {
                        dirty |= m.dirty;
                    }
                }
                if dirty {
                    self.stats.writebacks += 1; // LLC -> memory
                }
                way
            }
            Victim::Deadlock => unreachable!("LLC holds no pinned CData"),
        };
        self.llc.install(way, line);
    }

    // ------------------------------------------------------------------
    // CCache path (Section 4)
    // ------------------------------------------------------------------

    /// `merge_init(&fn, i)` — register a merge function.
    pub fn merge_init(&mut self, core: usize, slot: usize, kind: MergeKind) {
        self.mfrf[core].install(slot, kind);
    }

    /// `c_read(CData, i)` — commutative read of one word.
    pub fn c_read(&mut self, core: usize, addr: Addr, ty: u8) -> (u32, u64) {
        let line = addr.line();
        let cycles = self.cop_access(core, line, ty, false);
        self.drain_engine(core, cycles);
        let data = &self.priv_data[core][&line.0];
        (data[(addr.offset() / 4) as usize], cycles)
    }

    /// `c_write(CData, v, i)` — commutative write of one word.
    pub fn c_write(&mut self, core: usize, addr: Addr, val: u32, ty: u8) -> u64 {
        let line = addr.line();
        let cycles = self.cop_access(core, line, ty, true);
        self.drain_engine(core, cycles);
        let data = self.priv_data[core].get_mut(&line.0).unwrap();
        data[(addr.offset() / 4) as usize] = val;
        cycles
    }

    /// Common path for c_read/c_write: hit in L1 or privatize the line.
    fn cop_access(&mut self, core: usize, line: Line, ty: u8, write: bool) -> u64 {
        self.stats.cops += 1;
        debug_assert!(
            self.mfrf[core].try_get(ty).is_some(),
            "COp with uninitialized merge type {ty}"
        );

        if let Some(idx) = self.l1[core].lookup(line) {
            let m = self.l1[core].meta_mut(idx);
            if m.ccache {
                // a COp to a mergeable line resets the mergeable bit (4.3)
                m.mergeable = false;
                if write {
                    m.dirty = true;
                }
                m.merge_type = ty;
                self.stats.ccache_l1_hits += 1;
                return self.cfg.l1.hit_cycles;
            }
            // fall through: phase transition handled below
        }

        // Phase transition: the line may still be held *coherently* in
        // this core's L1/L2 from a previous phase (e.g. a reset pass
        // before a merge boundary). Drop the coherent copy and its
        // directory registration before privatizing — the paper requires
        // CData lines to be exclusively COp-accessed, which holds per
        // phase; across barriers the hardware analog is a flush.
        {
            let d1 = self.l1[core].invalidate(line).map_or(false, |m| m.dirty);
            if let Some(m2) = self.l2[core].invalidate(line) {
                let dirty = d1 || m2.dirty;
                let act = self.dir.put(line, core, dirty);
                self.stats.directory_msgs += act.dir_msgs as u64;
                if dirty {
                    self.stats.writebacks += 1;
                }
            }
        }

        // ---- privatizing fill ----
        self.stats.ccache_fills += 1;
        let mut cycles = self.cfg.l1.hit_cycles + self.cfg.llc.hit_cycles;

        // fetch current shared value (LLC or memory), no coherence actions
        if self.llc.lookup(line).is_some() {
            self.stats.llc.hits += 1;
        } else {
            self.stats.llc.misses += 1;
            self.stats.mem_accesses += 1;
            cycles += self.cfg.mem_cycles;
            self.install_llc(line);
        }

        // source buffer capacity: merge the LRU entry first (Fig 9 metric)
        if self.src_buf[core].is_full() {
            let victim = self.src_buf[core].lru_entry().unwrap().line;
            self.stats.src_buf_evictions += 1;
            cycles += self.evict_cdata_line(core, victim, false);
        }

        // L1 way: may itself merge-evict a mergeable CData line
        let way = loop {
            match self.l1[core].choose_victim(line) {
                Victim::Free { way } => break way,
                Victim::Evict { way, meta } => {
                    if meta.ccache {
                        self.stats.src_buf_evictions += 1;
                        cycles += self.evict_cdata_line(core, meta.line, false);
                        continue;
                    }
                    if meta.dirty {
                        if let Some(i2) = self.l2[core].probe(meta.line) {
                            self.l2[core].meta_mut(i2).dirty = true;
                        }
                    }
                    self.l1[core].invalidate(meta.line);
                    break way;
                }
                Victim::Deadlock => panic!(
                    "CCache deadlock filling CData line {:#x}: all ways pinned \
                     (w-1 rule, Section 4.4)",
                    line.0
                ),
            }
        };

        // copy into L1 (updated copy) and source buffer (source copy),
        // in parallel (Section 4.1) — one latency charged
        let value = self.mem_line(line);
        self.priv_data[core].insert(line.0, value);
        self.src_buf[core].insert(line, value, ty);
        let m = self.l1[core].install(way, line);
        m.ccache = true;
        m.merge_type = ty;
        m.dirty = write;
        cycles
    }

    /// `soft_merge` — mark every valid source-buffer entry's line
    /// mergeable (merge-on-evict). Without the optimization this is a
    /// full merge (the Fig 9 baseline).
    pub fn soft_merge(&mut self, core: usize) -> u64 {
        if !self.cfg.ccache.merge_on_evict {
            let entries = self.src_buf[core].valid_entries();
            let mut cycles = 0;
            for e in entries {
                self.stats.src_buf_evictions += 1;
                cycles += self.evict_cdata_line(core, e.line, false);
            }
            return cycles;
        }
        let mut marked = 0;
        for e in self.src_buf[core].valid_entries() {
            if let Some(idx) = self.l1[core].probe(e.line) {
                self.l1[core].meta_mut(idx).mergeable = true;
                marked += 1;
            }
        }
        // setting bits is a local L1 operation
        marked.max(1)
    }

    /// `merge` — merge every valid source-buffer entry now (Table 1).
    pub fn merge_all(&mut self, core: usize) -> u64 {
        let entries = self.src_buf[core].valid_entries();
        let mut cycles = 0;
        for e in entries {
            cycles += self.evict_cdata_line(core, e.line, true);
        }
        cycles
    }

    /// The core ran `cycles` of other work: the background merge engine
    /// drains in parallel.
    #[inline]
    fn drain_engine(&mut self, core: usize, cycles: u64) {
        let b = &mut self.engine_backlog[core];
        *b = b.saturating_sub(cycles);
    }

    /// Merge one CData line and remove it from the core's L1 + source
    /// buffer. Returns the cycles charged to the core.
    ///
    /// `sync` selects the timing path: the explicit `merge` instruction
    /// (Table 1) drains the engine and pays the full 170-cycle latency
    /// per line; eviction-triggered merges (merge-on-evict, Section 4.3)
    /// are handed to the pipelined background engine — the core stalls
    /// only when the engine's queue backs up.
    fn evict_cdata_line(&mut self, core: usize, line: Line, sync: bool) -> u64 {
        let Some(entry) = self.src_buf[core].remove(line) else {
            return 0;
        };
        let l1_meta = self.l1[core].invalidate(line);
        let dirty = l1_meta.map_or(true, |m| m.dirty);
        let upd = self.priv_data[core].remove(&line.0).expect("priv copy");

        // dirty-merge optimization: clean lines merge to a no-op
        if self.cfg.ccache.dirty_merge && !dirty {
            self.stats.silent_drops += 1;
            return 1;
        }
        let cost = if sync {
            let drain = self.engine_backlog[core];
            self.engine_backlog[core] = 0;
            drain + self.cfg.ccache.merge_latency
        } else {
            let ii = self.cfg.ccache.merge_engine_interval;
            let cap = self.cfg.ccache.merge_engine_queue * ii;
            let b = &mut self.engine_backlog[core];
            *b += ii;
            if *b > cap {
                let stall = *b - cap;
                *b = cap;
                self.cfg.ccache.source_buffer_hit_cycles + stall
            } else {
                self.cfg.ccache.source_buffer_hit_cycles
            }
        };

        let kind = self.mfrf[core].get(entry.merge_type);
        let mem_val = self.mem_line(line);
        let drop_update = match kind {
            MergeKind::ApproxAddF32 { drop_p } => {
                let drop = self.approx_rng.bernoulli(drop_p as f64);
                if drop {
                    self.stats.approx_drops += 1;
                }
                drop
            }
            _ => false,
        };
        let new = apply_line(kind, &entry.data, &upd, &mem_val, drop_update);
        self.set_mem_line(line, &new);
        if self.record_merges {
            self.merge_log.push(MergeRecord {
                kind,
                line,
                item: MergeItem {
                    src: entry.data,
                    upd,
                    mem: mem_val,
                    drop_update,
                },
            });
        }
        self.stats.merges += 1;
        cost
    }

    // ------------------------------------------------------------------
    // diagnostics / invariants (property tests)
    // ------------------------------------------------------------------

    pub fn directory(&self) -> &Directory {
        &self.dir
    }

    pub fn source_buffer(&self, core: usize) -> &SourceBuffer {
        &self.src_buf[core]
    }

    pub fn l1_cache(&self, core: usize) -> &Cache {
        &self.l1[core]
    }

    /// Cross-structure invariants (used by property tests):
    /// 1. every valid source-buffer entry has a CData line in L1;
    /// 2. every CData L1 line has a source-buffer entry and a private copy;
    /// 3. CData lines never appear in L2;
    /// 4. the directory's internal state is consistent.
    pub fn check_invariants(&self) -> Result<(), String> {
        for core in 0..self.cfg.cores {
            for e in self.src_buf[core].valid_entries() {
                let idx = self.l1[core]
                    .probe(e.line)
                    .ok_or(format!("core {core}: src-buf line {:#x} not in L1", e.line.0))?;
                if !self.l1[core].meta(idx).ccache {
                    return Err(format!(
                        "core {core}: src-buf line {:#x} in L1 without CCache bit",
                        e.line.0
                    ));
                }
            }
            for slot in self.l1[core].valid_slots() {
                let m = self.l1[core].meta(slot);
                if m.ccache {
                    if !self.src_buf[core].contains(m.line) {
                        return Err(format!(
                            "core {core}: CData line {:#x} lacks src-buf entry",
                            m.line.0
                        ));
                    }
                    if !self.priv_data[core].contains_key(&m.line.0) {
                        return Err(format!(
                            "core {core}: CData line {:#x} lacks private copy",
                            m.line.0
                        ));
                    }
                    if self.l2[core].probe(m.line).is_some() {
                        return Err(format!(
                            "core {core}: CData line {:#x} leaked into L2",
                            m.line.0
                        ));
                    }
                }
            }
        }
        self.dir.check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> MemSystem {
        MemSystem::new(MachineConfig::test_small())
    }

    #[test]
    fn read_miss_then_hit_latencies() {
        let mut s = sys();
        let a = s.alloc_lines(64);
        // cold: L1(4) + L2(10) + LLC(70) + mem(300)
        let (_, c1) = s.read(0, a);
        assert_eq!(c1, 4 + 10 + 70 + 300);
        // hot: L1 hit
        let (_, c2) = s.read(0, a);
        assert_eq!(c2, 4);
        assert_eq!(s.stats.l1.hits, 1);
        assert_eq!(s.stats.llc.misses, 1);
    }

    #[test]
    fn write_read_roundtrip() {
        let mut s = sys();
        let a = s.alloc_lines(64);
        s.write(0, a, 42);
        let (v, _) = s.read(0, a);
        assert_eq!(v, 42);
        let (v, _) = s.read(1, a.add(0), );
        assert_eq!(v, 42);
    }

    #[test]
    fn write_invalidates_readers() {
        let mut s = sys();
        let a = s.alloc_lines(64);
        s.read(0, a);
        s.read(1, a);
        let inv_before = s.stats.invalidations;
        s.write(0, a, 7);
        assert!(s.stats.invalidations > inv_before);
        // core 1 must now miss in L1
        let l1_misses = s.stats.l1.misses;
        s.read(1, a);
        assert_eq!(s.stats.l1.misses, l1_misses + 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn silent_upgrade_on_exclusive() {
        let mut s = sys();
        let a = s.alloc_lines(64);
        s.read(0, a); // granted E (only reader)
        let msgs = s.stats.directory_msgs;
        let c = s.write(0, a, 1); // silent E->M, L1 hit, owned
        assert_eq!(c, 4);
        assert_eq!(s.stats.directory_msgs, msgs);
    }

    #[test]
    fn shared_write_pays_upgrade() {
        let mut s = sys();
        let a = s.alloc_lines(64);
        s.read(0, a);
        s.read(1, a); // both sharers now
        let c = s.write(0, a, 1); // L1 hit + upgrade round trip
        assert_eq!(c, 4 + 70);
    }

    #[test]
    fn cas_swaps_and_fails_correctly() {
        let mut s = sys();
        let a = s.alloc_lines(64);
        s.poke(a, 0);
        let (ok, _) = s.cas(0, a, 0, 1);
        assert!(ok);
        let (ok, _) = s.cas(1, a, 0, 1);
        assert!(!ok);
        assert_eq!(s.peek(a), 1);
    }

    #[test]
    fn cop_privatizes_and_merges_adds() {
        let mut s = sys();
        let a = s.alloc_lines(64);
        s.poke(a, 100);
        for core in 0..2 {
            s.merge_init(core, 0, MergeKind::AddU32);
        }
        // both cores increment the same word privately
        let (v0, _) = s.c_read(0, a, 0);
        s.c_write(0, a, v0 + 1, 0);
        let (v1, _) = s.c_read(1, a, 0);
        s.c_write(1, a, v1 + 1, 0);
        assert_eq!(v0, 100);
        assert_eq!(v1, 100); // private copies, no interference
        assert_eq!(s.peek(a), 100); // memory untouched before merges
        s.merge_all(0);
        assert_eq!(s.peek(a), 101);
        s.merge_all(1);
        assert_eq!(s.peek(a), 102); // serialization of both updates
        assert_eq!(s.stats.merges, 2);
        s.check_invariants().unwrap();
    }

    #[test]
    fn cop_generates_no_coherence_traffic() {
        let mut s = sys();
        let a = s.alloc_lines(64);
        s.merge_init(0, 0, MergeKind::AddU32);
        s.merge_init(1, 0, MergeKind::AddU32);
        let msgs = s.stats.directory_msgs;
        let invs = s.stats.invalidations;
        for _ in 0..10 {
            let (v, _) = s.c_read(0, a, 0);
            s.c_write(0, a, v + 1, 0);
            let (v, _) = s.c_read(1, a, 0);
            s.c_write(1, a, v + 1, 0);
        }
        assert_eq!(s.stats.directory_msgs, msgs, "COps must not touch the directory");
        assert_eq!(s.stats.invalidations, invs);
    }

    #[test]
    fn source_buffer_capacity_forces_merge() {
        let mut s = sys();
        s.merge_init(0, 0, MergeKind::AddU32);
        let cap = s.cfg.ccache.source_buffer_entries;
        let base = s.alloc_lines(64 * (cap as u64 + 1));
        // touch cap+1 distinct lines; mark mergeable so L1 pressure is legal
        for i in 0..=cap as u64 {
            let addr = base.add(i * 64);
            let (v, _) = s.c_read(0, addr, 0);
            s.c_write(0, addr, v + 1, 0);
            s.soft_merge(0);
        }
        assert!(s.stats.src_buf_evictions >= 1);
        assert!(s.stats.merges >= 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn dirty_merge_drops_clean_lines() {
        let mut s = sys();
        s.merge_init(0, 0, MergeKind::AddU32);
        let a = s.alloc_lines(64);
        s.poke(a, 5);
        s.c_read(0, a, 0); // read-only privatization
        s.merge_all(0);
        assert_eq!(s.stats.silent_drops, 1);
        assert_eq!(s.stats.merges, 0);
        assert_eq!(s.peek(a), 5);
    }

    #[test]
    fn no_dirty_merge_merges_clean_lines_too() {
        let mut cfg = MachineConfig::test_small();
        cfg.ccache.dirty_merge = false;
        let mut s = MemSystem::new(cfg);
        s.merge_init(0, 0, MergeKind::AddU32);
        let a = s.alloc_lines(64);
        s.c_read(0, a, 0);
        s.merge_all(0);
        assert_eq!(s.stats.silent_drops, 0);
        assert_eq!(s.stats.merges, 1);
    }

    #[test]
    fn soft_merge_without_opt_flushes() {
        let mut cfg = MachineConfig::test_small();
        cfg.ccache.merge_on_evict = false;
        let mut s = MemSystem::new(cfg);
        s.merge_init(0, 0, MergeKind::AddU32);
        let a = s.alloc_lines(64);
        let (v, _) = s.c_read(0, a, 0);
        s.c_write(0, a, v + 3, 0);
        s.soft_merge(0);
        assert_eq!(s.peek(a), 3);
        assert_eq!(s.stats.src_buf_evictions, 1);
        assert!(s.source_buffer(0).is_empty());
    }

    #[test]
    fn soft_merge_with_opt_defers() {
        let mut s = sys();
        s.merge_init(0, 0, MergeKind::AddU32);
        let a = s.alloc_lines(64);
        let (v, _) = s.c_read(0, a, 0);
        s.c_write(0, a, v + 3, 0);
        s.soft_merge(0);
        assert_eq!(s.peek(a), 0, "merge deferred");
        assert!(!s.source_buffer(0).is_empty());
        // re-access resets the mergeable bit
        let (v, _) = s.c_read(0, a, 0);
        assert_eq!(v, 3);
        s.merge_all(0);
        assert_eq!(s.peek(a), 3);
    }

    #[test]
    #[should_panic(expected = "w-1 rule")]
    fn pinned_cdata_overflow_deadlocks() {
        let mut cfg = MachineConfig::test_small();
        cfg.ccache.source_buffer_entries = 64; // don't trip SB capacity first
        let mut s = MemSystem::new(cfg);
        s.merge_init(0, 0, MergeKind::AddU32);
        // L1 test_small: 1KB, 4 ways, 4 sets; fill one set with 5 pinned lines
        let sets = s.cfg.l1.sets() as u64;
        let base = s.alloc_lines(64 * sets * 8);
        for i in 0..5u64 {
            let addr = Addr(base.0 + i * sets * 64); // same set
            s.c_read(0, addr, 0); // never soft_merged -> pinned
        }
    }

    #[test]
    fn approx_merge_drops_some_updates() {
        let mut cfg = MachineConfig::test_small();
        cfg.ccache.dirty_merge = true;
        let mut s = MemSystem::new(cfg);
        s.merge_init(0, 0, MergeKind::ApproxAddF32 { drop_p: 0.5 });
        let base = s.alloc_lines(64 * 64);
        for i in 0..64u64 {
            let a = base.add(i * 64);
            let (v, _) = s.c_read(0, a, 0);
            s.c_write(0, a, (f32::from_bits(v) + 1.0).to_bits(), 0);
            s.merge_all(0);
        }
        assert!(s.stats.approx_drops > 5, "drops: {}", s.stats.approx_drops);
        assert!(s.stats.approx_drops < 60);
        // memory reflects kept updates only
        let kept: f32 = (0..64u64).map(|i| s.peek_f32(base.add(i * 64))).sum();
        assert_eq!(kept as u64, 64 - s.stats.approx_drops);
    }

    #[test]
    fn merge_log_records_when_enabled() {
        let mut s = sys();
        s.record_merges = true;
        s.merge_init(0, 0, MergeKind::AddU32);
        let a = s.alloc_lines(64);
        let (v, _) = s.c_read(0, a, 0);
        s.c_write(0, a, v + 1, 0);
        s.merge_all(0);
        assert_eq!(s.merge_log.len(), 1);
        assert_eq!(s.merge_log[0].kind, MergeKind::AddU32);
        assert_eq!(s.merge_log[0].item.upd[0], 1);
    }

    #[test]
    fn alloc_tracks_footprint_and_aligns() {
        let mut s = sys();
        let a = s.alloc(100, 64);
        assert_eq!(a.0 % 64, 0);
        let b = s.alloc_lines(100);
        assert_eq!(b.0 % 64, 0);
        assert!(b.0 >= a.0 + 100);
        assert_eq!(s.stats.bytes_allocated, 100 + 128);
    }
}
