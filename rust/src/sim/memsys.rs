//! The memory-system protocol engine: coherent MESI accesses through a
//! configurable hierarchy, plus the CCache commutative-access path.
//!
//! Timing model (Table 2 defaults): an access is charged the hit latency
//! of every level it touches (L1 4, +L2 10, +LLC 70, +memory 300); any
//! coherence transaction (upgrade, remote fetch, RFO) charges one extra
//! shared-level round trip because the directory lives at the shared
//! level. Merges charge the paper's flat 170 cycles per line. Waiting on
//! locked LLC lines is not modeled, exactly as in the paper (Section 5).
//!
//! Structure: the hierarchy walk, fills and recalls live in
//! [`AccessPath`](super::hierarchy::path::AccessPath) — an arbitrary
//! stack of private levels plus one shared level, built from
//! [`MachineConfig::levels`]. This file keeps the CCache engine state
//! (source buffers, MFRF, the background merge engine) and the merge
//! execution, with the merge/merge-on-evict/dirty-merge decisions behind
//! the [`MergePolicy`](super::hierarchy::merge_policy::MergePolicy) trait.
//!
//! Functional model: one flat `u32` memory is authoritative for coherent
//! data (the workloads synchronize their racy accesses, so a single copy
//! observes every serialization the protocol would produce). CData is
//! different: each core's privatized *updated copy* lives next to its
//! *source copy* in the source buffer entry
//! ([`SourceEntry::upd`](super::source_buffer::SourceEntry::upd)), so
//! merge functions compute real values — final memory contents are
//! checked against sequential golden runs in the integration tests.
//!
//! Partial coherence
//! ([`ProtocolKind::Partial`](super::hierarchy::protocol::ProtocolKind)):
//! the shared level stops ordering plain stores, so the flat memory can
//! no longer stand in for instant visibility. Each core's plain stores
//! land in a private word buffer (`partial_store`); its own loads read
//! through the buffer, remote cores keep seeing the stale flat-memory
//! word, and the buffer drains to flat memory only at publish points —
//! explicit CCache merges (line-granular at privatization/merge, full
//! at `merge`) and barrier flushes (`publish_partial`).
//!
//! Hot path (`MachineConfig::fast_path`, default on): the two dominant
//! access classes — coherent L1 read hits and private-hit COps — skip
//! the full multi-level walk and bump per-core [`HotCounters`] instead
//! of the shared [`Stats`]; [`MemSystem::flush_hot_stats`] folds the
//! scratch in at phase boundaries. The fast path is exact: state
//! transitions and post-flush stats are bit-identical to the full walk
//! (`tests/fastpath_diff.rs` proves it differentially).

use std::collections::HashMap;

use super::addr::{Addr, Line};
use super::cache::Cache;
use super::config::{ConfigError, MachineConfig};
use super::directory::Directory;
use super::hierarchy::level::PartitionPolicy;
use super::hierarchy::merge_policy::{self, MergeDecision, MergePolicy};
use super::hierarchy::path::AccessPath;
use super::hierarchy::protocol::ProtocolKind;
use super::invariant::InvariantViolation;
use super::mfrf::{MergeFault, Mfrf};
use super::source_buffer::SourceBuffer;
use super::stats::{reuse_ratio, HotCounters, Stats};
use crate::merge::batch::MergeItem;
use crate::merge::{LineData, MergeHandle, LINE_WORDS};
use crate::util::rng::Rng;

/// A recorded merge (for PJRT batch validation / deferred execution).
#[derive(Clone)]
pub struct MergeRecord {
    pub merge: MergeHandle,
    pub line: Line,
    pub item: MergeItem,
}

/// Sentinel in `cdata_slot`: this L1 way holds no CData binding.
const NO_SLOT: u32 = u32::MAX;

/// Reuse-aware partition controller epoch, in memory operations (every
/// timed access ticks once, fast path or slow — the tick rides on
/// [`MemSystem::drain_engine`], the one point both paths share).
const PARTITION_EPOCH_OPS: u32 = 512;
/// Grow only when the epoch saw real privatization traffic: at least
/// one fill per 16 ops.
const PARTITION_GROW_MIN_FILLS: u64 = (PARTITION_EPOCH_OPS / 16) as u64;
/// Shrink when privatization traffic dried up: under one fill per 64
/// ops means the merge region is over-provisioned.
const PARTITION_SHRINK_MAX_FILLS: u64 = (PARTITION_EPOCH_OPS / 64) as u64;

/// Epoch state of the reuse-aware way-partition controller (present
/// only when the shared level is partitioned with
/// [`PartitionPolicy::ReuseAware`]). Each epoch it compares the CData
/// reuse observed since the last decision — hits amortize fills, so
/// `reuse_ratio >= 1` means every privatized line earned its LLC way —
/// and grows or shrinks the merge region one way at a time, clamped to
/// `1..llc_ways`. Decisions are deterministic functions of the op
/// count and the exact counters, so fast- and slow-path runs repartition
/// at identical points (the differential suite relies on this).
struct PartitionCtl {
    /// Current merge-region width (mirrors `AccessPath::ccache_ways`).
    ways: usize,
    /// Ops seen this epoch.
    ops: u32,
    /// Counter snapshots at the last epoch boundary.
    last_hits: u64,
    last_fills: u64,
}

pub struct MemSystem {
    pub cfg: MachineConfig,
    /// The cache hierarchy + directory (structure); see module docs.
    path: AccessPath,
    /// Flat functional memory (word-addressed).
    mem: Vec<u32>,
    src_buf: Vec<SourceBuffer>,
    /// `cdata_slot[core][l1_way_index]` = the source-buffer slot bound to
    /// the CData line installed in that way. Written at privatization and
    /// cleared to [`NO_SLOT`] when the way's CData line is merged away
    /// ([`Self::evict_cdata_line`]), so a binding is live exactly while
    /// the way's CCache bit is set — invariant 6 in
    /// [`Self::check_invariants`] pins this (a stale binding would make
    /// the COp fast path resolve another line's updated copy). Gives COp
    /// hits O(1) access to the updated copy instead of an associative
    /// search.
    cdata_slot: Vec<Vec<u32>>,
    mfrf: Vec<Mfrf>,
    /// Background merge-engine backlog per core, in cycles of queued
    /// merge work (victim-buffer model; see CCacheConfig::merge_engine_*).
    engine_backlog: Vec<u64>,
    /// Merge timing/disposition decisions (Section 4.3) as data.
    policy: Box<dyn MergePolicy>,
    /// Reuse-aware way-partition controller; `None` for unpartitioned
    /// or statically partitioned configs.
    part_ctl: Option<PartitionCtl>,
    /// Per-core private store buffers (word index -> value), present
    /// exactly when the protocol is non-coherent
    /// ([`ProtocolKind::Partial`]): plain stores buffer here and become
    /// globally visible only at publish points. `None` under coherent
    /// protocols, where the flat memory is authoritative directly.
    partial_store: Option<Vec<HashMap<usize, u32>>>,
    pub stats: Stats,
    /// Per-core fast-path counter scratch; folded into `stats` by
    /// [`flush_hot_stats`](Self::flush_hot_stats).
    hot: Vec<HotCounters>,
    /// Reusable (lru, line) scratch for merge iteration — soft_merge and
    /// merge_all walk the source buffer through this instead of
    /// allocating a fresh sorted `Vec` per call.
    merge_scratch: Vec<(u64, Line)>,
    alloc_cursor: u64,
    /// Deterministic stream for approximate-merge drop decisions.
    approx_rng: Rng,
    /// When set, every executed merge is also recorded for batch
    /// validation through the PJRT executor.
    pub record_merges: bool,
    pub merge_log: Vec<MergeRecord>,
    /// The first machine fault this system raised (COp on an
    /// uninitialized MFRF slot). Recorded here so the execution layer
    /// can recover the typed fault even when the raising core thread
    /// unwinds; see [`MemSystem::take_fault`].
    fault: Option<MergeFault>,
}

impl MemSystem {
    /// Build the memory system a configuration describes; a malformed
    /// configuration is a typed [`ConfigError`] (the execution layer
    /// turns it into a CLI diagnostic instead of a panic).
    pub fn new(cfg: MachineConfig) -> Result<Self, ConfigError> {
        cfg.validate()?;
        let cores = cfg.cores;
        let l1_slots = cfg.l1().sets() * cfg.l1().ways;
        let partition = cfg.llc().partition;
        let mut stats = Stats::new(cores, cfg.depth());
        if let Some(p) = partition {
            let w = p.ccache_ways as u64;
            stats.partition_ways_min = w;
            stats.partition_ways_max = w;
            stats.partition_ways_final = w;
        }
        Ok(Self {
            path: AccessPath::new(&cfg),
            mem: vec![0u32; cfg.mem_bytes / 4],
            src_buf: (0..cores)
                .map(|_| SourceBuffer::new(cfg.ccache.source_buffer_entries))
                .collect(),
            cdata_slot: (0..cores).map(|_| vec![NO_SLOT; l1_slots]).collect(),
            engine_backlog: vec![0; cores],
            mfrf: (0..cores).map(|_| Mfrf::new(cfg.ccache.mfrf_slots)).collect(),
            policy: merge_policy::from_config(&cfg.ccache),
            part_ctl: partition.and_then(|p| {
                (p.policy == PartitionPolicy::ReuseAware).then_some(PartitionCtl {
                    ways: p.ccache_ways,
                    ops: 0,
                    last_hits: 0,
                    last_fills: 0,
                })
            }),
            partial_store: (cfg.protocol == ProtocolKind::Partial)
                .then(|| vec![HashMap::new(); cores]),
            stats,
            hot: vec![HotCounters::default(); cores],
            merge_scratch: Vec::new(),
            alloc_cursor: 64, // keep address 0 unused
            approx_rng: Rng::new(0xA990_05ED),
            record_merges: false,
            merge_log: Vec::new(),
            fault: None,
            cfg,
        })
    }

    /// Take the recorded machine fault, if any (execution-layer recovery
    /// path after a core thread unwound on a [`MergeFault`]).
    pub fn take_fault(&mut self) -> Option<MergeFault> {
        self.fault.take()
    }

    /// Record and return a merge fault for `core`/`slot`.
    fn merge_fault(&mut self, core: usize, slot: u8) -> MergeFault {
        let f = self.mfrf[core].fault(core, slot);
        self.fault.get_or_insert_with(|| f.clone());
        f
    }

    // ------------------------------------------------------------------
    // allocation + functional access (no timing)
    // ------------------------------------------------------------------

    /// Bump-allocate `bytes` with `align` (>= 4). Tracks Table 3 footprint.
    pub fn alloc(&mut self, bytes: u64, align: u64) -> Addr {
        assert!(align.is_power_of_two() && align >= 4);
        let base = (self.alloc_cursor + align - 1) & !(align - 1);
        self.alloc_cursor = base + bytes;
        assert!(
            (self.alloc_cursor as usize) <= self.mem.len() * 4,
            "simulated memory exhausted ({} > {} bytes)",
            self.alloc_cursor,
            self.mem.len() * 4
        );
        self.stats.bytes_allocated += bytes;
        Addr(base)
    }

    /// Line-aligned allocation — required for CData (Section 4.4).
    pub fn alloc_lines(&mut self, bytes: u64) -> Addr {
        self.alloc(bytes.next_multiple_of(64), 64)
    }

    #[inline]
    pub fn peek(&self, addr: Addr) -> u32 {
        self.mem[addr.word_index()]
    }

    #[inline]
    pub fn poke(&mut self, addr: Addr, val: u32) {
        let i = addr.word_index();
        self.mem[i] = val;
    }

    pub fn peek_f32(&self, addr: Addr) -> f32 {
        f32::from_bits(self.peek(addr))
    }

    pub fn poke_f32(&mut self, addr: Addr, val: f32) {
        self.poke(addr, val.to_bits());
    }

    /// Clone the flat functional memory image. The native backend seeds
    /// its `AtomicU32` array from this after `Workload::setup` ran.
    pub fn snapshot_mem(&self) -> Vec<u32> {
        self.mem.clone()
    }

    /// Overwrite the flat functional memory image (same length). The
    /// native backend writes its final image back through this so
    /// `Workload::verify` reads it via the ordinary peek API.
    pub fn restore_mem(&mut self, words: &[u32]) {
        assert_eq!(
            words.len(),
            self.mem.len(),
            "restored memory image must match the configured size"
        );
        self.mem.copy_from_slice(words);
    }

    /// One word as `core` observes it: its own buffered store if partial
    /// coherence holds one back, the flat memory otherwise. Both the
    /// fast and the slow read path load through this, so they are
    /// value-identical by construction.
    #[inline]
    fn load_word(&self, core: usize, i: usize) -> u32 {
        if let Some(buf) = &self.partial_store {
            if let Some(&v) = buf[core].get(&i) {
                return v;
            }
        }
        self.mem[i]
    }

    /// Store one word as `core`: buffered privately under partial
    /// coherence, straight to flat memory under coherent protocols.
    #[inline]
    fn store_word(&mut self, core: usize, i: usize, val: u32) {
        if let Some(buf) = &mut self.partial_store {
            buf[core].insert(i, val);
        } else {
            self.mem[i] = val;
        }
    }

    /// Publish every store `core` has buffered (partial coherence
    /// barrier flush; a no-op under coherent protocols). Distinct words
    /// drain independently, so the hash-map drain order cannot change
    /// the final image.
    pub fn publish_partial(&mut self, core: usize) {
        if let Some(buf) = &mut self.partial_store {
            for (i, v) in buf[core].drain() {
                self.mem[i] = v;
            }
        }
    }

    /// Publish every core's buffered stores (end-of-run flush).
    pub fn publish_partial_all(&mut self) {
        for core in 0..self.cfg.cores {
            self.publish_partial(core);
        }
    }

    /// Fold `core`'s buffered stores covering `line` into the flat
    /// memory. Runs before the engine reads a whole line on `core`'s
    /// behalf (privatizing fill source copy, merge target) — a CCache
    /// merge is a publish point under partial coherence, and the core
    /// must at least see its own earlier plain stores.
    fn publish_partial_line(&mut self, core: usize, line: Line) {
        if self.partial_store.is_none() {
            return;
        }
        let base = line.word_index();
        let buf = self.partial_store.as_mut().unwrap();
        for i in base..base + LINE_WORDS {
            if let Some(v) = buf[core].remove(&i) {
                self.mem[i] = v;
            }
        }
    }

    fn mem_line(&self, line: Line) -> LineData {
        let base = line.word_index();
        let mut out = [0u32; LINE_WORDS];
        out.copy_from_slice(&self.mem[base..base + LINE_WORDS]);
        out
    }

    fn set_mem_line(&mut self, line: Line, data: &LineData) {
        let base = line.word_index();
        self.mem[base..base + LINE_WORDS].copy_from_slice(data);
    }

    // ------------------------------------------------------------------
    // coherent access path
    // ------------------------------------------------------------------

    /// Coherent read of one word. Returns (value, cycles).
    pub fn read(&mut self, core: usize, addr: Addr) -> Result<(u32, u64), MergeFault> {
        let line = addr.line();
        // fast path: the dominant class — a read hitting L1. The probe
        // either commits the exact hit transaction (LRU touch, one
        // batched hit counter) or leaves no trace and the full walk runs.
        if self.cfg.fast_path {
            if let Some(cycles) = self.path.read_hit_innermost(core, line) {
                self.hot[core].l1_hits += 1;
                self.drain_engine(core, cycles);
                return Ok((self.load_word(core, addr.word_index()), cycles));
            }
        }
        let cycles = self.coherent_access(core, line, false)?;
        self.drain_engine(core, cycles);
        Ok((self.load_word(core, addr.word_index()), cycles))
    }

    /// Coherent write of one word. Returns cycles.
    pub fn write(&mut self, core: usize, addr: Addr, val: u32) -> Result<u64, MergeFault> {
        let cycles = self.coherent_access(core, addr.line(), true)?;
        self.drain_engine(core, cycles);
        let i = addr.word_index();
        self.store_word(core, i, val);
        Ok(cycles)
    }

    /// Atomic compare-and-swap (RFO + RMW). Returns (swapped, cycles).
    pub fn cas(
        &mut self,
        core: usize,
        addr: Addr,
        expected: u32,
        new: u32,
    ) -> Result<(bool, u64), MergeFault> {
        let cycles = self.coherent_access(core, addr.line(), true)?;
        self.drain_engine(core, cycles);
        self.stats.atomic_rmws += 1;
        // RMWs need a coherent shared level to be atomic; the driver
        // rejects RMW variants under partial coherence, so these operate
        // on the flat memory directly in every reachable configuration.
        let i = addr.word_index();
        if self.mem[i] == expected {
            self.mem[i] = new;
            Ok((true, cycles))
        } else {
            Ok((false, cycles))
        }
    }

    /// Atomic fetch-or on a word (BFS atomics variant).
    pub fn fetch_or(&mut self, core: usize, addr: Addr, bits: u32) -> Result<(u32, u64), MergeFault> {
        let cycles = self.coherent_access(core, addr.line(), true)?;
        self.drain_engine(core, cycles);
        self.stats.atomic_rmws += 1;
        let i = addr.word_index();
        let old = self.mem[i];
        self.mem[i] = old | bits;
        Ok((old, cycles))
    }

    /// The MESI walk for a coherent access: the path performs the walk
    /// and all outer fills; the innermost fill loops here because it may
    /// displace mergeable CData that only the engine can merge.
    fn coherent_access(&mut self, core: usize, line: Line, write: bool) -> Result<u64, MergeFault> {
        let walk = self.path.coherent_walk(core, line, write, &mut self.stats);
        if let Some(req) = walk.fill {
            loop {
                match self
                    .path
                    .try_fill_innermost(core, line, req.owned, req.dirty, &mut self.stats)
                {
                    Ok(()) => break,
                    Err(victim) => {
                        // mergeable CData chosen under pressure: merge
                        // first, then re-choose (cycles hidden behind the
                        // miss being serviced)
                        self.evict_cdata_line(core, victim, false)?;
                    }
                }
            }
        }
        Ok(walk.cycles)
    }

    // ------------------------------------------------------------------
    // CCache path (Section 4)
    // ------------------------------------------------------------------

    /// `merge_init(&fn, i)` — register a merge function.
    pub fn merge_init(&mut self, core: usize, slot: usize, f: MergeHandle) {
        self.mfrf[core].install(slot, f);
    }

    /// `c_read(CData, i)` — commutative read of one word.
    pub fn c_read(&mut self, core: usize, addr: Addr, ty: u8) -> Result<(u32, u64), MergeFault> {
        let line = addr.line();
        let (cycles, slot) = self.cop_access(core, line, ty, false)?;
        self.drain_engine(core, cycles);
        Ok((self.src_buf[core].upd(slot)[(addr.offset() / 4) as usize], cycles))
    }

    /// `c_write(CData, v, i)` — commutative write of one word.
    pub fn c_write(
        &mut self,
        core: usize,
        addr: Addr,
        val: u32,
        ty: u8,
    ) -> Result<u64, MergeFault> {
        let line = addr.line();
        let (cycles, slot) = self.cop_access(core, line, ty, true)?;
        self.drain_engine(core, cycles);
        self.src_buf[core].upd_mut(slot)[(addr.offset() / 4) as usize] = val;
        Ok(cycles)
    }

    /// Common path for c_read/c_write: hit innermost or privatize the
    /// line. Returns the cycles charged and the source-buffer slot
    /// holding the line's updated copy.
    ///
    /// A COp naming a merge type whose MFRF slot was never initialized is
    /// the hardware's undefined-instruction case: it raises a typed
    /// [`MergeFault`] before touching any structure.
    fn cop_access(
        &mut self,
        core: usize,
        line: Line,
        ty: u8,
        write: bool,
    ) -> Result<(u64, usize), MergeFault> {
        if self.mfrf[core].get(ty).is_none() {
            return Err(self.merge_fault(core, ty));
        }

        // fast path: private CData hit. Same transitions as the slow hit
        // block below, with the counters batched per core; a probe miss
        // or a coherent copy leaves no trace (probe never ticks) and
        // falls through to the full path.
        if self.cfg.fast_path {
            let hit_cycles = self.cfg.l1().hit_cycles;
            let l1 = self.path.innermost_mut(core);
            if let Some(idx) = l1.probe(line) {
                if l1.is_ccache(idx) {
                    l1.touch(idx);
                    l1.set_mergeable(idx, false);
                    if write {
                        l1.set_dirty(idx, true);
                    }
                    let retype = l1.merge_type(idx) != ty;
                    if retype {
                        l1.set_merge_type(idx, ty);
                        self.src_buf[core].set_merge_type(line, ty);
                    }
                    self.hot[core].cops += 1;
                    self.hot[core].ccache_l1_hits += 1;
                    return Ok((hit_cycles, self.cdata_slot[core][idx] as usize));
                }
            }
        }

        self.stats.cops += 1;

        if let Some(idx) = self.path.innermost_mut(core).lookup(line) {
            if self.path.innermost(core).is_ccache(idx) {
                // (with fast_path on, the block above already took this)
                self.stats.ccache_l1_hits += 1;
                let l1 = self.path.innermost_mut(core);
                // a COp to a mergeable line resets the mergeable bit (4.3)
                l1.set_mergeable(idx, false);
                if write {
                    l1.set_dirty(idx, true);
                }
                // a COp may re-type an already-privatized line: the
                // source-buffer slot binding must follow the L1 meta, or
                // the eventual merge resolves the stale slot captured at
                // privatization (invariant 5). Re-typing is rare, so the
                // source-buffer scan is gated on an actual change.
                if l1.merge_type(idx) != ty {
                    l1.set_merge_type(idx, ty);
                    self.src_buf[core].set_merge_type(line, ty);
                }
                return Ok((self.cfg.l1().hit_cycles, self.cdata_slot[core][idx] as usize));
            }
            // fall through: phase transition handled below
        }

        // Phase transition: the line may still be held *coherently* in
        // this core's private levels from a previous phase (e.g. a reset
        // pass before a merge boundary). Drop the coherent copies and the
        // directory registration before privatizing — the paper requires
        // CData lines to be exclusively COp-accessed, which holds per
        // phase; across barriers the hardware analog is a flush.
        self.path.drop_coherent(core, line, &mut self.stats);

        // ---- privatizing fill ----
        self.stats.ccache_fills += 1;
        let mut cycles = self.cfg.l1().hit_cycles + self.cfg.llc().hit_cycles;

        // fetch current shared value (shared level or memory), no
        // coherence actions; classed as CData so a partitioned LLC
        // allocates it inside the merge-region ways
        if !self.path.fetch_shared(line, true, &mut self.stats) {
            cycles += self.cfg.timing.mem_cycles;
        }

        // source buffer capacity: merge the LRU entry first (Fig 9 metric)
        if self.src_buf[core].is_full() {
            let victim = self.src_buf[core].lru_entry().unwrap().line;
            self.stats.src_buf_evictions += 1;
            cycles += self.evict_cdata_line(core, victim, false)?;
        }

        // innermost way: may itself merge-evict a mergeable CData line
        let way = loop {
            match self.path.try_cdata_way(core, line, &mut self.stats) {
                Ok(way) => break way,
                Err(victim) => {
                    self.stats.src_buf_evictions += 1;
                    cycles += self.evict_cdata_line(core, victim, false)?;
                }
            }
        };

        // copy into the innermost level (updated copy) and source buffer
        // (source copy), in parallel (Section 4.1) — one latency charged.
        // Under partial coherence the core's own buffered plain stores
        // to this line publish first, so the source copy sees them.
        self.publish_partial_line(core, line);
        let value = self.mem_line(line);
        let slot = self.src_buf[core].insert(line, value, ty);
        self.cdata_slot[core][way] = slot as u32;
        let l1 = self.path.innermost_mut(core);
        l1.install(way, line);
        l1.set_ccache(way, true);
        l1.set_merge_type(way, ty);
        l1.set_dirty(way, write);
        Ok((cycles, slot))
    }

    /// `soft_merge` — mark every valid source-buffer entry's line
    /// mergeable (merge-on-evict). Without the optimization this is a
    /// full merge (the Fig 9 baseline) — the policy decides.
    pub fn soft_merge(&mut self, core: usize) -> Result<u64, MergeFault> {
        // reuse the engine-wide scratch (take/restore keeps the borrow
        // checker happy while evictions run against &mut self)
        let mut scratch = std::mem::take(&mut self.merge_scratch);
        self.src_buf[core].collect_oldest_first(&mut scratch);
        let result = self.soft_merge_entries(core, &scratch);
        self.merge_scratch = scratch;
        result
    }

    fn soft_merge_entries(
        &mut self,
        core: usize,
        entries: &[(u64, Line)],
    ) -> Result<u64, MergeFault> {
        // an empty source buffer makes soft_merge a no-op in both policy
        // paths: nothing to mark (or flush), so it costs 0 cycles
        if entries.is_empty() {
            return Ok(0);
        }
        if !self.policy.defers_soft_merge() {
            let mut cycles = 0;
            for &(_, line) in entries {
                self.stats.src_buf_evictions += 1;
                cycles += self.evict_cdata_line(core, line, false)?;
            }
            return Ok(cycles);
        }
        let mut marked: u64 = 0;
        for &(_, line) in entries {
            if let Some(idx) = self.path.innermost(core).probe(line) {
                self.path.innermost_mut(core).set_mergeable(idx, true);
                marked += 1;
            }
        }
        // setting bits is a local L1 operation
        Ok(marked.max(1))
    }

    /// `merge` — merge every valid source-buffer entry now (Table 1).
    pub fn merge_all(&mut self, core: usize) -> Result<u64, MergeFault> {
        // a merge is a phase boundary: fold the fast-path scratch in so
        // anything inspecting stats right after sees exact totals, and
        // publish the core's buffered stores (partial coherence)
        self.flush_hot_stats();
        self.publish_partial(core);
        let mut scratch = std::mem::take(&mut self.merge_scratch);
        self.src_buf[core].collect_oldest_first(&mut scratch);
        let mut cycles = 0;
        let mut result = Ok(());
        for &(_, line) in &scratch {
            match self.evict_cdata_line(core, line, true) {
                Ok(c) => cycles += c,
                Err(f) => {
                    result = Err(f);
                    break;
                }
            }
        }
        self.merge_scratch = scratch;
        result.map(|()| cycles)
    }

    /// Fold the per-core fast-path scratch counters into [`Stats`].
    /// Called at phase boundaries (end of run, barrier, merge); safe to
    /// call any time — the fast path and the flush together account each
    /// event exactly once.
    pub fn flush_hot_stats(&mut self) {
        for h in &mut self.hot {
            if h.is_empty() {
                continue;
            }
            self.stats.levels[0].hits += h.l1_hits;
            self.stats.cops += h.cops;
            self.stats.ccache_l1_hits += h.ccache_l1_hits;
            *h = HotCounters::default();
        }
    }

    /// Exact statistics at any instant, fast path on or off: a copy of
    /// [`Stats`] with the per-core fast-path scratch counters folded in
    /// *non-destructively*. Mid-phase readers must use this (or call
    /// [`Self::flush_hot_stats`] first) — reading `self.stats` raw while
    /// `fast_path` is on under-reports L1 hits and COps by whatever the
    /// hot counters have batched since the last phase boundary.
    pub fn stats_snapshot(&self) -> Stats {
        let mut stats = self.stats.clone();
        for h in &self.hot {
            stats.levels[0].hits += h.l1_hits;
            stats.cops += h.cops;
            stats.ccache_l1_hits += h.ccache_l1_hits;
        }
        stats
    }

    /// The core ran `cycles` of other work: the background merge engine
    /// drains in parallel. Also the reuse-aware partition controller's
    /// tick point: every timed access passes through here exactly once
    /// on both the fast and the slow path, so epoch boundaries (and
    /// therefore repartition decisions) land on identical op indices in
    /// either mode — `tests/fastpath_diff.rs` proves it.
    #[inline]
    fn drain_engine(&mut self, core: usize, cycles: u64) {
        let b = &mut self.engine_backlog[core];
        *b = b.saturating_sub(cycles);
        if self.part_ctl.is_some() {
            self.tick_partition();
        }
    }

    /// One controller tick; at each epoch boundary compare the CData
    /// reuse since the last decision and resize the merge region by at
    /// most one way (see [`PartitionCtl`]).
    fn tick_partition(&mut self) {
        let Some(ctl) = self.part_ctl.as_mut() else {
            return;
        };
        ctl.ops += 1;
        if ctl.ops < PARTITION_EPOCH_OPS {
            return;
        }
        ctl.ops = 0;
        // exact counters regardless of fast-path batching: the hot
        // scratch holds whatever hasn't been folded into `stats` yet
        let hits = self.stats.ccache_l1_hits
            + self.hot.iter().map(|h| h.ccache_l1_hits).sum::<u64>();
        let fills = self.stats.ccache_fills;
        let d_hits = hits - ctl.last_hits;
        let d_fills = fills - ctl.last_fills;
        ctl.last_hits = hits;
        ctl.last_fills = fills;
        let max_ways = self.cfg.llc().ways - 1;
        let target = if d_fills >= PARTITION_GROW_MIN_FILLS && reuse_ratio(d_hits, d_fills) >= 1.0
        {
            // sustained privatization whose hits amortize the fills:
            // the merge region earns more capacity
            (ctl.ways + 1).min(max_ways)
        } else if d_fills < PARTITION_SHRINK_MAX_FILLS {
            // privatization traffic dried up (resident CData or a
            // coherent phase): give ways back to ordinary data
            ctl.ways.saturating_sub(1).max(1)
        } else {
            ctl.ways
        };
        if target != ctl.ways {
            ctl.ways = target;
            self.path.set_ccache_ways(target);
            self.stats.repartitions += 1;
            let w = target as u64;
            self.stats.partition_ways_min = self.stats.partition_ways_min.min(w);
            self.stats.partition_ways_max = self.stats.partition_ways_max.max(w);
            self.stats.partition_ways_final = w;
        }
    }

    /// Merge one CData line and remove it from the core's innermost
    /// level + source buffer. Returns the cycles charged to the core.
    ///
    /// `sync` selects the policy's timing path: the explicit `merge`
    /// instruction (Table 1) drains the engine and pays the full latency
    /// per line; eviction-triggered merges (merge-on-evict, Section 4.3)
    /// are handed to the pipelined background engine — the core stalls
    /// only when the engine's queue backs up.
    fn evict_cdata_line(&mut self, core: usize, line: Line, sync: bool) -> Result<u64, MergeFault> {
        let Some(entry) = self.src_buf[core].remove(line) else {
            return Ok(0);
        };
        // drop the way's fast-path binding with the line: a later CData
        // fill reusing this way rebinds before its first COp, but only
        // because privatization writes `cdata_slot` unconditionally — a
        // stale slot here would silently alias another line's updated
        // copy if that ordering ever changed, so clear it defensively
        // (invariant 6 then pins the live-binding property)
        if let Some(idx) = self.path.innermost(core).probe(line) {
            self.cdata_slot[core][idx] = NO_SLOT;
        }
        let l1_meta = self.path.innermost_mut(core).invalidate(line);
        let dirty = l1_meta.map_or(true, |m| m.dirty);

        // cop_access validated the slot at privatization time and
        // merge_init never uninstalls, so this holds in every reachable
        // state — but an uninitialized slot here is still a typed fault,
        // never a rust panic.
        let Some(merge) = self.mfrf[core].get(entry.merge_type).cloned() else {
            return Err(self.merge_fault(core, entry.merge_type));
        };

        match self.policy.on_evict(dirty, merge.as_ref()) {
            MergeDecision::SilentDrop => {
                self.stats.silent_drops += 1;
                return Ok(1);
            }
            MergeDecision::Execute => {}
        }
        let cost = self.policy.charge(sync, &mut self.engine_backlog[core]);

        // a merge publishes: fold the core's buffered stores to this
        // line in (partial coherence) before reading the merge target
        self.publish_partial_line(core, line);
        let mem_val = self.mem_line(line);
        let drop_p = merge.drop_probability();
        let drop_update = if drop_p > 0.0 {
            let drop = self.approx_rng.bernoulli(drop_p as f64);
            if drop {
                self.stats.approx_drops += 1;
            }
            drop
        } else {
            false
        };
        let new = merge.apply(&entry.data, &entry.upd, &mem_val, drop_update);
        self.set_mem_line(line, &new);
        if self.record_merges {
            self.merge_log.push(MergeRecord {
                merge: merge.clone(),
                line,
                item: MergeItem {
                    src: entry.data,
                    upd: entry.upd,
                    mem: mem_val,
                    drop_update,
                },
            });
        }
        self.stats.merges += 1;
        Ok(cost)
    }

    // ------------------------------------------------------------------
    // diagnostics / invariants (property tests)
    // ------------------------------------------------------------------

    pub fn directory(&self) -> &Directory {
        self.path.directory()
    }

    pub fn source_buffer(&self, core: usize) -> &SourceBuffer {
        &self.src_buf[core]
    }

    /// The innermost (CData-bearing) cache of `core`.
    pub fn l1_cache(&self, core: usize) -> &Cache {
        self.path.innermost(core)
    }

    /// The hierarchy this system was built with.
    pub fn hierarchy(&self) -> &AccessPath {
        &self.path
    }

    /// Mutable hierarchy access — exists for invariant-injection tests
    /// (corrupt the directory through
    /// [`AccessPath::directory_mut`], then watch
    /// [`Self::check_invariants`] catch it); engine code never needs it.
    pub fn hierarchy_mut(&mut self) -> &mut AccessPath {
        &mut self.path
    }

    /// Cross-structure invariants (used by property tests and the
    /// execution driver):
    /// 1. every valid source-buffer entry has a CData line innermost;
    /// 2. every CData line has a source-buffer entry;
    /// 3. CData lines never appear outside the innermost level;
    /// 4. the directory's internal state is consistent;
    /// 5. every source-buffer entry's merge-type slot equals its L1
    ///    meta's — a COp re-typing a privatized line must rebind both
    ///    (the merge engine resolves the source-buffer slot);
    /// 6. every CCache-bit way's `cdata_slot` binding is live: not
    ///    `NO_SLOT`, and the bound source-buffer slot holds exactly the
    ///    way's line — the COp fast path resolves the updated copy
    ///    through this binding, so a stale one would corrupt data;
    /// 7. with a shared-level way partition active, every CData-classed
    ///    LLC line sits inside the merge-region ways (repartition
    ///    shrinks clear stranded class tags); without one, no LLC line
    ///    is CData-classed at all;
    /// 8. directory registration and outermost-private-level residency
    ///    agree under coherent protocols (every sharer bit is backed by
    ///    a non-CData copy and vice versa); under partial coherence the
    ///    directory stays empty — see
    ///    [`AccessPath::check_sharer_invariant`].
    pub fn check_invariants(&self) -> Result<(), InvariantViolation> {
        for core in 0..self.cfg.cores {
            for e in self.src_buf[core].iter_valid() {
                let Some(idx) = self.path.innermost(core).probe(e.line) else {
                    return Err(InvariantViolation::engine(
                        core,
                        e.line.0,
                        "src-buf line not in L1",
                    ));
                };
                let meta = self.path.innermost(core).meta(idx);
                if !meta.ccache {
                    return Err(InvariantViolation::engine(
                        core,
                        e.line.0,
                        "src-buf line in L1 without CCache bit",
                    ));
                }
                if meta.merge_type != e.merge_type {
                    return Err(InvariantViolation::engine(
                        core,
                        e.line.0,
                        format!(
                            "merge-type skew (L1 meta slot {} vs src-buf slot {})",
                            meta.merge_type, e.merge_type
                        ),
                    ));
                }
            }
            for slot in self.path.innermost(core).valid_slots() {
                let m = self.path.innermost(core).meta(slot);
                if m.ccache {
                    if !self.src_buf[core].contains(m.line) {
                        return Err(InvariantViolation::engine(
                            core,
                            m.line.0,
                            "CData line lacks src-buf entry",
                        ));
                    }
                    let bound = self.cdata_slot[core][slot];
                    if bound == NO_SLOT {
                        return Err(InvariantViolation::engine(
                            core,
                            m.line.0,
                            "CData way has no cdata_slot binding",
                        ));
                    }
                    if self.src_buf[core].slot_line(bound as usize) != Some(m.line) {
                        return Err(InvariantViolation::engine(
                            core,
                            m.line.0,
                            format!(
                                "stale cdata_slot binding (way {slot} -> src-buf slot {bound})"
                            ),
                        ));
                    }
                    for lvl in 1..self.path.private_depth() {
                        if self.path.level(lvl).cache(core).probe(m.line).is_some() {
                            return Err(InvariantViolation::engine(
                                core,
                                m.line.0,
                                format!("CData line leaked into L{}", lvl + 1),
                            ));
                        }
                    }
                }
            }
        }
        self.path.check_partition_invariant()?;
        self.path.check_sharer_invariant()?;
        self.path.directory().check_invariants()
    }
}

// The protocol test suite lives in `rust/tests/protocol.rs` and
// `rust/tests/mesi.rs`: both exercise the 3-level and 2-level shapes
// through this public API.
