//! Set-associative cache model with per-line CCache metadata.
//!
//! Tag array only — functional data lives in the machine's flat memory
//! (coherent lines) or the per-core private copies (CData). Each line
//! carries the paper's extra state: the CCache bit, the mergeable bit and
//! the merge-type field (Section 4.1, Figure 4).
//!
//! Storage is struct-of-arrays: one flat `u64` tag array (probes touch a
//! single cache line per set instead of striding over 40-byte metadata
//! structs), one packed flag byte per slot, and separate merge-type and
//! LRU arrays that only the paths needing them touch. [`LineMeta`] is a
//! by-value *snapshot* assembled on demand for callers that want the
//! whole picture (victim selection, invalidation, diagnostics); the hot
//! paths use the per-field getters and setters.

use super::addr::Line;

/// Slot-is-empty sentinel in the tag array. Line addresses come from the
/// machine's bump allocator over a bounded memory, so `u64::MAX` can
/// never be a real line.
const TAG_NONE: u64 = u64::MAX;

const F_DIRTY: u8 = 1 << 0;
const F_OWNED: u8 = 1 << 1;
const F_CCACHE: u8 = 1 << 2;
const F_MERGEABLE: u8 = 1 << 3;

/// By-value snapshot of one (valid) cache line's metadata.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LineMeta {
    pub line: Line,
    pub dirty: bool,
    /// MESI ownership: this private cache holds the line E or M (the
    /// directory's `Owned` state). Unused in the shared LLC.
    pub owned: bool,
    /// CCache bit: the line holds CData (set by c_read/c_write on fill).
    pub ccache: bool,
    /// Mergeable bit: soft_merge ran; the line may be merged-and-evicted.
    pub mergeable: bool,
    /// MFRF slot index identifying the line's merge function.
    pub merge_type: u8,
}

impl LineMeta {
    /// An eviction candidate: a normal line, or a mergeable CData line.
    /// Non-mergeable CData is pinned (Section 4.4).
    pub fn evictable(&self) -> bool {
        !self.ccache || self.mergeable
    }
}

/// What `choose_victim` found for an insertion.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Victim {
    /// An invalid way — free slot.
    Free { way: usize },
    /// A valid line that must be evicted (caller handles writeback/merge).
    Evict { way: usize, meta: LineMeta },
    /// Every way is pinned CData — the w-1 rule was violated (Section 4.4).
    Deadlock,
}

/// Set-associative tag array with true-LRU replacement.
pub struct Cache {
    sets: usize,
    ways: usize,
    set_mask: u64,
    /// Tag per slot, `TAG_NONE` = invalid.
    tags: Vec<u64>,
    /// Packed dirty/owned/ccache/mergeable bits per slot.
    flags: Vec<u8>,
    merge_types: Vec<u8>,
    lru: Vec<u64>,
    tick: u64,
}

impl Cache {
    pub fn new(sets: usize, ways: usize) -> Self {
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        let n = sets * ways;
        Self {
            sets,
            ways,
            set_mask: (sets - 1) as u64,
            tags: vec![TAG_NONE; n],
            flags: vec![0; n],
            merge_types: vec![0; n],
            lru: vec![0; n],
            tick: 0,
        }
    }

    #[inline]
    pub fn set_index(&self, line: Line) -> usize {
        (line.0 & self.set_mask) as usize
    }

    #[inline]
    fn set_range(&self, line: Line) -> std::ops::Range<usize> {
        let s = self.set_index(line) * self.ways;
        s..s + self.ways
    }

    pub fn ways(&self) -> usize {
        self.ways
    }

    pub fn sets(&self) -> usize {
        self.sets
    }

    /// Find a line; returns its slot index without touching LRU.
    #[inline]
    pub fn probe(&self, line: Line) -> Option<usize> {
        // an empty slot's TAG_NONE can never equal a real line address,
        // so the tag compare alone decides validity
        self.set_range(line).find(|&i| self.tags[i] == line.0)
    }

    /// Find a line and mark it most-recently-used.
    #[inline]
    pub fn lookup(&mut self, line: Line) -> Option<usize> {
        let idx = self.probe(line)?;
        self.touch(idx);
        Some(idx)
    }

    /// Mark slot `idx` most-recently-used (the LRU half of `lookup`, for
    /// callers that already probed).
    #[inline]
    pub fn touch(&mut self, idx: usize) {
        self.tick += 1;
        self.lru[idx] = self.tick;
    }

    /// Snapshot of slot `idx`'s metadata. Only meaningful for valid
    /// slots (from `probe`/`lookup`/`valid_slots`/`choose_victim`).
    #[inline]
    pub fn meta(&self, idx: usize) -> LineMeta {
        let f = self.flags[idx];
        LineMeta {
            line: Line(self.tags[idx]),
            dirty: f & F_DIRTY != 0,
            owned: f & F_OWNED != 0,
            ccache: f & F_CCACHE != 0,
            mergeable: f & F_MERGEABLE != 0,
            merge_type: self.merge_types[idx],
        }
    }

    #[inline]
    pub fn is_dirty(&self, idx: usize) -> bool {
        self.flags[idx] & F_DIRTY != 0
    }

    #[inline]
    pub fn is_owned(&self, idx: usize) -> bool {
        self.flags[idx] & F_OWNED != 0
    }

    #[inline]
    pub fn is_ccache(&self, idx: usize) -> bool {
        self.flags[idx] & F_CCACHE != 0
    }

    #[inline]
    pub fn is_mergeable(&self, idx: usize) -> bool {
        self.flags[idx] & F_MERGEABLE != 0
    }

    #[inline]
    pub fn merge_type(&self, idx: usize) -> u8 {
        self.merge_types[idx]
    }

    #[inline]
    fn set_flag(&mut self, idx: usize, bit: u8, v: bool) {
        if v {
            self.flags[idx] |= bit;
        } else {
            self.flags[idx] &= !bit;
        }
    }

    #[inline]
    pub fn set_dirty(&mut self, idx: usize, v: bool) {
        self.set_flag(idx, F_DIRTY, v);
    }

    #[inline]
    pub fn set_owned(&mut self, idx: usize, v: bool) {
        self.set_flag(idx, F_OWNED, v);
    }

    #[inline]
    pub fn set_ccache(&mut self, idx: usize, v: bool) {
        self.set_flag(idx, F_CCACHE, v);
    }

    #[inline]
    pub fn set_mergeable(&mut self, idx: usize, v: bool) {
        self.set_flag(idx, F_MERGEABLE, v);
    }

    #[inline]
    pub fn set_merge_type(&mut self, idx: usize, ty: u8) {
        self.merge_types[idx] = ty;
    }

    /// Pick a victim way for inserting `line`. Preference order:
    /// free way > LRU non-CData > LRU mergeable CData > Deadlock.
    pub fn choose_victim(&self, line: Line) -> Victim {
        let mut best_normal: Option<usize> = None;
        let mut best_mergeable: Option<usize> = None;
        for i in self.set_range(line) {
            if self.tags[i] == TAG_NONE {
                return Victim::Free { way: i };
            }
            let f = self.flags[i];
            if f & F_CCACHE == 0 {
                if best_normal.map_or(true, |b| self.lru[i] < self.lru[b]) {
                    best_normal = Some(i);
                }
            } else if f & F_MERGEABLE != 0
                && best_mergeable.map_or(true, |b| self.lru[i] < self.lru[b])
            {
                best_mergeable = Some(i);
            }
        }
        if let Some(i) = best_normal.or(best_mergeable) {
            return Victim::Evict {
                way: i,
                meta: self.meta(i),
            };
        }
        Victim::Deadlock
    }

    /// Pick a victim way for inserting `line`, restricted to the way
    /// positions set in `way_mask` (bit `p` allows way position `p` of
    /// the set). The shared-level way partition routes CData installs to
    /// the merge-region ways and coherent installs to the rest; within
    /// the allowed ways every valid line is evictable — the shared level
    /// holds no pinned CData, the F_CCACHE bit there is a class tag, not
    /// a pin. Returns `Deadlock` only for an empty mask (prevented by
    /// config validation).
    pub fn choose_victim_masked(&self, line: Line, way_mask: u64) -> Victim {
        let start = self.set_index(line) * self.ways;
        let mut best: Option<usize> = None;
        for p in 0..self.ways {
            if way_mask & (1u64 << p) == 0 {
                continue;
            }
            let i = start + p;
            if self.tags[i] == TAG_NONE {
                return Victim::Free { way: i };
            }
            if best.map_or(true, |b| self.lru[i] < self.lru[b]) {
                best = Some(i);
            }
        }
        match best {
            Some(i) => Victim::Evict {
                way: i,
                meta: self.meta(i),
            },
            None => Victim::Deadlock,
        }
    }

    /// Install `line` into slot `idx` (obtained from `choose_victim`),
    /// resetting all MESI/CCache metadata and marking it MRU.
    pub fn install(&mut self, idx: usize, line: Line) {
        debug_assert_ne!(line.0, TAG_NONE, "line collides with the empty sentinel");
        self.tags[idx] = line.0;
        self.flags[idx] = 0;
        self.merge_types[idx] = 0;
        self.touch(idx);
    }

    /// Invalidate `line` if present; returns its metadata beforehand.
    pub fn invalidate(&mut self, line: Line) -> Option<LineMeta> {
        let idx = self.probe(line)?;
        let meta = self.meta(idx);
        self.tags[idx] = TAG_NONE;
        Some(meta)
    }

    /// Slot indices of all valid lines in the cache (test/diagnostic use).
    pub fn valid_slots(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.tags.len()).filter(|&i| self.tags[i] != TAG_NONE)
    }

    /// Count of pinned (non-mergeable) CData ways in `line`'s set.
    pub fn pinned_cdata_in_set(&self, line: Line) -> usize {
        self.set_range(line)
            .filter(|&i| {
                self.tags[i] != TAG_NONE
                    && self.flags[i] & (F_CCACHE | F_MERGEABLE) == F_CCACHE
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l(v: u64) -> Line {
        Line(v)
    }

    fn install_free(c: &mut Cache, line: Line) -> usize {
        let Victim::Free { way } = c.choose_victim(line) else {
            panic!("expected a free way for {line:?}")
        };
        c.install(way, line);
        way
    }

    #[test]
    fn hit_after_install() {
        let mut c = Cache::new(4, 2);
        install_free(&mut c, l(5));
        assert!(c.lookup(l(5)).is_some());
        assert!(c.lookup(l(9)).is_none()); // same set (5 % 4 == 1, 9 % 4 == 1), different tag
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = Cache::new(1, 2);
        install_free(&mut c, l(0));
        install_free(&mut c, l(1));
        // touch 0 so 1 becomes LRU
        c.lookup(l(0));
        match c.choose_victim(l(2)) {
            Victim::Evict { meta, .. } => assert_eq!(meta.line, l(1)),
            v => panic!("{v:?}"),
        }
    }

    #[test]
    fn pinned_cdata_never_chosen() {
        let mut c = Cache::new(1, 2);
        for i in 0..2 {
            let w = install_free(&mut c, l(i));
            c.set_ccache(w, true); // pinned: ccache bit set, not mergeable
        }
        assert_eq!(c.choose_victim(l(2)), Victim::Deadlock);
        assert_eq!(c.pinned_cdata_in_set(l(2)), 2);
    }

    #[test]
    fn mergeable_cdata_evictable_after_normals() {
        let mut c = Cache::new(1, 3);
        // way0: mergeable CData (oldest), way1: normal, way2: pinned CData
        let w = install_free(&mut c, l(0));
        c.set_ccache(w, true);
        c.set_mergeable(w, true);
        install_free(&mut c, l(1));
        let w = install_free(&mut c, l(2));
        c.set_ccache(w, true);
        // normal line evicted first even though the mergeable line is older
        match c.choose_victim(l(3)) {
            Victim::Evict { meta, .. } => assert_eq!(meta.line, l(1)),
            v => panic!("{v:?}"),
        }
    }

    #[test]
    fn invalidate_removes_line() {
        let mut c = Cache::new(2, 2);
        install_free(&mut c, l(0));
        let meta = c.invalidate(l(0)).unwrap();
        assert_eq!(meta.line, l(0));
        assert!(c.lookup(l(0)).is_none());
        assert!(c.invalidate(l(0)).is_none());
    }

    #[test]
    fn set_mapping_respects_mask() {
        let c = Cache::new(8, 1);
        assert_eq!(c.set_index(l(0)), 0);
        assert_eq!(c.set_index(l(8)), 0);
        assert_eq!(c.set_index(l(9)), 1);
    }

    #[test]
    fn install_resets_all_mesi_and_ccache_metadata() {
        let mut c = Cache::new(1, 1);
        let w = install_free(&mut c, l(0));
        c.set_owned(w, true);
        c.set_dirty(w, true);
        c.set_ccache(w, true);
        c.set_mergeable(w, true);
        c.set_merge_type(w, 3);
        // re-installing the slot (new line) must not inherit stale state
        c.install(w, l(9));
        let m = c.meta(w);
        assert_eq!(m.line, l(9));
        assert!(!m.owned && !m.dirty && !m.ccache && !m.mergeable);
        assert_eq!(m.merge_type, 0);
    }

    #[test]
    fn mergeable_bit_unpins_a_cdata_line() {
        let mut c = Cache::new(1, 1);
        let w = install_free(&mut c, l(0));
        c.set_ccache(w, true);
        assert_eq!(c.choose_victim(l(1)), Victim::Deadlock);
        let idx = c.probe(l(0)).unwrap();
        c.set_mergeable(idx, true);
        match c.choose_victim(l(1)) {
            Victim::Evict { meta, .. } => assert_eq!(meta.line, l(0)),
            v => panic!("{v:?}"),
        }
        assert_eq!(c.pinned_cdata_in_set(l(1)), 0);
    }

    #[test]
    fn invalidated_way_is_reused_before_evicting() {
        let mut c = Cache::new(1, 2);
        for i in 0..2 {
            install_free(&mut c, l(i));
        }
        c.invalidate(l(0));
        // the freed way is preferred over evicting line 1
        match c.choose_victim(l(7)) {
            Victim::Free { .. } => {}
            v => panic!("expected free way, got {v:?}"),
        }
        assert!(c.probe(l(1)).is_some());
    }

    #[test]
    fn probe_does_not_touch_lru_but_lookup_does() {
        let mut c = Cache::new(1, 2);
        for i in 0..2 {
            install_free(&mut c, l(i));
        }
        // probe line 0 only: line 0 stays LRU and gets evicted
        c.probe(l(0));
        match c.choose_victim(l(9)) {
            Victim::Evict { meta, .. } => assert_eq!(meta.line, l(0)),
            v => panic!("{v:?}"),
        }
        // lookup line 0: line 1 becomes the victim
        c.lookup(l(0));
        match c.choose_victim(l(9)) {
            Victim::Evict { meta, .. } => assert_eq!(meta.line, l(1)),
            v => panic!("{v:?}"),
        }
    }

    #[test]
    fn touch_is_equivalent_to_lookup_for_lru() {
        let mut c = Cache::new(1, 2);
        for i in 0..2 {
            install_free(&mut c, l(i));
        }
        // probe + touch line 0 ≡ lookup line 0: line 1 becomes the victim
        let idx = c.probe(l(0)).unwrap();
        c.touch(idx);
        match c.choose_victim(l(9)) {
            Victim::Evict { meta, .. } => assert_eq!(meta.line, l(1)),
            v => panic!("{v:?}"),
        }
    }

    #[test]
    fn masked_victims_stay_inside_the_mask() {
        let mut c = Cache::new(1, 4);
        for i in 0..4 {
            install_free(&mut c, l(i));
        }
        // make way 0 the globally-LRU line, then exclude it: the masked
        // chooser must pick the LRU way *inside* the mask (way 2)
        c.lookup(l(1));
        c.lookup(l(3));
        c.lookup(l(2)); // LRU order now: 0 < 1 < 3 < 2
        match c.choose_victim_masked(l(9), 0b1100) {
            Victim::Evict { way, meta } => {
                assert_eq!(way % 4, 3, "LRU of ways {{2,3}} is way 3 (line 3)");
                assert_eq!(meta.line, l(3));
            }
            v => panic!("{v:?}"),
        }
        // the unmasked chooser would have evicted way 0
        match c.choose_victim(l(9)) {
            Victim::Evict { meta, .. } => assert_eq!(meta.line, l(0)),
            v => panic!("{v:?}"),
        }
    }

    #[test]
    fn masked_chooser_prefers_free_ways_and_ignores_ccache_pinning() {
        let mut c = Cache::new(1, 4);
        // ways 0,1 valid CData-tagged (non-mergeable — the plain chooser
        // would treat them as pinned); ways 2,3 free
        for i in 0..2 {
            let w = install_free(&mut c, l(i));
            c.set_ccache(w, true);
        }
        // free way inside the mask wins
        match c.choose_victim_masked(l(9), 0b0111) {
            Victim::Free { way } => assert_eq!(way % 4, 2),
            v => panic!("{v:?}"),
        }
        // mask covering only CData-tagged ways still evicts: at the
        // shared level F_CCACHE is a class tag, not a pin
        match c.choose_victim_masked(l(9), 0b0011) {
            Victim::Evict { meta, .. } => assert_eq!(meta.line, l(0)),
            v => panic!("{v:?}"),
        }
        // empty mask is the only Deadlock
        assert_eq!(c.choose_victim_masked(l(9), 0), Victim::Deadlock);
    }

    #[test]
    fn meta_snapshot_mirrors_flag_setters() {
        let mut c = Cache::new(2, 2);
        let w = install_free(&mut c, l(3));
        c.set_ccache(w, true);
        c.set_dirty(w, true);
        c.set_merge_type(w, 7);
        let m = c.meta(w);
        assert!(m.ccache && m.dirty && !m.owned && !m.mergeable);
        assert_eq!(m.merge_type, 7);
        assert!(!m.evictable());
        assert!(c.is_ccache(w) && c.is_dirty(w));
        assert!(!c.is_owned(w) && !c.is_mergeable(w));
        assert_eq!(c.merge_type(w), 7);
        c.set_dirty(w, false);
        assert!(!c.is_dirty(w));
    }
}
