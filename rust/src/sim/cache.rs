//! Set-associative cache model with per-line CCache metadata.
//!
//! Tag array only — functional data lives in the machine's flat memory
//! (coherent lines) or the per-core private copies (CData). Each line
//! carries the paper's extra state: the CCache bit, the mergeable bit and
//! the merge-type field (Section 4.1, Figure 4).

use super::addr::Line;

/// Metadata for one cache line slot.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LineMeta {
    pub line: Line,
    pub valid: bool,
    pub dirty: bool,
    /// MESI ownership: this private cache holds the line E or M (the
    /// directory's `Owned` state). Unused in the shared LLC.
    pub owned: bool,
    /// CCache bit: the line holds CData (set by c_read/c_write on fill).
    pub ccache: bool,
    /// Mergeable bit: soft_merge ran; the line may be merged-and-evicted.
    pub mergeable: bool,
    /// MFRF slot index identifying the line's merge function.
    pub merge_type: u8,
    lru: u64,
}

impl LineMeta {
    fn empty() -> Self {
        Self {
            line: Line(0),
            valid: false,
            dirty: false,
            owned: false,
            ccache: false,
            mergeable: false,
            merge_type: 0,
            lru: 0,
        }
    }

    /// An eviction candidate: invalid, or a normal line, or a mergeable
    /// CData line. Non-mergeable CData is pinned (Section 4.4).
    pub fn evictable(&self) -> bool {
        !self.valid || !self.ccache || self.mergeable
    }
}

/// What `choose_victim` found for an insertion.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Victim {
    /// An invalid way — free slot.
    Free { way: usize },
    /// A valid line that must be evicted (caller handles writeback/merge).
    Evict { way: usize, meta: LineMeta },
    /// Every way is pinned CData — the w-1 rule was violated (Section 4.4).
    Deadlock,
}

/// Set-associative tag array with true-LRU replacement.
pub struct Cache {
    sets: usize,
    ways: usize,
    set_mask: u64,
    lines: Vec<LineMeta>,
    tick: u64,
}

impl Cache {
    pub fn new(sets: usize, ways: usize) -> Self {
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        Self {
            sets,
            ways,
            set_mask: (sets - 1) as u64,
            lines: vec![LineMeta::empty(); sets * ways],
            tick: 0,
        }
    }

    #[inline]
    pub fn set_index(&self, line: Line) -> usize {
        (line.0 & self.set_mask) as usize
    }

    #[inline]
    fn set_range(&self, line: Line) -> std::ops::Range<usize> {
        let s = self.set_index(line) * self.ways;
        s..s + self.ways
    }

    pub fn ways(&self) -> usize {
        self.ways
    }

    pub fn sets(&self) -> usize {
        self.sets
    }

    /// Find a line; returns its slot index without touching LRU.
    #[inline]
    pub fn probe(&self, line: Line) -> Option<usize> {
        self.set_range(line)
            .find(|&i| self.lines[i].valid && self.lines[i].line == line)
    }

    /// Find a line and mark it most-recently-used.
    #[inline]
    pub fn lookup(&mut self, line: Line) -> Option<usize> {
        let idx = self.probe(line)?;
        self.tick += 1;
        self.lines[idx].lru = self.tick;
        Some(idx)
    }

    #[inline]
    pub fn meta(&self, idx: usize) -> &LineMeta {
        &self.lines[idx]
    }

    #[inline]
    pub fn meta_mut(&mut self, idx: usize) -> &mut LineMeta {
        &mut self.lines[idx]
    }

    /// Pick a victim way for inserting `line`. Preference order:
    /// free way > LRU non-CData > LRU mergeable CData > Deadlock.
    pub fn choose_victim(&self, line: Line) -> Victim {
        let mut best_normal: Option<usize> = None;
        let mut best_mergeable: Option<usize> = None;
        for i in self.set_range(line) {
            let m = &self.lines[i];
            if !m.valid {
                return Victim::Free { way: i };
            }
            if !m.ccache {
                if best_normal.map_or(true, |b| m.lru < self.lines[b].lru) {
                    best_normal = Some(i);
                }
            } else if m.mergeable
                && best_mergeable.map_or(true, |b| m.lru < self.lines[b].lru)
            {
                best_mergeable = Some(i);
            }
        }
        if let Some(i) = best_normal {
            return Victim::Evict {
                way: i,
                meta: self.lines[i],
            };
        }
        if let Some(i) = best_mergeable {
            return Victim::Evict {
                way: i,
                meta: self.lines[i],
            };
        }
        Victim::Deadlock
    }

    /// Install `line` into slot `idx` (obtained from `choose_victim`).
    pub fn install(&mut self, idx: usize, line: Line) -> &mut LineMeta {
        self.tick += 1;
        self.lines[idx] = LineMeta {
            line,
            valid: true,
            dirty: false,
            owned: false,
            ccache: false,
            mergeable: false,
            merge_type: 0,
            lru: self.tick,
        };
        &mut self.lines[idx]
    }

    /// Invalidate `line` if present; returns its metadata beforehand.
    pub fn invalidate(&mut self, line: Line) -> Option<LineMeta> {
        let idx = self.probe(line)?;
        let meta = self.lines[idx];
        self.lines[idx].valid = false;
        Some(meta)
    }

    /// Slot indices of all valid lines in the cache (test/diagnostic use).
    pub fn valid_slots(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.lines.len()).filter(|&i| self.lines[i].valid)
    }

    /// Count of pinned (non-mergeable) CData ways in `line`'s set.
    pub fn pinned_cdata_in_set(&self, line: Line) -> usize {
        self.set_range(line)
            .filter(|&i| {
                let m = &self.lines[i];
                m.valid && m.ccache && !m.mergeable
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l(v: u64) -> Line {
        Line(v)
    }

    #[test]
    fn hit_after_install() {
        let mut c = Cache::new(4, 2);
        let v = c.choose_victim(l(5));
        let Victim::Free { way } = v else { panic!() };
        c.install(way, l(5));
        assert!(c.lookup(l(5)).is_some());
        assert!(c.lookup(l(9)).is_none()); // same set (5 % 4 == 1, 9 % 4 == 1), different tag
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = Cache::new(1, 2);
        let w0 = match c.choose_victim(l(0)) {
            Victim::Free { way } => way,
            _ => panic!(),
        };
        c.install(w0, l(0));
        let w1 = match c.choose_victim(l(1)) {
            Victim::Free { way } => way,
            _ => panic!(),
        };
        c.install(w1, l(1));
        // touch 0 so 1 becomes LRU
        c.lookup(l(0));
        match c.choose_victim(l(2)) {
            Victim::Evict { meta, .. } => assert_eq!(meta.line, l(1)),
            v => panic!("{v:?}"),
        }
    }

    #[test]
    fn pinned_cdata_never_chosen() {
        let mut c = Cache::new(1, 2);
        for i in 0..2 {
            let w = match c.choose_victim(l(i)) {
                Victim::Free { way } => way,
                _ => panic!(),
            };
            let m = c.install(w, l(i));
            m.ccache = true; // pinned: ccache bit set, not mergeable
        }
        assert_eq!(c.choose_victim(l(2)), Victim::Deadlock);
        assert_eq!(c.pinned_cdata_in_set(l(2)), 2);
    }

    #[test]
    fn mergeable_cdata_evictable_after_normals() {
        let mut c = Cache::new(1, 3);
        // way0: mergeable CData (oldest), way1: normal, way2: pinned CData
        let w = match c.choose_victim(l(0)) {
            Victim::Free { way } => way,
            _ => panic!(),
        };
        let m = c.install(w, l(0));
        m.ccache = true;
        m.mergeable = true;
        let w = match c.choose_victim(l(1)) {
            Victim::Free { way } => way,
            _ => panic!(),
        };
        c.install(w, l(1));
        let w = match c.choose_victim(l(2)) {
            Victim::Free { way } => way,
            _ => panic!(),
        };
        let m = c.install(w, l(2));
        m.ccache = true;
        // normal line evicted first even though the mergeable line is older
        match c.choose_victim(l(3)) {
            Victim::Evict { meta, .. } => assert_eq!(meta.line, l(1)),
            v => panic!("{v:?}"),
        }
    }

    #[test]
    fn invalidate_removes_line() {
        let mut c = Cache::new(2, 2);
        let w = match c.choose_victim(l(0)) {
            Victim::Free { way } => way,
            _ => panic!(),
        };
        c.install(w, l(0));
        let meta = c.invalidate(l(0)).unwrap();
        assert_eq!(meta.line, l(0));
        assert!(c.lookup(l(0)).is_none());
        assert!(c.invalidate(l(0)).is_none());
    }

    #[test]
    fn set_mapping_respects_mask() {
        let c = Cache::new(8, 1);
        assert_eq!(c.set_index(l(0)), 0);
        assert_eq!(c.set_index(l(8)), 0);
        assert_eq!(c.set_index(l(9)), 1);
    }

    #[test]
    fn install_resets_all_mesi_and_ccache_metadata() {
        let mut c = Cache::new(1, 1);
        let w = match c.choose_victim(l(0)) {
            Victim::Free { way } => way,
            _ => panic!(),
        };
        let m = c.install(w, l(0));
        m.owned = true;
        m.dirty = true;
        m.ccache = true;
        m.mergeable = true;
        m.merge_type = 3;
        // re-installing the slot (new line) must not inherit stale state
        let m = c.install(w, l(9));
        assert_eq!(m.line, l(9));
        assert!(!m.owned && !m.dirty && !m.ccache && !m.mergeable);
        assert_eq!(m.merge_type, 0);
    }

    #[test]
    fn mergeable_bit_unpins_a_cdata_line() {
        let mut c = Cache::new(1, 1);
        let w = match c.choose_victim(l(0)) {
            Victim::Free { way } => way,
            _ => panic!(),
        };
        let m = c.install(w, l(0));
        m.ccache = true;
        assert_eq!(c.choose_victim(l(1)), Victim::Deadlock);
        let idx = c.probe(l(0)).unwrap();
        c.meta_mut(idx).mergeable = true;
        match c.choose_victim(l(1)) {
            Victim::Evict { meta, .. } => assert_eq!(meta.line, l(0)),
            v => panic!("{v:?}"),
        }
        assert_eq!(c.pinned_cdata_in_set(l(1)), 0);
    }

    #[test]
    fn invalidated_way_is_reused_before_evicting() {
        let mut c = Cache::new(1, 2);
        for i in 0..2 {
            let w = match c.choose_victim(l(i)) {
                Victim::Free { way } => way,
                _ => panic!(),
            };
            c.install(w, l(i));
        }
        c.invalidate(l(0));
        // the freed way is preferred over evicting line 1
        match c.choose_victim(l(7)) {
            Victim::Free { .. } => {}
            v => panic!("expected free way, got {v:?}"),
        }
        assert!(c.probe(l(1)).is_some());
    }

    #[test]
    fn probe_does_not_touch_lru_but_lookup_does() {
        let mut c = Cache::new(1, 2);
        for i in 0..2 {
            let w = match c.choose_victim(l(i)) {
                Victim::Free { way } => way,
                _ => panic!(),
            };
            c.install(w, l(i));
        }
        // probe line 0 only: line 0 stays LRU and gets evicted
        c.probe(l(0));
        match c.choose_victim(l(9)) {
            Victim::Evict { meta, .. } => assert_eq!(meta.line, l(0)),
            v => panic!("{v:?}"),
        }
        // lookup line 0: line 1 becomes the victim
        c.lookup(l(0));
        match c.choose_victim(l(9)) {
            Victim::Evict { meta, .. } => assert_eq!(meta.line, l(1)),
            v => panic!("{v:?}"),
        }
    }
}
