//! Full-map MESI directory, co-located with the (inclusive) LLC.
//!
//! A directory entry exists exactly for lines resident in the LLC. It
//! tracks which private caches hold the line and whether one of them owns
//! it exclusively (E/M). CData never appears here: c_read/c_write bypass
//! coherence entirely (Section 4.4).

use std::collections::HashMap;

use super::addr::Line;

/// Sharer bitmask (up to 64 cores).
pub type SharerMask = u64;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DirState {
    /// No private cache holds the line.
    Uncached,
    /// One or more private caches hold it read-only.
    Shared,
    /// Exactly one private cache holds it E or M (silent E->M upgrade
    /// means the directory treats E and M identically: `owner` may have
    /// a dirty copy).
    Owned { owner: usize },
}

#[derive(Clone, Copy, Debug)]
pub struct DirEntry {
    pub state: DirState,
    pub sharers: SharerMask,
}

impl DirEntry {
    fn new() -> Self {
        Self {
            state: DirState::Uncached,
            sharers: 0,
        }
    }

    pub fn sharer_count(&self) -> u32 {
        self.sharers.count_ones()
    }

    pub fn is_sharer(&self, core: usize) -> bool {
        self.sharers & (1 << core) != 0
    }
}

/// Directory operations return what coherence actions the caller (memsys)
/// must perform and account.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CoherenceActions {
    /// Invalidation messages to send (count of private caches).
    pub invalidations: u32,
    /// Bitmask of cores whose private copies must be invalidated.
    pub inv_mask: SharerMask,
    /// A dirty owner must write its data back/through first.
    pub owner_writeback: Option<usize>,
    /// Directory messages exchanged for this transaction.
    pub dir_msgs: u32,
}

pub struct Directory {
    entries: HashMap<u64, DirEntry>,
}

impl Directory {
    pub fn new() -> Self {
        Self {
            entries: HashMap::new(),
        }
    }

    pub fn entry(&self, line: Line) -> Option<&DirEntry> {
        self.entries.get(&line.0)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Core `c` requests read access (GetS).
    pub fn get_s(&mut self, line: Line, c: usize) -> CoherenceActions {
        let e = self.entries.entry(line.0).or_insert_with(DirEntry::new);
        let mut act = CoherenceActions {
            dir_msgs: 1, // the GetS itself
            ..Default::default()
        };
        match e.state {
            DirState::Uncached => {
                e.state = DirState::Owned { owner: c }; // grant E
                e.sharers = 1 << c;
            }
            DirState::Shared => {
                e.sharers |= 1 << c;
            }
            DirState::Owned { owner } if owner == c => {
                // already owner (e.g. refetch after L1 evict, L2 hit path)
            }
            DirState::Owned { owner } => {
                // downgrade owner: fetch its (possibly dirty) data
                act.owner_writeback = Some(owner);
                act.dir_msgs += 2; // fwd + data
                e.state = DirState::Shared;
                e.sharers |= 1 << c;
            }
        }
        act
    }

    /// Core `c` requests write access (GetM / upgrade).
    pub fn get_m(&mut self, line: Line, c: usize) -> CoherenceActions {
        let e = self.entries.entry(line.0).or_insert_with(DirEntry::new);
        let mut act = CoherenceActions {
            dir_msgs: 1,
            ..Default::default()
        };
        match e.state {
            DirState::Uncached => {}
            DirState::Shared => {
                let others = e.sharers & !(1 << c);
                act.invalidations = others.count_ones();
                act.inv_mask = others;
                act.dir_msgs += act.invalidations; // one inv per sharer
            }
            DirState::Owned { owner } if owner == c => {
                e.sharers = 1 << c;
                return act; // silent upgrade, nothing to do
            }
            DirState::Owned { owner } => {
                act.owner_writeback = Some(owner);
                act.invalidations = 1;
                act.inv_mask = 1 << owner;
                act.dir_msgs += 2;
            }
        }
        e.state = DirState::Owned { owner: c };
        e.sharers = 1 << c;
        act
    }

    /// Core `c` evicted its private copy (PutS/PutM). `dirty` = had M.
    pub fn put(&mut self, line: Line, c: usize, dirty: bool) -> CoherenceActions {
        let mut act = CoherenceActions {
            dir_msgs: 1,
            ..Default::default()
        };
        if let Some(e) = self.entries.get_mut(&line.0) {
            e.sharers &= !(1 << c);
            match e.state {
                DirState::Owned { owner } if owner == c => {
                    e.state = if e.sharers == 0 {
                        DirState::Uncached
                    } else {
                        DirState::Shared
                    };
                }
                DirState::Shared if e.sharers == 0 => {
                    e.state = DirState::Uncached;
                }
                _ => {}
            }
            if dirty {
                act.dir_msgs += 1; // data message with the writeback
            }
        }
        act
    }

    /// LLC evicts the line (inclusive recall): every private copy must be
    /// invalidated; returns the sharers to invalidate and removes the entry.
    pub fn recall(&mut self, line: Line) -> (SharerMask, CoherenceActions) {
        let Some(e) = self.entries.remove(&line.0) else {
            return (0, CoherenceActions::default());
        };
        let act = CoherenceActions {
            invalidations: e.sharer_count(),
            inv_mask: e.sharers,
            owner_writeback: match e.state {
                DirState::Owned { owner } => Some(owner),
                _ => None,
            },
            dir_msgs: 1 + e.sharer_count(),
        };
        (e.sharers, act)
    }

    /// Internal-consistency check used by the property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (&line, e) in &self.entries {
            match e.state {
                DirState::Uncached => {
                    if e.sharers != 0 {
                        return Err(format!("line {line:#x}: Uncached but sharers != 0"));
                    }
                }
                DirState::Shared => {
                    if e.sharers == 0 {
                        return Err(format!("line {line:#x}: Shared but no sharers"));
                    }
                }
                DirState::Owned { owner } => {
                    if e.sharers != 1 << owner {
                        return Err(format!(
                            "line {line:#x}: Owned by {owner} but sharers {:#b}",
                            e.sharers
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

impl Default for Directory {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l(v: u64) -> Line {
        Line(v)
    }

    #[test]
    fn first_reader_gets_exclusive() {
        let mut d = Directory::new();
        let act = d.get_s(l(1), 0);
        assert_eq!(act.invalidations, 0);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 0 });
    }

    #[test]
    fn second_reader_downgrades_owner() {
        let mut d = Directory::new();
        d.get_s(l(1), 0);
        let act = d.get_s(l(1), 1);
        assert_eq!(act.owner_writeback, Some(0));
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Shared);
        assert_eq!(d.entry(l(1)).unwrap().sharer_count(), 2);
    }

    #[test]
    fn writer_invalidates_sharers() {
        let mut d = Directory::new();
        d.get_s(l(1), 0);
        d.get_s(l(1), 1);
        d.get_s(l(1), 2);
        let act = d.get_m(l(1), 0);
        assert_eq!(act.invalidations, 2); // cores 1, 2
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 0 });
        d.check_invariants().unwrap();
    }

    #[test]
    fn writer_steals_from_dirty_owner() {
        let mut d = Directory::new();
        d.get_m(l(1), 0);
        let act = d.get_m(l(1), 1);
        assert_eq!(act.owner_writeback, Some(0));
        assert_eq!(act.invalidations, 1);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 1 });
    }

    #[test]
    fn silent_upgrade_costs_nothing_extra() {
        let mut d = Directory::new();
        d.get_s(l(1), 0); // granted E
        let act = d.get_m(l(1), 0);
        assert_eq!(act.invalidations, 0);
        assert_eq!(act.owner_writeback, None);
    }

    #[test]
    fn put_last_sharer_uncaches() {
        let mut d = Directory::new();
        d.get_s(l(1), 0);
        d.put(l(1), 0, false);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Uncached);
        d.check_invariants().unwrap();
    }

    #[test]
    fn recall_reports_all_sharers() {
        let mut d = Directory::new();
        d.get_s(l(1), 0);
        d.get_s(l(1), 1);
        let (mask, act) = d.recall(l(1));
        assert_eq!(mask, 0b11);
        assert_eq!(act.invalidations, 2);
        assert!(d.entry(l(1)).is_none());
    }

    #[test]
    fn recall_absent_line_is_noop() {
        let mut d = Directory::new();
        let (mask, act) = d.recall(l(9));
        assert_eq!(mask, 0);
        assert_eq!(act, CoherenceActions::default());
    }

    #[test]
    fn rfo_from_uncached_grants_m_without_invalidations() {
        let mut d = Directory::new();
        let act = d.get_m(l(1), 3);
        assert_eq!(act.invalidations, 0);
        assert_eq!(act.owner_writeback, None);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 3 });
        assert!(d.entry(l(1)).unwrap().is_sharer(3));
        d.check_invariants().unwrap();
    }

    #[test]
    fn put_of_unregistered_line_is_harmless() {
        let mut d = Directory::new();
        let act = d.put(l(5), 0, false);
        assert_eq!(act.invalidations, 0);
        assert!(d.entry(l(5)).is_none());
        d.check_invariants().unwrap();
    }

    #[test]
    fn put_of_a_non_owner_sharer_keeps_the_line_shared() {
        let mut d = Directory::new();
        d.get_s(l(1), 0);
        d.get_s(l(1), 1); // downgrades 0 -> Shared {0,1}
        d.put(l(1), 1, false);
        let e = d.entry(l(1)).unwrap();
        assert_eq!(e.state, DirState::Shared);
        assert!(e.is_sharer(0));
        assert!(!e.is_sharer(1));
        d.check_invariants().unwrap();
    }

    #[test]
    fn reacquire_after_recall_regrants_exclusive() {
        let mut d = Directory::new();
        d.get_s(l(1), 0);
        d.get_s(l(1), 1);
        d.recall(l(1));
        // the entry is gone; the next reader is alone again -> E
        let act = d.get_s(l(1), 1);
        assert_eq!(act.owner_writeback, None);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 1 });
        d.check_invariants().unwrap();
    }

    #[test]
    fn dirty_put_costs_an_extra_data_message() {
        let mut d = Directory::new();
        d.get_m(l(1), 0);
        let clean = d.put(l(1), 0, false);
        d.get_m(l(1), 0);
        let dirty = d.put(l(1), 0, true);
        assert_eq!(dirty.dir_msgs, clean.dir_msgs + 1);
    }
}
