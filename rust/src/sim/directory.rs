//! Full-map MESI directory, co-located with the (inclusive) LLC.
//!
//! A directory entry exists exactly for lines resident in the LLC. It
//! tracks which private caches hold the line and whether one of them owns
//! it exclusively (E/M). CData never appears here: c_read/c_write bypass
//! coherence entirely (Section 4.4).
//!
//! Storage is an open-addressed hash table (linear probing, fibonacci
//! hashing, backward-shift deletion) rather than a `HashMap`: every
//! coherent miss performs a directory transaction, so the lookup is on
//! the simulator's hot path, and line addresses come densely from
//! `alloc_lines` — a flat probe sequence touches one or two cache lines
//! where the std map chases SipHash plus control bytes.

use super::addr::Line;
use super::invariant::InvariantViolation;

/// Sharer bitmask (up to 64 cores).
pub type SharerMask = u64;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DirState {
    /// No private cache holds the line.
    Uncached,
    /// One or more private caches hold it read-only.
    Shared,
    /// Exactly one private cache holds it E or M (silent E->M upgrade
    /// means the directory treats E and M identically: `owner` may have
    /// a dirty copy).
    Owned { owner: usize },
}

#[derive(Clone, Copy, Debug)]
pub struct DirEntry {
    pub state: DirState,
    pub sharers: SharerMask,
}

impl DirEntry {
    fn new() -> Self {
        Self {
            state: DirState::Uncached,
            sharers: 0,
        }
    }

    pub fn sharer_count(&self) -> u32 {
        self.sharers.count_ones()
    }

    pub fn is_sharer(&self, core: usize) -> bool {
        self.sharers & (1 << core) != 0
    }
}

/// Directory operations return what coherence actions the caller (memsys)
/// must perform and account.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CoherenceActions {
    /// Invalidation messages to send (count of private caches).
    pub invalidations: u32,
    /// Bitmask of cores whose private copies must be invalidated.
    pub inv_mask: SharerMask,
    /// A dirty owner must write its data back/through first.
    pub owner_writeback: Option<usize>,
    /// Directory messages exchanged for this transaction.
    pub dir_msgs: u32,
}

/// Key marking an empty table slot. Line addresses are `byte >> 6` of a
/// bump-allocated, bounds-checked memory, so `u64::MAX` is unreachable.
const EMPTY: u64 = u64::MAX;

pub struct Directory {
    /// Line keys, `EMPTY` = free slot. Power-of-two length.
    keys: Vec<u64>,
    entries: Vec<DirEntry>,
    len: usize,
    /// `keys.len() - 1`, for probe wraparound.
    mask: usize,
    /// `64 - log2(keys.len())`: fibonacci hashing keeps the high bits.
    shift: u32,
}

impl Directory {
    const INITIAL_CAPACITY: usize = 1024;

    pub fn new() -> Self {
        Self::with_capacity(Self::INITIAL_CAPACITY)
    }

    fn with_capacity(cap: usize) -> Self {
        debug_assert!(cap.is_power_of_two());
        Self {
            keys: vec![EMPTY; cap],
            entries: vec![DirEntry::new(); cap],
            len: 0,
            mask: cap - 1,
            shift: 64 - cap.trailing_zeros(),
        }
    }

    /// Fibonacci hash: multiply spreads dense line indices across the
    /// high bits, the shift keeps exactly `log2(capacity)` of them.
    #[inline]
    fn hash(&self, key: u64) -> usize {
        (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> self.shift) as usize
    }

    /// Slot of `key` if present.
    #[inline]
    fn find(&self, key: u64) -> Option<usize> {
        let mut i = self.hash(key);
        loop {
            let k = self.keys[i];
            if k == key {
                return Some(i);
            }
            if k == EMPTY {
                return None;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Slot of `key`, inserting a fresh `Uncached` entry if absent.
    fn slot_or_insert(&mut self, key: u64) -> usize {
        debug_assert_ne!(key, EMPTY, "line address collides with the EMPTY sentinel");
        if (self.len + 1) * 10 > self.keys.len() * 7 {
            self.grow();
        }
        let mut i = self.hash(key);
        loop {
            let k = self.keys[i];
            if k == key {
                return i;
            }
            if k == EMPTY {
                self.keys[i] = key;
                self.entries[i] = DirEntry::new();
                self.len += 1;
                return i;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Double the table and rehash every occupied slot.
    fn grow(&mut self) {
        let mut bigger = Self::with_capacity(self.keys.len() * 2);
        for i in 0..self.keys.len() {
            if self.keys[i] != EMPTY {
                let j = bigger.slot_or_insert(self.keys[i]);
                bigger.entries[j] = self.entries[i];
            }
        }
        *self = bigger;
    }

    /// Remove `key`, repairing the probe chain with backward-shift
    /// deletion (no tombstones: lookups stay one clean linear scan).
    fn remove(&mut self, key: u64) -> Option<DirEntry> {
        let mut i = self.find(key)?;
        let removed = self.entries[i];
        let mut j = i;
        loop {
            self.keys[i] = EMPTY;
            loop {
                j = (j + 1) & self.mask;
                if self.keys[j] == EMPTY {
                    self.len -= 1;
                    return Some(removed);
                }
                let home = self.hash(self.keys[j]);
                // keys[j] may stay put only if its home slot lies in the
                // cyclic range (i, j] — otherwise the new hole at i
                // breaks its probe chain and it must shift back
                let stays = if i <= j {
                    i < home && home <= j
                } else {
                    i < home || home <= j
                };
                if !stays {
                    break;
                }
            }
            self.keys[i] = self.keys[j];
            self.entries[i] = self.entries[j];
            i = j;
        }
    }

    pub fn entry(&self, line: Line) -> Option<&DirEntry> {
        self.find(line.0).map(|i| &self.entries[i])
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Core `c` requests read access (GetS).
    pub fn get_s(&mut self, line: Line, c: usize) -> CoherenceActions {
        let e = &mut self.entries[self.slot_or_insert(line.0)];
        let mut act = CoherenceActions {
            dir_msgs: 1, // the GetS itself
            ..Default::default()
        };
        match e.state {
            DirState::Uncached => {
                e.state = DirState::Owned { owner: c }; // grant E
                e.sharers = 1 << c;
            }
            DirState::Shared => {
                e.sharers |= 1 << c;
            }
            DirState::Owned { owner } if owner == c => {
                // already owner (e.g. refetch after L1 evict, L2 hit path)
            }
            DirState::Owned { owner } => {
                // downgrade owner: fetch its (possibly dirty) data
                act.owner_writeback = Some(owner);
                act.dir_msgs += 2; // fwd + data
                e.state = DirState::Shared;
                e.sharers |= 1 << c;
            }
        }
        act
    }

    /// Core `c` requests write access (GetM / upgrade).
    pub fn get_m(&mut self, line: Line, c: usize) -> CoherenceActions {
        let e = &mut self.entries[self.slot_or_insert(line.0)];
        let mut act = CoherenceActions {
            dir_msgs: 1,
            ..Default::default()
        };
        match e.state {
            DirState::Uncached => {}
            DirState::Shared => {
                let others = e.sharers & !(1 << c);
                act.invalidations = others.count_ones();
                act.inv_mask = others;
                act.dir_msgs += act.invalidations; // one inv per sharer
            }
            DirState::Owned { owner } if owner == c => {
                e.sharers = 1 << c;
                return act; // silent upgrade, nothing to do
            }
            DirState::Owned { owner } => {
                act.owner_writeback = Some(owner);
                act.invalidations = 1;
                act.inv_mask = 1 << owner;
                act.dir_msgs += 2;
            }
        }
        e.state = DirState::Owned { owner: c };
        e.sharers = 1 << c;
        act
    }

    /// Core `c` evicted its private copy (PutS/PutM). `dirty` = had M.
    pub fn put(&mut self, line: Line, c: usize, dirty: bool) -> CoherenceActions {
        let mut act = CoherenceActions {
            dir_msgs: 1,
            ..Default::default()
        };
        if let Some(i) = self.find(line.0) {
            let e = &mut self.entries[i];
            e.sharers &= !(1 << c);
            match e.state {
                DirState::Owned { owner } if owner == c => {
                    e.state = if e.sharers == 0 {
                        DirState::Uncached
                    } else {
                        DirState::Shared
                    };
                }
                DirState::Shared if e.sharers == 0 => {
                    e.state = DirState::Uncached;
                }
                _ => {}
            }
            if dirty {
                act.dir_msgs += 1; // data message with the writeback
            }
        }
        act
    }

    /// LLC evicts the line (inclusive recall): every private copy must be
    /// invalidated; returns the sharers to invalidate and removes the entry.
    pub fn recall(&mut self, line: Line) -> (SharerMask, CoherenceActions) {
        let Some(e) = self.remove(line.0) else {
            return (0, CoherenceActions::default());
        };
        let act = CoherenceActions {
            invalidations: e.sharer_count(),
            inv_mask: e.sharers,
            owner_writeback: match e.state {
                DirState::Owned { owner } => Some(owner),
                _ => None,
            },
            dir_msgs: 1 + e.sharer_count(),
        };
        (e.sharers, act)
    }

    /// Internal-consistency check used by the property tests.
    pub fn check_invariants(&self) -> Result<(), InvariantViolation> {
        for i in 0..self.keys.len() {
            let line = self.keys[i];
            if line == EMPTY {
                continue;
            }
            let e = &self.entries[i];
            match e.state {
                DirState::Uncached => {
                    if e.sharers != 0 {
                        return Err(InvariantViolation::directory(
                            line,
                            format!("Uncached but sharers {:#b}", e.sharers),
                        ));
                    }
                }
                DirState::Shared => {
                    if e.sharers == 0 {
                        return Err(InvariantViolation::directory(
                            line,
                            "Shared but no sharers",
                        ));
                    }
                }
                DirState::Owned { owner } => {
                    if e.sharers != 1 << owner {
                        return Err(InvariantViolation::directory(
                            line,
                            format!("Owned by {owner} but sharers {:#b}", e.sharers),
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

impl Default for Directory {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l(v: u64) -> Line {
        Line(v)
    }

    #[test]
    fn first_reader_gets_exclusive() {
        let mut d = Directory::new();
        let act = d.get_s(l(1), 0);
        assert_eq!(act.invalidations, 0);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 0 });
    }

    #[test]
    fn second_reader_downgrades_owner() {
        let mut d = Directory::new();
        d.get_s(l(1), 0);
        let act = d.get_s(l(1), 1);
        assert_eq!(act.owner_writeback, Some(0));
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Shared);
        assert_eq!(d.entry(l(1)).unwrap().sharer_count(), 2);
    }

    #[test]
    fn writer_invalidates_sharers() {
        let mut d = Directory::new();
        d.get_s(l(1), 0);
        d.get_s(l(1), 1);
        d.get_s(l(1), 2);
        let act = d.get_m(l(1), 0);
        assert_eq!(act.invalidations, 2); // cores 1, 2
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 0 });
        d.check_invariants().unwrap();
    }

    #[test]
    fn writer_steals_from_dirty_owner() {
        let mut d = Directory::new();
        d.get_m(l(1), 0);
        let act = d.get_m(l(1), 1);
        assert_eq!(act.owner_writeback, Some(0));
        assert_eq!(act.invalidations, 1);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 1 });
    }

    #[test]
    fn silent_upgrade_costs_nothing_extra() {
        let mut d = Directory::new();
        d.get_s(l(1), 0); // granted E
        let act = d.get_m(l(1), 0);
        assert_eq!(act.invalidations, 0);
        assert_eq!(act.owner_writeback, None);
    }

    #[test]
    fn put_last_sharer_uncaches() {
        let mut d = Directory::new();
        d.get_s(l(1), 0);
        d.put(l(1), 0, false);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Uncached);
        d.check_invariants().unwrap();
    }

    #[test]
    fn recall_reports_all_sharers() {
        let mut d = Directory::new();
        d.get_s(l(1), 0);
        d.get_s(l(1), 1);
        let (mask, act) = d.recall(l(1));
        assert_eq!(mask, 0b11);
        assert_eq!(act.invalidations, 2);
        assert!(d.entry(l(1)).is_none());
    }

    #[test]
    fn recall_absent_line_is_noop() {
        let mut d = Directory::new();
        let (mask, act) = d.recall(l(9));
        assert_eq!(mask, 0);
        assert_eq!(act, CoherenceActions::default());
    }

    #[test]
    fn rfo_from_uncached_grants_m_without_invalidations() {
        let mut d = Directory::new();
        let act = d.get_m(l(1), 3);
        assert_eq!(act.invalidations, 0);
        assert_eq!(act.owner_writeback, None);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 3 });
        assert!(d.entry(l(1)).unwrap().is_sharer(3));
        d.check_invariants().unwrap();
    }

    #[test]
    fn put_of_unregistered_line_is_harmless() {
        let mut d = Directory::new();
        let act = d.put(l(5), 0, false);
        assert_eq!(act.invalidations, 0);
        assert!(d.entry(l(5)).is_none());
        d.check_invariants().unwrap();
    }

    #[test]
    fn put_of_a_non_owner_sharer_keeps_the_line_shared() {
        let mut d = Directory::new();
        d.get_s(l(1), 0);
        d.get_s(l(1), 1); // downgrades 0 -> Shared {0,1}
        d.put(l(1), 1, false);
        let e = d.entry(l(1)).unwrap();
        assert_eq!(e.state, DirState::Shared);
        assert!(e.is_sharer(0));
        assert!(!e.is_sharer(1));
        d.check_invariants().unwrap();
    }

    #[test]
    fn reacquire_after_recall_regrants_exclusive() {
        let mut d = Directory::new();
        d.get_s(l(1), 0);
        d.get_s(l(1), 1);
        d.recall(l(1));
        // the entry is gone; the next reader is alone again -> E
        let act = d.get_s(l(1), 1);
        assert_eq!(act.owner_writeback, None);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 1 });
        d.check_invariants().unwrap();
    }

    #[test]
    fn dirty_put_costs_an_extra_data_message() {
        let mut d = Directory::new();
        d.get_m(l(1), 0);
        let clean = d.put(l(1), 0, false);
        d.get_m(l(1), 0);
        let dirty = d.put(l(1), 0, true);
        assert_eq!(dirty.dir_msgs, clean.dir_msgs + 1);
    }

    #[test]
    fn growth_past_initial_capacity_preserves_every_entry() {
        let mut d = Directory::new();
        let n = (Directory::INITIAL_CAPACITY * 4) as u64;
        for line in 0..n {
            d.get_s(l(line), (line % 8) as usize);
        }
        assert_eq!(d.len(), n as usize);
        for line in 0..n {
            let e = d.entry(l(line)).unwrap_or_else(|| panic!("line {line} lost"));
            assert_eq!(
                e.state,
                DirState::Owned {
                    owner: (line % 8) as usize
                }
            );
        }
        d.check_invariants().unwrap();
    }

    #[test]
    fn backward_shift_deletion_keeps_probe_chains_intact() {
        // drive a dense key range through interleaved inserts and
        // recalls: linear-probing clusters form and every deletion must
        // repair the chain or later finds go EMPTY too early
        let mut d = Directory::new();
        for line in 0..4096u64 {
            d.get_s(l(line), 0);
        }
        for line in (0..4096u64).step_by(2) {
            d.recall(l(line));
        }
        assert_eq!(d.len(), 2048);
        for line in 0..4096u64 {
            if line % 2 == 0 {
                assert!(d.entry(l(line)).is_none(), "line {line} should be gone");
            } else {
                assert!(d.entry(l(line)).is_some(), "line {line} lost its entry");
            }
        }
        // survivors are still fully operational
        for line in (1..4096u64).step_by(2) {
            d.get_m(l(line), 1);
        }
        d.check_invariants().unwrap();
    }

    #[test]
    fn len_tracks_inserts_and_recalls() {
        let mut d = Directory::new();
        assert!(d.is_empty());
        d.get_s(l(1), 0);
        d.get_m(l(2), 0);
        assert_eq!(d.len(), 2);
        d.recall(l(1));
        d.recall(l(1)); // double recall is a no-op
        assert_eq!(d.len(), 1);
    }
}
