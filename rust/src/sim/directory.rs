//! Full-map directory *storage*, co-located with the (inclusive) LLC.
//!
//! A directory entry exists exactly for lines resident in the LLC. It
//! tracks which private caches hold the line and whether one of them owns
//! it exclusively. The *transactions* over these entries (GetS/GetM/Put/
//! recall state machines) are not here: they belong to the active
//! [`CoherenceProtocol`](super::hierarchy::protocol::CoherenceProtocol) —
//! this module only stores protocol-opaque line states and hands out
//! mutable entries. CData never appears here under any protocol:
//! c_read/c_write bypass coherence entirely (Section 4.4).
//!
//! Storage is an open-addressed hash table (linear probing, fibonacci
//! hashing, backward-shift deletion) rather than a `HashMap`: every
//! coherent miss performs a directory transaction, so the lookup is on
//! the simulator's hot path, and line addresses come densely from
//! `alloc_lines` — a flat probe sequence touches one or two cache lines
//! where the std map chases SipHash plus control bytes.

use super::addr::Line;
use super::invariant::InvariantViolation;

/// Sharer bitmask (up to 64 cores).
pub type SharerMask = u64;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DirState {
    /// No private cache holds the line.
    Uncached,
    /// One or more private caches hold it read-only.
    Shared,
    /// Exactly one private cache holds it E or M (silent E->M upgrade
    /// means the directory treats E and M identically: `owner` may have
    /// a dirty copy).
    Owned { owner: usize },
}

#[derive(Clone, Copy, Debug)]
pub struct DirEntry {
    pub state: DirState,
    pub sharers: SharerMask,
}

impl DirEntry {
    fn new() -> Self {
        Self {
            state: DirState::Uncached,
            sharers: 0,
        }
    }

    pub fn sharer_count(&self) -> u32 {
        self.sharers.count_ones()
    }

    pub fn is_sharer(&self, core: usize) -> bool {
        self.sharers & (1 << core) != 0
    }
}

/// Protocol transactions return what coherence actions the caller (the
/// hierarchy walk) must perform and account.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CoherenceActions {
    /// Invalidation messages to send (count of private caches).
    pub invalidations: u32,
    /// Bitmask of cores whose private copies must be invalidated.
    pub inv_mask: SharerMask,
    /// A dirty owner must write its data back/through first.
    pub owner_writeback: Option<usize>,
    /// Directory messages exchanged for this transaction.
    pub dir_msgs: u32,
    /// Bitmask of cores whose retained copies receive a write-update
    /// message (Dragon); always 0 for invalidate-based protocols.
    pub update_mask: SharerMask,
    /// The forwarding owner keeps its dirty bit (Dragon Sm: writeback
    /// responsibility stays with the last writer instead of the data
    /// being cleaned through on the fetch).
    pub keep_owner_dirty: bool,
}

/// Key marking an empty table slot. Line addresses are `byte >> 6` of a
/// bump-allocated, bounds-checked memory, so `u64::MAX` is unreachable.
const EMPTY: u64 = u64::MAX;

pub struct Directory {
    /// Line keys, `EMPTY` = free slot. Power-of-two length.
    keys: Vec<u64>,
    entries: Vec<DirEntry>,
    len: usize,
    /// `keys.len() - 1`, for probe wraparound.
    mask: usize,
    /// `64 - log2(keys.len())`: fibonacci hashing keeps the high bits.
    shift: u32,
}

impl Directory {
    const INITIAL_CAPACITY: usize = 1024;

    pub fn new() -> Self {
        Self::with_capacity(Self::INITIAL_CAPACITY)
    }

    fn with_capacity(cap: usize) -> Self {
        debug_assert!(cap.is_power_of_two());
        Self {
            keys: vec![EMPTY; cap],
            entries: vec![DirEntry::new(); cap],
            len: 0,
            mask: cap - 1,
            shift: 64 - cap.trailing_zeros(),
        }
    }

    /// Fibonacci hash: multiply spreads dense line indices across the
    /// high bits, the shift keeps exactly `log2(capacity)` of them.
    #[inline]
    fn hash(&self, key: u64) -> usize {
        (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> self.shift) as usize
    }

    /// Slot of `key` if present.
    #[inline]
    fn find(&self, key: u64) -> Option<usize> {
        let mut i = self.hash(key);
        loop {
            let k = self.keys[i];
            if k == key {
                return Some(i);
            }
            if k == EMPTY {
                return None;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Slot of `key`, inserting a fresh `Uncached` entry if absent.
    fn slot_or_insert(&mut self, key: u64) -> usize {
        debug_assert_ne!(key, EMPTY, "line address collides with the EMPTY sentinel");
        if (self.len + 1) * 10 > self.keys.len() * 7 {
            self.grow();
        }
        let mut i = self.hash(key);
        loop {
            let k = self.keys[i];
            if k == key {
                return i;
            }
            if k == EMPTY {
                self.keys[i] = key;
                self.entries[i] = DirEntry::new();
                self.len += 1;
                return i;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Double the table and rehash every occupied slot.
    fn grow(&mut self) {
        let mut bigger = Self::with_capacity(self.keys.len() * 2);
        for i in 0..self.keys.len() {
            if self.keys[i] != EMPTY {
                let j = bigger.slot_or_insert(self.keys[i]);
                bigger.entries[j] = self.entries[i];
            }
        }
        *self = bigger;
    }

    /// Remove `key`, repairing the probe chain with backward-shift
    /// deletion (no tombstones: lookups stay one clean linear scan).
    fn remove(&mut self, key: u64) -> Option<DirEntry> {
        let mut i = self.find(key)?;
        let removed = self.entries[i];
        let mut j = i;
        loop {
            self.keys[i] = EMPTY;
            loop {
                j = (j + 1) & self.mask;
                if self.keys[j] == EMPTY {
                    self.len -= 1;
                    return Some(removed);
                }
                let home = self.hash(self.keys[j]);
                // keys[j] may stay put only if its home slot lies in the
                // cyclic range (i, j] — otherwise the new hole at i
                // breaks its probe chain and it must shift back
                let stays = if i <= j {
                    i < home && home <= j
                } else {
                    i < home || home <= j
                };
                if !stays {
                    break;
                }
            }
            self.keys[i] = self.keys[j];
            self.entries[i] = self.entries[j];
            i = j;
        }
    }

    pub fn entry(&self, line: Line) -> Option<&DirEntry> {
        self.find(line.0).map(|i| &self.entries[i])
    }

    /// Mutable entry access for protocol transactions (and for the
    /// invariant tests, which inject corrupted states through it).
    pub fn entry_mut(&mut self, line: Line) -> Option<&mut DirEntry> {
        self.find(line.0).map(|i| &mut self.entries[i])
    }

    /// Entry for `line`, inserting a fresh `Uncached` one if absent —
    /// the allocation half of a GetS/GetM transaction.
    pub fn entry_or_insert(&mut self, line: Line) -> &mut DirEntry {
        let i = self.slot_or_insert(line.0);
        &mut self.entries[i]
    }

    /// Remove the entry for `line` (the storage half of an inclusive
    /// recall), returning it so the protocol can derive invalidations.
    pub fn remove_entry(&mut self, line: Line) -> Option<DirEntry> {
        self.remove(line.0)
    }

    /// Every occupied entry, for whole-directory invariant sweeps.
    pub fn iter_entries(&self) -> impl Iterator<Item = (Line, &DirEntry)> + '_ {
        self.keys
            .iter()
            .zip(self.entries.iter())
            .filter(|(&k, _)| k != EMPTY)
            .map(|(&k, e)| (Line(k), e))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Internal-consistency check used by the property tests.
    pub fn check_invariants(&self) -> Result<(), InvariantViolation> {
        for i in 0..self.keys.len() {
            let line = self.keys[i];
            if line == EMPTY {
                continue;
            }
            let e = &self.entries[i];
            match e.state {
                DirState::Uncached => {
                    if e.sharers != 0 {
                        return Err(InvariantViolation::directory(
                            line,
                            format!("Uncached but sharers {:#b}", e.sharers),
                        ));
                    }
                }
                DirState::Shared => {
                    if e.sharers == 0 {
                        return Err(InvariantViolation::directory(
                            line,
                            "Shared but no sharers",
                        ));
                    }
                }
                DirState::Owned { owner } => {
                    if e.sharers != 1 << owner {
                        return Err(InvariantViolation::directory(
                            line,
                            format!("Owned by {owner} but sharers {:#b}", e.sharers),
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

impl Default for Directory {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    // Transaction-level (MESI/Dragon) tests live with the protocols in
    // `hierarchy/protocol.rs`; these cover the raw storage: probing,
    // growth, deletion, and the state/sharer consistency check.
    use super::*;

    fn l(v: u64) -> Line {
        Line(v)
    }

    /// Register `core` as exclusive holder of `line` (the storage writes
    /// a protocol would perform on a cold GetS/GetM).
    fn claim(d: &mut Directory, line: Line, core: usize) {
        let e = d.entry_or_insert(line);
        e.state = DirState::Owned { owner: core };
        e.sharers = 1 << core;
    }

    #[test]
    fn entry_or_insert_starts_uncached() {
        let mut d = Directory::new();
        let e = d.entry_or_insert(l(1));
        assert_eq!(e.state, DirState::Uncached);
        assert_eq!(e.sharers, 0);
        assert_eq!(d.len(), 1);
        // a second call finds the same entry rather than resetting it
        d.entry_or_insert(l(1)).sharers = 0b11;
        assert_eq!(d.entry_or_insert(l(1)).sharers, 0b11);
        assert_eq!(d.len(), 1);
    }

    #[test]
    fn entry_mut_is_none_for_absent_lines() {
        let mut d = Directory::new();
        assert!(d.entry_mut(l(9)).is_none());
        assert!(d.entry(l(9)).is_none());
        assert!(d.remove_entry(l(9)).is_none());
    }

    #[test]
    fn remove_entry_returns_the_stored_state() {
        let mut d = Directory::new();
        claim(&mut d, l(1), 3);
        let e = d.remove_entry(l(1)).unwrap();
        assert_eq!(e.state, DirState::Owned { owner: 3 });
        assert_eq!(e.sharers, 1 << 3);
        assert!(d.entry(l(1)).is_none());
        assert!(d.remove_entry(l(1)).is_none(), "double remove is a no-op");
    }

    #[test]
    fn iter_entries_walks_every_occupied_slot() {
        let mut d = Directory::new();
        for line in 0..100u64 {
            claim(&mut d, l(line), (line % 4) as usize);
        }
        let mut seen: Vec<u64> = d.iter_entries().map(|(line, _)| line.0).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..100u64).collect::<Vec<_>>());
        for (line, e) in d.iter_entries() {
            assert_eq!(e.state, DirState::Owned { owner: (line.0 % 4) as usize });
        }
    }

    #[test]
    fn growth_past_initial_capacity_preserves_every_entry() {
        let mut d = Directory::new();
        let n = (Directory::INITIAL_CAPACITY * 4) as u64;
        for line in 0..n {
            claim(&mut d, l(line), (line % 8) as usize);
        }
        assert_eq!(d.len(), n as usize);
        for line in 0..n {
            let e = d.entry(l(line)).unwrap_or_else(|| panic!("line {line} lost"));
            assert_eq!(
                e.state,
                DirState::Owned {
                    owner: (line % 8) as usize
                }
            );
        }
        d.check_invariants().unwrap();
    }

    #[test]
    fn backward_shift_deletion_keeps_probe_chains_intact() {
        // drive a dense key range through interleaved inserts and
        // removals: linear-probing clusters form and every deletion must
        // repair the chain or later finds go EMPTY too early
        let mut d = Directory::new();
        for line in 0..4096u64 {
            claim(&mut d, l(line), 0);
        }
        for line in (0..4096u64).step_by(2) {
            d.remove_entry(l(line));
        }
        assert_eq!(d.len(), 2048);
        for line in 0..4096u64 {
            if line % 2 == 0 {
                assert!(d.entry(l(line)).is_none(), "line {line} should be gone");
            } else {
                assert!(d.entry(l(line)).is_some(), "line {line} lost its entry");
            }
        }
        // survivors are still fully operational
        for line in (1..4096u64).step_by(2) {
            claim(&mut d, l(line), 1);
        }
        d.check_invariants().unwrap();
    }

    #[test]
    fn len_tracks_inserts_and_removes() {
        let mut d = Directory::new();
        assert!(d.is_empty());
        claim(&mut d, l(1), 0);
        claim(&mut d, l(2), 0);
        assert_eq!(d.len(), 2);
        d.remove_entry(l(1));
        d.remove_entry(l(1));
        assert_eq!(d.len(), 1);
    }

    #[test]
    fn invariant_check_rejects_inconsistent_states() {
        let mut d = Directory::new();
        claim(&mut d, l(1), 2);
        d.check_invariants().unwrap();
        // an Owned entry whose sharer mask disagrees with the owner
        d.entry_mut(l(1)).unwrap().sharers = 0b11;
        assert!(d.check_invariants().is_err());
        // Shared with no sharers is equally broken
        let e = d.entry_mut(l(1)).unwrap();
        e.state = DirState::Shared;
        e.sharers = 0;
        assert!(d.check_invariants().is_err());
        // and a consistent Shared state passes again
        d.entry_mut(l(1)).unwrap().sharers = 0b101;
        d.check_invariants().unwrap();
    }
}
