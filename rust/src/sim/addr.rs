//! Byte/line address helpers. Lines are 64 bytes throughout (Table 2).

/// Byte address in simulated memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Addr(pub u64);

/// Cache-line address (byte address >> 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Line(pub u64);

pub const LINE_BYTES: u64 = 64;
pub const LINE_SHIFT: u32 = 6;

impl Addr {
    #[inline]
    pub fn line(self) -> Line {
        Line(self.0 >> LINE_SHIFT)
    }

    #[inline]
    pub fn offset(self) -> u64 {
        self.0 & (LINE_BYTES - 1)
    }

    /// Word index into the flat u32 functional memory.
    #[inline]
    pub fn word_index(self) -> usize {
        debug_assert_eq!(self.0 % 4, 0, "unaligned word access at {:#x}", self.0);
        (self.0 / 4) as usize
    }

    #[inline]
    pub fn add(self, bytes: u64) -> Addr {
        Addr(self.0 + bytes)
    }
}

impl Line {
    #[inline]
    pub fn base(self) -> Addr {
        Addr(self.0 << LINE_SHIFT)
    }

    /// First word index of this line in the flat u32 memory.
    #[inline]
    pub fn word_index(self) -> usize {
        (self.0 << (LINE_SHIFT - 2)) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_and_offset_roundtrip() {
        let a = Addr(0x1234);
        assert_eq!(a.line(), Line(0x48));
        assert_eq!(a.offset(), 0x34);
        assert_eq!(a.line().base().0, 0x1200);
    }

    #[test]
    fn word_indices() {
        assert_eq!(Addr(0).word_index(), 0);
        assert_eq!(Addr(4).word_index(), 1);
        assert_eq!(Addr(64).word_index(), 16);
        assert_eq!(Line(1).word_index(), 16);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn unaligned_word_panics_in_debug() {
        let _ = Addr(3).word_index();
    }
}
