//! Typed invariant-violation errors for the simulator's
//! cross-structure consistency checks.
//!
//! The stress and property suites call
//! [`Directory::check_invariants`](super::directory::Directory::check_invariants)
//! and [`MemSystem::check_invariants`](super::memsys::MemSystem::check_invariants)
//! after every phase; a violation used to surface as a bare `String`,
//! which the execution layer could neither match on nor attribute. This
//! module gives those checks a structured error consistent with
//! [`ExecError`](crate::exec::ExecError): the failing structure, the
//! line, the core (for engine-side checks) and a human diagnostic.
//!
//! `From<InvariantViolation> for String` keeps the property-test
//! closures (whose result type is `Result<(), String>`) working with
//! `?` unchanged.

use std::fmt;

/// A broken cross-structure invariant, found by a `check_invariants`
/// sweep. Carries enough structure for the execution layer to report
/// *where* the simulated machine went inconsistent.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum InvariantViolation {
    /// The MESI directory's state/sharer bookkeeping is inconsistent.
    Directory { line: u64, detail: String },
    /// The CCache engine's L1/source-buffer/merge-type bindings are
    /// inconsistent for one core.
    Engine {
        core: usize,
        line: u64,
        detail: String,
    },
    /// The shared level's merge-region way partition is inconsistent: a
    /// CData-classed line sits outside the merge-region ways (or a line
    /// is CData-classed while no partition is configured).
    Partition { line: u64, detail: String },
}

impl InvariantViolation {
    pub fn directory(line: u64, detail: impl Into<String>) -> Self {
        InvariantViolation::Directory {
            line,
            detail: detail.into(),
        }
    }

    pub fn engine(core: usize, line: u64, detail: impl Into<String>) -> Self {
        InvariantViolation::Engine {
            core,
            line,
            detail: detail.into(),
        }
    }

    pub fn partition(line: u64, detail: impl Into<String>) -> Self {
        InvariantViolation::Partition {
            line,
            detail: detail.into(),
        }
    }

    /// The line the violation was detected on.
    pub fn line(&self) -> u64 {
        match self {
            InvariantViolation::Directory { line, .. }
            | InvariantViolation::Engine { line, .. }
            | InvariantViolation::Partition { line, .. } => *line,
        }
    }
}

impl fmt::Display for InvariantViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            InvariantViolation::Directory { line, detail } => {
                write!(f, "directory invariant violated: line {line:#x}: {detail}")
            }
            InvariantViolation::Engine { core, line, detail } => {
                write!(
                    f,
                    "engine invariant violated: core {core}: line {line:#x}: {detail}"
                )
            }
            InvariantViolation::Partition { line, detail } => {
                write!(
                    f,
                    "partition invariant violated: line {line:#x}: {detail}"
                )
            }
        }
    }
}

impl std::error::Error for InvariantViolation {}

/// The property-test driver's result type is `Result<(), String>`;
/// this keeps `check_invariants()?` working inside those closures.
impl From<InvariantViolation> for String {
    fn from(v: InvariantViolation) -> String {
        v.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_structure_core_and_line() {
        let v = InvariantViolation::engine(3, 0x40, "merge-type skew");
        let msg = v.to_string();
        assert!(msg.contains("core 3"), "{msg}");
        assert!(msg.contains("0x40"), "{msg}");
        assert!(msg.contains("merge-type skew"), "{msg}");
        assert_eq!(v.line(), 0x40);

        let v = InvariantViolation::directory(0x80, "Shared but no sharers");
        assert!(v.to_string().starts_with("directory invariant"), "{v}");
        assert_eq!(v.line(), 0x80);

        let v = InvariantViolation::partition(0x1c0, "CData line in way 5, partition is 2");
        assert!(v.to_string().starts_with("partition invariant"), "{v}");
        assert!(v.to_string().contains("way 5"), "{v}");
        assert_eq!(v.line(), 0x1c0);
    }

    #[test]
    fn converts_to_string_for_prop_results() {
        let run = || -> Result<(), String> {
            Err(InvariantViolation::directory(1, "x"))?;
            Ok(())
        };
        assert!(run().unwrap_err().contains("directory invariant"));
    }
}
