//! [`CoreCtx`] — the software-visible ISA surface one simulated core
//! programs against: every method is one "instruction" that advances the
//! core's clock through the timing model, cooperating with the
//! [`Machine`](super::machine::Machine)'s deterministic laggard-first
//! interleaver (turn management, locks, barriers).

use std::sync::MutexGuard;

use super::addr::Addr;
use super::machine::{MachState, Machine};
use super::mfrf::MergeFault;
use crate::exec::ctx::ExecCtx;
use crate::merge::MergeHandle;

/// The per-core execution context: every method is one "instruction" that
/// advances the core's clock through the timing model.
pub struct CoreCtx<'m> {
    machine: &'m Machine,
    core: usize,
    guard: Option<MutexGuard<'m, MachState>>,
}

/// A [`MergeFault`] is the hardware trapping mid-program: core programs
/// have no error channel (real code wouldn't either), so the fault
/// unwinds the core thread with the typed fault as payload. The machine
/// records it in the memory system first, and the execution driver
/// recovers it as `ExecError::MergeFault`.
fn ok_or_fault<T>(r: Result<T, MergeFault>) -> T {
    match r {
        Ok(v) => v,
        Err(fault) => std::panic::panic_any(fault),
    }
}

impl<'m> CoreCtx<'m> {
    pub(crate) fn new(machine: &'m Machine, core: usize) -> Self {
        Self {
            machine,
            core,
            guard: None,
        }
    }

    pub fn core_id(&self) -> usize {
        self.core
    }

    /// Current simulated cycle count of this core.
    pub fn cycles(&mut self) -> u64 {
        let core = self.core;
        self.state().clocks[core]
    }

    // ---- turn management -------------------------------------------------

    /// Acquire the machine state, waiting until it is this core's turn.
    fn state(&mut self) -> &mut MachState {
        if self.guard.is_none() {
            let mut g = self.machine.lock_state();
            while !g.aborted && g.turn != self.core {
                g = match self.machine.cvs[self.core].wait(g) {
                    Ok(g) => g,
                    Err(poison) => poison.into_inner(),
                };
            }
            if g.aborted {
                panic!("sibling core panicked; aborting core {}", self.core);
            }
            self.guard = Some(g);
        }
        self.guard.as_mut().unwrap()
    }

    /// After an operation: hand the turn over if we ran past the laggard.
    fn maybe_yield(&mut self) {
        let quantum = self.machine.quantum;
        let core = self.core;
        let g = match self.guard.as_mut() {
            Some(g) => g,
            None => return,
        };
        // fast path: still within the cached bound — no scan, no notify
        if g.clocks[core] <= g.yield_at {
            return;
        }
        if let Some(next) = g.laggard() {
            if next != core && g.clocks[next] + quantum < g.clocks[core] {
                g.grant_turn(next, quantum);
                self.guard = None; // drop the guard
                self.machine.notify_core(next);
                return;
            }
        }
        // we remain the laggard: refresh the bound
        g.grant_turn(core, quantum);
    }

    /// Unconditionally pass the turn (lock spins, barriers).
    fn yield_turn(&mut self) {
        let core = self.core;
        let g = match self.guard.as_mut() {
            Some(g) => g,
            None => return,
        };
        if let Some(next) = g.laggard() {
            if next != core {
                let q = self.machine.quantum;
                g.grant_turn(next, q);
                self.guard = None;
                self.machine.notify_core(next);
                return;
            }
        }
        // we remain the laggard: keep the turn
    }

    pub(crate) fn finish(&mut self) {
        let core = self.core;
        let quantum = self.machine.quantum;
        let g = self.state();
        g.finished[core] = true;
        // if every remaining active core is blocked at a barrier, this
        // finish is what releases it
        let all_waiting = (0..g.clocks.len()).all(|c| g.finished[c] || g.waiting[c]);
        let any_waiting = (0..g.clocks.len()).any(|c| g.waiting[c]);
        if all_waiting && any_waiting {
            let maxc = (0..g.clocks.len())
                .filter(|&c| g.waiting[c])
                .map(|c| g.clocks[c])
                .max()
                .unwrap_or(0);
            for c in 0..g.clocks.len() {
                if g.waiting[c] {
                    g.clocks[c] = g.clocks[c].max(maxc);
                    g.waiting[c] = false;
                }
            }
            g.barrier_gen += 1;
            if let Some(next) = g.laggard() {
                g.grant_turn(next, quantum);
            }
            self.guard = None;
            self.machine.notify_everyone();
            return;
        }
        if let Some(next) = g.laggard() {
            g.grant_turn(next, quantum);
        }
        self.guard = None;
        self.machine.notify_everyone();
    }

    // ---- timed operations -------------------------------------------------

    fn charge(&mut self, cycles: u64) {
        let core = self.core;
        self.state().clocks[core] += cycles;
        self.maybe_yield();
    }

    /// Non-memory work: `n` instructions at 1 cycle each (Table 2).
    pub fn compute(&mut self, n: u64) {
        self.charge(n);
    }

    pub fn read_u32(&mut self, addr: Addr) -> u32 {
        let core = self.core;
        let (v, c) = ok_or_fault(self.state().mem.read(core, addr));
        self.charge(c);
        v
    }

    pub fn write_u32(&mut self, addr: Addr, val: u32) {
        let core = self.core;
        let c = ok_or_fault(self.state().mem.write(core, addr, val));
        self.charge(c);
    }

    pub fn read_f32(&mut self, addr: Addr) -> f32 {
        f32::from_bits(self.read_u32(addr))
    }

    pub fn write_f32(&mut self, addr: Addr, val: f32) {
        self.write_u32(addr, val.to_bits());
    }

    pub fn cas_u32(&mut self, addr: Addr, expected: u32, new: u32) -> bool {
        let core = self.core;
        let (ok, c) = ok_or_fault(self.state().mem.cas(core, addr, expected, new));
        self.charge(c);
        ok
    }

    pub fn fetch_or_u32(&mut self, addr: Addr, bits: u32) -> u32 {
        let core = self.core;
        let (old, c) = ok_or_fault(self.state().mem.fetch_or(core, addr, bits));
        self.charge(c);
        old
    }

    // ---- CCache ISA (Table 1) ----------------------------------------------

    /// `merge_init(&fn, i)` — install any [`MergeHandle`], built-in or
    /// user-defined, into MFRF slot `i`.
    pub fn merge_init(&mut self, slot: usize, f: MergeHandle) {
        let core = self.core;
        self.state().mem.merge_init(core, slot, f);
        self.charge(1);
    }

    /// `c_read(CData, i)`.
    pub fn c_read_u32(&mut self, addr: Addr, ty: u8) -> u32 {
        let core = self.core;
        let (v, c) = ok_or_fault(self.state().mem.c_read(core, addr, ty));
        self.charge(c);
        v
    }

    /// `c_write(CData, v, i)`.
    pub fn c_write_u32(&mut self, addr: Addr, val: u32, ty: u8) {
        let core = self.core;
        let c = ok_or_fault(self.state().mem.c_write(core, addr, val, ty));
        self.charge(c);
    }

    pub fn c_read_f32(&mut self, addr: Addr, ty: u8) -> f32 {
        f32::from_bits(self.c_read_u32(addr, ty))
    }

    pub fn c_write_f32(&mut self, addr: Addr, val: f32, ty: u8) {
        self.c_write_u32(addr, val.to_bits(), ty);
    }

    /// `soft_merge` — mark CData mergeable (merge-on-evict).
    pub fn soft_merge(&mut self) {
        let core = self.core;
        let c = ok_or_fault(self.state().mem.soft_merge(core));
        self.charge(c);
    }

    /// `merge` — merge all of this core's CData now.
    pub fn merge(&mut self) {
        let core = self.core;
        let c = ok_or_fault(self.state().mem.merge_all(core));
        self.charge(c);
    }

    // ---- synchronization ----------------------------------------------------

    /// Spin lock acquire: CAS loop with backoff; the turn is handed to the
    /// laggard between attempts so the owner can make progress.
    pub fn lock(&mut self, addr: Addr) {
        let backoff = self.machine.lock_backoff;
        let core = self.core;
        loop {
            let (ok, c) = ok_or_fault(self.state().mem.cas(core, addr, 0, 1));
            {
                let g = self.guard.as_mut().unwrap();
                g.clocks[core] += c;
                if ok {
                    g.mem.stats.lock_acquires += 1;
                } else {
                    g.mem.stats.lock_retries += 1;
                    g.clocks[core] += backoff;
                }
            }
            if ok {
                self.maybe_yield();
                return;
            }
            self.yield_turn();
        }
    }

    /// Spin lock release: coherent store of 0.
    pub fn unlock(&mut self, addr: Addr) {
        self.write_u32(addr, 0);
    }

    /// Merge boundary barrier (Section 3.2.1): all cores must arrive;
    /// clocks synchronize to the latest arrival.
    pub fn barrier(&mut self) {
        let core = self.core;
        let quantum = self.machine.quantum;
        let gen = {
            let g = self.state();
            // a barrier is a phase boundary: fold this core's (and any
            // already-parked cores') fast-path counters into the stats,
            // and publish this core's buffered stores — under partial
            // coherence the barrier flush is what makes plain stores
            // globally visible
            g.mem.flush_hot_stats();
            g.mem.publish_partial(core);
            g.mem.stats.barriers += 1;
            g.waiting[core] = true;
            let gen = g.barrier_gen;
            let all_waiting = (0..g.clocks.len()).all(|c| g.finished[c] || g.waiting[c]);
            if all_waiting {
                let maxc = (0..g.clocks.len())
                    .filter(|&c| g.waiting[c])
                    .map(|c| g.clocks[c])
                    .max()
                    .unwrap_or(0);
                for c in 0..g.clocks.len() {
                    if g.waiting[c] {
                        g.clocks[c] = g.clocks[c].max(maxc);
                        g.waiting[c] = false;
                    }
                }
                g.barrier_gen += 1;
                if let Some(next) = g.laggard() {
                    g.grant_turn(next, quantum);
                }
                self.guard = None;
                self.machine.notify_everyone();
                return;
            }
            // others still running: hand over the turn and sleep
            if let Some(next) = g.laggard() {
                g.grant_turn(next, quantum);
            } else {
                panic!("barrier deadlock: no runnable core");
            }
            gen
        };
        let next_after = {
            let g = self.guard.as_ref().unwrap();
            g.turn
        };
        self.guard = None;
        self.machine.notify_core(next_after);
        let mut g = self.machine.lock_state();
        while !g.aborted && g.barrier_gen == gen {
            g = match self.machine.cvs[core].wait(g) {
                Ok(g) => g,
                Err(poison) => poison.into_inner(),
            };
        }
        if g.aborted {
            panic!("sibling core panicked during barrier");
        }
        drop(g);
    }
}

/// The simulator backend of the execution-context abstraction: pure
/// delegation to the inherent (timed, interleaved) methods above, so
/// generic `Workload::program<C: ExecCtx>` bodies run unchanged on the
/// simulated machine.
impl ExecCtx for CoreCtx<'_> {
    fn core_id(&self) -> usize {
        CoreCtx::core_id(self)
    }

    fn cycles(&mut self) -> u64 {
        CoreCtx::cycles(self)
    }

    fn compute(&mut self, n: u64) {
        CoreCtx::compute(self, n)
    }

    fn read_u32(&mut self, addr: Addr) -> u32 {
        CoreCtx::read_u32(self, addr)
    }

    fn write_u32(&mut self, addr: Addr, val: u32) {
        CoreCtx::write_u32(self, addr, val)
    }

    fn cas_u32(&mut self, addr: Addr, expected: u32, new: u32) -> bool {
        CoreCtx::cas_u32(self, addr, expected, new)
    }

    fn fetch_or_u32(&mut self, addr: Addr, bits: u32) -> u32 {
        CoreCtx::fetch_or_u32(self, addr, bits)
    }

    fn merge_init(&mut self, slot: usize, f: MergeHandle) {
        CoreCtx::merge_init(self, slot, f)
    }

    fn c_read_u32(&mut self, addr: Addr, ty: u8) -> u32 {
        CoreCtx::c_read_u32(self, addr, ty)
    }

    fn c_write_u32(&mut self, addr: Addr, val: u32, ty: u8) {
        CoreCtx::c_write_u32(self, addr, val, ty)
    }

    fn soft_merge(&mut self) {
        CoreCtx::soft_merge(self)
    }

    fn merge(&mut self) {
        CoreCtx::merge(self)
    }

    fn lock(&mut self, addr: Addr) {
        CoreCtx::lock(self, addr)
    }

    fn unlock(&mut self, addr: Addr) {
        CoreCtx::unlock(self, addr)
    }

    fn barrier(&mut self) {
        CoreCtx::barrier(self)
    }
}
