//! Simulation counters — one field per quantity a figure in Section 6
//! reports, plus general cache statistics.
//!
//! Cache-level counters are a vector indexed like
//! [`MachineConfig::levels`](super::config::MachineConfig::levels)
//! (innermost first, shared level last), so they follow whatever
//! hierarchy shape the machine was configured with. [`Stats::l1`] and
//! [`Stats::llc`] are convenience views of the first/last entries.

use std::fmt;

/// CData reuse: L1 hits amortizing each privatizing fill. The shared
/// form behind [`Stats::ccache_reuse_ratio`], the kmeans residency
/// check, and the reuse-aware partition controller's epoch deltas.
/// `hits/fills`, with the zero-fill edge cases pinned: no fills but
/// hits is perfect reuse (`inf`), no traffic at all is `0.0`.
pub fn reuse_ratio(hits: u64, fills: u64) -> f64 {
    if fills == 0 {
        if hits > 0 {
            f64::INFINITY
        } else {
            0.0
        }
    } else {
        hits as f64 / fills as f64
    }
}

/// Per-level hit/miss counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LevelStats {
    pub hits: u64,
    pub misses: u64,
}

impl LevelStats {
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn miss_rate(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses() as f64
        }
    }
}

/// Per-core scratch counters for the engine's fast paths. The dominant
/// access classes (coherent L1 read hits, private-hit COps) bump these
/// plain integers instead of dereferencing into the shared [`Stats`];
/// [`MemSystem::flush_hot_stats`](super::memsys::MemSystem::flush_hot_stats)
/// folds them in at phase boundaries (end of run, barrier, merge), so
/// the post-flush totals are identical to per-access accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct HotCounters {
    /// Innermost-level coherent read hits taken on the fast path.
    pub l1_hits: u64,
    /// COps executed on the fast path.
    pub cops: u64,
    /// CData L1 hits taken on the fast path.
    pub ccache_l1_hits: u64,
}

impl HotCounters {
    pub fn is_empty(&self) -> bool {
        self.l1_hits == 0 && self.cops == 0 && self.ccache_l1_hits == 0
    }
}

/// All counters collected during a simulation run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Stats {
    // -- time ---------------------------------------------------------
    /// Final per-core cycle counts; the run's "execution time" is the max.
    pub core_cycles: Vec<u64>,

    // -- cache hierarchy ----------------------------------------------
    /// Hit/miss counters per hierarchy level, innermost first; the last
    /// entry is the shared level.
    pub levels: Vec<LevelStats>,
    pub mem_accesses: u64,

    // -- coherence (Fig 8) ---------------------------------------------
    /// Messages handled by the directory (GetS/GetM/upgrade/writeback/recall).
    pub directory_msgs: u64,
    /// Invalidation messages sent to private caches.
    pub invalidations: u64,
    /// Dirty-line writebacks between levels and to memory.
    pub writebacks: u64,
    /// Write-update broadcasts performed (Dragon: one per write to a
    /// line with other sharers; always 0 under invalidate-based
    /// protocols).
    pub dragon_updates: u64,
    /// Update words delivered across all broadcasts (one per recipient
    /// sharer), i.e. the update-message fan-out Dragon pays.
    pub update_words: u64,

    // -- CCache (Fig 9, Section 6.4) ------------------------------------
    /// c_read/c_write operations executed.
    pub cops: u64,
    /// CData hits in the innermost level.
    pub ccache_l1_hits: u64,
    /// CData fills (innermost miss on a COp).
    pub ccache_fills: u64,
    /// Merge-function executions (one per merged line).
    pub merges: u64,
    /// Source-buffer entries evicted to make room (capacity) — the Fig 9
    /// quantity. Full-flush merges (no merge-on-evict) also count here.
    pub src_buf_evictions: u64,
    /// Clean mergeable lines silently dropped (dirty-merge optimization).
    pub silent_drops: u64,
    /// Approximate merges whose update was dropped.
    pub approx_drops: u64,

    // -- LLC way partitioning ---------------------------------------------
    /// Smallest merge-region width (in ways) the run saw; 0 when the
    /// shared level is unpartitioned. Static partitions keep
    /// min == max == final == the configured width.
    pub partition_ways_min: u64,
    /// Largest merge-region width the run saw.
    pub partition_ways_max: u64,
    /// Merge-region width at the end of the run.
    pub partition_ways_final: u64,
    /// Resize decisions the reuse-aware controller took.
    pub repartitions: u64,

    // -- synchronization -------------------------------------------------
    pub lock_acquires: u64,
    pub lock_retries: u64,
    pub atomic_rmws: u64,
    pub barriers: u64,

    // -- footprint --------------------------------------------------------
    /// Bytes allocated by the workload (Table 3).
    pub bytes_allocated: u64,
}

impl Stats {
    pub fn new(cores: usize, depth: usize) -> Self {
        Self {
            core_cycles: vec![0; cores],
            levels: vec![LevelStats::default(); depth],
            ..Default::default()
        }
    }

    /// Hierarchy depth these stats were collected on.
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Counters for level `i` (zeros if the level does not exist).
    pub fn level(&self, i: usize) -> LevelStats {
        self.levels.get(i).copied().unwrap_or_default()
    }

    /// The innermost level's counters.
    pub fn l1(&self) -> LevelStats {
        self.level(0)
    }

    /// The shared (last) level's counters.
    pub fn llc(&self) -> LevelStats {
        self.levels.last().copied().unwrap_or_default()
    }

    /// The run's execution time: the slowest core's clock.
    pub fn total_cycles(&self) -> u64 {
        self.core_cycles.iter().copied().max().unwrap_or(0)
    }

    /// Fig 8 normalization: events per 1000 cycles.
    pub fn per_kilocycle(&self, count: u64) -> f64 {
        let c = self.total_cycles();
        if c == 0 {
            0.0
        } else {
            count as f64 * 1000.0 / c as f64
        }
    }

    pub fn dir_msgs_per_kc(&self) -> f64 {
        self.per_kilocycle(self.directory_msgs)
    }

    pub fn invalidations_per_kc(&self) -> f64 {
        self.per_kilocycle(self.invalidations)
    }

    pub fn llc_misses_per_kc(&self) -> f64 {
        self.per_kilocycle(self.llc().misses)
    }

    /// CData reuse over the whole run: L1 hits per privatizing fill
    /// (see [`reuse_ratio`] for the zero-fill conventions). A ratio
    /// well above 1 means privatized lines stay resident and keep
    /// absorbing COps; near 0 means every COp re-privatizes.
    pub fn ccache_reuse_ratio(&self) -> f64 {
        reuse_ratio(self.ccache_l1_hits, self.ccache_fills)
    }
}

impl fmt::Display for Stats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "cycles            {:>14}", self.total_cycles())?;
        for (i, lv) in self.levels.iter().enumerate() {
            let name = if i + 1 == self.levels.len() {
                "LLC".to_string()
            } else {
                format!("L{}", i + 1)
            };
            writeln!(
                f,
                "{:<4}h/m           {:>14}/{} ({:.1}% miss)",
                name,
                lv.hits,
                lv.misses,
                lv.miss_rate() * 100.0
            )?;
        }
        writeln!(f, "mem accesses      {:>14}", self.mem_accesses)?;
        writeln!(f, "directory msgs    {:>14}", self.directory_msgs)?;
        writeln!(f, "invalidations     {:>14}", self.invalidations)?;
        writeln!(f, "writebacks        {:>14}", self.writebacks)?;
        if self.dragon_updates > 0 {
            writeln!(
                f,
                "dragon updates    {:>14} ({} words)",
                self.dragon_updates, self.update_words
            )?;
        }
        writeln!(f, "COps              {:>14}", self.cops)?;
        writeln!(f, "ccache L1 hits    {:>14}", self.ccache_l1_hits)?;
        writeln!(f, "ccache fills      {:>14}", self.ccache_fills)?;
        writeln!(f, "merges            {:>14}", self.merges)?;
        writeln!(f, "src-buf evictions {:>14}", self.src_buf_evictions)?;
        writeln!(f, "silent drops      {:>14}", self.silent_drops)?;
        writeln!(f, "approx drops      {:>14}", self.approx_drops)?;
        if self.partition_ways_max > 0 {
            writeln!(
                f,
                "partition ways    {:>14} (min {} / max {} / final {})",
                self.partition_ways_final,
                self.partition_ways_min,
                self.partition_ways_max,
                self.partition_ways_final
            )?;
            writeln!(f, "repartitions      {:>14}", self.repartitions)?;
        }
        writeln!(f, "lock acq/retry    {:>14}/{}", self.lock_acquires, self.lock_retries)?;
        writeln!(f, "atomic RMWs       {:>14}", self.atomic_rmws)?;
        writeln!(f, "barriers          {:>14}", self.barriers)?;
        writeln!(f, "bytes allocated   {:>14}", self.bytes_allocated)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_cycles_is_max_core() {
        let mut s = Stats::new(4, 3);
        s.core_cycles = vec![10, 500, 30, 2];
        assert_eq!(s.total_cycles(), 500);
    }

    #[test]
    fn per_kilocycle_normalizes() {
        let mut s = Stats::new(1, 3);
        s.core_cycles = vec![10_000];
        assert_eq!(s.per_kilocycle(50), 5.0);
    }

    #[test]
    fn zero_cycles_no_nan() {
        let s = Stats::new(1, 3);
        assert_eq!(s.per_kilocycle(10), 0.0);
        assert_eq!(s.l1().miss_rate(), 0.0);
    }

    #[test]
    fn level_views_track_shape() {
        let mut s = Stats::new(1, 2);
        s.levels[0].hits = 3;
        s.levels[1].misses = 7;
        assert_eq!(s.l1().hits, 3);
        assert_eq!(s.llc().misses, 7);
        assert_eq!(s.depth(), 2);
        // out-of-range levels read as zero
        assert_eq!(s.level(9).accesses(), 0);
    }

    #[test]
    fn display_renders_every_level() {
        let s = Stats::new(2, 4);
        let text = format!("{s}");
        assert!(text.contains("directory msgs"));
        assert!(text.contains("L3"));
        assert!(text.contains("LLC"));
    }

    #[test]
    fn reuse_ratio_is_hits_per_fill_with_pinned_edges() {
        assert_eq!(reuse_ratio(8, 2), 4.0);
        assert_eq!(reuse_ratio(1, 2), 0.5);
        // resident CData: hits with zero fills is perfect reuse
        assert_eq!(reuse_ratio(5, 0), f64::INFINITY);
        // no CData traffic at all
        assert_eq!(reuse_ratio(0, 0), 0.0);
    }

    #[test]
    fn ccache_reuse_ratio_reads_the_run_counters() {
        let mut s = Stats::new(1, 3);
        s.ccache_l1_hits = 41;
        s.ccache_fills = 10;
        // the kmeans residency check `hits > fills * 4` is exactly
        // `ratio > 4.0` — pin the equivalence both ways
        assert!(s.ccache_reuse_ratio() > 4.0);
        s.ccache_l1_hits = 40;
        assert!(s.ccache_reuse_ratio() <= 4.0);
        s.ccache_fills = 0;
        assert_eq!(s.ccache_reuse_ratio(), f64::INFINITY);
        s.ccache_l1_hits = 0;
        assert_eq!(s.ccache_reuse_ratio(), 0.0);
    }

    #[test]
    fn display_emits_partition_counters_only_when_partitioned() {
        let mut s = Stats::new(1, 3);
        // unpartitioned runs don't render the section at all
        assert!(!format!("{s}").contains("partition ways"));
        s.partition_ways_min = 2;
        s.partition_ways_max = 6;
        s.partition_ways_final = 5;
        s.repartitions = 9;
        let text = format!("{s}");
        assert!(text.contains("partition ways"), "{text}");
        assert!(text.contains("min 2 / max 6 / final 5"), "{text}");
        assert!(text.contains("repartitions"), "{text}");
        assert!(text.contains("9"), "{text}");
    }

    #[test]
    fn display_emits_dragon_counters_only_under_write_update() {
        let mut s = Stats::new(1, 3);
        // invalidate-based runs never broadcast: section stays hidden
        assert!(!format!("{s}").contains("dragon updates"));
        s.dragon_updates = 13;
        s.update_words = 37;
        let text = format!("{s}");
        assert!(text.contains("dragon updates"), "{text}");
        assert!(text.contains("13"), "{text}");
        assert!(text.contains("(37 words)"), "{text}");
    }

    #[test]
    fn display_emits_every_ccache_and_sync_counter() {
        // regression: these were collected but never rendered, so runs
        // silently hid the CCache hit/fill split and the sync traffic
        let mut s = Stats::new(1, 3);
        s.ccache_l1_hits = 11;
        s.ccache_fills = 7;
        s.approx_drops = 3;
        s.atomic_rmws = 19;
        s.barriers = 5;
        let text = format!("{s}");
        for (label, value) in [
            ("ccache L1 hits", "11"),
            ("ccache fills", "7"),
            ("approx drops", "3"),
            ("atomic RMWs", "19"),
            ("barriers", "5"),
        ] {
            assert!(text.contains(label), "missing label {label}: {text}");
            assert!(text.contains(value), "missing value {value}: {text}");
        }
    }
}
