//! Section 4.7 analytical area/energy model.
//!
//! The paper used CACTI (closed tooling + process files) to size the
//! CCache additions; we reproduce the *structure inventory* analytically:
//! bits added per cache line, source-buffer capacity, MFRF and merge
//! register sizes, and the paper's reported ratios (source buffer ≈ 0.1%
//! of LLC area, ≈ 6.5% of LLC access energy) as constants to compare our
//! structural model against. See DESIGN.md for the substitution note.

use super::config::MachineConfig;

/// Paper-reported CACTI results (32 nm) — the comparison targets.
pub const PAPER_SRC_BUF_AREA_FRAC_OF_LLC: f64 = 0.001; // 0.1 %
pub const PAPER_SRC_BUF_ENERGY_FRAC_OF_LLC: f64 = 0.065; // 6.5 %

/// Structural overhead of the CCache extensions for a given machine.
#[derive(Clone, Copy, Debug)]
pub struct OverheadModel {
    /// Extra metadata bits per L1 line: CCache bit + mergeable bit +
    /// merge-type field.
    pub l1_extra_bits_per_line: u32,
    /// Total extra L1 metadata bits per core.
    pub l1_extra_bits: u64,
    /// Source buffer bits per core (tag + data per entry).
    pub src_buf_bits: u64,
    /// MFRF bits per core (function pointers).
    pub mfrf_bits: u64,
    /// Merge register bits per core (3 line-sized registers).
    pub merge_reg_bits: u64,
    /// LLC data+tag bits (the denominator for area ratios).
    pub llc_bits: u64,
}

impl OverheadModel {
    pub fn for_config(cfg: &MachineConfig) -> Self {
        let merge_type_bits = (cfg.ccache.mfrf_slots as f64).log2().ceil() as u32;
        let l1_extra_bits_per_line = 2 + merge_type_bits; // ccache + mergeable + type
        let l1_lines = (cfg.l1().size_bytes / 64) as u64;

        // source buffer: per entry, a 58-bit line tag + 512 data bits + valid
        let sb_entry_bits = 58 + 512 + 1;
        let src_buf_bits = cfg.ccache.source_buffer_entries as u64 * sb_entry_bits;

        // MFRF: 64-bit function pointers
        let mfrf_bits = cfg.ccache.mfrf_slots as u64 * 64;

        // merge registers: src, upd, mem — 64 B each
        let merge_reg_bits = 3 * 512;

        // LLC: data + ~(tag 40b + state 8b) per line
        let llc_lines = (cfg.llc().size_bytes / 64) as u64;
        let llc_bits = llc_lines * (512 + 48);

        Self {
            l1_extra_bits_per_line,
            l1_extra_bits: l1_lines * l1_extra_bits_per_line as u64,
            src_buf_bits,
            mfrf_bits,
            merge_reg_bits,
            llc_bits,
        }
    }

    /// Source-buffer bit count as a fraction of LLC bits — the structural
    /// analogue of the paper's 0.1% CACTI area figure (SRAM area scales
    /// roughly with bit count at matched geometry).
    pub fn src_buf_frac_of_llc(&self) -> f64 {
        self.src_buf_bits as f64 / self.llc_bits as f64
    }

    /// Total extra state per core in bytes (context-switch cost bound,
    /// Section 4.6: at most ~1 KB with an 8-way L1 and 8-entry buffer).
    pub fn per_core_saved_state_bytes(&self, cfg: &MachineConfig) -> u64 {
        // CData lines in L1 (bounded by ways * sets, practically by the
        // source buffer) + source buffer entries, 64 B each
        (cfg.ccache.source_buffer_entries as u64) * 64 * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_machine_matches_paper_scale() {
        let cfg = MachineConfig::default();
        let m = OverheadModel::for_config(&cfg);
        // 4 MFRF slots -> 2 merge-type bits -> 4 extra bits/line
        assert_eq!(m.l1_extra_bits_per_line, 4);
        // the paper: tiny source buffer vs LLC — structurally well under 1%
        assert!(m.src_buf_frac_of_llc() < 0.01, "{}", m.src_buf_frac_of_llc());
        // paper's 32-entry example stays ~0.1% of LLC
        let mut cfg32 = cfg;
        cfg32.ccache.source_buffer_entries = 32;
        let m32 = OverheadModel::for_config(&cfg32);
        assert!(
            (m32.src_buf_frac_of_llc() - PAPER_SRC_BUF_AREA_FRAC_OF_LLC).abs() < 0.001,
            "{}",
            m32.src_buf_frac_of_llc()
        );
    }

    #[test]
    fn context_switch_state_under_1kb() {
        let cfg = MachineConfig::default();
        let m = OverheadModel::for_config(&cfg);
        assert!(m.per_core_saved_state_bytes(&cfg) <= 1024);
    }
}
