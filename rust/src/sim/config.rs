//! Machine configuration — a declarative hierarchy description plus the
//! CCache knobs. Defaults reproduce the paper's Table 2.
//!
//! The hierarchy is data: [`MachineConfig::levels`] lists every cache
//! level innermost-first (the last entry is the single shared level the
//! directory lives at), so topology ablations — a 2-level embedded
//! shape, a half-size LLC, deeper stacks — are config rows, not forks of
//! the protocol engine. [`MachineConfig::validate`] returns a typed
//! [`ConfigError`] the execution layer surfaces as a CLI diagnostic.

use std::fmt;

use super::hierarchy::level::{LevelConfig, PartitionPolicy};
use super::hierarchy::protocol::ProtocolKind;
use super::hierarchy::timing::Timing;

/// Why a machine configuration is illegal. Produced by
/// [`MachineConfig::validate`] and propagated through the execution
/// layer as [`ExecError::InvalidConfig`](crate::exec::ExecError).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// One level's geometry is broken (size/ways/sets).
    Level { level: String, reason: String },
    /// The level stack itself is malformed.
    Hierarchy { reason: String },
    /// A merge-region way partition is misplaced or mis-sized.
    Partition { level: String, reason: String },
    Cores { cores: usize },
    MfrfSlots { slots: usize },
    MemBytes { bytes: usize },
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Level { level, reason } => {
                write!(f, "invalid machine config: {level}: {reason}")
            }
            ConfigError::Hierarchy { reason } => {
                write!(f, "invalid machine config: hierarchy: {reason}")
            }
            ConfigError::Partition { level, reason } => {
                write!(f, "invalid machine config: {level} partition: {reason}")
            }
            ConfigError::Cores { cores } => {
                write!(f, "invalid machine config: cores must be in 1..=64, got {cores}")
            }
            ConfigError::MfrfSlots { slots } => {
                write!(f, "invalid machine config: mfrf_slots must be in 1..=16, got {slots}")
            }
            ConfigError::MemBytes { bytes } => {
                write!(f, "invalid machine config: mem_bytes must be line-aligned, got {bytes}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

/// CCache-specific knobs (Section 4 + the Section 4.3 optimizations).
#[derive(Clone, Copy, Debug)]
pub struct CCacheConfig {
    /// Source buffer entries per core (Table 2: 8 lines = 512 B).
    pub source_buffer_entries: usize,
    /// Source buffer hit latency (Table 2: 3 cycles).
    pub source_buffer_hit_cycles: u64,
    /// Merge latency per line including the LLC round trip (Table 2: 170).
    /// Charged synchronously by the explicit `merge` instruction.
    pub merge_latency: u64,
    /// Eviction-triggered (merge-on-evict) merges run in a background
    /// merge engine — victim-buffer semantics ("delays the merge and
    /// write back for as long as possible", Section 4.3). The engine is
    /// pipelined; one merge occupies it for this many cycles (LLC-port
    /// bound: one round trip).
    pub merge_engine_interval: u64,
    /// Pending-merge queue depth; the core stalls when the engine backs
    /// up beyond this many in-flight merges.
    pub merge_engine_queue: u64,
    /// MFRF slots (Section 4.2: four entries / two merge-type bits).
    pub mfrf_slots: usize,
    /// merge-on-evict: soft_merge defers merging to eviction (Section 4.3).
    /// When disabled, soft_merge behaves like a full merge.
    pub merge_on_evict: bool,
    /// dirty-merge: silently drop clean mergeable lines (Section 4.3).
    pub dirty_merge: bool,
}

impl Default for CCacheConfig {
    fn default() -> Self {
        Self {
            source_buffer_entries: 8,
            source_buffer_hit_cycles: 3,
            merge_latency: 170,
            merge_engine_interval: 70,
            merge_engine_queue: 4,
            mfrf_slots: 4,
            merge_on_evict: true,
            dirty_merge: true,
        }
    }
}

/// Whole-machine parameters. The default is the paper's Table 2
/// machine: 8 cores, L1 32 KiB/8w/4cyc + L2 512 KiB/8w/10cyc private,
/// LLC 4 MiB/16w/70cyc shared, 300-cycle memory.
#[derive(Clone, Debug)]
pub struct MachineConfig {
    pub cores: usize,
    /// The hierarchy, innermost (L1) first. Every level but the last is
    /// private (one cache per core); the last is the single shared level
    /// the directory is co-located with.
    pub levels: Vec<LevelConfig>,
    /// Machine-wide timing (memory latency, interleaver quantum, lock
    /// backoff).
    pub timing: Timing,
    pub ccache: CCacheConfig,
    /// The coherence protocol the hierarchy walk runs
    /// ([`ProtocolKind::Mesi`] reproduces the paper's machine; see
    /// [`protocol`](super::hierarchy::protocol) for Dragon and partial
    /// coherence). Variant support is protocol-dependent — the driver
    /// rejects combinations the protocol cannot run (e.g. atomics under
    /// partial coherence).
    pub protocol: ProtocolKind,
    /// Functional memory size in bytes.
    pub mem_bytes: usize,
    /// Take the engine's branch-light fast path for coherent L1 read
    /// hits and private-hit COps (default). The fast path is an exact
    /// shortcut — stats and memory stay bit-identical to the full walk
    /// (the differential suite in `tests/fastpath_diff.rs` proves it);
    /// disabling it exists for that differential testing, not as a
    /// semantic knob.
    pub fast_path: bool,
}

impl Default for MachineConfig {
    fn default() -> Self {
        Self {
            cores: 8,
            levels: vec![
                LevelConfig::new(32 << 10, 8, 4, false),
                LevelConfig::new(512 << 10, 8, 10, false),
                LevelConfig::new(4 << 20, 16, 70, true),
            ],
            timing: Timing::table2(),
            ccache: CCacheConfig::default(),
            protocol: ProtocolKind::Mesi,
            mem_bytes: 256 << 20,
            fast_path: true,
        }
    }
}

impl MachineConfig {
    // ---- hierarchy accessors -----------------------------------------

    /// Number of cache levels (private levels + the shared level).
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    pub fn level(&self, i: usize) -> &LevelConfig {
        &self.levels[i]
    }

    pub fn level_mut(&mut self, i: usize) -> &mut LevelConfig {
        &mut self.levels[i]
    }

    /// The innermost private level.
    pub fn l1(&self) -> &LevelConfig {
        &self.levels[0]
    }

    pub fn l1_mut(&mut self) -> &mut LevelConfig {
        &mut self.levels[0]
    }

    /// The shared last level.
    pub fn llc(&self) -> &LevelConfig {
        self.levels.last().expect("hierarchy has levels")
    }

    pub fn llc_mut(&mut self) -> &mut LevelConfig {
        self.levels.last_mut().expect("hierarchy has levels")
    }

    /// Display name of level `i`: "L1", "L2", ..., "LLC" for the last.
    pub fn level_name(&self, i: usize) -> String {
        if i + 1 == self.levels.len() {
            "LLC".to_string()
        } else {
            format!("L{}", i + 1)
        }
    }

    /// One-line human summary ("8 cores, L1 32 KiB + L2 512 KiB + LLC
    /// 4096 KiB (shared)").
    pub fn describe(&self) -> String {
        let levels = self
            .levels
            .iter()
            .enumerate()
            .map(|(i, lv)| {
                format!(
                    "{} {} KiB{}",
                    self.level_name(i),
                    lv.size_bytes >> 10,
                    if lv.shared { " (shared)" } else { "" }
                )
            })
            .collect::<Vec<_>>()
            .join(" + ");
        let proto = if self.protocol == ProtocolKind::Mesi {
            String::new() // the default machine; keep the familiar banner
        } else {
            format!(", {} protocol", self.protocol.name())
        };
        format!("{} cores, {}{}", self.cores, levels, proto)
    }

    // ---- builders ----------------------------------------------------

    /// The paper's Fig 7 configuration: CCache runs with a resized LLC.
    pub fn with_llc_bytes(mut self, bytes: usize) -> Self {
        self.llc_mut().size_bytes = bytes;
        self
    }

    pub fn with_cores(mut self, cores: usize) -> Self {
        self.cores = cores;
        self
    }

    /// Select the coherence protocol (`--protocol` on the CLI).
    pub fn with_protocol(mut self, protocol: ProtocolKind) -> Self {
        self.protocol = protocol;
        self
    }

    /// Reserve `ccache_ways` of the shared level's ways for merge-region
    /// lines under `policy` (`ccache_ways == 0` clears the partition).
    pub fn with_partition(mut self, ccache_ways: usize, policy: PartitionPolicy) -> Self {
        self.llc_mut().partition = if ccache_ways == 0 {
            None
        } else {
            Some(crate::sim::hierarchy::level::WayPartition::new(
                ccache_ways,
                policy,
            ))
        };
        self
    }

    /// Reshape the hierarchy to `depth` levels, keeping the current
    /// innermost and shared levels:
    /// * 2 — L1 + shared LLC (embedded shape)
    /// * 3 — L1 + L2 + LLC (the Table 2 shape); a missing L2 is
    ///   synthesized at LLC/8 capacity, 8 ways, 10 cycles
    /// * 4 — additionally inserts an L3 at LLC/2 capacity, LLC
    ///   associativity, 40 cycles
    pub fn with_depth(mut self, depth: usize) -> Result<Self, ConfigError> {
        if !(2..=4).contains(&depth) {
            return Err(ConfigError::Hierarchy {
                reason: format!("supported depths are 2..=4, got {depth}"),
            });
        }
        let first = self.levels[0];
        let last = *self.llc();
        let mid = if self.levels.len() >= 3 {
            self.levels[1]
        } else {
            LevelConfig::new(last.size_bytes / 8, 8, 10, false)
        };
        self.levels = match depth {
            2 => vec![first, last],
            3 => vec![first, mid, last],
            _ => vec![
                first,
                mid,
                LevelConfig::new(last.size_bytes / 2, last.ways, 40, false),
                last,
            ],
        };
        Ok(self)
    }

    /// Small machine for fast unit tests (geometry shrunk, same 3-level
    /// shape).
    pub fn test_small() -> Self {
        let mut cfg = Self::default();
        cfg.cores = 2;
        cfg.levels = vec![
            LevelConfig::new(1 << 10, 4, 4, false),
            LevelConfig::new(4 << 10, 4, 10, false),
            LevelConfig::new(16 << 10, 8, 70, true),
        ];
        cfg.mem_bytes = 8 << 20;
        cfg.timing.quantum = 0;
        cfg
    }

    /// Small 2-level machine (L1 + shared LLC) for shape-sensitivity
    /// tests.
    pub fn test_small_2level() -> Self {
        let mut cfg = Self::test_small();
        cfg.levels = vec![
            LevelConfig::new(1 << 10, 4, 4, false),
            LevelConfig::new(16 << 10, 8, 70, true),
        ];
        cfg
    }

    // ---- validation --------------------------------------------------

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.levels.len() < 2 {
            return Err(ConfigError::Hierarchy {
                reason: format!(
                    "need at least a private L1 and a shared last level, got {} level(s)",
                    self.levels.len()
                ),
            });
        }
        for (i, lv) in self.levels.iter().enumerate() {
            let name = self.level_name(i);
            lv.validate(&name)?;
            let is_last = i + 1 == self.levels.len();
            if lv.partition.is_some() && !is_last {
                return Err(ConfigError::Partition {
                    level: name.clone(),
                    reason: "way partitioning applies to the shared level only".to_string(),
                });
            }
            if lv.shared != is_last {
                return Err(ConfigError::Hierarchy {
                    reason: if is_last {
                        format!("the last level ({name}) must be shared")
                    } else {
                        format!("{name} is shared but only the last level may be")
                    },
                });
            }
        }
        if self.cores == 0 || self.cores > 64 {
            return Err(ConfigError::Cores { cores: self.cores });
        }
        if self.ccache.mfrf_slots == 0 || self.ccache.mfrf_slots > 16 {
            return Err(ConfigError::MfrfSlots {
                slots: self.ccache.mfrf_slots,
            });
        }
        if self.mem_bytes % 64 != 0 {
            return Err(ConfigError::MemBytes {
                bytes: self.mem_bytes,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_defaults() {
        let cfg = MachineConfig::default();
        assert_eq!(cfg.cores, 8);
        assert_eq!(cfg.depth(), 3);
        assert_eq!(cfg.l1().sets(), 64); // 32KB / (64B * 8)
        assert_eq!(cfg.level(1).sets(), 1024);
        assert_eq!(cfg.llc().sets(), 4096); // 4MB / (64B * 16)
        assert_eq!(cfg.l1().hit_cycles, 4);
        assert_eq!(cfg.level(1).hit_cycles, 10);
        assert_eq!(cfg.llc().hit_cycles, 70);
        assert_eq!(cfg.timing.mem_cycles, 300);
        assert_eq!(cfg.ccache.source_buffer_entries, 8);
        assert_eq!(cfg.ccache.merge_latency, 170);
        assert_eq!(cfg.protocol, ProtocolKind::Mesi);
        assert!(cfg.llc().shared && !cfg.l1().shared);
        cfg.validate().unwrap();
    }

    #[test]
    fn with_protocol_selects_and_describes() {
        let cfg = MachineConfig::test_small().with_protocol(ProtocolKind::Dragon);
        assert_eq!(cfg.protocol, ProtocolKind::Dragon);
        cfg.validate().unwrap();
        assert!(cfg.describe().contains("dragon protocol"), "{}", cfg.describe());
        // the default MESI machine keeps its familiar banner
        assert!(!MachineConfig::default().describe().contains("protocol"));
    }

    #[test]
    fn half_llc_for_fig7() {
        let cfg = MachineConfig::default().with_llc_bytes(2 << 20);
        assert_eq!(cfg.llc().sets(), 2048);
        cfg.validate().unwrap();
    }

    #[test]
    fn fig7_style_shrinks_must_revalidate_geometry() {
        // Halving a power-of-two LLC is always legal...
        MachineConfig::default()
            .with_llc_bytes((4 << 20) / 2)
            .validate()
            .unwrap();
        // ...but a blind `size_bytes / 2` on an arbitrary base config is
        // not: a 192 KiB LLC halves to 96 KiB = 96 sets at 16 ways —
        // not a power of two. The halved config must go through
        // validate(), which rejects it instead of mis-indexing sets.
        let odd = MachineConfig::default().with_llc_bytes(192 << 10);
        assert!(odd.validate().is_err(), "base 192 KiB already invalid");
        let halved = MachineConfig::default().with_llc_bytes((192 << 10) / 2);
        assert!(matches!(
            halved.validate(),
            Err(ConfigError::Level { .. })
        ));
        // And a shrink below ways*64 bytes violates associativity: a
        // 16-way LLC needs at least 1 KiB (one set).
        let tiny = MachineConfig::default().with_llc_bytes(512);
        assert!(matches!(tiny.validate(), Err(ConfigError::Level { .. })));
    }

    #[test]
    fn partition_must_sit_on_the_shared_level() {
        use crate::sim::hierarchy::level::WayPartition;
        // legal: shared-level partition within associativity
        let cfg = MachineConfig::default().with_partition(4, PartitionPolicy::ReuseAware);
        cfg.validate().unwrap();
        assert_eq!(
            cfg.llc().partition,
            Some(WayPartition::new(4, PartitionPolicy::ReuseAware))
        );
        // ccache_ways == 0 clears rather than configures
        let cfg = cfg.with_partition(0, PartitionPolicy::Static);
        assert_eq!(cfg.llc().partition, None);
        cfg.validate().unwrap();
        // a partition on a private level is rejected with a typed error
        let mut cfg = MachineConfig::default();
        cfg.level_mut(1).partition = Some(WayPartition::new(2, PartitionPolicy::Static));
        let err = cfg.validate().unwrap_err();
        assert!(matches!(err, ConfigError::Partition { .. }), "{err:?}");
        assert!(err.to_string().contains("shared level only"), "{err}");
        // and one wider than the associativity is rejected per-level
        let cfg = MachineConfig::default().with_partition(16, PartitionPolicy::Static);
        assert!(matches!(
            cfg.validate(),
            Err(ConfigError::Partition { .. })
        ));
    }

    #[test]
    fn invalid_geometry_rejected() {
        let mut cfg = MachineConfig::default();
        cfg.l1_mut().size_bytes = 1000; // not divisible
        assert!(matches!(cfg.validate(), Err(ConfigError::Level { .. })));
    }

    #[test]
    fn shared_level_must_be_last_and_only_last() {
        let mut cfg = MachineConfig::default();
        cfg.level_mut(1).shared = true;
        assert!(matches!(cfg.validate(), Err(ConfigError::Hierarchy { .. })));
        let mut cfg = MachineConfig::default();
        cfg.llc_mut().shared = false;
        assert!(matches!(cfg.validate(), Err(ConfigError::Hierarchy { .. })));
    }

    #[test]
    fn test_small_shapes_are_valid() {
        MachineConfig::test_small().validate().unwrap();
        let two = MachineConfig::test_small_2level();
        assert_eq!(two.depth(), 2);
        two.validate().unwrap();
    }

    #[test]
    fn with_depth_reshapes_and_validates() {
        let two = MachineConfig::default().with_depth(2).unwrap();
        assert_eq!(two.depth(), 2);
        assert_eq!(two.l1().size_bytes, 32 << 10);
        assert_eq!(two.llc().size_bytes, 4 << 20);
        two.validate().unwrap();

        let three = two.clone().with_depth(3).unwrap();
        assert_eq!(three.depth(), 3);
        assert_eq!(three.level(1).size_bytes, (4 << 20) / 8); // synthesized L2
        three.validate().unwrap();

        let four = MachineConfig::default().with_depth(4).unwrap();
        assert_eq!(four.depth(), 4);
        assert_eq!(four.level(2).size_bytes, 2 << 20);
        four.validate().unwrap();

        assert!(MachineConfig::default().with_depth(1).is_err());
        assert!(MachineConfig::default().with_depth(5).is_err());
    }

    #[test]
    fn errors_render_actionable_messages() {
        let mut cfg = MachineConfig::default();
        cfg.llc_mut().size_bytes = 3 << 10;
        let msg = cfg.validate().unwrap_err().to_string();
        assert!(msg.contains("LLC"), "{msg}");
        let msg = ConfigError::Cores { cores: 99 }.to_string();
        assert!(msg.contains("99"), "{msg}");
    }

    #[test]
    fn describe_names_every_level() {
        let s = MachineConfig::default().describe();
        assert!(s.contains("L1 32 KiB"), "{s}");
        assert!(s.contains("L2 512 KiB"), "{s}");
        assert!(s.contains("LLC 4096 KiB (shared)"), "{s}");
    }
}
