//! Machine configuration — defaults reproduce the paper's Table 2.

/// Cache geometry + latency for one level.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub ways: usize,
    pub hit_cycles: u64,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        self.size_bytes / (64 * self.ways)
    }
}

/// CCache-specific knobs (Section 4 + the Section 4.3 optimizations).
#[derive(Clone, Copy, Debug)]
pub struct CCacheConfig {
    /// Source buffer entries per core (Table 2: 8 lines = 512 B).
    pub source_buffer_entries: usize,
    /// Source buffer hit latency (Table 2: 3 cycles).
    pub source_buffer_hit_cycles: u64,
    /// Merge latency per line including the LLC round trip (Table 2: 170).
    /// Charged synchronously by the explicit `merge` instruction.
    pub merge_latency: u64,
    /// Eviction-triggered (merge-on-evict) merges run in a background
    /// merge engine — victim-buffer semantics ("delays the merge and
    /// write back for as long as possible", Section 4.3). The engine is
    /// pipelined; one merge occupies it for this many cycles (LLC-port
    /// bound: one round trip).
    pub merge_engine_interval: u64,
    /// Pending-merge queue depth; the core stalls when the engine backs
    /// up beyond this many in-flight merges.
    pub merge_engine_queue: u64,
    /// MFRF slots (Section 4.2: four entries / two merge-type bits).
    pub mfrf_slots: usize,
    /// merge-on-evict: soft_merge defers merging to eviction (Section 4.3).
    /// When disabled, soft_merge behaves like a full merge.
    pub merge_on_evict: bool,
    /// dirty-merge: silently drop clean mergeable lines (Section 4.3).
    pub dirty_merge: bool,
}

impl Default for CCacheConfig {
    fn default() -> Self {
        Self {
            source_buffer_entries: 8,
            source_buffer_hit_cycles: 3,
            merge_latency: 170,
            merge_engine_interval: 70,
            merge_engine_queue: 4,
            mfrf_slots: 4,
            merge_on_evict: true,
            dirty_merge: true,
        }
    }
}

/// Whole-machine parameters (Table 2 defaults).
#[derive(Clone, Copy, Debug)]
pub struct MachineConfig {
    pub cores: usize,
    pub l1: CacheConfig,
    pub l2: CacheConfig,
    pub llc: CacheConfig,
    pub mem_cycles: u64,
    pub ccache: CCacheConfig,
    /// Deterministic interleave quantum in cycles: a core keeps its turn
    /// until its clock exceeds the laggard's by this much. 0 = strict
    /// laggard-first per operation.
    pub quantum: u64,
    /// Cycles charged per failed lock-acquire attempt before retrying
    /// (spin backoff).
    pub lock_backoff: u64,
    /// Functional memory size in bytes.
    pub mem_bytes: usize,
}

impl Default for MachineConfig {
    fn default() -> Self {
        Self {
            cores: 8,
            l1: CacheConfig {
                size_bytes: 32 << 10,
                ways: 8,
                hit_cycles: 4,
            },
            l2: CacheConfig {
                size_bytes: 512 << 10,
                ways: 8,
                hit_cycles: 10,
            },
            llc: CacheConfig {
                size_bytes: 4 << 20,
                ways: 16,
                hit_cycles: 70,
            },
            mem_cycles: 300,
            ccache: CCacheConfig::default(),
            quantum: 256,
            lock_backoff: 40,
            mem_bytes: 256 << 20,
        }
    }
}

impl MachineConfig {
    /// The paper's Fig 7 configuration: CCache runs with half the LLC.
    pub fn with_llc_bytes(mut self, bytes: usize) -> Self {
        self.llc.size_bytes = bytes;
        self
    }

    pub fn with_cores(mut self, cores: usize) -> Self {
        self.cores = cores;
        self
    }

    /// Small machine for fast unit tests (geometry shrunk, same shape).
    pub fn test_small() -> Self {
        let mut cfg = Self::default();
        cfg.cores = 2;
        cfg.l1 = CacheConfig {
            size_bytes: 1 << 10,
            ways: 4,
            hit_cycles: 4,
        };
        cfg.l2 = CacheConfig {
            size_bytes: 4 << 10,
            ways: 4,
            hit_cycles: 10,
        };
        cfg.llc = CacheConfig {
            size_bytes: 16 << 10,
            ways: 8,
            hit_cycles: 70,
        };
        cfg.mem_bytes = 8 << 20;
        cfg.quantum = 0;
        cfg
    }

    pub fn validate(&self) -> Result<(), String> {
        for (name, c) in [("l1", &self.l1), ("l2", &self.l2), ("llc", &self.llc)] {
            if c.size_bytes % (64 * c.ways) != 0 {
                return Err(format!("{name}: size not divisible by ways*64"));
            }
            if !c.sets().is_power_of_two() {
                return Err(format!("{name}: sets ({}) not a power of two", c.sets()));
            }
        }
        if self.cores == 0 || self.cores > 64 {
            return Err("cores must be in 1..=64".into());
        }
        if self.ccache.mfrf_slots == 0 || self.ccache.mfrf_slots > 16 {
            return Err("mfrf_slots must be in 1..=16".into());
        }
        if self.mem_bytes % 64 != 0 {
            return Err("mem_bytes must be line-aligned".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_defaults() {
        let cfg = MachineConfig::default();
        assert_eq!(cfg.cores, 8);
        assert_eq!(cfg.l1.sets(), 64); // 32KB / (64B * 8)
        assert_eq!(cfg.l2.sets(), 1024);
        assert_eq!(cfg.llc.sets(), 4096); // 4MB / (64B * 16)
        assert_eq!(cfg.l1.hit_cycles, 4);
        assert_eq!(cfg.l2.hit_cycles, 10);
        assert_eq!(cfg.llc.hit_cycles, 70);
        assert_eq!(cfg.mem_cycles, 300);
        assert_eq!(cfg.ccache.source_buffer_entries, 8);
        assert_eq!(cfg.ccache.merge_latency, 170);
        cfg.validate().unwrap();
    }

    #[test]
    fn half_llc_for_fig7() {
        let cfg = MachineConfig::default().with_llc_bytes(2 << 20);
        assert_eq!(cfg.llc.sets(), 2048);
        cfg.validate().unwrap();
    }

    #[test]
    fn invalid_geometry_rejected() {
        let mut cfg = MachineConfig::default();
        cfg.l1.size_bytes = 1000; // not divisible
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn test_small_is_valid() {
        MachineConfig::test_small().validate().unwrap();
    }
}
