//! Machine-wide timing parameters, replacing the Table 2 constants that
//! used to be hard-coded across the protocol engine.
//!
//! Per-level hit latencies live with the hierarchy description
//! ([`LevelConfig::hit_cycles`](super::level::LevelConfig)); this struct
//! holds everything that is not a property of one cache level. The
//! defaults reproduce the paper's Table 2:
//!
//! | quantity           | Table 2 | field          |
//! |--------------------|---------|----------------|
//! | L1 hit             | 4 cyc   | `levels[0].hit_cycles` |
//! | L2 hit             | 10 cyc  | `levels[1].hit_cycles` |
//! | LLC hit            | 70 cyc  | `levels[last].hit_cycles` |
//! | memory             | 300 cyc | [`Timing::mem_cycles`] |
//!
//! `quantum` and `lock_backoff` are simulator knobs (deterministic
//! interleaver granularity and spin-retry interval), not paper
//! constants; their defaults match the seed configuration.
//! `update_cycles` prices one write-update message for the Dragon
//! protocol ([`protocol`](super::protocol)) — the paper's machine is
//! invalidate-based, so this too is a modeling constant.

/// Whole-machine timing knobs (everything not per-level).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Timing {
    /// Main-memory access latency beyond the shared level (Table 2: 300).
    pub mem_cycles: u64,
    /// Deterministic interleave quantum in cycles: a core keeps its turn
    /// until its clock exceeds the laggard's by this much. 0 = strict
    /// laggard-first per operation.
    pub quantum: u64,
    /// Cycles charged per failed lock-acquire attempt before retrying
    /// (spin backoff).
    pub lock_backoff: u64,
    /// Cycles charged per update message a write-update protocol
    /// (Dragon) sends to one sharer. A modeling constant, not a Table 2
    /// value: an update carries one word point-to-point, cheaper than a
    /// full line transfer but not free.
    pub update_cycles: u64,
}

impl Timing {
    /// The paper's Table 2 memory latency with the seed's interleaver
    /// settings.
    pub const fn table2() -> Self {
        Self {
            mem_cycles: 300,
            quantum: 256,
            lock_backoff: 40,
            update_cycles: 10,
        }
    }
}

impl Default for Timing {
    fn default() -> Self {
        Self::table2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table2() {
        let t = Timing::default();
        assert_eq!(t.mem_cycles, 300);
        assert_eq!(t.quantum, 256);
        assert_eq!(t.lock_backoff, 40);
        assert_eq!(t.update_cycles, 10);
        assert_eq!(t, Timing::table2());
    }
}
