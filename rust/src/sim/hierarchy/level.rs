//! One level of the cache hierarchy: geometry + hit latency as data
//! ([`LevelConfig`]) and the instantiated flat tag/metadata arrays
//! ([`Level`] wrapping the struct-of-arrays [`Cache`]).
//!
//! A level is either *private* (one [`Cache`] per core — L1, L2, ...)
//! or *shared* (a single cache all cores reach — the LLC). The
//! [`AccessPath`](super::path::AccessPath) composes a stack of these;
//! nothing in the protocol engine hard-codes how many there are.

use crate::sim::cache::Cache;
use crate::sim::config::ConfigError;

/// How the merge-region way partition is sized over a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionPolicy {
    /// The configured `ccache_ways` split is fixed for the whole run.
    Static,
    /// An epoch-based controller in the memory system grows/shrinks the
    /// merge partition one way at a time from the observed CData reuse
    /// ratio (`ccache_l1_hits` vs `ccache_fills`); `ccache_ways` is the
    /// initial split.
    ReuseAware,
}

impl PartitionPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            PartitionPolicy::Static => "static",
            PartitionPolicy::ReuseAware => "reuse",
        }
    }
}

/// Way-partitioning of the shared level between CData (merge-region)
/// lines and ordinary coherent data. Replacement-only: lookups still
/// hit across the whole set, but CData installs pick victims inside the
/// low `ccache_ways` way positions and coherent installs pick victims
/// outside them, so a streaming co-runner cannot evict the merge
/// region's LLC footprint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WayPartition {
    /// Ways reserved for merge-region (CData) lines; the remaining
    /// `ways - ccache_ways` hold ordinary coherent data. Must satisfy
    /// `1 <= ccache_ways < ways` (validated by the machine config).
    pub ccache_ways: usize,
    pub policy: PartitionPolicy,
}

impl WayPartition {
    pub const fn new(ccache_ways: usize, policy: PartitionPolicy) -> Self {
        Self {
            ccache_ways,
            policy,
        }
    }
}

/// Declarative description of one hierarchy level (the rows of a
/// Table 2-style machine spec). Part of
/// [`MachineConfig::levels`](crate::sim::config::MachineConfig::levels).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LevelConfig {
    pub size_bytes: usize,
    pub ways: usize,
    /// Cycles charged for reaching (and hitting in) this level.
    pub hit_cycles: u64,
    /// Shared by all cores (one cache) vs private (one cache per core).
    /// Exactly the last level of a hierarchy is shared; the directory
    /// lives there.
    pub shared: bool,
    /// Optional merge-region way partition. Only legal on the shared
    /// level (validated by the machine config); `None` keeps the
    /// unpartitioned replacement behavior bit-identical to before.
    pub partition: Option<WayPartition>,
}

impl LevelConfig {
    pub const fn new(size_bytes: usize, ways: usize, hit_cycles: u64, shared: bool) -> Self {
        Self {
            size_bytes,
            ways,
            hit_cycles,
            shared,
            partition: None,
        }
    }

    /// Builder: reserve `ccache_ways` of this level's ways for
    /// merge-region lines under `policy`.
    pub fn with_partition(mut self, ccache_ways: usize, policy: PartitionPolicy) -> Self {
        self.partition = Some(WayPartition::new(ccache_ways, policy));
        self
    }

    pub fn sets(&self) -> usize {
        self.size_bytes / (64 * self.ways)
    }

    /// Geometry legality for one level; `name` labels the diagnostic
    /// ("L1", "LLC", ...).
    pub fn validate(&self, name: &str) -> Result<(), ConfigError> {
        if self.ways == 0 {
            return Err(ConfigError::Level {
                level: name.to_string(),
                reason: "ways must be >= 1".to_string(),
            });
        }
        if self.size_bytes == 0 || self.size_bytes % (64 * self.ways) != 0 {
            return Err(ConfigError::Level {
                level: name.to_string(),
                reason: format!(
                    "size ({} B) not divisible by ways*64 ({} B)",
                    self.size_bytes,
                    64 * self.ways
                ),
            });
        }
        if !self.sets().is_power_of_two() {
            return Err(ConfigError::Level {
                level: name.to_string(),
                reason: format!("sets ({}) not a power of two", self.sets()),
            });
        }
        if let Some(p) = self.partition {
            if p.ccache_ways == 0 || p.ccache_ways >= self.ways {
                return Err(ConfigError::Partition {
                    level: name.to_string(),
                    reason: format!(
                        "ccache_ways must be in 1..{} (ways), got {}",
                        self.ways, p.ccache_ways
                    ),
                });
            }
        }
        Ok(())
    }
}

/// An instantiated hierarchy level: the tag arrays behind one
/// [`LevelConfig`].
pub struct Level {
    pub cfg: LevelConfig,
    caches: Vec<Cache>,
}

impl Level {
    pub fn new(cfg: LevelConfig, cores: usize) -> Self {
        let n = if cfg.shared { 1 } else { cores };
        Self {
            caches: (0..n)
                .map(|_| Cache::new(cfg.sets(), cfg.ways))
                .collect(),
            cfg,
        }
    }

    /// The cache `core` reaches at this level (the single shared cache
    /// regardless of `core` when the level is shared).
    #[inline]
    pub fn cache(&self, core: usize) -> &Cache {
        let i = if self.cfg.shared { 0 } else { core };
        &self.caches[i]
    }

    #[inline]
    pub fn cache_mut(&mut self, core: usize) -> &mut Cache {
        let i = if self.cfg.shared { 0 } else { core };
        &mut self.caches[i]
    }

    pub fn is_shared(&self) -> bool {
        self.cfg.shared
    }

    pub fn hit_cycles(&self) -> u64 {
        self.cfg.hit_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::addr::Line;

    #[test]
    fn private_level_has_one_cache_per_core() {
        let lc = LevelConfig::new(1 << 10, 4, 4, false);
        let mut lv = Level::new(lc, 3);
        // distinct caches: filling core 0 leaves core 2 empty
        let way = match lv.cache(0).choose_victim(Line(1)) {
            crate::sim::cache::Victim::Free { way } => way,
            v => panic!("{v:?}"),
        };
        lv.cache_mut(0).install(way, Line(1));
        assert!(lv.cache_mut(0).lookup(Line(1)).is_some());
        assert!(lv.cache_mut(2).lookup(Line(1)).is_none());
    }

    #[test]
    fn shared_level_is_one_cache_for_all_cores() {
        let lc = LevelConfig::new(1 << 10, 4, 70, true);
        let mut lv = Level::new(lc, 4);
        let way = match lv.cache(1).choose_victim(Line(9)) {
            crate::sim::cache::Victim::Free { way } => way,
            v => panic!("{v:?}"),
        };
        lv.cache_mut(1).install(way, Line(9));
        assert!(lv.cache_mut(3).lookup(Line(9)).is_some());
    }

    #[test]
    fn geometry_validation() {
        assert!(LevelConfig::new(32 << 10, 8, 4, false).validate("l1").is_ok());
        assert!(LevelConfig::new(1000, 8, 4, false).validate("l1").is_err());
        assert!(LevelConfig::new(3 * 64 * 8, 8, 4, false).validate("l1").is_err()); // 3 sets
        assert!(LevelConfig::new(0, 8, 4, false).validate("l1").is_err());
    }

    #[test]
    fn partition_ways_must_leave_room_for_both_classes() {
        let llc = LevelConfig::new(16 << 10, 8, 70, true);
        // legal splits: 1..=7 of 8 ways
        for w in 1..8 {
            llc.with_partition(w, PartitionPolicy::Static)
                .validate("llc")
                .unwrap();
        }
        // zero ways would starve CData installs; all ways would starve
        // coherent installs — both rejected with a typed Partition error
        for w in [0, 8, 9] {
            let err = llc
                .with_partition(w, PartitionPolicy::ReuseAware)
                .validate("llc")
                .unwrap_err();
            assert!(
                matches!(err, ConfigError::Partition { .. }),
                "ccache_ways={w}: {err:?}"
            );
        }
    }

    #[test]
    fn policy_names_are_stable_cli_tokens() {
        assert_eq!(PartitionPolicy::Static.name(), "static");
        assert_eq!(PartitionPolicy::ReuseAware.name(), "reuse");
    }
}
