//! [`AccessPath`]: the walk of a coherent access through an arbitrary
//! stack of private levels and one shared level, plus the structural
//! operations (fills, evictions, invalidations, inclusive recalls) the
//! protocol engine composes.
//!
//! The path owns the instantiated [`Level`]s, the [`Directory`]
//! (co-located with the shared level) and the active
//! [`CoherenceProtocol`](super::protocol::CoherenceProtocol). It is
//! shape- *and protocol*-agnostic: the same walk serves the paper's
//! 3-level machine, a 2-level embedded shape, or deeper hierarchies —
//! the stack is data from
//! [`MachineConfig::levels`](crate::sim::config::MachineConfig::levels)
//! — and every directory transaction (who to invalidate, who to update,
//! what a fill may own) is delegated to the protocol picked by
//! [`MachineConfig::protocol`](crate::sim::config::MachineConfig::protocol).
//! The walk's own job is timing and cache structure: latencies, fills,
//! inclusion bookkeeping, and applying whatever
//! [`CoherenceActions`] the protocol hands back.
//!
//! Division of labour with [`MemSystem`](crate::sim::memsys::MemSystem):
//! the path performs every structural step of an access *except*
//! executing CData merges — when a fill must displace a mergeable CData
//! line, the path hands the victim line back (`Err(line)`) and the
//! engine merges it (source buffer, MFRF and merge functions live
//! there), then retries. Inclusion invariants maintained here:
//! every line in private level `i` is present in level `i+1` (CData
//! excepted — it exists only innermost), and the shared level is
//! inclusive of all private levels.

use crate::sim::addr::Line;
use crate::sim::cache::{Cache, LineMeta, Victim};
use crate::sim::config::MachineConfig;
use crate::sim::directory::{CoherenceActions, Directory};
use crate::sim::invariant::InvariantViolation;
use crate::sim::stats::Stats;

use super::level::Level;
use super::protocol::CoherenceProtocol;

/// Low-`n` way-position mask (`n == 64` would overflow the shift; way
/// counts are validated far below that, but stay total anyway).
#[inline]
fn low_ways_mask(n: usize) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

/// Result of the shared portion of a coherent walk: cycles charged plus
/// the pending innermost-level fill (absent when the access hit
/// innermost).
pub struct CoherentWalk {
    pub cycles: u64,
    pub fill: Option<FillReq>,
}

/// A pending innermost-level fill the engine must perform (it may
/// require CData merge-evictions the path cannot execute).
#[derive(Clone, Copy, Debug)]
pub struct FillReq {
    pub owned: bool,
    pub dirty: bool,
}

pub struct AccessPath {
    /// Innermost (L1) first; the last entry is the single shared level.
    levels: Vec<Level>,
    dir: Directory,
    /// The coherence state machine every directory transaction routes
    /// through ([`MachineConfig::protocol`](crate::sim::config::MachineConfig::protocol)).
    protocol: Box<dyn CoherenceProtocol>,
    cores: usize,
    mem_cycles: u64,
    /// Cycles per write-update message (Dragon), from
    /// [`Timing::update_cycles`](super::Timing).
    update_cycles: u64,
    /// Current shared-level merge-region width in ways; `None` when the
    /// config carries no [`WayPartition`](super::level::WayPartition).
    /// Mutable at run time — the reuse-aware controller in
    /// [`MemSystem`](crate::sim::memsys::MemSystem) resizes it through
    /// [`set_ccache_ways`](Self::set_ccache_ways).
    ccache_ways: Option<usize>,
}

impl AccessPath {
    /// Instantiate the stack a (validated) machine config describes.
    pub fn new(cfg: &MachineConfig) -> Self {
        Self {
            levels: cfg
                .levels
                .iter()
                .map(|lc| Level::new(*lc, cfg.cores))
                .collect(),
            dir: Directory::new(),
            protocol: cfg.protocol.build(),
            cores: cfg.cores,
            mem_cycles: cfg.timing.mem_cycles,
            update_cycles: cfg.timing.update_cycles,
            ccache_ways: cfg.llc().partition.map(|p| p.ccache_ways),
        }
    }

    /// The active coherence protocol.
    pub fn protocol(&self) -> &dyn CoherenceProtocol {
        &*self.protocol
    }

    /// Current merge-region partition width (`None` = unpartitioned).
    pub fn ccache_ways(&self) -> Option<usize> {
        self.ccache_ways
    }

    /// Resize the merge-region partition to `new` ways (partitioned
    /// configs only; clamped by the caller to `1..llc_ways`). Shrinking
    /// strands CData-classed lines in way positions now outside the
    /// merge region; their class tag is cleared so they age out as
    /// ordinary lines and the partition invariant holds immediately.
    /// Growing needs no sweep — ordinary lines stranded inside the new
    /// merge region are evicted naturally by CData installs.
    pub fn set_ccache_ways(&mut self, new: usize) {
        let sh = self.shared_index();
        let ways = self.levels[sh].cfg.ways;
        debug_assert!(self.ccache_ways.is_some(), "resize on unpartitioned path");
        debug_assert!(new >= 1 && new < ways, "partition width out of range");
        let old = self.ccache_ways.unwrap_or(0);
        if new < old {
            let cache = self.levels[sh].cache_mut(0);
            let demoted: Vec<usize> = cache
                .valid_slots()
                .filter(|&i| {
                    let p = i % ways;
                    p >= new && p < old && cache.is_ccache(i)
                })
                .collect();
            for i in demoted {
                cache.set_ccache(i, false);
            }
        }
        self.ccache_ways = Some(new);
    }

    /// Partition invariant (engine invariant 7): with a partition
    /// active, every CData-classed shared-level line sits at a way
    /// position inside the merge region; without one, no shared-level
    /// line is CData-classed at all.
    pub fn check_partition_invariant(&self) -> Result<(), InvariantViolation> {
        let sh = self.shared_index();
        let cache = self.levels[sh].cache(0);
        let ways = self.levels[sh].cfg.ways;
        let limit = self.ccache_ways.unwrap_or(0);
        for i in cache.valid_slots() {
            if !cache.is_ccache(i) {
                continue;
            }
            let p = i % ways;
            if p >= limit {
                let line = cache.meta(i).line;
                return Err(InvariantViolation::partition(
                    line.0,
                    if limit == 0 {
                        format!("CData-classed LLC line in way {p} with no partition configured")
                    } else {
                        format!("CData-classed LLC line in way {p}, merge region is 0..{limit}")
                    },
                ));
            }
        }
        Ok(())
    }

    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Number of private levels (everything below the shared level).
    pub fn private_depth(&self) -> usize {
        self.levels.len() - 1
    }

    #[inline]
    fn shared_index(&self) -> usize {
        self.levels.len() - 1
    }

    pub fn level(&self, i: usize) -> &Level {
        &self.levels[i]
    }

    pub fn directory(&self) -> &Directory {
        &self.dir
    }

    /// Mutable directory access — exists for the invariant tests, which
    /// inject corrupted sharer bits and assert the engine catches them.
    /// Production callers go through the protocol transactions.
    pub fn directory_mut(&mut self) -> &mut Directory {
        &mut self.dir
    }

    /// The innermost (CData-bearing) cache of `core`.
    #[inline]
    pub fn innermost(&self, core: usize) -> &Cache {
        self.levels[0].cache(core)
    }

    #[inline]
    pub fn innermost_mut(&mut self, core: usize) -> &mut Cache {
        self.levels[0].cache_mut(core)
    }

    // ------------------------------------------------------------------
    // the protocol-generic coherent walk
    // ------------------------------------------------------------------

    /// Branch-light fast path for the dominant access class: a coherent
    /// *read* hitting the innermost level. Returns the cycles to charge
    /// on a clean hit, `None` when the full walk must run instead (miss,
    /// or a CData line — the walk owns that diagnosis). Exactness: the
    /// `touch` on the hit is the same LRU transaction `lookup` performs
    /// in [`coherent_walk`], and on `None` no state has changed (`probe`
    /// never ticks), so falling back replays the access bit-identically.
    #[inline]
    pub fn read_hit_innermost(&mut self, core: usize, line: Line) -> Option<u64> {
        let hit_cycles = self.levels[0].cfg.hit_cycles;
        let cache = self.levels[0].cache_mut(core);
        let idx = cache.probe(line)?;
        if cache.is_ccache(idx) {
            return None; // the slow path asserts with the full diagnostic
        }
        cache.touch(idx);
        Some(hit_cycles)
    }

    /// Walk a coherent access through the stack: private levels innermost
    /// outward, then the shared level + directory. Performs all fills
    /// except the innermost one, which is returned for the engine to
    /// execute (it may displace CData).
    pub fn coherent_walk(
        &mut self,
        core: usize,
        line: Line,
        write: bool,
        stats: &mut Stats,
    ) -> CoherentWalk {
        let n_priv = self.private_depth();
        let mut cycles = 0;

        // ---- private levels ----
        for lvl in 0..n_priv {
            cycles += self.levels[lvl].cfg.hit_cycles;
            let Some(idx) = self.levels[lvl].cache_mut(core).lookup(line) else {
                stats.levels[lvl].misses += 1;
                continue;
            };
            let meta = self.levels[lvl].cache(core).meta(idx);
            if lvl == 0 {
                assert!(
                    !meta.ccache,
                    "coherent access to CData line {:#x} (paper forbids mixing; pad CData)",
                    line.0
                );
            }
            stats.levels[lvl].hits += 1;
            let mut owned = meta.owned;
            if write {
                if !owned {
                    // MESI: S->M upgrade, always granted exclusive.
                    // Dragon: update broadcast — exclusivity only once
                    // no other sharer remains, so the next write here
                    // consults the protocol again.
                    let (up_cycles, exclusive) = self.upgrade(core, line, stats);
                    cycles += up_cycles;
                    owned = exclusive;
                }
                // mark dirty (and ownership as granted) here and at every
                // outer private level holding the line (inclusion
                // bookkeeping)
                {
                    let c = self.levels[lvl].cache_mut(core);
                    c.set_dirty(idx, true);
                    c.set_owned(idx, owned);
                }
                for outer in lvl + 1..n_priv {
                    if let Some(i2) = self.levels[outer].cache_mut(core).lookup(line) {
                        let c2 = self.levels[outer].cache_mut(core);
                        c2.set_dirty(i2, true);
                        c2.set_owned(i2, owned);
                    }
                }
            }
            // fill the levels inside the hit level (inclusion), outermost
            // first; innermost is the engine's job
            for inner in (1..lvl).rev() {
                self.fill_private(core, inner, line, owned, write, stats);
            }
            let fill = if lvl == 0 {
                None
            } else {
                Some(FillReq {
                    owned,
                    dirty: write,
                })
            };
            return CoherentWalk { cycles, fill };
        }

        // ---- shared level + protocol transaction ----
        let sh = self.shared_index();
        cycles += self.levels[sh].cfg.hit_cycles;
        let grant = if write {
            self.protocol.write_shared(&mut self.dir, line, core)
        } else {
            self.protocol.read_shared(&mut self.dir, line, core)
        };
        let act = grant.actions;
        // remote dirty owner: the directory must forward the request and
        // wait for the owner's data — one extra shared-level round trip
        if act.owner_writeback.map_or(false, |o| o != core) {
            cycles += self.levels[sh].cfg.hit_cycles;
        }
        cycles += self.update_cycles * u64::from(act.update_mask.count_ones());
        self.apply_actions(core, line, &act, stats);

        if !self.fetch_shared(line, false, stats) {
            cycles += self.mem_cycles;
        }

        // owned iff the protocol granted exclusivity (MESI: E on a lone
        // read, M on any write; Dragon: only when no other sharer holds
        // a copy; partial coherence: always)
        let owned = grant.exclusive;
        for lvl in (1..n_priv).rev() {
            self.fill_private(core, lvl, line, owned, write, stats);
        }
        CoherentWalk {
            cycles,
            fill: Some(FillReq {
                owned,
                dirty: write,
            }),
        }
    }

    /// Write permission for a line already held non-exclusively: the
    /// protocol's write transaction (MESI S->M upgrade + invalidations;
    /// Dragon update broadcast). Returns the cycles charged (one
    /// shared-level round trip, one more when a remote owner's data must
    /// be forwarded, plus per-recipient update messages) and whether the
    /// writer now holds the line exclusively.
    pub fn upgrade(&mut self, core: usize, line: Line, stats: &mut Stats) -> (u64, bool) {
        let sh_hit = self.levels[self.shared_index()].cfg.hit_cycles;
        let grant = self.protocol.write_shared(&mut self.dir, line, core);
        let act = grant.actions;
        let mut cycles = sh_hit;
        if act.owner_writeback.map_or(false, |o| o != core) {
            cycles += sh_hit;
        }
        cycles += self.update_cycles * u64::from(act.update_mask.count_ones());
        self.apply_actions(core, line, &act, stats);
        (cycles, grant.exclusive)
    }

    /// Apply a protocol transaction's side effects to the other cores'
    /// private levels and the stats.
    fn apply_actions(
        &mut self,
        me: usize,
        line: Line,
        act: &CoherenceActions,
        stats: &mut Stats,
    ) {
        stats.directory_msgs += act.dir_msgs as u64;
        stats.invalidations += act.invalidations as u64;
        if act.update_mask != 0 {
            // write-update broadcast: recipients keep their (refreshed)
            // copies; the flat functional memory already carries the
            // value, so only the accounting happens here
            stats.dragon_updates += 1;
            stats.update_words += u64::from(act.update_mask.count_ones());
        }
        if let Some(owner) = act.owner_writeback {
            // keep_owner_dirty (Dragon Sm) forwards cache-to-cache
            // without cleaning through to memory: no writeback counted
            if owner != me && !act.keep_owner_dirty {
                stats.writebacks += 1;
            }
        }
        let n_priv = self.private_depth();
        let mut mask = act.inv_mask;
        while mask != 0 {
            let c = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            if c == me {
                continue;
            }
            // CData lines never match an incoming coherence message
            // (Section 4.4): leave them untouched even if the directory
            // has a stale registration for this core.
            if let Some(idx) = self.levels[0].cache(c).probe(line) {
                if !self.levels[0].cache(c).is_ccache(idx) {
                    self.levels[0].cache_mut(c).invalidate(line);
                }
            }
            for lvl in 1..n_priv {
                self.levels[lvl].cache_mut(c).invalidate(line);
            }
        }
        // a pure downgrade (a fetch hitting an owner) leaves the owner's
        // copy in place but clears its ownership; under Dragon's Sm the
        // dirty bit survives — the owner still owes the writeback
        if act.inv_mask == 0 {
            if let Some(owner) = act.owner_writeback {
                if owner != me {
                    for lvl in 0..n_priv {
                        if let Some(idx) = self.levels[lvl].cache(owner).probe(line) {
                            let c = self.levels[lvl].cache_mut(owner);
                            c.set_owned(idx, false);
                            if !act.keep_owner_dirty {
                                c.set_dirty(idx, false);
                            }
                        }
                    }
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // fills + evictions
    // ------------------------------------------------------------------

    /// Attempt to install `line` into the innermost level. `Err(victim)`
    /// means a mergeable CData line must be merged by the engine first;
    /// retry after merging. Panics on the w-1 deadlock (Section 4.4).
    pub fn try_fill_innermost(
        &mut self,
        core: usize,
        line: Line,
        owned: bool,
        dirty: bool,
        stats: &mut Stats,
    ) -> Result<(), Line> {
        if self.levels[0].cache(core).probe(line).is_some() {
            return Ok(());
        }
        let way = self.try_cdata_way(core, line, stats)?;
        let c = self.levels[0].cache_mut(core);
        c.install(way, line);
        c.set_owned(way, owned);
        c.set_dirty(way, dirty);
        Ok(())
    }

    /// Choose (and clear) an innermost-level way for `line`, evicting a
    /// coherent victim if needed. `Err(victim)` = a mergeable CData line
    /// the engine must merge first. Panics on the w-1 deadlock.
    pub fn try_cdata_way(
        &mut self,
        core: usize,
        line: Line,
        stats: &mut Stats,
    ) -> Result<usize, Line> {
        match self.levels[0].cache(core).choose_victim(line) {
            Victim::Free { way } => Ok(way),
            Victim::Evict { way, meta } => {
                if meta.ccache {
                    return Err(meta.line);
                }
                self.evict_private(core, 0, meta, stats);
                Ok(way)
            }
            Victim::Deadlock => panic!(
                "CCache deadlock: all L1 ways in set {} hold pinned CData \
                 (w-1 rule violated, Section 4.4); insert soft_merge/merge",
                self.levels[0].cache(core).set_index(line)
            ),
        }
    }

    /// Fill `line` into private level `lvl` (1..private_depth), evicting
    /// as needed. Only the innermost level holds CData, so victims here
    /// are always coherent lines.
    fn fill_private(
        &mut self,
        core: usize,
        lvl: usize,
        line: Line,
        owned: bool,
        dirty: bool,
        stats: &mut Stats,
    ) {
        if let Some(idx) = self.levels[lvl].cache_mut(core).lookup(line) {
            let c = self.levels[lvl].cache_mut(core);
            c.set_owned(idx, owned);
            if dirty {
                c.set_dirty(idx, true);
            }
            return;
        }
        let way = match self.levels[lvl].cache(core).choose_victim(line) {
            Victim::Free { way } => way,
            Victim::Evict { way, meta } => {
                debug_assert!(!meta.ccache, "CData never resides outside the innermost level");
                self.evict_private(core, lvl, meta, stats);
                way
            }
            Victim::Deadlock => unreachable!("only the innermost level holds CData"),
        };
        let c = self.levels[lvl].cache_mut(core);
        c.install(way, line);
        c.set_owned(way, owned);
        c.set_dirty(way, dirty);
    }

    /// Evict a coherent line from private level `lvl`: back-invalidate
    /// every inner level (inclusion), then write back — into the next
    /// private level, or to the directory + shared level when `lvl` is
    /// the outermost private level.
    fn evict_private(&mut self, core: usize, lvl: usize, meta: LineMeta, stats: &mut Stats) {
        let mut dirty = meta.dirty;
        for inner in 0..lvl {
            if let Some(m) = self.levels[inner].cache_mut(core).invalidate(meta.line) {
                dirty |= m.dirty;
            }
        }
        self.levels[lvl].cache_mut(core).invalidate(meta.line);
        if lvl + 1 == self.shared_index() {
            // outermost private level: the protocol must be told
            let act = self.protocol.evict(&mut self.dir, meta.line, core, dirty);
            stats.directory_msgs += act.dir_msgs as u64;
            if dirty {
                stats.writebacks += 1;
                let sh = self.shared_index();
                if let Some(i) = self.levels[sh].cache(0).probe(meta.line) {
                    self.levels[sh].cache_mut(0).set_dirty(i, true);
                }
            }
        } else if dirty {
            // write back into the next private level (inclusion
            // guarantees presence)
            if let Some(i) = self.levels[lvl + 1].cache(core).probe(meta.line) {
                self.levels[lvl + 1].cache_mut(core).set_dirty(i, true);
            }
        }
    }

    // ------------------------------------------------------------------
    // shared level
    // ------------------------------------------------------------------

    /// Look `line` up in the shared level, installing it (with an
    /// inclusive recall of any victim) on a miss. `cdata` classifies the
    /// access for the way partition: `true` for merge-region
    /// (privatization) fetches, `false` for coherent ones. Lookups hit
    /// across the whole set regardless — only a miss's victim choice is
    /// partitioned. Returns whether it hit; the caller charges memory
    /// latency on a miss.
    pub fn fetch_shared(&mut self, line: Line, cdata: bool, stats: &mut Stats) -> bool {
        let sh = self.shared_index();
        if self.levels[sh].cache_mut(0).lookup(line).is_some() {
            stats.levels[sh].hits += 1;
            true
        } else {
            stats.levels[sh].misses += 1;
            stats.mem_accesses += 1;
            self.install_shared(line, cdata, stats);
            false
        }
    }

    /// Install `line` into the shared level; an evicted victim triggers
    /// an inclusive recall killing every private copy. With a partition
    /// active, CData installs pick victims inside the merge-region way
    /// mask and coherent installs outside it, and the installed line is
    /// class-tagged (F_CCACHE at this level is the partition's class
    /// tag, never a pin). Without a partition the byte-identical
    /// pre-partitioning behavior runs: plain LRU choice, no tagging.
    fn install_shared(&mut self, line: Line, cdata: bool, stats: &mut Stats) {
        let sh = self.shared_index();
        if self.levels[sh].cache(0).probe(line).is_some() {
            return;
        }
        let victim = match self.ccache_ways {
            None => self.levels[sh].cache(0).choose_victim(line),
            Some(cw) => {
                let ways = self.levels[sh].cfg.ways;
                let merge_mask = low_ways_mask(cw);
                let mask = if cdata {
                    merge_mask
                } else {
                    low_ways_mask(ways) & !merge_mask
                };
                self.levels[sh].cache(0).choose_victim_masked(line, mask)
            }
        };
        let way = match victim {
            Victim::Free { way } => way,
            Victim::Evict { way, meta } => {
                let (_, act) = self.protocol.recall(&mut self.dir, meta.line);
                stats.directory_msgs += act.dir_msgs as u64;
                stats.invalidations += act.invalidations as u64;
                let mut dirty = meta.dirty;
                let mut mask = act.inv_mask;
                while mask != 0 {
                    let c = mask.trailing_zeros() as usize;
                    mask &= mask - 1;
                    for lvl in 0..sh {
                        if let Some(m) = self.levels[lvl].cache_mut(c).invalidate(meta.line) {
                            dirty |= m.dirty;
                        }
                    }
                }
                if dirty {
                    stats.writebacks += 1; // shared level -> memory
                }
                way
            }
            Victim::Deadlock => unreachable!(
                "the shared level holds no pinned CData and partition masks are non-empty"
            ),
        };
        self.levels[sh].cache_mut(0).install(way, line);
        if self.ccache_ways.is_some() {
            self.levels[sh].cache_mut(0).set_ccache(way, cdata);
        }
    }

    /// Drop any coherent copies of `line` held by `core`'s private levels
    /// (phase transition into CData, Section 4.4): the directory
    /// registration is released as if the core had evicted the line.
    ///
    /// The eviction transaction fires when a copy was found *or* when the
    /// directory still registers this core — gating on presence alone
    /// would leak a sharer bit whenever the registration outlives the
    /// cached copy, and a stale bit inflates every later invalidation
    /// (MESI) or update broadcast (Dragon) for the line. Engine
    /// invariant 8 ([`check_sharer_invariant`](Self::check_sharer_invariant))
    /// pins the discipline.
    pub fn drop_coherent(&mut self, core: usize, line: Line, stats: &mut Stats) {
        let n_priv = self.private_depth();
        let mut dirty = false;
        let mut present = false;
        for lvl in 0..n_priv {
            if let Some(m) = self.levels[lvl].cache_mut(core).invalidate(line) {
                dirty |= m.dirty;
                present = true;
            }
        }
        let registered = self.protocol.is_coherent()
            && self.dir.entry(line).map_or(false, |e| e.is_sharer(core));
        if present || registered {
            let act = self.protocol.evict(&mut self.dir, line, core, dirty);
            stats.directory_msgs += act.dir_msgs as u64;
            if dirty {
                stats.writebacks += 1;
            }
        }
    }

    /// Engine invariant 8: the directory's sharer bookkeeping and the
    /// private caches agree. For a coherent protocol, every sharer bit
    /// corresponds to a real, non-CData copy in that core's outermost
    /// private level, and every coherent line cached there is registered
    /// (drop_coherent/eviction leaks would break Dragon's update fan-out
    /// and MESI's invalidation sets). For partial coherence the
    /// directory must simply stay empty — no transaction ever writes it.
    pub fn check_sharer_invariant(&self) -> Result<(), InvariantViolation> {
        let outer = self.private_depth() - 1;
        if !self.protocol.is_coherent() {
            return match self.dir.iter_entries().next() {
                None => Ok(()),
                Some((line, _)) => Err(InvariantViolation::directory(
                    line.0,
                    "non-coherent protocol but the directory has an entry",
                )),
            };
        }
        // directory -> caches: no stale sharer bits
        for (line, e) in self.dir.iter_entries() {
            let mut mask = e.sharers;
            while mask != 0 {
                let c = mask.trailing_zeros() as usize;
                mask &= mask - 1;
                let cache = self.levels[outer].cache(c);
                match cache.probe(line) {
                    Some(idx) if !cache.is_ccache(idx) => {}
                    Some(_) => {
                        return Err(InvariantViolation::directory(
                            line.0,
                            format!("core {c} registered as sharer but holds the line as CData"),
                        ))
                    }
                    None => {
                        return Err(InvariantViolation::directory(
                            line.0,
                            format!(
                                "stale sharer bit: core {c} registered but holds no copy in \
                                 private level {outer}"
                            ),
                        ))
                    }
                }
            }
        }
        // caches -> directory: no unregistered coherent residents
        for core in 0..self.cores {
            let cache = self.levels[outer].cache(core);
            for i in cache.valid_slots() {
                if cache.is_ccache(i) {
                    continue;
                }
                let line = cache.meta(i).line;
                if !self.dir.entry(line).map_or(false, |e| e.is_sharer(core)) {
                    return Err(InvariantViolation::directory(
                        line.0,
                        format!(
                            "core {core} holds coherent line in private level {outer} without a \
                             sharer registration"
                        ),
                    ));
                }
            }
        }
        Ok(())
    }
}

// Walk-level unit tests live in `rust/tests/hierarchy.rs` (the walk,
// fills and directory hand-off are all public API); `rust/tests/{protocol,
// mesi}.rs` cover the composed engine on multiple shapes.
