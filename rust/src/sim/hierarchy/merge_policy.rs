//! [`MergePolicy`]: the CData merge decisions as a trait, extracted from
//! the branches that used to be inlined in the protocol engine.
//!
//! The policy answers three questions the engine asks on every merge
//! event (Section 4.3):
//! 1. does `soft_merge` defer merging to eviction (merge-on-evict), or
//!    flush the source buffer immediately?
//! 2. what happens to an evicted CData line — run the merge function, or
//!    silently drop it because it is clean (dirty-merge)?
//! 3. how many cycles does one executed merge charge the core — the
//!    synchronous `merge` instruction drains the background engine and
//!    pays the full latency; eviction-triggered merges are queued on the
//!    pipelined engine and stall the core only when its queue backs up.
//!
//! [`PaperMergePolicy`] reproduces the paper's behaviour, parameterized
//! by the Table 2 latencies and the two optimization switches; the trait
//! is the seam for alternative policies (always-eager, batched, ...).

use crate::merge::MergeFn;
use crate::sim::config::CCacheConfig;

/// Disposition of an evicted CData line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergeDecision {
    /// Run the merge function and update memory.
    Execute,
    /// Silently drop the line (dirty-merge optimization, clean line).
    SilentDrop,
}

/// When/what/how-long decisions for CData merges. Implementations must
/// be `Send + Sync`: the memory system lives inside the machine mutex
/// shared by the core threads.
pub trait MergePolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// `soft_merge` semantics: `true` marks lines mergeable and defers
    /// the merge to eviction (merge-on-evict); `false` makes
    /// `soft_merge` a full flush (the Fig 9 baseline).
    fn defers_soft_merge(&self) -> bool;

    /// Decide what happens to an evicted CData line with the given dirty
    /// state. The line's installed merge function is passed so policies
    /// can consult its metadata (e.g. idempotent functions tolerate
    /// re-execution of clean lines); the paper's policy looks only at
    /// the dirty bit.
    fn on_evict(&self, dirty: bool, merge: &dyn MergeFn) -> MergeDecision;

    /// Cycles charged to the core for one executed merge. `sync` is true
    /// for the explicit `merge` instruction, false for
    /// eviction-triggered merges. `backlog` is the core's background
    /// merge-engine backlog in cycles; the policy updates it.
    fn charge(&self, sync: bool, backlog: &mut u64) -> u64;
}

/// The paper's policy (Sections 4.1 + 4.3): merge-on-evict and
/// dirty-merge switches over the Table 2 latencies, with a pipelined
/// background merge engine for eviction-triggered merges.
#[derive(Clone, Copy, Debug)]
pub struct PaperMergePolicy {
    pub merge_on_evict: bool,
    pub dirty_merge: bool,
    /// Synchronous merge latency per line, LLC round trip included
    /// (Table 2: 170).
    pub merge_latency: u64,
    /// Background engine occupancy per merge (LLC-port bound).
    pub engine_interval: u64,
    /// Pending-merge queue depth before the core stalls.
    pub engine_queue: u64,
    /// Cycles to hand a line to the engine (source-buffer hit latency).
    pub source_buffer_hit_cycles: u64,
}

impl PaperMergePolicy {
    pub fn from_config(c: &CCacheConfig) -> Self {
        Self {
            merge_on_evict: c.merge_on_evict,
            dirty_merge: c.dirty_merge,
            merge_latency: c.merge_latency,
            engine_interval: c.merge_engine_interval,
            engine_queue: c.merge_engine_queue,
            source_buffer_hit_cycles: c.source_buffer_hit_cycles,
        }
    }
}

impl MergePolicy for PaperMergePolicy {
    fn name(&self) -> &'static str {
        "paper"
    }

    fn defers_soft_merge(&self) -> bool {
        self.merge_on_evict
    }

    fn on_evict(&self, dirty: bool, _merge: &dyn MergeFn) -> MergeDecision {
        if self.dirty_merge && !dirty {
            MergeDecision::SilentDrop
        } else {
            MergeDecision::Execute
        }
    }

    fn charge(&self, sync: bool, backlog: &mut u64) -> u64 {
        if sync {
            let drain = *backlog;
            *backlog = 0;
            drain + self.merge_latency
        } else {
            let cap = self.engine_queue * self.engine_interval;
            *backlog += self.engine_interval;
            if *backlog > cap {
                let stall = *backlog - cap;
                *backlog = cap;
                self.source_buffer_hit_cycles + stall
            } else {
                self.source_buffer_hit_cycles
            }
        }
    }
}

/// Build the merge policy a machine configuration describes.
pub fn from_config(c: &CCacheConfig) -> Box<dyn MergePolicy> {
    Box::new(PaperMergePolicy::from_config(c))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> PaperMergePolicy {
        PaperMergePolicy::from_config(&CCacheConfig::default())
    }

    #[test]
    fn dirty_merge_drops_clean_only() {
        let f = crate::merge::funcs::AddU32;
        let p = policy();
        assert_eq!(p.on_evict(false, &f), MergeDecision::SilentDrop);
        assert_eq!(p.on_evict(true, &f), MergeDecision::Execute);
        let mut p2 = policy();
        p2.dirty_merge = false;
        assert_eq!(p2.on_evict(false, &f), MergeDecision::Execute);
    }

    #[test]
    fn sync_merge_drains_backlog_and_pays_full_latency() {
        let p = policy();
        let mut backlog = 50;
        assert_eq!(p.charge(true, &mut backlog), 50 + p.merge_latency);
        assert_eq!(backlog, 0);
    }

    #[test]
    fn background_merges_stall_only_past_queue_capacity() {
        let p = policy();
        let cap = p.engine_queue * p.engine_interval;
        let mut backlog = 0;
        // fill the queue: each enqueue costs only the source-buffer hit
        for _ in 0..p.engine_queue {
            assert_eq!(p.charge(false, &mut backlog), p.source_buffer_hit_cycles);
        }
        assert_eq!(backlog, cap);
        // one more backs the engine up: the overflow stalls the core
        let c = p.charge(false, &mut backlog);
        assert_eq!(c, p.source_buffer_hit_cycles + p.engine_interval);
        assert_eq!(backlog, cap);
    }

    #[test]
    fn soft_merge_deferral_follows_switch() {
        let mut p = policy();
        assert!(p.defers_soft_merge());
        p.merge_on_evict = false;
        assert!(!p.defers_soft_merge());
    }
}
