//! Pluggable coherence protocols: the state machine that used to be
//! inlined in [`path`](super::path) and `directory.rs`, extracted behind
//! the [`CoherenceProtocol`] trait so the hierarchy walk is
//! protocol-generic and the [`Directory`] is plain storage of
//! protocol-opaque line states.
//!
//! Three implementations:
//!
//! * [`Mesi`] — the paper's baseline: write-invalidate, full-map
//!   directory. A bit-identical refactor of the walk that used to be
//!   hard-coded (pinned by `tests/mesi_refactor_diff.rs`).
//! * [`Dragon`] — write-update: a write to a shared line broadcasts the
//!   word to every other sharer instead of invalidating them. Sharers
//!   keep read hits; every write to a still-shared line pays the
//!   broadcast again ([`Timing::update_cycles`](super::Timing) per
//!   recipient, counted in `Stats::{dragon_updates, update_words}`).
//!   A reader fetching from a dirty owner leaves the owner's copy dirty
//!   (Sm-style: writeback responsibility stays with the last writer,
//!   signalled by [`CoherenceActions::keep_owner_dirty`]).
//! * [`PartialCoherence`] — the shared level is non-coherent (modeled on
//!   partially cache-coherent CXL memory): no directory traffic at all,
//!   private hits never consult anyone, and remote stores become visible
//!   only when the writer publishes — at a barrier, an explicit merge,
//!   or end of run (store buffering lives in `memsys`). Variants that
//!   need coherent RMWs (cgl/fgl/atomic) are typed-rejected.
//!
//! The trait's contract with the walk: `read_shared`/`write_shared` run
//! the directory transaction for a shared-level access and return a
//! [`Grant`] — the coherence actions the caller must account (message
//! counts, invalidation mask, owner writeback, update fan-out) plus
//! whether the requester may treat the line as exclusive. `evict` and
//! `recall` are the PutS/PutM and inclusive-recall transactions. CData
//! never reaches any of these: c_read/c_write bypass coherence entirely
//! (Section 4.4), which is exactly why merge-based privatization can be
//! swept *against* these protocols (`ccache protosweep`).

use crate::sim::addr::Line;
use crate::sim::directory::{CoherenceActions, DirState, Directory, SharerMask};

/// The protocol registry: every selectable protocol, its CLI token, and
/// what it supports. `--list-protocols` and config validation both read
/// this, so help text cannot drift from the implementations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtocolKind {
    /// Write-invalidate MESI (the paper's machine).
    Mesi,
    /// Write-update Dragon.
    Dragon,
    /// Non-coherent shared level; only merges/barriers publish.
    Partial,
}

impl ProtocolKind {
    pub const ALL: [ProtocolKind; 3] = [
        ProtocolKind::Mesi,
        ProtocolKind::Dragon,
        ProtocolKind::Partial,
    ];

    /// CLI token (`--protocol <name>`).
    pub fn name(self) -> &'static str {
        match self {
            ProtocolKind::Mesi => "mesi",
            ProtocolKind::Dragon => "dragon",
            ProtocolKind::Partial => "partial",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "mesi" => Some(ProtocolKind::Mesi),
            "dragon" => Some(ProtocolKind::Dragon),
            "partial" | "partial-coherence" => Some(ProtocolKind::Partial),
            _ => None,
        }
    }

    /// One-line summary for `--list-protocols`.
    pub fn description(self) -> &'static str {
        match self {
            ProtocolKind::Mesi => {
                "write-invalidate full-map directory MESI (the paper's baseline)"
            }
            ProtocolKind::Dragon => {
                "write-update: writes broadcast word updates to sharers instead of invalidating"
            }
            ProtocolKind::Partial => {
                "non-coherent shared level: only CCache merges and barrier flushes publish stores"
            }
        }
    }

    /// Names of the execution variants this protocol can run. Partial
    /// coherence has no coherent RMWs, so every lock- or atomic-based
    /// variant (cgl, fgl, atomic) is out; dup and ccache communicate
    /// only at merge/barrier points, which is exactly what publishes.
    pub fn supported_variants(self) -> &'static [&'static str] {
        match self {
            ProtocolKind::Mesi | ProtocolKind::Dragon => {
                &["cgl", "fgl", "dup", "ccache", "atomic"]
            }
            ProtocolKind::Partial => &["dup", "ccache"],
        }
    }

    pub fn supports(self, variant_name: &str) -> bool {
        self.supported_variants().contains(&variant_name)
    }

    /// Instantiate the protocol behind the trait.
    pub fn build(self) -> Box<dyn CoherenceProtocol> {
        match self {
            ProtocolKind::Mesi => Box::new(Mesi),
            ProtocolKind::Dragon => Box::new(Dragon),
            ProtocolKind::Partial => Box::new(PartialCoherence),
        }
    }
}

impl std::fmt::Display for ProtocolKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What a shared-level access transaction grants the requester.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Grant {
    /// Coherence actions the walk must perform and account.
    pub actions: CoherenceActions,
    /// May the requester install/hold the line exclusively (E/M)? When
    /// false, a later write by the same core must re-consult the
    /// protocol (MESI: upgrade; Dragon: re-broadcast).
    pub exclusive: bool,
}

/// A coherence protocol: owns every directory transaction the hierarchy
/// walk performs. Implementations mutate the [`Directory`] (plain
/// storage) and return the actions/grants the walk accounts; they never
/// touch caches or stats themselves, so the walk stays the single place
/// where timing is charged.
pub trait CoherenceProtocol: Send + Sync {
    fn kind(&self) -> ProtocolKind;

    /// Core `core` misses privately and reads `line` at the shared level
    /// (GetS-shaped).
    fn read_shared(&self, dir: &mut Directory, line: Line, core: usize) -> Grant;

    /// Core `core` writes `line` at the shared level (GetM / upgrade /
    /// Dragon update-broadcast).
    fn write_shared(&self, dir: &mut Directory, line: Line, core: usize) -> Grant;

    /// Core `core` dropped its private copy (PutS/PutM). `dirty` = the
    /// copy was modified and is being written back.
    fn evict(&self, dir: &mut Directory, line: Line, core: usize, dirty: bool)
        -> CoherenceActions;

    /// The inclusive LLC evicts `line`: every private copy must go.
    /// Returns the sharer set to invalidate; the entry is removed.
    fn recall(&self, dir: &mut Directory, line: Line) -> (SharerMask, CoherenceActions);

    /// False for protocols that keep the directory empty and publish
    /// through explicit merges/barriers only (partial coherence).
    fn is_coherent(&self) -> bool {
        true
    }
}

/// The paper's write-invalidate MESI. These four transactions are the
/// former `Directory::{get_s, get_m, put, recall}`, moved verbatim; the
/// differential test in `tests/mesi_refactor_diff.rs` pins them
/// bit-identical to the pre-refactor walk.
pub struct Mesi;

impl CoherenceProtocol for Mesi {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::Mesi
    }

    fn read_shared(&self, dir: &mut Directory, line: Line, core: usize) -> Grant {
        let e = dir.entry_or_insert(line);
        let mut act = CoherenceActions {
            dir_msgs: 1, // the GetS itself
            ..Default::default()
        };
        match e.state {
            DirState::Uncached => {
                e.state = DirState::Owned { owner: core }; // grant E
                e.sharers = 1 << core;
            }
            DirState::Shared => {
                e.sharers |= 1 << core;
            }
            DirState::Owned { owner } if owner == core => {
                // already owner (e.g. refetch after L1 evict, L2 hit path)
            }
            DirState::Owned { owner } => {
                // downgrade owner: fetch its (possibly dirty) data
                act.owner_writeback = Some(owner);
                act.dir_msgs += 2; // fwd + data
                e.state = DirState::Shared;
                e.sharers |= 1 << core;
            }
        }
        Grant {
            // post-state Owned can only mean owned by `core` here
            exclusive: matches!(e.state, DirState::Owned { .. }),
            actions: act,
        }
    }

    fn write_shared(&self, dir: &mut Directory, line: Line, core: usize) -> Grant {
        let e = dir.entry_or_insert(line);
        let mut act = CoherenceActions {
            dir_msgs: 1,
            ..Default::default()
        };
        match e.state {
            DirState::Uncached => {}
            DirState::Shared => {
                let others = e.sharers & !(1 << core);
                act.invalidations = others.count_ones();
                act.inv_mask = others;
                act.dir_msgs += act.invalidations; // one inv per sharer
            }
            DirState::Owned { owner } if owner == core => {
                e.sharers = 1 << core;
                return Grant {
                    actions: act,
                    exclusive: true,
                }; // silent upgrade, nothing to do
            }
            DirState::Owned { owner } => {
                act.owner_writeback = Some(owner);
                act.invalidations = 1;
                act.inv_mask = 1 << owner;
                act.dir_msgs += 2;
            }
        }
        e.state = DirState::Owned { owner: core };
        e.sharers = 1 << core;
        Grant {
            actions: act,
            exclusive: true,
        }
    }

    fn evict(
        &self,
        dir: &mut Directory,
        line: Line,
        core: usize,
        dirty: bool,
    ) -> CoherenceActions {
        let mut act = CoherenceActions {
            dir_msgs: 1,
            ..Default::default()
        };
        if let Some(e) = dir.entry_mut(line) {
            e.sharers &= !(1 << core);
            match e.state {
                DirState::Owned { owner } if owner == core => {
                    e.state = if e.sharers == 0 {
                        DirState::Uncached
                    } else {
                        DirState::Shared
                    };
                }
                DirState::Shared if e.sharers == 0 => {
                    e.state = DirState::Uncached;
                }
                _ => {}
            }
            if dirty {
                act.dir_msgs += 1; // data message with the writeback
            }
        }
        act
    }

    fn recall(&self, dir: &mut Directory, line: Line) -> (SharerMask, CoherenceActions) {
        let Some(e) = dir.remove_entry(line) else {
            return (0, CoherenceActions::default());
        };
        let act = CoherenceActions {
            invalidations: e.sharer_count(),
            inv_mask: e.sharers,
            owner_writeback: match e.state {
                DirState::Owned { owner } => Some(owner),
                _ => None,
            },
            dir_msgs: 1 + e.sharer_count(),
            ..Default::default()
        };
        (e.sharers, act)
    }
}

/// Write-update Dragon. Reads behave like MESI reads except a dirty
/// owner keeps its dirty bit (Sm: writeback responsibility stays put).
/// Writes never invalidate: a write to a shared line stays shared and
/// broadcasts the word to every other sharer (`update_mask`), so a
/// producer re-pays the broadcast on every write for as long as
/// consumers keep copies — the cost signature `protosweep` contrasts
/// against MESI's invalidate-then-miss pattern.
pub struct Dragon;

impl CoherenceProtocol for Dragon {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::Dragon
    }

    fn read_shared(&self, dir: &mut Directory, line: Line, core: usize) -> Grant {
        let e = dir.entry_or_insert(line);
        let mut act = CoherenceActions {
            dir_msgs: 1,
            ..Default::default()
        };
        match e.state {
            DirState::Uncached => {
                e.state = DirState::Owned { owner: core }; // alone: E
                e.sharers = 1 << core;
            }
            DirState::Shared => {
                e.sharers |= 1 << core;
            }
            DirState::Owned { owner } if owner == core => {}
            DirState::Owned { owner } => {
                // fetch from the owner, but unlike MESI the owner's copy
                // stays dirty: Sm keeps writeback responsibility, memory
                // is not updated
                act.owner_writeback = Some(owner);
                act.keep_owner_dirty = true;
                act.dir_msgs += 2; // fwd + data
                e.state = DirState::Shared;
                e.sharers |= 1 << core;
            }
        }
        Grant {
            exclusive: matches!(e.state, DirState::Owned { .. }),
            actions: act,
        }
    }

    fn write_shared(&self, dir: &mut Directory, line: Line, core: usize) -> Grant {
        let e = dir.entry_or_insert(line);
        let mut act = CoherenceActions {
            dir_msgs: 1,
            ..Default::default()
        };
        let exclusive = match e.state {
            DirState::Uncached => {
                e.state = DirState::Owned { owner: core };
                e.sharers = 1 << core;
                true
            }
            DirState::Shared => {
                e.sharers |= 1 << core;
                let others = e.sharers & !(1 << core);
                if others == 0 {
                    // sole remaining sharer: promote to M silently
                    e.state = DirState::Owned { owner: core };
                    true
                } else {
                    // broadcast the word; everyone keeps their copy
                    act.update_mask = others;
                    act.dir_msgs += others.count_ones();
                    false
                }
            }
            DirState::Owned { owner } if owner == core => {
                e.sharers = 1 << core;
                true
            }
            DirState::Owned { owner } => {
                // fetch from the old owner, then update its (retained)
                // copy; writeback responsibility moves to the writer
                act.owner_writeback = Some(owner);
                act.update_mask = 1 << owner;
                act.dir_msgs += 3; // fwd + data + update
                e.state = DirState::Shared;
                e.sharers = (1 << owner) | (1 << core);
                false
            }
        };
        Grant {
            actions: act,
            exclusive,
        }
    }

    fn evict(
        &self,
        dir: &mut Directory,
        line: Line,
        core: usize,
        dirty: bool,
    ) -> CoherenceActions {
        let mut act = CoherenceActions {
            dir_msgs: 1,
            ..Default::default()
        };
        if let Some(e) = dir.entry_mut(line) {
            e.sharers &= !(1 << core);
            match e.state {
                DirState::Owned { owner } if owner == core => {
                    e.state = if e.sharers == 0 {
                        DirState::Uncached
                    } else {
                        DirState::Shared
                    };
                }
                DirState::Shared if e.sharers == 0 => {
                    e.state = DirState::Uncached;
                }
                DirState::Shared if e.sharers.count_ones() == 1 => {
                    // last-sharer degrade: the survivor stops being a
                    // broadcast target and future writes go exclusive
                    e.state = DirState::Owned {
                        owner: e.sharers.trailing_zeros() as usize,
                    };
                }
                _ => {}
            }
            if dirty {
                act.dir_msgs += 1;
            }
        }
        act
    }

    fn recall(&self, dir: &mut Directory, line: Line) -> (SharerMask, CoherenceActions) {
        // inclusive recall is invalidation-shaped in any protocol
        Mesi.recall(dir, line)
    }
}

/// Partial coherence: the shared level answers fetches but tracks
/// nothing. No transaction touches the directory (it stays empty — the
/// engine invariant checks that), every fill is trivially "exclusive",
/// and evict/recall are silent. Store visibility is the caller's
/// problem: `memsys` buffers each core's coherent stores and publishes
/// them at merges, barriers and end of run.
pub struct PartialCoherence;

impl CoherenceProtocol for PartialCoherence {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::Partial
    }

    fn read_shared(&self, _dir: &mut Directory, _line: Line, _core: usize) -> Grant {
        Grant {
            actions: CoherenceActions::default(),
            exclusive: true,
        }
    }

    fn write_shared(&self, _dir: &mut Directory, _line: Line, _core: usize) -> Grant {
        Grant {
            actions: CoherenceActions::default(),
            exclusive: true,
        }
    }

    fn evict(
        &self,
        _dir: &mut Directory,
        _line: Line,
        _core: usize,
        _dirty: bool,
    ) -> CoherenceActions {
        CoherenceActions::default()
    }

    fn recall(&self, _dir: &mut Directory, _line: Line) -> (SharerMask, CoherenceActions) {
        (0, CoherenceActions::default())
    }

    fn is_coherent(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l(v: u64) -> Line {
        Line(v)
    }

    // ---- registry ----

    #[test]
    fn tokens_round_trip_and_cover_all() {
        for kind in ProtocolKind::ALL {
            assert_eq!(ProtocolKind::parse(kind.name()), Some(kind));
            assert_eq!(kind.build().kind(), kind);
            assert!(!kind.description().is_empty());
        }
        assert_eq!(ProtocolKind::parse("moesi"), None);
        assert_eq!(
            ProtocolKind::parse("partial-coherence"),
            Some(ProtocolKind::Partial)
        );
    }

    #[test]
    fn partial_rejects_rmw_variants() {
        let p = ProtocolKind::Partial;
        assert!(p.supports("ccache") && p.supports("dup"));
        assert!(!p.supports("fgl") && !p.supports("atomic") && !p.supports("cgl"));
        for kind in [ProtocolKind::Mesi, ProtocolKind::Dragon] {
            assert_eq!(kind.supported_variants().len(), 5);
        }
    }

    // ---- MESI (moved from directory.rs: semantics are unchanged) ----

    #[test]
    fn mesi_first_reader_gets_exclusive() {
        let mut d = Directory::new();
        let g = Mesi.read_shared(&mut d, l(1), 0);
        assert_eq!(g.actions.invalidations, 0);
        assert!(g.exclusive);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 0 });
    }

    #[test]
    fn mesi_second_reader_downgrades_owner() {
        let mut d = Directory::new();
        Mesi.read_shared(&mut d, l(1), 0);
        let g = Mesi.read_shared(&mut d, l(1), 1);
        assert_eq!(g.actions.owner_writeback, Some(0));
        assert!(!g.actions.keep_owner_dirty, "MESI downgrade cleans the owner");
        assert!(!g.exclusive);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Shared);
        assert_eq!(d.entry(l(1)).unwrap().sharer_count(), 2);
    }

    #[test]
    fn mesi_writer_invalidates_sharers() {
        let mut d = Directory::new();
        Mesi.read_shared(&mut d, l(1), 0);
        Mesi.read_shared(&mut d, l(1), 1);
        Mesi.read_shared(&mut d, l(1), 2);
        let g = Mesi.write_shared(&mut d, l(1), 0);
        assert_eq!(g.actions.invalidations, 2); // cores 1, 2
        assert_eq!(g.actions.inv_mask, 0b110);
        assert_eq!(g.actions.update_mask, 0, "MESI never updates");
        assert!(g.exclusive);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 0 });
        d.check_invariants().unwrap();
    }

    #[test]
    fn mesi_writer_steals_from_dirty_owner() {
        let mut d = Directory::new();
        Mesi.write_shared(&mut d, l(1), 0);
        let g = Mesi.write_shared(&mut d, l(1), 1);
        assert_eq!(g.actions.owner_writeback, Some(0));
        assert_eq!(g.actions.invalidations, 1);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 1 });
    }

    #[test]
    fn mesi_silent_upgrade_costs_nothing_extra() {
        let mut d = Directory::new();
        Mesi.read_shared(&mut d, l(1), 0); // granted E
        let g = Mesi.write_shared(&mut d, l(1), 0);
        assert_eq!(g.actions.invalidations, 0);
        assert_eq!(g.actions.owner_writeback, None);
    }

    #[test]
    fn mesi_put_last_sharer_uncaches() {
        let mut d = Directory::new();
        Mesi.read_shared(&mut d, l(1), 0);
        Mesi.evict(&mut d, l(1), 0, false);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Uncached);
        d.check_invariants().unwrap();
    }

    #[test]
    fn mesi_put_of_a_non_owner_sharer_keeps_the_line_shared() {
        let mut d = Directory::new();
        Mesi.read_shared(&mut d, l(1), 0);
        Mesi.read_shared(&mut d, l(1), 1); // downgrades 0 -> Shared {0,1}
        Mesi.evict(&mut d, l(1), 1, false);
        let e = d.entry(l(1)).unwrap();
        assert_eq!(e.state, DirState::Shared);
        assert!(e.is_sharer(0) && !e.is_sharer(1));
        d.check_invariants().unwrap();
    }

    #[test]
    fn mesi_recall_reports_all_sharers() {
        let mut d = Directory::new();
        Mesi.read_shared(&mut d, l(1), 0);
        Mesi.read_shared(&mut d, l(1), 1);
        let (mask, act) = Mesi.recall(&mut d, l(1));
        assert_eq!(mask, 0b11);
        assert_eq!(act.invalidations, 2);
        assert!(d.entry(l(1)).is_none());
        // the entry is gone; the next reader is alone again -> E
        let g = Mesi.read_shared(&mut d, l(1), 1);
        assert!(g.exclusive);
        d.check_invariants().unwrap();
    }

    #[test]
    fn mesi_dirty_put_costs_an_extra_data_message() {
        let mut d = Directory::new();
        Mesi.write_shared(&mut d, l(1), 0);
        let clean = Mesi.evict(&mut d, l(1), 0, false);
        Mesi.write_shared(&mut d, l(1), 0);
        let dirty = Mesi.evict(&mut d, l(1), 0, true);
        assert_eq!(dirty.dir_msgs, clean.dir_msgs + 1);
    }

    // ---- Dragon ----

    #[test]
    fn dragon_write_updates_sharers_without_invalidating() {
        let mut d = Directory::new();
        Dragon.read_shared(&mut d, l(1), 0);
        Dragon.read_shared(&mut d, l(1), 1);
        Dragon.read_shared(&mut d, l(1), 2);
        let g = Dragon.write_shared(&mut d, l(1), 0);
        assert_eq!(g.actions.invalidations, 0, "write-update never invalidates");
        assert_eq!(g.actions.inv_mask, 0);
        assert_eq!(g.actions.update_mask, 0b110, "cores 1 and 2 get the word");
        assert!(!g.exclusive, "line stays shared while others hold it");
        let e = d.entry(l(1)).unwrap();
        assert_eq!(e.state, DirState::Shared);
        assert_eq!(e.sharer_count(), 3, "every sharer keeps its copy");
        d.check_invariants().unwrap();
    }

    #[test]
    fn dragon_repeated_writes_keep_broadcasting() {
        let mut d = Directory::new();
        Dragon.read_shared(&mut d, l(1), 0);
        Dragon.read_shared(&mut d, l(1), 1);
        for _ in 0..3 {
            let g = Dragon.write_shared(&mut d, l(1), 0);
            assert_eq!(g.actions.update_mask, 0b10);
            assert!(!g.exclusive);
        }
    }

    #[test]
    fn dragon_sole_writer_goes_exclusive() {
        let mut d = Directory::new();
        let g = Dragon.write_shared(&mut d, l(1), 3);
        assert!(g.exclusive);
        assert_eq!(g.actions.update_mask, 0);
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Owned { owner: 3 });
    }

    #[test]
    fn dragon_read_from_dirty_owner_keeps_owner_dirty() {
        let mut d = Directory::new();
        Dragon.write_shared(&mut d, l(1), 0); // owner, dirty copy
        let g = Dragon.read_shared(&mut d, l(1), 1);
        assert_eq!(g.actions.owner_writeback, Some(0));
        assert!(g.actions.keep_owner_dirty, "Sm: owner retains writeback duty");
        assert_eq!(d.entry(l(1)).unwrap().state, DirState::Shared);
    }

    #[test]
    fn dragon_write_steal_retains_old_owner_as_sharer() {
        let mut d = Directory::new();
        Dragon.write_shared(&mut d, l(1), 0);
        let g = Dragon.write_shared(&mut d, l(1), 1);
        assert_eq!(g.actions.owner_writeback, Some(0));
        assert_eq!(g.actions.invalidations, 0);
        assert_eq!(g.actions.update_mask, 0b1, "old owner is updated, not dropped");
        let e = d.entry(l(1)).unwrap();
        assert_eq!(e.state, DirState::Shared);
        assert!(e.is_sharer(0) && e.is_sharer(1));
        d.check_invariants().unwrap();
    }

    #[test]
    fn dragon_last_sharer_eviction_degrades_to_exclusive() {
        let mut d = Directory::new();
        Dragon.read_shared(&mut d, l(1), 0);
        Dragon.read_shared(&mut d, l(1), 1);
        Dragon.write_shared(&mut d, l(1), 0); // Shared {0,1}, broadcasting
        Dragon.evict(&mut d, l(1), 1, false);
        assert_eq!(
            d.entry(l(1)).unwrap().state,
            DirState::Owned { owner: 0 },
            "survivor stops being a broadcast target"
        );
        // and its next write is silent
        let g = Dragon.write_shared(&mut d, l(1), 0);
        assert_eq!(g.actions.update_mask, 0);
        assert!(g.exclusive);
        d.check_invariants().unwrap();
    }

    #[test]
    fn dragon_recall_invalidates_like_mesi() {
        let mut d = Directory::new();
        Dragon.read_shared(&mut d, l(1), 0);
        Dragon.read_shared(&mut d, l(1), 1);
        let (mask, act) = Dragon.recall(&mut d, l(1));
        assert_eq!(mask, 0b11);
        assert_eq!(act.invalidations, 2);
        assert!(d.entry(l(1)).is_none());
    }

    // ---- partial coherence ----

    #[test]
    fn partial_never_touches_the_directory() {
        let mut d = Directory::new();
        let p = PartialCoherence;
        assert!(p.read_shared(&mut d, l(1), 0).exclusive);
        assert!(p.write_shared(&mut d, l(1), 1).exclusive);
        p.evict(&mut d, l(1), 0, true);
        let (mask, act) = p.recall(&mut d, l(1));
        assert_eq!(mask, 0);
        assert_eq!(act, CoherenceActions::default());
        assert!(d.is_empty(), "partial coherence keeps the directory empty");
        assert!(!p.is_coherent());
    }

    #[test]
    fn partial_grants_carry_no_traffic() {
        let mut d = Directory::new();
        let g = PartialCoherence.write_shared(&mut d, l(7), 2);
        assert_eq!(g.actions, CoherenceActions::default());
        assert_eq!(g.actions.dir_msgs, 0);
    }
}
