//! The composable protocol stack: hierarchy shape, timing and merge
//! policy as *data*, not code.
//!
//! The memory system used to bake one fixed 3-level shape and the
//! Table 2 latency constants into the protocol engine; this module is
//! the decomposition that makes topology a configuration row:
//!
//! * [`level`] — [`LevelConfig`](level::LevelConfig) (size / ways /
//!   latency / shared-vs-private) and the instantiated
//!   [`Level`](level::Level) tag arrays
//! * [`path`] — [`AccessPath`](path::AccessPath): the protocol-generic
//!   walk over an arbitrary stack of private levels + one shared level,
//!   with the directory co-located at the shared level
//! * [`protocol`] — [`CoherenceProtocol`](protocol::CoherenceProtocol):
//!   the coherence state machine as a trait, with MESI
//!   (write-invalidate), Dragon (write-update) and partial coherence
//!   (non-coherent shared level) behind one registry
//!   ([`ProtocolKind`](protocol::ProtocolKind))
//! * [`timing`] — [`Timing`](timing::Timing): machine-wide latencies
//!   (memory, interleaver quantum, lock backoff, update messages)
//!   replacing the hard-coded Table 2 constants
//! * [`merge_policy`] — [`MergePolicy`](merge_policy::MergePolicy): the
//!   merge / merge-on-evict / dirty-merge decisions behind a trait, with
//!   the paper's policy as the default implementation
//!
//! The CCache machinery itself (source buffer, MFRF, private updated
//! copies, merge execution) stays in
//! [`memsys`](crate::sim::memsys) — it is per-core engine state, not
//! hierarchy structure. Only the innermost level holds CData.

pub mod level;
pub mod merge_policy;
pub mod path;
pub mod protocol;
pub mod timing;

pub use level::{Level, LevelConfig};
pub use merge_policy::{MergeDecision, MergePolicy, PaperMergePolicy};
pub use path::{AccessPath, CoherentWalk, FillReq};
pub use protocol::{CoherenceProtocol, Grant, ProtocolKind};
pub use timing::Timing;
