//! The per-core source buffer (Section 4.1, Figure 4).
//!
//! A small, fully-associative, cache-line-granularity memory that
//! preserves the *source copy* of every CData line the core has
//! privatized. One entry corresponds 1:1 with a CData line in the core's
//! L1. Entries are LRU-replaced; replacing a valid entry forces a merge
//! of its line (counted as a source-buffer eviction — the Fig 9 metric).
//!
//! Each entry also carries the core's private *updated* copy ([`upd`]):
//! the COp working data that, in hardware, lives in the L1 data array.
//! Keeping it next to the source copy gives the engine O(1) slot-indexed
//! access on the COp hit path (via [`SourceBuffer::upd`]) instead of a
//! hash lookup per word access.
//!
//! [`upd`]: SourceEntry::upd

use super::addr::Line;
use crate::merge::LineData;

#[derive(Clone, Copy, Debug)]
pub struct SourceEntry {
    pub line: Line,
    /// The source copy: the line's memory value at privatization time.
    pub data: LineData,
    /// The updated copy: the core's private working data, mutated by
    /// c_read/c_write and handed to the merge function on eviction.
    pub upd: LineData,
    /// MFRF slot index of the line's merge function — the buffer stores
    /// the *slot*, not the function: the MFRF
    /// ([`crate::sim::mfrf::Mfrf`]) resolves the installed
    /// [`MergeHandle`](crate::merge::MergeHandle) at merge time, exactly
    /// as the hardware would read the register file. `merge_init` may
    /// rebind a slot, and a COp may re-type the line itself —
    /// [`SourceBuffer::set_merge_type`] keeps this field in lock-step
    /// with the L1 meta's merge-type bits so the merge engine resolves
    /// the function the *last* COp named.
    pub merge_type: u8,
    lru: u64,
    valid: bool,
}

pub struct SourceBuffer {
    entries: Vec<SourceEntry>,
    tick: u64,
}

impl SourceBuffer {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            entries: vec![
                SourceEntry {
                    line: Line(0),
                    data: [0; 16],
                    upd: [0; 16],
                    merge_type: 0,
                    lru: 0,
                    valid: false,
                };
                capacity
            ],
            tick: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.entries.len()
    }

    pub fn len(&self) -> usize {
        self.entries.iter().filter(|e| e.valid).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_full(&self) -> bool {
        self.len() == self.capacity()
    }

    /// Look up the source copy for `line`, refreshing LRU.
    pub fn get(&mut self, line: Line) -> Option<&SourceEntry> {
        self.tick += 1;
        let tick = self.tick;
        self.entries
            .iter_mut()
            .find(|e| e.valid && e.line == line)
            .map(|e| {
                e.lru = tick;
                &*e
            })
    }

    pub fn contains(&self, line: Line) -> bool {
        self.entries.iter().any(|e| e.valid && e.line == line)
    }

    /// The LRU valid entry — the one a capacity eviction will merge.
    pub fn lru_entry(&self) -> Option<&SourceEntry> {
        self.entries
            .iter()
            .filter(|e| e.valid)
            .min_by_key(|e| e.lru)
    }

    /// Insert a source copy (the updated copy starts identical), and
    /// return the slot index for later O(1) [`upd`](Self::upd) access.
    /// Slots are stable until `remove`/`clear`. Precondition: `line`
    /// absent and not full (memsys merges the LRU entry first when at
    /// capacity).
    pub fn insert(&mut self, line: Line, data: LineData, merge_type: u8) -> usize {
        debug_assert!(!self.contains(line), "duplicate source entry");
        self.tick += 1;
        let tick = self.tick;
        let slot = self
            .entries
            .iter()
            .position(|e| !e.valid)
            .expect("source buffer full; caller must evict first");
        self.entries[slot] = SourceEntry {
            line,
            data,
            upd: data,
            merge_type,
            lru: tick,
            valid: true,
        };
        slot
    }

    /// The updated (private working) copy in `slot`.
    #[inline]
    pub fn upd(&self, slot: usize) -> &LineData {
        debug_assert!(self.entries[slot].valid, "stale source-buffer slot");
        &self.entries[slot].upd
    }

    /// Mutable access to the updated copy in `slot` (the c_write path).
    #[inline]
    pub fn upd_mut(&mut self, slot: usize) -> &mut LineData {
        debug_assert!(self.entries[slot].valid, "stale source-buffer slot");
        &mut self.entries[slot].upd
    }

    /// The line held in `slot`, or `None` if the slot is invalid or out
    /// of range (invariant checks validate `cdata_slot` bindings with
    /// this — see `MemSystem::check_invariants`, invariant 6).
    pub fn slot_line(&self, slot: usize) -> Option<Line> {
        self.entries
            .get(slot)
            .filter(|e| e.valid)
            .map(|e| e.line)
    }

    /// Rebind the merge-type slot of `line`'s entry (no-op when the line
    /// holds no source copy). A COp that re-types an already-privatized
    /// line rewrites the L1 meta's merge-type field; the source copy's
    /// binding must follow, or the eventual merge resolves the *stale*
    /// slot (see `MemSystem::check_invariants`, invariant 5).
    pub fn set_merge_type(&mut self, line: Line, merge_type: u8) {
        if let Some(e) = self
            .entries
            .iter_mut()
            .find(|e| e.valid && e.line == line)
        {
            e.merge_type = merge_type;
        }
    }

    /// Remove `line`'s entry, returning it.
    pub fn remove(&mut self, line: Line) -> Option<SourceEntry> {
        let e = self
            .entries
            .iter_mut()
            .find(|e| e.valid && e.line == line)?;
        e.valid = false;
        Some(*e)
    }

    /// All valid entries, in slot order (diagnostic/invariant use).
    pub fn iter_valid(&self) -> impl Iterator<Item = &SourceEntry> {
        self.entries.iter().filter(|e| e.valid)
    }

    /// Collect the valid lines oldest-first into `out` (merge walks the
    /// buffer in this order, Table 1). The caller owns `out` and reuses
    /// it across merges, so the per-`soft_merge` allocation the old
    /// `valid_entries()` paid is gone after the scratch's first growth.
    pub fn collect_oldest_first(&self, out: &mut Vec<(u64, Line)>) {
        out.clear();
        out.extend(
            self.entries
                .iter()
                .filter(|e| e.valid)
                .map(|e| (e.lru, e.line)),
        );
        out.sort_unstable_by_key(|&(lru, _)| lru);
    }

    /// Flash-clear (end of a full merge, Table 1).
    pub fn clear(&mut self) {
        for e in &mut self.entries {
            e.valid = false;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l(v: u64) -> Line {
        Line(v)
    }

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut sb = SourceBuffer::new(4);
        sb.insert(l(7), [7; 16], 2);
        assert_eq!(sb.len(), 1);
        let e = sb.get(l(7)).unwrap();
        assert_eq!(e.data[0], 7);
        assert_eq!(e.merge_type, 2);
        let removed = sb.remove(l(7)).unwrap();
        assert_eq!(removed.line, l(7));
        assert!(sb.is_empty());
    }

    #[test]
    fn lru_entry_is_least_recently_touched() {
        let mut sb = SourceBuffer::new(3);
        sb.insert(l(1), [1; 16], 0);
        sb.insert(l(2), [2; 16], 0);
        sb.insert(l(3), [3; 16], 0);
        sb.get(l(1)); // refresh 1
        assert_eq!(sb.lru_entry().unwrap().line, l(2));
    }

    #[test]
    fn collect_oldest_first_orders_by_lru_and_reuses_scratch() {
        let mut sb = SourceBuffer::new(4);
        sb.insert(l(5), [0; 16], 0);
        sb.insert(l(6), [0; 16], 0);
        sb.get(l(5));
        let mut scratch = vec![(99, l(99))]; // stale content must vanish
        sb.collect_oldest_first(&mut scratch);
        let order: Vec<u64> = scratch.iter().map(|&(_, line)| line.0).collect();
        assert_eq!(order, vec![6, 5]);
    }

    #[test]
    fn upd_starts_as_source_copy_and_tracks_writes() {
        let mut sb = SourceBuffer::new(2);
        let slot = sb.insert(l(1), [3; 16], 0);
        assert_eq!(sb.upd(slot)[4], 3);
        sb.upd_mut(slot)[4] = 9;
        assert_eq!(sb.upd(slot)[4], 9);
        // the source copy is untouched
        let e = sb.remove(l(1)).unwrap();
        assert_eq!(e.data[4], 3);
        assert_eq!(e.upd[4], 9);
    }

    #[test]
    #[should_panic(expected = "source buffer full")]
    fn overflow_panics_without_evict() {
        let mut sb = SourceBuffer::new(2);
        sb.insert(l(1), [0; 16], 0);
        sb.insert(l(2), [0; 16], 0);
        sb.insert(l(3), [0; 16], 0);
    }

    #[test]
    fn set_merge_type_rebinds_only_the_named_line() {
        let mut sb = SourceBuffer::new(4);
        sb.insert(l(1), [0; 16], 0);
        sb.insert(l(2), [0; 16], 0);
        sb.set_merge_type(l(1), 3);
        assert_eq!(sb.get(l(1)).unwrap().merge_type, 3);
        assert_eq!(sb.get(l(2)).unwrap().merge_type, 0);
        // absent lines are a no-op, not a panic
        sb.set_merge_type(l(9), 1);
        assert!(!sb.contains(l(9)));
    }

    #[test]
    fn slots_are_stable_and_reused_after_remove() {
        let mut sb = SourceBuffer::new(2);
        let s1 = sb.insert(l(1), [1; 16], 0);
        let s2 = sb.insert(l(2), [2; 16], 0);
        assert_ne!(s1, s2);
        sb.remove(l(1));
        // s2 still addresses line 2's entry
        assert_eq!(sb.upd(s2)[0], 2);
        // the freed slot is handed out again
        let s3 = sb.insert(l(3), [3; 16], 0);
        assert_eq!(s3, s1);
    }

    #[test]
    fn slot_line_reports_only_live_slots() {
        let mut sb = SourceBuffer::new(2);
        let s1 = sb.insert(l(4), [0; 16], 0);
        assert_eq!(sb.slot_line(s1), Some(l(4)));
        sb.remove(l(4));
        assert_eq!(sb.slot_line(s1), None);
        assert_eq!(sb.slot_line(99), None);
    }

    #[test]
    fn clear_empties_buffer() {
        let mut sb = SourceBuffer::new(2);
        sb.insert(l(1), [0; 16], 0);
        sb.insert(l(2), [0; 16], 0);
        sb.clear();
        assert!(sb.is_empty());
        assert!(!sb.contains(l(1)));
    }
}
