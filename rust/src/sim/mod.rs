//! Execution-driven multicore simulator with CCache extensions.
//!
//! This is the substrate the paper built on PIN (Section 5): a multicore
//! with a *configurable* cache hierarchy — an arbitrary stack of private
//! levels under one shared level with directory-based MESI coherence —
//! and the CCache additions of Section 4: per-line CCache and mergeable
//! bits, a per-core source buffer, a merge-function register file,
//! merge-register staging, and the merge-on-evict / dirty-merge
//! optimizations behind a pluggable merge policy.
//!
//! The simulator is *execution-driven*: workloads run on real data in a
//! simulated flat memory while every access flows through the timing
//! model. That split lets us check the paper's correctness claim (merged
//! results equal a serialization) against sequential golden runs, not
//! just count cycles.
//!
//! Module map:
//! * [`config`] — the declarative machine description (per-level
//!   geometry/latency, Table 2 defaults, typed [`config::ConfigError`])
//! * [`hierarchy`] — the composable protocol stack:
//!   [`hierarchy::level`] (one cache level as data),
//!   [`hierarchy::path`] (the MESI walk over an arbitrary stack),
//!   [`hierarchy::timing`] (machine-wide latencies) and
//!   [`hierarchy::merge_policy`] (merge decisions as a trait)
//! * [`addr`] — byte/line address helpers
//! * [`cache`] — set-associative cache with per-line CCache metadata
//! * [`directory`] — full-map MESI directory (shared-level-inclusive)
//! * [`source_buffer`] — the per-core source-copy buffer (Section 4.1)
//! * [`mfrf`] — merge-function register file (Section 4.2)
//! * [`memsys`] — the CCache engine over the hierarchy
//! * [`machine`] — cores-as-threads deterministic interleaver
//! * [`core_ctx`] — the `CoreCtx` ISA surface
//!   (`c_read`/`c_write`/`merge`/...), locks and barriers
//! * [`stats`] — the counters behind every figure in Section 6,
//!   per-level vectors following the configured hierarchy depth
//! * [`invariant`] — typed cross-structure invariant-violation errors
//! * [`overhead`] — Section 4.7 area/energy analytical model

pub mod addr;
pub mod cache;
pub mod config;
pub mod core_ctx;
pub mod directory;
pub mod hierarchy;
pub mod invariant;
pub mod machine;
pub mod memsys;
pub mod mfrf;
pub mod overhead;
pub mod source_buffer;
pub mod stats;
