//! Execution-driven multicore simulator with CCache extensions.
//!
//! This is the substrate the paper built on PIN (Section 5): a multicore
//! with per-core private L1/L2, a shared LLC, directory-based MESI
//! coherence, and the CCache additions of Section 4 — per-line CCache and
//! mergeable bits, a per-core source buffer, a merge-function register
//! file, merge-register staging, LLC line locking during merges, and the
//! merge-on-evict / dirty-merge optimizations.
//!
//! The simulator is *execution-driven*: workloads run on real data in a
//! simulated flat memory while every access flows through the timing
//! model. That split lets us check the paper's correctness claim (merged
//! results equal a serialization) against sequential golden runs, not
//! just count cycles.
//!
//! Module map:
//! * [`config`] — Table 2 machine parameters + CCache knobs
//! * [`addr`] — byte/line address helpers
//! * [`cache`] — set-associative cache with per-line CCache metadata
//! * [`directory`] — full-map MESI directory (LLC-inclusive)
//! * [`source_buffer`] — the per-core source-copy buffer (Section 4.1)
//! * [`mfrf`] — merge-function register file (Section 4.2)
//! * [`memsys`] — the coherence + CCache protocol engine
//! * [`machine`] — cores-as-threads deterministic interleaver, the
//!   `CoreCtx` ISA surface (`c_read`/`c_write`/`merge`/...), locks and
//!   barriers
//! * [`stats`] — the counters behind every figure in Section 6
//! * [`overhead`] — Section 4.7 area/energy analytical model

pub mod addr;
pub mod cache;
pub mod config;
pub mod directory;
pub mod machine;
pub mod memsys;
pub mod mfrf;
pub mod overhead;
pub mod source_buffer;
pub mod stats;
