//! The multicore machine: workload threads as simulated cores under a
//! deterministic, laggard-first interleaver.
//!
//! Each simulated core runs as an OS thread executing real workload code
//! against a [`CoreCtx`] — the software-visible ISA surface (`read`,
//! `write`, `c_read`, `c_write`, `merge`, `soft_merge`, `merge_init`,
//! `cas`, locks, barriers, `compute`), defined in
//! [`core_ctx`](super::core_ctx) and re-exported here. A single
//! mutex-protected machine state serializes cores; the *turn* always
//! belongs to the core with the smallest cycle clock (ties to the lowest
//! id), and a core keeps its turn until it runs `quantum` cycles ahead
//! of the laggard. The interleaving is therefore deterministic for a
//! fixed config and seed, while still exhibiting realistic contention
//! (lock hand-offs, invalidation storms, merge serialization).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard};

pub use super::core_ctx::CoreCtx;

use super::config::{ConfigError, MachineConfig};
use super::memsys::MemSystem;
use super::mfrf::MergeFault;
use super::stats::Stats;

/// Machine faults are delivered by unwinding the faulting core thread
/// with the typed [`MergeFault`] as payload, and sibling cores unwind
/// with a "sibling core panicked" notice; both are expected, recovered
/// control flow — not crashes. Filter them out of the process panic
/// hook (once, first Machine construction — the native backend installs
/// the same hook, since its faults unwind identically) so the execution
/// layer's clean diagnostic is not buried under raw panic spew; every
/// other panic still reaches the previous hook untouched.
pub(crate) fn install_quiet_fault_hook() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<MergeFault>().is_some() {
                return;
            }
            if let Some(s) = info.payload().downcast_ref::<String>() {
                if s.starts_with("sibling core panicked") {
                    return;
                }
            }
            prev(info);
        }));
    });
}

pub(crate) struct MachState {
    pub(crate) mem: MemSystem,
    pub(crate) clocks: Vec<u64>,
    pub(crate) turn: usize,
    pub(crate) finished: Vec<bool>,
    pub(crate) waiting: Vec<bool>,
    pub(crate) barrier_gen: u64,
    pub(crate) aborted: bool,
    /// Cached clock bound for the current turn: the turn holder yields
    /// once its clock exceeds this (laggard clock + quantum at the time
    /// the turn was granted). Recomputed on every turn change — turns a
    /// per-op O(cores) scan into one comparison.
    pub(crate) yield_at: u64,
}

impl MachState {
    /// Grant the turn to `next` and cache its yield bound.
    pub(crate) fn grant_turn(&mut self, next: usize, quantum: u64) {
        self.turn = next;
        // bound = min clock among *other* eligible cores + quantum
        let mut min_other = u64::MAX;
        for c in 0..self.clocks.len() {
            if c == next || self.finished[c] || self.waiting[c] {
                continue;
            }
            min_other = min_other.min(self.clocks[c]);
        }
        self.yield_at = min_other.saturating_add(quantum);
    }

    /// The eligible core with the smallest clock (ties to lowest id).
    pub(crate) fn laggard(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for c in 0..self.clocks.len() {
            if self.finished[c] || self.waiting[c] {
                continue;
            }
            if best.map_or(true, |b| self.clocks[c] < self.clocks[b]) {
                best = Some(c);
            }
        }
        best
    }
}

/// The machine: construct, [`Machine::setup`] memory, then
/// [`Machine::run`] one closure per core.
pub struct Machine {
    state: Mutex<MachState>,
    /// One condvar per core: turn hand-offs wake exactly the next core
    /// instead of thundering every sibling (the dominant interleaver
    /// cost before this change — see EXPERIMENTS.md §Perf).
    pub(crate) cvs: Vec<Condvar>,
    pub(crate) quantum: u64,
    pub(crate) lock_backoff: u64,
    cores: usize,
}

impl Machine {
    /// Build the machine a configuration describes; a malformed
    /// configuration is a typed [`ConfigError`].
    pub fn new(cfg: MachineConfig) -> Result<Self, ConfigError> {
        install_quiet_fault_hook();
        let cores = cfg.cores;
        let quantum = cfg.timing.quantum;
        let lock_backoff = cfg.timing.lock_backoff;
        let mem = MemSystem::new(cfg)?;
        Ok(Self {
            state: Mutex::new(MachState {
                mem,
                clocks: vec![0; cores],
                turn: 0,
                finished: vec![false; cores],
                waiting: vec![false; cores],
                barrier_gen: 0,
                aborted: false,
                yield_at: u64::MAX,
            }),
            cvs: (0..cores).map(|_| Condvar::new()).collect(),
            quantum,
            lock_backoff,
            cores,
        })
    }

    pub fn cores(&self) -> usize {
        self.cores
    }

    /// Untimed access to the memory system (allocation, initialization,
    /// final-state verification, machine-fault recovery). Tolerates a
    /// poisoned state mutex so the fault path — a core thread unwinding
    /// on a [`MergeFault`](super::mfrf::MergeFault) — can still read the
    /// recorded fault afterwards.
    pub fn setup<R>(&self, f: impl FnOnce(&mut MemSystem) -> R) -> R {
        let mut g = self.lock_state();
        f(&mut g.mem)
    }

    /// Run one program per core to completion; returns the collected
    /// statistics (core clocks included).
    pub fn run(&self, programs: Vec<Box<dyn FnOnce(&mut CoreCtx) + Send + '_>>) -> Stats {
        assert_eq!(programs.len(), self.cores, "one program per core");
        {
            let mut g = self.state.lock().unwrap();
            for c in 0..self.cores {
                g.finished[c] = false;
                g.waiting[c] = false;
            }
            g.aborted = false;
            let first = g.laggard().unwrap_or(0);
            g.grant_turn(first, self.quantum);
        }
        std::thread::scope(|scope| {
            let mut handles = VecDeque::new();
            for (core, prog) in programs.into_iter().enumerate() {
                let machine = &*self;
                handles.push_back(scope.spawn(move || {
                    let result = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| {
                            let mut ctx = CoreCtx::new(machine, core);
                            prog(&mut ctx);
                            ctx.finish();
                        }),
                    );
                    if let Err(payload) = result {
                        machine.abort(core);
                        std::panic::resume_unwind(payload);
                    }
                }));
            }
            // joining happens at scope exit; propagate the first panic
            let mut first_panic = None;
            while let Some(h) = handles.pop_front() {
                if let Err(p) = h.join() {
                    first_panic.get_or_insert(p);
                }
            }
            if let Some(p) = first_panic {
                std::panic::resume_unwind(p);
            }
        });
        let mut g = self.state.lock().unwrap();
        // end of run: fold the fast-path scratch counters in before the
        // stats are cloned out, and publish any stores still buffered
        // under partial coherence so `Workload::verify` reads final data
        g.mem.flush_hot_stats();
        g.mem.publish_partial_all();
        let clocks = g.clocks.clone();
        let mut stats = g.mem.stats.clone();
        stats.core_cycles = clocks;
        g.mem.stats.core_cycles = stats.core_cycles.clone();
        stats
    }

    /// Mark a crashed core and wake everyone so sibling threads can bail.
    fn abort(&self, core: usize) {
        let mut g = match self.state.lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        };
        g.aborted = true;
        g.finished[core] = true;
        drop(g);
        self.notify_everyone();
    }

    #[inline]
    pub(crate) fn notify_core(&self, core: usize) {
        self.cvs[core].notify_one();
    }

    pub(crate) fn notify_everyone(&self) {
        for cv in &self.cvs {
            cv.notify_all();
        }
    }

    pub(crate) fn lock_state(&self) -> MutexGuard<'_, MachState> {
        match self.state.lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        }
    }
}
