//! The multicore machine: workload threads as simulated cores under a
//! deterministic, laggard-first interleaver.
//!
//! Each simulated core runs as an OS thread executing real workload code
//! against a [`CoreCtx`] — the software-visible ISA surface (`read`,
//! `write`, `c_read`, `c_write`, `merge`, `soft_merge`, `merge_init`,
//! `cas`, locks, barriers, `compute`). A single mutex-protected machine
//! state serializes cores; the *turn* always belongs to the core with the
//! smallest cycle clock (ties to the lowest id), and a core keeps its
//! turn until it runs `quantum` cycles ahead of the laggard. The
//! interleaving is therefore deterministic for a fixed config and seed,
//! while still exhibiting realistic contention (lock hand-offs,
//! invalidation storms, merge serialization).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard};

use super::addr::Addr;
use super::config::MachineConfig;
use super::memsys::MemSystem;
use super::stats::Stats;
use crate::merge::MergeKind;

struct MachState {
    mem: MemSystem,
    clocks: Vec<u64>,
    turn: usize,
    finished: Vec<bool>,
    waiting: Vec<bool>,
    barrier_gen: u64,
    aborted: bool,
    /// Cached clock bound for the current turn: the turn holder yields
    /// once its clock exceeds this (laggard clock + quantum at the time
    /// the turn was granted). Recomputed on every turn change — turns a
    /// per-op O(cores) scan into one comparison.
    yield_at: u64,
}

impl MachState {
    /// Grant the turn to `next` and cache its yield bound.
    fn grant_turn(&mut self, next: usize, quantum: u64) {
        self.turn = next;
        // bound = min clock among *other* eligible cores + quantum
        let mut min_other = u64::MAX;
        for c in 0..self.clocks.len() {
            if c == next || self.finished[c] || self.waiting[c] {
                continue;
            }
            min_other = min_other.min(self.clocks[c]);
        }
        self.yield_at = min_other.saturating_add(quantum);
    }

    /// The eligible core with the smallest clock (ties to lowest id).
    fn laggard(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for c in 0..self.clocks.len() {
            if self.finished[c] || self.waiting[c] {
                continue;
            }
            if best.map_or(true, |b| self.clocks[c] < self.clocks[b]) {
                best = Some(c);
            }
        }
        best
    }
}

/// The machine: construct, [`Machine::setup`] memory, then
/// [`Machine::run`] one closure per core.
pub struct Machine {
    state: Mutex<MachState>,
    /// One condvar per core: turn hand-offs wake exactly the next core
    /// instead of thundering every sibling (the dominant interleaver
    /// cost before this change — see EXPERIMENTS.md §Perf).
    cvs: Vec<Condvar>,
    quantum: u64,
    lock_backoff: u64,
    cores: usize,
}

impl Machine {
    pub fn new(cfg: MachineConfig) -> Self {
        let cores = cfg.cores;
        let quantum = cfg.quantum;
        let lock_backoff = cfg.lock_backoff;
        Self {
            state: Mutex::new(MachState {
                mem: MemSystem::new(cfg),
                clocks: vec![0; cores],
                turn: 0,
                finished: vec![false; cores],
                waiting: vec![false; cores],
                barrier_gen: 0,
                aborted: false,
                yield_at: u64::MAX,
            }),
            cvs: (0..cores).map(|_| Condvar::new()).collect(),
            quantum,
            lock_backoff,
            cores,
        }
    }

    pub fn cores(&self) -> usize {
        self.cores
    }

    /// Untimed access to the memory system (allocation, initialization,
    /// final-state verification).
    pub fn setup<R>(&self, f: impl FnOnce(&mut MemSystem) -> R) -> R {
        let mut g = self.state.lock().unwrap();
        f(&mut g.mem)
    }

    /// Run one program per core to completion; returns the collected
    /// statistics (core clocks included).
    pub fn run(&self, programs: Vec<Box<dyn FnOnce(&mut CoreCtx) + Send + '_>>) -> Stats {
        assert_eq!(programs.len(), self.cores, "one program per core");
        {
            let mut g = self.state.lock().unwrap();
            for c in 0..self.cores {
                g.finished[c] = false;
                g.waiting[c] = false;
            }
            g.aborted = false;
            let first = g.laggard().unwrap_or(0);
            g.grant_turn(first, self.quantum);
        }
        std::thread::scope(|scope| {
            let mut handles = VecDeque::new();
            for (core, prog) in programs.into_iter().enumerate() {
                let machine = &*self;
                handles.push_back(scope.spawn(move || {
                    let result = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| {
                            let mut ctx = CoreCtx {
                                machine,
                                core,
                                guard: None,
                            };
                            prog(&mut ctx);
                            ctx.finish();
                        }),
                    );
                    if let Err(payload) = result {
                        machine.abort(core);
                        std::panic::resume_unwind(payload);
                    }
                }));
            }
            // joining happens at scope exit; propagate the first panic
            let mut first_panic = None;
            while let Some(h) = handles.pop_front() {
                if let Err(p) = h.join() {
                    first_panic.get_or_insert(p);
                }
            }
            if let Some(p) = first_panic {
                std::panic::resume_unwind(p);
            }
        });
        let mut g = self.state.lock().unwrap();
        let clocks = g.clocks.clone();
        let mut stats = g.mem.stats.clone();
        stats.core_cycles = clocks;
        g.mem.stats.core_cycles = stats.core_cycles.clone();
        stats
    }

    /// Mark a crashed core and wake everyone so sibling threads can bail.
    fn abort(&self, core: usize) {
        let mut g = match self.state.lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        };
        g.aborted = true;
        g.finished[core] = true;
        drop(g);
        self.notify_everyone();
    }

    #[inline]
    fn notify_core(&self, core: usize) {
        self.cvs[core].notify_one();
    }

    fn notify_everyone(&self) {
        for cv in &self.cvs {
            cv.notify_all();
        }
    }

    fn lock_state(&self) -> MutexGuard<'_, MachState> {
        match self.state.lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        }
    }
}

/// The per-core execution context: every method is one "instruction" that
/// advances the core's clock through the timing model.
pub struct CoreCtx<'m> {
    machine: &'m Machine,
    core: usize,
    guard: Option<MutexGuard<'m, MachState>>,
}

impl<'m> CoreCtx<'m> {
    pub fn core_id(&self) -> usize {
        self.core
    }

    /// Current simulated cycle count of this core.
    pub fn cycles(&mut self) -> u64 {
        let core = self.core;
        self.state().clocks[core]
    }

    // ---- turn management -------------------------------------------------

    /// Acquire the machine state, waiting until it is this core's turn.
    fn state(&mut self) -> &mut MachState {
        if self.guard.is_none() {
            let mut g = self.machine.lock_state();
            while !g.aborted && g.turn != self.core {
                g = match self.machine.cvs[self.core].wait(g) {
                    Ok(g) => g,
                    Err(poison) => poison.into_inner(),
                };
            }
            if g.aborted {
                panic!("sibling core panicked; aborting core {}", self.core);
            }
            self.guard = Some(g);
        }
        self.guard.as_mut().unwrap()
    }

    /// After an operation: hand the turn over if we ran past the laggard.
    fn maybe_yield(&mut self) {
        let quantum = self.machine.quantum;
        let core = self.core;
        let g = match self.guard.as_mut() {
            Some(g) => g,
            None => return,
        };
        // fast path: still within the cached bound — no scan, no notify
        if g.clocks[core] <= g.yield_at {
            return;
        }
        if let Some(next) = g.laggard() {
            if next != core && g.clocks[next] + quantum < g.clocks[core] {
                g.grant_turn(next, quantum);
                self.guard = None; // drop the guard
                self.machine.notify_core(next);
                return;
            }
        }
        // we remain the laggard: refresh the bound
        g.grant_turn(core, quantum);
    }

    /// Unconditionally pass the turn (lock spins, barriers).
    fn yield_turn(&mut self) {
        let core = self.core;
        let g = match self.guard.as_mut() {
            Some(g) => g,
            None => return,
        };
        if let Some(next) = g.laggard() {
            if next != core {
                let q = self.machine.quantum;
                g.grant_turn(next, q);
                self.guard = None;
                self.machine.notify_core(next);
                return;
            }
        }
        // we remain the laggard: keep the turn
    }

    fn finish(&mut self) {
        let core = self.core;
        let quantum = self.machine.quantum;
        let g = self.state();
        g.finished[core] = true;
        // if every remaining active core is blocked at a barrier, this
        // finish is what releases it
        let all_waiting = (0..g.clocks.len()).all(|c| g.finished[c] || g.waiting[c]);
        let any_waiting = (0..g.clocks.len()).any(|c| g.waiting[c]);
        if all_waiting && any_waiting {
            let maxc = (0..g.clocks.len())
                .filter(|&c| g.waiting[c])
                .map(|c| g.clocks[c])
                .max()
                .unwrap_or(0);
            for c in 0..g.clocks.len() {
                if g.waiting[c] {
                    g.clocks[c] = g.clocks[c].max(maxc);
                    g.waiting[c] = false;
                }
            }
            g.barrier_gen += 1;
            if let Some(next) = g.laggard() {
                g.grant_turn(next, quantum);
            }
            self.guard = None;
            self.machine.notify_everyone();
            return;
        }
        if let Some(next) = g.laggard() {
            g.grant_turn(next, quantum);
        }
        self.guard = None;
        self.machine.notify_everyone();
    }

    // ---- timed operations -------------------------------------------------

    fn charge(&mut self, cycles: u64) {
        let core = self.core;
        self.state().clocks[core] += cycles;
        self.maybe_yield();
    }

    /// Non-memory work: `n` instructions at 1 cycle each (Table 2).
    pub fn compute(&mut self, n: u64) {
        self.charge(n);
    }

    pub fn read_u32(&mut self, addr: Addr) -> u32 {
        let core = self.core;
        let (v, c) = self.state().mem.read(core, addr);
        self.charge(c);
        v
    }

    pub fn write_u32(&mut self, addr: Addr, val: u32) {
        let core = self.core;
        let c = self.state().mem.write(core, addr, val);
        self.charge(c);
    }

    pub fn read_f32(&mut self, addr: Addr) -> f32 {
        f32::from_bits(self.read_u32(addr))
    }

    pub fn write_f32(&mut self, addr: Addr, val: f32) {
        self.write_u32(addr, val.to_bits());
    }

    pub fn cas_u32(&mut self, addr: Addr, expected: u32, new: u32) -> bool {
        let core = self.core;
        let (ok, c) = self.state().mem.cas(core, addr, expected, new);
        self.charge(c);
        ok
    }

    pub fn fetch_or_u32(&mut self, addr: Addr, bits: u32) -> u32 {
        let core = self.core;
        let (old, c) = self.state().mem.fetch_or(core, addr, bits);
        self.charge(c);
        old
    }

    // ---- CCache ISA (Table 1) ----------------------------------------------

    /// `merge_init(&fn, i)`.
    pub fn merge_init(&mut self, slot: usize, kind: MergeKind) {
        let core = self.core;
        self.state().mem.merge_init(core, slot, kind);
        self.charge(1);
    }

    /// `c_read(CData, i)`.
    pub fn c_read_u32(&mut self, addr: Addr, ty: u8) -> u32 {
        let core = self.core;
        let (v, c) = self.state().mem.c_read(core, addr, ty);
        self.charge(c);
        v
    }

    /// `c_write(CData, v, i)`.
    pub fn c_write_u32(&mut self, addr: Addr, val: u32, ty: u8) {
        let core = self.core;
        let c = self.state().mem.c_write(core, addr, val, ty);
        self.charge(c);
    }

    pub fn c_read_f32(&mut self, addr: Addr, ty: u8) -> f32 {
        f32::from_bits(self.c_read_u32(addr, ty))
    }

    pub fn c_write_f32(&mut self, addr: Addr, val: f32, ty: u8) {
        self.c_write_u32(addr, val.to_bits(), ty);
    }

    /// `soft_merge` — mark CData mergeable (merge-on-evict).
    pub fn soft_merge(&mut self) {
        let core = self.core;
        let c = self.state().mem.soft_merge(core);
        self.charge(c);
    }

    /// `merge` — merge all of this core's CData now.
    pub fn merge(&mut self) {
        let core = self.core;
        let c = self.state().mem.merge_all(core);
        self.charge(c);
    }

    // ---- synchronization ----------------------------------------------------

    /// Spin lock acquire: CAS loop with backoff; the turn is handed to the
    /// laggard between attempts so the owner can make progress.
    pub fn lock(&mut self, addr: Addr) {
        let backoff = self.machine.lock_backoff;
        let core = self.core;
        loop {
            let (ok, c) = self.state().mem.cas(core, addr, 0, 1);
            {
                let g = self.guard.as_mut().unwrap();
                g.clocks[core] += c;
                if ok {
                    g.mem.stats.lock_acquires += 1;
                } else {
                    g.mem.stats.lock_retries += 1;
                    g.clocks[core] += backoff;
                }
            }
            if ok {
                self.maybe_yield();
                return;
            }
            self.yield_turn();
        }
    }

    /// Spin lock release: coherent store of 0.
    pub fn unlock(&mut self, addr: Addr) {
        self.write_u32(addr, 0);
    }

    /// Merge boundary barrier (Section 3.2.1): all cores must arrive;
    /// clocks synchronize to the latest arrival.
    pub fn barrier(&mut self) {
        let core = self.core;
        let quantum = self.machine.quantum;
        let gen = {
            let g = self.state();
            g.mem.stats.barriers += 1;
            g.waiting[core] = true;
            let gen = g.barrier_gen;
            let all_waiting = (0..g.clocks.len()).all(|c| g.finished[c] || g.waiting[c]);
            if all_waiting {
                let maxc = (0..g.clocks.len())
                    .filter(|&c| g.waiting[c])
                    .map(|c| g.clocks[c])
                    .max()
                    .unwrap_or(0);
                for c in 0..g.clocks.len() {
                    if g.waiting[c] {
                        g.clocks[c] = g.clocks[c].max(maxc);
                        g.waiting[c] = false;
                    }
                }
                g.barrier_gen += 1;
                if let Some(next) = g.laggard() {
                    g.grant_turn(next, quantum);
                }
                self.guard = None;
                self.machine.notify_everyone();
                return;
            }
            // others still running: hand over the turn and sleep
            if let Some(next) = g.laggard() {
                g.grant_turn(next, quantum);
            } else {
                panic!("barrier deadlock: no runnable core");
            }
            gen
        };
        let next_after = {
            let g = self.guard.as_ref().unwrap();
            g.turn
        };
        self.guard = None;
        self.machine.notify_core(next_after);
        let mut g = self.machine.lock_state();
        while !g.aborted && g.barrier_gen == gen {
            g = match self.machine.cvs[core].wait(g) {
                Ok(g) => g,
                Err(poison) => poison.into_inner(),
            };
        }
        if g.aborted {
            panic!("sibling core panicked during barrier");
        }
        drop(g);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::addr::Addr;

    fn machine() -> Machine {
        Machine::new(MachineConfig::test_small())
    }

    #[test]
    fn single_core_reads_writes() {
        let m = Machine::new(MachineConfig::test_small().with_cores(1));
        let a = m.setup(|mem| mem.alloc_lines(64));
        let stats = m.run(vec![Box::new(move |ctx: &mut CoreCtx| {
            ctx.write_u32(a, 5);
            let v = ctx.read_u32(a);
            assert_eq!(v, 5);
            ctx.compute(10);
        })]);
        assert!(stats.total_cycles() > 10);
    }

    #[test]
    fn two_cores_interleave_deterministically() {
        let run_once = || {
            let m = machine();
            let a = m.setup(|mem| mem.alloc_lines(64));
            let stats = m.run(vec![
                Box::new(move |ctx: &mut CoreCtx| {
                    for _ in 0..100 {
                        ctx.read_u32(a);
                        ctx.compute(3);
                    }
                }),
                Box::new(move |ctx: &mut CoreCtx| {
                    for _ in 0..100 {
                        ctx.read_u32(a.add(64));
                        ctx.compute(7);
                    }
                }),
            ]);
            (stats.total_cycles(), stats.l1.hits, stats.directory_msgs)
        };
        assert_eq!(run_once(), run_once());
    }

    #[test]
    fn lock_serializes_increments() {
        let m = machine();
        let (lock, data) = m.setup(|mem| (mem.alloc_lines(64), mem.alloc_lines(64)));
        let n = 200u32;
        let mk = |_id: usize| -> Box<dyn FnOnce(&mut CoreCtx) + Send + '_> {
            Box::new(move |ctx: &mut CoreCtx| {
                for _ in 0..n {
                    ctx.lock(lock);
                    let v = ctx.read_u32(data);
                    ctx.write_u32(data, v + 1);
                    ctx.unlock(lock);
                }
            })
        };
        let stats = m.run(vec![mk(0), mk(1)]);
        let total = m.setup(|mem| mem.peek(data));
        assert_eq!(total, 2 * n, "lost updates under lock");
        assert_eq!(stats.lock_acquires, 2 * n as u64);
    }

    #[test]
    fn unsynchronized_ccache_increments_merge_correctly() {
        let m = machine();
        let a = m.setup(|mem| {
            let a = mem.alloc_lines(64);
            mem.poke(a, 1000);
            a
        });
        let n = 50u32;
        let mk = |_| -> Box<dyn FnOnce(&mut CoreCtx) + Send + '_> {
            Box::new(move |ctx: &mut CoreCtx| {
                ctx.merge_init(0, MergeKind::AddU32);
                for _ in 0..n {
                    let v = ctx.c_read_u32(a, 0);
                    ctx.c_write_u32(a, v + 1, 0);
                }
                ctx.merge();
            })
        };
        m.run(vec![mk(0), mk(1)]);
        let v = m.setup(|mem| mem.peek(a));
        assert_eq!(v, 1000 + 2 * n);
    }

    #[test]
    fn barrier_synchronizes_clocks() {
        let m = machine();
        let a = m.setup(|mem| mem.alloc_lines(128));
        let stats = m.run(vec![
            Box::new(move |ctx: &mut CoreCtx| {
                ctx.compute(10_000); // slow phase 1
                ctx.barrier();
                ctx.write_u32(a, ctx.core_id() as u32 + 1);
            }),
            Box::new(move |ctx: &mut CoreCtx| {
                ctx.compute(10); // fast phase 1
                ctx.barrier();
                ctx.write_u32(a.add(64), ctx.core_id() as u32 + 1);
            }),
        ]);
        // both cores' final clocks must be >= the barrier sync point
        assert!(stats.core_cycles.iter().all(|&c| c >= 10_000));
        assert_eq!(stats.barriers, 2);
    }

    #[test]
    fn barrier_orders_phases() {
        // phase 1: core 0 writes; phase 2: core 1 reads the value
        let m = machine();
        let a = m.setup(|mem| mem.alloc_lines(64));
        m.run(vec![
            Box::new(move |ctx: &mut CoreCtx| {
                ctx.write_u32(a, 77);
                ctx.barrier();
            }),
            Box::new(move |ctx: &mut CoreCtx| {
                ctx.barrier();
                assert_eq!(ctx.read_u32(a), 77);
            }),
        ]);
    }

    #[test]
    fn merge_boundary_pattern_makes_data_visible() {
        // the paper's merge boundary: merge + barrier, then read
        let m = machine();
        let a = m.setup(|mem| mem.alloc_lines(64));
        m.run(vec![
            Box::new(move |ctx: &mut CoreCtx| {
                ctx.merge_init(0, MergeKind::AddU32);
                let v = ctx.c_read_u32(a, 0);
                ctx.c_write_u32(a, v + 5, 0);
                ctx.merge();
                ctx.barrier();
            }),
            Box::new(move |ctx: &mut CoreCtx| {
                ctx.merge_init(0, MergeKind::AddU32);
                let v = ctx.c_read_u32(a, 0);
                ctx.c_write_u32(a, v + 7, 0);
                ctx.merge();
                ctx.barrier();
                assert_eq!(ctx.read_u32(a), 12);
            }),
        ]);
    }

    #[test]
    #[should_panic]
    fn core_panic_propagates() {
        let m = machine();
        m.run(vec![
            Box::new(|_ctx: &mut CoreCtx| panic!("boom")),
            Box::new(|ctx: &mut CoreCtx| {
                for _ in 0..1000 {
                    ctx.compute(100);
                }
            }),
        ]);
    }

    #[test]
    fn quantum_zero_still_completes() {
        let mut cfg = MachineConfig::test_small();
        cfg.quantum = 0;
        let m = Machine::new(cfg);
        let a = m.setup(|mem| mem.alloc_lines(64));
        let stats = m.run(vec![
            Box::new(move |ctx: &mut CoreCtx| {
                for i in 0..50 {
                    ctx.write_u32(a, i);
                }
            }),
            Box::new(move |ctx: &mut CoreCtx| {
                for _ in 0..50 {
                    ctx.read_u32(a);
                }
            }),
        ]);
        assert!(stats.total_cycles() > 0);
    }
}
