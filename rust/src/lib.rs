//! # ccache-rs
//!
//! Reproduction of *"Flexible Support for Fast Parallel Commutative
//! Updates"* (Balaji, Tirumala, Lucia — CMU, 2017): **CCache**, an
//! architecture for on-demand privatization of commutatively-updated data
//! with programmer-defined software merge functions.
//!
//! The crate is the Layer-3 rust side of a three-layer stack:
//!
//! * [`sim`] — execution-driven multicore simulator: set-associative
//!   caches, directory MESI coherence over a *configurable* hierarchy
//!   ([`sim::hierarchy`]: levels, access path, timing and merge policy
//!   as data), and the paper's CCache hardware extensions
//!   (CCache/mergeable bits, source buffer, MFRF, merge registers,
//!   merge-on-evict and dirty-merge optimizations).
//! * [`merge`] — the **open** software-defined merge-function API: the
//!   [`merge::MergeFn`] trait, the name→constructor
//!   [`merge::MergeRegistry`], the nine paper built-ins
//!   ([`merge::funcs`]: add, saturating add, complex multiply, bitwise
//!   OR, min/max, approximate) and extension functions
//!   ([`merge::ext`]: XOR, log-sum-exp) registered through the same
//!   public API any user function uses.
//! * [`workloads`] — the benchmark suite (key-value store, K-Means,
//!   PageRank, BFS, histogram, and the streaming-sketch family:
//!   count-min, Bloom filter, HyperLogLog) plus the graph substrate and
//!   generators; each benchmark is one [`exec::Workload`] trait impl.
//!   `workloads::sketch` also defines the `max_u8x64` merge function,
//!   registered through the public merge registry only.
//! * [`exec`] — the execution layer: the variants the paper compares
//!   (coarse/fine-grained locking, static duplication, atomics, CCache),
//!   the [`exec::Workload`] trait, the generic [`exec::driver`] that
//!   runs any workload/variant with golden verification, and the
//!   [`exec::registry`] the CLI and coordinator dispatch through.
//! * [`runtime`] — PJRT client that loads the AOT-compiled JAX/Pallas
//!   merge and compute kernels (`artifacts/*.hlo.txt`) and executes them
//!   from the rust hot path (Python never runs at simulation time).
//! * [`coordinator`] — experiment orchestration: sweeps, per-figure
//!   drivers, report tables.
//! * [`util`] — in-house RNG, CLI parsing, bench harness and
//!   property-test driver (external crates are unavailable offline).

// Simulator-style code: timed loops index many parallel arrays by
// element, constructors take no arguments, and core programs thread
// explicit (ctx, core, cores, variant, layout) state. Keep those
// idioms rather than fighting the style lints.
#![allow(
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod coordinator;
pub mod exec;
pub mod merge;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workloads;

pub use merge::{MergeFn, MergeHandle, MergeRegistry};
pub use sim::config::{CCacheConfig, ConfigError, MachineConfig};
pub use sim::hierarchy::{LevelConfig, MergePolicy, Timing};
pub use sim::machine::Machine;
pub use sim::mfrf::MergeFault;
pub use sim::stats::Stats;
