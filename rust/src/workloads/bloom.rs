//! Bloom-filter ingest — streaming set membership over a shared bit
//! array: cores stream keys and set `hashes` hashed bits per key.
//! Bitwise OR is idempotent and commutative, so the CCache variant
//! reuses the BFS bitmap merge ([`BitOr`]) and every interleaving
//! produces the identical bit array — verification is exact equality
//! with the sequential golden filter (and, by construction, zero false
//! negatives).
//!
//! The contended structure is the bit array itself: hot words shared by
//! every core are exactly the sharing-induced private-cache-miss pattern
//! the ROADMAP's scenario-diversity goal targets.

use crate::exec::registry::SizeSpec;
use crate::exec::scaffold::{DupSpace, LockArray};
use crate::exec::{driver, ExecCtx, RunResult, Variant, Workload};
use crate::merge::funcs::BitOr;
use crate::merge::{handle, MergeHandle};
use crate::sim::addr::Addr;
use crate::sim::config::MachineConfig;
use crate::sim::memsys::MemSystem;
use crate::workloads::sketch::{hash_key, keyed_stream};

/// Salt base for the probe hash family.
const PROBE_SALT: u64 = 0xB1_00;

#[derive(Clone, Debug)]
pub struct BloomParams {
    /// Keys ingested.
    pub items: usize,
    /// Filter size in bits (rounded up to whole u32 words).
    pub bits: usize,
    /// Probes (bits set) per key.
    pub hashes: usize,
    pub seed: u64,
    /// 0.0 = uniform keys; >0 = zipf-skewed (hot keys re-inserted).
    pub zipf_theta: f64,
}

impl Default for BloomParams {
    fn default() -> Self {
        Self {
            items: 8192,
            bits: 1 << 16,
            hashes: 4,
            seed: 0xB1_003,
            zipf_theta: 0.0,
        }
    }
}

impl BloomParams {
    /// Bit-array words (the filter is word-granular in memory).
    pub fn words(&self) -> usize {
        self.bits.div_ceil(32)
    }

    /// Distinct keys the stream draws from.
    pub fn key_space(&self) -> usize {
        // ~m/8 distinct keys with k=4 keeps the fill factor in the
        // filter's useful range
        (self.bits / 8).max(64)
    }

    /// Input stream + bit array (the Fig 6 x-axis).
    pub fn working_set_bytes(&self) -> u64 {
        (self.items * 4 + self.words() * 4) as u64
    }

    /// The bit index of probe `h` for `key`.
    pub fn probe(&self, key: u64, h: usize) -> u64 {
        hash_key(key, PROBE_SALT + h as u64) % (self.words() as u64 * 32)
    }
}

/// Host-side key stream (shared by programs and the golden run).
fn key_stream(p: &BloomParams) -> Vec<u32> {
    keyed_stream(p.seed ^ 0xB100_77, p.items, p.key_space(), p.zipf_theta)
}

/// Sequential golden filter: the bit array as u32 words.
pub fn golden_words(p: &BloomParams) -> Vec<u32> {
    let mut words = vec![0u32; p.words()];
    for key in key_stream(p) {
        for h in 0..p.hashes {
            let bit = p.probe(key as u64, h);
            words[(bit / 32) as usize] |= 1 << (bit % 32);
        }
    }
    words
}

/// Membership query against a golden (or any) word array.
pub fn contains(p: &BloomParams, words: &[u32], key: u64) -> bool {
    (0..p.hashes).all(|h| {
        let bit = p.probe(key, h);
        words[(bit / 32) as usize] & (1 << (bit % 32)) != 0
    })
}

#[derive(Clone, Copy)]
pub struct BloomLayout {
    input: Addr,
    words: Addr,
    locks: LockArray,
    copies: DupSpace,
}

const SLOT_BITOR: usize = 0;

/// The variants Bloom implements (CGL is pointless for a bit array the
/// paper's FGL already locks at word granularity).
pub const VARIANTS: [Variant; 4] = [
    Variant::Fgl,
    Variant::Dup,
    Variant::CCache,
    Variant::Atomic,
];

pub struct BloomWorkload {
    p: BloomParams,
}

impl BloomWorkload {
    pub fn new(p: BloomParams) -> Self {
        Self { p }
    }

    /// Size the bit array to `frac` x LLC; the stream scales with it.
    pub fn sized(s: &SizeSpec) -> Self {
        let hashes = if s.sketch.bloom_hashes > 0 {
            s.sketch.bloom_hashes
        } else {
            4
        };
        let bits = (s.target_bytes() * 8).max(2048) as usize;
        Self::new(BloomParams {
            items: (bits / 8).max(1024),
            bits,
            hashes,
            seed: s.seed,
            zipf_theta: s.zipf_theta,
        })
    }

    pub fn params(&self) -> &BloomParams {
        &self.p
    }
}

impl Workload for BloomWorkload {
    type Layout = BloomLayout;
    type Golden = Vec<u32>;

    fn name(&self) -> String {
        "bloom".into()
    }

    fn supported_variants(&self) -> Vec<Variant> {
        VARIANTS.to_vec()
    }

    fn footprint(&self) -> u64 {
        self.p.working_set_bytes()
    }

    fn merge_slots(&self) -> Vec<(usize, MergeHandle)> {
        vec![(SLOT_BITOR, handle(BitOr))]
    }

    fn setup(&self, mem: &mut MemSystem, variant: Variant, cores: usize) -> BloomLayout {
        let p = &self.p;
        let input = mem.alloc_lines(p.items as u64 * 4);
        for (i, k) in key_stream(p).into_iter().enumerate() {
            mem.poke(input.add(i as u64 * 4), k);
        }
        let words = mem.alloc_lines(p.words() as u64 * 4);
        let mut l = BloomLayout {
            input,
            words,
            locks: LockArray::none(),
            copies: DupSpace::none(),
        };
        match variant {
            Variant::Fgl => {
                // one padded lock per bitmap word, as in BFS
                l.locks = LockArray::alloc(mem, p.words() as u64, 64);
            }
            Variant::Dup => {
                l.copies = DupSpace::alloc(mem, p.words() as u64 * 4, cores);
            }
            _ => {}
        }
        l
    }

    fn program<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        cores: usize,
        variant: Variant,
        l: &BloomLayout,
    ) {
        let p = &self.p;
        let lo = core * p.items / cores;
        let hi = (core + 1) * p.items / cores;
        for i in lo..hi {
            let key = ctx.read_u32(l.input.add(i as u64 * 4)) as u64;
            for h in 0..p.hashes {
                let b = p.probe(key, h);
                let (w, bit) = (b / 32, 1u32 << (b % 32));
                let a = l.words.add(w * 4);
                match variant {
                    Variant::Fgl => {
                        l.locks.lock(ctx, w);
                        let v = ctx.read_u32(a);
                        ctx.write_u32(a, v | bit);
                        l.locks.unlock(ctx, w);
                    }
                    Variant::Dup => {
                        let pa = l.copies.copy_base(core).add(w * 4);
                        let v = ctx.read_u32(pa);
                        ctx.write_u32(pa, v | bit);
                    }
                    Variant::CCache => {
                        let v = ctx.c_read_u32(a, SLOT_BITOR as u8);
                        ctx.c_write_u32(a, v | bit, SLOT_BITOR as u8);
                        ctx.soft_merge();
                    }
                    Variant::Atomic => {
                        ctx.fetch_or_u32(a, bit);
                    }
                    Variant::Cgl => unreachable!("driver rejects unsupported variants"),
                }
                ctx.compute(2);
            }
        }
        if variant == Variant::CCache {
            ctx.merge();
        }
        ctx.barrier();
        if variant == Variant::Dup {
            // OR-reduce every core's private bit array into the master,
            // word range partitioned across cores
            let words = p.words() as u64;
            let lo = core as u64 * words / cores as u64;
            let hi = (core as u64 + 1) * words / cores as u64;
            for w in lo..hi {
                let master = l.words.add(w * 4);
                let mut acc = ctx.read_u32(master);
                for c in 0..cores {
                    acc |= ctx.read_u32(l.copies.copy_base(c).add(w * 4));
                    ctx.compute(1);
                }
                ctx.write_u32(master, acc);
            }
            ctx.barrier();
        }
    }

    fn golden(&self, _cores: usize) -> Vec<u32> {
        golden_words(&self.p)
    }

    fn verify(
        &self,
        mem: &mut MemSystem,
        l: &BloomLayout,
        gold: &Vec<u32>,
        _cores: usize,
    ) -> (bool, Option<f64>) {
        let ok = (0..self.p.words()).all(|w| mem.peek(l.words.add(w as u64 * 4)) == gold[w]);
        (ok, None)
    }
}

/// Run through the generic driver, panicking on unsupported variants.
pub fn run(p: &BloomParams, variant: Variant, cfg: MachineConfig) -> RunResult {
    driver::run(&BloomWorkload::new(p.clone()), variant, cfg).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecError;

    fn small() -> BloomParams {
        BloomParams {
            items: 2048,
            bits: 1 << 13,
            hashes: 3,
            seed: 31,
            zipf_theta: 0.0,
        }
    }

    fn cfg() -> MachineConfig {
        MachineConfig::test_small().with_cores(2)
    }

    #[test]
    fn all_variants_verify() {
        for v in VARIANTS {
            let r = run(&small(), v, cfg());
            assert!(r.verified, "variant {v:?} diverged from golden");
        }
    }

    #[test]
    fn zipf_stream_verifies() {
        let p = BloomParams {
            zipf_theta: 0.9,
            ..small()
        };
        for v in [Variant::Fgl, Variant::CCache, Variant::Atomic] {
            let r = run(&p, v, cfg());
            assert!(r.verified, "variant {v:?} diverged");
        }
    }

    #[test]
    fn no_false_negatives() {
        let p = small();
        let words = golden_words(&p);
        for k in key_stream(&p) {
            assert!(
                contains(&p, &words, k as u64),
                "inserted key {k} queries negative"
            );
        }
        // the filter is not degenerate (some bits still clear)
        let set: u32 = words.iter().map(|w| w.count_ones()).sum();
        assert!((set as usize) < p.words() * 32, "filter saturated");
        assert!(set > 0);
    }

    #[test]
    fn ccache_merges_with_bitor() {
        let r = run(&small(), Variant::CCache, cfg());
        assert!(r.stats.merges > 0);
        assert_eq!(r.merge_fns, vec!["bitor".to_string()]);
    }

    #[test]
    fn cgl_is_a_typed_error() {
        let r = driver::run(&BloomWorkload::new(small()), Variant::Cgl, cfg());
        assert!(matches!(
            r,
            Err(ExecError::UnsupportedVariant { variant: Variant::Cgl, .. })
        ));
    }

    #[test]
    fn sized_respects_hash_override() {
        let mut s = SizeSpec::new(0.25, 1 << 16, 1);
        s.sketch.bloom_hashes = 7;
        let w = BloomWorkload::sized(&s);
        assert_eq!(w.params().hashes, 7);
    }
}
