//! Graph substrate: CSR representation and the paper's input generators.
//!
//! PageRank uses Graph500-generator inputs in RMAT, SSCA and Random
//! configurations (Section 5.1); BFS uses GAP kronecker and uniform
//! random graphs. We implement all of them from scratch with
//! deterministic seeds:
//! * [`GraphKind::Rmat`] — Graph500 Kronecker/R-MAT (a,b,c,d) =
//!   (0.57, 0.19, 0.19, 0.05)
//! * [`GraphKind::Ssca`] — SSCA#2-style clustered graph: vertices grouped
//!   into cliquish clusters with sparse inter-cluster edges
//! * [`GraphKind::Uniform`] — Erdős–Rényi-style uniform random

use crate::util::rng::Rng;

/// Compressed-sparse-row directed graph.
#[derive(Clone, Debug)]
pub struct Csr {
    /// offsets.len() == v + 1
    pub offsets: Vec<u32>,
    pub targets: Vec<u32>,
}

impl Csr {
    pub fn vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn edges(&self) -> usize {
        self.targets.len()
    }

    #[inline]
    pub fn out_degree(&self, v: usize) -> usize {
        (self.offsets[v + 1] - self.offsets[v]) as usize
    }

    #[inline]
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.targets[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// Build from an edge list (duplicates kept — multigraph semantics,
    /// matching Graph500 generator output).
    pub fn from_edges(v: usize, edges: &[(u32, u32)]) -> Self {
        let mut degree = vec![0u32; v];
        for &(s, _) in edges {
            degree[s as usize] += 1;
        }
        let mut offsets = vec![0u32; v + 1];
        for i in 0..v {
            offsets[i + 1] = offsets[i] + degree[i];
        }
        let mut cursor = offsets.clone();
        let mut targets = vec![0u32; edges.len()];
        for &(s, t) in edges {
            let c = &mut cursor[s as usize];
            targets[*c as usize] = t;
            *c += 1;
        }
        Self { offsets, targets }
    }

    /// The transpose (in-edges), needed by pull-based PageRank (DUP).
    pub fn transpose(&self) -> Csr {
        let v = self.vertices();
        let mut edges = Vec::with_capacity(self.edges());
        for s in 0..v {
            for &t in self.neighbors(s) {
                edges.push((t, s as u32));
            }
        }
        Csr::from_edges(v, &edges)
    }

    /// Sanity invariants for property tests.
    pub fn check(&self) -> Result<(), String> {
        let v = self.vertices() as u32;
        if self.offsets[0] != 0 {
            return Err("offsets[0] != 0".into());
        }
        for w in self.offsets.windows(2) {
            if w[1] < w[0] {
                return Err("offsets not monotone".into());
            }
        }
        if *self.offsets.last().unwrap() as usize != self.targets.len() {
            return Err("offsets tail != edge count".into());
        }
        if let Some(&t) = self.targets.iter().find(|&&t| t >= v) {
            return Err(format!("target {t} out of range {v}"));
        }
        Ok(())
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphKind {
    Rmat,
    Ssca,
    Uniform,
}

impl GraphKind {
    pub fn name(&self) -> &'static str {
        match self {
            GraphKind::Rmat => "rmat",
            GraphKind::Ssca => "ssca",
            GraphKind::Uniform => "uniform",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rmat" | "kron" => Some(GraphKind::Rmat),
            "ssca" => Some(GraphKind::Ssca),
            "uniform" | "random" => Some(GraphKind::Uniform),
            _ => None,
        }
    }
}

/// Generate a graph with `v` vertices (rounded up to a power of two for
/// RMAT) and ~`v * avg_degree` directed edges.
pub fn generate(kind: GraphKind, v: usize, avg_degree: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed ^ 0x9A27);
    match kind {
        GraphKind::Rmat => rmat(v.next_power_of_two(), v * avg_degree, &mut rng),
        GraphKind::Ssca => ssca(v, avg_degree, &mut rng),
        GraphKind::Uniform => uniform(v, v * avg_degree, &mut rng),
    }
}

fn uniform(v: usize, e: usize, rng: &mut Rng) -> Csr {
    let edges: Vec<(u32, u32)> = (0..e)
        .map(|_| {
            (
                rng.usize_below(v) as u32,
                rng.usize_below(v) as u32,
            )
        })
        .collect();
    Csr::from_edges(v, &edges)
}

/// Graph500 R-MAT: recursive quadrant descent with (a,b,c,d) =
/// (0.57, 0.19, 0.19, 0.05) and the standard noise on each level.
fn rmat(v: usize, e: usize, rng: &mut Rng) -> Csr {
    assert!(v.is_power_of_two());
    let levels = v.trailing_zeros();
    let (a, b, c) = (0.57, 0.19, 0.19);
    let edges: Vec<(u32, u32)> = (0..e)
        .map(|_| {
            let (mut s, mut t) = (0usize, 0usize);
            for _ in 0..levels {
                s <<= 1;
                t <<= 1;
                let r = rng.f64();
                if r < a {
                    // top-left
                } else if r < a + b {
                    t |= 1;
                } else if r < a + b + c {
                    s |= 1;
                } else {
                    s |= 1;
                    t |= 1;
                }
            }
            (s as u32, t as u32)
        })
        .collect();
    Csr::from_edges(v, &edges)
}

/// SSCA#2-flavoured clustered graph: vertices in contiguous clusters of
/// size up to `max_cluster`; dense intra-cluster edges plus sparse
/// inter-cluster links.
fn ssca(v: usize, avg_degree: usize, rng: &mut Rng) -> Csr {
    let max_cluster = (avg_degree * 2).max(2);
    let mut edges = Vec::with_capacity(v * avg_degree);
    let mut start = 0usize;
    while start < v {
        let size = 1 + rng.usize_below(max_cluster.min(v - start));
        // intra-cluster: each vertex links to ~avg_degree/2 cluster peers
        for i in 0..size {
            let s = (start + i) as u32;
            for _ in 0..avg_degree / 2 {
                let t = (start + rng.usize_below(size)) as u32;
                edges.push((s, t));
            }
            // inter-cluster long link(s)
            for _ in 0..(avg_degree - avg_degree / 2) {
                edges.push((s, rng.usize_below(v) as u32));
            }
        }
        start += size;
    }
    Csr::from_edges(v, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;

    #[test]
    fn all_kinds_produce_valid_csr() {
        for kind in [GraphKind::Rmat, GraphKind::Ssca, GraphKind::Uniform] {
            let g = generate(kind, 512, 8, 42);
            g.check().unwrap();
            assert!(g.edges() >= 512 * 4, "{kind:?}: {} edges", g.edges());
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(GraphKind::Rmat, 256, 8, 7);
        let b = generate(GraphKind::Rmat, 256, 8, 7);
        assert_eq!(a.offsets, b.offsets);
        assert_eq!(a.targets, b.targets);
        let c = generate(GraphKind::Rmat, 256, 8, 8);
        assert_ne!(a.targets, c.targets);
    }

    #[test]
    fn rmat_is_skewed() {
        let g = generate(GraphKind::Rmat, 1024, 16, 3);
        let mut degs: Vec<usize> = (0..g.vertices()).map(|v| g.out_degree(v)).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        // heavy head: top 1% of vertices own a disproportionate share
        let top: usize = degs[..10].iter().sum();
        let mean = g.edges() / g.vertices();
        assert!(
            top > 10 * mean * 4,
            "top10={top}, mean_deg={mean} — not skewed"
        );
    }

    #[test]
    fn uniform_is_not_skewed() {
        let g = generate(GraphKind::Uniform, 1024, 16, 3);
        let max_deg = (0..g.vertices()).map(|v| g.out_degree(v)).max().unwrap();
        assert!(max_deg < 16 * 4, "max degree {max_deg} too skewed for uniform");
    }

    #[test]
    fn transpose_preserves_edge_count_and_reverses() {
        let g = generate(GraphKind::Uniform, 128, 4, 9);
        let t = g.transpose();
        t.check().unwrap();
        assert_eq!(g.edges(), t.edges());
        // edge multiset reversal: (s,t) in g <=> (t,s) in t
        let mut fwd: Vec<(u32, u32)> = Vec::new();
        for s in 0..g.vertices() {
            for &tgt in g.neighbors(s) {
                fwd.push((s as u32, tgt));
            }
        }
        let mut rev: Vec<(u32, u32)> = Vec::new();
        for s in 0..t.vertices() {
            for &tgt in t.neighbors(s) {
                rev.push((tgt, s as u32));
            }
        }
        fwd.sort_unstable();
        rev.sort_unstable();
        assert_eq!(fwd, rev);
    }

    #[test]
    fn property_csr_from_random_edges_valid() {
        ptest::check(
            11,
            50,
            |rng| {
                let v = 1 + rng.usize_below(64);
                let e = rng.usize_below(256);
                let edges: Vec<(u32, u32)> = (0..e)
                    .map(|_| (rng.usize_below(v) as u32, rng.usize_below(v) as u32))
                    .collect();
                edges.iter().flat_map(|&(a, b)| [a as usize, b as usize]).collect::<Vec<usize>>()
            },
            |flat| {
                if flat.len() % 2 != 0 {
                    return Ok(());
                }
                let v = flat.iter().copied().max().map_or(1, |m| m + 1);
                let edges: Vec<(u32, u32)> = flat
                    .chunks(2)
                    .map(|c| (c[0] as u32, c[1] as u32))
                    .collect();
                let g = Csr::from_edges(v, &edges);
                g.check()?;
                if g.edges() != edges.len() {
                    return Err("edge count mismatch".into());
                }
                Ok(())
            },
        );
    }
}
