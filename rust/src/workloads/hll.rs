//! HyperLogLog cardinality estimation — the third streaming-sketch
//! workload: cores stream item ids and raise `m = 2^p` rank registers
//! (packed four u8 registers per u32 word) to the lane-wise max of the
//! observed hash ranks. The merge is [`MaxU8x64`] — a merge function
//! defined in the *workload* layer and registered purely through the
//! public [`MergeRegistry`](crate::merge::MergeRegistry) API, proving
//! the merge layer is open one layer further out than `merge/ext.rs`.
//!
//! Lane max is idempotent and commutative, so every variant must produce
//! the *bit-identical* register array of the sequential golden run;
//! verification additionally checks the cardinality estimate against the
//! stream's true distinct count (the quality metric reported for the
//! run).

use crate::exec::registry::SizeSpec;
use crate::exec::scaffold::{DupSpace, LockArray};
use crate::exec::{driver, ExecCtx, RunResult, Variant, Workload};
use crate::merge::{handle, MergeHandle};
use crate::sim::addr::Addr;
use crate::sim::config::MachineConfig;
use crate::sim::memsys::MemSystem;
use crate::workloads::sketch::{
    hash_key, keyed_stream, lane_get, lane_max_word, lane_set, MaxU8x64,
};

/// Salt of the single item-hash function.
const ITEM_SALT: u64 = 0x177;

#[derive(Clone, Debug)]
pub struct HllParams {
    /// Items streamed (with repeats; the estimator counts distincts).
    pub items: usize,
    /// Precision: `m = 2^precision` registers. 4..=16.
    pub precision: usize,
    pub seed: u64,
    /// 0.0 = uniform item ids; >0 = zipf-skewed (heavy repeats).
    pub zipf_theta: f64,
}

impl Default for HllParams {
    fn default() -> Self {
        Self {
            items: 16384,
            precision: 10,
            seed: 0x4117,
            zipf_theta: 0.0,
        }
    }
}

impl HllParams {
    /// Register count `m = 2^precision`.
    pub fn registers(&self) -> usize {
        1 << self.precision
    }

    /// Packed u32 words holding the registers (4 per word).
    pub fn words(&self) -> usize {
        self.registers() / 4
    }

    /// Distinct item ids the stream draws from.
    pub fn key_space(&self) -> usize {
        self.items.max(16)
    }

    /// Input stream + register array (the Fig 6 x-axis).
    pub fn working_set_bytes(&self) -> u64 {
        (self.items * 4 + self.registers()) as u64
    }

    /// `(register index, rank)` of one item: the top `precision` hash
    /// bits select the register, the leading-zero run of the rest (+1)
    /// is the rank, capped so it fits the register width.
    pub fn index_rank(&self, item: u64) -> (usize, u8) {
        let h = hash_key(item, ITEM_SALT);
        let idx = (h >> (64 - self.precision)) as usize;
        let tail = h << self.precision;
        let rank = (tail.leading_zeros() as u8 + 1).min((64 - self.precision + 1) as u8);
        (idx, rank)
    }
}

/// Host-side item stream (shared by programs and the golden run).
fn item_stream(p: &HllParams) -> Vec<u32> {
    keyed_stream(p.seed ^ 0x477_11, p.items, p.key_space(), p.zipf_theta)
}

/// Sequential golden run: the register array (one u8 rank per register).
pub fn golden_registers(p: &HllParams) -> Vec<u8> {
    let mut regs = vec![0u8; p.registers()];
    for item in item_stream(p) {
        let (idx, rank) = p.index_rank(item as u64);
        regs[idx] = regs[idx].max(rank);
    }
    regs
}

/// True distinct count of the stream (what the estimator approximates).
pub fn true_cardinality(p: &HllParams) -> usize {
    let mut seen = std::collections::HashSet::new();
    for item in item_stream(p) {
        seen.insert(item);
    }
    seen.len()
}

/// The HyperLogLog estimate of a register array, with the standard
/// small-range (linear counting) correction.
pub fn estimate(regs: &[u8]) -> f64 {
    let m = regs.len() as f64;
    let alpha = match regs.len() {
        16 => 0.673,
        32 => 0.697,
        64 => 0.709,
        _ => 0.7213 / (1.0 + 1.079 / m),
    };
    let sum: f64 = regs.iter().map(|&r| 2f64.powi(-(r as i32))).sum();
    let raw = alpha * m * m / sum;
    if raw <= 2.5 * m {
        let zeros = regs.iter().filter(|&&r| r == 0).count();
        if zeros > 0 {
            return m * (m / zeros as f64).ln();
        }
    }
    raw
}

#[derive(Clone, Copy)]
pub struct HllLayout {
    input: Addr,
    /// Packed register words (4 u8 registers per u32, little-lane).
    words: Addr,
    locks: LockArray,
    copies: DupSpace,
}

const SLOT_MAX: usize = 0;

/// The variants HLL implements (CGL is pointless at this granularity).
pub const VARIANTS: [Variant; 4] = [
    Variant::Fgl,
    Variant::Dup,
    Variant::CCache,
    Variant::Atomic,
];

pub struct HllWorkload {
    p: HllParams,
}

impl HllWorkload {
    pub fn new(p: HllParams) -> Self {
        assert!(
            (4..=16).contains(&p.precision),
            "HLL precision must be in 4..=16, got {}",
            p.precision
        );
        Self { p }
    }

    /// Size the register array to `frac` x LLC (1 byte per register),
    /// unless an explicit precision override is given.
    pub fn sized(s: &SizeSpec) -> Self {
        let precision = if s.sketch.hll_precision > 0 {
            s.sketch.hll_precision
        } else {
            // largest p with 2^p <= target bytes, clamped to the legal range
            (s.target_bytes().max(64).ilog2() as usize).clamp(4, 16)
        };
        let m = 1usize << precision;
        Self::new(HllParams {
            items: (m * 4).max(2048),
            precision,
            seed: s.seed,
            zipf_theta: s.zipf_theta,
        })
    }

    pub fn params(&self) -> &HllParams {
        &self.p
    }

    /// Estimate tolerance for verification: generous multiple of the
    /// estimator's theoretical standard error `1.04/sqrt(m)` so healthy
    /// runs never flake, while a broken estimator or register array
    /// still fails loudly.
    pub fn tolerance(&self) -> f64 {
        (5.0 * 1.04 / (self.p.registers() as f64).sqrt()).max(0.25)
    }
}

impl Workload for HllWorkload {
    type Layout = HllLayout;
    type Golden = (Vec<u8>, usize);

    fn name(&self) -> String {
        "hll".into()
    }

    fn supported_variants(&self) -> Vec<Variant> {
        VARIANTS.to_vec()
    }

    fn footprint(&self) -> u64 {
        self.p.working_set_bytes()
    }

    fn merge_slots(&self) -> Vec<(usize, MergeHandle)> {
        // the workload-layer merge function: no `merge/` edit anywhere
        vec![(SLOT_MAX, handle(MaxU8x64))]
    }

    fn setup(&self, mem: &mut MemSystem, variant: Variant, cores: usize) -> HllLayout {
        let p = &self.p;
        let input = mem.alloc_lines(p.items as u64 * 4);
        for (i, k) in item_stream(p).into_iter().enumerate() {
            mem.poke(input.add(i as u64 * 4), k);
        }
        let words = mem.alloc_lines(p.words() as u64 * 4);
        let mut l = HllLayout {
            input,
            words,
            locks: LockArray::none(),
            copies: DupSpace::none(),
        };
        match variant {
            Variant::Fgl => {
                // one padded lock per packed register word
                l.locks = LockArray::alloc(mem, p.words() as u64, 64);
            }
            Variant::Dup => {
                l.copies = DupSpace::alloc(mem, p.words() as u64 * 4, cores);
            }
            _ => {}
        }
        l
    }

    fn program<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        cores: usize,
        variant: Variant,
        l: &HllLayout,
    ) {
        let p = &self.p;
        let lo = core * p.items / cores;
        let hi = (core + 1) * p.items / cores;
        for i in lo..hi {
            let item = ctx.read_u32(l.input.add(i as u64 * 4)) as u64;
            let (idx, rank) = p.index_rank(item);
            let (w, lane) = ((idx / 4) as u64, idx % 4);
            let a = l.words.add(w * 4);
            match variant {
                Variant::Fgl => {
                    l.locks.lock(ctx, w);
                    let v = ctx.read_u32(a);
                    if rank > lane_get(v, lane) {
                        ctx.write_u32(a, lane_set(v, lane, rank));
                    }
                    l.locks.unlock(ctx, w);
                }
                Variant::Dup => {
                    let pa = l.copies.copy_base(core).add(w * 4);
                    let v = ctx.read_u32(pa);
                    if rank > lane_get(v, lane) {
                        ctx.write_u32(pa, lane_set(v, lane, rank));
                    }
                }
                Variant::CCache => {
                    let v = ctx.c_read_u32(a, SLOT_MAX as u8);
                    if rank > lane_get(v, lane) {
                        ctx.c_write_u32(a, lane_set(v, lane, rank), SLOT_MAX as u8);
                    }
                    // the c_read alone privatizes: keep the line evictable
                    ctx.soft_merge();
                }
                Variant::Atomic => loop {
                    let v = ctx.read_u32(a);
                    if rank <= lane_get(v, lane) {
                        break; // register already covers this rank
                    }
                    if ctx.cas_u32(a, v, lane_set(v, lane, rank)) {
                        break;
                    }
                },
                Variant::Cgl => unreachable!("driver rejects unsupported variants"),
            }
            ctx.compute(4);
        }
        if variant == Variant::CCache {
            ctx.merge();
        }
        ctx.barrier();
        if variant == Variant::Dup {
            // lane-max reduce every core's registers into the master
            let words = p.words() as u64;
            let lo = core as u64 * words / cores as u64;
            let hi = (core as u64 + 1) * words / cores as u64;
            for w in lo..hi {
                let master = l.words.add(w * 4);
                let mut acc = ctx.read_u32(master);
                for c in 0..cores {
                    acc = lane_max_word(acc, ctx.read_u32(l.copies.copy_base(c).add(w * 4)));
                    ctx.compute(1);
                }
                ctx.write_u32(master, acc);
            }
            ctx.barrier();
        }
    }

    fn golden(&self, _cores: usize) -> (Vec<u8>, usize) {
        (golden_registers(&self.p), true_cardinality(&self.p))
    }

    fn verify(
        &self,
        mem: &mut MemSystem,
        l: &HllLayout,
        gold: &(Vec<u8>, usize),
        _cores: usize,
    ) -> (bool, Option<f64>) {
        let (gold_regs, truth) = gold;
        // 1. register-array equality (bit-exact: lane max commutes)
        let mut regs = vec![0u8; self.p.registers()];
        let mut equal = true;
        for w in 0..self.p.words() {
            let v = mem.peek(l.words.add(w as u64 * 4));
            for lane in 0..4 {
                let r = lane_get(v, lane);
                regs[w * 4 + lane] = r;
                equal &= r == gold_regs[w * 4 + lane];
            }
        }
        // 2. the estimate tracks the true cardinality
        let est = estimate(&regs);
        let quality = (est - *truth as f64).abs() / (*truth as f64).max(1.0);
        (equal && quality <= self.tolerance(), Some(quality))
    }
}

/// Run through the generic driver, panicking on unsupported variants.
pub fn run(p: &HllParams, variant: Variant, cfg: MachineConfig) -> RunResult {
    driver::run(&HllWorkload::new(p.clone()), variant, cfg).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> HllParams {
        HllParams {
            items: 4096,
            precision: 8,
            seed: 17,
            zipf_theta: 0.0,
        }
    }

    fn cfg() -> MachineConfig {
        MachineConfig::test_small().with_cores(2)
    }

    #[test]
    fn all_variants_verify_with_estimate_quality() {
        for v in VARIANTS {
            let r = run(&small(), v, cfg());
            assert!(r.verified, "variant {v:?} diverged from golden");
            let q = r.quality.expect("HLL reports estimate quality");
            assert!(q < 0.35, "estimate error {q} too large");
        }
    }

    #[test]
    fn zipf_stream_verifies() {
        let p = HllParams {
            zipf_theta: 0.99,
            ..small()
        };
        for v in [Variant::Fgl, Variant::CCache, Variant::Dup] {
            let r = run(&p, v, cfg());
            assert!(r.verified, "variant {v:?} diverged");
        }
        // heavy skew shrinks the distinct set the estimator must track
        assert!(true_cardinality(&p) < true_cardinality(&small()));
    }

    #[test]
    fn estimator_tracks_known_cardinalities() {
        // feed n distinct synthetic items straight into golden registers
        for n in [100usize, 1000, 10000] {
            let p = HllParams {
                precision: 10,
                ..small()
            };
            let mut regs = vec![0u8; p.registers()];
            for item in 0..n as u64 {
                let (idx, rank) = p.index_rank(item);
                regs[idx] = regs[idx].max(rank);
            }
            let est = estimate(&regs);
            let err = (est - n as f64).abs() / n as f64;
            assert!(err < 0.15, "n={n}: estimate {est} err {err}");
        }
    }

    #[test]
    fn rank_is_capped_to_register_width() {
        let p = small();
        for item in 0..10_000u64 {
            let (idx, rank) = p.index_rank(item);
            assert!(idx < p.registers());
            assert!((1..=(64 - p.precision + 1) as u8).contains(&rank));
        }
    }

    #[test]
    fn ccache_merges_with_the_workload_layer_function() {
        let r = run(&small(), Variant::CCache, cfg());
        assert!(r.stats.merges > 0);
        assert_eq!(r.merge_fns, vec!["max_u8x64".to_string()]);
    }

    #[test]
    fn sized_respects_precision_override_and_derives_otherwise() {
        let mut s = SizeSpec::new(0.25, 1 << 16, 1);
        let derived = HllWorkload::sized(&s);
        // 16 KiB target -> 2^14 registers
        assert_eq!(derived.params().precision, 14);
        s.sketch.hll_precision = 6;
        let forced = HllWorkload::sized(&s);
        assert_eq!(forced.params().precision, 6);
    }

    #[test]
    #[should_panic(expected = "precision must be in 4..=16")]
    fn illegal_precision_is_rejected_at_construction() {
        HllWorkload::new(HllParams {
            precision: 2,
            ..small()
        });
    }
}
