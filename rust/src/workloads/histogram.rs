//! Histogram benchmark — the classic privatization workload: cores
//! stream a shared read-only input array and apply commutative `+1`
//! updates to a small, hot array of bins. Uniform or zipf-skewed bin
//! choice (the skew knob concentrates contention the way the paper's
//! uniform keys do not).
//!
//! This is the registry's "fifth benchmark": one [`Workload`] impl, no
//! bespoke driver code — the template for adding new scenarios.

use crate::exec::registry::SizeSpec;
use crate::exec::scaffold::{DupSpace, LockArray, PTHREAD_LOCK_BYTES};
use crate::exec::{driver, ExecCtx, RunResult, Variant, Workload};
use crate::merge::funcs::AddU32;
use crate::merge::{handle, MergeHandle};
use crate::sim::addr::Addr;
use crate::sim::config::MachineConfig;
use crate::sim::memsys::MemSystem;
use crate::util::rng::{Rng, Zipf};

#[derive(Clone, Debug)]
pub struct HgParams {
    /// Input elements streamed (each one increments one bin).
    pub items: usize,
    pub bins: usize,
    pub seed: u64,
    /// 0.0 = uniform bins; >0 = zipf-skewed hot bins.
    pub zipf_theta: f64,
}

impl Default for HgParams {
    fn default() -> Self {
        Self {
            items: 65536,
            bins: 1024,
            seed: 0x4157,
            zipf_theta: 0.0,
        }
    }
}

impl HgParams {
    /// Input stream + bins (the input dominates; bins stay hot in L1).
    pub fn working_set_bytes(&self) -> u64 {
        (self.items * 4 + self.bins * 4) as u64
    }
}

/// Host-side input stream: the bin index of each element.
fn bin_stream(p: &HgParams) -> Vec<u32> {
    let mut rng = Rng::new(p.seed ^ 0x8157_0000);
    let zipf = (p.zipf_theta > 0.0).then(|| Zipf::new(p.bins, p.zipf_theta));
    (0..p.items)
        .map(|_| match &zipf {
            Some(z) => z.sample(&mut rng) as u32,
            None => rng.usize_below(p.bins) as u32,
        })
        .collect()
}

/// Sequential golden run: per-bin counts.
pub fn golden_counts(p: &HgParams) -> Vec<u32> {
    let mut counts = vec![0u32; p.bins];
    for b in bin_stream(p) {
        counts[b as usize] += 1;
    }
    counts
}

#[derive(Clone, Copy)]
pub struct HgLayout {
    input: Addr,
    bins: Addr,
    global_lock: Addr,
    locks: LockArray,
    copies: DupSpace,
}

/// Histogram implements every variant, including atomics (CAS-loop
/// increment) and the CGL baseline.
pub const VARIANTS: [Variant; 5] = [
    Variant::Cgl,
    Variant::Fgl,
    Variant::Dup,
    Variant::CCache,
    Variant::Atomic,
];

pub struct HgWorkload {
    p: HgParams,
}

impl HgWorkload {
    pub fn new(p: HgParams) -> Self {
        Self { p }
    }

    /// Size the input stream to `frac` x LLC; bins stay small and hot.
    pub fn sized(s: &SizeSpec) -> Self {
        Self::new(HgParams {
            items: (s.target_bytes() / 4).max(1024) as usize,
            bins: 1024,
            seed: s.seed,
            zipf_theta: s.zipf_theta,
        })
    }

    pub fn params(&self) -> &HgParams {
        &self.p
    }
}

impl Workload for HgWorkload {
    type Layout = HgLayout;
    type Golden = Vec<u32>;

    fn name(&self) -> String {
        "histogram".into()
    }

    fn supported_variants(&self) -> Vec<Variant> {
        VARIANTS.to_vec()
    }

    fn footprint(&self) -> u64 {
        self.p.working_set_bytes()
    }

    fn merge_slots(&self) -> Vec<(usize, MergeHandle)> {
        vec![(0, handle(AddU32))]
    }

    fn setup(&self, mem: &mut MemSystem, variant: Variant, cores: usize) -> HgLayout {
        let p = &self.p;
        let input = mem.alloc_lines(p.items as u64 * 4);
        for (i, b) in bin_stream(p).into_iter().enumerate() {
            mem.poke(input.add(i as u64 * 4), b);
        }
        let bins = mem.alloc_lines(p.bins as u64 * 4);
        let mut l = HgLayout {
            input,
            bins,
            global_lock: Addr(0),
            locks: LockArray::none(),
            copies: DupSpace::none(),
        };
        match variant {
            Variant::Cgl => l.global_lock = mem.alloc_lines(64),
            Variant::Fgl => {
                l.locks = LockArray::alloc(mem, p.bins as u64, PTHREAD_LOCK_BYTES)
            }
            Variant::Dup => l.copies = DupSpace::alloc(mem, p.bins as u64 * 4, cores),
            _ => {}
        }
        l
    }

    fn program<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        cores: usize,
        variant: Variant,
        l: &HgLayout,
    ) {
        let p = &self.p;
        let lo = core * p.items / cores;
        let hi = (core + 1) * p.items / cores;
        for i in lo..hi {
            let b = ctx.read_u32(l.input.add(i as u64 * 4)) as u64;
            let a = l.bins.add(b * 4);
            match variant {
                Variant::Cgl | Variant::Fgl => {
                    let lock = if variant == Variant::Fgl {
                        l.locks.addr(b)
                    } else {
                        l.global_lock
                    };
                    ctx.lock(lock);
                    let v = ctx.read_u32(a);
                    ctx.write_u32(a, v.wrapping_add(1));
                    ctx.unlock(lock);
                }
                Variant::Dup => {
                    let pa = l.copies.copy_base(core).add(b * 4);
                    let v = ctx.read_u32(pa);
                    ctx.write_u32(pa, v.wrapping_add(1));
                }
                Variant::CCache => {
                    let v = ctx.c_read_u32(a, 0);
                    ctx.c_write_u32(a, v.wrapping_add(1), 0);
                    ctx.soft_merge();
                }
                Variant::Atomic => loop {
                    // fetch-add via CAS loop (the ISA has no fetch-add)
                    let v = ctx.read_u32(a);
                    if ctx.cas_u32(a, v, v.wrapping_add(1)) {
                        break;
                    }
                },
            }
            ctx.compute(2);
        }
        if variant == Variant::CCache {
            ctx.merge();
        }
        ctx.barrier();
        if variant == Variant::Dup {
            // end-of-phase reduction, bin range partitioned across cores
            let lo = (core * p.bins / cores) as u64;
            let hi = ((core + 1) * p.bins / cores) as u64;
            l.copies.reduce_add_u32(ctx, l.bins, cores, lo, hi);
            ctx.barrier();
        }
    }

    fn golden(&self, _cores: usize) -> Vec<u32> {
        golden_counts(&self.p)
    }

    fn verify(
        &self,
        mem: &mut MemSystem,
        l: &HgLayout,
        gold: &Vec<u32>,
        _cores: usize,
    ) -> (bool, Option<f64>) {
        let ok = (0..self.p.bins).all(|b| mem.peek(l.bins.add(b as u64 * 4)) == gold[b]);
        (ok, None)
    }
}

/// Run through the generic driver, panicking on unsupported variants.
pub fn run(p: &HgParams, variant: Variant, cfg: MachineConfig) -> RunResult {
    driver::run(&HgWorkload::new(p.clone()), variant, cfg).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> HgParams {
        HgParams {
            items: 4096,
            bins: 128,
            seed: 13,
            zipf_theta: 0.0,
        }
    }

    fn cfg() -> MachineConfig {
        MachineConfig::test_small().with_cores(2)
    }

    #[test]
    fn all_five_variants_verify() {
        for v in VARIANTS {
            let r = run(&small(), v, cfg());
            assert!(r.verified, "variant {v:?} diverged from golden");
        }
    }

    #[test]
    fn zipf_skew_verifies_and_concentrates_mass() {
        let p = HgParams {
            zipf_theta: 0.9,
            ..small()
        };
        for v in [Variant::Fgl, Variant::Dup, Variant::CCache, Variant::Atomic] {
            let r = run(&p, v, cfg());
            assert!(r.verified, "variant {v:?} diverged");
        }
        let counts = golden_counts(&p);
        let max = *counts.iter().max().unwrap() as f64;
        let mean = p.items as f64 / p.bins as f64;
        assert!(max > 4.0 * mean, "zipf should concentrate: max {max} mean {mean}");
    }

    #[test]
    fn golden_counts_sum_to_items() {
        let p = small();
        let total: u64 = golden_counts(&p).iter().map(|&c| c as u64).sum();
        assert_eq!(total, p.items as u64);
    }

    #[test]
    fn atomic_variant_counts_rmws() {
        let r = run(&small(), Variant::Atomic, cfg());
        assert!(r.stats.atomic_rmws as usize >= small().items / 2);
    }

    #[test]
    fn dup_allocates_more_than_ccache() {
        let d = run(&small(), Variant::Dup, cfg());
        let c = run(&small(), Variant::CCache, cfg());
        assert!(d.stats.bytes_allocated > c.stats.bytes_allocated);
    }

    #[test]
    fn ccache_merges_bins() {
        let r = run(&small(), Variant::CCache, cfg());
        assert!(r.stats.merges > 0);
        assert!(r.stats.cops > 0);
    }
}
