//! K-Means clustering benchmark (Section 5.1).
//!
//! Lloyd iterations with a fixed iteration count (as in the paper, to
//! bound simulation time). Points are read-only and partitioned across
//! cores; the shared, commutatively-updated state is the per-cluster
//! accumulator (component-wise sums + counts) that every core hammers —
//! the paper's motivating case for the soft-merge optimization, because
//! cluster accumulators have high reuse in each core's L1.
//!
//! Variants:
//! * FGL — one padded lock per cluster protecting its sums line + count
//! * DUP — Rodinia-style per-thread copy of the accumulator, reduced at
//!   the end of each iteration
//! * CCache — sums lines are CData with an AddF32 merge; counts are f32
//!   CData in their own line; soft_merge after every point
//! * approx (Section 6.3) — CCache with point-level update dropping;
//!   reports intra-cluster-distance degradation

use crate::exec::registry::SizeSpec;
use crate::exec::scaffold::{DupSpace, LockArray};
use crate::exec::{driver, ExecCtx, RunResult, Variant, Workload};
use crate::merge::funcs::AddF32;
use crate::merge::{handle, MergeHandle};
use crate::sim::addr::Addr;
use crate::sim::config::MachineConfig;
use crate::sim::memsys::MemSystem;
use crate::util::rng::Rng;

/// Dimensions fixed at 16 f32 = one cache line per point / per centroid
/// row (the natural CCache granularity; see DESIGN.md §Hardware-Adaptation).
pub const DIM: usize = 16;

#[derive(Clone, Debug)]
pub struct KmParams {
    pub points: usize,
    pub clusters: usize,
    pub iters: usize,
    pub seed: u64,
    /// >0.0 selects the approximate-merge variant (CCache only).
    pub approx_drop_p: f32,
}

impl Default for KmParams {
    fn default() -> Self {
        Self {
            points: 4096,
            clusters: 4,
            iters: 3,
            seed: 0x44EA,
            approx_drop_p: 0.0,
        }
    }
}

impl KmParams {
    pub fn with_points(mut self, n: usize) -> Self {
        self.points = n;
        self
    }

    pub fn working_set_bytes(&self) -> u64 {
        (self.points * DIM * 4) as u64
    }
}

/// Deterministic dataset: `clusters` well-separated Gaussian blobs,
/// point order shuffled. Returns (points, true_centers).
pub fn dataset(p: &KmParams) -> (Vec<[f32; DIM]>, Vec<[f32; DIM]>) {
    let mut rng = Rng::new(p.seed);
    let mut centers = Vec::with_capacity(p.clusters);
    for _ in 0..p.clusters {
        let mut c = [0f32; DIM];
        for x in c.iter_mut() {
            *x = rng.f32_range(-50.0, 50.0);
        }
        centers.push(c);
    }
    let mut pts = Vec::with_capacity(p.points);
    for i in 0..p.points {
        let c = &centers[i % p.clusters];
        let mut v = [0f32; DIM];
        for (j, x) in v.iter_mut().enumerate() {
            *x = c[j] + rng.normal() as f32 * 2.0;
        }
        pts.push(v);
    }
    rng.shuffle(&mut pts);
    (pts, centers)
}

fn nearest(point: &[f32; DIM], centroids: &[[f32; DIM]]) -> usize {
    let mut best = 0;
    let mut best_d = f32::INFINITY;
    for (c, cen) in centroids.iter().enumerate() {
        let mut d = 0f32;
        for j in 0..DIM {
            let t = point[j] - cen[j];
            d += t * t;
        }
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    best
}

/// Sequential golden run: final centroids after `iters` Lloyd steps.
pub fn golden(p: &KmParams) -> Vec<[f32; DIM]> {
    let (pts, centers) = dataset(p);
    let mut centroids = centers;
    for _ in 0..p.iters {
        let mut sums = vec![[0f32; DIM]; p.clusters];
        let mut counts = vec![0f32; p.clusters];
        for pt in &pts {
            let c = nearest(pt, &centroids);
            for j in 0..DIM {
                sums[c][j] += pt[j];
            }
            counts[c] += 1.0;
        }
        for c in 0..p.clusters {
            if counts[c] > 0.0 {
                for j in 0..DIM {
                    centroids[c][j] = sums[c][j] / counts[c];
                }
            }
        }
    }
    centroids
}

/// Mean intra-cluster squared distance for a set of centroids.
pub fn intra_cluster_distance(p: &KmParams, centroids: &[[f32; DIM]]) -> f64 {
    let (pts, _) = dataset(p);
    let mut total = 0f64;
    for pt in &pts {
        let c = nearest(pt, centroids);
        for j in 0..DIM {
            let t = (pt[j] - centroids[c][j]) as f64;
            total += t * t;
        }
    }
    total / pts.len() as f64
}

#[derive(Clone, Copy)]
pub struct KmLayout {
    points: Addr,
    centroids: Addr,
    sums: Addr,
    counts: Addr,
    locks: LockArray,
    copies: DupSpace,
    /// Offset of the counts line inside a DUP copy block.
    copy_counts_off: u64,
}

const SLOT_SUMS: usize = 0;
const SLOT_COUNTS: usize = 1;

/// The variants K-Means implements.
pub const VARIANTS: [Variant; 3] = [Variant::Fgl, Variant::Dup, Variant::CCache];

/// K-Means as a [`Workload`].
pub struct KmWorkload {
    p: KmParams,
}

impl KmWorkload {
    pub fn new(p: KmParams) -> Self {
        assert!(
            p.clusters * 4 <= 64,
            "counts must fit one line (clusters <= 16)"
        );
        Self { p }
    }

    /// Size the point set to `frac` x LLC (accumulators are tiny by
    /// design).
    pub fn sized(approx: bool, s: &SizeSpec) -> Self {
        let points = (s.target_bytes() / (DIM as u64 * 4)).max(256) as usize;
        Self::new(KmParams {
            points,
            clusters: 4,
            iters: 2,
            seed: s.seed,
            approx_drop_p: if approx { 0.1 } else { 0.0 },
        })
    }

    pub fn params(&self) -> &KmParams {
        &self.p
    }
}

impl Workload for KmWorkload {
    type Layout = KmLayout;
    type Golden = Vec<[f32; DIM]>;

    fn name(&self) -> String {
        if self.p.approx_drop_p > 0.0 {
            "kmeans-approx".into()
        } else {
            "kmeans".into()
        }
    }

    fn supported_variants(&self) -> Vec<Variant> {
        VARIANTS.to_vec()
    }

    fn footprint(&self) -> u64 {
        self.p.working_set_bytes()
    }

    fn merge_slots(&self) -> Vec<(usize, MergeHandle)> {
        vec![(SLOT_SUMS, handle(AddF32)), (SLOT_COUNTS, handle(AddF32))]
    }

    fn setup(&self, mem: &mut MemSystem, variant: Variant, cores: usize) -> KmLayout {
        let p = &self.p;
        let (pts, centers) = dataset(p);
        let points = mem.alloc_lines((p.points * DIM * 4) as u64);
        for (i, pt) in pts.iter().enumerate() {
            for j in 0..DIM {
                mem.poke_f32(points.add((i * DIM + j) as u64 * 4), pt[j]);
            }
        }
        let centroids = mem.alloc_lines((p.clusters * DIM * 4) as u64);
        for (c, cen) in centers.iter().enumerate() {
            for j in 0..DIM {
                mem.poke_f32(centroids.add((c * DIM + j) as u64 * 4), cen[j]);
            }
        }
        let sums = mem.alloc_lines((p.clusters * DIM * 4) as u64);
        let counts = mem.alloc_lines(64); // all counts in one line (f32)
        let copy_counts_off = ((p.clusters * DIM * 4) as u64).next_multiple_of(64);
        let mut l = KmLayout {
            points,
            centroids,
            sums,
            counts,
            locks: LockArray::none(),
            copies: DupSpace::none(),
            copy_counts_off,
        };
        match variant {
            Variant::Fgl => {
                // one padded lock (own line) per cluster
                l.locks = LockArray::alloc(mem, p.clusters as u64, 64);
            }
            Variant::Dup => {
                l.copies = DupSpace::alloc(mem, copy_counts_off + 64, cores);
            }
            _ => {}
        }
        l
    }

    fn program<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        cores: usize,
        variant: Variant,
        l: &KmLayout,
    ) {
        let p = &self.p;
        // approximate variant (Section 6.3): "discards updates
        // for some points in a dataset" — each point's
        // accumulation is dropped with probability drop_p. (At
        // our merge cadence — merge-on-evict keeps K-Means
        // merges rare and huge — dropping whole merges would
        // discard a core's entire epoch, so the perforation is
        // applied at the paper's stated granularity: points.)
        let mut drop_rng = Rng::new(p.seed ^ (0xD0 + core as u64));
        let lo = core * p.points / cores;
        let hi = (core + 1) * p.points / cores;
        let sums_w = |c: usize, j: usize| l.sums.add((c * DIM + j) as u64 * 4);
        let counts_w = |c: usize| l.counts.add(c as u64 * 4);

        for _iter in 0..p.iters {
            // -- read current centroids into "registers" (timed) --
            let mut cen = vec![[0f32; DIM]; p.clusters];
            for c in 0..p.clusters {
                for j in 0..DIM {
                    cen[c][j] = ctx.read_f32(l.centroids.add((c * DIM + j) as u64 * 4));
                }
            }

            // -- assignment + accumulation over my points --
            for i in lo..hi {
                let mut pt = [0f32; DIM];
                for j in 0..DIM {
                    pt[j] = ctx.read_f32(l.points.add((i * DIM + j) as u64 * 4));
                }
                // distance compute: clusters * DIM * 3 flops
                ctx.compute((p.clusters * DIM * 3) as u64);
                let c = nearest(&pt, &cen);

                if variant == Variant::CCache
                    && p.approx_drop_p > 0.0
                    && drop_rng.bernoulli(p.approx_drop_p as f64)
                {
                    continue; // perforated update
                }

                match variant {
                    Variant::Fgl => {
                        l.locks.lock(ctx, c as u64);
                        for j in 0..DIM {
                            let a = sums_w(c, j);
                            let v = ctx.read_f32(a);
                            ctx.write_f32(a, v + pt[j]);
                        }
                        let a = counts_w(c);
                        let v = ctx.read_f32(a);
                        ctx.write_f32(a, v + 1.0);
                        l.locks.unlock(ctx, c as u64);
                    }
                    Variant::Dup => {
                        let base = l.copies.copy_base(core);
                        for j in 0..DIM {
                            let a = base.add((c * DIM + j) as u64 * 4);
                            let v = ctx.read_f32(a);
                            ctx.write_f32(a, v + pt[j]);
                        }
                        let ca = base.add(l.copy_counts_off + c as u64 * 4);
                        let v = ctx.read_f32(ca);
                        ctx.write_f32(ca, v + 1.0);
                    }
                    Variant::CCache => {
                        for j in 0..DIM {
                            let a = sums_w(c, j);
                            let v = ctx.c_read_f32(a, SLOT_SUMS as u8);
                            ctx.c_write_f32(a, v + pt[j], SLOT_SUMS as u8);
                        }
                        let a = counts_w(c);
                        let v = ctx.c_read_f32(a, SLOT_COUNTS as u8);
                        ctx.c_write_f32(a, v + 1.0, SLOT_COUNTS as u8);
                        ctx.soft_merge();
                    }
                    _ => unreachable!("driver rejects unsupported variants"),
                }
            }

            // -- merge boundary --
            if variant == Variant::CCache {
                ctx.merge();
            }
            ctx.barrier();

            // -- DUP reduction (partitioned by cluster) --
            if variant == Variant::Dup {
                for c in 0..p.clusters {
                    if c % cores != core {
                        continue;
                    }
                    for src in 0..cores {
                        let base = l.copies.copy_base(src);
                        for j in 0..DIM {
                            let a = sums_w(c, j);
                            let v = ctx.read_f32(a);
                            let add = ctx.read_f32(base.add((c * DIM + j) as u64 * 4));
                            ctx.write_f32(a, v + add);
                        }
                        let ca = base.add(l.copy_counts_off + c as u64 * 4);
                        let v = ctx.read_f32(counts_w(c));
                        let add = ctx.read_f32(ca);
                        ctx.write_f32(counts_w(c), v + add);
                    }
                }
                ctx.barrier();
            }

            // -- centroid recompute + accumulator reset (cluster-
            //    partitioned, coherent) --
            for c in 0..p.clusters {
                if c % cores != core {
                    continue;
                }
                let count = ctx.read_f32(counts_w(c));
                for j in 0..DIM {
                    let s = ctx.read_f32(sums_w(c, j));
                    if count > 0.0 {
                        ctx.write_f32(l.centroids.add((c * DIM + j) as u64 * 4), s / count);
                    }
                    ctx.write_f32(sums_w(c, j), 0.0);
                }
                ctx.write_f32(counts_w(c), 0.0);
                // zero every core's DUP copy of this cluster
                if variant == Variant::Dup {
                    for src in 0..cores {
                        let base = l.copies.copy_base(src);
                        for j in 0..DIM {
                            ctx.write_f32(base.add((c * DIM + j) as u64 * 4), 0.0);
                        }
                        ctx.write_f32(base.add(l.copy_counts_off + c as u64 * 4), 0.0);
                    }
                }
            }
            ctx.barrier();
        }
    }

    fn golden(&self, _cores: usize) -> Vec<[f32; DIM]> {
        golden(&self.p)
    }

    fn verify(
        &self,
        mem: &mut MemSystem,
        l: &KmLayout,
        gold: &Vec<[f32; DIM]>,
        _cores: usize,
    ) -> (bool, Option<f64>) {
        let p = &self.p;
        let final_centroids: Vec<[f32; DIM]> = (0..p.clusters)
            .map(|c| {
                let mut v = [0f32; DIM];
                for (j, x) in v.iter_mut().enumerate() {
                    *x = mem.peek_f32(l.centroids.add((c * DIM + j) as u64 * 4));
                }
                v
            })
            .collect();

        if p.approx_drop_p > 0.0 {
            // approximate variant: judge by clustering-quality degradation
            let gold_q = intra_cluster_distance(p, gold);
            let got_q = intra_cluster_distance(p, &final_centroids);
            let degradation = (got_q - gold_q) / gold_q;
            // the paper reports ~20% degradation at 10% drops; accept the run
            // as long as clustering hasn't collapsed
            (degradation < 2.0, Some(degradation))
        } else {
            let ok = gold.iter().zip(&final_centroids).all(|(g, f)| {
                g.iter()
                    .zip(f)
                    .all(|(a, b)| (a - b).abs() <= 1e-2 * (1.0 + a.abs()))
            });
            (ok, None)
        }
    }
}

/// Run through the generic driver, panicking on unsupported variants.
pub fn run(p: &KmParams, variant: Variant, cfg: MachineConfig) -> RunResult {
    driver::run(&KmWorkload::new(p.clone()), variant, cfg).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> KmParams {
        KmParams {
            points: 512,
            clusters: 4,
            iters: 2,
            seed: 3,
            approx_drop_p: 0.0,
        }
    }

    fn cfg() -> MachineConfig {
        MachineConfig::test_small().with_cores(2)
    }

    #[test]
    fn all_variants_verify() {
        for v in [Variant::Fgl, Variant::Dup, Variant::CCache] {
            let r = run(&small(), v, cfg());
            assert!(r.verified, "variant {v:?} diverged from golden");
        }
    }

    #[test]
    fn golden_recovers_separated_clusters() {
        let p = small();
        let (_, centers) = dataset(&p);
        let gold = golden(&p);
        for c in &centers {
            let best = gold
                .iter()
                .map(|g| {
                    c.iter()
                        .zip(g)
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum::<f32>()
                })
                .fold(f32::INFINITY, f32::min);
            assert!(best < 4.0, "center not recovered: d2={best}");
        }
    }

    #[test]
    fn ccache_reuses_cdata_lines() {
        let r = run(&small(), Variant::CCache, cfg());
        // accumulators are few lines with huge reuse: well over 4 L1
        // hits per privatizing fill (the same ratio the reuse-aware
        // LLC partition controller samples per epoch)
        assert!(
            r.stats.ccache_reuse_ratio() > 4.0,
            "reuse ratio {} (hits {} fills {})",
            r.stats.ccache_reuse_ratio(),
            r.stats.ccache_l1_hits,
            r.stats.ccache_fills
        );
    }

    #[test]
    fn approx_variant_degrades_bounded() {
        let p = KmParams {
            approx_drop_p: 0.1,
            ..small()
        };
        let r = run(&p, Variant::CCache, cfg());
        assert!(r.verified);
        let q = r.quality.unwrap();
        assert!(q < 2.0, "degradation {q} too large");
    }

    #[test]
    fn dataset_deterministic() {
        let p = small();
        let (a, _) = dataset(&p);
        let (b, _) = dataset(&p);
        assert_eq!(a.len(), b.len());
        assert!(a
            .iter()
            .zip(&b)
            .all(|(x, y)| x.iter().zip(y).all(|(u, v)| u == v)));
    }
}
