//! Breadth-First Search benchmark (Section 5.1).
//!
//! Level-synchronous BFS over a bitmap frontier, after the BFS kernel of
//! GAP's Betweenness Centrality. Two bitmaps: `visited` (cumulative) and
//! `next` (this level's discoveries — the commutatively-updated
//! structure). Each level, cores scan their slice of the current
//! frontier bitmap and set bits of newly discovered vertices in `next`;
//! at the level boundary `next` is folded into `visited` and becomes the
//! frontier.
//!
//! Variants (Section 6.2 compares all four):
//! * Atomic — the GAP original: atomic fetch-or on `next` words
//! * FGL — one padded lock per bitmap word ("locks matching the update
//!   granularity of the set operation")
//! * DUP — thread-local update containers, applied with atomics at the
//!   level-end merge (the paper's memory-frugal DUP for BFS)
//! * CCache — `next` words are CData with a BitOr merge

use crate::exec::registry::SizeSpec;
use crate::exec::scaffold::{DupSpace, LockArray};
use crate::exec::{driver, ExecCtx, RunResult, Variant, Workload};
use crate::merge::funcs::BitOr;
use crate::merge::{handle, MergeHandle};
use crate::sim::addr::Addr;
use crate::sim::config::MachineConfig;
use crate::sim::memsys::MemSystem;
use crate::workloads::graph::{generate, Csr, GraphKind};

#[derive(Clone, Debug)]
pub struct BfsParams {
    pub vertices: usize,
    pub avg_degree: usize,
    pub graph: GraphKind,
    pub seed: u64,
    pub source: usize,
}

impl Default for BfsParams {
    fn default() -> Self {
        Self {
            vertices: 4096,
            avg_degree: 8,
            graph: GraphKind::Rmat,
            seed: 0xBF5,
            source: 0,
        }
    }
}

impl BfsParams {
    pub fn with_vertices(mut self, v: usize) -> Self {
        self.vertices = v;
        self
    }

    pub fn with_graph(mut self, g: GraphKind) -> Self {
        self.graph = g;
        self
    }

    /// Bitmap working set (the Fig 6 x-axis for BFS tracks the graph).
    pub fn working_set_bytes(&self) -> u64 {
        // CSR dominates: offsets + targets
        ((self.vertices + 1) * 4 + self.vertices * self.avg_degree * 4) as u64
    }

    pub fn build_graph(&self) -> Csr {
        generate(self.graph, self.vertices, self.avg_degree, self.seed)
    }

    /// Pick a source with non-zero degree (deterministic).
    pub fn effective_source(&self, g: &Csr) -> usize {
        if g.out_degree(self.source) > 0 {
            return self.source;
        }
        (0..g.vertices())
            .max_by_key(|&v| g.out_degree(v))
            .unwrap_or(0)
    }
}

/// Sequential golden run: the reachable set as a bitmap.
pub fn golden(g: &Csr, source: usize) -> Vec<u32> {
    let words = g.vertices().div_ceil(32);
    let mut visited = vec![0u32; words];
    let mut frontier = vec![source];
    visited[source / 32] |= 1 << (source % 32);
    while !frontier.is_empty() {
        let mut nxt = Vec::new();
        for u in frontier {
            for &t in g.neighbors(u) {
                let (w, b) = (t as usize / 32, t % 32);
                if visited[w] & (1 << b) == 0 {
                    visited[w] |= 1 << b;
                    nxt.push(t as usize);
                }
            }
        }
        frontier = nxt;
    }
    visited
}

#[derive(Clone, Copy)]
pub struct BfsLayout {
    offsets: Addr,
    targets: Addr,
    visited: Addr,
    next: Addr,
    locks: LockArray,
    /// DUP: per-core update lists (u32 vertex ids) + per-core list length
    /// words.
    lists: DupSpace,
    list_len: Addr,
    /// Per-core "discovered anything this level" flags.
    flags: Addr,
    words: usize,
}

const SLOT_BITOR: usize = 0;

/// The variants BFS implements (the paper's Section 6.2 four-way
/// comparison; CGL is not modeled).
pub const VARIANTS: [Variant; 4] = [
    Variant::Fgl,
    Variant::Dup,
    Variant::CCache,
    Variant::Atomic,
];

/// BFS as a [`Workload`]: owns the generated graph and the effective
/// source so setup, golden and verification agree.
pub struct BfsWorkload {
    p: BfsParams,
    g: Csr,
    source: usize,
}

impl BfsWorkload {
    pub fn new(p: BfsParams) -> Self {
        let g = p.build_graph();
        let source = p.effective_source(&g);
        Self { p, g, source }
    }

    /// Size CSR + bitmaps to `frac` x LLC (~40 B/vertex at deg 8).
    pub fn sized(graph: GraphKind, s: &SizeSpec) -> Self {
        let vertices = (s.target_bytes() / 40).max(256) as usize;
        Self::new(BfsParams {
            vertices,
            avg_degree: 8,
            graph,
            seed: s.seed,
            source: 0,
        })
    }

    pub fn params(&self) -> &BfsParams {
        &self.p
    }
}

impl Workload for BfsWorkload {
    type Layout = BfsLayout;
    type Golden = Vec<u32>;

    fn name(&self) -> String {
        format!("bfs-{}", self.p.graph.name())
    }

    fn supported_variants(&self) -> Vec<Variant> {
        VARIANTS.to_vec()
    }

    fn footprint(&self) -> u64 {
        self.p.working_set_bytes()
    }

    fn merge_slots(&self) -> Vec<(usize, MergeHandle)> {
        vec![(SLOT_BITOR, handle(BitOr))]
    }

    fn setup(&self, mem: &mut MemSystem, variant: Variant, cores: usize) -> BfsLayout {
        let g = &self.g;
        let v = g.vertices();
        let words = v.div_ceil(32);
        let offsets = mem.alloc_lines((v as u64 + 1) * 4);
        for (i, &o) in g.offsets.iter().enumerate() {
            mem.poke(offsets.add(i as u64 * 4), o);
        }
        let targets = mem.alloc_lines(g.edges().max(1) as u64 * 4);
        for (i, &t) in g.targets.iter().enumerate() {
            mem.poke(targets.add(i as u64 * 4), t);
        }
        let visited = mem.alloc_lines(words as u64 * 4);
        let next = mem.alloc_lines(words as u64 * 4);
        // seed: source visited; the level-0 frontier is the source,
        // handled by core 0's program directly
        mem.poke(
            visited.add((self.source / 32) as u64 * 4),
            1 << (self.source % 32),
        );
        let mut l = BfsLayout {
            offsets,
            targets,
            visited,
            next,
            locks: LockArray::none(),
            lists: DupSpace::none(),
            list_len: Addr(0),
            flags: Addr(0),
            words,
        };
        match variant {
            Variant::Fgl => {
                // one padded lock per bitmap word (Table 3: FGL's big
                // footprint for BFS)
                l.locks = LockArray::alloc(mem, words as u64, 64);
            }
            Variant::Dup => {
                // thread-local update containers: v/4 entries per core,
                // spilling to direct atomic application on overflow
                l.lists = DupSpace::alloc(mem, (v as u64 / 4).max(64) * 4, cores);
                l.list_len = mem.alloc_lines(cores as u64 * 64);
            }
            _ => {}
        }
        l.flags = mem.alloc_lines(cores as u64 * 64);
        l
    }

    fn program<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        cores: usize,
        variant: Variant,
        l: &BfsLayout,
    ) {
        let v = self.g.vertices();
        let source = self.source;
        let wlo = core * l.words / cores;
        let whi = (core + 1) * l.words / cores;
        // level-0 frontier: the source only, handled by core 0
        let mut frontier: Vec<u32> = if core == 0 {
            vec![source as u32]
        } else {
            vec![]
        };

        for _level in 0..v {
            // -- expand my frontier into `next` --
            let mut discovered = false;
            for &u in &frontier {
                let s = ctx.read_u32(l.offsets.add(u as u64 * 4));
                let e = ctx.read_u32(l.offsets.add((u as u64 + 1) * 4));
                for ei in s..e {
                    let t = ctx.read_u32(l.targets.add(ei as u64 * 4));
                    let (w, b) = ((t / 32) as u64, t % 32);
                    let bit = 1u32 << b;
                    // visited is stable within a level
                    let seen = ctx.read_u32(l.visited.add(w * 4));
                    if seen & bit != 0 {
                        continue;
                    }
                    discovered = true;
                    match variant {
                        Variant::Atomic => {
                            ctx.fetch_or_u32(l.next.add(w * 4), bit);
                        }
                        Variant::Fgl => {
                            l.locks.lock(ctx, w);
                            let cur = ctx.read_u32(l.next.add(w * 4));
                            ctx.write_u32(l.next.add(w * 4), cur | bit);
                            l.locks.unlock(ctx, w);
                        }
                        Variant::Dup => {
                            // append to my container; spill = apply
                            let len_a = l.list_len.add(core as u64 * 64);
                            let len = ctx.read_u32(len_a);
                            if (len as u64 + 1) * 4 < l.lists.stride() {
                                ctx.write_u32(
                                    l.lists.copy_base(core).add(len as u64 * 4),
                                    t,
                                );
                                ctx.write_u32(len_a, len + 1);
                            } else {
                                ctx.fetch_or_u32(l.next.add(w * 4), bit);
                            }
                        }
                        Variant::CCache => {
                            let a = l.next.add(w * 4);
                            let cur = ctx.c_read_u32(a, SLOT_BITOR as u8);
                            ctx.c_write_u32(a, cur | bit, SLOT_BITOR as u8);
                            // per-COp soft_merge: w-1 discipline
                            // for arbitrary-degree vertices
                            ctx.soft_merge();
                        }
                        Variant::Cgl => unreachable!("driver rejects unsupported variants"),
                    }
                    ctx.compute(2);
                }
            }

            // -- level-end merge --
            if variant == Variant::CCache {
                ctx.merge();
            }
            ctx.barrier();
            if variant == Variant::Dup {
                // apply my container with atomics (paper's scheme)
                let len_a = l.list_len.add(core as u64 * 64);
                let len = ctx.read_u32(len_a);
                for i in 0..len as u64 {
                    let t = ctx.read_u32(l.lists.copy_base(core).add(i * 4));
                    let (w, b) = ((t / 32) as u64, t % 32);
                    ctx.fetch_or_u32(l.next.add(w * 4), 1 << b);
                }
                ctx.write_u32(len_a, 0);
                ctx.barrier();
            }

            // -- fold next into visited, build the new frontier --
            frontier.clear();
            for w in wlo..whi {
                let nw = ctx.read_u32(l.next.add(w as u64 * 4));
                if nw == 0 {
                    continue;
                }
                let seen = ctx.read_u32(l.visited.add(w as u64 * 4));
                let fresh = nw & !seen;
                if fresh != 0 {
                    ctx.write_u32(l.visited.add(w as u64 * 4), seen | fresh);
                    let mut bits = fresh;
                    while bits != 0 {
                        let b = bits.trailing_zeros();
                        bits &= bits - 1;
                        frontier.push((w * 32) as u32 + b);
                    }
                }
                ctx.write_u32(l.next.add(w as u64 * 4), 0);
            }
            ctx.compute(frontier.len() as u64);

            // -- global termination check --
            ctx.write_u32(
                l.flags.add(core as u64 * 64),
                (discovered || !frontier.is_empty()) as u32,
            );
            ctx.barrier();
            let mut any = 0;
            for c in 0..cores as u64 {
                any |= ctx.read_u32(l.flags.add(c * 64));
            }
            ctx.barrier();
            if any == 0 {
                break;
            }
        }
    }

    fn golden(&self, _cores: usize) -> Vec<u32> {
        golden(&self.g, self.source)
    }

    fn verify(
        &self,
        mem: &mut MemSystem,
        l: &BfsLayout,
        gold: &Vec<u32>,
        _cores: usize,
    ) -> (bool, Option<f64>) {
        let ok = (0..l.words).all(|w| mem.peek(l.visited.add(w as u64 * 4)) == gold[w]);
        (ok, None)
    }
}

/// Run through the generic driver, panicking on unsupported variants.
pub fn run(p: &BfsParams, variant: Variant, cfg: MachineConfig) -> RunResult {
    driver::run(&BfsWorkload::new(p.clone()), variant, cfg).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecError;

    fn small() -> BfsParams {
        BfsParams {
            vertices: 512,
            avg_degree: 4,
            graph: GraphKind::Uniform,
            seed: 7,
            source: 0,
        }
    }

    fn cfg() -> MachineConfig {
        MachineConfig::test_small().with_cores(2)
    }

    #[test]
    fn all_variants_verify_uniform() {
        for v in [Variant::Atomic, Variant::Fgl, Variant::Dup, Variant::CCache] {
            let r = run(&small(), v, cfg());
            assert!(r.verified, "variant {v:?} diverged from golden");
        }
    }

    #[test]
    fn kron_input_verifies() {
        let p = small().with_graph(GraphKind::Rmat);
        for v in [Variant::Atomic, Variant::CCache, Variant::Dup] {
            let r = run(&p, v, cfg());
            assert!(r.verified, "variant {v:?} diverged");
        }
    }

    #[test]
    fn cgl_is_a_typed_error() {
        let r = driver::run(&BfsWorkload::new(small()), Variant::Cgl, cfg());
        assert!(matches!(
            r,
            Err(ExecError::UnsupportedVariant { variant: Variant::Cgl, .. })
        ));
    }

    #[test]
    fn golden_reaches_source_component() {
        let p = small();
        let g = p.build_graph();
        let src = p.effective_source(&g);
        let gold = golden(&g, src);
        let count: u32 = gold.iter().map(|w| w.count_ones()).sum();
        assert!(count > 1, "BFS found only the source");
        assert!(gold[src / 32] & (1 << (src % 32)) != 0);
    }

    #[test]
    fn atomic_variant_counts_rmws() {
        let r = run(&small(), Variant::Atomic, cfg());
        assert!(r.stats.atomic_rmws > 0);
    }

    #[test]
    fn ccache_uses_bitor_merges() {
        let r = run(&small(), Variant::CCache, cfg());
        assert!(r.stats.merges > 0);
    }

    #[test]
    fn fgl_footprint_exceeds_ccache() {
        let f = run(&small(), Variant::Fgl, cfg());
        let c = run(&small(), Variant::CCache, cfg());
        assert!(f.stats.bytes_allocated > c.stats.bytes_allocated);
    }
}
