//! The benchmark suite: the paper's four workloads (Section 5.1) plus
//! the histogram privatization workload, each implemented as one
//! [`Workload`](crate::exec::Workload) trait impl over the simulated
//! machine:
//!
//! * [`kvstore`] — random-access key-value store with commutative
//!   increments; merge-function variants: saturating add and complex
//!   multiply (Section 6.3)
//! * [`kmeans`] — Lloyd's K-Means with CData cluster centers; approximate
//!   merge variant (Section 6.3)
//! * [`pagerank`] — push-based PageRank with CData rank accumulators;
//!   optimized double-buffer DUP (the paper's Section 5.1 scheme)
//! * [`bfs`] — level-synchronous BFS over a bitmap frontier (GAP-style),
//!   with an additional atomics variant (Section 6.2)
//! * [`histogram`] — streaming binned counts with uniform/zipf skew: the
//!   classic privatization workload, and the template for new scenarios
//! * [`cms`] / [`bloom`] / [`hll`] — the streaming-sketch family
//!   (count-min, Bloom filter, HyperLogLog): natively-commutative
//!   aggregation under heavy keyed traffic; [`sketch`] holds the shared
//!   hashing substrate and the workload-layer `max_u8x64` merge function
//!   (registered through the public merge registry only)
//! * [`kvserve`] — the sharded multi-tenant KV *serving* tier: a
//!   sustained trace-driven read/update/scan stream ([`traffic`]) under
//!   epoch-phased execution with a soft-merge deadline; quality metric
//!   is the measured **staleness bound** of unmerged updates
//! * [`traffic`] — the deterministic YCSB-style trace engine behind
//!   kvserve: per-tenant zipf distributions with seeded skew drift
//! * [`graph`] — CSR + RMAT / SSCA / uniform generators (Graph500/GAP
//!   input substitution)
//!
//! Every workload verifies its final simulated-memory state against a
//! sequential golden run — the paper's Section 3 serializability claim is
//! *checked*, not assumed, on every benchmark execution. Instances are
//! built and dispatched through
//! [`exec::registry`](crate::exec::registry); there is no per-benchmark
//! enumeration here anymore.

pub mod bfs;
pub mod bloom;
pub mod cms;
pub mod graph;
pub mod histogram;
pub mod hll;
pub mod kmeans;
pub mod kvserve;
pub mod kvstore;
pub mod pagerank;
pub mod sketch;
pub mod traffic;
