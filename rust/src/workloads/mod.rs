//! The paper's four benchmarks (Section 5.1), each in FGL / DUP / CCache
//! (plus CGL and atomics where meaningful) over the simulated machine:
//!
//! * [`kvstore`] — random-access key-value store with commutative
//!   increments; merge-function variants: saturating add and complex
//!   multiply (Section 6.3)
//! * [`kmeans`] — Lloyd's K-Means with CData cluster centers; approximate
//!   merge variant (Section 6.3)
//! * [`pagerank`] — push-based PageRank with CData rank accumulators;
//!   optimized double-buffer DUP (the paper's Section 5.1 scheme)
//! * [`bfs`] — level-synchronous BFS over a bitmap frontier (GAP-style),
//!   with an additional atomics variant (Section 6.2)
//! * [`graph`] — CSR + RMAT / SSCA / uniform generators (Graph500/GAP
//!   input substitution)
//!
//! Every workload verifies its final simulated-memory state against a
//! sequential golden run — the paper's Section 3 serializability claim is
//! *checked*, not assumed, on every benchmark execution.

pub mod bfs;
pub mod graph;
pub mod kmeans;
pub mod kvstore;
pub mod pagerank;

use crate::exec::{RunResult, Variant};
use crate::sim::config::MachineConfig;

/// Uniform handle over all benchmarks for the coordinator / CLI.
#[derive(Clone, Debug)]
pub enum Benchmark {
    Kv(kvstore::KvParams),
    KMeans(kmeans::KmParams),
    PageRank(pagerank::PrParams),
    Bfs(bfs::BfsParams),
}

impl Benchmark {
    pub fn name(&self) -> String {
        match self {
            Benchmark::Kv(p) => format!("kvstore-{}", p.merge.name()),
            Benchmark::KMeans(p) => {
                if p.approx_drop_p > 0.0 {
                    "kmeans-approx".to_string()
                } else {
                    "kmeans".to_string()
                }
            }
            Benchmark::PageRank(p) => format!("pagerank-{}", p.graph.name()),
            Benchmark::Bfs(p) => format!("bfs-{}", p.graph.name()),
        }
    }

    pub fn run(&self, variant: Variant, cfg: MachineConfig) -> RunResult {
        match self {
            Benchmark::Kv(p) => kvstore::run(p, variant, cfg),
            Benchmark::KMeans(p) => kmeans::run(p, variant, cfg),
            Benchmark::PageRank(p) => pagerank::run(p, variant, cfg),
            Benchmark::Bfs(p) => bfs::run(p, variant, cfg),
        }
    }

    /// Variants this benchmark supports.
    pub fn variants(&self) -> Vec<Variant> {
        match self {
            Benchmark::Kv(_) => vec![
                Variant::Cgl,
                Variant::Fgl,
                Variant::Dup,
                Variant::CCache,
            ],
            Benchmark::KMeans(_) => vec![Variant::Fgl, Variant::Dup, Variant::CCache],
            Benchmark::PageRank(_) => vec![Variant::Fgl, Variant::Dup, Variant::CCache],
            Benchmark::Bfs(_) => vec![
                Variant::Fgl,
                Variant::Dup,
                Variant::CCache,
                Variant::Atomic,
            ],
        }
    }
}
