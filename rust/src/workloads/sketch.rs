//! Shared substrate for the streaming-sketch workload family
//! ([`cms`](crate::workloads::cms), [`bloom`](crate::workloads::bloom),
//! [`hll`](crate::workloads::hll)): salted 64-bit hashing, u8-lane
//! packing helpers, and the [`MaxU8x64`] merge function.
//!
//! `MaxU8x64` is deliberately defined *here*, in the workload layer, and
//! registered only through the public
//! [`MergeRegistry::register`](crate::merge::MergeRegistry::register)
//! call — the same proof shape as `merge/ext.rs`, one layer further out:
//! no file under `merge/` names it, no match arm dispatches on it, yet it
//! drives the HyperLogLog workload to golden verification and is
//! law-checked by the auto-generated suite like any built-in. That is the
//! openness property the merge-API redesign exists to provide.

use crate::merge::registry::{no_param, MergeRegistry};
use crate::merge::{handle, LineData, MergeFn, MergeOperand, LINE_WORDS};
use crate::util::rng::{Rng, Zipf};

// ---------------------------------------------------------------------
// hashing
// ---------------------------------------------------------------------

/// SplitMix64 finalizer: a full-avalanche 64-bit mixer.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Salted key hash: the row/probe family every sketch derives its
/// per-row (CMS), per-probe (Bloom) and register (HLL) indices from.
/// Distinct salts give effectively independent hash functions.
#[inline]
pub fn hash_key(key: u64, salt: u64) -> u64 {
    mix64(key ^ mix64(salt.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1)))
}

/// Host-side uniform-or-zipf key/item stream over `[0, key_space)` —
/// the shared generator behind every sketch's ingest stream (programs
/// and golden runs consume the same vector). `seed` is the workload
/// seed already salted per sketch, so streams stay decorrelated.
pub fn keyed_stream(seed: u64, items: usize, key_space: usize, zipf_theta: f64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let zipf = (zipf_theta > 0.0).then(|| Zipf::new(key_space, zipf_theta));
    (0..items)
        .map(|_| match &zipf {
            Some(z) => z.sample(&mut rng) as u32,
            None => rng.usize_below(key_space) as u32,
        })
        .collect()
}

// ---------------------------------------------------------------------
// u8-lane packing (HLL registers: 4 registers per u32 word)
// ---------------------------------------------------------------------

/// Extract u8 lane `lane` (0..4) of a packed word.
#[inline]
pub fn lane_get(word: u32, lane: usize) -> u8 {
    word.to_le_bytes()[lane]
}

/// Return `word` with u8 lane `lane` replaced by `val`.
#[inline]
pub fn lane_set(word: u32, lane: usize, val: u8) -> u32 {
    let mut b = word.to_le_bytes();
    b[lane] = val;
    u32::from_le_bytes(b)
}

/// Lane-wise u8 max of two packed words.
#[inline]
pub fn lane_max_word(a: u32, b: u32) -> u32 {
    let (x, y) = (a.to_le_bytes(), b.to_le_bytes());
    u32::from_le_bytes([
        x[0].max(y[0]),
        x[1].max(y[1]),
        x[2].max(y[2]),
        x[3].max(y[3]),
    ])
}

// ---------------------------------------------------------------------
// the max_u8x64 merge function
// ---------------------------------------------------------------------

/// `mem = max(mem, upd)` lane-wise over the line's 64 u8 lanes — the
/// HyperLogLog register merge (each 64-byte line holds 64 packed
/// registers). Max is commutative, associative and idempotent, so the
/// source copy is ignored and re-merging is harmless. No AOT batch
/// kernel: the PJRT batch path transparently falls back to this native
/// `apply`.
pub struct MaxU8x64;

impl MergeFn for MaxU8x64 {
    fn name(&self) -> &str {
        "max_u8x64"
    }

    fn apply(&self, _src: &LineData, upd: &LineData, mem: &LineData, _drop: bool) -> LineData {
        let mut out = *mem;
        for i in 0..LINE_WORDS {
            out[i] = lane_max_word(mem[i], upd[i]);
        }
        out
    }

    fn idempotent(&self) -> bool {
        true
    }

    fn sample_line(&self, rng: &mut Rng, _role: MergeOperand) -> LineData {
        // lane max is defined bit-exactly for every byte pattern: draw
        // the full u32 domain rather than the default f32 range
        let mut l = [0u32; LINE_WORDS];
        for w in l.iter_mut() {
            *w = rng.next_u32();
        }
        l
    }
}

/// Register the sketch merge functions into `reg` — consumer-side
/// registration through the exact public API any downstream crate would
/// use (the CLI and the property suite both call this; nothing under
/// `merge/` knows these functions exist).
pub fn register_sketch_merges(reg: &mut MergeRegistry) {
    reg.register(
        "max_u8x64",
        "lane-wise u8 max over 64 lanes (HLL registers)",
        |p| {
            no_param("max_u8x64", p)?;
            Ok(handle(MaxU8x64))
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_salts_decorrelate() {
        // the same key under different salts must land on different
        // values essentially always
        let same = (0..1000u64)
            .filter(|&k| hash_key(k, 1) % 64 == hash_key(k, 2) % 64)
            .count();
        assert!(same < 40, "salted hashes too correlated: {same}/1000");
    }

    #[test]
    fn lane_roundtrip_and_max() {
        let w = u32::from_le_bytes([1, 200, 3, 40]);
        assert_eq!(lane_get(w, 1), 200);
        assert_eq!(lane_get(lane_set(w, 2, 99), 2), 99);
        let a = u32::from_le_bytes([1, 200, 3, 40]);
        let b = u32::from_le_bytes([9, 100, 3, 41]);
        assert_eq!(lane_max_word(a, b), u32::from_le_bytes([9, 200, 3, 41]));
    }

    #[test]
    fn max_u8x64_is_lane_max_and_idempotent() {
        let mem = [u32::from_le_bytes([5, 0, 255, 7]); LINE_WORDS];
        let upd = [u32::from_le_bytes([4, 9, 1, 7]); LINE_WORDS];
        let src = [0u32; LINE_WORDS];
        let once = MaxU8x64.apply(&src, &upd, &mem, false);
        assert_eq!(once, [u32::from_le_bytes([5, 9, 255, 7]); LINE_WORDS]);
        let twice = MaxU8x64.apply(&src, &upd, &once, false);
        assert_eq!(twice, once, "idempotence");
        assert!(MaxU8x64.idempotent());
    }

    #[test]
    fn max_u8x64_registers_through_the_public_api_and_obeys_the_laws() {
        use crate::merge::default_registry;
        use crate::util::ptest::check_merge_fn_laws;
        let mut reg = default_registry();
        register_sketch_merges(&mut reg);
        let f = reg.build("max_u8x64").unwrap();
        assert_eq!(f.name(), "max_u8x64");
        assert!(f.idempotent());
        check_merge_fn_laws(f.as_ref(), 0x5C, 50);
    }
}
