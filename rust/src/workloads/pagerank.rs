//! PageRank benchmark (Section 5.1).
//!
//! Damped power iteration over a CSR graph, fixed iteration count.
//! Variants:
//! * FGL — push-based: each core pushes its vertices' contributions into
//!   `rank_next[v]` under a per-vertex lock
//! * DUP — the paper's *optimized* duplication: no locks, pull-based
//!   double buffer. One read-only copy holds the previous iteration, the
//!   other receives this iteration's values; copies switch each
//!   iteration. Requires the transpose (in-edge) CSR.
//! * CCache — push-based with `rank_next` as CData (AddF32 merges) and
//!   soft_merge per source vertex
//!
//! Inputs: RMAT / SSCA / uniform graphs (Graph500 generator
//! substitution, see workloads::graph).

use crate::exec::registry::SizeSpec;
use crate::exec::scaffold::LockArray;
use crate::exec::{driver, ExecCtx, RunResult, Variant, Workload};
use crate::merge::funcs::AddF32;
use crate::merge::{handle, MergeHandle};
use crate::sim::addr::Addr;
use crate::sim::config::MachineConfig;
use crate::sim::memsys::MemSystem;
use crate::workloads::graph::{generate, Csr, GraphKind};

#[derive(Clone, Debug)]
pub struct PrParams {
    pub vertices: usize,
    pub avg_degree: usize,
    pub graph: GraphKind,
    pub iters: usize,
    pub damping: f32,
    pub seed: u64,
}

impl Default for PrParams {
    fn default() -> Self {
        Self {
            vertices: 4096,
            avg_degree: 8,
            graph: GraphKind::Uniform,
            iters: 3,
            damping: 0.85,
            seed: 0x9A6E,
        }
    }
}

impl PrParams {
    pub fn with_vertices(mut self, v: usize) -> Self {
        self.vertices = v;
        self
    }

    pub fn with_graph(mut self, g: GraphKind) -> Self {
        self.graph = g;
        self
    }

    /// Rank-structure working set (two f32 arrays) — the Fig 6 x-axis.
    pub fn working_set_bytes(&self) -> u64 {
        (self.vertices * 8) as u64
    }

    pub fn build_graph(&self) -> Csr {
        generate(self.graph, self.vertices, self.avg_degree, self.seed)
    }
}

/// Sequential golden run (push order, matching the parallel variants'
/// arithmetic up to merge reordering).
pub fn golden(p: &PrParams, g: &Csr) -> Vec<f32> {
    let v = g.vertices();
    let mut old = vec![1.0f32 / v as f32; v];
    let mut new = vec![0.0f32; v];
    for _ in 0..p.iters {
        new.iter_mut().for_each(|x| *x = 0.0);
        for u in 0..v {
            let deg = g.out_degree(u);
            if deg == 0 {
                continue;
            }
            let contrib = old[u] / deg as f32;
            for &t in g.neighbors(u) {
                new[t as usize] += contrib;
            }
        }
        for t in 0..v {
            new[t] = (1.0 - p.damping) / v as f32 + p.damping * new[t];
        }
        std::mem::swap(&mut old, &mut new);
    }
    old
}

#[derive(Clone, Copy)]
pub struct PrLayout {
    offsets: Addr,
    targets: Addr,
    /// Transpose CSR (pull-based variants only).
    t_offsets: Addr,
    t_targets: Addr,
    /// Out-degree array (DUP pull needs source degrees).
    out_deg: Addr,
    rank: [Addr; 2], // double buffer: roles swap each iteration
    locks: LockArray,
}

const SLOT_RANK: usize = 0;

/// The variants PageRank implements.
pub const VARIANTS: [Variant; 3] = [Variant::Fgl, Variant::Dup, Variant::CCache];

/// PageRank as a [`Workload`]: owns the generated graph so setup,
/// golden and verification share one CSR.
pub struct PrWorkload {
    p: PrParams,
    g: Csr,
}

impl PrWorkload {
    pub fn new(p: PrParams) -> Self {
        let g = p.build_graph();
        Self { p, g }
    }

    /// Size rank arrays + CSR to `frac` x LLC:
    /// rank (8 B/v) + CSR ((1+deg)*4 B/v), deg=8 -> 44 B/v.
    pub fn sized(graph: GraphKind, s: &SizeSpec) -> Self {
        let vertices = (s.target_bytes() / 44).max(256) as usize;
        Self::new(PrParams {
            vertices,
            avg_degree: 8,
            graph,
            iters: 2,
            damping: 0.85,
            seed: s.seed,
        })
    }

    pub fn params(&self) -> &PrParams {
        &self.p
    }
}

impl Workload for PrWorkload {
    type Layout = PrLayout;
    type Golden = Vec<f32>;

    fn name(&self) -> String {
        format!("pagerank-{}", self.p.graph.name())
    }

    fn supported_variants(&self) -> Vec<Variant> {
        VARIANTS.to_vec()
    }

    fn footprint(&self) -> u64 {
        self.p.working_set_bytes()
    }

    fn merge_slots(&self) -> Vec<(usize, MergeHandle)> {
        vec![(SLOT_RANK, handle(AddF32))]
    }

    fn setup(&self, mem: &mut MemSystem, variant: Variant, _cores: usize) -> PrLayout {
        let g = &self.g;
        let v = g.vertices();
        // pull-based variants (DUP and CCache) work on the transpose; the
        // push-based FGL works on the forward CSR. Each variant allocates
        // only the direction it uses (Table 3 footprint).
        let pull = matches!(variant, Variant::Dup | Variant::CCache);
        let (offsets, targets) = if !pull {
            let offsets = mem.alloc_lines((v as u64 + 1) * 4);
            for (i, &o) in g.offsets.iter().enumerate() {
                mem.poke(offsets.add(i as u64 * 4), o);
            }
            let targets = mem.alloc_lines(g.edges().max(1) as u64 * 4);
            for (i, &tv) in g.targets.iter().enumerate() {
                mem.poke(targets.add(i as u64 * 4), tv);
            }
            (offsets, targets)
        } else {
            (Addr(0), Addr(0))
        };
        let rank0 = mem.alloc_lines(v as u64 * 4);
        let rank1 = mem.alloc_lines(v as u64 * 4);
        let init = 1.0f32 / v as f32;
        for i in 0..v as u64 {
            mem.poke_f32(rank0.add(i * 4), init);
            mem.poke_f32(rank1.add(i * 4), 0.0);
        }
        let mut l = PrLayout {
            offsets,
            targets,
            t_offsets: Addr(0),
            t_targets: Addr(0),
            out_deg: Addr(0),
            rank: [rank0, rank1],
            locks: LockArray::none(),
        };
        if pull {
            let tg = g.transpose();
            let t_offsets = mem.alloc_lines((v as u64 + 1) * 4);
            for (i, &o) in tg.offsets.iter().enumerate() {
                mem.poke(t_offsets.add(i as u64 * 4), o);
            }
            let t_targets = mem.alloc_lines(tg.edges().max(1) as u64 * 4);
            for (i, &tv) in tg.targets.iter().enumerate() {
                mem.poke(t_targets.add(i as u64 * 4), tv);
            }
            let out_deg = mem.alloc_lines(v as u64 * 4);
            for i in 0..v {
                mem.poke(out_deg.add(i as u64 * 4), g.out_degree(i) as u32);
            }
            l.t_offsets = t_offsets;
            l.t_targets = t_targets;
            l.out_deg = out_deg;
        }
        if variant == Variant::Fgl {
            // per-vertex lock, unpadded (4 B each) — PageRank's FGL
            // footprint in Table 3 is modest
            l.locks = LockArray::alloc(mem, v as u64, 4);
        }
        l
    }

    fn program<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        cores: usize,
        variant: Variant,
        l: &PrLayout,
    ) {
        let p = &self.p;
        let v = self.g.vertices();
        let lo = core * v / cores;
        let hi = (core + 1) * v / cores;

        for iter in 0..p.iters {
            let old = l.rank[iter % 2];
            let new = l.rank[(iter + 1) % 2];

            match variant {
                Variant::Fgl => {
                    // push: iterate my sources, scatter
                    // contributions under per-vertex locks
                    for u in lo..hi {
                        let s = ctx.read_u32(l.offsets.add(u as u64 * 4));
                        let e = ctx.read_u32(l.offsets.add((u as u64 + 1) * 4));
                        let deg = e - s;
                        if deg == 0 {
                            continue;
                        }
                        let r = ctx.read_f32(old.add(u as u64 * 4));
                        let contrib = r / deg as f32;
                        ctx.compute(2);
                        for ei in s..e {
                            let tv = ctx.read_u32(l.targets.add(ei as u64 * 4)) as u64;
                            let a = new.add(tv * 4);
                            l.locks.lock(ctx, tv);
                            let cur = ctx.read_f32(a);
                            ctx.write_f32(a, cur + contrib);
                            l.locks.unlock(ctx, tv);
                            ctx.compute(1);
                        }
                    }
                    ctx.barrier();
                    // damping pass over my destination range
                    for dst in lo..hi {
                        let a = new.add(dst as u64 * 4);
                        let r = ctx.read_f32(a);
                        ctx.write_f32(a, (1.0 - p.damping) / v as f32 + p.damping * r);
                        ctx.compute(2);
                    }
                    // reset the old buffer: it becomes the next
                    // iteration's accumulator
                    if iter + 1 < p.iters {
                        for dst in lo..hi {
                            ctx.write_f32(old.add(dst as u64 * 4), 0.0);
                        }
                    }
                    ctx.barrier();
                }
                Variant::Dup | Variant::CCache => {
                    // pull: iterate my destinations, gather from
                    // in-neighbors. DUP reads the shared old copy
                    // coherently (the paper's optimized
                    // double-buffer duplication); CCache marks
                    // the whole rank structure CData — old-rank
                    // reads privatize lines that stay clean and
                    // are silently dropped under dirty-merge
                    // (Section 6.4), new-rank writes carry the
                    // AddF32 merge.
                    for dst in lo..hi {
                        let s = ctx.read_u32(l.t_offsets.add(dst as u64 * 4));
                        let e = ctx.read_u32(l.t_offsets.add((dst as u64 + 1) * 4));
                        let mut acc = 0f32;
                        for ei in s..e {
                            let u = ctx.read_u32(l.t_targets.add(ei as u64 * 4)) as u64;
                            let deg = ctx.read_u32(l.out_deg.add(u * 4));
                            let r = if variant == Variant::CCache {
                                let r = ctx.c_read_f32(old.add(u * 4), SLOT_RANK as u8);
                                ctx.soft_merge(); // w-1 discipline
                                r
                            } else {
                                ctx.read_f32(old.add(u * 4))
                            };
                            acc += r / deg as f32;
                            ctx.compute(2);
                        }
                        let val = (1.0 - p.damping) / v as f32 + p.damping * acc;
                        let a = new.add(dst as u64 * 4);
                        if variant == Variant::CCache {
                            let cur = ctx.c_read_f32(a, SLOT_RANK as u8);
                            ctx.c_write_f32(a, cur + val, SLOT_RANK as u8);
                            ctx.soft_merge();
                        } else {
                            ctx.write_f32(a, val);
                        }
                    }
                    if variant == Variant::CCache {
                        ctx.merge();
                    }
                    ctx.barrier();
                    // CCache: reset the old buffer (next
                    // iteration's merge-add accumulator starts
                    // from zero); DUP overwrites, no reset needed
                    if variant == Variant::CCache && iter + 1 < p.iters {
                        for dst in lo..hi {
                            ctx.write_f32(old.add(dst as u64 * 4), 0.0);
                        }
                        ctx.barrier();
                    }
                }
                _ => unreachable!("driver rejects unsupported variants"),
            }
        }
    }

    fn golden(&self, _cores: usize) -> Vec<f32> {
        golden(&self.p, &self.g)
    }

    fn verify(
        &self,
        mem: &mut MemSystem,
        l: &PrLayout,
        gold: &Vec<f32>,
        _cores: usize,
    ) -> (bool, Option<f64>) {
        let v = self.g.vertices();
        let final_buf = l.rank[self.p.iters % 2];
        let ok = (0..v).all(|i| {
            let got = mem.peek_f32(final_buf.add(i as u64 * 4));
            (got - gold[i]).abs() <= 1e-4 + 1e-3 * gold[i].abs()
        });
        (ok, None)
    }
}

/// Run through the generic driver, panicking on unsupported variants.
pub fn run(p: &PrParams, variant: Variant, cfg: MachineConfig) -> RunResult {
    driver::run(&PrWorkload::new(p.clone()), variant, cfg).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> PrParams {
        PrParams {
            vertices: 256,
            avg_degree: 4,
            graph: GraphKind::Uniform,
            iters: 2,
            damping: 0.85,
            seed: 5,
        }
    }

    fn cfg() -> MachineConfig {
        MachineConfig::test_small().with_cores(2)
    }

    #[test]
    fn all_variants_verify_uniform() {
        for v in [Variant::Fgl, Variant::Dup, Variant::CCache] {
            let r = run(&small(), v, cfg());
            assert!(r.verified, "variant {v:?} diverged from golden");
        }
    }

    #[test]
    fn rmat_and_ssca_inputs_verify() {
        for kind in [GraphKind::Rmat, GraphKind::Ssca] {
            let p = small().with_graph(kind);
            for v in [Variant::Fgl, Variant::CCache] {
                let r = run(&p, v, cfg());
                assert!(r.verified, "{kind:?}/{v:?} diverged");
            }
        }
    }

    #[test]
    fn golden_ranks_form_distribution() {
        let p = small();
        let g = p.build_graph();
        let gold = golden(&p, &g);
        let sum: f32 = gold.iter().sum();
        // dangling mass leaks, so <= 1; all entries positive
        assert!(sum > 0.2 && sum <= 1.001, "sum={sum}");
        assert!(gold.iter().all(|&r| r > 0.0));
    }

    #[test]
    fn dup_variant_has_no_lock_traffic() {
        let r = run(&small(), Variant::Dup, cfg());
        assert_eq!(r.stats.lock_acquires, 0);
    }

    #[test]
    fn ccache_merges_ranks() {
        let r = run(&small(), Variant::CCache, cfg());
        assert!(r.stats.merges > 0);
        assert!(r.stats.cops > 0);
    }
}
