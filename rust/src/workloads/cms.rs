//! Count-min sketch ingest — the first of the streaming-sketch workload
//! family: cores stream a zipf- (or uniformly-) keyed update stream and
//! increment `depth` hashed cells per key in a `depth x width` counter
//! matrix. Per-cell counters saturate at [`CmsParams::sat_max`]
//! (narrow-counter emulation), so the CCache variant installs the
//! saturating-add merge ([`SatAddU32`]) — the Section 6.3 "software
//! merge functions generalize" scenario at sketch scale.
//!
//! Saturating increments commute: the final cell value is
//! `min(total_increments, sat_max)` under every interleaving and every
//! merge schedule, so verification demands *exact* equality with the
//! sequential golden sketch on all variants.

use crate::exec::registry::SizeSpec;
use crate::exec::scaffold::{DupSpace, LockArray, PTHREAD_LOCK_BYTES};
use crate::exec::{driver, ExecCtx, RunResult, Variant, Workload};
use crate::merge::funcs::SatAddU32;
use crate::merge::{handle, MergeHandle};
use crate::sim::addr::Addr;
use crate::sim::config::MachineConfig;
use crate::sim::memsys::MemSystem;
use crate::workloads::sketch::{hash_key, keyed_stream};

/// Salt base for the per-row hash family.
const ROW_SALT: u64 = 0xC0_55;

#[derive(Clone, Debug)]
pub struct CmsParams {
    /// Stream length (keys ingested).
    pub items: usize,
    /// Cells per row.
    pub width: usize,
    /// Hash rows.
    pub depth: usize,
    /// Per-cell saturation ceiling (narrow-counter emulation).
    pub sat_max: u32,
    pub seed: u64,
    /// 0.0 = uniform keys; >0 = zipf-skewed hot keys.
    pub zipf_theta: f64,
}

impl Default for CmsParams {
    fn default() -> Self {
        Self {
            items: 16384,
            width: 1024,
            depth: 4,
            sat_max: 65535,
            seed: 0xC3_5,
            zipf_theta: 0.0,
        }
    }
}

impl CmsParams {
    /// Distinct keys the stream draws from (4x the row width keeps the
    /// sketch in its over-subscribed, collision-bearing regime).
    pub fn key_space(&self) -> usize {
        self.width * 4
    }

    /// Input stream + counter matrix (the Fig 6 x-axis).
    pub fn working_set_bytes(&self) -> u64 {
        (self.items * 4 + self.depth * self.width * 4) as u64
    }

    /// The hashed column of `key` in row `r`.
    pub fn column(&self, key: u64, r: usize) -> u64 {
        hash_key(key, ROW_SALT + r as u64) % self.width as u64
    }
}

/// Host-side key stream (shared by programs and the golden run).
fn key_stream(p: &CmsParams) -> Vec<u32> {
    keyed_stream(p.seed ^ 0xC4_5517, p.items, p.key_space(), p.zipf_theta)
}

/// Sequential golden sketch: row-major `depth x width` saturated counts.
pub fn golden_cells(p: &CmsParams) -> Vec<u32> {
    let mut cells = vec![0u32; p.depth * p.width];
    for key in key_stream(p) {
        for r in 0..p.depth {
            let c = p.column(key as u64, r) as usize;
            let cell = &mut cells[r * p.width + c];
            *cell = cell.saturating_add(1).min(p.sat_max);
        }
    }
    cells
}

/// Point query against a golden (or any row-major) cell array: the
/// count-min estimate is the minimum over the key's row cells.
pub fn point_query(p: &CmsParams, cells: &[u32], key: u64) -> u32 {
    (0..p.depth)
        .map(|r| cells[r * p.width + p.column(key, r) as usize])
        .min()
        .unwrap_or(0)
}

#[derive(Clone, Copy)]
pub struct CmsLayout {
    input: Addr,
    /// Row-major `depth x width` u32 counter matrix.
    cells: Addr,
    global_lock: Addr,
    locks: LockArray,
    copies: DupSpace,
}

/// CMS implements every variant, like histogram (the CAS-loop atomic
/// saturating increment included).
pub const VARIANTS: [Variant; 5] = [
    Variant::Cgl,
    Variant::Fgl,
    Variant::Dup,
    Variant::CCache,
    Variant::Atomic,
];

pub struct CmsWorkload {
    p: CmsParams,
}

impl CmsWorkload {
    pub fn new(p: CmsParams) -> Self {
        Self { p }
    }

    /// Size the counter matrix to `frac` x LLC; the stream scales with
    /// the width so per-cell traffic stays constant across fractions.
    pub fn sized(s: &SizeSpec) -> Self {
        let depth = if s.sketch.cms_depth > 0 {
            s.sketch.cms_depth
        } else {
            4
        };
        let width = (s.target_bytes() / (4 * depth as u64)).max(64) as usize;
        Self::new(CmsParams {
            items: (width * 4).max(2048),
            width,
            depth,
            sat_max: 65535,
            seed: s.seed,
            zipf_theta: s.zipf_theta,
        })
    }

    pub fn params(&self) -> &CmsParams {
        &self.p
    }
}

impl Workload for CmsWorkload {
    type Layout = CmsLayout;
    type Golden = Vec<u32>;

    fn name(&self) -> String {
        "cms".into()
    }

    fn supported_variants(&self) -> Vec<Variant> {
        VARIANTS.to_vec()
    }

    fn footprint(&self) -> u64 {
        self.p.working_set_bytes()
    }

    fn merge_slots(&self) -> Vec<(usize, MergeHandle)> {
        vec![(0, handle(SatAddU32 { max: self.p.sat_max }))]
    }

    fn setup(&self, mem: &mut MemSystem, variant: Variant, cores: usize) -> CmsLayout {
        let p = &self.p;
        let input = mem.alloc_lines(p.items as u64 * 4);
        for (i, k) in key_stream(p).into_iter().enumerate() {
            mem.poke(input.add(i as u64 * 4), k);
        }
        let cells = mem.alloc_lines((p.depth * p.width) as u64 * 4);
        let mut l = CmsLayout {
            input,
            cells,
            global_lock: Addr(0),
            locks: LockArray::none(),
            copies: DupSpace::none(),
        };
        match variant {
            Variant::Cgl => l.global_lock = mem.alloc_lines(64),
            Variant::Fgl => {
                l.locks = LockArray::alloc(
                    mem,
                    (p.depth * p.width) as u64,
                    PTHREAD_LOCK_BYTES,
                )
            }
            Variant::Dup => {
                l.copies = DupSpace::alloc(mem, (p.depth * p.width) as u64 * 4, cores)
            }
            _ => {}
        }
        l
    }

    fn program<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        cores: usize,
        variant: Variant,
        l: &CmsLayout,
    ) {
        let p = &self.p;
        let lo = core * p.items / cores;
        let hi = (core + 1) * p.items / cores;
        for i in lo..hi {
            let key = ctx.read_u32(l.input.add(i as u64 * 4)) as u64;
            for r in 0..p.depth {
                let cell = (r as u64) * p.width as u64 + p.column(key, r);
                let a = l.cells.add(cell * 4);
                match variant {
                    Variant::Cgl | Variant::Fgl => {
                        let lock = if variant == Variant::Fgl {
                            l.locks.addr(cell)
                        } else {
                            l.global_lock
                        };
                        ctx.lock(lock);
                        let v = ctx.read_u32(a);
                        ctx.write_u32(a, v.saturating_add(1).min(p.sat_max));
                        ctx.unlock(lock);
                    }
                    Variant::Dup => {
                        // private copies hold raw counts; the reduction
                        // applies the clamp against the master (the same
                        // contract as the saturating merge function)
                        let pa = l.copies.copy_base(core).add(cell * 4);
                        let v = ctx.read_u32(pa);
                        ctx.write_u32(pa, v.wrapping_add(1));
                    }
                    Variant::CCache => {
                        let v = ctx.c_read_u32(a, 0);
                        ctx.c_write_u32(a, v.saturating_add(1).min(p.sat_max), 0);
                        ctx.soft_merge();
                    }
                    Variant::Atomic => loop {
                        let v = ctx.read_u32(a);
                        let n = v.saturating_add(1).min(p.sat_max);
                        if n == v {
                            break; // already saturated: nothing to publish
                        }
                        if ctx.cas_u32(a, v, n) {
                            break;
                        }
                    },
                }
                ctx.compute(2);
            }
        }
        if variant == Variant::CCache {
            ctx.merge();
        }
        ctx.barrier();
        if variant == Variant::Dup {
            let cells = (p.depth * p.width) as u64;
            let lo = core as u64 * cells / cores as u64;
            let hi = (core as u64 + 1) * cells / cores as u64;
            for cell in lo..hi {
                let master = l.cells.add(cell * 4);
                let mut acc = ctx.read_u32(master);
                for c in 0..cores {
                    let v = ctx.read_u32(l.copies.copy_base(c).add(cell * 4));
                    acc = acc.saturating_add(v);
                    ctx.compute(1);
                }
                ctx.write_u32(master, acc.min(p.sat_max));
            }
            ctx.barrier();
        }
    }

    fn golden(&self, _cores: usize) -> Vec<u32> {
        golden_cells(&self.p)
    }

    fn verify(
        &self,
        mem: &mut MemSystem,
        l: &CmsLayout,
        gold: &Vec<u32>,
        _cores: usize,
    ) -> (bool, Option<f64>) {
        let n = self.p.depth * self.p.width;
        let ok = (0..n).all(|i| mem.peek(l.cells.add(i as u64 * 4)) == gold[i]);
        (ok, None)
    }
}

/// Run through the generic driver, panicking on unsupported variants.
pub fn run(p: &CmsParams, variant: Variant, cfg: MachineConfig) -> RunResult {
    driver::run(&CmsWorkload::new(p.clone()), variant, cfg).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CmsParams {
        CmsParams {
            items: 4096,
            width: 256,
            depth: 3,
            sat_max: 65535,
            seed: 21,
            zipf_theta: 0.0,
        }
    }

    fn cfg() -> MachineConfig {
        MachineConfig::test_small().with_cores(2)
    }

    #[test]
    fn all_five_variants_verify() {
        for v in VARIANTS {
            let r = run(&small(), v, cfg());
            assert!(r.verified, "variant {v:?} diverged from golden");
        }
    }

    #[test]
    fn zipf_stream_verifies_and_concentrates() {
        let p = CmsParams {
            zipf_theta: 0.99,
            ..small()
        };
        for v in [Variant::Fgl, Variant::Dup, Variant::CCache, Variant::Atomic] {
            let r = run(&p, v, cfg());
            assert!(r.verified, "variant {v:?} diverged");
        }
        // the hottest key dominates under heavy skew
        let cells = golden_cells(&p);
        let max = *cells.iter().max().unwrap() as f64;
        let mean = p.items as f64 / p.width as f64;
        assert!(max > 4.0 * mean, "zipf should concentrate: {max} vs {mean}");
    }

    #[test]
    fn tiny_sat_max_clamps_identically_on_every_variant() {
        // a 2-bit-counter-style ceiling forces the saturating paths
        let p = CmsParams {
            sat_max: 3,
            zipf_theta: 0.99,
            ..small()
        };
        let gold = golden_cells(&p);
        assert!(gold.iter().any(|&c| c == 3), "clamp never engaged");
        for v in VARIANTS {
            let r = run(&p, v, cfg());
            assert!(r.verified, "variant {v:?} diverged under saturation");
        }
    }

    #[test]
    fn point_queries_never_undercount() {
        let p = small();
        let cells = golden_cells(&p);
        // true per-key counts
        let mut truth = vec![0u32; p.key_space()];
        for k in key_stream(&p) {
            truth[k as usize] += 1;
        }
        for (k, &t) in truth.iter().enumerate() {
            let est = point_query(&p, &cells, k as u64);
            assert!(
                est >= t.min(p.sat_max),
                "key {k}: estimate {est} < true {t}"
            );
        }
    }

    #[test]
    fn ccache_merges_with_the_saturating_function() {
        let r = run(&small(), Variant::CCache, cfg());
        assert!(r.stats.merges > 0);
        assert_eq!(r.merge_fns, vec!["sat_add_u32".to_string()]);
    }

    #[test]
    fn sized_respects_depth_override() {
        let mut s = SizeSpec::new(0.25, 1 << 16, 1);
        s.sketch.cms_depth = 2;
        let w = CmsWorkload::sized(&s);
        assert_eq!(w.params().depth, 2);
        assert!(w.footprint() > 0);
    }
}
