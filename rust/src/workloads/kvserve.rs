//! `kvserve` — the sharded multi-tenant KV *serving* workload: a
//! sustained request stream (trace-driven, [`traffic`](super::traffic))
//! against the commutatively-updated value table, executed in epochs
//! with a **soft-merge deadline**.
//!
//! Where the batch `kvstore` workload measures one update phase, this
//! models a serving tier: reads, commutative-increment updates and
//! short scans arrive interleaved per the YCSB-style mix, tenants' zipf
//! skews drift across epochs, and readers may observe *stale* values —
//! updates privatized by other cores and not yet merged. The run
//! measures that staleness as its quality metric:
//!
//! * **staleness age** of an update = operations (on the issuing core)
//!   between the update and the merge that publishes it;
//! * the run reports the **max** (the staleness *bound*) and the
//!   **mean** across all updates, in ops.
//!
//! Per variant: fgl/atomic publish immediately (age 0); dup publishes
//! at the per-epoch reduction (age bounded by the epoch length); ccache
//! soft-merges continuously and *forces* a merge every
//! [`ServeParams::merge_deadline`] updates, so its bound is the
//! deadline — the knob the `ccache serve` frontier sweeps. The bound is
//! not just reported but *checked* in [`Workload::verify`] on both
//! backends.
//!
//! Staleness accounting is performed by the program itself (it is a
//! pure function of the deterministic merge schedule, identical on the
//! simulator and the native backend) and published post-barrier into a
//! per-core stats line that verification reads back.

use std::sync::Mutex;

use crate::exec::registry::SizeSpec;
use crate::exec::scaffold::{DupSpace, LockArray, PTHREAD_LOCK_BYTES};
use crate::exec::{driver, ExecCtx, RunResult, Variant, Workload};
use crate::merge::funcs::AddU32;
use crate::merge::{handle, MergeHandle};
use crate::sim::addr::Addr;
use crate::sim::config::MachineConfig;
use crate::sim::memsys::MemSystem;

use super::traffic::{Mix, OpKind, Request, TraceGen, TrafficSpec};

/// Parameters of one serving run.
#[derive(Clone, Debug)]
pub struct ServeParams {
    pub traffic: TrafficSpec,
    /// Epoch-phased execution: every epoch ends in a publish + barrier.
    pub epochs: usize,
    /// Total requests = total_keys * accesses_per_key, split evenly
    /// across cores and epochs.
    pub accesses_per_key: usize,
    /// CCache variant: force a full merge after this many unmerged
    /// updates — the staleness bound, in ops.
    pub merge_deadline: usize,
}

impl Default for ServeParams {
    fn default() -> Self {
        Self {
            traffic: TrafficSpec {
                tenants: 4,
                keys_per_tenant: 256,
                shards: 4,
                mix: Mix::default(),
                base_theta: 0.6,
                skew_drift: 0.2,
                scan_len: 8,
                seed: 0x5E7E,
            },
            epochs: 4,
            accesses_per_key: 8,
            merge_deadline: 64,
        }
    }
}

impl ServeParams {
    /// Requests one core issues per epoch.
    pub fn ops_per_core_epoch(&self, cores: usize) -> usize {
        (self.traffic.total_keys() * self.accesses_per_key / (cores * self.epochs)).max(1)
    }

    /// Working-set bytes of the value table.
    pub fn working_set_bytes(&self) -> u64 {
        self.traffic.total_keys() as u64 * 4
    }
}

/// Aggregated staleness of one run: the bound (max age), the age sum
/// and the update count, all in ops. See the module docs for the model.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Staleness {
    pub max_ops: u64,
    pub sum_ops: u64,
    pub updates: u64,
}

impl Staleness {
    /// Mean age of an update at publication, in ops.
    pub fn mean_ops(&self) -> f64 {
        if self.updates == 0 {
            0.0
        } else {
            self.sum_ops as f64 / self.updates as f64
        }
    }
}

/// Per-core staleness accumulator the program carries through the run.
#[derive(Clone, Copy, Debug, Default)]
struct StalenessAcc {
    max: u64,
    sum: u64,
    cnt: u64,
}

impl StalenessAcc {
    /// `w` pending updates just got published together: their ages at
    /// the merge point are `w, w-1, ..., 1`.
    fn window(&mut self, w: u64) {
        if w > 0 {
            self.max = self.max.max(w);
            self.sum += w * (w + 1) / 2;
            self.cnt += w;
        }
    }

    /// `n` updates published immediately (age 0 — fgl/atomic).
    fn immediate(&mut self, n: u64) {
        self.cnt += n;
    }
}

/// Bytes reserved per core for the published staleness tallies (one
/// cache line each, so the post-barrier writes never false-share).
const STATS_LINE: u64 = 64;

#[derive(Clone, Copy)]
pub struct ServeLayout {
    values: Addr,
    locks: LockArray,
    copies: DupSpace,
    /// Per-core staleness stats lines ([max, sum_lo, sum_hi, cnt_lo,
    /// cnt_hi] as u32 words), written post-barrier, read by `verify`.
    stats: Addr,
    variant: Variant,
}

/// The variants the serving tier implements: no CGL (a global lock on a
/// serving tier is not a credible baseline), atomics included (point
/// increments map to CAS).
pub const VARIANTS: [Variant; 4] = [Variant::Fgl, Variant::Dup, Variant::CCache, Variant::Atomic];

/// The serving workload. Keeps the staleness report of the last
/// verified run so the serve coordinator can read max *and* mean
/// (`RunResult::quality` only carries the mean).
pub struct KvServeWorkload {
    p: ServeParams,
    last: Mutex<Option<Staleness>>,
}

impl KvServeWorkload {
    pub fn new(p: ServeParams) -> Self {
        Self {
            p,
            last: Mutex::new(None),
        }
    }

    /// Size the tier to `frac` x LLC, deriving defaults for every
    /// [`ServeSpec`](crate::exec::registry::ServeSpec) knob left at its
    /// sentinel.
    pub fn sized(s: &SizeSpec) -> Self {
        let sv = s.serve;
        let tenants = if sv.tenants == 0 { 4 } else { sv.tenants };
        let keys_total = ((s.target_bytes() / 4) as usize).max(256);
        let keys_per_tenant = (keys_total / tenants).max(64);
        let shards = if sv.shards == 0 { tenants } else { sv.shards };
        let mix = if sv.mix == (0, 0, 0) {
            Mix::default()
        } else {
            Mix {
                read: sv.mix.0,
                update: sv.mix.1,
                scan: sv.mix.2,
            }
        };
        Self::new(ServeParams {
            traffic: TrafficSpec {
                tenants,
                keys_per_tenant,
                shards,
                mix,
                base_theta: if s.zipf_theta > 0.0 {
                    s.zipf_theta
                } else {
                    0.6
                },
                skew_drift: if sv.skew_drift < 0.0 {
                    0.2
                } else {
                    sv.skew_drift
                },
                scan_len: 8,
                seed: s.seed,
            },
            epochs: 4,
            accesses_per_key: 8,
            merge_deadline: if sv.merge_deadline == 0 {
                64
            } else {
                sv.merge_deadline
            },
        })
    }

    pub fn params(&self) -> &ServeParams {
        &self.p
    }

    /// Staleness of the last verified run (`None` before any verify).
    pub fn staleness(&self) -> Option<Staleness> {
        *self.last.lock().unwrap()
    }

    fn read_one<C: ExecCtx>(&self, ctx: &mut C, variant: Variant, l: &ServeLayout, key: usize) {
        let a = l.values.add(key as u64 * 4);
        // ccache reads go through the COp path (own updates visible,
        // other cores' unmerged updates not — the staleness semantics);
        // the other variants read the shared table coherently
        let _ = match variant {
            Variant::CCache => ctx.c_read_u32(a, 0),
            _ => ctx.read_u32(a),
        };
    }

    fn update_one<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        variant: Variant,
        l: &ServeLayout,
        key: usize,
    ) {
        let k = key as u64;
        let a = l.values.add(k * 4);
        match variant {
            Variant::Fgl => {
                let lock = l.locks.addr(k);
                ctx.lock(lock);
                let v = ctx.read_u32(a);
                ctx.write_u32(a, v.wrapping_add(1));
                ctx.unlock(lock);
            }
            Variant::Atomic => loop {
                // fetch-add via CAS loop (the ISA has no fetch-add)
                let v = ctx.read_u32(a);
                if ctx.cas_u32(a, v, v.wrapping_add(1)) {
                    break;
                }
            },
            Variant::Dup => {
                let pa = l.copies.copy_base(core).add(k * 4);
                let v = ctx.read_u32(pa);
                ctx.write_u32(pa, v.wrapping_add(1));
            }
            Variant::CCache => {
                let v = ctx.c_read_u32(a, 0);
                ctx.c_write_u32(a, v.wrapping_add(1), 0);
            }
            Variant::Cgl => unreachable!("driver rejects unsupported variants"),
        }
    }

    fn scan_one<C: ExecCtx>(&self, ctx: &mut C, variant: Variant, l: &ServeLayout, req: Request) {
        let kpt = self.p.traffic.keys_per_tenant;
        let tstart = req.tenant * kpt;
        for i in 0..self.p.traffic.scan_len {
            let k = tstart + (req.key - tstart + i) % kpt;
            self.read_one(ctx, variant, l, k);
        }
    }

    /// Per-epoch DUP reduction: this core folds its key range over all
    /// copies into the master and zeroes the copies, so the next epoch
    /// accumulates fresh deltas.
    fn dup_reduce_epoch<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        cores: usize,
        l: &ServeLayout,
    ) {
        let keys = self.p.traffic.total_keys();
        let lo = (core * keys / cores) as u64;
        let hi = ((core + 1) * keys / cores) as u64;
        for k in lo..hi {
            let master = l.values.add(k * 4);
            let mut acc = ctx.read_u32(master);
            let mut touched = false;
            for c in 0..cores {
                let pa = l.copies.copy_base(c).add(k * 4);
                let v = ctx.read_u32(pa);
                if v != 0 {
                    acc = acc.wrapping_add(v);
                    ctx.write_u32(pa, 0);
                    touched = true;
                }
                ctx.compute(1);
            }
            if touched {
                ctx.write_u32(master, acc);
            }
        }
    }
}

impl Workload for KvServeWorkload {
    type Layout = ServeLayout;
    type Golden = Vec<u32>;

    fn name(&self) -> String {
        "kvserve".into()
    }

    fn supported_variants(&self) -> Vec<Variant> {
        VARIANTS.to_vec()
    }

    fn footprint(&self) -> u64 {
        self.p.working_set_bytes()
    }

    fn merge_slots(&self) -> Vec<(usize, MergeHandle)> {
        vec![(0, handle(AddU32))]
    }

    fn setup(&self, mem: &mut MemSystem, variant: Variant, cores: usize) -> ServeLayout {
        let keys = self.p.traffic.total_keys() as u64;
        let values = mem.alloc_lines(keys * 4);
        let mut l = ServeLayout {
            values,
            locks: LockArray::none(),
            copies: DupSpace::none(),
            stats: Addr(0),
            variant,
        };
        match variant {
            Variant::Fgl => l.locks = LockArray::alloc(mem, keys, PTHREAD_LOCK_BYTES),
            Variant::Dup => l.copies = DupSpace::alloc(mem, keys * 4, cores),
            _ => {}
        }
        l.stats = mem.alloc_lines(cores as u64 * STATS_LINE);
        l
    }

    fn program<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        cores: usize,
        variant: Variant,
        l: &ServeLayout,
    ) {
        let p = &self.p;
        let per_epoch = p.ops_per_core_epoch(cores);
        let deadline = p.merge_deadline as u64;
        let mut acc = StalenessAcc::default();
        let mut pending: u64 = 0; // unpublished updates by this core
        for epoch in 0..p.epochs {
            let mut gen = TraceGen::new(&p.traffic, core, cores, epoch);
            for _ in 0..per_epoch {
                let req = gen.next_request();
                match req.op {
                    OpKind::Read => self.read_one(ctx, variant, l, req.key),
                    OpKind::Scan => self.scan_one(ctx, variant, l, req),
                    OpKind::Update => {
                        self.update_one(ctx, core, variant, l, req.key);
                        match variant {
                            Variant::CCache => {
                                pending += 1;
                                ctx.soft_merge();
                                if pending >= deadline {
                                    ctx.merge();
                                    acc.window(pending);
                                    pending = 0;
                                }
                            }
                            Variant::Dup => pending += 1,
                            _ => acc.immediate(1),
                        }
                    }
                }
                ctx.compute(2);
            }
            // epoch boundary: publish everything still pending, then
            // synchronize — every variant runs the same barrier count
            match variant {
                Variant::CCache => {
                    ctx.merge();
                    acc.window(pending);
                    pending = 0;
                    ctx.barrier();
                }
                Variant::Dup => {
                    ctx.barrier();
                    self.dup_reduce_epoch(ctx, core, cores, l);
                    acc.window(pending);
                    pending = 0;
                    ctx.barrier();
                }
                _ => ctx.barrier(),
            }
        }
        ctx.barrier();
        // publish this core's tallies in its own stats line (plain
        // coherent stores; distinct lines, so no contention)
        let base = l.stats.add(core as u64 * STATS_LINE);
        ctx.write_u32(base, acc.max as u32);
        ctx.write_u32(base.add(4), acc.sum as u32);
        ctx.write_u32(base.add(8), (acc.sum >> 32) as u32);
        ctx.write_u32(base.add(12), acc.cnt as u32);
        ctx.write_u32(base.add(16), (acc.cnt >> 32) as u32);
    }

    fn golden(&self, cores: usize) -> Vec<u32> {
        golden_counts(&self.p, cores)
    }

    fn verify(
        &self,
        mem: &mut MemSystem,
        l: &ServeLayout,
        counts: &Vec<u32>,
        cores: usize,
    ) -> (bool, Option<f64>) {
        let p = &self.p;
        let values_ok =
            (0..p.traffic.total_keys()).all(|k| mem.peek(l.values.add(k as u64 * 4)) == counts[k]);
        // aggregate the per-core staleness tallies
        let mut st = Staleness::default();
        for core in 0..cores {
            let base = l.stats.add(core as u64 * STATS_LINE);
            st.max_ops = st.max_ops.max(mem.peek(base) as u64);
            st.sum_ops += mem.peek(base.add(4)) as u64 | (mem.peek(base.add(8)) as u64) << 32;
            st.updates += mem.peek(base.add(12)) as u64 | (mem.peek(base.add(16)) as u64) << 32;
        }
        // the staleness *bound* is part of verification, per variant:
        // immediate publication for fgl/atomic, the merge deadline for
        // ccache, the epoch length for dup
        let bound_ok = match l.variant {
            Variant::Fgl | Variant::Atomic => st.max_ops == 0,
            Variant::CCache => st.max_ops <= p.merge_deadline as u64,
            Variant::Dup => st.max_ops <= p.ops_per_core_epoch(cores) as u64,
            Variant::Cgl => false,
        };
        *self.last.lock().unwrap() = Some(st);
        (values_ok && bound_ok, Some(st.mean_ops()))
    }
}

/// Sequential golden run: per-key update counts, replaying the same
/// deterministic traces every core consumes.
pub fn golden_counts(p: &ServeParams, cores: usize) -> Vec<u32> {
    let per_epoch = p.ops_per_core_epoch(cores);
    let mut counts = vec![0u32; p.traffic.total_keys()];
    for core in 0..cores {
        for epoch in 0..p.epochs {
            let mut gen = TraceGen::new(&p.traffic, core, cores, epoch);
            for _ in 0..per_epoch {
                let r = gen.next_request();
                if r.op == OpKind::Update {
                    counts[r.key] += 1;
                }
            }
        }
    }
    counts
}

/// Run through the generic driver, panicking on unsupported variants.
pub fn run(p: &ServeParams, variant: Variant, cfg: MachineConfig) -> RunResult {
    driver::run(&KvServeWorkload::new(p.clone()), variant, cfg).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ServeParams {
        ServeParams {
            traffic: TrafficSpec {
                tenants: 4,
                keys_per_tenant: 64,
                shards: 4,
                mix: Mix::default(),
                base_theta: 0.6,
                skew_drift: 0.2,
                scan_len: 4,
                seed: 11,
            },
            epochs: 2,
            accesses_per_key: 8,
            merge_deadline: 32,
        }
    }

    fn cfg() -> MachineConfig {
        MachineConfig::test_small().with_cores(2)
    }

    fn run_staleness(p: &ServeParams, v: Variant) -> (RunResult, Staleness) {
        let w = KvServeWorkload::new(p.clone());
        let r = driver::run(&w, v, cfg()).unwrap_or_else(|e| panic!("{e}"));
        let st = w.staleness().expect("verify ran");
        (r, st)
    }

    #[test]
    fn all_variants_verify() {
        for v in VARIANTS {
            let r = run(&small(), v, cfg());
            assert!(r.verified, "variant {v:?} diverged");
        }
    }

    #[test]
    fn coherent_variants_have_zero_staleness() {
        for v in [Variant::Fgl, Variant::Atomic] {
            let (r, st) = run_staleness(&small(), v);
            assert!(r.verified);
            assert_eq!(st.max_ops, 0, "{v:?} published late");
            assert_eq!(r.quality, Some(0.0));
            assert!(st.updates > 0, "no updates in the mix");
        }
    }

    #[test]
    fn ccache_staleness_respects_the_deadline() {
        let p = small();
        let (r, st) = run_staleness(&p, Variant::CCache);
        assert!(r.verified);
        assert!(st.max_ops > 0, "deadline-batched merges show no staleness");
        assert!(st.max_ops <= p.merge_deadline as u64);
        assert!(st.mean_ops() > 0.0 && st.mean_ops() <= st.max_ops as f64);
    }

    #[test]
    fn staleness_bound_is_monotone_in_the_deadline() {
        let mut prev = 0u64;
        for deadline in [4, 16, 64] {
            let p = ServeParams {
                merge_deadline: deadline,
                ..small()
            };
            let (r, st) = run_staleness(&p, Variant::CCache);
            assert!(r.verified);
            assert!(
                st.max_ops >= prev,
                "staleness bound not monotone: {} at deadline {deadline} after {prev}",
                st.max_ops
            );
            prev = st.max_ops;
        }
    }

    #[test]
    fn dup_staleness_is_epoch_bounded_and_coarser_than_ccache() {
        let p = ServeParams {
            merge_deadline: 8,
            ..small()
        };
        let (_, dup) = run_staleness(&p, Variant::Dup);
        let (_, cc) = run_staleness(&p, Variant::CCache);
        assert!(dup.max_ops <= p.ops_per_core_epoch(2) as u64);
        assert!(
            dup.max_ops > cc.max_ops,
            "epoch-batched dup ({}) should be staler than deadline-8 ccache ({})",
            dup.max_ops,
            cc.max_ops
        );
    }

    #[test]
    fn update_free_mix_serves_reads_only() {
        let mut p = small();
        p.traffic.mix = Mix {
            read: 1,
            update: 0,
            scan: 0,
        };
        let (r, st) = run_staleness(&p, Variant::CCache);
        assert!(r.verified);
        assert_eq!(st.updates, 0);
        assert_eq!(st.mean_ops(), 0.0);
    }

    #[test]
    fn ccache_merges_and_fgl_locks() {
        let c = run(&small(), Variant::CCache, cfg());
        assert!(c.stats.merges > 0);
        assert!(c.stats.cops > 0);
        let f = run(&small(), Variant::Fgl, cfg());
        assert!(f.stats.lock_acquires > 0);
        let a = run(&small(), Variant::Atomic, cfg());
        assert!(a.stats.atomic_rmws > 0);
    }

    #[test]
    fn sized_derives_serve_defaults() {
        let w = KvServeWorkload::sized(&SizeSpec::new(0.25, 1 << 18, 7));
        let p = w.params();
        assert_eq!(p.traffic.tenants, 4);
        assert_eq!(p.traffic.shards, 4);
        assert_eq!(p.merge_deadline, 64);
        assert_eq!(p.traffic.mix, Mix::default());
        assert!((p.traffic.base_theta - 0.6).abs() < 1e-12);
        assert!((p.traffic.skew_drift - 0.2).abs() < 1e-12);
        assert!(w.footprint() > 0);
    }

    #[test]
    fn golden_is_deterministic() {
        let p = small();
        assert_eq!(golden_counts(&p, 2), golden_counts(&p, 2));
        // per-core op split covers the whole request budget
        let total: u64 = golden_counts(&p, 2).iter().map(|&c| c as u64).sum();
        assert!(total > 0);
    }
}
