//! Key-value store benchmark (Section 5.1).
//!
//! A lookup table of integer (or complex) values indexed by key; cores
//! apply commutative updates to uniformly random keys, `accesses_per_key`
//! times the key count in total. Variants:
//!
//! * CGL — one global lock
//! * FGL — one padded lock per key (locks get their own lines to avoid
//!   lock false-sharing, which is what makes FGL's footprint balloon in
//!   Table 3)
//! * DUP — a per-core copy of the whole value array, merged at the end
//!   (the paper: "it was reasonable to duplicate the table across all
//!   cores" since any core may access any key)
//! * CCache — COps + soft_merge; merges happen on-demand at source-buffer
//!   or L1 pressure
//!
//! Merge-function variants (Section 6.3): plain add, saturating add,
//! complex multiplication.

use crate::exec::{RunResult, Variant};
use crate::merge::MergeKind;
use crate::sim::addr::Addr;
use crate::sim::config::MachineConfig;
use crate::sim::machine::{CoreCtx, Machine};
use crate::util::rng::{Rng, Zipf};

/// Which commutative update / merge function the store uses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KvMerge {
    /// `v += 1`, merge `mem += upd - src`.
    Add,
    /// `v = v + 1` saturating at `max` (merge clamps at memory).
    Sat { max: u32 },
    /// `v *= e^{i*theta}` on complex values, merge `mem *= upd / src`.
    Cmul,
}

impl KvMerge {
    pub fn name(&self) -> &'static str {
        match self {
            KvMerge::Add => "add",
            KvMerge::Sat { .. } => "sat",
            KvMerge::Cmul => "cmul",
        }
    }
}

#[derive(Clone, Debug)]
pub struct KvParams {
    pub keys: usize,
    /// Total accesses = keys * accesses_per_key (paper: 16).
    pub accesses_per_key: usize,
    pub seed: u64,
    pub merge: KvMerge,
    /// 0.0 = uniform keys (the paper); >0 = zipf-skewed ablation.
    pub zipf_theta: f64,
}

impl Default for KvParams {
    fn default() -> Self {
        Self {
            keys: 4096,
            accesses_per_key: 16,
            seed: 0xCC57,
            merge: KvMerge::Add,
            zipf_theta: 0.0,
        }
    }
}

impl KvParams {
    pub fn with_keys(mut self, keys: usize) -> Self {
        self.keys = keys;
        self
    }

    pub fn with_merge(mut self, merge: KvMerge) -> Self {
        self.merge = merge;
        self
    }

    /// Bytes per key in the value array.
    fn value_bytes(&self) -> u64 {
        match self.merge {
            KvMerge::Cmul => 8,
            _ => 4,
        }
    }

    /// Working-set bytes of the core data structure (the Fig 6 x-axis).
    pub fn working_set_bytes(&self) -> u64 {
        self.keys as u64 * self.value_bytes()
    }
}

/// The per-core key stream — shared by programs and the golden run.
fn key_stream(p: &KvParams, core: usize) -> impl FnMut() -> usize {
    let mut rng = Rng::new(p.seed ^ (core as u64 + 1).wrapping_mul(0x9E37_79B9));
    let zipf = if p.zipf_theta > 0.0 {
        Some(Zipf::new(p.keys, p.zipf_theta))
    } else {
        None
    };
    let keys = p.keys;
    move || match &zipf {
        Some(z) => z.sample(&mut rng),
        None => rng.usize_below(keys),
    }
}

/// Sequential golden run: per-key access counts.
pub fn golden_counts(p: &KvParams, cores: usize) -> Vec<u32> {
    let per_core = p.keys * p.accesses_per_key / cores;
    let mut counts = vec![0u32; p.keys];
    for core in 0..cores {
        let mut next = key_stream(p, core);
        for _ in 0..per_core {
            counts[next()] += 1;
        }
    }
    counts
}

/// Per-key lock stride: a pthread-mutex-sized object (40 B), word-aligned.
const LOCK_STRIDE: u64 = 40;

#[derive(Clone, Copy)]
struct Layout {
    values: Addr,
    locks: Addr,
    global_lock: Addr,
    copies: Addr,
    copy_stride: u64,
}

pub fn run(p: &KvParams, variant: Variant, cfg: MachineConfig) -> RunResult {
    let cores = cfg.cores;
    let machine = Machine::new(cfg);
    let vb = p.value_bytes();

    let layout = machine.setup(|mem| {
        let values = mem.alloc_lines(p.keys as u64 * vb);
        if p.merge == KvMerge::Cmul {
            for k in 0..p.keys as u64 {
                mem.poke_f32(values.add(k * 8), 1.0);
                mem.poke_f32(values.add(k * 8 + 4), 0.0);
            }
        }
        let mut l = Layout {
            values,
            locks: Addr(0),
            global_lock: Addr(0),
            copies: Addr(0),
            copy_stride: 0,
        };
        match variant {
            Variant::Fgl => {
                // one pthread-mutex-sized (40 B) lock per key: the
                // Table 3 footprint (FGL ~12x the value array) with the
                // residual false sharing of ~1.6 locks per line
                l.locks = mem.alloc_lines(p.keys as u64 * LOCK_STRIDE);
            }
            Variant::Cgl => {
                l.global_lock = mem.alloc_lines(64);
            }
            Variant::Dup => {
                let stride = (p.keys as u64 * vb).next_multiple_of(64);
                l.copies = mem.alloc_lines(stride * cores as u64);
                l.copy_stride = stride;
                if p.merge == KvMerge::Cmul {
                    for c in 0..cores as u64 {
                        for k in 0..p.keys as u64 {
                            mem.poke_f32(l.copies.add(c * stride + k * 8), 1.0);
                            mem.poke_f32(l.copies.add(c * stride + k * 8 + 4), 0.0);
                        }
                    }
                }
            }
            _ => {}
        }
        l
    });

    let per_core = p.keys * p.accesses_per_key / cores;
    let merge_kind = match p.merge {
        KvMerge::Add => MergeKind::AddU32,
        KvMerge::Sat { max } => MergeKind::SatAddU32 { max },
        KvMerge::Cmul => MergeKind::CmulF32,
    };
    // the rotation factor for cmul updates
    let (fr, fi) = (0.01f32.cos(), 0.01f32.sin());

    let programs: Vec<Box<dyn FnOnce(&mut CoreCtx) + Send + '_>> = (0..cores)
        .map(|core| {
            let p = p.clone();
            let l = layout;
            let f: Box<dyn FnOnce(&mut CoreCtx) + Send + '_> = Box::new(move |ctx| {
                let mut next = key_stream(&p, core);
                match variant {
                    Variant::Cgl | Variant::Fgl => {
                        for _ in 0..per_core {
                            let k = next() as u64;
                            let lock = if variant == Variant::Fgl {
                                l.locks.add(k * LOCK_STRIDE)
                            } else {
                                l.global_lock
                            };
                            ctx.lock(lock);
                            update_coherent(ctx, &p, l.values, k, fr, fi);
                            ctx.unlock(lock);
                            ctx.compute(4);
                        }
                    }
                    Variant::Dup => {
                        let base = l.copies.add(core as u64 * l.copy_stride);
                        for _ in 0..per_core {
                            let k = next() as u64;
                            update_coherent(ctx, &p, base, k, fr, fi);
                            ctx.compute(4);
                        }
                        ctx.barrier();
                        // reduction: this core merges its key range over
                        // all copies into the master array
                        let lo = (core * p.keys / cores) as u64;
                        let hi = ((core + 1) * p.keys / cores) as u64;
                        dup_reduce(ctx, &p, &l, cores, lo, hi);
                        ctx.barrier();
                    }
                    Variant::CCache => {
                        ctx.merge_init(0, merge_kind);
                        for _ in 0..per_core {
                            let k = next() as u64;
                            update_ccache(ctx, &p, l.values, k, fr, fi);
                            ctx.soft_merge();
                            ctx.compute(4);
                        }
                        ctx.merge();
                        ctx.barrier();
                    }
                    Variant::Atomic => unimplemented!("atomics KV not in the paper"),
                }
            });
            f
        })
        .collect();

    let stats = machine.run(programs);

    // ---- verification against the sequential golden run ----
    let counts = golden_counts(p, cores);
    let verified = machine.setup(|mem| match p.merge {
        KvMerge::Add => (0..p.keys)
            .all(|k| mem.peek(layout.values.add(k as u64 * 4)) == counts[k]),
        KvMerge::Sat { max } => (0..p.keys)
            .all(|k| mem.peek(layout.values.add(k as u64 * 4)) == counts[k].min(max)),
        KvMerge::Cmul => (0..p.keys).all(|k| {
            let re = mem.peek_f32(layout.values.add(k as u64 * 8));
            let im = mem.peek_f32(layout.values.add(k as u64 * 8 + 4));
            // golden: factor^count
            let theta = 0.01f64 * counts[k] as f64;
            let (gr, gi) = (theta.cos() as f32, theta.sin() as f32);
            (re - gr).abs() < 1e-2 && (im - gi).abs() < 1e-2
        }),
    });

    RunResult {
        benchmark: format!("kvstore-{}", p.merge.name()),
        variant,
        stats,
        verified,
        quality: None,
    }
}

/// One coherent (locked or private-copy) update.
fn update_coherent(ctx: &mut CoreCtx, p: &KvParams, base: Addr, k: u64, fr: f32, fi: f32) {
    match p.merge {
        KvMerge::Add => {
            let a = base.add(k * 4);
            let v = ctx.read_u32(a);
            ctx.write_u32(a, v.wrapping_add(1));
        }
        KvMerge::Sat { max } => {
            let a = base.add(k * 4);
            let v = ctx.read_u32(a);
            ctx.write_u32(a, (v + 1).min(max));
        }
        KvMerge::Cmul => {
            let ar = base.add(k * 8);
            let ai = base.add(k * 8 + 4);
            let (re, im) = (ctx.read_f32(ar), ctx.read_f32(ai));
            ctx.compute(6);
            ctx.write_f32(ar, re * fr - im * fi);
            ctx.write_f32(ai, re * fi + im * fr);
        }
    }
}

/// One CCache COp update.
fn update_ccache(ctx: &mut CoreCtx, p: &KvParams, base: Addr, k: u64, fr: f32, fi: f32) {
    match p.merge {
        KvMerge::Add | KvMerge::Sat { .. } => {
            let a = base.add(k * 4);
            let v = ctx.c_read_u32(a, 0);
            ctx.c_write_u32(a, v.wrapping_add(1), 0);
        }
        KvMerge::Cmul => {
            let ar = base.add(k * 8);
            let ai = base.add(k * 8 + 4);
            let (re, im) = (ctx.c_read_f32(ar, 0), ctx.c_read_f32(ai, 0));
            ctx.compute(6);
            ctx.c_write_f32(ar, re * fr - im * fi, 0);
            ctx.c_write_f32(ai, re * fi + im * fr, 0);
        }
    }
}

/// DUP reduction of key range [lo, hi) over all `cores` copies into the
/// master array. Note for Sat: private copies hold raw counts; the clamp
/// is applied against the master (the DUP merge function, same as
/// CCache's — the paper uses the same merge for both).
fn dup_reduce(ctx: &mut CoreCtx, p: &KvParams, l: &Layout, cores: usize, lo: u64, hi: u64) {
    for k in lo..hi {
        match p.merge {
            KvMerge::Add | KvMerge::Sat { .. } => {
                let master = l.values.add(k * 4);
                let mut acc = ctx.read_u32(master);
                for c in 0..cores as u64 {
                    let v = ctx.read_u32(l.copies.add(c * l.copy_stride + k * 4));
                    acc = acc.wrapping_add(v);
                    ctx.compute(1);
                }
                if let KvMerge::Sat { max } = p.merge {
                    acc = acc.min(max);
                }
                ctx.write_u32(master, acc);
            }
            KvMerge::Cmul => {
                let ar = l.values.add(k * 8);
                let ai = l.values.add(k * 8 + 4);
                let (mut re, mut im) = (ctx.read_f32(ar), ctx.read_f32(ai));
                for c in 0..cores as u64 {
                    let cr = ctx.read_f32(l.copies.add(c * l.copy_stride + k * 8));
                    let ci = ctx.read_f32(l.copies.add(c * l.copy_stride + k * 8 + 4));
                    let nr = re * cr - im * ci;
                    let ni = re * ci + im * cr;
                    re = nr;
                    im = ni;
                    ctx.compute(6);
                }
                ctx.write_f32(ar, re);
                ctx.write_f32(ai, im);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> KvParams {
        KvParams {
            keys: 256,
            accesses_per_key: 8,
            seed: 11,
            merge: KvMerge::Add,
            zipf_theta: 0.0,
        }
    }

    fn cfg() -> MachineConfig {
        MachineConfig::test_small().with_cores(2)
    }

    #[test]
    fn all_variants_verify_add() {
        for v in [Variant::Cgl, Variant::Fgl, Variant::Dup, Variant::CCache] {
            let r = run(&small(), v, cfg());
            assert!(r.verified, "variant {:?} diverged", v);
        }
    }

    #[test]
    fn sat_variant_clamps() {
        let p = small().with_merge(KvMerge::Sat { max: 3 });
        for v in [Variant::Fgl, Variant::Dup, Variant::CCache] {
            let r = run(&p, v, cfg());
            assert!(r.verified, "variant {:?} diverged", v);
        }
    }

    #[test]
    fn cmul_variant_verifies() {
        let p = KvParams {
            keys: 64,
            accesses_per_key: 8,
            merge: KvMerge::Cmul,
            ..small()
        };
        for v in [Variant::Fgl, Variant::Dup, Variant::CCache] {
            let r = run(&p, v, cfg());
            assert!(r.verified, "variant {:?} diverged", v);
        }
    }

    #[test]
    fn ccache_produces_merges_and_no_invalidations_on_values() {
        let r = run(&small(), Variant::CCache, cfg());
        assert!(r.stats.merges > 0);
        assert!(r.stats.cops > 0);
    }

    #[test]
    fn fgl_produces_lock_traffic() {
        let r = run(&small(), Variant::Fgl, cfg());
        assert!(r.stats.lock_acquires > 0);
        assert!(r.stats.invalidations > 0);
    }

    #[test]
    fn dup_allocates_more_memory_than_ccache() {
        let d = run(&small(), Variant::Dup, cfg());
        let c = run(&small(), Variant::CCache, cfg());
        assert!(d.stats.bytes_allocated > c.stats.bytes_allocated);
    }

    #[test]
    fn zipf_skew_also_verifies() {
        let p = KvParams {
            zipf_theta: 0.9,
            ..small()
        };
        for v in [Variant::Fgl, Variant::CCache] {
            let r = run(&p, v, cfg());
            assert!(r.verified, "variant {:?} diverged", v);
        }
    }
}
