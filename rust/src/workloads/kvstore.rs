//! Key-value store benchmark (Section 5.1).
//!
//! A lookup table of integer (or complex) values indexed by key; cores
//! apply commutative updates to uniformly random keys, `accesses_per_key`
//! times the key count in total. Variants:
//!
//! * CGL — one global lock
//! * FGL — one padded lock per key (locks get their own lines to avoid
//!   lock false-sharing, which is what makes FGL's footprint balloon in
//!   Table 3)
//! * DUP — a per-core copy of the whole value array, merged at the end
//!   (the paper: "it was reasonable to duplicate the table across all
//!   cores" since any core may access any key)
//! * CCache — COps + soft_merge; merges happen on-demand at source-buffer
//!   or L1 pressure
//!
//! Merge-function variants (Section 6.3): plain add, saturating add,
//! complex multiplication.

use crate::exec::registry::SizeSpec;
use crate::exec::scaffold::{DupSpace, LockArray, PTHREAD_LOCK_BYTES};
use crate::exec::{driver, ExecCtx, RunResult, Variant, Workload};
use crate::merge::funcs::{AddU32, CmulF32, SatAddU32};
use crate::merge::{handle, MergeHandle};
use crate::sim::addr::Addr;
use crate::sim::config::MachineConfig;
use crate::sim::memsys::MemSystem;
use crate::util::rng::{Rng, Zipf};

/// Which commutative update / merge function the store uses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KvMerge {
    /// `v += 1`, merge `mem += upd - src`.
    Add,
    /// `v = v + 1` saturating at `max` (merge clamps at memory).
    Sat { max: u32 },
    /// `v *= e^{i*theta}` on complex values, merge `mem *= upd / src`.
    Cmul,
}

impl KvMerge {
    pub fn name(&self) -> &'static str {
        match self {
            KvMerge::Add => "add",
            KvMerge::Sat { .. } => "sat",
            KvMerge::Cmul => "cmul",
        }
    }
}

#[derive(Clone, Debug)]
pub struct KvParams {
    pub keys: usize,
    /// Total accesses = keys * accesses_per_key (paper: 16).
    pub accesses_per_key: usize,
    pub seed: u64,
    pub merge: KvMerge,
    /// 0.0 = uniform keys (the paper); >0 = zipf-skewed ablation.
    pub zipf_theta: f64,
}

impl Default for KvParams {
    fn default() -> Self {
        Self {
            keys: 4096,
            accesses_per_key: 16,
            seed: 0xCC57,
            merge: KvMerge::Add,
            zipf_theta: 0.0,
        }
    }
}

impl KvParams {
    pub fn with_keys(mut self, keys: usize) -> Self {
        self.keys = keys;
        self
    }

    pub fn with_merge(mut self, merge: KvMerge) -> Self {
        self.merge = merge;
        self
    }

    /// Bytes per key in the value array.
    fn value_bytes(&self) -> u64 {
        match self.merge {
            KvMerge::Cmul => 8,
            _ => 4,
        }
    }

    /// Working-set bytes of the core data structure (the Fig 6 x-axis).
    pub fn working_set_bytes(&self) -> u64 {
        self.keys as u64 * self.value_bytes()
    }
}

/// The per-core key stream — shared by programs and the golden run.
fn key_stream(p: &KvParams, core: usize) -> impl FnMut() -> usize {
    let mut rng = Rng::new(p.seed ^ (core as u64 + 1).wrapping_mul(0x9E37_79B9));
    let zipf = if p.zipf_theta > 0.0 {
        Some(Zipf::new(p.keys, p.zipf_theta))
    } else {
        None
    };
    let keys = p.keys;
    move || match &zipf {
        Some(z) => z.sample(&mut rng),
        None => rng.usize_below(keys),
    }
}

/// Sequential golden run: per-key access counts.
pub fn golden_counts(p: &KvParams, cores: usize) -> Vec<u32> {
    let per_core = p.keys * p.accesses_per_key / cores;
    let mut counts = vec![0u32; p.keys];
    for core in 0..cores {
        let mut next = key_stream(p, core);
        for _ in 0..per_core {
            counts[next()] += 1;
        }
    }
    counts
}

#[derive(Clone, Copy)]
pub struct KvLayout {
    values: Addr,
    locks: LockArray,
    global_lock: Addr,
    copies: DupSpace,
}

/// The variants the KV store implements (atomics are BFS/histogram-only
/// in the paper's comparison).
pub const VARIANTS: [Variant; 4] = [Variant::Cgl, Variant::Fgl, Variant::Dup, Variant::CCache];

/// The KV store as a [`Workload`]: all variant scaffolding, programs and
/// verification behind the one trait the driver consumes.
pub struct KvWorkload {
    p: KvParams,
}

impl KvWorkload {
    pub fn new(p: KvParams) -> Self {
        Self { p }
    }

    /// Size the value table to `frac` x LLC (Section 6.1's sweep).
    pub fn sized(merge: KvMerge, s: &SizeSpec) -> Self {
        let bytes_per_key = if matches!(merge, KvMerge::Cmul) { 8 } else { 4 };
        let keys = (s.target_bytes() / bytes_per_key).max(256) as usize;
        Self::new(KvParams {
            keys,
            accesses_per_key: 16, // the paper's ratio (Section 5.1)
            seed: s.seed,
            merge,
            zipf_theta: s.zipf_theta,
        })
    }

    pub fn params(&self) -> &KvParams {
        &self.p
    }
}

impl Workload for KvWorkload {
    type Layout = KvLayout;
    type Golden = Vec<u32>;

    fn name(&self) -> String {
        format!("kvstore-{}", self.p.merge.name())
    }

    fn supported_variants(&self) -> Vec<Variant> {
        VARIANTS.to_vec()
    }

    fn footprint(&self) -> u64 {
        self.p.working_set_bytes()
    }

    fn merge_slots(&self) -> Vec<(usize, MergeHandle)> {
        let f: MergeHandle = match self.p.merge {
            KvMerge::Add => handle(AddU32),
            KvMerge::Sat { max } => handle(SatAddU32 { max }),
            KvMerge::Cmul => handle(CmulF32),
        };
        vec![(0, f)]
    }

    fn setup(&self, mem: &mut MemSystem, variant: Variant, cores: usize) -> KvLayout {
        let p = &self.p;
        let vb = p.value_bytes();
        let values = mem.alloc_lines(p.keys as u64 * vb);
        if p.merge == KvMerge::Cmul {
            for k in 0..p.keys as u64 {
                mem.poke_f32(values.add(k * 8), 1.0);
                mem.poke_f32(values.add(k * 8 + 4), 0.0);
            }
        }
        let mut l = KvLayout {
            values,
            locks: LockArray::none(),
            global_lock: Addr(0),
            copies: DupSpace::none(),
        };
        match variant {
            Variant::Fgl => {
                // one pthread-mutex-sized (40 B) lock per key: the
                // Table 3 footprint (FGL ~12x the value array) with the
                // residual false sharing of ~1.6 locks per line
                l.locks = LockArray::alloc(mem, p.keys as u64, PTHREAD_LOCK_BYTES);
            }
            Variant::Cgl => {
                l.global_lock = mem.alloc_lines(64);
            }
            Variant::Dup => {
                l.copies = DupSpace::alloc(mem, p.keys as u64 * vb, cores);
                if p.merge == KvMerge::Cmul {
                    for c in 0..cores {
                        let base = l.copies.copy_base(c);
                        for k in 0..p.keys as u64 {
                            mem.poke_f32(base.add(k * 8), 1.0);
                            mem.poke_f32(base.add(k * 8 + 4), 0.0);
                        }
                    }
                }
            }
            _ => {}
        }
        l
    }

    fn program<C: ExecCtx>(
        &self,
        ctx: &mut C,
        core: usize,
        cores: usize,
        variant: Variant,
        l: &KvLayout,
    ) {
        let p = &self.p;
        let per_core = p.keys * p.accesses_per_key / cores;
        // the rotation factor for cmul updates
        let (fr, fi) = (0.01f32.cos(), 0.01f32.sin());
        let mut next = key_stream(p, core);
        match variant {
            Variant::Cgl | Variant::Fgl => {
                for _ in 0..per_core {
                    let k = next() as u64;
                    let lock = if variant == Variant::Fgl {
                        l.locks.addr(k)
                    } else {
                        l.global_lock
                    };
                    ctx.lock(lock);
                    update_coherent(ctx, p, l.values, k, fr, fi);
                    ctx.unlock(lock);
                    ctx.compute(4);
                }
            }
            Variant::Dup => {
                let base = l.copies.copy_base(core);
                for _ in 0..per_core {
                    let k = next() as u64;
                    update_coherent(ctx, p, base, k, fr, fi);
                    ctx.compute(4);
                }
                ctx.barrier();
                // reduction: this core merges its key range over
                // all copies into the master array
                let lo = (core * p.keys / cores) as u64;
                let hi = ((core + 1) * p.keys / cores) as u64;
                dup_reduce(ctx, p, l, cores, lo, hi);
                ctx.barrier();
            }
            Variant::CCache => {
                for _ in 0..per_core {
                    let k = next() as u64;
                    update_ccache(ctx, p, l.values, k, fr, fi);
                    ctx.soft_merge();
                    ctx.compute(4);
                }
                ctx.merge();
                ctx.barrier();
            }
            Variant::Atomic => unreachable!("driver rejects unsupported variants"),
        }
    }

    fn golden(&self, cores: usize) -> Vec<u32> {
        golden_counts(&self.p, cores)
    }

    fn verify(
        &self,
        mem: &mut MemSystem,
        l: &KvLayout,
        counts: &Vec<u32>,
        _cores: usize,
    ) -> (bool, Option<f64>) {
        let p = &self.p;
        let ok = match p.merge {
            KvMerge::Add => {
                (0..p.keys).all(|k| mem.peek(l.values.add(k as u64 * 4)) == counts[k])
            }
            KvMerge::Sat { max } => (0..p.keys)
                .all(|k| mem.peek(l.values.add(k as u64 * 4)) == counts[k].min(max)),
            KvMerge::Cmul => (0..p.keys).all(|k| {
                let re = mem.peek_f32(l.values.add(k as u64 * 8));
                let im = mem.peek_f32(l.values.add(k as u64 * 8 + 4));
                // golden: factor^count
                let theta = 0.01f64 * counts[k] as f64;
                let (gr, gi) = (theta.cos() as f32, theta.sin() as f32);
                (re - gr).abs() < 1e-2 && (im - gi).abs() < 1e-2
            }),
        };
        (ok, None)
    }
}

/// Run through the generic driver, panicking on unsupported variants
/// (ergonomic entry point for unit tests and custom-parameter callers).
pub fn run(p: &KvParams, variant: Variant, cfg: MachineConfig) -> RunResult {
    driver::run(&KvWorkload::new(p.clone()), variant, cfg).unwrap_or_else(|e| panic!("{e}"))
}

/// One coherent (locked or private-copy) update.
fn update_coherent<C: ExecCtx>(ctx: &mut C, p: &KvParams, base: Addr, k: u64, fr: f32, fi: f32) {
    match p.merge {
        KvMerge::Add => {
            let a = base.add(k * 4);
            let v = ctx.read_u32(a);
            ctx.write_u32(a, v.wrapping_add(1));
        }
        KvMerge::Sat { max } => {
            let a = base.add(k * 4);
            let v = ctx.read_u32(a);
            ctx.write_u32(a, (v + 1).min(max));
        }
        KvMerge::Cmul => {
            let ar = base.add(k * 8);
            let ai = base.add(k * 8 + 4);
            let (re, im) = (ctx.read_f32(ar), ctx.read_f32(ai));
            ctx.compute(6);
            ctx.write_f32(ar, re * fr - im * fi);
            ctx.write_f32(ai, re * fi + im * fr);
        }
    }
}

/// One CCache COp update.
fn update_ccache<C: ExecCtx>(ctx: &mut C, p: &KvParams, base: Addr, k: u64, fr: f32, fi: f32) {
    match p.merge {
        KvMerge::Add | KvMerge::Sat { .. } => {
            let a = base.add(k * 4);
            let v = ctx.c_read_u32(a, 0);
            ctx.c_write_u32(a, v.wrapping_add(1), 0);
        }
        KvMerge::Cmul => {
            let ar = base.add(k * 8);
            let ai = base.add(k * 8 + 4);
            let (re, im) = (ctx.c_read_f32(ar, 0), ctx.c_read_f32(ai, 0));
            ctx.compute(6);
            ctx.c_write_f32(ar, re * fr - im * fi, 0);
            ctx.c_write_f32(ai, re * fi + im * fr, 0);
        }
    }
}

/// DUP reduction of key range [lo, hi) over all `cores` copies into the
/// master array. Note for Sat: private copies hold raw counts; the clamp
/// is applied against the master (the DUP merge function, same as
/// CCache's — the paper uses the same merge for both).
fn dup_reduce<C: ExecCtx>(ctx: &mut C, p: &KvParams, l: &KvLayout, cores: usize, lo: u64, hi: u64) {
    match p.merge {
        KvMerge::Add => l.copies.reduce_add_u32(ctx, l.values, cores, lo, hi),
        KvMerge::Sat { max } => {
            for k in lo..hi {
                let master = l.values.add(k * 4);
                let mut acc = ctx.read_u32(master);
                for c in 0..cores {
                    let v = ctx.read_u32(l.copies.copy_base(c).add(k * 4));
                    acc = acc.wrapping_add(v);
                    ctx.compute(1);
                }
                ctx.write_u32(master, acc.min(max));
            }
        }
        KvMerge::Cmul => {
            for k in lo..hi {
                let ar = l.values.add(k * 8);
                let ai = l.values.add(k * 8 + 4);
                let (mut re, mut im) = (ctx.read_f32(ar), ctx.read_f32(ai));
                for c in 0..cores {
                    let base = l.copies.copy_base(c);
                    let cr = ctx.read_f32(base.add(k * 8));
                    let ci = ctx.read_f32(base.add(k * 8 + 4));
                    let nr = re * cr - im * ci;
                    let ni = re * ci + im * cr;
                    re = nr;
                    im = ni;
                    ctx.compute(6);
                }
                ctx.write_f32(ar, re);
                ctx.write_f32(ai, im);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecError;

    fn small() -> KvParams {
        KvParams {
            keys: 256,
            accesses_per_key: 8,
            seed: 11,
            merge: KvMerge::Add,
            zipf_theta: 0.0,
        }
    }

    fn cfg() -> MachineConfig {
        MachineConfig::test_small().with_cores(2)
    }

    #[test]
    fn all_variants_verify_add() {
        for v in [Variant::Cgl, Variant::Fgl, Variant::Dup, Variant::CCache] {
            let r = run(&small(), v, cfg());
            assert!(r.verified, "variant {:?} diverged", v);
        }
    }

    #[test]
    fn sat_variant_clamps() {
        let p = small().with_merge(KvMerge::Sat { max: 3 });
        for v in [Variant::Fgl, Variant::Dup, Variant::CCache] {
            let r = run(&p, v, cfg());
            assert!(r.verified, "variant {:?} diverged", v);
        }
    }

    #[test]
    fn cmul_variant_verifies() {
        let p = KvParams {
            keys: 64,
            accesses_per_key: 8,
            merge: KvMerge::Cmul,
            ..small()
        };
        for v in [Variant::Fgl, Variant::Dup, Variant::CCache] {
            let r = run(&p, v, cfg());
            assert!(r.verified, "variant {:?} diverged", v);
        }
    }

    #[test]
    fn atomics_variant_is_a_typed_error() {
        let r = driver::run(&KvWorkload::new(small()), Variant::Atomic, cfg());
        assert!(matches!(
            r,
            Err(ExecError::UnsupportedVariant { variant: Variant::Atomic, .. })
        ));
    }

    #[test]
    fn ccache_produces_merges_and_no_invalidations_on_values() {
        let r = run(&small(), Variant::CCache, cfg());
        assert!(r.stats.merges > 0);
        assert!(r.stats.cops > 0);
    }

    #[test]
    fn fgl_produces_lock_traffic() {
        let r = run(&small(), Variant::Fgl, cfg());
        assert!(r.stats.lock_acquires > 0);
        assert!(r.stats.invalidations > 0);
    }

    #[test]
    fn dup_allocates_more_memory_than_ccache() {
        let d = run(&small(), Variant::Dup, cfg());
        let c = run(&small(), Variant::CCache, cfg());
        assert!(d.stats.bytes_allocated > c.stats.bytes_allocated);
    }

    #[test]
    fn zipf_skew_also_verifies() {
        let p = KvParams {
            zipf_theta: 0.9,
            ..small()
        };
        for v in [Variant::Fgl, Variant::CCache] {
            let r = run(&p, v, cfg());
            assert!(r.verified, "variant {:?} diverged", v);
        }
    }
}
