//! Deterministic trace engine for the serving-tier workloads: a
//! YCSB-style read/update/scan request mix over a multi-tenant key
//! space, with per-tenant zipf key distributions whose skew *drifts*
//! across epochs on a seeded, replayable schedule.
//!
//! The generator is a pure function of `(spec, core, epoch)` — no
//! hidden state, no host entropy — so the same spec replays the same
//! trace on the simulator and the native-thread backend, and the golden
//! run can re-derive exactly the requests every core issued
//! (`tests/traffic.rs` pins both properties, plus a chi-square
//! goodness-of-fit of the sampler against the analytic zipf mass).
//!
//! Tenancy model: `tenants` tenants each own a contiguous range of
//! `keys_per_tenant` keys; tenant `t` lives on shard `t % shards` and
//! shard `s` is pinned to core `s % cores`. Every front-end core draws
//! requests for *all* tenants (commutative updates need no routing —
//! the CCache premise), but with probability [`LOCAL_BIAS`] it picks
//! one of its own pinned tenants, modeling affinity routing.

use crate::util::rng::{Rng, SplitMix64, Zipf};

/// Probability that a request targets one of the issuing core's pinned
/// tenants rather than a uniformly random tenant.
pub const LOCAL_BIAS: f64 = 0.5;

/// Request kinds in the YCSB-style mix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// Point read of one key.
    Read,
    /// Commutative increment of one key.
    Update,
    /// Short sequential read of [`TrafficSpec::scan_len`] keys.
    Scan,
}

/// One generated request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Request {
    pub tenant: usize,
    /// Global key index in `[0, tenants * keys_per_tenant)`.
    pub key: usize,
    pub op: OpKind,
}

/// Read:update:scan weights (the `--mix r:u:s` CLI flag).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mix {
    pub read: u32,
    pub update: u32,
    pub scan: u32,
}

impl Default for Mix {
    /// The YCSB-B-flavored serving default.
    fn default() -> Self {
        Self {
            read: 70,
            update: 25,
            scan: 5,
        }
    }
}

impl Mix {
    /// Parse `"r:u:s"` (e.g. `70:25:5`). At least one weight must be
    /// non-zero; updates may be zero (a read-only tier is legal).
    pub fn parse(s: &str) -> Result<Self, String> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() != 3 {
            return Err(format!("--mix wants r:u:s (e.g. 70:25:5), got '{s}'"));
        }
        let w: Vec<u32> = parts
            .iter()
            .map(|p| p.trim().parse::<u32>())
            .collect::<Result<_, _>>()
            .map_err(|e| format!("--mix '{s}': {e}"))?;
        let mix = Self {
            read: w[0],
            update: w[1],
            scan: w[2],
        };
        if mix.total() == 0 {
            return Err(format!("--mix '{s}': all weights are zero"));
        }
        Ok(mix)
    }

    pub fn total(&self) -> u64 {
        self.read as u64 + self.update as u64 + self.scan as u64
    }

    /// Stable `r:u:s` token for reports and JSON.
    pub fn token(&self) -> String {
        format!("{}:{}:{}", self.read, self.update, self.scan)
    }
}

/// Everything that determines a trace. Two equal specs generate
/// byte-identical request streams on any backend.
#[derive(Clone, Copy, Debug)]
pub struct TrafficSpec {
    pub tenants: usize,
    pub keys_per_tenant: usize,
    /// Shards the tenant set is mapped onto (pinned round-robin across
    /// cores).
    pub shards: usize,
    pub mix: Mix,
    /// Zipf skew every tenant starts each drift period at.
    pub base_theta: f64,
    /// Peak-to-base amplitude of the per-epoch skew drift (0 = static
    /// skew). Drifted thetas are clamped to the sampler's legal range.
    pub skew_drift: f64,
    /// Keys one scan request touches.
    pub scan_len: usize,
    pub seed: u64,
}

impl TrafficSpec {
    pub fn total_keys(&self) -> usize {
        self.tenants * self.keys_per_tenant
    }

    /// The core a tenant's shard is pinned to.
    pub fn home_core(&self, tenant: usize, cores: usize) -> usize {
        (tenant % self.shards) % cores
    }
}

/// Drift period in epochs: skew ramps up and back over this many epochs
/// (triangle wave), phase-shifted per tenant so tenants peak at
/// different times — the multi-tenant interference pattern.
const DRIFT_PERIOD: f64 = 8.0;

/// The zipf theta tenant `tenant` serves during `epoch` — the seeded,
/// replayable drift schedule. Clamped away from the sampler's poles
/// (`theta > 0`, `theta != 1`).
pub fn drifted_theta(spec: &TrafficSpec, tenant: usize, epoch: usize) -> f64 {
    let base = spec.base_theta;
    let theta = if spec.skew_drift == 0.0 {
        base
    } else {
        let phase = (epoch as f64 + tenant as f64 * 1.7).rem_euclid(DRIFT_PERIOD) / DRIFT_PERIOD;
        // triangle wave in [-1, 1]: -1 at phase 0, +1 at phase 0.5
        let tri = 2.0 * (1.0 - (2.0 * phase - 1.0).abs()) - 1.0;
        base + spec.skew_drift * tri
    };
    theta.clamp(0.05, 0.95)
}

/// Analytic zipf mass `P(rank = k)` over `[0, n)` at skew `theta` —
/// the reference distribution for the chi-square goodness-of-fit test.
pub fn zipf_pmf(n: usize, theta: f64, k: usize) -> f64 {
    let h: f64 = (1..=n).map(|i| (i as f64).powf(-theta)).sum();
    (k as f64 + 1.0).powf(-theta) / h
}

/// The per-`(core, epoch)` request generator. Construction derives the
/// epoch's drifted skew for every tenant; [`TraceGen::next`] then emits
/// requests from one deterministic RNG stream.
pub struct TraceGen {
    rng: Rng,
    zipf: Vec<Zipf>,
    local: Vec<usize>,
    spec: TrafficSpec,
}

impl TraceGen {
    pub fn new(spec: &TrafficSpec, core: usize, cores: usize, epoch: usize) -> Self {
        assert!(spec.tenants > 0 && spec.keys_per_tenant > 0 && spec.shards > 0);
        // mix core and epoch into the stream seed through SplitMix64 so
        // neighboring (core, epoch) pairs get uncorrelated streams
        let mut sm = SplitMix64::new(
            spec.seed
                ^ (core as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (epoch as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        let rng = Rng::new(sm.next_u64());
        let zipf = (0..spec.tenants)
            .map(|t| Zipf::new(spec.keys_per_tenant, drifted_theta(spec, t, epoch)))
            .collect();
        let local = (0..spec.tenants)
            .filter(|&t| spec.home_core(t, cores) == core)
            .collect();
        Self {
            rng,
            zipf,
            local,
            spec: *spec,
        }
    }

    /// The next request in the stream.
    pub fn next_request(&mut self) -> Request {
        let tenant = if !self.local.is_empty() && self.rng.bernoulli(LOCAL_BIAS) {
            self.local[self.rng.usize_below(self.local.len())]
        } else {
            self.rng.usize_below(self.spec.tenants)
        };
        let mix = self.spec.mix;
        let draw = self.rng.below(mix.total());
        let op = if draw < mix.read as u64 {
            OpKind::Read
        } else if draw < mix.read as u64 + mix.update as u64 {
            OpKind::Update
        } else {
            OpKind::Scan
        };
        let key = tenant * self.spec.keys_per_tenant + self.zipf[tenant].sample(&mut self.rng);
        Request { tenant, key, op }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> TrafficSpec {
        TrafficSpec {
            tenants: 4,
            keys_per_tenant: 256,
            shards: 4,
            mix: Mix::default(),
            base_theta: 0.6,
            skew_drift: 0.2,
            scan_len: 8,
            seed: 42,
        }
    }

    #[test]
    fn mix_parses_and_rejects() {
        assert_eq!(
            Mix::parse("70:25:5").unwrap(),
            Mix {
                read: 70,
                update: 25,
                scan: 5
            }
        );
        assert_eq!(Mix::parse(" 1 : 0 : 0 ").unwrap().update, 0);
        assert!(Mix::parse("70:25").is_err());
        assert!(Mix::parse("a:b:c").is_err());
        assert!(Mix::parse("0:0:0").is_err());
        assert_eq!(Mix::default().token(), "70:25:5");
    }

    #[test]
    fn drift_schedule_is_bounded_and_moves() {
        let s = spec();
        let thetas: Vec<f64> = (0..16).map(|e| drifted_theta(&s, 0, e)).collect();
        for &t in &thetas {
            assert!((0.05..=0.95).contains(&t), "theta {t} out of range");
        }
        assert!(
            thetas.iter().any(|&t| (t - thetas[0]).abs() > 0.05),
            "drift schedule never moved: {thetas:?}"
        );
        // zero drift is static
        let flat = TrafficSpec {
            skew_drift: 0.0,
            ..s
        };
        for e in 0..16 {
            assert_eq!(drifted_theta(&flat, 1, e), flat.base_theta);
        }
    }

    #[test]
    fn tenants_peak_at_different_epochs() {
        let s = spec();
        let peak = |tenant: usize| {
            (0..8)
                .max_by(|&a, &b| {
                    drifted_theta(&s, tenant, a)
                        .partial_cmp(&drifted_theta(&s, tenant, b))
                        .unwrap()
                })
                .unwrap()
        };
        assert_ne!(peak(0), peak(1), "tenant phases collide");
    }

    #[test]
    fn requests_stay_in_tenant_ranges() {
        let s = spec();
        let mut gen = TraceGen::new(&s, 0, 2, 0);
        for _ in 0..2000 {
            let r = gen.next_request();
            assert!(r.tenant < s.tenants);
            assert_eq!(r.key / s.keys_per_tenant, r.tenant, "key outside tenant range");
        }
    }

    #[test]
    fn identical_specs_replay_identical_traces() {
        let s = spec();
        for (core, epoch) in [(0, 0), (1, 3), (3, 7)] {
            let mut a = TraceGen::new(&s, core, 4, epoch);
            let mut b = TraceGen::new(&s, core, 4, epoch);
            for _ in 0..500 {
                assert_eq!(a.next_request(), b.next_request());
            }
        }
    }

    #[test]
    fn cores_and_epochs_get_distinct_streams() {
        let s = spec();
        let take = |core: usize, epoch: usize| -> Vec<Request> {
            let mut g = TraceGen::new(&s, core, 4, epoch);
            (0..200).map(|_| g.next_request()).collect()
        };
        assert_ne!(take(0, 0), take(1, 0), "cores share a stream");
        assert_ne!(take(0, 0), take(0, 1), "epochs share a stream");
    }

    #[test]
    fn pmf_sums_to_one() {
        let total: f64 = (0..64).map(|k| zipf_pmf(64, 0.6, k)).sum();
        assert!((total - 1.0).abs() < 1e-9, "pmf sums to {total}");
    }

    #[test]
    fn local_bias_favors_pinned_tenants() {
        let s = spec(); // 4 tenants on 4 shards over 2 cores: core 0 owns tenants 0, 2
        let mut gen = TraceGen::new(&s, 0, 2, 0);
        let n = 4000;
        let local = (0..n)
            .filter(|_| {
                let r = gen.next_request();
                s.home_core(r.tenant, 2) == 0
            })
            .count();
        // expect LOCAL_BIAS + (1 - LOCAL_BIAS)/2 = 75%; allow slack
        assert!(
            local as f64 / n as f64 > 0.65,
            "local fraction {}",
            local as f64 / n as f64
        );
    }
}
