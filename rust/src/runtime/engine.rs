//! The PJRT engine: compiles each artifact once and exposes typed
//! execution wrappers for the workload kernels.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::artifacts::{
    Manifest, KMEANS_D, KMEANS_K, KMEANS_N, PAGERANK_V,
};

pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Load the manifest and create the PJRT CPU client. Executables are
    /// compiled lazily on first use and cached.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            exes: HashMap::new(),
        })
    }

    /// Convenience: load from the default artifacts location.
    pub fn load_default() -> Result<Self> {
        Self::load(&super::artifacts::default_artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch the cached) executable for an entry.
    pub fn executable(&mut self, entry: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(entry) {
            let path = self.manifest.hlo_path(entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {entry}"))?;
            self.exes.insert(entry.to_string(), exe);
        }
        Ok(&self.exes[entry])
    }

    /// Execute an entry with literal inputs; returns the decomposed
    /// result tuple (aot.py lowers with return_tuple=True).
    pub fn execute(&mut self, entry: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(entry)?;
        let result = exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {entry}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        lit.to_tuple().context("decomposing result tuple")
    }

    // ------------------------------------------------------------------
    // typed workload kernels
    // ------------------------------------------------------------------

    /// One K-Means iteration of numeric work (Pallas assignment + one-hot
    /// accumulation). Pads `points` up to the AOT shape with masked rows
    /// and `centroids` with +inf sentinels (never nearest).
    ///
    /// Returns (assign, sums, counts) truncated to the real sizes.
    pub fn kmeans_step(
        &mut self,
        points: &[[f32; KMEANS_D]],
        centroids: &[[f32; KMEANS_D]],
    ) -> Result<(Vec<i32>, Vec<[f32; KMEANS_D]>, Vec<f32>)> {
        let n = points.len();
        let k = centroids.len();
        anyhow::ensure!(n <= KMEANS_N, "points {n} > AOT shape {KMEANS_N}");
        anyhow::ensure!(k <= KMEANS_K, "clusters {k} > AOT shape {KMEANS_K}");

        let mut flat_p = vec![0f32; KMEANS_N * KMEANS_D];
        for (i, p) in points.iter().enumerate() {
            flat_p[i * KMEANS_D..(i + 1) * KMEANS_D].copy_from_slice(p);
        }
        let mut flat_c = vec![1e30f32; KMEANS_K * KMEANS_D];
        for (i, c) in centroids.iter().enumerate() {
            flat_c[i * KMEANS_D..(i + 1) * KMEANS_D].copy_from_slice(c);
        }
        let mut mask = vec![0f32; KMEANS_N];
        mask[..n].iter_mut().for_each(|m| *m = 1.0);

        let p_lit = xla::Literal::vec1(&flat_p)
            .reshape(&[KMEANS_N as i64, KMEANS_D as i64])?;
        let c_lit = xla::Literal::vec1(&flat_c)
            .reshape(&[KMEANS_K as i64, KMEANS_D as i64])?;
        let m_lit = xla::Literal::vec1(&mask);

        let out = self.execute("kmeans_step", &[p_lit, c_lit, m_lit])?;
        anyhow::ensure!(out.len() == 3, "kmeans_step returned {} values", out.len());
        let assign: Vec<i32> = out[0].to_vec::<i32>()?[..n].to_vec();
        let sums_flat = out[1].to_vec::<f32>()?;
        let counts: Vec<f32> = out[2].to_vec::<f32>()?[..k].to_vec();
        let sums: Vec<[f32; KMEANS_D]> = (0..k)
            .map(|c| {
                let mut row = [0f32; KMEANS_D];
                row.copy_from_slice(&sums_flat[c * KMEANS_D..(c + 1) * KMEANS_D]);
                row
            })
            .collect();
        Ok((assign, sums, counts))
    }

    /// One damped PageRank iteration on a dense normalized adjacency.
    /// `adj[dst][src]` = 1.0 if edge src->dst. Sizes padded to the AOT V.
    pub fn pagerank_iter(
        &mut self,
        adj: &[Vec<f32>],
        rank: &[f32],
        out_deg_inv: &[f32],
    ) -> Result<Vec<f32>> {
        let v = rank.len();
        anyhow::ensure!(v <= PAGERANK_V, "V {v} > AOT shape {PAGERANK_V}");
        let mut flat = vec![0f32; PAGERANK_V * PAGERANK_V];
        for (d, row) in adj.iter().enumerate() {
            for (s, &x) in row.iter().enumerate() {
                flat[d * PAGERANK_V + s] = x;
            }
        }
        let mut r = vec![0f32; PAGERANK_V];
        r[..v].copy_from_slice(rank);
        let mut inv = vec![0f32; PAGERANK_V];
        inv[..v].copy_from_slice(out_deg_inv);

        let a_lit = xla::Literal::vec1(&flat)
            .reshape(&[PAGERANK_V as i64, PAGERANK_V as i64])?;
        let r_lit = xla::Literal::vec1(&r);
        let i_lit = xla::Literal::vec1(&inv);
        let out = self.execute("pagerank_iter", &[a_lit, r_lit, i_lit])?;
        anyhow::ensure!(out.len() == 1);
        Ok(out[0].to_vec::<f32>()?[..v].to_vec())
        // note: the (1-d)/V damping constant inside the kernel uses the
        // padded V; callers compare against a reference computed the same
        // way (see tests) or rescale
    }
}

#[cfg(test)]
mod tests {
    use super::super::artifacts::artifacts_available;
    use super::*;

    #[test]
    fn engine_compiles_and_runs_merge_add() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut e = Engine::load_default().unwrap();
        assert!(e.platform().to_lowercase().contains("cpu")
            || e.platform().to_lowercase().contains("host"));
        let b = super::super::artifacts::MERGE_BATCH;
        let w = super::super::artifacts::LINE_WORDS;
        let src = xla::Literal::vec1(&vec![1f32; b * w])
            .reshape(&[b as i64, w as i64])
            .unwrap();
        let upd = xla::Literal::vec1(&vec![4f32; b * w])
            .reshape(&[b as i64, w as i64])
            .unwrap();
        let mem = xla::Literal::vec1(&vec![10f32; b * w])
            .reshape(&[b as i64, w as i64])
            .unwrap();
        let out = e.execute("merge_add", &[src, upd, mem]).unwrap();
        let v = out[0].to_vec::<f32>().unwrap();
        assert!(v.iter().all(|&x| x == 13.0));
    }
}
