//! Artifact manifest: the shape contract between `python/compile/aot.py`
//! and the rust runtime.
//!
//! aot.py writes `artifacts/manifest.txt`; this module parses it and
//! checks the constants the rust wrappers are compiled against. A
//! mismatch (e.g. someone re-exported with a different batch size) fails
//! loudly at load time instead of producing shape errors deep in PJRT.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Rust-side copies of the aot.py shape contract.
pub const MERGE_BATCH: usize = 256;
pub const LINE_WORDS: usize = 16;
pub const KMEANS_N: usize = 2048;
pub const KMEANS_D: usize = 16;
pub const KMEANS_K: usize = 16;
pub const PAGERANK_V: usize = 1024;

/// One entry's argument signature, e.g. `float32[256,16]`.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSig {
    pub dtype: String,
    pub dims: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, Vec<ArgSig>>,
}

impl Manifest {
    /// Parse `<dir>/manifest.txt` and validate the shape contract.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}; run `make artifacts` first", path.display()))?;
        let mut entries = BTreeMap::new();
        let mut kv: BTreeMap<String, String> = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some((k, v)) = line.split_once('=') {
                kv.insert(k.to_string(), v.to_string());
                continue;
            }
            let (name, args) = line
                .split_once(' ')
                .with_context(|| format!("malformed manifest line: {line}"))?;
            let sigs = args
                .split(';')
                .map(parse_sig)
                .collect::<Result<Vec<_>>>()?;
            entries.insert(name.to_string(), sigs);
        }
        let m = Self {
            dir: dir.to_path_buf(),
            entries,
        };
        m.validate(&kv)?;
        Ok(m)
    }

    fn validate(&self, kv: &BTreeMap<String, String>) -> Result<()> {
        let expect = |key: &str, want: String| -> Result<()> {
            match kv.get(key) {
                Some(v) if *v == want => Ok(()),
                Some(v) => bail!("manifest {key}={v}, rust expects {want}; re-run make artifacts"),
                None => bail!("manifest missing {key}"),
            }
        };
        expect("merge_batch", MERGE_BATCH.to_string())?;
        expect("line_words", LINE_WORDS.to_string())?;
        expect("kmeans", format!("{KMEANS_N},{KMEANS_D},{KMEANS_K}"))?;
        expect("pagerank_v", PAGERANK_V.to_string())?;
        for required in required_entries()? {
            if !self.entries.contains_key(&required) {
                bail!("manifest missing entry {required}");
            }
        }
        Ok(())
    }

    pub fn hlo_path(&self, entry: &str) -> PathBuf {
        self.dir.join(format!("{entry}.hlo.txt"))
    }
}

/// The artifact entries the rust side requires: the compute kernels plus
/// every batch-kernel id declared by a registered merge function
/// ([`MergeFn::batch_kernel`](crate::merge::MergeFn::batch_kernel)) — so
/// the manifest contract follows the open merge registry instead of a
/// hard-coded list. Functions without an AOT kernel (user extensions,
/// `xor_u32`, `logsumexp_f32`) require nothing: they execute natively.
///
/// A registered function whose default construction fails is an error,
/// not a skip: silently dropping it would drop its (unknowable) kernel
/// entry from the contract and turn a missing artifact into a late
/// PJRT failure at merge time — the exact failure mode load-time
/// validation exists to prevent.
pub fn required_entries() -> Result<BTreeSet<String>> {
    let mut required: BTreeSet<String> =
        ["kmeans_step", "pagerank_iter"].iter().map(|s| s.to_string()).collect();
    for spec in crate::merge::default_registry().iter() {
        let f = spec.build(None).map_err(|e| {
            anyhow::anyhow!(
                "merge function '{}' has no default construction ({e}); \
                 its artifact requirements cannot be derived",
                spec.name
            )
        })?;
        if let Some(kernel) = f.batch_kernel() {
            required.insert(kernel.entry);
        }
    }
    Ok(required)
}

fn parse_sig(s: &str) -> Result<ArgSig> {
    let (dtype, rest) = s
        .split_once('[')
        .with_context(|| format!("malformed arg sig: {s}"))?;
    let dims = rest
        .trim_end_matches(']')
        .split(',')
        .filter(|d| !d.is_empty())
        .map(|d| d.parse::<usize>().context("bad dim"))
        .collect::<Result<Vec<_>>>()?;
    Ok(ArgSig {
        dtype: dtype.to_string(),
        dims,
    })
}

/// Locate the artifacts directory: `$CCACHE_ARTIFACTS`, else
/// `<manifest dir>/artifacts` (the repo layout), else `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("CCACHE_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let repo = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if repo.exists() {
        return repo;
    }
    PathBuf::from("artifacts")
}

/// True when `make artifacts` has been run (used by tests to skip
/// gracefully when the AOT step hasn't happened).
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sig_roundtrip() {
        let s = parse_sig("float32[256,16]").unwrap();
        assert_eq!(s.dtype, "float32");
        assert_eq!(s.dims, vec![256, 16]);
        let s = parse_sig("int32[2048]").unwrap();
        assert_eq!(s.dims, vec![2048]);
        assert!(parse_sig("garbage").is_err());
    }

    #[test]
    fn required_entries_follow_the_merge_registry() {
        let req = required_entries().unwrap();
        for entry in [
            "merge_add",
            "merge_sat",
            "merge_cmul",
            "merge_bitor",
            "merge_min",
            "merge_max",
            "merge_approx",
            "kmeans_step",
            "pagerank_iter",
        ] {
            assert!(req.contains(entry), "missing {entry}");
        }
        // kernel-less extension functions must not inflate the contract
        assert_eq!(req.len(), 9);
    }

    #[test]
    fn manifest_loads_when_artifacts_present() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(&default_artifacts_dir()).unwrap();
        assert_eq!(m.entries["merge_add"].len(), 3);
        assert_eq!(m.entries["merge_add"][0].dims, vec![MERGE_BATCH, LINE_WORDS]);
        assert_eq!(m.entries["merge_sat"].len(), 4);
        assert!(m.hlo_path("merge_add").exists());
    }
}
