//! PJRT-backed batch merge executor.
//!
//! Implements [`BatchExecutor`] over the Pallas merge kernels: batches
//! are padded to the AOT batch size (rows are independent, padding
//! outputs are discarded) and dispatched as one PJRT execution per
//! chunk. Integer add/saturating kinds route through the f32 kernels —
//! exact for values below 2^24, which covers every workload here (the
//! native executor remains the reference; the integration tests
//! cross-check the two).
//!
//! Kernel selection is driven entirely by the merge function's own
//! [`BatchKernel`] descriptor ([`MergeFn::batch_kernel`]) — this module
//! names no merge function. A function without an AOT kernel (e.g. a
//! user-registered extension) transparently executes through the native
//! per-line path, so the batch interface stays total over the open
//! registry.

use anyhow::Result;

use super::artifacts::MERGE_BATCH;
use super::engine::Engine;
use crate::merge::batch::{BatchExecutor, MergeItem, NativeExecutor};
use crate::merge::{BatchKernel, KernelLane, LineData, MergeFn};

pub struct PjrtMergeExecutor {
    engine: Engine,
}

impl PjrtMergeExecutor {
    pub fn new(engine: Engine) -> Self {
        Self { engine }
    }

    pub fn load_default() -> Result<Self> {
        Ok(Self::new(Engine::load_default()?))
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    fn run_chunk(
        &mut self,
        kernel: &BatchKernel,
        chunk: &[MergeItem],
    ) -> Result<Vec<LineData>> {
        let b = MERGE_BATCH;
        let w = crate::merge::LINE_WORDS;

        fn field(it: &MergeItem, which: usize) -> &LineData {
            match which {
                0 => &it.src,
                1 => &it.upd,
                _ => &it.mem,
            }
        }

        let mut args: Vec<xla::Literal> = Vec::with_capacity(4);
        for which in 0..3 {
            match kernel.lane {
                KernelLane::I32 => {
                    let mut flat = vec![0i32; b * w];
                    for (i, it) in chunk.iter().enumerate() {
                        let line = field(it, which);
                        for j in 0..w {
                            flat[i * w + j] = line[j] as i32;
                        }
                    }
                    args.push(
                        xla::Literal::vec1(&flat).reshape(&[b as i64, w as i64])?,
                    );
                }
                KernelLane::F32 | KernelLane::U32AsF32 => {
                    let mut flat = vec![0f32; b * w];
                    for (i, it) in chunk.iter().enumerate() {
                        let line = field(it, which);
                        for j in 0..w {
                            flat[i * w + j] = match kernel.lane {
                                KernelLane::F32 => f32::from_bits(line[j]),
                                _ => line[j] as f32,
                            };
                        }
                    }
                    args.push(
                        xla::Literal::vec1(&flat).reshape(&[b as i64, w as i64])?,
                    );
                }
            }
        }

        // trailing operands: scalar (saturation threshold) / drop mask
        if let Some(scalar) = kernel.scalar {
            args.push(xla::Literal::vec1(&[scalar]).reshape(&[1, 1])?);
        }
        if kernel.keep_mask {
            let mut mask = vec![1f32; b];
            for (i, it) in chunk.iter().enumerate() {
                mask[i] = if it.drop_update { 0.0 } else { 1.0 };
            }
            args.push(xla::Literal::vec1(&mask).reshape(&[b as i64, 1])?);
        }

        let out = self.engine.execute(&kernel.entry, &args)?;
        anyhow::ensure!(out.len() == 1, "{}: expected 1 output", kernel.entry);
        let mut result = Vec::with_capacity(chunk.len());
        match kernel.lane {
            KernelLane::I32 => {
                let flat = out[0].to_vec::<i32>()?;
                for i in 0..chunk.len() {
                    let mut line = [0u32; 16];
                    for j in 0..w {
                        line[j] = flat[i * w + j] as u32;
                    }
                    result.push(line);
                }
            }
            KernelLane::U32AsF32 => {
                let flat = out[0].to_vec::<f32>()?;
                for i in 0..chunk.len() {
                    let mut line = [0u32; 16];
                    for j in 0..w {
                        line[j] = flat[i * w + j].round() as u32;
                    }
                    result.push(line);
                }
            }
            KernelLane::F32 => {
                let flat = out[0].to_vec::<f32>()?;
                for i in 0..chunk.len() {
                    let mut line = [0u32; 16];
                    for j in 0..w {
                        line[j] = flat[i * w + j].to_bits();
                    }
                    result.push(line);
                }
            }
        }
        Ok(result)
    }
}

impl BatchExecutor for PjrtMergeExecutor {
    fn execute(&mut self, f: &dyn MergeFn, items: &[MergeItem]) -> Vec<LineData> {
        let Some(kernel) = f.batch_kernel() else {
            // no AOT kernel for this function: the software definition
            // *is* the function — run it natively
            return NativeExecutor.execute(f, items);
        };
        let mut out = Vec::with_capacity(items.len());
        for chunk in items.chunks(MERGE_BATCH) {
            match self.run_chunk(&kernel, chunk) {
                Ok(mut lines) => out.append(&mut lines),
                Err(e) => panic!("PJRT merge execution failed ({}): {e:#}", f.name()),
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
