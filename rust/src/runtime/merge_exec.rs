//! PJRT-backed batch merge executor.
//!
//! Implements [`BatchExecutor`] over the Pallas merge kernels: batches
//! are padded to the AOT batch size (rows are independent, padding
//! outputs are discarded) and dispatched as one PJRT execution per
//! chunk. Integer add/saturating kinds route through the f32 kernels —
//! exact for values below 2^24, which covers every workload here (the
//! native executor remains the reference; the integration tests
//! cross-check the two).

use anyhow::Result;

use super::artifacts::{LINE_WORDS, MERGE_BATCH};
use super::engine::Engine;
use crate::merge::batch::{BatchExecutor, MergeItem};
use crate::merge::{LineData, MergeKind};

pub struct PjrtMergeExecutor {
    engine: Engine,
}

enum Lane {
    F32,
    U32AsF32,
    I32,
}

impl PjrtMergeExecutor {
    pub fn new(engine: Engine) -> Self {
        Self { engine }
    }

    pub fn load_default() -> Result<Self> {
        Ok(Self::new(Engine::load_default()?))
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    fn entry_for(kind: MergeKind) -> (&'static str, Lane) {
        match kind {
            MergeKind::AddU32 => ("merge_add", Lane::U32AsF32),
            MergeKind::AddF32 => ("merge_add", Lane::F32),
            MergeKind::SatAddU32 { .. } => ("merge_sat", Lane::U32AsF32),
            MergeKind::SatAddF32 { .. } => ("merge_sat", Lane::F32),
            MergeKind::CmulF32 => ("merge_cmul", Lane::F32),
            MergeKind::BitOr => ("merge_bitor", Lane::I32),
            MergeKind::MinF32 => ("merge_min", Lane::F32),
            MergeKind::MaxF32 => ("merge_max", Lane::F32),
            MergeKind::ApproxAddF32 { .. } => ("merge_approx", Lane::F32),
        }
    }

    fn run_chunk(
        &mut self,
        kind: MergeKind,
        chunk: &[MergeItem],
    ) -> Result<Vec<LineData>> {
        let (entry, lane) = Self::entry_for(kind);
        let b = MERGE_BATCH;
        let w = LINE_WORDS;

        fn field(it: &MergeItem, which: usize) -> &LineData {
            match which {
                0 => &it.src,
                1 => &it.upd,
                _ => &it.mem,
            }
        }

        let mut args: Vec<xla::Literal> = Vec::with_capacity(4);
        for which in 0..3 {
            match lane {
                Lane::I32 => {
                    let mut flat = vec![0i32; b * w];
                    for (i, it) in chunk.iter().enumerate() {
                        let line = field(it, which);
                        for j in 0..w {
                            flat[i * w + j] = line[j] as i32;
                        }
                    }
                    args.push(
                        xla::Literal::vec1(&flat).reshape(&[b as i64, w as i64])?,
                    );
                }
                Lane::F32 | Lane::U32AsF32 => {
                    let mut flat = vec![0f32; b * w];
                    for (i, it) in chunk.iter().enumerate() {
                        let line = field(it, which);
                        for j in 0..w {
                            flat[i * w + j] = match lane {
                                Lane::F32 => f32::from_bits(line[j]),
                                _ => line[j] as f32,
                            };
                        }
                    }
                    args.push(
                        xla::Literal::vec1(&flat).reshape(&[b as i64, w as i64])?,
                    );
                }
            }
        }

        // trailing operands: saturation threshold / drop mask
        match kind {
            MergeKind::SatAddU32 { max } => {
                args.push(xla::Literal::vec1(&[max as f32]).reshape(&[1, 1])?);
            }
            MergeKind::SatAddF32 { max } => {
                args.push(xla::Literal::vec1(&[max]).reshape(&[1, 1])?);
            }
            MergeKind::ApproxAddF32 { .. } => {
                let mut mask = vec![1f32; b];
                for (i, it) in chunk.iter().enumerate() {
                    mask[i] = if it.drop_update { 0.0 } else { 1.0 };
                }
                args.push(xla::Literal::vec1(&mask).reshape(&[b as i64, 1])?);
            }
            _ => {}
        }

        let out = self.engine.execute(entry, &args)?;
        anyhow::ensure!(out.len() == 1, "{entry}: expected 1 output");
        let mut result = Vec::with_capacity(chunk.len());
        match lane {
            Lane::I32 => {
                let flat = out[0].to_vec::<i32>()?;
                for i in 0..chunk.len() {
                    let mut line = [0u32; 16];
                    for j in 0..w {
                        line[j] = flat[i * w + j] as u32;
                    }
                    result.push(line);
                }
            }
            Lane::U32AsF32 => {
                let flat = out[0].to_vec::<f32>()?;
                for i in 0..chunk.len() {
                    let mut line = [0u32; 16];
                    for j in 0..w {
                        line[j] = flat[i * w + j].round() as u32;
                    }
                    result.push(line);
                }
            }
            Lane::F32 => {
                let flat = out[0].to_vec::<f32>()?;
                for i in 0..chunk.len() {
                    let mut line = [0u32; 16];
                    for j in 0..w {
                        line[j] = flat[i * w + j].to_bits();
                    }
                    result.push(line);
                }
            }
        }
        Ok(result)
    }
}

impl BatchExecutor for PjrtMergeExecutor {
    fn execute(&mut self, kind: MergeKind, items: &[MergeItem]) -> Vec<LineData> {
        let mut out = Vec::with_capacity(items.len());
        for chunk in items.chunks(MERGE_BATCH) {
            match self.run_chunk(kind, chunk) {
                Ok(mut lines) => out.append(&mut lines),
                Err(e) => panic!("PJRT merge execution failed: {e:#}"),
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
