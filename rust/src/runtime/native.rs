//! Native-thread execution backend: runs [`Workload`] programs on real
//! OS threads over `AtomicU32` shared memory instead of the simulator.
//!
//! This is the machine half of `--backend native`
//! ([`exec::driver::run_native`](crate::exec::driver::run_native) is the
//! orchestration half). Where the simulator interleaves logical cores
//! deterministically and charges cycles through the timing model, the
//! [`NativeMachine`] spawns one scoped thread per core and lets the
//! hardware schedule them:
//!
//! * coherent operations are real atomics — `Acquire` loads, `Release`
//!   stores, `compare_exchange`/`fetch_or` RMWs;
//! * `lock`/`unlock` are a CAS spinlock over the same lock words the
//!   simulated variants use;
//! * `barrier` is an abortable spin barrier (a faulting sibling releases
//!   waiters instead of deadlocking them);
//! * COps (`c_read`/`c_write`) privatize the accessed line into a
//!   per-thread buffer — a software source buffer: original value
//!   (`src`) plus updated copy (`upd`) — and `merge` pushes every
//!   private line through its registry-resolved [`MergeFn`] handle via
//!   the same [`BatchExecutor`] dispatch the simulator's merge engine
//!   uses, under a global merge lock so each line merge is atomic.
//!
//! Merging only at explicit `merge` boundaries (no capacity evictions)
//! is a *schedule* change, not a semantic one: registered merge
//! functions are commutative delta/monotone reconciliations, so any
//! merge order reaches the same final memory — which the driver then
//! checks against the same sequential goldens as the simulation.
//!
//! A COp naming an uninstalled MFRF slot is the same machine fault as in
//! the simulator: the thread records a typed [`MergeFault`] and unwinds;
//! the driver recovers it as `ExecError::MergeFault`.

use std::collections::HashMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::exec::ctx::ExecCtx;
use crate::merge::batch::{BatchExecutor, MergeItem, NativeExecutor};
use crate::merge::{LineData, MergeHandle, LINE_WORDS};
use crate::sim::addr::{Addr, Line};
use crate::sim::machine::install_quiet_fault_hook;
use crate::sim::mfrf::MergeFault;

/// Spin barrier with abort support: a thread that unwinds (fault, bug)
/// flips the abort flag so waiting siblings panic out instead of
/// spinning forever on an arrival count that will never complete.
pub struct SpinBarrier {
    arrived: AtomicUsize,
    generation: AtomicUsize,
    cores: usize,
    aborted: AtomicBool,
}

impl SpinBarrier {
    pub fn new(cores: usize) -> Self {
        Self {
            arrived: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
            cores,
            aborted: AtomicBool::new(false),
        }
    }

    /// Release every current and future waiter by panicking it.
    pub fn abort(&self) {
        self.aborted.store(true, Ordering::Release);
    }

    pub fn is_aborted(&self) -> bool {
        self.aborted.load(Ordering::Acquire)
    }

    /// Block (spin) until all `cores` threads arrive. Panics with a
    /// "sibling core panicked" notice if the barrier is aborted.
    pub fn wait(&self) {
        if self.is_aborted() {
            panic!("sibling core panicked; aborting native barrier");
        }
        if self.cores <= 1 {
            return;
        }
        let gen = self.generation.load(Ordering::Acquire);
        if self.arrived.fetch_add(1, Ordering::AcqRel) + 1 == self.cores {
            // last arrival: reset the count *before* publishing the new
            // generation, so released threads re-entering the next
            // barrier see a zeroed count
            self.arrived.store(0, Ordering::Release);
            self.generation.fetch_add(1, Ordering::AcqRel);
        } else {
            let mut spins = 0u32;
            while self.generation.load(Ordering::Acquire) == gen {
                if self.is_aborted() {
                    panic!("sibling core panicked; aborting native barrier");
                }
                spins = spins.wrapping_add(1);
                if spins % 4096 == 0 {
                    // more threads than hardware cores: let the laggard run
                    std::thread::yield_now();
                }
                std::hint::spin_loop();
            }
        }
    }
}

/// State shared by every thread of one native run.
struct NativeShared {
    /// The flat functional memory, word-addressed — the native analog of
    /// the simulator's `MemSystem` memory array.
    words: Vec<AtomicU32>,
    barrier: SpinBarrier,
    /// Serializes merges so each line's read-reconcile-write is atomic
    /// with respect to other threads' merges.
    merge_lock: Mutex<()>,
    /// First machine fault raised by any thread (authoritative, like
    /// `MemSystem::take_fault`).
    fault: Mutex<Option<MergeFault>>,
    cores: usize,
    mfrf_slots: usize,
}

/// One privatized line in a thread's software source buffer.
#[derive(Clone)]
struct PrivLine {
    /// Line value at privatization time.
    src: LineData,
    /// The thread's updated copy (COps read/write this).
    upd: LineData,
    /// MFRF slot naming the merge function (last COp wins, mirroring
    /// the simulator's re-typing rule).
    ty: u8,
}

/// Per-thread operation tally, folded into [`NativeRun`] at join time.
#[derive(Clone, Debug, Default)]
pub struct CoreTally {
    /// Memory operations + COps issued (the native "cycles").
    pub ops: u64,
    pub cops: u64,
    pub atomic_rmws: u64,
    pub lock_acquires: u64,
    pub merges: u64,
    pub barriers: u64,
}

/// Outcome of one native parallel section.
#[derive(Clone, Debug)]
pub struct NativeRun {
    /// Per-core operation counts (the native stand-in for core clocks).
    pub per_core_ops: Vec<u64>,
    pub cops: u64,
    pub atomic_rmws: u64,
    pub lock_acquires: u64,
    pub merges: u64,
    pub barriers: u64,
    /// Wall-clock seconds of the parallel section (threads spawned →
    /// all joined).
    pub secs: f64,
}

impl NativeRun {
    pub fn ops_total(&self) -> u64 {
        self.per_core_ops.iter().sum()
    }

    /// Measured throughput in Mops/s.
    pub fn mops(&self) -> f64 {
        if self.secs <= 0.0 {
            return 0.0;
        }
        self.ops_total() as f64 / self.secs / 1e6
    }
}

/// The native machine: shared atomic memory + one OS thread per core.
pub struct NativeMachine {
    shared: NativeShared,
}

impl NativeMachine {
    /// Build shared memory initialized from a flat word snapshot (the
    /// simulator `MemSystem` after `Workload::setup` — the allocator and
    /// input data are backend-independent).
    pub fn new(words: &[u32], cores: usize, mfrf_slots: usize) -> Self {
        assert!(cores >= 1, "native machine needs at least one core");
        Self {
            shared: NativeShared {
                words: words.iter().map(|&w| AtomicU32::new(w)).collect(),
                barrier: SpinBarrier::new(cores),
                merge_lock: Mutex::new(()),
                fault: Mutex::new(None),
                cores,
                mfrf_slots,
            },
        }
    }

    pub fn cores(&self) -> usize {
        self.shared.cores
    }

    /// Final flat memory (after `run`), for writing back into a
    /// `MemSystem` and verifying against the golden.
    pub fn snapshot(&self) -> Vec<u32> {
        self.shared
            .words
            .iter()
            .map(|w| w.load(Ordering::Acquire))
            .collect()
    }

    /// The first machine fault any thread raised, if one did.
    pub fn take_fault(&self) -> Option<MergeFault> {
        self.shared
            .fault
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .take()
    }

    /// Run one program per core on real threads; returns the tallies and
    /// wall clock. A thread panic (machine fault included) aborts the
    /// barrier, joins the siblings, and re-raises the first payload —
    /// the same contract as the simulator's `Machine::run`, so the
    /// driver's fault recovery is backend-independent.
    pub fn run(&self, programs: Vec<Box<dyn FnOnce(&mut NativeCtx) + Send + '_>>) -> NativeRun {
        install_quiet_fault_hook();
        let cores = self.shared.cores;
        assert_eq!(programs.len(), cores, "one program per core");
        let mut tallies: Vec<CoreTally> = Vec::with_capacity(cores);
        let mut first_panic: Option<Box<dyn std::any::Any + Send>> = None;
        let start = Instant::now();
        std::thread::scope(|s| {
            let handles: Vec<_> = programs
                .into_iter()
                .enumerate()
                .map(|(core, prog)| {
                    let shared = &self.shared;
                    s.spawn(move || {
                        let out = catch_unwind(AssertUnwindSafe(|| {
                            let mut ctx = NativeCtx::new(shared, core);
                            prog(&mut ctx);
                            // drain any still-private lines: commutative
                            // merge functions make this an identity for
                            // clean (read-only) lines, and it publishes
                            // updates a program left unmerged
                            ctx.merge();
                            ctx.tally()
                        }));
                        if out.is_err() {
                            // release siblings spinning at a barrier
                            shared.barrier.abort();
                        }
                        match out {
                            Ok(t) => t,
                            Err(p) => resume_unwind(p),
                        }
                    })
                })
                .collect();
            for h in handles {
                match h.join() {
                    Ok(t) => tallies.push(t),
                    Err(p) => {
                        if first_panic.is_none() {
                            first_panic = Some(p);
                        }
                    }
                }
            }
        });
        let secs = start.elapsed().as_secs_f64();
        if let Some(p) = first_panic {
            resume_unwind(p);
        }
        NativeRun {
            per_core_ops: tallies.iter().map(|t| t.ops).collect(),
            cops: tallies.iter().map(|t| t.cops).sum(),
            atomic_rmws: tallies.iter().map(|t| t.atomic_rmws).sum(),
            lock_acquires: tallies.iter().map(|t| t.lock_acquires).sum(),
            merges: tallies.iter().map(|t| t.merges).sum(),
            barriers: tallies.iter().map(|t| t.barriers).sum(),
            secs,
        }
    }
}

/// Load one line (16 words) from shared memory.
fn load_line(words: &[AtomicU32], line: Line) -> LineData {
    let base = line.word_index();
    let mut data = [0u32; LINE_WORDS];
    for (i, d) in data.iter_mut().enumerate() {
        *d = words[base + i].load(Ordering::Acquire);
    }
    data
}

/// Store one line (16 words) into shared memory.
fn store_line(words: &[AtomicU32], line: Line, data: &LineData) {
    let base = line.word_index();
    for (i, d) in data.iter().enumerate() {
        words[base + i].store(*d, Ordering::Release);
    }
}

/// The native implementation of [`ExecCtx`]: one OS thread's view of the
/// shared machine. Operation semantics match `CoreCtx` (the contract is
/// documented on the trait); timing does not — `cycles()` reports the
/// operation count, and wall-clock time is measured by the machine.
pub struct NativeCtx<'m> {
    shared: &'m NativeShared,
    core: usize,
    /// Per-thread MFRF: slot → merge handle.
    mfrf: Vec<Option<MergeHandle>>,
    /// Software source buffer: privatized lines under COps.
    priv_lines: HashMap<u64, PrivLine>,
    tally: CoreTally,
}

impl<'m> NativeCtx<'m> {
    fn new(shared: &'m NativeShared, core: usize) -> Self {
        Self {
            shared,
            core,
            mfrf: vec![None; shared.mfrf_slots],
            priv_lines: HashMap::new(),
            tally: CoreTally::default(),
        }
    }

    fn tally(&self) -> CoreTally {
        self.tally.clone()
    }

    /// Number of currently privatized lines (diagnostics/tests).
    pub fn private_lines(&self) -> usize {
        self.priv_lines.len()
    }

    fn word(&self, addr: Addr) -> &AtomicU32 {
        &self.shared.words[addr.word_index()]
    }

    /// Raise the machine fault for MFRF slot `ty`: record it, release
    /// the siblings, unwind this thread with the typed payload.
    fn merge_fault(&self, ty: u8) -> ! {
        let fault = MergeFault {
            core: self.core,
            slot: ty,
            slots: self.shared.mfrf_slots,
        };
        {
            let mut slot = self
                .shared
                .fault
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            slot.get_or_insert(fault.clone());
        }
        self.shared.barrier.abort();
        std::panic::panic_any(fault)
    }

    /// Privatize `line` (if not already private) and bind it to MFRF
    /// slot `ty`; faults if the slot holds no merge function.
    fn privatize(&mut self, line: Line, ty: u8) -> &mut PrivLine {
        if self
            .mfrf
            .get(ty as usize)
            .and_then(|s| s.as_ref())
            .is_none()
        {
            self.merge_fault(ty);
        }
        if !self.priv_lines.contains_key(&line.0) {
            let data = load_line(&self.shared.words, line);
            self.priv_lines.insert(
                line.0,
                PrivLine {
                    src: data,
                    upd: data,
                    ty,
                },
            );
        }
        let entry = self.priv_lines.get_mut(&line.0).unwrap();
        // re-typing: the last COp names the merge function
        entry.ty = ty;
        entry
    }
}

impl ExecCtx for NativeCtx<'_> {
    fn core_id(&self) -> usize {
        self.core
    }

    fn cycles(&mut self) -> u64 {
        self.tally.ops
    }

    fn compute(&mut self, _n: u64) {
        // modeled computation is free natively; only memory operations
        // count toward the measured throughput
    }

    fn read_u32(&mut self, addr: Addr) -> u32 {
        self.tally.ops += 1;
        self.word(addr).load(Ordering::Acquire)
    }

    fn write_u32(&mut self, addr: Addr, val: u32) {
        self.tally.ops += 1;
        self.word(addr).store(val, Ordering::Release);
    }

    fn cas_u32(&mut self, addr: Addr, expected: u32, new: u32) -> bool {
        self.tally.ops += 1;
        self.tally.atomic_rmws += 1;
        self.word(addr)
            .compare_exchange(expected, new, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    fn fetch_or_u32(&mut self, addr: Addr, bits: u32) -> u32 {
        self.tally.ops += 1;
        self.tally.atomic_rmws += 1;
        self.word(addr).fetch_or(bits, Ordering::AcqRel)
    }

    fn merge_init(&mut self, slot: usize, f: MergeHandle) {
        assert!(
            slot < self.mfrf.len(),
            "MFRF slot {slot} out of range (have {})",
            self.mfrf.len()
        );
        self.mfrf[slot] = Some(f);
    }

    fn c_read_u32(&mut self, addr: Addr, ty: u8) -> u32 {
        self.tally.ops += 1;
        self.tally.cops += 1;
        let off = (addr.offset() / 4) as usize;
        self.privatize(addr.line(), ty).upd[off]
    }

    fn c_write_u32(&mut self, addr: Addr, val: u32, ty: u8) {
        self.tally.ops += 1;
        self.tally.cops += 1;
        let off = (addr.offset() / 4) as usize;
        self.privatize(addr.line(), ty).upd[off] = val;
    }

    fn soft_merge(&mut self) {
        // no capacity pressure natively: private lines live until the
        // explicit merge, so marking them evictable is a no-op
    }

    fn merge(&mut self) {
        if self.priv_lines.is_empty() {
            return;
        }
        // deterministic line order, grouped into homogeneous same-type
        // batches for the BatchExecutor dispatch the sim engine also uses
        let mut lines: Vec<(u64, PrivLine)> = self.priv_lines.drain().collect();
        lines.sort_by_key(|(l, e)| (e.ty, *l));
        let _guard = self
            .shared
            .merge_lock
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        let mut exec = NativeExecutor;
        let mut i = 0;
        while i < lines.len() {
            let ty = lines[i].1.ty;
            let mut j = i;
            while j < lines.len() && lines[j].1.ty == ty {
                j += 1;
            }
            let Some(f) = self.mfrf[ty as usize].clone() else {
                // unreachable through privatize(), which gates on the
                // slot — but a fault beats silent data loss
                self.merge_fault(ty);
            };
            let items: Vec<MergeItem> = lines[i..j]
                .iter()
                .map(|(l, e)| MergeItem {
                    src: e.src,
                    upd: e.upd,
                    mem: load_line(&self.shared.words, Line(*l)),
                    drop_update: false,
                })
                .collect();
            let out = exec.execute(&*f, &items);
            for ((l, _), data) in lines[i..j].iter().zip(out.iter()) {
                store_line(&self.shared.words, Line(*l), data);
            }
            self.tally.merges += (j - i) as u64;
            i = j;
        }
    }

    fn lock(&mut self, addr: Addr) {
        self.tally.ops += 1;
        self.tally.lock_acquires += 1;
        let w = self.word(addr);
        let mut spins = 0u32;
        while w
            .compare_exchange_weak(0, 1, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            spins = spins.wrapping_add(1);
            if spins % 1024 == 0 {
                if self.shared.barrier.is_aborted() {
                    panic!("sibling core panicked; aborting native lock wait");
                }
                std::thread::yield_now();
            }
            std::hint::spin_loop();
        }
    }

    fn unlock(&mut self, addr: Addr) {
        self.tally.ops += 1;
        self.word(addr).store(0, Ordering::Release);
    }

    fn barrier(&mut self) {
        self.tally.barriers += 1;
        self.shared.barrier.wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::funcs::AddU32;
    use crate::merge::handle;

    fn programs<'a>(
        cores: usize,
        f: impl Fn(&mut NativeCtx, usize) + Send + Sync + Copy + 'a,
    ) -> Vec<Box<dyn FnOnce(&mut NativeCtx) + Send + 'a>> {
        (0..cores)
            .map(|core| {
                let b: Box<dyn FnOnce(&mut NativeCtx) + Send + 'a> =
                    Box::new(move |ctx| f(ctx, core));
                b
            })
            .collect()
    }

    #[test]
    fn cas_increments_are_not_lost() {
        let cores = 4;
        let m = NativeMachine::new(&[0u32; 16], cores, 4);
        let run = m.run(programs(cores, |ctx, _| {
            for _ in 0..1000 {
                loop {
                    let v = ctx.read_u32(Addr(0));
                    if ctx.cas_u32(Addr(0), v, v + 1) {
                        break;
                    }
                }
            }
        }));
        assert_eq!(m.snapshot()[0], 4000);
        assert_eq!(run.per_core_ops.len(), cores);
        assert!(run.atomic_rmws >= 4000);
        assert!(run.secs > 0.0);
    }

    #[test]
    fn spinlock_protects_a_plain_counter() {
        let cores = 4;
        // word 0 = lock, word 16 (next line) = counter
        let m = NativeMachine::new(&[0u32; 32], cores, 4);
        m.run(programs(cores, |ctx, _| {
            for _ in 0..500 {
                ctx.lock(Addr(0));
                let v = ctx.read_u32(Addr(64));
                ctx.write_u32(Addr(64), v + 1);
                ctx.unlock(Addr(0));
            }
        }));
        assert_eq!(m.snapshot()[16], 2000);
        assert_eq!(m.snapshot()[0], 0, "lock released");
    }

    #[test]
    fn cop_updates_merge_to_the_sum() {
        let cores = 4;
        let m = NativeMachine::new(&[0u32; 16], cores, 4);
        let run = m.run(programs(cores, |ctx, _| {
            ctx.merge_init(0, handle(AddU32));
            for _ in 0..100 {
                let v = ctx.c_read_u32(Addr(4), 0);
                ctx.c_write_u32(Addr(4), v + 1, 0);
            }
            ctx.merge();
            ctx.barrier();
        }));
        assert_eq!(m.snapshot()[1], 400);
        assert_eq!(run.merges, cores as u64);
        assert_eq!(run.barriers, cores as u64);
    }

    #[test]
    fn unmerged_private_lines_drain_at_thread_exit() {
        let m = NativeMachine::new(&[0u32; 16], 2, 4);
        m.run(programs(2, |ctx, core| {
            ctx.merge_init(0, handle(AddU32));
            let v = ctx.c_read_u32(Addr(0), 0);
            ctx.c_write_u32(Addr(0), v + 1 + core as u32, 0);
            // no explicit merge: the machine drains on exit
        }));
        assert_eq!(m.snapshot()[0], 3); // (1) + (2)
    }

    #[test]
    fn uninstalled_slot_is_a_recovered_merge_fault() {
        let m = NativeMachine::new(&[0u32; 16], 2, 4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            m.run(programs(2, |ctx, _| {
                // barrier first: proves an aborted barrier releases the
                // sibling instead of deadlocking the join
                let _ = ctx.c_read_u32(Addr(0), 3);
                ctx.barrier();
            }));
        }));
        assert!(r.is_err(), "fault must unwind");
        let fault = m.take_fault().expect("fault recorded");
        assert_eq!(fault.slot, 3);
        assert!(m.take_fault().is_none(), "fault is taken once");
    }

    #[test]
    fn barrier_synchronizes_phases() {
        let cores = 3;
        // one counter word per core in distinct lines
        let words = vec![0u32; 16 * cores];
        let m = NativeMachine::new(&words, cores, 4);
        m.run(programs(cores, |ctx, core| {
            ctx.write_u32(Addr(core as u64 * 64), 7);
            ctx.barrier();
            // after the barrier every sibling's phase-1 store is visible
            let mut sum = 0;
            for c in 0..3u64 {
                sum += ctx.read_u32(Addr(c * 64));
            }
            assert_eq!(sum, 21);
        }));
    }
}
