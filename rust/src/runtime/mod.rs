//! PJRT runtime: loads the AOT-compiled JAX/Pallas kernels and executes
//! them from rust.
//!
//! `make artifacts` runs `python/compile/aot.py` once, lowering every L2
//! entry point to HLO **text** under `artifacts/` (text, not serialized
//! proto — xla_extension 0.5.1 rejects jax≥0.5's 64-bit instruction
//! ids). This module loads those files through the `xla` crate's PJRT
//! CPU client, compiles them once, and exposes typed wrappers:
//!
//! * [`engine::Engine`] — artifact registry + compiled-executable cache
//! * [`merge_exec::PjrtMergeExecutor`] — [`crate::merge::batch::BatchExecutor`]
//!   backed by the Pallas merge kernels (pads batches to the AOT shape)
//! * [`engine::Engine::kmeans_step`] / [`engine::Engine::pagerank_iter`] —
//!   the workload compute kernels used by the examples and the
//!   end-to-end driver
//! * [`native::NativeMachine`] — the `--backend native` execution
//!   machine: real OS threads + atomics running the same `Workload`
//!   programs the simulator runs (no PJRT involvement; it lives here
//!   because `runtime/` is the "actually execute things" layer)
//!
//! Python never runs at simulation time: the rust binary is
//! self-contained once `artifacts/` exists.

pub mod artifacts;
pub mod engine;
pub mod merge_exec;
pub mod native;

pub use artifacts::{default_artifacts_dir, Manifest};
pub use engine::Engine;
pub use merge_exec::PjrtMergeExecutor;
pub use native::{NativeCtx, NativeMachine, NativeRun};
