//! Software-defined merge functions (paper Sections 3.2, 4.5, 6.3).
//!
//! A merge function combines a core's preserved *source* copy and its
//! *updated* copy with the *in-memory* copy of one 64-byte cache line,
//! producing the new memory value. The paper's central claim is that
//! keeping these functions in **software** (vs. COUP's fixed hardware set)
//! makes commutative-update acceleration broadly applicable: saturating
//! arithmetic, complex multiplication, bitwise logic, approximate merging.
//!
//! The merge layer is an **open API**: any type implementing [`MergeFn`]
//! can be installed into a core's merge-function register file and driven
//! by the simulator — the nine paper behaviours in [`funcs`] and the
//! extension functions in [`ext`] register through the exact same
//! [`registry::MergeRegistry`] surface a downstream user would use (see
//! `examples/custom_merge.rs` for a user-defined merge function that
//! never touches this module).
//!
//! Two execution paths compute identical results:
//! * [`funcs`] — native rust reference implementations, used per-merge on
//!   the simulator's critical path;
//! * [`crate::runtime`] — the AOT-compiled JAX/Pallas batch kernels,
//!   executed via PJRT for array-scale reductions (DUP) and deferred
//!   merge batches. A [`MergeFn`] opts in by returning a [`BatchKernel`]
//!   descriptor; functions without one transparently fall back to their
//!   native [`MergeFn::apply`].

pub mod batch;
pub mod ext;
pub mod funcs;
pub mod registry;

use std::sync::Arc;

use crate::util::rng::Rng;

pub use registry::{default_registry, MergeError, MergeRegistry, MergeSpec};

/// 64-byte cache line as 16 32-bit words — the merge-register granularity.
pub const LINE_WORDS: usize = 16;
pub type LineData = [u32; LINE_WORDS];

pub const ZERO_LINE: LineData = [0u32; LINE_WORDS];

/// A shared, installable merge function. `merge_init` installs one of
/// these into a core's merge-function register file (MFRF) slot; each
/// CData line carries the slot index in its merge-type field.
pub type MergeHandle = Arc<dyn MergeFn>;

/// Wrap a concrete merge function into an installable [`MergeHandle`].
pub fn handle<F: MergeFn + 'static>(f: F) -> MergeHandle {
    Arc::new(f)
}

/// Which operand of a merge a randomly generated line will play, for
/// the auto-generated law suite ([`crate::util::ptest::check_merge_laws`]).
/// Functions with a restricted input domain (e.g. complex multiply needs
/// source values away from zero) override [`MergeFn::sample_line`] per
/// role.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergeOperand {
    /// The preserved source copy.
    Src,
    /// A core's updated copy.
    Upd,
    /// The in-memory value merges accumulate into.
    Mem,
}

/// Numeric lane interpretation of a line on the PJRT batch path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelLane {
    /// Words are f32 bit patterns.
    F32,
    /// Words are u32 values routed through the f32 kernels — exact for
    /// values below 2^24 (covers every counting workload here).
    U32AsF32,
    /// Words are routed as i32 (bitwise kernels).
    I32,
}

/// Descriptor of an AOT-compiled batch kernel implementing a merge
/// function on the PJRT path (see `runtime::merge_exec`). The kernel
/// receives `src`, `upd`, `mem` tiles of shape `[B, 16]` in `lane`
/// representation, then `scalar` (as a `[1, 1]` operand) and, when
/// `keep_mask` is set, a per-row `[B, 1]` keep/drop mask.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchKernel {
    /// Artifact entry name (`artifacts/<entry>.hlo.txt`).
    pub entry: String,
    pub lane: KernelLane,
    /// Trailing scalar operand (e.g. a saturation threshold).
    pub scalar: Option<f32>,
    /// Append the per-item keep mask (approximate kinds).
    pub keep_mask: bool,
}

impl BatchKernel {
    pub fn new(entry: &str, lane: KernelLane) -> Self {
        Self {
            entry: entry.to_string(),
            lane,
            scalar: None,
            keep_mask: false,
        }
    }

    pub fn with_scalar(mut self, scalar: f32) -> Self {
        self.scalar = Some(scalar);
        self
    }

    pub fn with_keep_mask(mut self) -> Self {
        self.keep_mask = true;
        self
    }
}

/// A software-defined merge function: the open extension point of the
/// whole system.
///
/// Implementations must be commutative in the sense of the paper's
/// Section 3 correctness condition: applying two cores' updates in
/// either order must produce the same memory value (to
/// [`MergeFn::law_tolerance`]). Every function registered in a
/// [`MergeRegistry`] is checked against this law (and idempotence, where
/// declared) by the auto-generated property suite — new registrations
/// get law-checked for free.
pub trait MergeFn: Send + Sync {
    /// Stable name used by the CLI (`--merge`), reports and the artifact
    /// registry.
    fn name(&self) -> &str;

    /// Apply the merge to one line: returns the new memory value.
    ///
    /// `drop_update` is consulted only by approximate functions: when
    /// true the line's update is discarded (the caller samples the
    /// binomial with [`MergeFn::drop_probability`], keeping the native
    /// and PJRT paths in agreement).
    fn apply(
        &self,
        src: &LineData,
        upd: &LineData,
        mem: &LineData,
        drop_update: bool,
    ) -> LineData;

    /// Whether repeated merging of the same updated copy is harmless.
    /// (Idempotent merges need no source copy to be correct.)
    fn idempotent(&self) -> bool {
        false
    }

    /// Probability that one line's update is dropped (approximate,
    /// loop-perforation-style merges, Section 6.3). The simulator
    /// samples this per merged line and passes the decision to
    /// [`MergeFn::apply`] as `drop_update`.
    fn drop_probability(&self) -> f32 {
        0.0
    }

    /// The AOT batch kernel computing this function on the PJRT path,
    /// if one exists. `None` (the default) makes batch executors fall
    /// back to the native [`MergeFn::apply`] loop.
    fn batch_kernel(&self) -> Option<BatchKernel> {
        None
    }

    /// Generate a random line in this function's input domain for the
    /// law suite. The default draws f32 values in ±100 — valid for
    /// float adds and for every bit-exact integer function (which is
    /// insensitive to the bit patterns used).
    fn sample_line(&self, rng: &mut Rng, _role: MergeOperand) -> LineData {
        funcs::f32_line(rng, -100.0, 100.0)
    }

    /// Relative tolerance for the commutativity/idempotence law check:
    /// `0.0` (the default) demands bit equality; floating-point
    /// functions return their rounding slack.
    fn law_tolerance(&self) -> f32 {
        0.0
    }
}

#[inline]
pub fn f32_bits(v: f32) -> u32 {
    v.to_bits()
}

#[inline]
pub fn bits_f32(v: u32) -> f32 {
    f32::from_bits(v)
}
