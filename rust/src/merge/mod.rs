//! Software-defined merge functions (paper Sections 3.2, 4.5, 6.3).
//!
//! A merge function combines a core's preserved *source* copy and its
//! *updated* copy with the *in-memory* copy of one 64-byte cache line,
//! producing the new memory value. The paper's central claim is that
//! keeping these functions in **software** (vs. COUP's fixed hardware set)
//! makes commutative-update acceleration broadly applicable: saturating
//! arithmetic, complex multiplication, bitwise logic, approximate merging.
//!
//! Two execution paths compute identical results:
//! * [`funcs`] — native rust reference implementations, used per-merge on
//!   the simulator's critical path;
//! * [`crate::runtime`] — the AOT-compiled JAX/Pallas batch kernels,
//!   executed via PJRT for array-scale reductions (DUP) and deferred
//!   merge batches.

pub mod batch;
pub mod funcs;

/// 64-byte cache line as 16 32-bit words — the merge-register granularity.
pub const LINE_WORDS: usize = 16;
pub type LineData = [u32; LINE_WORDS];

pub const ZERO_LINE: LineData = [0u32; LINE_WORDS];

/// The registered merge behaviours. `merge_init` installs one of these
/// into a core's merge-function register file (MFRF) slot; each CData
/// line carries the slot index in its merge-type field.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MergeKind {
    /// `mem += upd - src` over u32 lanes (wrapping) — the key-value store.
    AddU32,
    /// `mem += upd - src` over f32 lanes — K-Means, PageRank.
    AddF32,
    /// Additive with saturation at `max` (u32 lanes). The clamp observes
    /// the merged *memory* value (Section 4.5).
    SatAddU32 { max: u32 },
    /// Additive with saturation at `max` (f32 lanes).
    SatAddF32 { max: f32 },
    /// Complex multiply: lanes are 8 interleaved (re, im) f32 pairs;
    /// `mem *= upd / src`.
    CmulF32,
    /// `mem |= upd` — BFS bitmaps. Idempotent.
    BitOr,
    /// `mem = min(mem, upd)` over f32 lanes. Idempotent.
    MinF32,
    /// `mem = max(mem, upd)` over f32 lanes. Idempotent.
    MaxF32,
    /// Additive over f32 lanes, but each line's update is dropped with
    /// probability `drop_p` (loop-perforation-style approximate merge,
    /// Section 6.3). The drop decision comes from the caller-provided
    /// decision value so both execution paths agree.
    ApproxAddF32 { drop_p: f32 },
}

impl MergeKind {
    /// Stable name used by the CLI, reports and the artifact registry.
    pub fn name(&self) -> &'static str {
        match self {
            MergeKind::AddU32 => "add_u32",
            MergeKind::AddF32 => "add_f32",
            MergeKind::SatAddU32 { .. } => "sat_add_u32",
            MergeKind::SatAddF32 { .. } => "sat_add_f32",
            MergeKind::CmulF32 => "cmul_f32",
            MergeKind::BitOr => "bitor",
            MergeKind::MinF32 => "min_f32",
            MergeKind::MaxF32 => "max_f32",
            MergeKind::ApproxAddF32 { .. } => "approx_add_f32",
        }
    }

    /// Whether repeated merging of the same updated copy is harmless.
    /// (Idempotent merges need no source copy to be correct.)
    pub fn idempotent(&self) -> bool {
        matches!(
            self,
            MergeKind::BitOr | MergeKind::MinF32 | MergeKind::MaxF32
        )
    }
}

#[inline]
pub fn f32_bits(v: f32) -> u32 {
    v.to_bits()
}

#[inline]
pub fn bits_f32(v: u32) -> f32 {
    f32::from_bits(v)
}
