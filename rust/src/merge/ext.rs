//! Extension merge functions beyond the paper's set, registered *only*
//! through the public [`MergeRegistry`](super::MergeRegistry) API — no
//! match arm anywhere in the crate names these types, which is the
//! openness property the redesign exists to provide (Sections 3.2/4.5:
//! software merge functions make the acceleration broadly applicable).
//!
//! Neither function has an AOT batch kernel; the PJRT batch executor
//! transparently falls back to the native [`MergeFn::apply`] loop.

use super::registry::MergeRegistry;
use super::{bits_f32, f32_bits, handle, LineData, MergeFn, MergeOperand, LINE_WORDS};
use crate::util::rng::Rng;

/// `mem ^= upd ^ src` over u32 lanes: XOR-accumulation (parity sets,
/// Bloom-filter-style sketches, reversible tagging). XOR deltas form an
/// abelian group, so merges commute bit-exactly.
pub struct XorU32;

impl MergeFn for XorU32 {
    fn name(&self) -> &str {
        "xor_u32"
    }

    fn apply(&self, src: &LineData, upd: &LineData, mem: &LineData, _drop: bool) -> LineData {
        let mut out = *mem;
        for i in 0..LINE_WORDS {
            out[i] = mem[i] ^ (upd[i] ^ src[i]);
        }
        out
    }
}

/// Log-space accumulation over f32 lanes:
/// `mem = ln(e^mem + e^upd - e^src)` — streaming log-sum-exp, the merge
/// rule for probabilistic accumulators kept in log space. Commutative up
/// to float rounding; the argument is clamped to stay positive so a
/// pathological (upd < src with tiny mem) delta degrades gracefully
/// instead of producing NaN.
pub struct LogSumExpF32;

impl MergeFn for LogSumExpF32 {
    fn name(&self) -> &str {
        "logsumexp_f32"
    }

    fn apply(&self, src: &LineData, upd: &LineData, mem: &LineData, _drop: bool) -> LineData {
        let mut out = *mem;
        for i in 0..LINE_WORDS {
            let sum = bits_f32(mem[i]).exp() + bits_f32(upd[i]).exp() - bits_f32(src[i]).exp();
            out[i] = f32_bits(sum.max(f32::MIN_POSITIVE).ln());
        }
        out
    }

    fn sample_line(&self, rng: &mut Rng, role: MergeOperand) -> LineData {
        // keep e^upd >= e^src so the accumulated mass stays positive
        let (lo, hi) = match role {
            MergeOperand::Src => (-4.0, 0.0),
            MergeOperand::Upd => (0.0, 4.0),
            MergeOperand::Mem => (-4.0, 4.0),
        };
        super::funcs::f32_line(rng, lo, hi)
    }

    fn law_tolerance(&self) -> f32 {
        1e-3
    }
}

/// Register the extension functions. Called by
/// [`registry::default_registry`](super::registry::default_registry);
/// exactly what third-party code does for its own functions.
pub fn register_extras(reg: &mut MergeRegistry) {
    reg.register("xor_u32", "XOR-accumulate (parity/sketches)", |p| {
        super::registry::no_param("xor_u32", p)?;
        Ok(handle(XorU32))
    });
    reg.register("logsumexp_f32", "log-space accumulation", |p| {
        super::registry::no_param("logsumexp_f32", p)?;
        Ok(handle(LogSumExpF32))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xor_merges_commute_exactly() {
        let mut rng = Rng::new(0x10);
        let mk = |rng: &mut Rng| {
            let mut l = [0u32; LINE_WORDS];
            for w in l.iter_mut() {
                *w = rng.next_u32();
            }
            l
        };
        for _ in 0..50 {
            let (mem, src, a, b) = (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
            let ab = XorU32.apply(&src, &b, &XorU32.apply(&src, &a, &mem, false), false);
            let ba = XorU32.apply(&src, &a, &XorU32.apply(&src, &b, &mem, false), false);
            assert_eq!(ab, ba);
        }
    }

    #[test]
    fn xor_delta_roundtrips() {
        // applying the same delta twice cancels (XOR group inverse)
        let mem = [0xDEAD_BEEFu32; LINE_WORDS];
        let src = [3u32; LINE_WORDS];
        let upd = [12u32; LINE_WORDS];
        let once = XorU32.apply(&src, &upd, &mem, false);
        assert_ne!(once, mem);
        let twice = XorU32.apply(&src, &upd, &once, false);
        assert_eq!(twice, mem);
    }

    #[test]
    fn logsumexp_accumulates_mass() {
        // mem = ln(1), upd = ln(2), src = ln(1) -> ln(1 + 2 - 1) = ln(2)
        let mem = [f32_bits(0.0); LINE_WORDS];
        let src = [f32_bits(0.0); LINE_WORDS];
        let upd = [f32_bits(2f32.ln()); LINE_WORDS];
        let out = LogSumExpF32.apply(&src, &upd, &mem, false);
        for w in out {
            assert!((bits_f32(w) - 2f32.ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn logsumexp_never_produces_nan() {
        // adversarial: upd far below src drains more mass than exists
        let mem = [f32_bits(-10.0); LINE_WORDS];
        let src = [f32_bits(5.0); LINE_WORDS];
        let upd = [f32_bits(-5.0); LINE_WORDS];
        let out = LogSumExpF32.apply(&src, &upd, &mem, false);
        assert!(out.iter().all(|&w| bits_f32(w).is_finite()));
    }
}
