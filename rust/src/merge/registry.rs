//! The open merge-function registry: stable names → constructors.
//!
//! The registry is the seam that makes the merge layer extensible
//! without editing this crate: the nine paper built-ins register through
//! [`MergeRegistry::register`] exactly like a downstream user's function
//! does, the CLI resolves `--merge name[:param]` here, and the
//! auto-generated law suite ([`crate::util::ptest::check_merge_laws`])
//! iterates whatever is registered — so a new function is law-checked,
//! listable and CLI-selectable the moment it is registered.
//!
//! ```
//! use ccache::merge::{handle, LineData, MergeFn, MergeRegistry, LINE_WORDS};
//!
//! /// A user-defined merge: XOR the update delta into memory.
//! struct XorDelta;
//!
//! impl MergeFn for XorDelta {
//!     fn name(&self) -> &str {
//!         "xor_delta"
//!     }
//!     fn apply(&self, src: &LineData, upd: &LineData, mem: &LineData, _d: bool) -> LineData {
//!         let mut out = *mem;
//!         for i in 0..LINE_WORDS {
//!             out[i] = mem[i] ^ upd[i] ^ src[i];
//!         }
//!         out
//!     }
//! }
//!
//! let mut reg = MergeRegistry::with_builtins();
//! reg.register("xor_delta", "XOR-accumulate", |_param| Ok(handle(XorDelta)));
//! let f = reg.build("xor_delta").unwrap();
//! assert_eq!(f.name(), "xor_delta");
//! assert!(reg.build("add_u32").is_ok()); // built-ins resolve the same way
//! ```

use std::fmt;
use std::str::FromStr;

use super::funcs;
use super::{ext, handle, MergeHandle};

/// Typed merge-resolution errors (CLI prints the diagnostic and exits,
/// mirroring `ExecError`).
#[derive(Clone, Debug, PartialEq)]
pub enum MergeError {
    /// No registered merge function has this name.
    UnknownMerge { name: String, known: Vec<String> },
    /// The `name:param` parameter failed to parse (or the function takes
    /// no parameter).
    BadParam {
        name: String,
        param: String,
        expected: &'static str,
    },
}

impl fmt::Display for MergeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MergeError::UnknownMerge { name, known } => {
                write!(
                    f,
                    "unknown merge function '{name}' (known: {})",
                    known.join(" ")
                )
            }
            MergeError::BadParam {
                name,
                param,
                expected,
            } => {
                write!(f, "merge function '{name}': bad parameter '{param}' (expected {expected})")
            }
        }
    }
}

impl std::error::Error for MergeError {}

/// One registry row: a stable name, a human summary, and the constructor
/// taking the optional `name:param` parameter string.
pub struct MergeSpec {
    pub name: String,
    pub summary: String,
    ctor: Box<dyn Fn(Option<&str>) -> Result<MergeHandle, MergeError> + Send + Sync>,
}

impl MergeSpec {
    /// Construct an instance; `None` uses the function's default
    /// parameters.
    pub fn build(&self, param: Option<&str>) -> Result<MergeHandle, MergeError> {
        (self.ctor)(param)
    }
}

/// Registry of installable merge functions, keyed by stable name.
pub struct MergeRegistry {
    entries: Vec<MergeSpec>,
}

impl MergeRegistry {
    /// An empty registry (no built-ins).
    pub fn new() -> Self {
        Self {
            entries: Vec::new(),
        }
    }

    /// A registry pre-populated with the nine paper merge functions,
    /// registered through the public [`MergeRegistry::register`] path.
    ///
    /// Parameterized functions take a `name:param` argument with these
    /// defaults: `sat_add_u32` (max, default `1000000`), `sat_add_f32`
    /// (max, default `100.0`), `approx_add_f32` (drop probability,
    /// default `0.1`).
    pub fn with_builtins() -> Self {
        let mut r = Self::new();
        r.register("add_u32", "wrapping u32 add (mem += upd - src)", |p| {
            no_param("add_u32", p)?;
            Ok(handle(funcs::AddU32))
        });
        r.register("add_f32", "f32 add (mem += upd - src)", |p| {
            no_param("add_f32", p)?;
            Ok(handle(funcs::AddF32))
        });
        r.register("sat_add_u32", "u32 add saturating at :max", |p| {
            let max = parse_or("sat_add_u32", p, 1_000_000u32, "a u32 maximum")?;
            Ok(handle(funcs::SatAddU32 { max }))
        });
        r.register("sat_add_f32", "f32 add saturating at :max", |p| {
            let max = parse_or("sat_add_f32", p, 100.0f32, "an f32 maximum")?;
            Ok(handle(funcs::SatAddF32 { max }))
        });
        r.register("cmul_f32", "complex multiply (mem *= upd / src)", |p| {
            no_param("cmul_f32", p)?;
            Ok(handle(funcs::CmulF32))
        });
        r.register("bitor", "bitwise OR (idempotent)", |p| {
            no_param("bitor", p)?;
            Ok(handle(funcs::BitOr))
        });
        r.register("min_f32", "f32 minimum (idempotent)", |p| {
            no_param("min_f32", p)?;
            Ok(handle(funcs::MinF32))
        });
        r.register("max_f32", "f32 maximum (idempotent)", |p| {
            no_param("max_f32", p)?;
            Ok(handle(funcs::MaxF32))
        });
        r.register("approx_add_f32", "f32 add dropping updates at :p", |p| {
            let drop_p = parse_or("approx_add_f32", p, 0.1f32, "a drop probability")?;
            if !(0.0..=1.0).contains(&drop_p) {
                return Err(MergeError::BadParam {
                    name: "approx_add_f32".into(),
                    param: p.unwrap_or_default().into(),
                    expected: "a drop probability in [0, 1]",
                });
            }
            Ok(handle(funcs::ApproxAddF32 { drop_p }))
        });
        r
    }

    /// Register a merge-function constructor under a stable name.
    /// The constructor receives the optional `name:param` parameter.
    ///
    /// Panics on a duplicate name — registration is setup-time
    /// configuration, and a silent override would make `--merge`
    /// ambiguous.
    pub fn register<C>(&mut self, name: &str, summary: &str, ctor: C) -> &mut Self
    where
        C: Fn(Option<&str>) -> Result<MergeHandle, MergeError> + Send + Sync + 'static,
    {
        assert!(
            self.lookup(name).is_none(),
            "merge function '{name}' is already registered"
        );
        self.entries.push(MergeSpec {
            name: name.to_string(),
            summary: summary.to_string(),
            ctor: Box::new(ctor),
        });
        self
    }

    /// Resolve a `name` or `name:param` spec string to an instance.
    pub fn build(&self, spec: &str) -> Result<MergeHandle, MergeError> {
        let (name, param) = match spec.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (spec, None),
        };
        let entry = self.lookup(name).ok_or_else(|| MergeError::UnknownMerge {
            name: name.to_string(),
            known: self.names(),
        })?;
        entry.build(param)
    }

    pub fn lookup(&self, name: &str) -> Option<&MergeSpec> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.name.clone()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &MergeSpec> {
        self.entries.iter()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl Default for MergeRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// The registry the CLI and tests use: the nine paper built-ins plus the
/// [`ext`] extension functions (which register through the public API,
/// proving the extension path).
pub fn default_registry() -> MergeRegistry {
    let mut r = MergeRegistry::with_builtins();
    ext::register_extras(&mut r);
    r
}

/// Constructor helper: reject a `name:param` parameter for functions
/// that take none (shared by the built-ins and extension registrations).
pub fn no_param(name: &'static str, p: Option<&str>) -> Result<(), MergeError> {
    match p {
        None => Ok(()),
        Some(p) => Err(MergeError::BadParam {
            name: name.into(),
            param: p.into(),
            expected: "no parameter",
        }),
    }
}

/// Constructor helper: parse an optional `name:param` parameter, falling
/// back to `default` when absent.
pub fn parse_or<T: FromStr + Copy>(
    name: &'static str,
    p: Option<&str>,
    default: T,
    expected: &'static str,
) -> Result<T, MergeError> {
    match p {
        None => Ok(default),
        Some(s) => s.parse().map_err(|_| MergeError::BadParam {
            name: name.into(),
            param: s.into(),
            expected,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::{LineData, MergeFn, LINE_WORDS};

    #[test]
    fn builtins_resolve_by_name() {
        let reg = MergeRegistry::with_builtins();
        for name in [
            "add_u32",
            "add_f32",
            "sat_add_u32",
            "sat_add_f32",
            "cmul_f32",
            "bitor",
            "min_f32",
            "max_f32",
            "approx_add_f32",
        ] {
            let f = reg.build(name).unwrap_or_else(|e| panic!("{e}"));
            assert_eq!(f.name(), name);
        }
        assert_eq!(reg.len(), 9);
    }

    #[test]
    fn default_registry_includes_extension_functions() {
        let reg = default_registry();
        assert!(reg.build("xor_u32").is_ok());
        assert!(reg.build("logsumexp_f32").is_ok());
        assert!(reg.len() > 9);
    }

    #[test]
    fn params_parse_and_default() {
        let reg = MergeRegistry::with_builtins();
        let f = reg.build("sat_add_u32:12").unwrap();
        // clamp at 12: mem 10 + delta 5 -> 12
        let src = [0u32; LINE_WORDS];
        let upd = [5u32; LINE_WORDS];
        let mem = [10u32; LINE_WORDS];
        assert_eq!(f.apply(&src, &upd, &mem, false), [12u32; LINE_WORDS]);
        assert!(reg.build("sat_add_u32").is_ok(), "default param");
        assert!(matches!(
            reg.build("sat_add_u32:notanumber"),
            Err(MergeError::BadParam { .. })
        ));
        assert!(matches!(
            reg.build("add_u32:5"),
            Err(MergeError::BadParam { .. })
        ));
        assert!(matches!(
            reg.build("approx_add_f32:1.5"),
            Err(MergeError::BadParam { .. })
        ));
    }

    #[test]
    fn unknown_name_lists_known() {
        let reg = MergeRegistry::with_builtins();
        let err = reg.build("nope").unwrap_err();
        assert!(matches!(err, MergeError::UnknownMerge { .. }));
        assert!(err.to_string().contains("add_u32"));
    }

    #[test]
    fn user_registration_resolves_like_a_builtin() {
        struct Keep;
        impl MergeFn for Keep {
            fn name(&self) -> &str {
                "keep"
            }
            fn apply(&self, _s: &LineData, _u: &LineData, m: &LineData, _d: bool) -> LineData {
                *m
            }
            fn idempotent(&self) -> bool {
                true
            }
        }
        let mut reg = MergeRegistry::with_builtins();
        reg.register("keep", "discard updates", |_| Ok(handle(Keep)));
        let f = reg.build("keep").unwrap();
        assert!(f.idempotent());
        assert!(reg.names().contains(&"keep".to_string()));
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn duplicate_registration_panics() {
        let mut reg = MergeRegistry::with_builtins();
        reg.register("add_u32", "dup", |_| Ok(handle(funcs::AddU32)));
    }
}
