//! Batched merge execution.
//!
//! The CCache hardware merges line-by-line through the merge registers;
//! in software we batch pending line merges and hand the whole `[B, 16]`
//! tile to one executor call. Two executors implement [`BatchExecutor`]:
//! the native loop (here) and the PJRT/Pallas path
//! (`runtime::merge_exec::PjrtMergeExecutor`). They must agree —
//! integration tests cross-check them.
//!
//! Executors take the merge function as a `&dyn` [`MergeFn`], so batches
//! of user-registered functions run through the same interface as the
//! built-ins; functions without an AOT [`BatchKernel`](super::BatchKernel)
//! execute natively on either path.

use super::{LineData, MergeFn};

/// One pending line merge.
#[derive(Clone, Debug)]
pub struct MergeItem {
    pub src: LineData,
    pub upd: LineData,
    pub mem: LineData,
    /// Approximate kinds: drop this line's update (sampled by the caller).
    pub drop_update: bool,
}

/// Executes a homogeneous batch of line merges, returning the new memory
/// values in order.
pub trait BatchExecutor {
    fn execute(&mut self, f: &dyn MergeFn, items: &[MergeItem]) -> Vec<LineData>;

    /// Executor label for reports.
    fn name(&self) -> &'static str;
}

/// Reference executor: native per-line loop over [`MergeFn::apply`].
#[derive(Default)]
pub struct NativeExecutor;

impl BatchExecutor for NativeExecutor {
    fn execute(&mut self, f: &dyn MergeFn, items: &[MergeItem]) -> Vec<LineData> {
        items
            .iter()
            .map(|it| f.apply(&it.src, &it.upd, &it.mem, it.drop_update))
            .collect()
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::funcs::{line_from_f32, AddU32, ApproxAddF32};
    use crate::merge::LINE_WORDS;

    #[test]
    fn native_executor_matches_apply() {
        let items: Vec<MergeItem> = (0..5)
            .map(|i| MergeItem {
                src: [i as u32; LINE_WORDS],
                upd: [(i + 3) as u32; LINE_WORDS],
                mem: [100; LINE_WORDS],
                drop_update: false,
            })
            .collect();
        let out = NativeExecutor.execute(&AddU32, &items);
        for (i, line) in out.iter().enumerate() {
            assert_eq!(line[0], 103, "item {i}");
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        assert!(NativeExecutor.execute(&AddU32, &[]).is_empty());
    }

    #[test]
    fn approx_batch_respects_per_item_drop() {
        let mk = |drop| MergeItem {
            src: line_from_f32(&[0.0; LINE_WORDS]),
            upd: line_from_f32(&[2.0; LINE_WORDS]),
            mem: line_from_f32(&[1.0; LINE_WORDS]),
            drop_update: drop,
        };
        let out = NativeExecutor.execute(&ApproxAddF32 { drop_p: 0.5 }, &[mk(false), mk(true)]);
        assert_eq!(f32::from_bits(out[0][0]), 3.0);
        assert_eq!(f32::from_bits(out[1][0]), 1.0);
    }
}
