//! The nine built-in merge functions, as [`MergeFn`] implementations.
//!
//! These are the rust mirror of `python/compile/kernels/ref.py`; the PJRT
//! batch path (`runtime::merge_exec`) must agree with them bit-for-bit on
//! integers and to f32 tolerance on floats (covered by integration tests).
//! Each struct registers in [`super::registry::MergeRegistry::with_builtins`]
//! through the same public [`register`](super::registry::MergeRegistry::register)
//! call a user extension would use — there is no privileged dispatch.

use super::{
    bits_f32, f32_bits, BatchKernel, KernelLane, LineData, MergeFn, MergeOperand,
    LINE_WORDS,
};
use crate::util::rng::Rng;

/// Random line of u32 lane values in `[lo, hi)` — shared sampler for
/// law-suite input domains (use from `MergeFn::sample_line` overrides).
pub fn int_line(rng: &mut Rng, lo: u32, hi: u32) -> LineData {
    let mut l = [0u32; LINE_WORDS];
    for w in l.iter_mut() {
        *w = lo + rng.next_u32() % (hi - lo);
    }
    l
}

/// Random line of f32 lane values in `[lo, hi)` — shared sampler for
/// law-suite input domains (use from `MergeFn::sample_line` overrides).
pub fn f32_line(rng: &mut Rng, lo: f32, hi: f32) -> LineData {
    let mut l = [0u32; LINE_WORDS];
    for w in l.iter_mut() {
        *w = rng.f32_range(lo, hi).to_bits();
    }
    l
}

/// `mem += upd - src` over u32 lanes (wrapping) — the key-value store.
pub struct AddU32;

impl MergeFn for AddU32 {
    fn name(&self) -> &str {
        "add_u32"
    }

    fn apply(&self, src: &LineData, upd: &LineData, mem: &LineData, _drop: bool) -> LineData {
        let mut out = *mem;
        for i in 0..LINE_WORDS {
            out[i] = mem[i].wrapping_add(upd[i].wrapping_sub(src[i]));
        }
        out
    }

    fn batch_kernel(&self) -> Option<BatchKernel> {
        Some(BatchKernel::new("merge_add", KernelLane::U32AsF32))
    }
}

/// `mem += upd - src` over f32 lanes — K-Means, PageRank.
pub struct AddF32;

impl MergeFn for AddF32 {
    fn name(&self) -> &str {
        "add_f32"
    }

    fn apply(&self, src: &LineData, upd: &LineData, mem: &LineData, _drop: bool) -> LineData {
        let mut out = *mem;
        for i in 0..LINE_WORDS {
            out[i] = f32_bits(bits_f32(mem[i]) + (bits_f32(upd[i]) - bits_f32(src[i])));
        }
        out
    }

    fn batch_kernel(&self) -> Option<BatchKernel> {
        Some(BatchKernel::new("merge_add", KernelLane::F32))
    }

    fn law_tolerance(&self) -> f32 {
        1e-3
    }
}

/// Additive with saturation at `max` (u32 lanes). The clamp observes the
/// merged *memory* value (Section 4.5). Commutative for non-negative
/// deltas (counts), which is its contract.
pub struct SatAddU32 {
    pub max: u32,
}

impl MergeFn for SatAddU32 {
    fn name(&self) -> &str {
        "sat_add_u32"
    }

    fn apply(&self, src: &LineData, upd: &LineData, mem: &LineData, _drop: bool) -> LineData {
        let mut out = *mem;
        for i in 0..LINE_WORDS {
            let delta = upd[i].wrapping_sub(src[i]);
            out[i] = mem[i].saturating_add(delta).min(self.max);
        }
        out
    }

    fn batch_kernel(&self) -> Option<BatchKernel> {
        Some(BatchKernel::new("merge_sat", KernelLane::U32AsF32).with_scalar(self.max as f32))
    }

    fn sample_line(&self, rng: &mut Rng, role: MergeOperand) -> LineData {
        // commutativity holds for non-negative deltas: draw upd >= src
        match role {
            MergeOperand::Src => int_line(rng, 0, 1_000),
            MergeOperand::Upd => int_line(rng, 1_000, 1_000_000),
            MergeOperand::Mem => int_line(rng, 0, 1_000_000),
        }
    }
}

/// Additive with saturation at `max` (f32 lanes).
pub struct SatAddF32 {
    pub max: f32,
}

impl MergeFn for SatAddF32 {
    fn name(&self) -> &str {
        "sat_add_f32"
    }

    fn apply(&self, src: &LineData, upd: &LineData, mem: &LineData, _drop: bool) -> LineData {
        let mut out = *mem;
        for i in 0..LINE_WORDS {
            let v = bits_f32(mem[i]) + (bits_f32(upd[i]) - bits_f32(src[i]));
            out[i] = f32_bits(v.min(self.max));
        }
        out
    }

    fn batch_kernel(&self) -> Option<BatchKernel> {
        Some(BatchKernel::new("merge_sat", KernelLane::F32).with_scalar(self.max))
    }

    fn sample_line(&self, rng: &mut Rng, role: MergeOperand) -> LineData {
        match role {
            MergeOperand::Src => f32_line(rng, 0.0, 10.0),
            MergeOperand::Upd => f32_line(rng, 10.0, 100.0),
            MergeOperand::Mem => f32_line(rng, 0.0, 100.0),
        }
    }

    fn law_tolerance(&self) -> f32 {
        1e-3
    }
}

/// Complex multiply: lanes are 8 interleaved (re, im) f32 pairs;
/// `mem *= upd / src`. A zero source (|src|² == 0) would make the
/// factor undefined — the update is skipped for that pair instead of
/// poisoning memory with NaN.
pub struct CmulF32;

impl MergeFn for CmulF32 {
    fn name(&self) -> &str {
        "cmul_f32"
    }

    fn apply(&self, src: &LineData, upd: &LineData, mem: &LineData, _drop: bool) -> LineData {
        let mut out = *mem;
        for p in 0..LINE_WORDS / 2 {
            let (sr, si) = (bits_f32(src[2 * p]), bits_f32(src[2 * p + 1]));
            let (ur, ui) = (bits_f32(upd[2 * p]), bits_f32(upd[2 * p + 1]));
            let (mr, mi) = (bits_f32(mem[2 * p]), bits_f32(mem[2 * p + 1]));
            let den = sr * sr + si * si;
            // zero-denominator hazard: upd/src is undefined for src == 0;
            // apply the identity factor (drop this pair's update)
            let (fr, fi) = if den == 0.0 {
                (1.0, 0.0)
            } else {
                ((ur * sr + ui * si) / den, (ui * sr - ur * si) / den)
            };
            out[2 * p] = f32_bits(mr * fr - mi * fi);
            out[2 * p + 1] = f32_bits(mr * fi + mi * fr);
        }
        out
    }

    fn batch_kernel(&self) -> Option<BatchKernel> {
        Some(BatchKernel::new("merge_cmul", KernelLane::F32))
    }

    fn sample_line(&self, rng: &mut Rng, role: MergeOperand) -> LineData {
        match role {
            // source values away from zero keep the factor well-defined
            MergeOperand::Src | MergeOperand::Upd => f32_line(rng, 1.0, 4.0),
            MergeOperand::Mem => f32_line(rng, -4.0, 4.0),
        }
    }

    fn law_tolerance(&self) -> f32 {
        1e-3
    }
}

/// `mem |= upd` — BFS bitmaps. Idempotent.
pub struct BitOr;

impl MergeFn for BitOr {
    fn name(&self) -> &str {
        "bitor"
    }

    fn apply(&self, _src: &LineData, upd: &LineData, mem: &LineData, _drop: bool) -> LineData {
        let mut out = *mem;
        for i in 0..LINE_WORDS {
            out[i] = mem[i] | upd[i];
        }
        out
    }

    fn idempotent(&self) -> bool {
        true
    }

    fn batch_kernel(&self) -> Option<BatchKernel> {
        Some(BatchKernel::new("merge_bitor", KernelLane::I32))
    }
}

/// `mem = min(mem, upd)` over f32 lanes. Idempotent.
pub struct MinF32;

impl MergeFn for MinF32 {
    fn name(&self) -> &str {
        "min_f32"
    }

    fn apply(&self, _src: &LineData, upd: &LineData, mem: &LineData, _drop: bool) -> LineData {
        let mut out = *mem;
        for i in 0..LINE_WORDS {
            out[i] = f32_bits(bits_f32(mem[i]).min(bits_f32(upd[i])));
        }
        out
    }

    fn idempotent(&self) -> bool {
        true
    }

    fn batch_kernel(&self) -> Option<BatchKernel> {
        Some(BatchKernel::new("merge_min", KernelLane::F32))
    }
}

/// `mem = max(mem, upd)` over f32 lanes. Idempotent.
pub struct MaxF32;

impl MergeFn for MaxF32 {
    fn name(&self) -> &str {
        "max_f32"
    }

    fn apply(&self, _src: &LineData, upd: &LineData, mem: &LineData, _drop: bool) -> LineData {
        let mut out = *mem;
        for i in 0..LINE_WORDS {
            out[i] = f32_bits(bits_f32(mem[i]).max(bits_f32(upd[i])));
        }
        out
    }

    fn idempotent(&self) -> bool {
        true
    }

    fn batch_kernel(&self) -> Option<BatchKernel> {
        Some(BatchKernel::new("merge_max", KernelLane::F32))
    }
}

/// Additive over f32 lanes, but each line's update is dropped with
/// probability `drop_p` (loop-perforation-style approximate merge,
/// Section 6.3). The drop decision comes from the caller-provided
/// decision value so both execution paths agree.
pub struct ApproxAddF32 {
    pub drop_p: f32,
}

impl MergeFn for ApproxAddF32 {
    fn name(&self) -> &str {
        "approx_add_f32"
    }

    fn apply(&self, src: &LineData, upd: &LineData, mem: &LineData, drop: bool) -> LineData {
        if drop {
            return *mem;
        }
        let mut out = *mem;
        for i in 0..LINE_WORDS {
            out[i] = f32_bits(bits_f32(mem[i]) + (bits_f32(upd[i]) - bits_f32(src[i])));
        }
        out
    }

    fn drop_probability(&self) -> f32 {
        self.drop_p
    }

    fn batch_kernel(&self) -> Option<BatchKernel> {
        Some(BatchKernel::new("merge_approx", KernelLane::F32).with_keep_mask())
    }

    fn law_tolerance(&self) -> f32 {
        1e-3
    }
}

/// Convenience: line of f32 values.
pub fn line_from_f32(vals: &[f32; LINE_WORDS]) -> LineData {
    let mut out = [0u32; LINE_WORDS];
    for i in 0..LINE_WORDS {
        out[i] = f32_bits(vals[i]);
    }
    out
}

pub fn line_to_f32(line: &LineData) -> [f32; LINE_WORDS] {
    let mut out = [0f32; LINE_WORDS];
    for i in 0..LINE_WORDS {
        out[i] = bits_f32(line[i]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_line(rng: &mut Rng) -> LineData {
        let mut l = [0u32; LINE_WORDS];
        for w in l.iter_mut() {
            *w = rng.next_u32();
        }
        l
    }

    fn rand_f32_line(rng: &mut Rng, lo: f32, hi: f32) -> LineData {
        let mut l = [0f32; LINE_WORDS];
        for w in l.iter_mut() {
            *w = rng.f32_range(lo, hi);
        }
        line_from_f32(&l)
    }

    #[test]
    fn add_u32_applies_delta() {
        let src = [10u32; LINE_WORDS];
        let upd = [17u32; LINE_WORDS];
        let mem = [100u32; LINE_WORDS];
        let out = AddU32.apply(&src, &upd, &mem, false);
        assert_eq!(out, [107u32; LINE_WORDS]);
    }

    #[test]
    fn add_u32_two_merges_commute() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let mem0 = rand_line(&mut rng);
            let src = rand_line(&mut rng);
            let (a, b) = (rand_line(&mut rng), rand_line(&mut rng));
            let ab = AddU32.apply(&src, &b, &AddU32.apply(&src, &a, &mem0, false), false);
            let ba = AddU32.apply(&src, &a, &AddU32.apply(&src, &b, &mem0, false), false);
            assert_eq!(ab, ba);
        }
    }

    #[test]
    fn sat_add_clamps_at_max() {
        let src = [0u32; LINE_WORDS];
        let upd = [50u32; LINE_WORDS];
        let mem = [80u32; LINE_WORDS];
        let out = SatAddU32 { max: 100 }.apply(&src, &upd, &mem, false);
        assert_eq!(out, [100u32; LINE_WORDS]);
    }

    #[test]
    fn sat_add_observes_memory_not_update() {
        // memory already at max; positive delta must not push past it
        let src = [0u32; LINE_WORDS];
        let upd = [5u32; LINE_WORDS];
        let mem = [100u32; LINE_WORDS];
        let out = SatAddU32 { max: 100 }.apply(&src, &upd, &mem, false);
        assert_eq!(out, [100u32; LINE_WORDS]);
    }

    #[test]
    fn bitor_merges_bits_idempotently() {
        let src = [0u32; LINE_WORDS];
        let upd = [0b1010u32; LINE_WORDS];
        let mem = [0b0101u32; LINE_WORDS];
        let once = BitOr.apply(&src, &upd, &mem, false);
        assert_eq!(once, [0b1111u32; LINE_WORDS]);
        let twice = BitOr.apply(&src, &upd, &once, false);
        assert_eq!(twice, once);
    }

    #[test]
    fn cmul_applies_multiplicative_factor() {
        // src = 1+0i, upd = 2+0i (factor 2), mem = 3+4i -> 6+8i
        let mut src = [0f32; LINE_WORDS];
        let mut upd = [0f32; LINE_WORDS];
        let mut mem = [0f32; LINE_WORDS];
        for p in 0..LINE_WORDS / 2 {
            src[2 * p] = 1.0;
            upd[2 * p] = 2.0;
            mem[2 * p] = 3.0;
            mem[2 * p + 1] = 4.0;
        }
        let out = CmulF32.apply(
            &line_from_f32(&src),
            &line_from_f32(&upd),
            &line_from_f32(&mem),
            false,
        );
        let o = line_to_f32(&out);
        for p in 0..LINE_WORDS / 2 {
            assert!((o[2 * p] - 6.0).abs() < 1e-5);
            assert!((o[2 * p + 1] - 8.0).abs() < 1e-5);
        }
    }

    #[test]
    fn cmul_zero_source_keeps_memory_finite() {
        // regression: src = 0+0i used to divide by zero and poison the
        // whole line with NaN; the guard skips the undefined update
        let src = line_from_f32(&[0f32; LINE_WORDS]);
        let upd = rand_f32_line(&mut Rng::new(9), 1.0, 4.0);
        let mut mem = [0f32; LINE_WORDS];
        for p in 0..LINE_WORDS / 2 {
            mem[2 * p] = 3.0;
            mem[2 * p + 1] = -2.0;
        }
        let mem = line_from_f32(&mem);
        let out = CmulF32.apply(&src, &upd, &mem, false);
        assert_eq!(out, mem, "zero source must leave memory unchanged");
        let o = line_to_f32(&out);
        assert!(o.iter().all(|v| v.is_finite()), "NaN leaked: {o:?}");
    }

    #[test]
    fn cmul_merges_commute() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let mem0 = rand_f32_line(&mut rng, -4.0, 4.0);
            let src = rand_f32_line(&mut rng, 1.0, 4.0); // away from zero
            let a = rand_f32_line(&mut rng, 1.0, 4.0);
            let b = rand_f32_line(&mut rng, 1.0, 4.0);
            let ab = CmulF32.apply(&src, &b, &CmulF32.apply(&src, &a, &mem0, false), false);
            let ba = CmulF32.apply(&src, &a, &CmulF32.apply(&src, &b, &mem0, false), false);
            let (fab, fba) = (line_to_f32(&ab), line_to_f32(&ba));
            for i in 0..LINE_WORDS {
                assert!(
                    (fab[i] - fba[i]).abs() <= 1e-3 * (1.0 + fab[i].abs()),
                    "{} vs {}",
                    fab[i],
                    fba[i]
                );
            }
        }
    }

    #[test]
    fn min_max_idempotent() {
        let mut rng = Rng::new(5);
        let src = rand_f32_line(&mut rng, -10.0, 10.0);
        let upd = rand_f32_line(&mut rng, -10.0, 10.0);
        let mem = rand_f32_line(&mut rng, -10.0, 10.0);
        let fns: [&dyn MergeFn; 2] = [&MinF32, &MaxF32];
        for f in fns {
            let once = f.apply(&src, &upd, &mem, false);
            let twice = f.apply(&src, &upd, &once, false);
            assert_eq!(once, twice);
            assert!(f.idempotent());
        }
    }

    #[test]
    fn approx_drops_update_when_told() {
        let src = line_from_f32(&[0f32; LINE_WORDS]);
        let upd = line_from_f32(&[5f32; LINE_WORDS]);
        let mem = line_from_f32(&[1f32; LINE_WORDS]);
        let f = ApproxAddF32 { drop_p: 0.5 };
        assert_eq!(f.apply(&src, &upd, &mem, true), mem);
        let kept = f.apply(&src, &upd, &mem, false);
        assert_eq!(line_to_f32(&kept)[0], 6.0);
        assert_eq!(f.drop_probability(), 0.5);
    }

    #[test]
    fn f32_add_matches_scalar_math() {
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let src = rand_f32_line(&mut rng, -100.0, 100.0);
            let upd = rand_f32_line(&mut rng, -100.0, 100.0);
            let mem = rand_f32_line(&mut rng, -100.0, 100.0);
            let out = AddF32.apply(&src, &upd, &mem, false);
            let (s, u, m, o) = (
                line_to_f32(&src),
                line_to_f32(&upd),
                line_to_f32(&mem),
                line_to_f32(&out),
            );
            for i in 0..LINE_WORDS {
                assert_eq!(o[i], m[i] + (u[i] - s[i]));
            }
        }
    }

    #[test]
    fn kernel_descriptors_name_the_aot_entries() {
        assert_eq!(AddU32.batch_kernel().unwrap().entry, "merge_add");
        assert_eq!(AddU32.batch_kernel().unwrap().lane, KernelLane::U32AsF32);
        assert_eq!(
            SatAddF32 { max: 9.0 }.batch_kernel().unwrap().scalar,
            Some(9.0)
        );
        assert!(ApproxAddF32 { drop_p: 0.1 }.batch_kernel().unwrap().keep_mask);
        assert_eq!(BitOr.batch_kernel().unwrap().lane, KernelLane::I32);
    }
}
