//! Native reference implementations of the merge functions.
//!
//! These are the rust mirror of `python/compile/kernels/ref.py`; the PJRT
//! batch path (`runtime::merge_exec`) must agree with them bit-for-bit on
//! integers and to f32 tolerance on floats (covered by integration tests).

use super::{bits_f32, f32_bits, LineData, MergeKind, LINE_WORDS};

/// Apply `kind` to one line: returns the new memory value.
///
/// `drop_update` is consulted only by approximate kinds: when true the
/// line's update is discarded (the caller samples the binomial, keeping
/// the native and PJRT paths in agreement).
pub fn apply_line(
    kind: MergeKind,
    src: &LineData,
    upd: &LineData,
    mem: &LineData,
    drop_update: bool,
) -> LineData {
    let mut out = *mem;
    match kind {
        MergeKind::AddU32 => {
            for i in 0..LINE_WORDS {
                out[i] = mem[i]
                    .wrapping_add(upd[i].wrapping_sub(src[i]));
            }
        }
        MergeKind::AddF32 => {
            for i in 0..LINE_WORDS {
                out[i] = f32_bits(
                    bits_f32(mem[i]) + (bits_f32(upd[i]) - bits_f32(src[i])),
                );
            }
        }
        MergeKind::SatAddU32 { max } => {
            for i in 0..LINE_WORDS {
                let delta = upd[i].wrapping_sub(src[i]);
                out[i] = mem[i].saturating_add(delta).min(max);
            }
        }
        MergeKind::SatAddF32 { max } => {
            for i in 0..LINE_WORDS {
                let v = bits_f32(mem[i]) + (bits_f32(upd[i]) - bits_f32(src[i]));
                out[i] = f32_bits(v.min(max));
            }
        }
        MergeKind::CmulF32 => {
            for p in 0..LINE_WORDS / 2 {
                let (sr, si) = (bits_f32(src[2 * p]), bits_f32(src[2 * p + 1]));
                let (ur, ui) = (bits_f32(upd[2 * p]), bits_f32(upd[2 * p + 1]));
                let (mr, mi) = (bits_f32(mem[2 * p]), bits_f32(mem[2 * p + 1]));
                let den = sr * sr + si * si;
                let fr = (ur * sr + ui * si) / den;
                let fi = (ui * sr - ur * si) / den;
                out[2 * p] = f32_bits(mr * fr - mi * fi);
                out[2 * p + 1] = f32_bits(mr * fi + mi * fr);
            }
        }
        MergeKind::BitOr => {
            for i in 0..LINE_WORDS {
                out[i] = mem[i] | upd[i];
            }
        }
        MergeKind::MinF32 => {
            for i in 0..LINE_WORDS {
                out[i] = f32_bits(bits_f32(mem[i]).min(bits_f32(upd[i])));
            }
        }
        MergeKind::MaxF32 => {
            for i in 0..LINE_WORDS {
                out[i] = f32_bits(bits_f32(mem[i]).max(bits_f32(upd[i])));
            }
        }
        MergeKind::ApproxAddF32 { .. } => {
            if !drop_update {
                for i in 0..LINE_WORDS {
                    out[i] = f32_bits(
                        bits_f32(mem[i]) + (bits_f32(upd[i]) - bits_f32(src[i])),
                    );
                }
            }
        }
    }
    out
}

/// Convenience: line of f32 values.
pub fn line_from_f32(vals: &[f32; LINE_WORDS]) -> LineData {
    let mut out = [0u32; LINE_WORDS];
    for i in 0..LINE_WORDS {
        out[i] = f32_bits(vals[i]);
    }
    out
}

pub fn line_to_f32(line: &LineData) -> [f32; LINE_WORDS] {
    let mut out = [0f32; LINE_WORDS];
    for i in 0..LINE_WORDS {
        out[i] = bits_f32(line[i]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_line(rng: &mut Rng) -> LineData {
        let mut l = [0u32; LINE_WORDS];
        for w in l.iter_mut() {
            *w = rng.next_u32();
        }
        l
    }

    fn rand_f32_line(rng: &mut Rng, lo: f32, hi: f32) -> LineData {
        let mut l = [0f32; LINE_WORDS];
        for w in l.iter_mut() {
            *w = rng.f32_range(lo, hi);
        }
        line_from_f32(&l)
    }

    #[test]
    fn add_u32_applies_delta() {
        let src = [10u32; LINE_WORDS];
        let upd = [17u32; LINE_WORDS];
        let mem = [100u32; LINE_WORDS];
        let out = apply_line(MergeKind::AddU32, &src, &upd, &mem, false);
        assert_eq!(out, [107u32; LINE_WORDS]);
    }

    #[test]
    fn add_u32_two_merges_commute() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let mem0 = rand_line(&mut rng);
            let src = rand_line(&mut rng);
            let (a, b) = (rand_line(&mut rng), rand_line(&mut rng));
            let ab = apply_line(
                MergeKind::AddU32,
                &src,
                &b,
                &apply_line(MergeKind::AddU32, &src, &a, &mem0, false),
                false,
            );
            let ba = apply_line(
                MergeKind::AddU32,
                &src,
                &a,
                &apply_line(MergeKind::AddU32, &src, &b, &mem0, false),
                false,
            );
            assert_eq!(ab, ba);
        }
    }

    #[test]
    fn sat_add_clamps_at_max() {
        let src = [0u32; LINE_WORDS];
        let upd = [50u32; LINE_WORDS];
        let mem = [80u32; LINE_WORDS];
        let out = apply_line(MergeKind::SatAddU32 { max: 100 }, &src, &upd, &mem, false);
        assert_eq!(out, [100u32; LINE_WORDS]);
    }

    #[test]
    fn sat_add_observes_memory_not_update() {
        // memory already at max; positive delta must not push past it
        let src = [0u32; LINE_WORDS];
        let upd = [5u32; LINE_WORDS];
        let mem = [100u32; LINE_WORDS];
        let out = apply_line(MergeKind::SatAddU32 { max: 100 }, &src, &upd, &mem, false);
        assert_eq!(out, [100u32; LINE_WORDS]);
    }

    #[test]
    fn bitor_merges_bits_idempotently() {
        let src = [0u32; LINE_WORDS];
        let upd = [0b1010u32; LINE_WORDS];
        let mem = [0b0101u32; LINE_WORDS];
        let once = apply_line(MergeKind::BitOr, &src, &upd, &mem, false);
        assert_eq!(once, [0b1111u32; LINE_WORDS]);
        let twice = apply_line(MergeKind::BitOr, &src, &upd, &once, false);
        assert_eq!(twice, once);
    }

    #[test]
    fn cmul_applies_multiplicative_factor() {
        // src = 1+0i, upd = 2+0i (factor 2), mem = 3+4i -> 6+8i
        let mut src = [0f32; LINE_WORDS];
        let mut upd = [0f32; LINE_WORDS];
        let mut mem = [0f32; LINE_WORDS];
        for p in 0..LINE_WORDS / 2 {
            src[2 * p] = 1.0;
            upd[2 * p] = 2.0;
            mem[2 * p] = 3.0;
            mem[2 * p + 1] = 4.0;
        }
        let out = apply_line(
            MergeKind::CmulF32,
            &line_from_f32(&src),
            &line_from_f32(&upd),
            &line_from_f32(&mem),
            false,
        );
        let o = line_to_f32(&out);
        for p in 0..LINE_WORDS / 2 {
            assert!((o[2 * p] - 6.0).abs() < 1e-5);
            assert!((o[2 * p + 1] - 8.0).abs() < 1e-5);
        }
    }

    #[test]
    fn cmul_merges_commute() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let mem0 = rand_f32_line(&mut rng, -4.0, 4.0);
            let src = rand_f32_line(&mut rng, 1.0, 4.0); // away from zero
            let a = rand_f32_line(&mut rng, 1.0, 4.0);
            let b = rand_f32_line(&mut rng, 1.0, 4.0);
            let ab = apply_line(
                MergeKind::CmulF32,
                &src,
                &b,
                &apply_line(MergeKind::CmulF32, &src, &a, &mem0, false),
                false,
            );
            let ba = apply_line(
                MergeKind::CmulF32,
                &src,
                &a,
                &apply_line(MergeKind::CmulF32, &src, &b, &mem0, false),
                false,
            );
            let (fab, fba) = (line_to_f32(&ab), line_to_f32(&ba));
            for i in 0..LINE_WORDS {
                assert!(
                    (fab[i] - fba[i]).abs() <= 1e-3 * (1.0 + fab[i].abs()),
                    "{} vs {}",
                    fab[i],
                    fba[i]
                );
            }
        }
    }

    #[test]
    fn min_max_idempotent() {
        let mut rng = Rng::new(5);
        let src = rand_f32_line(&mut rng, -10.0, 10.0);
        let upd = rand_f32_line(&mut rng, -10.0, 10.0);
        let mem = rand_f32_line(&mut rng, -10.0, 10.0);
        for kind in [MergeKind::MinF32, MergeKind::MaxF32] {
            let once = apply_line(kind, &src, &upd, &mem, false);
            let twice = apply_line(kind, &src, &upd, &once, false);
            assert_eq!(once, twice);
        }
    }

    #[test]
    fn approx_drops_update_when_told() {
        let src = line_from_f32(&[0f32; LINE_WORDS]);
        let upd = line_from_f32(&[5f32; LINE_WORDS]);
        let mem = line_from_f32(&[1f32; LINE_WORDS]);
        let kind = MergeKind::ApproxAddF32 { drop_p: 0.5 };
        assert_eq!(apply_line(kind, &src, &upd, &mem, true), mem);
        let kept = apply_line(kind, &src, &upd, &mem, false);
        assert_eq!(line_to_f32(&kept)[0], 6.0);
    }

    #[test]
    fn f32_add_matches_scalar_math() {
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let src = rand_f32_line(&mut rng, -100.0, 100.0);
            let upd = rand_f32_line(&mut rng, -100.0, 100.0);
            let mem = rand_f32_line(&mut rng, -100.0, 100.0);
            let out = apply_line(MergeKind::AddF32, &src, &upd, &mem, false);
            let (s, u, m, o) = (
                line_to_f32(&src),
                line_to_f32(&upd),
                line_to_f32(&mem),
                line_to_f32(&out),
            );
            for i in 0..LINE_WORDS {
                assert_eq!(o[i], m[i] + (u[i] - s[i]));
            }
        }
    }
}
