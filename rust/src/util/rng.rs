//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 for seeding, xoshiro256** for the stream — both standard,
//! tiny, and reproducible across platforms. A Zipf sampler covers skewed
//! key-value workloads; the paper's KV store uses uniform keys, but the
//! skewed variant is exercised by the ablation benches.

/// SplitMix64: used to expand a single `u64` seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (Lemire's method, bias-free enough for sims).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply trick
        let x = self.next_u64();
        ((x as u128 * bound as u128) >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value; the pair's twin is
    /// discarded for simplicity — this is test-data generation).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Zipf(θ) sampler over `[0, n)` using the rejection-inversion method of
/// Hörmann & Derflinger — O(1) per sample, no table.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: f64,
    theta: f64,
    h_x1: f64,
    h_n: f64,
    s: f64,
}

impl Zipf {
    pub fn new(n: usize, theta: f64) -> Self {
        assert!(n >= 1);
        assert!(theta > 0.0 && theta != 1.0, "theta=1 unsupported; use 0.99");
        let h = |x: f64| -> f64 { (x.powf(1.0 - theta) - 1.0) / (1.0 - theta) };
        let h_inv =
            |x: f64| -> f64 { (1.0 + x * (1.0 - theta)).powf(1.0 / (1.0 - theta)) };
        Self {
            n: n as f64,
            theta,
            h_x1: h(1.5) - 1.0,
            h_n: h(n as f64 + 0.5),
            s: 2.0 - h_inv(h(2.5) - 2f64.powf(-theta)),
        }
    }

    fn h(&self, x: f64) -> f64 {
        (x.powf(1.0 - self.theta) - 1.0) / (1.0 - self.theta)
    }

    fn h_inv(&self, x: f64) -> f64 {
        (1.0 + x * (1.0 - self.theta)).powf(1.0 / (1.0 - self.theta))
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        loop {
            let u = self.h_x1 + rng.f64() * (self.h_n - self.h_x1);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().max(1.0);
            if k - x <= self.s || u >= self.h(k + 0.5) - k.powf(-self.theta) {
                let idx = k as usize - 1;
                if idx < self.n as usize {
                    return idx;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 17, 1 << 40] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_mean_and_var_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_toward_low_ranks() {
        let z = Zipf::new(1000, 0.99);
        let mut r = Rng::new(5);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // rank 0 must dominate rank 100 under heavy skew
        assert!(counts[0] > counts[100] * 5, "{} vs {}", counts[0], counts[100]);
        // all samples in range (sample() guarantees this by construction,
        // but the counting above would have panicked otherwise)
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
