//! In-house utilities: deterministic RNG, key-distribution samplers, a tiny
//! CLI argument parser, a bench harness (timing + paper-style tables) and a
//! minimal property-test driver. All of these exist in-crate because the
//! offline environment only vendors the `xla` dependency closure.

pub mod bench;
pub mod cli;
pub mod ptest;
pub mod rng;
