//! Minimal property-test driver (proptest is unavailable offline).
//!
//! `check(cases, gen, prop)` runs `prop` on `cases` generated inputs and,
//! on failure, performs a bounded greedy shrink via the input's
//! [`Shrink`] implementation before panicking with the minimal
//! counterexample.
//!
//! [`check_merge_laws`] is the auto-generated suite over a
//! [`MergeRegistry`]: every registered merge function — built-in or
//! user-registered — is checked against the paper's Section 3
//! commutativity condition (and idempotence, where declared), so new
//! registrations are law-checked for free.

use crate::merge::{MergeFn, MergeOperand, MergeRegistry, LINE_WORDS};
use crate::util::rng::Rng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate shrinks, roughly ordered smallest-first.
    fn shrinks(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for u64 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        (*self as u64).shrinks().into_iter().map(|v| v as usize).collect()
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // halves
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // drop one element
        if self.len() <= 16 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
        }
        // shrink one element
        if let Some(first) = self.first() {
            for s in first.shrinks() {
                let mut v = self.clone();
                v[0] = s;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Run `prop` on `cases` inputs drawn via `gen`; shrink on failure.
///
/// The RNG seed is fixed (per-callsite via `seed`) so failures reproduce.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> PropResult,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &mut prop);
            panic!(
                "property failed (case {case}/{cases}, seed {seed}):\n  input: {min_input:?}\n  error: {min_msg}"
            );
        }
    }
}

/// Differential property check: run each generated input through two
/// executions (`run_a`, `run_b`) and require equal results, shrinking a
/// divergence like any other property failure. The workhorse behind
/// `tests/fastpath_diff.rs`, where A and B are the engine with the fast
/// path on vs off and `R` bundles stats + final memory.
pub fn check_diff<T, R, G, A, B>(seed: u64, cases: usize, gen: G, mut run_a: A, mut run_b: B)
where
    T: Shrink,
    R: PartialEq + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    A: FnMut(&T) -> R,
    B: FnMut(&T) -> R,
{
    check(seed, cases, gen, move |input| {
        let a = run_a(input);
        let b = run_b(input);
        if a == b {
            Ok(())
        } else {
            Err(format!("engines diverged:\n  A: {a:?}\n  B: {b:?}"))
        }
    });
}

fn shrink_loop<T: Shrink, P: FnMut(&T) -> PropResult>(
    mut input: T,
    mut msg: String,
    prop: &mut P,
) -> (T, String) {
    // bounded greedy descent
    for _ in 0..200 {
        let mut improved = false;
        for cand in input.shrinks() {
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                improved = true;
                break;
            }
        }
        if !improved {
            break;
        }
    }
    (input, msg)
}

// ---------------------------------------------------------------------
// merge-function law suite
// ---------------------------------------------------------------------

/// Compare two lines lane-by-lane: bit equality when `tol == 0.0`,
/// otherwise relative f32 tolerance.
fn lanes_match(a: &[u32; LINE_WORDS], b: &[u32; LINE_WORDS], tol: f32) -> Result<(), String> {
    for i in 0..LINE_WORDS {
        let ok = if tol == 0.0 {
            a[i] == b[i]
        } else {
            let (x, y) = (f32::from_bits(a[i]), f32::from_bits(b[i]));
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs()))
        };
        if !ok {
            return Err(format!(
                "lane {i}: {} vs {} (bits {:#x} vs {:#x})",
                f32::from_bits(a[i]),
                f32::from_bits(b[i]),
                a[i],
                b[i]
            ));
        }
    }
    Ok(())
}

/// Check one merge function's algebraic laws on `cases` random inputs
/// drawn from its own [`MergeFn::sample_line`] domain:
/// * **commutativity** — two updates applied in either order produce
///   the same memory value (to [`MergeFn::law_tolerance`]);
/// * **idempotence** — when declared, re-merging the same updated copy
///   is a no-op.
pub fn check_merge_fn_laws(f: &dyn MergeFn, seed: u64, cases: usize) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let src = f.sample_line(&mut rng, MergeOperand::Src);
        let a = f.sample_line(&mut rng, MergeOperand::Upd);
        let b = f.sample_line(&mut rng, MergeOperand::Upd);
        let mem = f.sample_line(&mut rng, MergeOperand::Mem);
        let tol = f.law_tolerance();

        let ab = f.apply(&src, &b, &f.apply(&src, &a, &mem, false), false);
        let ba = f.apply(&src, &a, &f.apply(&src, &b, &mem, false), false);
        if let Err(msg) = lanes_match(&ab, &ba, tol) {
            panic!(
                "merge function '{}' is not commutative (case {case}/{cases}, seed {seed}): {msg}",
                f.name()
            );
        }

        if f.idempotent() {
            let once = f.apply(&src, &a, &mem, false);
            let twice = f.apply(&src, &a, &once, false);
            if let Err(msg) = lanes_match(&once, &twice, tol) {
                panic!(
                    "merge function '{}' declares idempotence but re-merging changed memory \
                     (case {case}/{cases}, seed {seed}): {msg}",
                    f.name()
                );
            }
        }
    }
}

/// Run [`check_merge_fn_laws`] over *every* function in `reg` (built
/// with default parameters). Registering a function is all it takes to
/// be law-checked.
pub fn check_merge_laws(reg: &MergeRegistry, seed: u64, cases: usize) {
    assert!(!reg.is_empty(), "empty merge registry");
    for spec in reg.iter() {
        let f = spec
            .build(None)
            .unwrap_or_else(|e| panic!("'{}': default construction failed: {e}", spec.name));
        check_merge_fn_laws(f.as_ref(), seed, cases);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 100, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            check(
                2,
                100,
                |r| r.below(1000),
                |&x| {
                    if x < 50 {
                        Ok(())
                    } else {
                        Err(format!("{x} >= 50"))
                    }
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // greedy halving from any failing x >= 50 lands on exactly 50
        assert!(msg.contains("input: 50"), "got: {msg}");
    }

    #[test]
    fn check_diff_passes_on_identical_executions() {
        check_diff(3, 50, |r| r.below(1000), |&x| x * 2, |&x| x + x);
    }

    #[test]
    fn check_diff_reports_a_divergence() {
        let result = std::panic::catch_unwind(|| {
            check_diff(
                4,
                50,
                |r| r.below(1000),
                |&x| x,
                |&x| if x >= 100 { x + 1 } else { x },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("engines diverged"), "got: {msg}");
        // shrink lands on the smallest diverging input
        assert!(msg.contains("input: 100"), "got: {msg}");
    }

    #[test]
    fn vec_shrinks_reduce_length_or_elements() {
        let v = vec![5u64, 6, 7];
        let shrinks = v.shrinks();
        assert!(shrinks.iter().any(|s| s.len() < 3));
        assert!(shrinks.iter().any(|s| s.len() == 3 && s[0] < 5));
    }

    #[test]
    fn law_suite_passes_on_builtins() {
        check_merge_laws(&MergeRegistry::with_builtins(), 0xA1, 25);
    }

    #[test]
    #[should_panic(expected = "not commutative")]
    fn law_suite_catches_a_non_commutative_function() {
        use crate::merge::LineData;
        // overwrite-with-update is order-dependent: the suite must flag it
        struct Overwrite;
        impl MergeFn for Overwrite {
            fn name(&self) -> &str {
                "overwrite"
            }
            fn apply(&self, _s: &LineData, u: &LineData, _m: &LineData, _d: bool) -> LineData {
                *u
            }
        }
        check_merge_fn_laws(&Overwrite, 0xBAD, 25);
    }

    #[test]
    #[should_panic(expected = "declares idempotence")]
    fn law_suite_catches_a_false_idempotence_claim() {
        use crate::merge::LineData;
        struct BadClaim;
        impl MergeFn for BadClaim {
            fn name(&self) -> &str {
                "bad_claim"
            }
            fn apply(&self, s: &LineData, u: &LineData, m: &LineData, _d: bool) -> LineData {
                let mut out = *m;
                for i in 0..LINE_WORDS {
                    out[i] = m[i].wrapping_add(u[i].wrapping_sub(s[i]));
                }
                out
            }
            fn idempotent(&self) -> bool {
                true // adds are not idempotent
            }
        }
        check_merge_fn_laws(&BadClaim, 0xBAD, 25);
    }
}
