//! Minimal property-test driver (proptest is unavailable offline).
//!
//! `check(cases, gen, prop)` runs `prop` on `cases` generated inputs and,
//! on failure, performs a bounded greedy shrink via the input's
//! [`Shrink`] implementation before panicking with the minimal
//! counterexample.

use crate::util::rng::Rng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate shrinks, roughly ordered smallest-first.
    fn shrinks(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for u64 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        (*self as u64).shrinks().into_iter().map(|v| v as usize).collect()
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // halves
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // drop one element
        if self.len() <= 16 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
        }
        // shrink one element
        if let Some(first) = self.first() {
            for s in first.shrinks() {
                let mut v = self.clone();
                v[0] = s;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Run `prop` on `cases` inputs drawn via `gen`; shrink on failure.
///
/// The RNG seed is fixed (per-callsite via `seed`) so failures reproduce.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> PropResult,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &mut prop);
            panic!(
                "property failed (case {case}/{cases}, seed {seed}):\n  input: {min_input:?}\n  error: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T: Shrink, P: FnMut(&T) -> PropResult>(
    mut input: T,
    mut msg: String,
    prop: &mut P,
) -> (T, String) {
    // bounded greedy descent
    for _ in 0..200 {
        let mut improved = false;
        for cand in input.shrinks() {
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                improved = true;
                break;
            }
        }
        if !improved {
            break;
        }
    }
    (input, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 100, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            check(
                2,
                100,
                |r| r.below(1000),
                |&x| {
                    if x < 50 {
                        Ok(())
                    } else {
                        Err(format!("{x} >= 50"))
                    }
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // greedy halving from any failing x >= 50 lands on exactly 50
        assert!(msg.contains("input: 50"), "got: {msg}");
    }

    #[test]
    fn vec_shrinks_reduce_length_or_elements() {
        let v = vec![5u64, 6, 7];
        let shrinks = v.shrinks();
        assert!(shrinks.iter().any(|s| s.len() < 3));
        assert!(shrinks.iter().any(|s| s.len() == 3 && s[0] < 5));
    }
}
