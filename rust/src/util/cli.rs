//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters and a generated usage string.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A declared option (for usage text and validation).
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// Parsed arguments plus declared specs.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: &'static str,
    specs: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(about: &'static str) -> Self {
        Self {
            about,
            ..Default::default()
        }
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    /// Parse from an explicit iterator (tests) or `std::env::args`.
    pub fn parse_from<I: IntoIterator<Item = String>>(
        mut self,
        args: I,
    ) -> Result<Self, String> {
        let mut it = args.into_iter();
        self.program = it.next().unwrap_or_else(|| "ccache".into());
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    self.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{key} needs a value"))?,
                    };
                    self.values.insert(key, val);
                }
            } else {
                self.positional.push(a);
            }
        }
        Ok(self)
    }

    pub fn parse(self) -> Self {
        match self.parse_from(std::env::args()) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}\n", self.about);
        let _ = writeln!(s, "usage: {} [options] [args...]", self.program);
        for spec in &self.specs {
            if spec.is_flag {
                let _ = writeln!(s, "  --{:<24}{}", spec.name, spec.help);
            } else {
                let _ = writeln!(
                    s,
                    "  --{:<24}{} (default: {})",
                    format!("{} <v>", spec.name),
                    spec.help,
                    spec.default.as_deref().unwrap_or("-")
                );
            }
        }
        s
    }

    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
            .unwrap_or_else(|| panic!("option {name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a float"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(list: &[&str]) -> Vec<String> {
        std::iter::once("prog".to_string())
            .chain(list.iter().map(|s| s.to_string()))
            .collect()
    }

    fn base() -> Args {
        Args::new("test")
            .opt("keys", "1000", "number of keys")
            .opt("theta", "0.0", "zipf skew")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_apply() {
        let a = base().parse_from(argv(&[])).unwrap();
        assert_eq!(a.get_usize("keys"), 1000);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = base()
            .parse_from(argv(&["--keys", "5", "--theta=0.9", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("keys"), 5);
        assert_eq!(a.get_f64("theta"), 0.9);
        assert!(a.has("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(base().parse_from(argv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(base().parse_from(argv(&["--keys"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = base().parse_from(argv(&["--help"])).unwrap_err();
        assert!(err.contains("usage:"));
        assert!(err.contains("--keys"));
    }
}
