//! Bench harness: wall-clock timing plus paper-style ASCII tables and
//! series plots, and the persistent perf-trajectory record
//! ([`BenchReport`] — the `BENCH_<n>.json` files `ccache bench --json`
//! writes). Criterion is unavailable offline; every `[[bench]]` target
//! is a `harness = false` binary built on this module.

use std::fmt::Write as _;
use std::time::Instant;

/// Schema identifier stamped into every [`BenchReport`] JSON record so
/// downstream tooling (CI smoke validation, cross-PR comparisons) can
/// reject records it does not understand.
pub const SCHEMA: &str = "ccache-bench-v1";

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run a closure `iters` times after `warmup` runs; report min/mean seconds.
pub fn sample<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchSample {
    for _ in 0..warmup {
        let _ = f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        let _ = f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchSample {
        min: times[0],
        mean,
        max: *times.last().unwrap(),
    }
}

#[derive(Clone, Copy, Debug)]
pub struct BenchSample {
    pub min: f64,
    pub mean: f64,
    pub max: f64,
}

/// One perf-suite scenario: how many simulated operations ran, how long
/// the wall clock took, and — for scenarios with a slow twin — the
/// throughput of the same work with the engine fast path disabled.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub name: String,
    /// Operations executed (reads, COps, merged lines, ... — whatever
    /// the scenario counts as one unit of work).
    pub ops: u64,
    /// Wall-clock seconds for the (fast-path) run.
    pub secs: f64,
    /// Mops/s of the identical run with `fast_path` disabled; `None`
    /// when the scenario has no fast/slow split (batch executors, the
    /// sweep cell).
    pub slow_mops: Option<f64>,
}

impl ScenarioResult {
    /// Millions of operations per second.
    pub fn mops(&self) -> f64 {
        self.ops as f64 / self.secs / 1e6
    }

    /// Fast-path speedup over the slow twin, when one was measured.
    pub fn speedup(&self) -> Option<f64> {
        self.slow_mops.map(|s| self.mops() / s)
    }
}

/// One native-backend measurement: a registry workload run on real OS
/// threads (`--backend native`), wall-clock timed, with the matching
/// simulated cycle count alongside so trajectory diffs can correlate the
/// two. Serialized under the report's top-level `"native"` key — a new
/// key, not a new scenario shape, so existing `scenarios` validators
/// keep passing.
#[derive(Clone, Debug)]
pub struct NativeResult {
    pub name: String,
    pub variant: String,
    /// Operations executed across all threads (memory ops + COps).
    pub ops: u64,
    /// Wall-clock seconds of the parallel section.
    pub secs: f64,
    /// Simulated cycles of the same workload/variant on the sim backend.
    pub sim_cycles: u64,
    /// Golden verification outcome of the native run.
    pub verified: bool,
}

impl NativeResult {
    /// Millions of operations per second (wall clock).
    pub fn mops(&self) -> f64 {
        if self.secs > 0.0 {
            self.ops as f64 / self.secs / 1e6
        } else {
            0.0
        }
    }
}

/// One LLC-partition measurement: a CCache workload run next to the
/// streaming co-runner, with and without the reuse-aware merge-region
/// partition. Serialized under the report's top-level `"partition"` key
/// (same precedent as `"native"`: a new key with its own shape, so
/// existing section validators keep passing).
#[derive(Clone, Debug)]
pub struct PartitionResult {
    pub name: String,
    /// Partition mode token: "none" | "static" | "reuse".
    pub policy: String,
    /// Co-runner scanner cores the cell ran against.
    pub corun: usize,
    /// Workload cycles (co-runner cores excluded).
    pub cycles: u64,
    pub ways_min: u64,
    pub ways_max: u64,
    pub ways_final: u64,
    pub repartitions: u64,
    pub verified: bool,
}

/// One kvserve serving cell for the trajectory record: the staleness
/// bound and throughput of the serving tier at one merge deadline.
/// Serialized under the report's top-level `"kvserve"` key (same
/// precedent as `"native"`/`"partition"`: a new key with its own shape,
/// so existing section validators keep passing).
#[derive(Clone, Debug)]
pub struct KvServeResult {
    /// Soft-merge deadline the cell ran under, in unmerged updates.
    pub deadline: usize,
    pub variant: String,
    pub cycles: u64,
    /// Requests served.
    pub ops: u64,
    /// Measured staleness bound: max age, in ops, of an update at
    /// publication (0 for the coherent baselines).
    pub staleness_max: u64,
    pub staleness_mean: f64,
    pub verified: bool,
}

impl KvServeResult {
    /// Simulated throughput: requests per thousand cycles.
    pub fn ops_per_kcycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.ops as f64 * 1e3 / self.cycles as f64
        }
    }
}

/// One coherence-protocol cell for the trajectory record: a benchmark ×
/// protocol × variant point from the protosweep grid, so the trajectory
/// tracks how mesi/dragon/partial move relative to each other.
/// Serialized under the report's top-level `"protosweep"` key (same
/// precedent as `"native"`/`"partition"`/`"kvserve"`: a new key with its
/// own shape, so existing section validators keep passing).
#[derive(Clone, Debug)]
pub struct ProtoResult {
    pub name: String,
    /// Protocol token: "mesi" | "dragon" | "partial".
    pub protocol: String,
    pub variant: String,
    /// False when the protocol typed-rejects the variant (partial
    /// coherence has no coherent RMWs); numeric fields are zero then.
    pub supported: bool,
    pub cycles: u64,
    /// Dragon write-update broadcasts (0 under invalidate protocols).
    pub dragon_updates: u64,
    pub dir_msgs: u64,
    pub verified: bool,
}

/// The perf-trajectory record one `ccache bench` run produces.
/// Serialized (hand-rolled JSON — serde is unavailable offline) to
/// `BENCH_<bench_id>.json`; committing one per perf-relevant PR gives
/// the repo a wall-clock history reviewers can diff.
#[derive(Clone, Debug)]
pub struct BenchReport {
    /// Trajectory label, normally the PR number (`BENCH_6.json`).
    pub bench_id: String,
    /// True when iteration counts were cut ~20x (CI smoke mode).
    pub quick: bool,
    /// Machine fingerprint the scenarios ran on
    /// ([`MachineConfig::describe`](crate::sim::config::MachineConfig::describe)).
    pub config: String,
    /// Wall clock for the whole suite.
    pub wall_clock_secs: f64,
    /// Free-form provenance (host notes, caveats); empty when none.
    pub note: String,
    pub scenarios: Vec<ScenarioResult>,
    /// Native-backend wall-clock measurements (empty when the suite ran
    /// sim-only).
    pub native: Vec<NativeResult>,
    /// LLC-partition cells: the partitioned-vs-unpartitioned cycle
    /// trajectory under the co-runner stressor.
    pub partition: Vec<PartitionResult>,
    /// kvserve serving cells: the staleness-vs-throughput trajectory
    /// across merge deadlines (ccache plus the atomic baseline).
    pub kvserve: Vec<KvServeResult>,
    /// Coherence-protocol cells: the protosweep grid on the small
    /// machine, one row per benchmark × protocol × variant.
    pub protosweep: Vec<ProtoResult>,
}

impl BenchReport {
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(2048);
        out.push_str("{\n");
        out.push_str(&format!("  \"schema\": {},\n", json_str(SCHEMA)));
        out.push_str(&format!("  \"bench_id\": {},\n", json_str(&self.bench_id)));
        out.push_str(&format!("  \"quick\": {},\n", self.quick));
        out.push_str(&format!("  \"config\": {},\n", json_str(&self.config)));
        out.push_str(&format!(
            "  \"wall_clock_secs\": {:.3},\n",
            self.wall_clock_secs
        ));
        out.push_str(&format!("  \"note\": {},\n", json_str(&self.note)));
        out.push_str("  \"scenarios\": [\n");
        for (i, s) in self.scenarios.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            let slow = s
                .slow_mops
                .map(|v| format!("{v:.3}"))
                .unwrap_or_else(|| "null".into());
            let speedup = s
                .speedup()
                .map(|v| format!("{v:.2}"))
                .unwrap_or_else(|| "null".into());
            out.push_str(&format!(
                "    {{\"name\": {}, \"ops\": {}, \"secs\": {:.6}, \
                 \"mops\": {:.3}, \"slow_mops\": {slow}, \"speedup\": {speedup}}}",
                json_str(&s.name),
                s.ops,
                s.secs,
                s.mops()
            ));
        }
        out.push_str("\n  ],\n");
        out.push_str("  \"native\": [\n");
        for (i, n) in self.native.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "    {{\"name\": {}, \"variant\": {}, \"ops\": {}, \
                 \"secs\": {:.6}, \"mops\": {:.3}, \"sim_cycles\": {}, \
                 \"verified\": {}}}",
                json_str(&n.name),
                json_str(&n.variant),
                n.ops,
                n.secs,
                n.mops(),
                n.sim_cycles,
                n.verified
            ));
        }
        out.push_str("\n  ],\n");
        out.push_str("  \"partition\": [\n");
        for (i, p) in self.partition.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "    {{\"name\": {}, \"policy\": {}, \"corun\": {}, \
                 \"cycles\": {}, \"ways_min\": {}, \"ways_max\": {}, \
                 \"ways_final\": {}, \"repartitions\": {}, \"verified\": {}}}",
                json_str(&p.name),
                json_str(&p.policy),
                p.corun,
                p.cycles,
                p.ways_min,
                p.ways_max,
                p.ways_final,
                p.repartitions,
                p.verified
            ));
        }
        out.push_str("\n  ],\n");
        out.push_str("  \"kvserve\": [\n");
        for (i, k) in self.kvserve.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "    {{\"deadline\": {}, \"variant\": {}, \"cycles\": {}, \
                 \"ops\": {}, \"ops_per_kcycle\": {:.4}, \"staleness_max\": {}, \
                 \"staleness_mean\": {:.4}, \"verified\": {}}}",
                k.deadline,
                json_str(&k.variant),
                k.cycles,
                k.ops,
                k.ops_per_kcycle(),
                k.staleness_max,
                k.staleness_mean,
                k.verified
            ));
        }
        out.push_str("\n  ],\n");
        out.push_str("  \"protosweep\": [\n");
        for (i, p) in self.protosweep.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "    {{\"name\": {}, \"protocol\": {}, \"variant\": {}, \
                 \"supported\": {}, \"cycles\": {}, \"dragon_updates\": {}, \
                 \"dir_msgs\": {}, \"verified\": {}}}",
                json_str(&p.name),
                json_str(&p.protocol),
                json_str(&p.variant),
                p.supported,
                p.cycles,
                p.dragon_updates,
                p.dir_msgs,
                p.verified
            ));
        }
        out.push_str("\n  ]\n}\n");
        out
    }

    /// The suite as a paper-style ASCII table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!("perf_hotpath — {}", self.config),
            &["scenario", "Mops/s", "slow Mops/s", "speedup"],
        );
        for s in &self.scenarios {
            t.row(&[
                s.name.clone(),
                format!("{:.2}", s.mops()),
                s.slow_mops
                    .map(|v| format!("{v:.2}"))
                    .unwrap_or_else(|| "-".into()),
                s.speedup()
                    .map(|v| format!("{v:.2}x"))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        t
    }

    /// The LLC-partition section as its own table (empty reports render
    /// a header-only table).
    pub fn partition_table(&self) -> Table {
        let mut t = Table::new(
            format!("LLC partition under co-runner — {}", self.config),
            &["workload", "policy", "corun", "cycles", "ways min/max/final", "repart", "verified"],
        );
        for p in &self.partition {
            t.row(&[
                p.name.clone(),
                p.policy.clone(),
                p.corun.to_string(),
                p.cycles.to_string(),
                format!("{}/{}/{}", p.ways_min, p.ways_max, p.ways_final),
                p.repartitions.to_string(),
                p.verified.to_string(),
            ]);
        }
        t
    }

    /// The kvserve serving section as its own table (empty reports
    /// render a header-only table).
    pub fn serve_table(&self) -> Table {
        let mut t = Table::new(
            format!("kvserve staleness vs throughput — {}", self.config),
            &["deadline", "variant", "ops/kcyc", "stale max", "stale mean", "verified"],
        );
        for k in &self.kvserve {
            t.row(&[
                k.deadline.to_string(),
                k.variant.clone(),
                format!("{:.2}", k.ops_per_kcycle()),
                k.staleness_max.to_string(),
                format!("{:.1}", k.staleness_mean),
                k.verified.to_string(),
            ]);
        }
        t
    }

    /// The coherence-protocol section as its own table (empty reports
    /// render a header-only table).
    pub fn proto_table(&self) -> Table {
        let mut t = Table::new(
            format!("coherence protocols — {}", self.config),
            &["workload", "protocol", "variant", "cycles", "updates", "dir msgs", "verified"],
        );
        for p in &self.protosweep {
            if p.supported {
                t.row(&[
                    p.name.clone(),
                    p.protocol.clone(),
                    p.variant.clone(),
                    p.cycles.to_string(),
                    p.dragon_updates.to_string(),
                    p.dir_msgs.to_string(),
                    p.verified.to_string(),
                ]);
            } else {
                t.row(&[
                    p.name.clone(),
                    p.protocol.clone(),
                    p.variant.clone(),
                    "unsupported".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
            }
        }
        t
    }

    /// The native-backend section as its own table (empty reports render
    /// a header-only table).
    pub fn native_table(&self) -> Table {
        let mut t = Table::new(
            format!("native backend — {}", self.config),
            &["workload", "variant", "wall Mops/s", "sim cycles", "verified"],
        );
        for n in &self.native {
            t.row(&[
                n.name.clone(),
                n.variant.clone(),
                format!("{:.2}", n.mops()),
                n.sim_cycles.to_string(),
                n.verified.to_string(),
            ]);
        }
        t
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// A simple right-aligned ASCII table with a title, matching the tabular
/// presentation of the paper's figures.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line_w: usize = widths.iter().sum::<usize>() + 3 * ncol + 1;
        let sep = "-".repeat(line_w);
        let _ = writeln!(out, "{sep}");
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, " {:>w$} |", c, w = widths[i]);
            }
            s
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        let _ = writeln!(out, "{sep}");
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Render a labeled series as an ASCII bar chart (one bar per point),
/// used for figure-shaped outputs.
pub fn bar_chart(title: &str, labels: &[String], values: &[f64]) -> String {
    assert_eq!(labels.len(), values.len());
    let maxv = values.iter().cloned().fold(f64::MIN, f64::max).max(1e-12);
    let label_w = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = String::new();
    let _ = writeln!(out, "== {title} ==");
    for (l, v) in labels.iter().zip(values) {
        let n = ((v / maxv) * 50.0).round().max(0.0) as usize;
        let _ = writeln!(out, "{:>w$} | {:<50} {:.3}", l, "#".repeat(n), v, w = label_w);
    }
    out
}

pub fn fmt_f(v: f64, digits: usize) -> String {
    format!("{:.*}", digits, v)
}

pub fn fmt_ratio(v: f64) -> String {
    format!("{v:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_all_rows() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("333"));
        assert_eq!(s.matches('\n').count() >= 6, true);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn sample_orders_min_mean_max() {
        let s = sample(1, 5, || std::thread::sleep(std::time::Duration::from_micros(50)));
        assert!(s.min <= s.mean && s.mean <= s.max);
        assert!(s.min > 0.0);
    }

    fn demo_report() -> BenchReport {
        BenchReport {
            bench_id: "6".into(),
            quick: true,
            config: "8 cores, L1 32 KiB".into(),
            wall_clock_secs: 1.5,
            note: "".into(),
            scenarios: vec![
                ScenarioResult {
                    name: "memsys_read_hit".into(),
                    ops: 4_000_000,
                    secs: 0.5,
                    slow_mops: Some(1.6),
                },
                ScenarioResult {
                    name: "sweep_cell".into(),
                    ops: 1000,
                    secs: 0.1,
                    slow_mops: None,
                },
            ],
            native: vec![NativeResult {
                name: "histogram".into(),
                variant: "atomic".into(),
                ops: 2_000_000,
                secs: 0.25,
                sim_cycles: 9_000_000,
                verified: true,
            }],
            partition: vec![PartitionResult {
                name: "kvstore".into(),
                policy: "reuse".into(),
                corun: 2,
                cycles: 5_000_000,
                ways_min: 2,
                ways_max: 6,
                ways_final: 5,
                repartitions: 7,
                verified: true,
            }],
            kvserve: vec![KvServeResult {
                deadline: 64,
                variant: "ccache".into(),
                cycles: 2_000_000,
                ops: 40_000,
                staleness_max: 61,
                staleness_mean: 17.25,
                verified: true,
            }],
            protosweep: vec![
                ProtoResult {
                    name: "kvstore".into(),
                    protocol: "dragon".into(),
                    variant: "ccache".into(),
                    supported: true,
                    cycles: 3_000_000,
                    dragon_updates: 128,
                    dir_msgs: 900,
                    verified: true,
                },
                ProtoResult {
                    name: "kvstore".into(),
                    protocol: "partial".into(),
                    variant: "fgl".into(),
                    supported: false,
                    cycles: 0,
                    dragon_updates: 0,
                    dir_msgs: 0,
                    verified: false,
                },
            ],
        }
    }

    #[test]
    fn scenario_math() {
        let s = &demo_report().scenarios[0];
        assert!((s.mops() - 8.0).abs() < 1e-9);
        assert!((s.speedup().unwrap() - 5.0).abs() < 1e-9);
        assert_eq!(demo_report().scenarios[1].speedup(), None);
    }

    #[test]
    fn report_json_has_schema_and_balanced_structure() {
        let j = demo_report().to_json();
        assert!(j.contains("\"schema\": \"ccache-bench-v1\""), "{j}");
        assert!(j.contains("\"bench_id\": \"6\""), "{j}");
        assert!(j.contains("\"name\": \"memsys_read_hit\""), "{j}");
        assert!(j.contains("\"speedup\": 5.00"), "{j}");
        // scenarios without a slow twin serialize null, not a number
        assert!(j.contains("\"slow_mops\": null"), "{j}");
        // the native section is a top-level key with its own shape
        assert!(j.contains("\"native\": ["), "{j}");
        assert!(j.contains("\"variant\": \"atomic\""), "{j}");
        assert!(j.contains("\"sim_cycles\": 9000000"), "{j}");
        assert!(j.contains("\"verified\": true"), "{j}");
        // so is the partition section (PR 8 trajectory record)
        assert!(j.contains("\"partition\": ["), "{j}");
        assert!(j.contains("\"policy\": \"reuse\""), "{j}");
        assert!(j.contains("\"ways_final\": 5"), "{j}");
        assert!(j.contains("\"repartitions\": 7"), "{j}");
        // and the kvserve serving section (PR 9 trajectory record)
        assert!(j.contains("\"kvserve\": ["), "{j}");
        assert!(j.contains("\"deadline\": 64"), "{j}");
        assert!(j.contains("\"staleness_max\": 61"), "{j}");
        assert!(j.contains("\"staleness_mean\": 17.2500"), "{j}");
        // and the protosweep section (PR 10 trajectory record)
        assert!(j.contains("\"protosweep\": ["), "{j}");
        assert!(j.contains("\"protocol\": \"dragon\""), "{j}");
        assert!(j.contains("\"dragon_updates\": 128"), "{j}");
        assert!(j.contains("\"supported\": false"), "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count(), "{j}");
        assert_eq!(j.matches('[').count(), j.matches(']').count(), "{j}");
    }

    #[test]
    fn native_result_mops_handles_zero_secs() {
        let n = NativeResult {
            name: "x".into(),
            variant: "fgl".into(),
            ops: 100,
            secs: 0.0,
            sim_cycles: 0,
            verified: true,
        };
        assert_eq!(n.mops(), 0.0);
        let t = demo_report().native_table().render();
        assert!(t.contains("histogram"), "{t}");
    }

    #[test]
    fn partition_table_renders_the_way_trajectory() {
        let t = demo_report().partition_table().render();
        assert!(t.contains("kvstore"), "{t}");
        assert!(t.contains("reuse"), "{t}");
        assert!(t.contains("2/6/5"), "{t}");
    }

    #[test]
    fn serve_table_renders_the_frontier_cell() {
        let t = demo_report().serve_table().render();
        assert!(t.contains("ccache"), "{t}");
        assert!(t.contains("61"), "{t}");
        assert!(t.contains("17.2"), "{t}");
    }

    #[test]
    fn proto_table_marks_rejected_cells() {
        let t = demo_report().proto_table().render();
        assert!(t.contains("dragon"), "{t}");
        assert!(t.contains("3000000"), "{t}");
        assert!(t.contains("unsupported"), "{t}");
    }

    #[test]
    fn report_table_renders_every_scenario() {
        let t = demo_report().table().render();
        assert!(t.contains("memsys_read_hit"), "{t}");
        assert!(t.contains("5.00x"), "{t}");
        assert!(t.contains('-'), "{t}"); // the no-twin scenario
    }

    #[test]
    fn bar_chart_scales_to_max() {
        let s = bar_chart(
            "t",
            &["x".into(), "y".into()],
            &[1.0, 2.0],
        );
        assert!(s.contains('#'));
        assert!(s.lines().count() == 3);
    }
}
