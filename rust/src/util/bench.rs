//! Bench harness: wall-clock timing plus paper-style ASCII tables and
//! series plots. Criterion is unavailable offline; every `[[bench]]`
//! target is a `harness = false` binary built on this module.

use std::fmt::Write as _;
use std::time::Instant;

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run a closure `iters` times after `warmup` runs; report min/mean seconds.
pub fn sample<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchSample {
    for _ in 0..warmup {
        let _ = f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        let _ = f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchSample {
        min: times[0],
        mean,
        max: *times.last().unwrap(),
    }
}

#[derive(Clone, Copy, Debug)]
pub struct BenchSample {
    pub min: f64,
    pub mean: f64,
    pub max: f64,
}

/// A simple right-aligned ASCII table with a title, matching the tabular
/// presentation of the paper's figures.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line_w: usize = widths.iter().sum::<usize>() + 3 * ncol + 1;
        let sep = "-".repeat(line_w);
        let _ = writeln!(out, "{sep}");
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, " {:>w$} |", c, w = widths[i]);
            }
            s
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        let _ = writeln!(out, "{sep}");
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Render a labeled series as an ASCII bar chart (one bar per point),
/// used for figure-shaped outputs.
pub fn bar_chart(title: &str, labels: &[String], values: &[f64]) -> String {
    assert_eq!(labels.len(), values.len());
    let maxv = values.iter().cloned().fold(f64::MIN, f64::max).max(1e-12);
    let label_w = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = String::new();
    let _ = writeln!(out, "== {title} ==");
    for (l, v) in labels.iter().zip(values) {
        let n = ((v / maxv) * 50.0).round().max(0.0) as usize;
        let _ = writeln!(out, "{:>w$} | {:<50} {:.3}", l, "#".repeat(n), v, w = label_w);
    }
    out
}

pub fn fmt_f(v: f64, digits: usize) -> String {
    format!("{:.*}", digits, v)
}

pub fn fmt_ratio(v: f64) -> String {
    format!("{v:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_all_rows() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("333"));
        assert_eq!(s.matches('\n').count() >= 6, true);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn sample_orders_min_mean_max() {
        let s = sample(1, 5, || std::thread::sleep(std::time::Duration::from_micros(50)));
        assert!(s.min <= s.mean && s.mean <= s.max);
        assert!(s.min > 0.0);
    }

    #[test]
    fn bar_chart_scales_to_max() {
        let s = bar_chart(
            "t",
            &["x".into(), "y".into()],
            &[1.0, 2.0],
        );
        assert!(s.contains('#'));
        assert!(s.lines().count() == 3);
    }
}
