//! Experiment configuration: the scaled bench machine and verified-run
//! helpers. Benchmark sizing lives with each workload
//! (`Workload::sized` constructors, driven by
//! [`SizeSpec`](crate::exec::SizeSpec)) and enumeration lives in
//! [`exec::registry`](crate::exec::registry) — this module no longer
//! keeps a parallel benchmark list.
//!
//! Simulation-scale note: the paper's Table 2 machine (4 MB LLC) with
//! 16 accesses/key at 4 M keys means hundreds of millions of simulated
//! operations per run. The benches default to a geometrically-scaled
//! machine (1/4-size caches, same ways/latencies — `scaled_config`) so a
//! full figure regenerates in minutes; every quantity the figures report
//! is relative to LLC capacity, which the scaling preserves. Set
//! `CCACHE_FULL_SIZE=1` to run the paper's exact Table 2 geometry.

use crate::exec::{RunResult, SizeSpec, Variant, WorkloadHandle};
use crate::sim::config::MachineConfig;

/// LLC size of the scaled bench machine (1 MB; the paper's is 4 MB).
pub const SCALED_LLC_BYTES: usize = 1 << 20;

/// The scaled bench machine: Table 2 shape at 1/4 linear size.
pub fn scaled_config() -> MachineConfig {
    if std::env::var("CCACHE_FULL_SIZE").is_ok() {
        return MachineConfig::default();
    }
    let mut cfg = MachineConfig::default();
    cfg.l1_mut().size_bytes = 8 << 10; // 16 sets x 8 ways
    cfg.level_mut(1).size_bytes = 128 << 10; // the L2
    cfg.llc_mut().size_bytes = SCALED_LLC_BYTES;
    cfg
}

/// Build a registered benchmark whose primary working set is `frac` x
/// the LLC (working-set definitions per benchmark match Section 6.1's
/// sweep of the *shared, contended* structure — see each workload's
/// `sized` constructor). Panics on unknown names; use
/// `exec::registry::build` for the fallible form.
pub fn sized_workload(name: &str, frac: f64, llc_bytes: usize, seed: u64) -> WorkloadHandle {
    crate::exec::registry::build(name, &SizeSpec::new(frac, llc_bytes, seed))
        .unwrap_or_else(|e| panic!("{e}"))
}

/// Run one benchmark/variant on a config, asserting verification.
pub fn run_verified(bench: &WorkloadHandle, variant: Variant, cfg: &MachineConfig) -> RunResult {
    let r = bench
        .run(variant, cfg.clone())
        .unwrap_or_else(|e| panic!("{e}"));
    r.assert_verified();
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::registry;

    #[test]
    fn sizing_tracks_fraction() {
        let llc = 1 << 20;
        let small = sized_workload("kvstore", 0.25, llc, 1);
        let large = sized_workload("kvstore", 4.0, llc, 1);
        assert_eq!(small.footprint() * 16, large.footprint());
        assert_eq!(small.footprint(), llc as u64 / 4);
    }

    #[test]
    fn scaled_config_keeps_table2_shape() {
        let cfg = scaled_config();
        assert_eq!(cfg.depth(), 3);
        assert_eq!(cfg.l1().ways, 8);
        assert_eq!(cfg.llc().ways, 16);
        assert_eq!(cfg.l1().hit_cycles, 4);
        assert_eq!(cfg.timing.mem_cycles, 300);
        cfg.validate().unwrap();
    }

    #[test]
    fn all_fig6_panels_buildable() {
        for spec in registry::fig6_panels() {
            let b = sized_workload(spec.name, 0.25, 1 << 18, 7);
            assert!(!b.name().is_empty());
        }
    }
}
