//! Benchmark sizing: map a working-set fraction of the LLC to concrete
//! benchmark parameters, exactly how the paper sweeps inputs "from 25%
//! of the L3 cache size up to 400% of the L3 size" (Section 6.1).
//!
//! Simulation-scale note: the paper's Table 2 machine (4 MB LLC) with
//! 16 accesses/key at 4 M keys means hundreds of millions of simulated
//! operations per run. The benches default to a geometrically-scaled
//! machine (1/4-size caches, same ways/latencies — `scaled_config`) so a
//! full figure regenerates in minutes; every quantity the figures report
//! is relative to LLC capacity, which the scaling preserves. Set
//! `CCACHE_FULL_SIZE=1` to run the paper's exact Table 2 geometry.

use crate::exec::{RunResult, Variant};
use crate::sim::config::MachineConfig;
use crate::workloads::graph::GraphKind;
use crate::workloads::{bfs, kmeans, kvstore, pagerank, Benchmark};

/// LLC size of the scaled bench machine (1 MB; the paper's is 4 MB).
pub const SCALED_LLC_BYTES: usize = 1 << 20;

/// The benchmark axis of Fig 6 (panels) and Fig 8.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BenchKind {
    KvAdd,
    KvSat,
    KvCmul,
    KMeans,
    KMeansApprox,
    PageRank(GraphKind),
    Bfs(GraphKind),
}

impl BenchKind {
    pub fn name(&self) -> String {
        match self {
            BenchKind::KvAdd => "kvstore".into(),
            BenchKind::KvSat => "kvstore-sat".into(),
            BenchKind::KvCmul => "kvstore-cmul".into(),
            BenchKind::KMeans => "kmeans".into(),
            BenchKind::KMeansApprox => "kmeans-approx".into(),
            BenchKind::PageRank(g) => format!("pagerank-{}", g.name()),
            BenchKind::Bfs(g) => format!("bfs-{}", g.name()),
        }
    }

    /// All panels of Fig 6 (baselines + Section 6.3 merge variants).
    pub fn fig6_panels() -> Vec<BenchKind> {
        vec![
            BenchKind::KvAdd,
            BenchKind::KMeans,
            BenchKind::PageRank(GraphKind::Rmat),
            BenchKind::PageRank(GraphKind::Ssca),
            BenchKind::PageRank(GraphKind::Uniform),
            BenchKind::Bfs(GraphKind::Rmat),
            BenchKind::Bfs(GraphKind::Uniform),
            BenchKind::KvSat,
            BenchKind::KvCmul,
            BenchKind::KMeansApprox,
        ]
    }

    /// The four core benchmarks.
    pub fn core_four() -> Vec<BenchKind> {
        vec![
            BenchKind::KvAdd,
            BenchKind::KMeans,
            BenchKind::PageRank(GraphKind::Uniform),
            BenchKind::Bfs(GraphKind::Rmat),
        ]
    }
}

/// The scaled bench machine: Table 2 shape at 1/4 linear size.
pub fn scaled_config() -> MachineConfig {
    if std::env::var("CCACHE_FULL_SIZE").is_ok() {
        return MachineConfig::default();
    }
    let mut cfg = MachineConfig::default();
    cfg.l1.size_bytes = 8 << 10; // 16 sets x 8 ways
    cfg.l2.size_bytes = 128 << 10;
    cfg.llc.size_bytes = SCALED_LLC_BYTES;
    cfg
}

/// Build a benchmark whose primary working set is `frac` x the LLC.
///
/// Working-set definitions per benchmark (matching Section 6.1's sweep
/// of the *shared, contended* structure):
/// * KV store — the value table
/// * K-Means — the point set (accumulators are tiny by design)
/// * PageRank — rank arrays + CSR
/// * BFS — CSR + bitmaps
pub fn sized_benchmark(kind: BenchKind, frac: f64, llc_bytes: usize, seed: u64) -> Benchmark {
    let target = (frac * llc_bytes as f64) as u64;
    match kind {
        BenchKind::KvAdd | BenchKind::KvSat | BenchKind::KvCmul => {
            let merge = match kind {
                BenchKind::KvSat => kvstore::KvMerge::Sat { max: 12 },
                BenchKind::KvCmul => kvstore::KvMerge::Cmul,
                _ => kvstore::KvMerge::Add,
            };
            let bytes_per_key = if matches!(merge, kvstore::KvMerge::Cmul) {
                8
            } else {
                4
            };
            let keys = (target / bytes_per_key).max(256) as usize;
            Benchmark::Kv(kvstore::KvParams {
                keys,
                accesses_per_key: 16, // the paper's ratio (Section 5.1)
                seed,
                merge,
                zipf_theta: 0.0,
            })
        }
        BenchKind::KMeans | BenchKind::KMeansApprox => {
            let points = (target / (kmeans::DIM as u64 * 4)).max(256) as usize;
            Benchmark::KMeans(kmeans::KmParams {
                points,
                clusters: 4,
                iters: 2,
                seed,
                approx_drop_p: if kind == BenchKind::KMeansApprox {
                    0.1
                } else {
                    0.0
                },
            })
        }
        BenchKind::PageRank(g) => {
            // rank arrays (8 B/v) + CSR ((1+deg)*4 B/v), deg=8 -> 44 B/v
            let vertices = (target / 44).max(256) as usize;
            Benchmark::PageRank(pagerank::PrParams {
                vertices,
                avg_degree: 8,
                graph: g,
                iters: 2,
                damping: 0.85,
                seed,
            })
        }
        BenchKind::Bfs(g) => {
            let vertices = (target / 40).max(256) as usize;
            Benchmark::Bfs(bfs::BfsParams {
                vertices,
                avg_degree: 8,
                graph: g,
                seed,
                source: 0,
            })
        }
    }
}

/// Run one benchmark/variant on a config, asserting verification.
pub fn run_verified(bench: &Benchmark, variant: Variant, cfg: MachineConfig) -> RunResult {
    let r = bench.run(variant, cfg);
    r.assert_verified();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizing_tracks_fraction() {
        let llc = 1 << 20;
        let small = sized_benchmark(BenchKind::KvAdd, 0.25, llc, 1);
        let large = sized_benchmark(BenchKind::KvAdd, 4.0, llc, 1);
        let (Benchmark::Kv(s), Benchmark::Kv(l)) = (&small, &large) else {
            panic!()
        };
        assert_eq!(s.keys * 16, l.keys);
        assert_eq!(s.working_set_bytes(), llc as u64 / 4);
    }

    #[test]
    fn scaled_config_keeps_table2_shape() {
        let cfg = scaled_config();
        assert_eq!(cfg.l1.ways, 8);
        assert_eq!(cfg.llc.ways, 16);
        assert_eq!(cfg.l1.hit_cycles, 4);
        assert_eq!(cfg.mem_cycles, 300);
        cfg.validate().unwrap();
    }

    #[test]
    fn all_fig6_panels_buildable() {
        for kind in BenchKind::fig6_panels() {
            let b = sized_benchmark(kind, 0.25, 1 << 18, 7);
            assert!(!b.name().is_empty());
        }
    }
}
