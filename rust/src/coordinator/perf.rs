//! The `perf_hotpath` suite as a library: every scenario the
//! `ccache bench` subcommand and the `perf_hotpath` bench target run,
//! producing one [`BenchReport`] — the persistent perf-trajectory
//! record (`BENCH_<n>.json`).
//!
//! Engine scenarios run twice, once with the branch-light fast path
//! ([`MachineConfig::fast_path`]) and once without, so every record
//! carries its own fast/slow speedup; the differential suite
//! (`tests/fastpath_diff.rs`) proves the two runs do identical
//! simulated work, which is what makes the wall-clock ratio meaningful.

use std::time::Instant;

use crate::exec::registry::{self, SizeSpec};
use crate::exec::{driver, Backend, CorunSpec, Variant};
use crate::merge::batch::{BatchExecutor, MergeItem, NativeExecutor};
use crate::merge::funcs::AddU32;
use crate::merge::handle;
use crate::sim::addr::Addr;
use crate::sim::config::MachineConfig;
use crate::sim::hierarchy::level::PartitionPolicy;
use crate::sim::machine::{CoreCtx, Machine};
use crate::sim::memsys::MemSystem;
use crate::util::bench::{
    time, BenchReport, KvServeResult, NativeResult, PartitionResult, ProtoResult,
    ScenarioResult,
};
use crate::workloads::kvserve::{KvServeWorkload, ServeParams};
use crate::workloads::traffic::{Mix, TrafficSpec};

use super::experiment::scaled_config;
use super::protosweep::{run_protosweep_on, ProtosweepOptions};
use super::serve::SERVE_DEADLINES;

/// How to run the suite.
#[derive(Clone, Debug)]
pub struct SuiteOptions {
    /// Cut iteration counts ~20x: the CI smoke mode (`bench --quick`).
    pub quick: bool,
    /// Trajectory label for the record (`BENCH_<bench_id>.json`).
    pub bench_id: String,
}

impl Default for SuiteOptions {
    fn default() -> Self {
        Self {
            quick: false,
            bench_id: "dev".into(),
        }
    }
}

/// A fresh Table 2 memory system with the fast path on or off, plus an
/// 8192-line region every engine scenario indexes into.
fn memsys(fast: bool) -> (MemSystem, Addr) {
    let mut cfg = MachineConfig::default();
    cfg.fast_path = fast;
    let mut s = MemSystem::new(cfg).expect("valid config");
    let a = s.alloc_lines(64 * 8192);
    (s, a)
}

/// Coherent read hits: 256 lines (well inside the 512-line L1) cycled
/// `n` times — after one warm lap every access is the L1 read-hit path.
fn read_hit(n: u64, fast: bool) -> u64 {
    let (mut s, a) = memsys(fast);
    let mut acc = 0u64;
    for i in 0..n {
        let (v, c) = s.read(0, Addr(a.0 + (i % 256) * 64)).unwrap();
        acc = acc.wrapping_add(v as u64 + c);
    }
    std::hint::black_box(acc);
    n
}

/// COp updates on resident CData: 8 lines (exactly the source-buffer
/// capacity) so every `c_read`/`c_write` after the first lap is a
/// private hit, with a periodic `soft_merge` re-marking them mergeable.
fn cop_update(n: u64, fast: bool) -> u64 {
    let (mut s, a) = memsys(fast);
    s.merge_init(0, 0, handle(AddU32));
    let mut ops = 0u64;
    for i in 0..n {
        let addr = Addr(a.0 + (i % 8) * 64);
        let (v, _) = s.c_read(0, addr, 0).unwrap();
        s.c_write(0, addr, v.wrapping_add(1), 0).unwrap();
        ops += 2;
        if i % 16 == 0 {
            s.soft_merge(0).unwrap();
            ops += 1;
        }
    }
    ops
}

/// COp misses + merge-type re-binding: a 4096-line cold stream (far
/// beyond the 8-entry source buffer, so every access privatizes and
/// capacity-evicts), whose merge type flips each lap, interleaved with
/// 4 hot resident lines whose type flips every access.
fn cop_miss_retype(n: u64, fast: bool) -> u64 {
    let (mut s, a) = memsys(fast);
    s.merge_init(0, 0, handle(AddU32));
    s.merge_init(0, 1, handle(AddU32));
    let mut ops = 0u64;
    for i in 0..n {
        let cold = Addr(a.0 + (i % 4096) * 64);
        let ty = ((i / 4096) & 1) as u8;
        let (v, _) = s.c_read(0, cold, ty).unwrap();
        s.c_write(0, cold, v.wrapping_add(1), ty).unwrap();
        let hot = Addr(a.0 + 4096 * 64 + (i % 4) * 64);
        s.c_write(0, hot, 1, (i & 1) as u8).unwrap();
        ops += 3;
        if i % 64 == 0 {
            s.soft_merge(0).unwrap();
            ops += 1;
        }
    }
    ops
}

/// Merge-on-evict: 64 CData lines against an 8-entry source buffer with
/// every line soft-merge-marked, so each `c_write` on a non-resident
/// line forces an eviction-triggered merge through the merge engine.
fn merge_on_evict(n: u64, fast: bool) -> u64 {
    let (mut s, a) = memsys(fast);
    s.merge_init(0, 0, handle(AddU32));
    let mut ops = 0u64;
    for i in 0..n {
        s.c_write(0, Addr(a.0 + (i % 64) * 64), 1, 0).unwrap();
        s.soft_merge(0).unwrap();
        ops += 2;
    }
    ops
}

/// The 8-core interleaver with a mixed coherent read/write stream (the
/// original `perf_hotpath` scenario 3).
fn machine_interleave(per_core: u64, fast: bool) -> u64 {
    let mut cfg = MachineConfig::default();
    cfg.fast_path = fast;
    let cores = cfg.cores;
    let machine = Machine::new(cfg).expect("valid config");
    let region = machine.setup(|mem| mem.alloc_lines(64 * 8192));
    let programs: Vec<Box<dyn FnOnce(&mut CoreCtx) + Send + '_>> = (0..cores)
        .map(|core| {
            let f: Box<dyn FnOnce(&mut CoreCtx) + Send + '_> = Box::new(move |ctx| {
                let mut x = core as u64 + 1;
                for _ in 0..per_core {
                    x = x.wrapping_mul(6364136223846793005).wrapping_add(97);
                    let k = (x >> 33) % 8192;
                    if x & 1 == 0 {
                        ctx.read_u32(region.add(k * 64));
                    } else {
                        ctx.write_u32(region.add(k * 64), x as u32);
                    }
                }
            });
            f
        })
        .collect();
    machine.run(programs);
    cores as u64 * per_core
}

fn batch_items() -> Vec<MergeItem> {
    (0..4096)
        .map(|i| MergeItem {
            src: [i as u32; 16],
            upd: [(i + 7) as u32; 16],
            mem: [1000; 16],
            drop_update: false,
        })
        .collect()
}

/// Run `f` once slow (fast path off) and once fast, returning the fast
/// measurement annotated with the slow twin's throughput.
fn fast_slow(name: &str, n: u64, f: fn(u64, bool) -> u64) -> ScenarioResult {
    let (slow_ops, slow_secs) = time(|| f(n, false));
    let (ops, secs) = time(|| f(n, true));
    ScenarioResult {
        name: name.into(),
        ops,
        secs,
        slow_mops: Some(slow_ops as f64 / slow_secs / 1e6),
    }
}

/// One representative registry cell (kvstore/ccache on the scaled bench
/// machine), so the trajectory also tracks end-to-end workload
/// throughput, not just synthetic engine loops.
fn sweep_cell(quick: bool) -> ScenarioResult {
    let cfg = scaled_config();
    let spec = registry::lookup("kvstore").expect("kvstore is registered");
    let frac = if quick { 0.1 } else { 0.5 };
    let bench = spec.build(&SizeSpec::new(frac, cfg.llc().size_bytes, 42));
    let t0 = Instant::now();
    let r = bench
        .run_with_merge(Variant::CCache, cfg, None)
        .expect("sweep cell runs");
    let secs = t0.elapsed().as_secs_f64();
    assert!(r.verified, "sweep cell failed golden verification");
    ScenarioResult {
        name: "sweep_cell_kvstore_ccache".into(),
        ops: r.stats.cops + r.stats.l1().accesses(),
        secs,
        slow_mops: None,
    }
}

/// Wall-clock measurements on the native-thread backend: a small set of
/// registry cells, each golden-verified on real OS threads, paired with
/// the same cell's simulated cycle count so the trajectory record can
/// correlate measured throughput with the simulator's estimates. The
/// cells cover both backend mapping families: coherent/atomic (fgl,
/// atomic) and privatized (dup, ccache).
fn native_section(quick: bool) -> Vec<NativeResult> {
    let cfg = MachineConfig::test_small().with_cores(4);
    let frac = if quick { 0.25 } else { 1.0 };
    let cells = [
        ("histogram", Variant::Fgl),
        ("histogram", Variant::Atomic),
        ("kvstore", Variant::Dup),
        ("kvstore", Variant::CCache),
    ];
    let mut out = Vec::new();
    for (name, variant) in cells {
        let spec = registry::lookup(name).expect("registered workload");
        let bench = spec.build(&SizeSpec::new(frac, cfg.llc().size_bytes, 42));
        let nat = bench
            .run_on(Backend::Native, variant, cfg.clone())
            .expect("native cell runs");
        let sim = bench
            .run_on(Backend::Sim, variant, cfg.clone())
            .expect("sim twin runs");
        out.push(NativeResult {
            name: name.into(),
            variant: variant.name().into(),
            ops: nat.ops_total(),
            secs: nat.wall_secs.unwrap_or(0.0),
            sim_cycles: sim.cycles(),
            verified: nat.verified,
        });
    }
    out
}

/// LLC-partition cells for the trajectory record: kvstore and kmeans
/// under the CCache variant with the streaming co-runner attached, once
/// unpartitioned and once with the reuse-aware controller. Runs in
/// quick mode too — the partitioned-vs-not cycle delta under
/// interference is the number `partsweep` exists to track, and the
/// trajectory should carry it from the first record on.
fn partition_section(quick: bool) -> Vec<PartitionResult> {
    let cfg = MachineConfig::test_small().with_cores(2);
    let frac = if quick { 0.25 } else { 0.5 };
    let init_ways = (cfg.llc().ways / 4).max(1);
    let mut out = Vec::new();
    for name in ["kvstore", "kmeans"] {
        let spec = registry::lookup(name).expect("registered workload");
        let bench = spec.build(&SizeSpec::new(frac, cfg.llc().size_bytes, 42));
        let cells = [
            ("none", cfg.clone()),
            (
                "reuse",
                cfg.clone()
                    .with_partition(init_ways, PartitionPolicy::ReuseAware),
            ),
        ];
        for (policy, pcfg) in cells {
            let r = bench
                .run_corun(Variant::CCache, pcfg, Some(CorunSpec::new(2)))
                .expect("partition cell runs");
            out.push(PartitionResult {
                name: name.into(),
                policy: policy.into(),
                corun: 2,
                cycles: r.cycles(),
                ways_min: r.stats.partition_ways_min,
                ways_max: r.stats.partition_ways_max,
                ways_final: r.stats.partition_ways_final,
                repartitions: r.stats.repartitions,
                verified: r.verified,
            });
        }
    }
    out
}

/// kvserve cells for the trajectory record: the serving tier across the
/// merge-deadline axis under the CCache variant, with the atomic
/// baseline at each deadline — the staleness-vs-throughput numbers the
/// `serve` subcommand sweeps, carried in every trajectory record.
fn serve_section(quick: bool) -> Vec<KvServeResult> {
    let cfg = MachineConfig::test_small().with_cores(2);
    let mut out = Vec::new();
    for &deadline in &SERVE_DEADLINES {
        let p = ServeParams {
            traffic: TrafficSpec {
                tenants: 4,
                keys_per_tenant: if quick { 64 } else { 128 },
                shards: 4,
                mix: Mix::default(),
                base_theta: 0.6,
                skew_drift: 0.2,
                scan_len: 8,
                seed: 42,
            },
            epochs: if quick { 2 } else { 4 },
            accesses_per_key: if quick { 4 } else { 8 },
            merge_deadline: deadline,
        };
        let ops = (p.ops_per_core_epoch(cfg.cores) * cfg.cores * p.epochs) as u64;
        for variant in [Variant::CCache, Variant::Atomic] {
            let wl = KvServeWorkload::new(p.clone());
            let r = driver::run(&wl, variant, cfg.clone()).expect("serve cell runs");
            let st = wl.staleness().expect("verify ran");
            out.push(KvServeResult {
                deadline,
                variant: variant.name().into(),
                cycles: r.cycles(),
                ops,
                staleness_max: st.max_ops,
                staleness_mean: st.mean_ops(),
                verified: r.verified,
            });
        }
    }
    out
}

/// Coherence-protocol cells for the trajectory record: the protosweep
/// grid on the small machine, one row per benchmark × protocol ×
/// variant, so the trajectory tracks how mesi/dragon/partial move
/// relative to each other PR over PR. Always the quick (two-benchmark)
/// grid — the full grid is `ccache protosweep`'s job; the record only
/// needs the relative-cycle signal.
fn proto_section(_quick: bool) -> Vec<ProtoResult> {
    let base = MachineConfig::test_small().with_cores(2);
    let r = run_protosweep_on(
        base,
        ProtosweepOptions {
            quick: true,
            jobs: 0,
            seed: 42,
        },
    );
    r.cells
        .iter()
        .map(|c| ProtoResult {
            name: c.benchmark.clone(),
            protocol: c.protocol.into(),
            variant: c.variant.into(),
            supported: c.supported,
            cycles: c.cycles,
            dragon_updates: c.dragon_updates,
            dir_msgs: c.dir_msgs,
            verified: c.verified,
        })
        .collect()
}

/// Run the whole suite.
pub fn run_suite(opts: &SuiteOptions) -> BenchReport {
    let div = if opts.quick { 20 } else { 1 };
    let t0 = Instant::now();
    let mut scenarios = vec![
        fast_slow("memsys_read_hit", 4_000_000 / div, read_hit),
        fast_slow("memsys_cop_update", 1_000_000 / div, cop_update),
        fast_slow("cop_miss_retype", 200_000 / div, cop_miss_retype),
        fast_slow("merge_on_evict", 200_000 / div, merge_on_evict),
        fast_slow("machine_interleave_8core", 250_000 / div, machine_interleave),
    ];

    let items = batch_items();
    let reps = (200 / div).max(1);
    let (_, secs) = time(|| {
        for _ in 0..reps {
            std::hint::black_box(NativeExecutor.execute(&AddU32, &items));
        }
    });
    scenarios.push(ScenarioResult {
        name: "native_merge_batch".into(),
        ops: reps * items.len() as u64,
        secs,
        slow_mops: None,
    });

    let pjrt = if crate::runtime::artifacts::artifacts_available() {
        crate::runtime::PjrtMergeExecutor::load_default().ok()
    } else {
        None
    };
    if let Some(mut pjrt) = pjrt {
        pjrt.execute(&AddU32, &items[..256]); // warm-up compile
        let reps = (20 / div).max(1);
        let (_, secs) = time(|| {
            for _ in 0..reps {
                std::hint::black_box(pjrt.execute(&AddU32, &items));
            }
        });
        scenarios.push(ScenarioResult {
            name: "pjrt_merge_batch".into(),
            ops: reps * items.len() as u64,
            secs,
            slow_mops: None,
        });
    }

    scenarios.push(sweep_cell(opts.quick));
    let native = native_section(opts.quick);
    let partition = partition_section(opts.quick);
    let kvserve = serve_section(opts.quick);
    let protosweep = proto_section(opts.quick);

    BenchReport {
        bench_id: opts.bench_id.clone(),
        quick: opts.quick,
        config: MachineConfig::default().describe(),
        wall_clock_secs: t0.elapsed().as_secs_f64(),
        note: String::new(),
        scenarios,
        native,
        partition,
        kvserve,
        protosweep,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // tiny iteration counts: these assert the scenarios run and count,
    // not that they are fast
    #[test]
    fn engine_scenarios_count_their_ops() {
        assert_eq!(read_hit(64, true), 64);
        assert_eq!(read_hit(64, false), 64);
        assert!(cop_update(32, true) >= 64);
        assert!(cop_miss_retype(32, true) >= 96);
        assert_eq!(merge_on_evict(32, true), 64);
    }

    #[test]
    fn fast_slow_records_the_twin() {
        let s = fast_slow("memsys_read_hit", 64, read_hit);
        assert_eq!(s.ops, 64);
        assert!(s.slow_mops.is_some());
        assert!(s.speedup().is_some());
    }

    #[test]
    fn partition_section_covers_both_policies_per_workload() {
        let rows = partition_section(true);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.verified, "{}-{} diverged under the co-runner", r.name, r.policy);
            assert!(r.cycles > 0);
            assert_eq!(r.corun, 2);
        }
        // unpartitioned cells carry no way telemetry; reuse cells do
        for r in rows.iter().filter(|r| r.policy == "none") {
            assert_eq!((r.ways_min, r.ways_max, r.ways_final, r.repartitions), (0, 0, 0, 0));
        }
        for r in rows.iter().filter(|r| r.policy == "reuse") {
            assert!(r.ways_max >= 1, "{}: no partition telemetry", r.name);
            assert!(r.ways_min >= 1);
        }
    }

    #[test]
    fn serve_section_tracks_the_deadline_axis() {
        let rows = serve_section(true);
        // ccache + atomic at each of the three deadlines
        assert_eq!(rows.len(), 2 * SERVE_DEADLINES.len());
        for r in &rows {
            assert!(r.verified, "{}-d{} diverged", r.variant, r.deadline);
            assert!(r.cycles > 0 && r.ops > 0);
            match r.variant.as_str() {
                "atomic" => assert_eq!(r.staleness_max, 0, "atomic published late"),
                "ccache" => assert!(r.staleness_max <= r.deadline as u64),
                other => panic!("unexpected variant {other}"),
            }
        }
    }

    #[test]
    fn proto_section_covers_every_protocol() {
        let rows = proto_section(true);
        for p in ["mesi", "dragon", "partial"] {
            assert!(
                rows.iter().any(|r| r.protocol == p && r.supported),
                "no supported {p} cell in the record"
            );
        }
        for r in &rows {
            if r.supported {
                assert!(r.verified, "{}-{}-{} diverged", r.name, r.protocol, r.variant);
                assert!(r.cycles > 0);
            } else {
                assert_eq!(r.cycles, 0);
            }
            if r.protocol != "dragon" {
                assert_eq!(r.dragon_updates, 0, "{}-{} broadcast updates", r.name, r.protocol);
            }
        }
    }

    #[test]
    fn native_section_verifies_all_cells() {
        let rows = native_section(true);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.verified, "{}-{} diverged on the native backend", r.name, r.variant);
            assert!(r.ops > 0, "{}-{} counted no operations", r.name, r.variant);
            assert!(r.sim_cycles > 0);
        }
    }
}
