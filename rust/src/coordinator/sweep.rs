//! Working-set sweeps: the x-axis of Fig 6 / Fig 8.

use crate::exec::{RunResult, Variant};
use crate::sim::config::MachineConfig;

use super::experiment::{sized_benchmark, BenchKind};

/// The paper's input sizes relative to LLC capacity (Section 6.1).
pub const WS_FRACTIONS: [f64; 5] = [0.25, 0.5, 1.0, 2.0, 4.0];

#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub frac: f64,
    pub results: Vec<RunResult>,
}

impl SweepPoint {
    pub fn get(&self, v: Variant) -> Option<&RunResult> {
        self.results.iter().find(|r| r.variant == v)
    }

    /// Speedup of `v` relative to the FGL baseline at this point.
    pub fn speedup_vs_fgl(&self, v: Variant) -> Option<f64> {
        let base = self.get(Variant::Fgl)?;
        let other = self.get(v)?;
        Some(base.cycles() as f64 / other.cycles() as f64)
    }
}

#[derive(Clone, Debug)]
pub struct SweepResult {
    pub kind: BenchKind,
    pub points: Vec<SweepPoint>,
}

/// Run `variants` of `kind` at each working-set fraction.
pub fn run_sweep(
    kind: BenchKind,
    variants: &[Variant],
    fracs: &[f64],
    cfg: MachineConfig,
    seed: u64,
) -> SweepResult {
    let mut points = Vec::new();
    for &frac in fracs {
        let bench = sized_benchmark(kind, frac, cfg.llc.size_bytes, seed);
        // variants are independent machines: run them on parallel host
        // threads (results and their determinism are unaffected)
        let results: Vec<RunResult> = std::thread::scope(|scope| {
            let handles: Vec<_> = variants
                .iter()
                .map(|&v| {
                    let bench = &bench;
                    scope.spawn(move || bench.run(v, cfg))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in &results {
            assert!(
                r.verified,
                "{}/{} diverged at frac {frac}",
                r.benchmark,
                r.variant.name()
            );
        }
        points.push(SweepPoint { frac, results });
    }
    SweepResult { kind, points }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::MachineConfig;

    #[test]
    fn tiny_sweep_produces_speedups() {
        let mut cfg = MachineConfig::test_small();
        cfg.cores = 2;
        let sweep = run_sweep(
            BenchKind::KvAdd,
            &[Variant::Fgl, Variant::CCache],
            &[0.5, 1.0],
            cfg,
            42,
        );
        assert_eq!(sweep.points.len(), 2);
        for p in &sweep.points {
            assert!(p.speedup_vs_fgl(Variant::CCache).unwrap() > 0.0);
            assert_eq!(p.speedup_vs_fgl(Variant::Fgl).unwrap(), 1.0);
        }
    }
}
