//! Working-set sweeps: the x-axis of Fig 6 / Fig 8. Registry-driven:
//! a sweep takes a registered workload name, rebuilds the sized instance
//! at each fraction, and runs every *supported* requested variant —
//! unsupported variants skip their cell instead of aborting the sweep.

use crate::exec::registry::{self, SizeSpec};
use crate::exec::{RunResult, Variant};
use crate::sim::config::MachineConfig;

/// The paper's input sizes relative to LLC capacity (Section 6.1).
pub const WS_FRACTIONS: [f64; 5] = [0.25, 0.5, 1.0, 2.0, 4.0];

#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub frac: f64,
    pub results: Vec<RunResult>,
}

impl SweepPoint {
    pub fn get(&self, v: Variant) -> Option<&RunResult> {
        self.results.iter().find(|r| r.variant == v)
    }

    /// Speedup of `v` relative to the FGL baseline at this point.
    pub fn speedup_vs_fgl(&self, v: Variant) -> Option<f64> {
        let base = self.get(Variant::Fgl)?;
        let other = self.get(v)?;
        Some(base.cycles() as f64 / other.cycles() as f64)
    }
}

#[derive(Clone, Debug)]
pub struct SweepResult {
    /// Registry name of the swept benchmark.
    pub name: String,
    pub points: Vec<SweepPoint>,
}

/// Run `variants` of the registered benchmark `name` at each working-set
/// fraction. Variants the benchmark does not support are skipped (their
/// cells render as "-"); divergence from the golden run still panics.
/// Panics on unknown benchmark names.
pub fn run_sweep(
    name: &str,
    variants: &[Variant],
    fracs: &[f64],
    cfg: MachineConfig,
    seed: u64,
) -> SweepResult {
    run_sweep_skewed(name, variants, fracs, cfg, seed, 0.0)
}

/// [`run_sweep`] with a zipf key-skew theta for the workloads that have
/// a key distribution (kvstore, histogram).
pub fn run_sweep_skewed(
    name: &str,
    variants: &[Variant],
    fracs: &[f64],
    cfg: MachineConfig,
    seed: u64,
    zipf_theta: f64,
) -> SweepResult {
    let spec = registry::lookup(name).unwrap_or_else(|e| panic!("{e}"));
    assert!(
        zipf_theta == 0.0 || spec.key_skew,
        "{} has no key distribution; zipf_theta {zipf_theta} would be silently ignored",
        spec.name
    );
    let mut points = Vec::new();
    for &frac in fracs {
        let size = SizeSpec::new(frac, cfg.llc.size_bytes, seed).with_zipf(zipf_theta);
        let bench = spec.build(&size);
        let supported: Vec<Variant> = variants
            .iter()
            .copied()
            .filter(|&v| bench.supports(v))
            .collect();
        // variants are independent machines: run them on parallel host
        // threads (results and their determinism are unaffected)
        let results: Vec<RunResult> = std::thread::scope(|scope| {
            let handles: Vec<_> = supported
                .iter()
                .map(|&v| {
                    let bench = &bench;
                    scope.spawn(move || {
                        bench.run(v, cfg).unwrap_or_else(|e| panic!("{e}"))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in &results {
            assert!(
                r.verified,
                "{}/{} diverged at frac {frac}",
                r.benchmark,
                r.variant.name()
            );
        }
        points.push(SweepPoint { frac, results });
    }
    SweepResult {
        name: spec.name.to_string(),
        points,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::MachineConfig;

    #[test]
    fn tiny_sweep_produces_speedups() {
        let mut cfg = MachineConfig::test_small();
        cfg.cores = 2;
        let sweep = run_sweep(
            "kvstore",
            &[Variant::Fgl, Variant::CCache],
            &[0.5, 1.0],
            cfg,
            42,
        );
        assert_eq!(sweep.points.len(), 2);
        for p in &sweep.points {
            assert!(p.speedup_vs_fgl(Variant::CCache).unwrap() > 0.0);
            assert_eq!(p.speedup_vs_fgl(Variant::Fgl).unwrap(), 1.0);
        }
    }

    #[test]
    fn unsupported_variants_skip_cells_instead_of_aborting() {
        let mut cfg = MachineConfig::test_small();
        cfg.cores = 2;
        // kmeans has no atomics variant: the cell is skipped, the sweep
        // still completes with the supported variants present
        let sweep = run_sweep(
            "kmeans",
            &[Variant::CCache, Variant::Atomic],
            &[0.05],
            cfg,
            42,
        );
        assert_eq!(sweep.points.len(), 1);
        assert!(sweep.points[0].get(Variant::CCache).is_some());
        assert!(sweep.points[0].get(Variant::Atomic).is_none());
    }
}
