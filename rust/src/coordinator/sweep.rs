//! Working-set sweeps: the x-axis of Fig 6 / Fig 8. Registry-driven:
//! a sweep takes a registered workload name, rebuilds the sized instance
//! at each fraction, and runs every *supported* requested variant —
//! unsupported variants skip their cell instead of aborting the sweep.
//!
//! Every (fraction, variant) cell is an independent
//! [`Machine`](crate::sim::machine::Machine) run, so
//! the sweep fans the whole cell grid out over a scoped worker pool
//! ([`SweepOptions::jobs`], default: all host cores). Cell results are
//! bit-identical to serial execution — each cell builds its own machine
//! and the deterministic interleaver never observes the host schedule —
//! and are reassembled in cell order, so `--jobs N` changes wall-clock
//! only. The elapsed time is recorded in [`SweepResult::wall_clock_ms`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::exec::registry::{self, SizeSpec, SketchSpec};
use crate::exec::workload::WorkloadHandle;
use crate::exec::{RunResult, Variant};
use crate::sim::config::MachineConfig;

/// The paper's input sizes relative to LLC capacity (Section 6.1).
pub const WS_FRACTIONS: [f64; 5] = [0.25, 0.5, 1.0, 2.0, 4.0];

/// Knobs for one sweep run.
#[derive(Clone, Copy, Debug)]
pub struct SweepOptions {
    pub seed: u64,
    /// 0.0 = uniform keys; >0 = zipf skew for workloads with a key
    /// distribution.
    pub zipf_theta: f64,
    /// Worker threads for the cell grid; 0 = all host cores.
    pub jobs: usize,
    /// Sketch geometry knobs (ignored by non-sketch workloads).
    pub sketch: SketchSpec,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            seed: 42,
            zipf_theta: 0.0,
            jobs: 0,
            sketch: SketchSpec::default(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub frac: f64,
    pub results: Vec<RunResult>,
}

impl SweepPoint {
    pub fn get(&self, v: Variant) -> Option<&RunResult> {
        self.results.iter().find(|r| r.variant == v)
    }

    /// Speedup of `v` relative to the FGL baseline at this point.
    /// `None` when either cell is missing *or* reports zero cycles — a
    /// zero-cycle cell is a degenerate (empty-program) run, and dividing
    /// by it would leak `inf`/`NaN` into tables and `sweep --json`.
    pub fn speedup_vs_fgl(&self, v: Variant) -> Option<f64> {
        let base = self.get(Variant::Fgl)?;
        let other = self.get(v)?;
        if base.cycles() == 0 || other.cycles() == 0 {
            return None;
        }
        Some(base.cycles() as f64 / other.cycles() as f64)
    }
}

#[derive(Clone, Debug)]
pub struct SweepResult {
    /// Registry name of the swept benchmark.
    pub name: String,
    pub points: Vec<SweepPoint>,
    /// Host wall-clock the cell grid took, in milliseconds.
    pub wall_clock_ms: f64,
    /// Worker threads the grid ran on.
    pub jobs: usize,
}

impl SweepResult {
    /// Sorted, deduplicated names of the merge functions installed
    /// across the sweep's cells (CCache cells carry them; lock/dup
    /// cells install none) — the merge identity reports print.
    pub fn merge_fns(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .points
            .iter()
            .flat_map(|p| p.results.iter())
            .flat_map(|r| r.merge_fns.iter().cloned())
            .collect();
        names.sort();
        names.dedup();
        names
    }
}

/// Run `variants` of the registered benchmark `name` at each working-set
/// fraction (serial-equivalent parallel execution, auto job count).
/// Variants the benchmark does not support are skipped (their cells
/// render as "-"); divergence from the golden run still panics. Panics
/// on unknown benchmark names or an invalid machine config.
pub fn run_sweep(
    name: &str,
    variants: &[Variant],
    fracs: &[f64],
    cfg: MachineConfig,
    seed: u64,
) -> SweepResult {
    run_sweep_with(
        name,
        variants,
        fracs,
        cfg,
        SweepOptions {
            seed,
            ..Default::default()
        },
    )
}

/// [`run_sweep`] with a zipf key-skew theta for the workloads that have
/// a key distribution (kvstore, histogram).
pub fn run_sweep_skewed(
    name: &str,
    variants: &[Variant],
    fracs: &[f64],
    cfg: MachineConfig,
    seed: u64,
    zipf_theta: f64,
) -> SweepResult {
    run_sweep_with(
        name,
        variants,
        fracs,
        cfg,
        SweepOptions {
            seed,
            zipf_theta,
            ..Default::default()
        },
    )
}

/// The general form: every option explicit.
pub fn run_sweep_with(
    name: &str,
    variants: &[Variant],
    fracs: &[f64],
    cfg: MachineConfig,
    opts: SweepOptions,
) -> SweepResult {
    let spec = registry::lookup(name).unwrap_or_else(|e| panic!("{e}"));
    assert!(
        opts.zipf_theta == 0.0 || spec.key_skew,
        "{} has no key distribution; zipf_theta {} would be silently ignored",
        spec.name,
        opts.zipf_theta
    );
    cfg.validate().unwrap_or_else(|e| panic!("{e}"));
    let t0 = Instant::now();

    // one sized instance per fraction, shared by its variants
    let benches: Vec<(f64, WorkloadHandle)> = fracs
        .iter()
        .map(|&frac| {
            let size = SizeSpec::new(frac, cfg.llc().size_bytes, opts.seed)
                .with_zipf(opts.zipf_theta)
                .with_sketch(opts.sketch);
            (frac, spec.build(&size))
        })
        .collect();

    // the independent cell grid: (point index, bench, variant)
    let cells: Vec<(usize, &WorkloadHandle, Variant)> = benches
        .iter()
        .enumerate()
        .flat_map(|(pi, (_, bench))| {
            variants
                .iter()
                .copied()
                .filter(|&v| bench.supports(v))
                .map(move |v| (pi, bench, v))
                .collect::<Vec<_>>()
        })
        .collect();

    let jobs = effective_jobs(opts.jobs, cells.len());
    let results: Vec<RunResult> = if jobs <= 1 {
        cells
            .iter()
            .map(|&(_, bench, v)| {
                bench.run(v, cfg.clone()).unwrap_or_else(|e| panic!("{e}"))
            })
            .collect()
    } else {
        let next = AtomicUsize::new(0);
        let slots: Mutex<Vec<Option<RunResult>>> = Mutex::new(vec![None; cells.len()]);
        std::thread::scope(|scope| {
            for _ in 0..jobs {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cells.len() {
                        break;
                    }
                    let (_, bench, v) = cells[i];
                    let r = bench.run(v, cfg.clone()).unwrap_or_else(|e| panic!("{e}"));
                    slots.lock().unwrap()[i] = Some(r);
                });
            }
        });
        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("every cell completed"))
            .collect()
    };

    // reassemble in cell order (frac-major, then requested variant
    // order) — independent of which worker ran which cell
    let mut points: Vec<SweepPoint> = benches
        .iter()
        .map(|&(frac, _)| SweepPoint {
            frac,
            results: Vec::new(),
        })
        .collect();
    for (&(pi, _, _), r) in cells.iter().zip(results) {
        assert!(
            r.verified,
            "{}/{} diverged at frac {}",
            r.benchmark,
            r.variant.name(),
            points[pi].frac
        );
        points[pi].results.push(r);
    }
    SweepResult {
        name: spec.name.to_string(),
        points,
        wall_clock_ms: t0.elapsed().as_secs_f64() * 1e3,
        jobs,
    }
}

fn effective_jobs(requested: usize, cells: usize) -> usize {
    let auto = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let j = if requested == 0 { auto } else { requested };
    j.clamp(1, cells.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::MachineConfig;

    #[test]
    fn tiny_sweep_produces_speedups() {
        let mut cfg = MachineConfig::test_small();
        cfg.cores = 2;
        let sweep = run_sweep(
            "kvstore",
            &[Variant::Fgl, Variant::CCache],
            &[0.5, 1.0],
            cfg,
            42,
        );
        assert_eq!(sweep.points.len(), 2);
        assert!(sweep.jobs >= 1);
        assert!(sweep.wall_clock_ms > 0.0);
        for p in &sweep.points {
            assert!(p.speedup_vs_fgl(Variant::CCache).unwrap() > 0.0);
            assert_eq!(p.speedup_vs_fgl(Variant::Fgl).unwrap(), 1.0);
        }
        // the installed merge identity is visible on the sweep
        assert_eq!(sweep.merge_fns(), vec!["add_u32".to_string()]);
    }

    #[test]
    fn zero_cycle_cells_report_no_speedup_instead_of_inf() {
        use crate::exec::RunResult;
        use crate::sim::stats::Stats;
        let mk = |v: Variant, cyc: u64| RunResult {
            benchmark: "synthetic".into(),
            variant: v,
            stats: {
                let mut s = Stats::new(1, 3);
                s.core_cycles = vec![cyc];
                s
            },
            verified: true,
            quality: None,
            merge_fns: Vec::new(),
            wall_secs: None,
        };
        // degenerate CCache cell: zero cycles must not divide through
        let p = SweepPoint {
            frac: 1.0,
            results: vec![mk(Variant::Fgl, 100), mk(Variant::CCache, 0)],
        };
        assert_eq!(p.speedup_vs_fgl(Variant::CCache), None);
        // degenerate baseline poisons every ratio the same way
        let p = SweepPoint {
            frac: 1.0,
            results: vec![mk(Variant::Fgl, 0), mk(Variant::CCache, 50)],
        };
        assert_eq!(p.speedup_vs_fgl(Variant::CCache), None);
        assert_eq!(p.speedup_vs_fgl(Variant::Fgl), None);
        // healthy cells are unaffected
        let p = SweepPoint {
            frac: 1.0,
            results: vec![mk(Variant::Fgl, 100), mk(Variant::CCache, 50)],
        };
        assert_eq!(p.speedup_vs_fgl(Variant::CCache), Some(2.0));
    }

    #[test]
    fn unsupported_variants_skip_cells_instead_of_aborting() {
        let mut cfg = MachineConfig::test_small();
        cfg.cores = 2;
        // kmeans has no atomics variant: the cell is skipped, the sweep
        // still completes with the supported variants present
        let sweep = run_sweep(
            "kmeans",
            &[Variant::CCache, Variant::Atomic],
            &[0.05],
            cfg,
            42,
        );
        assert_eq!(sweep.points.len(), 1);
        assert!(sweep.points[0].get(Variant::CCache).is_some());
        assert!(sweep.points[0].get(Variant::Atomic).is_none());
    }

    #[test]
    fn parallel_jobs_match_serial_cell_for_cell() {
        let cfg = MachineConfig::test_small().with_cores(2);
        let mk = |jobs: usize| {
            run_sweep_with(
                "kvstore",
                &[Variant::Fgl, Variant::CCache],
                &[0.25, 0.5],
                cfg.clone(),
                SweepOptions {
                    seed: 7,
                    jobs,
                    ..Default::default()
                },
            )
        };
        let serial = mk(1);
        let parallel = mk(4);
        assert_eq!(serial.jobs, 1);
        assert_eq!(serial.points.len(), parallel.points.len());
        for (ps, pp) in serial.points.iter().zip(&parallel.points) {
            assert_eq!(ps.frac, pp.frac);
            assert_eq!(ps.results.len(), pp.results.len());
            for (rs, rp) in ps.results.iter().zip(&pp.results) {
                assert_eq!(rs.variant, rp.variant);
                assert_eq!(rs.cycles(), rp.cycles(), "cycles diverged under --jobs");
                assert_eq!(rs.stats.merges, rp.stats.merges);
                assert_eq!(rs.stats.llc().misses, rp.stats.llc().misses);
                assert_eq!(rs.stats.directory_msgs, rp.stats.directory_msgs);
            }
        }
    }

    #[test]
    fn sweeps_run_on_a_2_level_hierarchy() {
        let cfg = MachineConfig::test_small_2level().with_cores(2);
        let sweep = run_sweep("kvstore", &[Variant::Fgl, Variant::CCache], &[0.25], cfg, 3);
        assert_eq!(sweep.points.len(), 1);
        assert!(sweep.points[0].speedup_vs_fgl(Variant::CCache).is_some());
    }
}
