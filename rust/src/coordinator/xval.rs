//! Backend cross-validation: every registered workload, every variant it
//! supports, run on **both** execution backends against the same
//! sequential golden.
//!
//! The simulated backend ([`Backend::Sim`]) executes the program on the
//! deterministic interleaver and cycle model; the native backend
//! ([`Backend::Native`]) executes the *same* generic program (the
//! [`ExecCtx`](crate::exec::ExecCtx) trait is the only op surface either
//! one sees) on real OS threads with real atomics and software
//! privatization. Both end states are checked against the workload's
//! sequential golden run, so a pass means the CCache semantics — COps,
//! soft merge, explicit merge, merge-function identity — survive the
//! trip from model to metal. This is the `ccache xval` subcommand and
//! the CI `native-xval` job.

use std::time::Instant;

use crate::exec::registry::{self, SizeSpec};
use crate::exec::{Backend, Variant};
use crate::sim::config::MachineConfig;
use crate::util::bench::Table;

/// Knobs for one cross-validation pass.
#[derive(Clone, Debug)]
pub struct XvalOptions {
    /// Cores for both backends (native spawns this many OS threads).
    pub cores: usize,
    /// Working-set fraction of the (small) validation machine's LLC.
    pub frac: f64,
    pub seed: u64,
    /// Restrict to these registry names (empty = the whole registry).
    pub only: Vec<String>,
}

impl Default for XvalOptions {
    fn default() -> Self {
        Self {
            cores: 4,
            frac: 0.25,
            seed: 42,
            only: Vec::new(),
        }
    }
}

/// One (workload, variant) cell run on both backends.
#[derive(Clone, Debug)]
pub struct XvalCell {
    pub workload: String,
    pub variant: Variant,
    /// Simulated cycle count (the model's currency).
    pub sim_cycles: u64,
    /// Native operations executed across all threads.
    pub native_ops: u64,
    /// Wall-clock seconds of the native parallel section.
    pub native_secs: f64,
    pub sim_verified: bool,
    pub native_verified: bool,
}

impl XvalCell {
    /// Both backends reached the golden memory image.
    pub fn pass(&self) -> bool {
        self.sim_verified && self.native_verified
    }

    /// Measured native throughput in Mops/s (0 for a degenerate timer).
    pub fn native_mops(&self) -> f64 {
        if self.native_secs > 0.0 {
            self.native_ops as f64 / self.native_secs / 1e6
        } else {
            0.0
        }
    }
}

#[derive(Clone, Debug)]
pub struct XvalReport {
    pub cells: Vec<XvalCell>,
    pub wall_clock_secs: f64,
}

impl XvalReport {
    /// Every cell passed on both backends.
    pub fn all_verified(&self) -> bool {
        self.cells.iter().all(XvalCell::pass)
    }

    /// Names of the cells that failed, as `workload/variant` strings.
    pub fn failures(&self) -> Vec<String> {
        self.cells
            .iter()
            .filter(|c| !c.pass())
            .map(|c| format!("{}/{}", c.workload, c.variant.name()))
            .collect()
    }

    /// Human-readable summary table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Backend cross-validation — {} cells, {}",
                self.cells.len(),
                if self.all_verified() {
                    "all verified"
                } else {
                    "FAILURES"
                }
            ),
            &["workload", "variant", "sim cycles", "native Mops/s", "sim", "native"],
        );
        for c in &self.cells {
            t.row(&[
                c.workload.clone(),
                c.variant.name().into(),
                c.sim_cycles.to_string(),
                format!("{:.2}", c.native_mops()),
                if c.sim_verified { "ok" } else { "FAIL" }.into(),
                if c.native_verified { "ok" } else { "FAIL" }.into(),
            ]);
        }
        t
    }
}

/// Run the cross-validation grid. Panics only on machine-config or
/// driver errors — a golden divergence is *recorded* in the cell (and
/// fails [`XvalReport::all_verified`]) so one bad cell doesn't hide the
/// rest of the grid.
pub fn run_xval(opts: &XvalOptions) -> XvalReport {
    let cfg = MachineConfig::test_small().with_cores(opts.cores);
    let t0 = Instant::now();
    let mut cells = Vec::new();
    for spec in registry::registry() {
        if !opts.only.is_empty() && !opts.only.iter().any(|n| n == spec.name) {
            continue;
        }
        let size = SizeSpec::new(opts.frac, cfg.llc().size_bytes, opts.seed);
        let bench = spec.build(&size);
        for &variant in spec.variants {
            let sim = bench
                .run_on(Backend::Sim, variant, cfg.clone())
                .unwrap_or_else(|e| panic!("{}/{} (sim): {e}", spec.name, variant.name()));
            let nat = bench
                .run_on(Backend::Native, variant, cfg.clone())
                .unwrap_or_else(|e| panic!("{}/{} (native): {e}", spec.name, variant.name()));
            cells.push(XvalCell {
                workload: spec.name.to_string(),
                variant,
                sim_cycles: sim.cycles(),
                native_ops: nat.ops_total(),
                native_secs: nat.wall_secs.unwrap_or(0.0),
                sim_verified: sim.verified,
                native_verified: nat.verified,
            });
        }
    }
    XvalReport {
        cells,
        wall_clock_secs: t0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subset_grid_passes_on_both_backends() {
        let report = run_xval(&XvalOptions {
            cores: 2,
            only: vec!["kvstore".into(), "bloom".into()],
            ..Default::default()
        });
        // kvstore: 4 variants (cgl/fgl/dup/ccache), bloom: 4
        // (fgl/dup/ccache/atomic) — one cell per supported variant
        assert_eq!(report.cells.len(), 8);
        assert!(
            report.all_verified(),
            "cross-validation failures: {:?}",
            report.failures()
        );
        for c in &report.cells {
            assert!(c.sim_cycles > 0, "{}/{} simulated no cycles", c.workload, c.variant.name());
            assert!(c.native_ops > 0, "{}/{} counted no native ops", c.workload, c.variant.name());
        }
        let rendered = report.table().render();
        assert!(rendered.contains("all verified"), "{rendered}");
        assert!(rendered.contains("kvstore"), "{rendered}");
    }

    #[test]
    fn failures_surface_in_the_table_title() {
        let mut report = run_xval(&XvalOptions {
            cores: 2,
            only: vec!["histogram".into()],
            ..Default::default()
        });
        assert!(report.all_verified());
        report.cells[0].native_verified = false;
        assert!(!report.all_verified());
        assert_eq!(report.failures().len(), 1);
        assert!(report.table().render().contains("FAILURES"));
    }
}
