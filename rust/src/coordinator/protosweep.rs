//! The protocol sweep: coherence protocol x execution variant x
//! benchmark, the experiment behind the protocol-generic hierarchy
//! refactor: *which coherence protocol serves which sharing pattern,
//! and does CCache keep winning under all of them?*
//!
//! Each cell is one simulated run of a benchmark/variant pair under one
//! [`ProtocolKind`]:
//! * **mesi** — the write-invalidate baseline every earlier experiment
//!   ran on (the refactor is pinned bit-identical to the pre-trait walk
//!   by `tests/mesi_refactor_diff.rs`);
//! * **dragon** — write-update: writes broadcast to sharers instead of
//!   invalidating them, trading invalidation+refetch storms for update
//!   bandwidth (`dragon_updates`/`update_words` count it);
//! * **partial** — the shared level stops ordering plain stores; only
//!   CCache merges and barrier flushes publish. Variants that need
//!   coherent RMWs (fgl, atomic, cgl) are typed-rejected
//!   ([`ExecError::UnsupportedProtocol`]) and recorded as unsupported
//!   cells, not failures.
//!
//! Cells fan out over the same scoped worker pool as
//! [`partsweep`](super::partsweep): each cell builds its own machine,
//! so results are bit-identical to serial execution and `--jobs`
//! changes wall-clock only.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::exec::{ExecError, RunResult, Variant, WorkloadHandle};
use crate::sim::config::MachineConfig;
use crate::sim::hierarchy::protocol::ProtocolKind;
use crate::util::bench::Table;

use super::experiment::{scaled_config, sized_workload};

/// Working-set fraction of the LLC every cell uses — big enough that
/// the shared structure spills across private caches (sharing traffic
/// is the whole point of a protocol sweep).
pub const PROTO_WS_FRAC: f64 = 0.5;

/// Workload cores every cell runs.
pub const PROTO_WORK_CORES: usize = 4;

/// The benchmark set; `--quick` keeps the first two.
pub const PROTO_BENCHES: [&str; 4] = ["kvstore", "kmeans", "pagerank-uniform", "kvserve"];

/// Knobs for one protocol sweep.
#[derive(Clone, Copy, Debug)]
pub struct ProtosweepOptions {
    /// Trim the grid for CI smoke: 2 benchmarks.
    pub quick: bool,
    /// Worker threads for the cell grid; 0 = all host cores.
    pub jobs: usize,
    pub seed: u64,
}

impl Default for ProtosweepOptions {
    fn default() -> Self {
        Self {
            quick: false,
            jobs: 0,
            seed: 42,
        }
    }
}

/// One grid cell: the axes plus the counters the trajectory record and
/// the CI schema check consume. `merge_fns`/`quality` are the shared
/// sweep-cell keys every coordinator emitter carries.
#[derive(Clone, Debug)]
pub struct ProtoCell {
    pub benchmark: String,
    /// Protocol token ([`ProtocolKind::name`]).
    pub protocol: &'static str,
    /// Variant token ([`Variant::name`]).
    pub variant: &'static str,
    /// False when the protocol typed-rejected the variant (partial x
    /// fgl); every timing field below is then zero.
    pub supported: bool,
    pub cycles: u64,
    pub verified: bool,
    pub dir_msgs: u64,
    pub invalidations: u64,
    pub dragon_updates: u64,
    pub llc_misses: u64,
    /// Merge functions installed in the MFRF (CCache cells; empty
    /// otherwise) — shared cell key with the other sweep emitters.
    pub merge_fns: Vec<String>,
    /// Quality metric of approximate variants (shared cell key; `null`
    /// for the exact protosweep benchmarks).
    pub quality: Option<f64>,
}

impl ProtoCell {
    fn from_run(
        benchmark: &str,
        protocol: ProtocolKind,
        variant: Variant,
        r: Option<&RunResult>,
    ) -> Self {
        match r {
            Some(r) => Self {
                benchmark: benchmark.to_string(),
                protocol: protocol.name(),
                variant: variant.name(),
                supported: true,
                cycles: r.cycles(),
                verified: r.verified,
                dir_msgs: r.stats.directory_msgs,
                invalidations: r.stats.invalidations,
                dragon_updates: r.stats.dragon_updates,
                llc_misses: r.stats.llc().misses,
                merge_fns: r.merge_fns.clone(),
                quality: r.quality,
            },
            None => Self {
                benchmark: benchmark.to_string(),
                protocol: protocol.name(),
                variant: variant.name(),
                supported: false,
                cycles: 0,
                verified: false,
                dir_msgs: 0,
                invalidations: 0,
                dragon_updates: 0,
                llc_misses: 0,
                merge_fns: Vec::new(),
                quality: None,
            },
        }
    }
}

/// A completed protocol sweep.
#[derive(Clone, Debug)]
pub struct ProtosweepResult {
    pub llc_bytes: usize,
    pub work_cores: usize,
    pub seed: u64,
    pub cells: Vec<ProtoCell>,
    pub wall_clock_ms: f64,
    pub jobs: usize,
}

impl ProtosweepResult {
    /// The headline: per protocol, the benchmarks where the CCache
    /// variant beats every other supported variant outright (strictly
    /// fewer cycles). Returned in [`ProtocolKind::ALL`] order.
    pub fn ccache_wins_by_protocol(&self) -> Vec<(&'static str, usize)> {
        ProtocolKind::ALL
            .iter()
            .map(|p| {
                let wins = self
                    .cells
                    .iter()
                    .filter(|c| {
                        c.protocol == p.name() && c.variant == "ccache" && c.supported
                    })
                    .filter(|cc| {
                        self.cells
                            .iter()
                            .filter(|o| {
                                o.protocol == cc.protocol
                                    && o.benchmark == cc.benchmark
                                    && o.variant != "ccache"
                                    && o.supported
                            })
                            .all(|o| cc.cycles < o.cycles)
                    })
                    .count();
                (p.name(), wins)
            })
            .collect()
    }

    /// Cells where a non-MESI protocol's cycle total differs from the
    /// MESI cell on the same benchmark/variant axes — the sweep is
    /// vacuous if the protocols never diverge.
    pub fn divergent_cells(&self) -> Vec<&ProtoCell> {
        self.cells
            .iter()
            .filter(|c| c.protocol != "mesi" && c.supported)
            .filter(|c| {
                self.cells.iter().any(|m| {
                    m.protocol == "mesi"
                        && m.benchmark == c.benchmark
                        && m.variant == c.variant
                        && m.cycles != c.cycles
                })
            })
            .collect()
    }

    /// Hand-rolled JSON (serde is unavailable offline), one object per
    /// cell under a top-level `"protosweep"` key, headlined by
    /// `ccache_wins_by_protocol`. Shape is pinned by the CI
    /// `protosweep-smoke` schema check.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(4096);
        out.push_str("{\n  \"protosweep\": {\n");
        out.push_str(&format!("    \"llc_bytes\": {},\n", self.llc_bytes));
        out.push_str(&format!("    \"work_cores\": {},\n", self.work_cores));
        out.push_str(&format!("    \"ws_frac\": {:.2},\n", PROTO_WS_FRAC));
        out.push_str(&format!("    \"seed\": {},\n", self.seed));
        out.push_str(&format!("    \"jobs\": {},\n", self.jobs));
        out.push_str(&format!(
            "    \"wall_clock_ms\": {:.1},\n",
            self.wall_clock_ms
        ));
        out.push_str("    \"ccache_wins_by_protocol\": {");
        for (i, (name, wins)) in self.ccache_wins_by_protocol().iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("\"{name}\": {wins}"));
        }
        out.push_str("},\n");
        out.push_str(&format!(
            "    \"divergent_cells\": {},\n",
            self.divergent_cells().len()
        ));
        out.push_str("    \"cells\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "      {{\"benchmark\": \"{}\", \"protocol\": \"{}\", \"variant\": \"{}\", \
                 \"supported\": {}, \"cycles\": {}, \"verified\": {}, \"dir_msgs\": {}, \
                 \"invalidations\": {}, \"dragon_updates\": {}, \"llc_misses\": {}, \
                 \"merge_fns\": [{}], \"quality\": {}}}",
                c.benchmark,
                c.protocol,
                c.variant,
                c.supported,
                c.cycles,
                c.verified,
                c.dir_msgs,
                c.invalidations,
                c.dragon_updates,
                c.llc_misses,
                c.merge_fns
                    .iter()
                    .map(|f| format!("\"{f}\""))
                    .collect::<Vec<_>>()
                    .join(", "),
                c.quality
                    .filter(|q| q.is_finite())
                    .map(|q| format!("{q:.6}"))
                    .unwrap_or_else(|| "null".into()),
            ));
        }
        out.push_str("\n    ]\n  }\n}\n");
        out
    }

    /// The grid as a paper-style ASCII table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "protosweep — cycles by protocol / variant / benchmark",
            &[
                "benchmark",
                "protocol",
                "variant",
                "Mcyc",
                "dir msg",
                "inval",
                "updates",
                "llc miss",
            ],
        );
        for c in &self.cells {
            if !c.supported {
                t.row(&[
                    c.benchmark.clone(),
                    c.protocol.to_string(),
                    c.variant.to_string(),
                    "unsupported".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            }
            t.row(&[
                c.benchmark.clone(),
                c.protocol.to_string(),
                c.variant.to_string(),
                format!("{:.2}", c.cycles as f64 / 1e6),
                c.dir_msgs.to_string(),
                c.invalidations.to_string(),
                c.dragon_updates.to_string(),
                c.llc_misses.to_string(),
            ]);
        }
        t
    }
}

/// Run the protocol sweep on the scaled bench machine.
pub fn run_protosweep(opts: ProtosweepOptions) -> ProtosweepResult {
    let mut base = scaled_config();
    base.cores = PROTO_WORK_CORES;
    run_protosweep_on(base, opts)
}

/// [`run_protosweep`] on an explicit base machine (tests use the small
/// config). `base.protocol` is ignored — the grid crosses every
/// registered protocol.
pub fn run_protosweep_on(base: MachineConfig, opts: ProtosweepOptions) -> ProtosweepResult {
    base.validate().unwrap_or_else(|e| panic!("{e}"));
    let t0 = Instant::now();
    let benches: &[&str] = if opts.quick {
        &PROTO_BENCHES[..2]
    } else {
        &PROTO_BENCHES
    };

    let handles: Vec<(&str, WorkloadHandle)> = benches
        .iter()
        .map(|&name| {
            (
                name,
                sized_workload(name, PROTO_WS_FRAC, base.llc().size_bytes, opts.seed),
            )
        })
        .collect();

    // the independent cell grid, benchmark-major, protocol-minor — so
    // the table groups a benchmark's protocol columns together
    struct CellSpec<'a> {
        name: &'a str,
        bench: &'a WorkloadHandle,
        protocol: ProtocolKind,
        variant: Variant,
        cfg: MachineConfig,
    }
    let cells: Vec<CellSpec> = handles
        .iter()
        .flat_map(|(name, bench)| {
            let name: &str = name;
            let base = &base;
            ProtocolKind::ALL.iter().flat_map(move |&protocol| {
                Variant::MAIN
                    .iter()
                    .filter(|v| bench.supports(**v))
                    .map(move |&variant| CellSpec {
                        name,
                        bench,
                        protocol,
                        variant,
                        cfg: base.clone().with_protocol(protocol),
                    })
            })
        })
        .collect();

    // a protocol rejecting a variant is a recorded grid fact, not a
    // failure; anything else aborts the sweep
    let run_cell = |spec: &CellSpec| -> Option<RunResult> {
        match spec.bench.run(spec.variant, spec.cfg.clone()) {
            Ok(r) => Some(r),
            Err(ExecError::UnsupportedProtocol { .. }) => None,
            Err(e) => panic!(
                "protosweep {}/{}/{}: {e}",
                spec.name,
                spec.protocol.name(),
                spec.variant.name()
            ),
        }
    };

    let jobs = effective_jobs(opts.jobs, cells.len());
    let results: Vec<Option<RunResult>> = if jobs <= 1 {
        cells.iter().map(run_cell).collect()
    } else {
        let next = AtomicUsize::new(0);
        let slots: Mutex<Vec<Option<Option<RunResult>>>> = Mutex::new(vec![None; cells.len()]);
        std::thread::scope(|scope| {
            for _ in 0..jobs {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cells.len() {
                        break;
                    }
                    let r = run_cell(&cells[i]);
                    slots.lock().unwrap()[i] = Some(r);
                });
            }
        });
        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("every cell completed"))
            .collect()
    };

    let out_cells: Vec<ProtoCell> = cells
        .iter()
        .zip(&results)
        .map(|(spec, r)| {
            if let Some(r) = r {
                assert!(
                    r.verified,
                    "protosweep {}/{}/{} diverged from the golden run",
                    spec.name,
                    spec.protocol.name(),
                    spec.variant.name()
                );
            }
            ProtoCell::from_run(spec.name, spec.protocol, spec.variant, r.as_ref())
        })
        .collect();

    ProtosweepResult {
        llc_bytes: base.llc().size_bytes,
        work_cores: base.cores,
        seed: opts.seed,
        cells: out_cells,
        wall_clock_ms: t0.elapsed().as_secs_f64() * 1e3,
        jobs,
    }
}

fn effective_jobs(requested: usize, cells: usize) -> usize {
    let auto = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let j = if requested == 0 { auto } else { requested };
    j.clamp(1, cells.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_opts() -> ProtosweepOptions {
        ProtosweepOptions {
            quick: true,
            jobs: 0,
            seed: 42,
        }
    }

    fn small_base() -> MachineConfig {
        MachineConfig::test_small().with_cores(2)
    }

    #[test]
    fn quick_grid_covers_every_protocol_and_variant() {
        let r = run_protosweep_on(small_base(), small_opts());
        // 2 benchmarks x 3 protocols x 3 variants
        assert_eq!(r.cells.len(), 18);
        for p in ProtocolKind::ALL {
            assert!(r.cells.iter().any(|c| c.protocol == p.name()));
        }
        // partial rejects fgl but runs dup and ccache
        for c in r.cells.iter().filter(|c| c.protocol == "partial") {
            assert_eq!(c.supported, c.variant != "fgl", "{c:?}");
        }
        // every supported cell ran and verified
        for c in r.cells.iter().filter(|c| c.supported) {
            assert!(c.verified, "{c:?}");
            assert!(c.cycles > 0, "{c:?}");
        }
        // unsupported cells carry no telemetry
        for c in r.cells.iter().filter(|c| !c.supported) {
            assert_eq!((c.cycles, c.dir_msgs, c.llc_misses), (0, 0, 0));
        }
    }

    #[test]
    fn non_mesi_protocols_actually_change_the_timing() {
        // the sweep's non-vacuity: dragon and partial must each produce
        // a different cycle total than mesi on at least one
        // sharing-heavy cell, and only dragon ever broadcasts updates
        let r = run_protosweep_on(small_base(), small_opts());
        let div = r.divergent_cells();
        for p in ["dragon", "partial"] {
            assert!(
                div.iter().any(|c| c.protocol == p),
                "{p} never diverged from mesi:\n{}",
                r.table().render()
            );
        }
        assert!(
            r.cells
                .iter()
                .any(|c| c.protocol == "dragon" && c.dragon_updates > 0),
            "dragon cells never broadcast an update"
        );
        for c in r.cells.iter().filter(|c| c.protocol != "dragon") {
            assert_eq!(c.dragon_updates, 0, "{c:?}");
        }
        // partial's whole point: private hits never consult the
        // directory, so its dup cells send no directory messages
        for c in r
            .cells
            .iter()
            .filter(|c| c.protocol == "partial" && c.supported)
        {
            assert_eq!((c.dir_msgs, c.invalidations), (0, 0), "{c:?}");
        }
    }

    #[test]
    fn json_shape_is_stable_for_the_ci_schema_check() {
        let mut opts = small_opts();
        opts.jobs = 1;
        let r = run_protosweep_on(small_base(), opts);
        let j = r.to_json();
        assert!(j.contains("\"protosweep\""), "{j}");
        for key in [
            "\"ccache_wins_by_protocol\"",
            "\"divergent_cells\"",
            "\"benchmark\"",
            "\"protocol\"",
            "\"variant\"",
            "\"supported\"",
            "\"cycles\"",
            "\"verified\"",
            "\"dir_msgs\"",
            "\"invalidations\"",
            "\"dragon_updates\"",
            "\"llc_misses\"",
            "\"merge_fns\"",
            "\"quality\"",
            "\"mesi\"",
            "\"dragon\"",
            "\"partial\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert_eq!(j.matches('{').count(), j.matches('}').count(), "{j}");
        assert_eq!(j.matches('[').count(), j.matches(']').count(), "{j}");
    }

    #[test]
    fn parallel_cells_match_serial_cell_for_cell() {
        let serial = run_protosweep_on(
            small_base(),
            ProtosweepOptions {
                jobs: 1,
                ..small_opts()
            },
        );
        let parallel = run_protosweep_on(
            small_base(),
            ProtosweepOptions {
                jobs: 4,
                ..small_opts()
            },
        );
        assert_eq!(serial.cells.len(), parallel.cells.len());
        for (s, p) in serial.cells.iter().zip(&parallel.cells) {
            assert_eq!(s.benchmark, p.benchmark);
            assert_eq!(s.protocol, p.protocol);
            assert_eq!(s.variant, p.variant);
            assert_eq!(s.cycles, p.cycles, "cycles diverged under --jobs");
            assert_eq!(s.dir_msgs, p.dir_msgs);
            assert_eq!(s.llc_misses, p.llc_misses);
        }
    }

    #[test]
    fn headline_counts_only_outright_wins() {
        let r = run_protosweep_on(small_base(), small_opts());
        let wins = r.ccache_wins_by_protocol();
        assert_eq!(wins.len(), ProtocolKind::ALL.len());
        for (name, count) in &wins {
            assert!(
                ProtocolKind::ALL.iter().any(|p| p.name() == *name),
                "{name}"
            );
            // quick grid: at most 2 benchmarks can be won per protocol
            assert!(*count <= 2, "{name}: {count}");
        }
    }
}
