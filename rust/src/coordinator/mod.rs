//! Experiment coordination: the scaled bench machine, registry-driven
//! working-set sweeps, paper-style reporting and the [`perf`] hot-path
//! suite (`ccache bench`). Every figure/table bench target is a thin
//! wrapper over this module; benchmark enumeration and sizing live in
//! [`exec::registry`](crate::exec::registry).

pub mod experiment;
pub mod partsweep;
pub mod perf;
pub mod protosweep;
pub mod report;
pub mod serve;
pub mod sweep;
pub mod xval;

pub use experiment::{run_verified, scaled_config, sized_workload, SCALED_LLC_BYTES};
pub use partsweep::{run_partsweep, run_partsweep_on, PartsweepOptions, PartsweepResult};
pub use protosweep::{
    run_protosweep, run_protosweep_on, ProtosweepOptions, ProtosweepResult,
};
pub use serve::{run_serve, run_serve_on, ServeOptions, ServeResult};
pub use sweep::{
    run_sweep, run_sweep_skewed, run_sweep_with, SweepOptions, SweepPoint, SweepResult,
    WS_FRACTIONS,
};
pub use xval::{run_xval, XvalOptions, XvalReport};
