//! Experiment coordination: benchmark sizing, working-set sweeps and
//! paper-style reporting. Every figure/table bench target is a thin
//! wrapper over this module.

pub mod experiment;
pub mod report;
pub mod sweep;

pub use experiment::{scaled_config, sized_benchmark, BenchKind, SCALED_LLC_BYTES};
pub use sweep::{run_sweep, SweepPoint, SweepResult, WS_FRACTIONS};
