//! The serving sweep behind `ccache serve`: merge-deadline x skew x
//! variant over the [`kvserve`](crate::workloads::kvserve) tier, with
//! the **staleness-vs-throughput frontier** as the headline result.
//!
//! Each cell is one epoch-phased serving run. The grid crosses:
//! * **merge deadline** — how many unmerged updates a core may sit on
//!   before being forced to publish ([`SERVE_DEADLINES`]). Only the
//!   ccache variant consumes it; the coherent baselines ride along at
//!   every deadline so each frontier point carries its own baselines;
//! * **base skew** — the tenants' zipf theta the drift schedule
//!   oscillates around (`--quick` keeps one);
//! * **variant** — fgl, atomic, dup, ccache ([`kvserve::VARIANTS`]).
//!
//! The sweep composes with the rest of the bench harness: an optional
//! streaming co-runner ([`CorunSpec`]) and an optional reuse-aware LLC
//! way partition squeeze the serving tier exactly like `partsweep`
//! cells, and one ccache cell is re-run on the native-thread backend as
//! a golden cross-check. Cells fan out over the same scoped worker pool
//! as [`sweep`](super::sweep)/[`partsweep`](super::partsweep), so
//! results are bit-identical to serial execution.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::exec::{driver, CorunSpec, RunResult, Variant};
use crate::sim::config::MachineConfig;
use crate::sim::hierarchy::level::PartitionPolicy;
use crate::util::bench::Table;
use crate::workloads::kvserve::{KvServeWorkload, ServeParams, Staleness, VARIANTS};
use crate::workloads::traffic::{Mix, TrafficSpec};

use super::experiment::scaled_config;

/// Serving-table fraction of the LLC. Quarter-LLC keeps room for the
/// merge region and the co-runner experiments.
pub const SERVE_WS_FRAC: f64 = 0.25;

/// Front-end cores the tier runs on (co-runner cores ride on top).
pub const SERVE_WORK_CORES: usize = 4;

/// The merge-deadline axis, in unmerged updates per core. All three
/// survive `--quick` — the frontier *is* the experiment.
pub const SERVE_DEADLINES: [usize; 3] = [16, 64, 256];

/// Base zipf skews; `--quick` keeps the first.
pub const SERVE_SKEWS: [f64; 2] = [0.6, 0.9];

/// Knobs for one serving sweep (the `ccache serve` subcommand).
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Trim for CI smoke: one skew, shorter epochs.
    pub quick: bool,
    /// Worker threads for the cell grid; 0 = all host cores.
    pub jobs: usize,
    pub seed: u64,
    /// Tenants in the tier (0 = default 4).
    pub tenants: usize,
    /// Shards the tenants map onto (0 = one per tenant).
    pub shards: usize,
    pub mix: Mix,
    /// Peak amplitude of the per-epoch skew drift.
    pub skew_drift: f64,
    /// Pin the deadline axis to one value (0 = sweep the full axis).
    pub deadline: usize,
    /// Streaming co-runner cores (0 = none).
    pub corun_cores: usize,
    /// Reuse-aware merge-region ways (0 = unpartitioned LLC).
    pub partition_ways: usize,
    /// Re-run one ccache cell on the native backend as a cross-check.
    pub native_check: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            quick: false,
            jobs: 0,
            seed: 42,
            tenants: 0,
            shards: 0,
            mix: Mix::default(),
            skew_drift: 0.2,
            deadline: 0,
            corun_cores: 0,
            partition_ways: 0,
            native_check: true,
        }
    }
}

/// One grid cell: axes plus the measurements the report, the JSON
/// record and the CI schema check consume.
#[derive(Clone, Debug)]
pub struct ServeCell {
    /// Merge deadline this frontier point ran under (ccache consumes
    /// it; baselines carry it as their grid coordinate).
    pub deadline: usize,
    pub skew: f64,
    pub variant: Variant,
    pub cycles: u64,
    /// Requests served (the trace length, identical for every variant
    /// on the same axes).
    pub ops: u64,
    pub verified: bool,
    pub merges: u64,
    pub merge_fns: Vec<String>,
    /// The measured staleness bound: max age, in ops, of an update at
    /// publication.
    pub staleness_max: u64,
    pub staleness_mean: f64,
    /// [`RunResult::quality`] — the mean staleness age, reported like
    /// hll's cardinality error.
    pub quality: Option<f64>,
}

impl ServeCell {
    /// Simulated throughput: requests served per thousand cycles.
    pub fn ops_per_kcycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.ops as f64 * 1e3 / self.cycles as f64
        }
    }
}

/// A completed serving sweep.
#[derive(Clone, Debug)]
pub struct ServeResult {
    pub llc_bytes: usize,
    pub work_cores: usize,
    pub seed: u64,
    pub tenants: usize,
    pub shards: usize,
    pub mix: Mix,
    pub skew_drift: f64,
    pub corun: usize,
    pub partition_ways: usize,
    pub cells: Vec<ServeCell>,
    /// Outcome of the native-backend cross-check cell (`None` when the
    /// check was disabled).
    pub native_verified: Option<bool>,
    pub wall_clock_ms: f64,
    pub jobs: usize,
}

impl ServeResult {
    /// The headline frontier: the ccache cells, deadline-ordered within
    /// each skew — staleness bound on one axis, throughput on the other.
    pub fn frontier(&self) -> Vec<&ServeCell> {
        let mut f: Vec<&ServeCell> = self
            .cells
            .iter()
            .filter(|c| c.variant == Variant::CCache)
            .collect();
        f.sort_by(|a, b| {
            a.skew
                .partial_cmp(&b.skew)
                .unwrap()
                .then(a.deadline.cmp(&b.deadline))
        });
        f
    }

    /// Grid points (skew, deadline) where ccache's throughput is at
    /// least atomic's — the acceptance headline counts these.
    pub fn ccache_wins_vs_atomic(&self) -> usize {
        self.grid_points()
            .into_iter()
            .filter(|&(skew, deadline)| {
                let cycles = |v: Variant| {
                    self.cells
                        .iter()
                        .find(|c| c.variant == v && c.skew == skew && c.deadline == deadline)
                        .map(|c| c.cycles)
                };
                matches!((cycles(Variant::CCache), cycles(Variant::Atomic)),
                    (Some(cc), Some(at)) if cc <= at)
            })
            .count()
    }

    /// Distinct (skew, deadline) coordinates in the grid.
    pub fn grid_points(&self) -> Vec<(f64, usize)> {
        let mut pts: Vec<(f64, usize)> = Vec::new();
        for c in &self.cells {
            if !pts.contains(&(c.skew, c.deadline)) {
                pts.push((c.skew, c.deadline));
            }
        }
        pts
    }

    /// Hand-rolled JSON under a top-level `"kvserve"` key (the
    /// `ccache-bench-v1` section name). Cell objects share the
    /// `cycles`/`verified`/`merge_fns`/`quality` key-set with the sweep
    /// and partsweep emitters; staleness keys are always present and
    /// null-safe. Shape is pinned by the CI `serve-smoke` check.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(4096);
        out.push_str("{\n  \"kvserve\": {\n");
        out.push_str(&format!("    \"llc_bytes\": {},\n", self.llc_bytes));
        out.push_str(&format!("    \"work_cores\": {},\n", self.work_cores));
        out.push_str(&format!("    \"seed\": {},\n", self.seed));
        out.push_str(&format!("    \"tenants\": {},\n", self.tenants));
        out.push_str(&format!("    \"shards\": {},\n", self.shards));
        out.push_str(&format!("    \"mix\": \"{}\",\n", self.mix.token()));
        out.push_str(&format!("    \"skew_drift\": {:.3},\n", self.skew_drift));
        out.push_str(&format!("    \"corun\": {},\n", self.corun));
        out.push_str(&format!(
            "    \"partition_ways\": {},\n",
            self.partition_ways
        ));
        out.push_str(&format!("    \"jobs\": {},\n", self.jobs));
        out.push_str(&format!(
            "    \"wall_clock_ms\": {:.1},\n",
            self.wall_clock_ms
        ));
        out.push_str(&format!(
            "    \"native_verified\": {},\n",
            match self.native_verified {
                Some(v) => v.to_string(),
                None => "null".into(),
            }
        ));
        out.push_str(&format!(
            "    \"ccache_wins_vs_atomic\": {},\n",
            self.ccache_wins_vs_atomic()
        ));
        out.push_str(&format!(
            "    \"grid_points\": {},\n",
            self.grid_points().len()
        ));
        out.push_str("    \"cells\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "      {{\"deadline\": {}, \"skew\": {:.3}, \"variant\": \"{}\", \
                 \"cycles\": {}, \"ops\": {}, \"ops_per_kcycle\": {:.4}, \
                 \"verified\": {}, \"merges\": {}, \"merge_fns\": [{}], \
                 \"staleness_max\": {}, \"staleness_mean\": {:.4}, \"quality\": {}}}",
                c.deadline,
                c.skew,
                c.variant.name(),
                c.cycles,
                c.ops,
                c.ops_per_kcycle(),
                c.verified,
                c.merges,
                c.merge_fns
                    .iter()
                    .map(|f| format!("\"{f}\""))
                    .collect::<Vec<_>>()
                    .join(", "),
                c.staleness_max,
                c.staleness_mean,
                c.quality
                    .filter(|q| q.is_finite())
                    .map(|q| format!("{q:.4}"))
                    .unwrap_or_else(|| "null".into()),
            ));
        }
        out.push_str("\n    ],\n");
        // the headline: staleness bound vs throughput, ccache cells only
        out.push_str("    \"staleness_vs_throughput\": [\n");
        let frontier = self.frontier();
        for (i, c) in frontier.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "      {{\"deadline\": {}, \"skew\": {:.3}, \"staleness_max\": {}, \
                 \"staleness_mean\": {:.4}, \"ops_per_kcycle\": {:.4}}}",
                c.deadline,
                c.skew,
                c.staleness_max,
                c.staleness_mean,
                c.ops_per_kcycle(),
            ));
        }
        out.push_str("\n    ]\n  }\n}\n");
        out
    }

    /// The grid as a paper-style ASCII table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "serve — staleness vs throughput by merge deadline / skew / variant",
            &[
                "deadline",
                "skew",
                "variant",
                "Mcyc",
                "ops/kcyc",
                "stale max",
                "stale mean",
                "merges",
                "ok",
            ],
        );
        for c in &self.cells {
            t.row(&[
                c.deadline.to_string(),
                format!("{:.2}", c.skew),
                c.variant.name().to_string(),
                format!("{:.2}", c.cycles as f64 / 1e6),
                format!("{:.2}", c.ops_per_kcycle()),
                c.staleness_max.to_string(),
                format!("{:.1}", c.staleness_mean),
                c.merges.to_string(),
                if c.verified { "yes" } else { "NO" }.into(),
            ]);
        }
        t
    }
}

/// The serving parameters one cell runs: sweep geometry, the cell's
/// skew as the drift base, the cell's deadline.
fn cell_params(llc_bytes: usize, opts: &ServeOptions, skew: f64, deadline: usize) -> ServeParams {
    let tenants = if opts.tenants == 0 { 4 } else { opts.tenants };
    let keys_total = ((SERVE_WS_FRAC * llc_bytes as f64) as usize / 4).max(256);
    let keys_per_tenant = (keys_total / tenants).max(64);
    let shards = if opts.shards == 0 {
        tenants
    } else {
        opts.shards
    };
    ServeParams {
        traffic: TrafficSpec {
            tenants,
            keys_per_tenant,
            shards,
            mix: opts.mix,
            base_theta: skew,
            skew_drift: opts.skew_drift,
            scan_len: 8,
            seed: opts.seed,
        },
        epochs: if opts.quick { 2 } else { 4 },
        accesses_per_key: if opts.quick { 4 } else { 8 },
        merge_deadline: deadline,
    }
}

/// The machine one cell runs on: optional reuse-aware merge region on
/// top of the base geometry.
fn cell_config(base: &MachineConfig, partition_ways: usize) -> MachineConfig {
    let cfg = if partition_ways == 0 {
        base.clone()
    } else {
        base.clone()
            .with_partition(partition_ways, PartitionPolicy::ReuseAware)
    };
    cfg.validate().unwrap_or_else(|e| panic!("{e}"));
    cfg
}

/// Run the serving sweep on the scaled bench machine.
pub fn run_serve(opts: ServeOptions) -> ServeResult {
    let mut base = scaled_config();
    base.cores = SERVE_WORK_CORES;
    run_serve_on(base, opts)
}

/// [`run_serve`] on an explicit base machine (tests use the small
/// config; `base.cores` is the front-end core count).
pub fn run_serve_on(base: MachineConfig, opts: ServeOptions) -> ServeResult {
    base.validate().unwrap_or_else(|e| panic!("{e}"));
    let t0 = Instant::now();
    let deadlines: Vec<usize> = if opts.deadline > 0 {
        vec![opts.deadline]
    } else {
        SERVE_DEADLINES.to_vec()
    };
    let skews: &[f64] = if opts.quick {
        &SERVE_SKEWS[..1]
    } else {
        &SERVE_SKEWS
    };
    let cfg = cell_config(&base, opts.partition_ways);

    struct CellSpec {
        skew: f64,
        deadline: usize,
        variant: Variant,
        params: ServeParams,
    }
    let cells: Vec<CellSpec> = skews
        .iter()
        .flat_map(|&skew| {
            let deadlines = &deadlines;
            let opts = &opts;
            let llc = base.llc().size_bytes;
            deadlines.iter().flat_map(move |&deadline| {
                VARIANTS.iter().map(move |&variant| CellSpec {
                    skew,
                    deadline,
                    variant,
                    params: cell_params(llc, opts, skew, deadline),
                })
            })
        })
        .collect();

    let run_cell = |spec: &CellSpec| -> (RunResult, Staleness) {
        let wl = KvServeWorkload::new(spec.params.clone());
        let corun = (opts.corun_cores > 0).then(|| CorunSpec::new(opts.corun_cores));
        let r = driver::run_sim(&wl, spec.variant, cfg.clone(), None, corun).unwrap_or_else(|e| {
            panic!(
                "serve {}/d{}/theta{}: {e}",
                spec.variant.name(),
                spec.deadline,
                spec.skew
            )
        });
        let st = wl.staleness().expect("verify ran");
        (r, st)
    };

    let jobs = effective_jobs(opts.jobs, cells.len());
    let results: Vec<(RunResult, Staleness)> = if jobs <= 1 {
        cells.iter().map(run_cell).collect()
    } else {
        let next = AtomicUsize::new(0);
        let slots: Mutex<Vec<Option<(RunResult, Staleness)>>> =
            Mutex::new(vec![None; cells.len()]);
        std::thread::scope(|scope| {
            for _ in 0..jobs {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cells.len() {
                        break;
                    }
                    let r = run_cell(&cells[i]);
                    slots.lock().unwrap()[i] = Some(r);
                });
            }
        });
        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("every cell completed"))
            .collect()
    };

    let out_cells: Vec<ServeCell> = cells
        .iter()
        .zip(&results)
        .map(|(spec, (r, st))| {
            assert!(
                r.verified,
                "serve {}/d{} diverged from the golden run",
                spec.variant.name(),
                spec.deadline
            );
            let p = &spec.params;
            let ops = (p.ops_per_core_epoch(base.cores) * base.cores * p.epochs) as u64;
            ServeCell {
                deadline: spec.deadline,
                skew: spec.skew,
                variant: spec.variant,
                cycles: r.cycles(),
                ops,
                verified: r.verified,
                merges: r.stats.merges,
                merge_fns: r.merge_fns.clone(),
                staleness_max: st.max_ops,
                staleness_mean: st.mean_ops(),
                quality: r.quality,
            }
        })
        .collect();

    // golden cross-check on the native backend: one ccache cell at the
    // middle deadline (real threads, real atomics, same trace)
    let native_verified = opts.native_check.then(|| {
        let deadline = deadlines[deadlines.len() / 2];
        let params = cell_params(base.llc().size_bytes, &opts, skews[0], deadline);
        let wl = KvServeWorkload::new(params);
        driver::run_native_with_merge(&wl, Variant::CCache, base.clone(), None)
            .map(|r| r.verified)
            .unwrap_or(false)
    });

    ServeResult {
        llc_bytes: base.llc().size_bytes,
        work_cores: base.cores,
        seed: opts.seed,
        tenants: if opts.tenants == 0 { 4 } else { opts.tenants },
        shards: if opts.shards == 0 {
            if opts.tenants == 0 {
                4
            } else {
                opts.tenants
            }
        } else {
            opts.shards
        },
        mix: opts.mix,
        skew_drift: opts.skew_drift,
        corun: opts.corun_cores,
        partition_ways: opts.partition_ways,
        cells: out_cells,
        native_verified,
        wall_clock_ms: t0.elapsed().as_secs_f64() * 1e3,
        jobs,
    }
}

fn effective_jobs(requested: usize, cells: usize) -> usize {
    let auto = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let j = if requested == 0 { auto } else { requested };
    j.clamp(1, cells.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_opts() -> ServeOptions {
        ServeOptions {
            quick: true,
            jobs: 0,
            native_check: false,
            ..ServeOptions::default()
        }
    }

    fn small_base() -> MachineConfig {
        MachineConfig::test_small().with_cores(2)
    }

    #[test]
    fn quick_grid_covers_the_frontier_axes() {
        let r = run_serve_on(small_base(), small_opts());
        // 1 skew x 3 deadlines x 4 variants
        assert_eq!(r.cells.len(), 12);
        assert!(r.cells.iter().all(|c| c.verified));
        let frontier = r.frontier();
        assert!(
            frontier.len() >= 3,
            "frontier needs >= 3 deadline points, got {}",
            frontier.len()
        );
        // every cell serves the same trace on the same axes
        for pts in r.grid_points() {
            let ops: Vec<u64> = r
                .cells
                .iter()
                .filter(|c| (c.skew, c.deadline) == pts)
                .map(|c| c.ops)
                .collect();
            assert!(ops.windows(2).all(|w| w[0] == w[1]), "{ops:?}");
        }
    }

    #[test]
    fn staleness_bound_tightens_with_the_deadline() {
        // the acceptance pin: along the frontier, the measured bound is
        // monotonically non-increasing as the deadline tightens
        let r = run_serve_on(small_base(), small_opts());
        let f = r.frontier();
        for pair in f.windows(2) {
            assert!(
                pair[0].staleness_max <= pair[1].staleness_max,
                "bound grew as the deadline tightened: d{} -> {} vs d{} -> {}",
                pair[0].deadline,
                pair[0].staleness_max,
                pair[1].deadline,
                pair[1].staleness_max
            );
            assert!(pair[0].staleness_max <= pair[0].deadline as u64);
        }
        // coherent baselines publish immediately
        for c in r.cells.iter().filter(|c| c.variant == Variant::Fgl) {
            assert_eq!(c.staleness_max, 0);
        }
    }

    #[test]
    fn ccache_throughput_dominates_atomic_on_the_quick_grid() {
        // the acceptance headline: ccache >= atomic at every deadline
        let r = run_serve_on(small_base(), small_opts());
        assert_eq!(
            r.ccache_wins_vs_atomic(),
            r.grid_points().len(),
            "ccache lost to atomic somewhere:\n{}",
            r.table().render()
        );
    }

    #[test]
    fn corun_and_partition_compose() {
        let opts = ServeOptions {
            corun_cores: 2,
            partition_ways: 2,
            ..small_opts()
        };
        let r = run_serve_on(small_base(), opts);
        assert!(r.cells.iter().all(|c| c.verified));
        // the stressor slows the tier down
        let quiet = run_serve_on(small_base(), small_opts());
        let cycles = |res: &ServeResult| {
            res.cells
                .iter()
                .find(|c| c.variant == Variant::CCache && c.deadline == SERVE_DEADLINES[0])
                .unwrap()
                .cycles
        };
        assert!(cycles(&r) > cycles(&quiet), "co-runner did not cost cycles");
    }

    #[test]
    fn json_shape_is_stable_for_the_ci_schema_check() {
        let mut opts = small_opts();
        opts.jobs = 1;
        let r = run_serve_on(small_base(), opts);
        let j = r.to_json();
        assert!(j.contains("\"kvserve\""), "{j}");
        for key in [
            "\"deadline\"",
            "\"skew\"",
            "\"variant\"",
            "\"cycles\"",
            "\"ops\"",
            "\"ops_per_kcycle\"",
            "\"verified\"",
            "\"merges\"",
            "\"merge_fns\"",
            "\"staleness_max\"",
            "\"staleness_mean\"",
            "\"quality\"",
            "\"staleness_vs_throughput\"",
            "\"ccache_wins_vs_atomic\"",
            "\"native_verified\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert_eq!(j.matches('{').count(), j.matches('}').count(), "{j}");
        assert_eq!(j.matches('[').count(), j.matches(']').count(), "{j}");
        // the native check was disabled -> null, never omitted
        assert!(j.contains("\"native_verified\": null"), "{j}");
    }

    #[test]
    fn parallel_cells_match_serial_cell_for_cell() {
        let serial = run_serve_on(
            small_base(),
            ServeOptions {
                jobs: 1,
                ..small_opts()
            },
        );
        let parallel = run_serve_on(
            small_base(),
            ServeOptions {
                jobs: 4,
                ..small_opts()
            },
        );
        assert_eq!(serial.cells.len(), parallel.cells.len());
        for (s, p) in serial.cells.iter().zip(&parallel.cells) {
            assert_eq!(s.variant, p.variant);
            assert_eq!(s.cycles, p.cycles, "cycles diverged under --jobs");
            assert_eq!(s.staleness_max, p.staleness_max);
            assert_eq!(s.staleness_mean, p.staleness_mean);
        }
    }

    #[test]
    fn native_cross_check_verifies() {
        let opts = ServeOptions {
            deadline: 32,
            native_check: true,
            ..small_opts()
        };
        let r = run_serve_on(small_base(), opts);
        assert_eq!(r.native_verified, Some(true), "native backend diverged");
    }
}
