//! The partition sweep: LLC capacity x partition-ways x policy x
//! co-runner, CCache variant, over the fig 7 benchmark set. The
//! experiment behind the tentpole question of reuse-aware way
//! partitioning: *when does fencing the merge region off from the rest
//! of the LLC pay for the capacity it takes away?*
//!
//! Each cell is one simulated run. The grid crosses:
//! * **LLC capacity** — full, and (full mode) halved, fig 7 style: the
//!   working set stays sized against the *full* LLC, so the halved
//!   cells measure capacity pressure, not a smaller problem;
//! * **partition mode** — no partition, a static merge region, or the
//!   reuse-aware controller that resizes the region each epoch
//!   ([`PartitionPolicy::ReuseAware`]);
//! * **co-runner** — none, or a cache-hostile streaming scanner
//!   ([`CorunSpec`]) evicting the workload's shared-level footprint.
//!   Partitioned cells confine the scanner to the ordinary ways, so the
//!   merge region's CData survives; unpartitioned cells let it thrash
//!   everything. The with-co-runner columns are where partitioning is
//!   expected to win.
//!
//! Cells fan out over a scoped worker pool exactly like
//! [`sweep`](super::sweep) — each cell builds its own machine, so
//! results are bit-identical to serial execution and `--jobs` changes
//! wall-clock only.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::exec::{CorunSpec, RunResult, Variant, WorkloadHandle};
use crate::sim::config::MachineConfig;
use crate::sim::hierarchy::level::PartitionPolicy;
use crate::util::bench::Table;

use super::experiment::{scaled_config, sized_workload};

/// Working-set fraction of the *base* (full) LLC every cell uses. Kept
/// below 1.0 so the shared structure fits the full LLC with room for
/// the merge region — the halved-capacity cells then squeeze it.
pub const PART_WS_FRAC: f64 = 0.5;

/// Workload cores every cell runs (co-runner cores ride on top).
pub const PART_WORK_CORES: usize = 4;

/// Default co-runner width for the with-stressor cells.
pub const PART_CORUN_CORES: usize = 2;

/// The fig 7 benchmark set; `--quick` keeps the first two.
pub const PART_BENCHES: [&str; 4] = ["kvstore", "kmeans", "pagerank-uniform", "bfs-rmat"];

/// LLC capacity scales; `--quick` keeps the full-capacity column.
pub const PART_CAPS: [f64; 2] = [1.0, 0.5];

/// How a cell partitions the shared level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartMode {
    /// No way partition — the pre-partitioning baseline.
    NoPartition,
    /// A fixed merge region ([`PartitionPolicy::Static`]).
    Static,
    /// The epoch-based controller ([`PartitionPolicy::ReuseAware`]).
    Reuse,
}

impl PartMode {
    pub const ALL: [PartMode; 3] = [PartMode::NoPartition, PartMode::Static, PartMode::Reuse];

    /// Stable CLI/JSON token.
    pub fn name(&self) -> &'static str {
        match self {
            PartMode::NoPartition => "none",
            PartMode::Static => "static",
            PartMode::Reuse => "reuse",
        }
    }
}

/// Knobs for one partition sweep.
#[derive(Clone, Copy, Debug)]
pub struct PartsweepOptions {
    /// Trim the grid for CI smoke: 2 benchmarks, full capacity only.
    pub quick: bool,
    /// Worker threads for the cell grid; 0 = all host cores.
    pub jobs: usize,
    pub seed: u64,
    /// Scanner cores for the with-co-runner cells (0 disables them).
    pub corun_cores: usize,
}

impl Default for PartsweepOptions {
    fn default() -> Self {
        Self {
            quick: false,
            jobs: 0,
            seed: 42,
            corun_cores: PART_CORUN_CORES,
        }
    }
}

/// One grid cell: the configuration axes plus the counters the
/// trajectory record and the CI schema check consume.
#[derive(Clone, Debug)]
pub struct PartCell {
    pub benchmark: String,
    /// LLC capacity relative to the base machine (1.0 or 0.5).
    pub cap: f64,
    /// Partition mode token ([`PartMode::name`]).
    pub policy: &'static str,
    /// Configured merge-region ways (0 when unpartitioned).
    pub ccache_ways: u64,
    /// Co-runner scanner cores (0 = no stressor).
    pub corun: usize,
    /// Workload cycles ([`RunResult::cycles`]; co-runner cores excluded).
    pub cycles: u64,
    pub verified: bool,
    pub ways_min: u64,
    pub ways_max: u64,
    pub ways_final: u64,
    pub repartitions: u64,
    pub ccache_l1_hits: u64,
    pub ccache_fills: u64,
    pub llc_misses: u64,
    /// Merge functions installed in the MFRF — shared cell key with the
    /// sweep and serve emitters (CCache cells; empty otherwise).
    pub merge_fns: Vec<String>,
    /// Quality metric of approximate variants (shared cell key; `null`
    /// for the exact partsweep benchmarks).
    pub quality: Option<f64>,
}

impl PartCell {
    fn from_run(
        benchmark: &str,
        cap: f64,
        mode: PartMode,
        ccache_ways: usize,
        corun: usize,
        r: &RunResult,
    ) -> Self {
        Self {
            benchmark: benchmark.to_string(),
            cap,
            policy: mode.name(),
            ccache_ways: ccache_ways as u64,
            corun,
            cycles: r.cycles(),
            verified: r.verified,
            ways_min: r.stats.partition_ways_min,
            ways_max: r.stats.partition_ways_max,
            ways_final: r.stats.partition_ways_final,
            repartitions: r.stats.repartitions,
            ccache_l1_hits: r.stats.ccache_l1_hits,
            ccache_fills: r.stats.ccache_fills,
            llc_misses: r.stats.llc().misses,
            merge_fns: r.merge_fns.clone(),
            quality: r.quality,
        }
    }
}

/// A completed partition sweep.
#[derive(Clone, Debug)]
pub struct PartsweepResult {
    /// Base (full-capacity) LLC bytes cells were sized against.
    pub llc_bytes: usize,
    pub work_cores: usize,
    pub seed: u64,
    pub cells: Vec<PartCell>,
    pub wall_clock_ms: f64,
    pub jobs: usize,
}

impl PartsweepResult {
    /// With-co-runner cells where the reuse-aware partition beats the
    /// unpartitioned baseline outright (strictly fewer cycles on the
    /// same benchmark/capacity/co-runner axes) — the sweep's headline.
    pub fn reuse_wins_under_corun(&self) -> Vec<&PartCell> {
        self.cells
            .iter()
            .filter(|c| c.corun > 0 && c.policy == "reuse")
            .filter(|reuse| {
                self.cells.iter().any(|base| {
                    base.policy == "none"
                        && base.benchmark == reuse.benchmark
                        && base.cap == reuse.cap
                        && base.corun == reuse.corun
                        && reuse.cycles < base.cycles
                })
            })
            .collect()
    }

    /// Hand-rolled JSON (serde is unavailable offline), one object per
    /// cell under a top-level `"partsweep"` key. Shape is pinned by the
    /// CI `partsweep-smoke` schema check.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(4096);
        out.push_str("{\n  \"partsweep\": {\n");
        out.push_str(&format!("    \"llc_bytes\": {},\n", self.llc_bytes));
        out.push_str(&format!("    \"work_cores\": {},\n", self.work_cores));
        out.push_str(&format!("    \"ws_frac\": {:.2},\n", PART_WS_FRAC));
        out.push_str(&format!("    \"seed\": {},\n", self.seed));
        out.push_str(&format!("    \"jobs\": {},\n", self.jobs));
        out.push_str(&format!(
            "    \"wall_clock_ms\": {:.1},\n",
            self.wall_clock_ms
        ));
        out.push_str(&format!(
            "    \"reuse_wins_under_corun\": {},\n",
            self.reuse_wins_under_corun().len()
        ));
        out.push_str("    \"cells\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "      {{\"benchmark\": \"{}\", \"cap\": {:.2}, \"policy\": \"{}\", \
                 \"ccache_ways\": {}, \"corun\": {}, \"cycles\": {}, \"verified\": {}, \
                 \"ways_min\": {}, \"ways_max\": {}, \"ways_final\": {}, \
                 \"repartitions\": {}, \"ccache_l1_hits\": {}, \"ccache_fills\": {}, \
                 \"llc_misses\": {}, \"merge_fns\": [{}], \"quality\": {}}}",
                c.benchmark,
                c.cap,
                c.policy,
                c.ccache_ways,
                c.corun,
                c.cycles,
                c.verified,
                c.ways_min,
                c.ways_max,
                c.ways_final,
                c.repartitions,
                c.ccache_l1_hits,
                c.ccache_fills,
                c.llc_misses,
                c.merge_fns
                    .iter()
                    .map(|f| format!("\"{f}\""))
                    .collect::<Vec<_>>()
                    .join(", "),
                c.quality
                    .filter(|q| q.is_finite())
                    .map(|q| format!("{q:.6}"))
                    .unwrap_or_else(|| "null".into()),
            ));
        }
        out.push_str("\n    ]\n  }\n}\n");
        out
    }

    /// The grid as a paper-style ASCII table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "partsweep — CCache cycles by LLC capacity / partition / co-runner",
            &[
                "benchmark",
                "cap",
                "policy",
                "ways",
                "corun",
                "Mcyc",
                "llc miss",
                "repart",
                "final",
            ],
        );
        for c in &self.cells {
            t.row(&[
                c.benchmark.clone(),
                format!("{:.2}", c.cap),
                c.policy.to_string(),
                if c.ccache_ways == 0 {
                    "-".into()
                } else {
                    c.ccache_ways.to_string()
                },
                c.corun.to_string(),
                format!("{:.2}", c.cycles as f64 / 1e6),
                c.llc_misses.to_string(),
                c.repartitions.to_string(),
                if c.ccache_ways == 0 {
                    "-".into()
                } else {
                    c.ways_final.to_string()
                },
            ]);
        }
        t
    }
}

/// Initial merge-region width for a partitioned cell: a quarter of the
/// LLC's ways (4 of 16 on the Table 2 shape), the static column's fixed
/// width and the reuse-aware controller's starting point.
fn init_ways(cfg: &MachineConfig) -> usize {
    (cfg.llc().ways / 4).max(1)
}

/// The machine one cell runs on: base geometry, scaled LLC capacity,
/// partition mode. Halved capacities reuse the fig 7 validation path —
/// a geometry the shrink breaks is a panic here, not a mis-indexed run.
fn cell_config(base: &MachineConfig, cap: f64, mode: PartMode) -> MachineConfig {
    let mut cfg = base.clone();
    if cap != 1.0 {
        cfg = cfg.with_llc_bytes((base.llc().size_bytes as f64 * cap) as usize);
    }
    cfg = match mode {
        PartMode::NoPartition => cfg,
        PartMode::Static => {
            let w = init_ways(&cfg);
            cfg.with_partition(w, PartitionPolicy::Static)
        }
        PartMode::Reuse => {
            let w = init_ways(&cfg);
            cfg.with_partition(w, PartitionPolicy::ReuseAware)
        }
    };
    cfg.validate().unwrap_or_else(|e| panic!("{e}"));
    cfg
}

/// Run the partition sweep on the scaled bench machine.
pub fn run_partsweep(opts: PartsweepOptions) -> PartsweepResult {
    let mut base = scaled_config();
    base.cores = PART_WORK_CORES;
    run_partsweep_on(base, opts)
}

/// [`run_partsweep`] on an explicit base machine (tests use the small
/// config; `base.cores` is the workload core count).
pub fn run_partsweep_on(base: MachineConfig, opts: PartsweepOptions) -> PartsweepResult {
    base.validate().unwrap_or_else(|e| panic!("{e}"));
    let t0 = Instant::now();
    let benches: &[&str] = if opts.quick {
        &PART_BENCHES[..2]
    } else {
        &PART_BENCHES
    };
    let caps: &[f64] = if opts.quick { &PART_CAPS[..1] } else { &PART_CAPS };
    let coruns: Vec<usize> = if opts.corun_cores == 0 {
        vec![0]
    } else {
        vec![0, opts.corun_cores]
    };

    // one sized instance per benchmark — the working set tracks the
    // *base* LLC so halved-capacity cells measure pressure, not a
    // smaller problem (fig 7's methodology)
    let handles: Vec<(&str, WorkloadHandle)> = benches
        .iter()
        .map(|&name| {
            (
                name,
                sized_workload(name, PART_WS_FRAC, base.llc().size_bytes, opts.seed),
            )
        })
        .collect();

    // the independent cell grid, benchmark-major
    struct CellSpec<'a> {
        name: &'a str,
        bench: &'a WorkloadHandle,
        cap: f64,
        mode: PartMode,
        ways: usize,
        corun: usize,
        cfg: MachineConfig,
    }
    let cells: Vec<CellSpec> = handles
        .iter()
        .flat_map(|(name, bench)| {
            let name: &str = name;
            let base = &base;
            let coruns = &coruns;
            caps.iter().flat_map(move |&cap| {
                PartMode::ALL.iter().flat_map(move |&mode| {
                    coruns.iter().map(move |&corun| {
                        let cfg = cell_config(base, cap, mode);
                        let ways = match mode {
                            PartMode::NoPartition => 0,
                            _ => init_ways(&cfg),
                        };
                        CellSpec {
                            name,
                            bench,
                            cap,
                            mode,
                            ways,
                            corun,
                            cfg,
                        }
                    })
                })
            })
        })
        .collect();

    let run_cell = |spec: &CellSpec| -> RunResult {
        let corun = (spec.corun > 0).then(|| CorunSpec::new(spec.corun));
        spec.bench
            .run_corun(Variant::CCache, spec.cfg.clone(), corun)
            .unwrap_or_else(|e| panic!("partsweep {}: {e}", spec.name))
    };

    let jobs = effective_jobs(opts.jobs, cells.len());
    let results: Vec<RunResult> = if jobs <= 1 {
        cells.iter().map(run_cell).collect()
    } else {
        let next = AtomicUsize::new(0);
        let slots: Mutex<Vec<Option<RunResult>>> = Mutex::new(vec![None; cells.len()]);
        std::thread::scope(|scope| {
            for _ in 0..jobs {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cells.len() {
                        break;
                    }
                    let r = run_cell(&cells[i]);
                    slots.lock().unwrap()[i] = Some(r);
                });
            }
        });
        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("every cell completed"))
            .collect()
    };

    let out_cells: Vec<PartCell> = cells
        .iter()
        .zip(&results)
        .map(|(spec, r)| {
            assert!(
                r.verified,
                "partsweep {}/{}/corun{} diverged from the golden run",
                spec.name,
                spec.mode.name(),
                spec.corun
            );
            PartCell::from_run(spec.name, spec.cap, spec.mode, spec.ways, spec.corun, r)
        })
        .collect();

    PartsweepResult {
        llc_bytes: base.llc().size_bytes,
        work_cores: base.cores,
        seed: opts.seed,
        cells: out_cells,
        wall_clock_ms: t0.elapsed().as_secs_f64() * 1e3,
        jobs,
    }
}

fn effective_jobs(requested: usize, cells: usize) -> usize {
    let auto = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let j = if requested == 0 { auto } else { requested };
    j.clamp(1, cells.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_opts() -> PartsweepOptions {
        PartsweepOptions {
            quick: true,
            jobs: 0,
            seed: 42,
            corun_cores: 2,
        }
    }

    fn small_base() -> MachineConfig {
        MachineConfig::test_small().with_cores(2)
    }

    #[test]
    fn quick_grid_covers_every_axis_combination() {
        let r = run_partsweep_on(small_base(), small_opts());
        // 2 benchmarks x 1 capacity x 3 modes x 2 co-runner widths
        assert_eq!(r.cells.len(), 12);
        assert!(r.cells.iter().all(|c| c.verified));
        for policy in ["none", "static", "reuse"] {
            assert!(r.cells.iter().any(|c| c.policy == policy));
        }
        assert!(r.cells.iter().any(|c| c.corun == 2));
        assert!(r.cells.iter().any(|c| c.corun == 0));
        // unpartitioned cells carry no partition telemetry
        for c in r.cells.iter().filter(|c| c.policy == "none") {
            assert_eq!((c.ccache_ways, c.ways_max, c.repartitions), (0, 0, 0));
        }
        // partitioned cells report the configured region
        for c in r.cells.iter().filter(|c| c.policy == "static") {
            assert_eq!(c.ccache_ways, 2); // 8-way small LLC / 4
            assert_eq!(c.ways_final, c.ccache_ways);
            assert_eq!(c.repartitions, 0, "static partitions never move");
        }
    }

    #[test]
    fn corun_interference_costs_cycles() {
        let r = run_partsweep_on(small_base(), small_opts());
        // the stressor must actually stress: for every benchmark, the
        // unpartitioned with-co-runner cell is slower than the quiet one
        for name in ["kvstore", "kmeans"] {
            let cell = |corun: usize| {
                r.cells
                    .iter()
                    .find(|c| c.benchmark == name && c.policy == "none" && c.corun == corun)
                    .unwrap()
            };
            assert!(
                cell(2).cycles > cell(0).cycles,
                "{name}: corun cell not slower ({} <= {})",
                cell(2).cycles,
                cell(0).cycles
            );
        }
    }

    #[test]
    fn reuse_beats_no_partition_under_the_corun_stressor() {
        // the tentpole acceptance cell: with a scanner thrashing the
        // LLC, fencing the merge region must win outright somewhere
        let r = run_partsweep_on(small_base(), small_opts());
        let wins = r.reuse_wins_under_corun();
        assert!(
            !wins.is_empty(),
            "no corun cell where reuse-aware beats no-partition:\n{}",
            r.table().render()
        );
    }

    #[test]
    fn json_shape_is_stable_for_the_ci_schema_check() {
        let mut opts = small_opts();
        opts.jobs = 1;
        let r = run_partsweep_on(small_base(), opts);
        let j = r.to_json();
        assert!(j.contains("\"partsweep\""), "{j}");
        for key in [
            "\"benchmark\"",
            "\"cap\"",
            "\"policy\"",
            "\"ccache_ways\"",
            "\"corun\"",
            "\"cycles\"",
            "\"verified\"",
            "\"ways_min\"",
            "\"ways_max\"",
            "\"ways_final\"",
            "\"repartitions\"",
            "\"ccache_l1_hits\"",
            "\"ccache_fills\"",
            "\"llc_misses\"",
            "\"merge_fns\"",
            "\"quality\"",
            "\"reuse_wins_under_corun\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert_eq!(j.matches('{').count(), j.matches('}').count(), "{j}");
        assert_eq!(j.matches('[').count(), j.matches(']').count(), "{j}");
    }

    #[test]
    fn parallel_cells_match_serial_cell_for_cell() {
        let serial = run_partsweep_on(small_base(), PartsweepOptions {
            jobs: 1,
            ..small_opts()
        });
        let parallel = run_partsweep_on(small_base(), PartsweepOptions {
            jobs: 4,
            ..small_opts()
        });
        assert_eq!(serial.cells.len(), parallel.cells.len());
        for (s, p) in serial.cells.iter().zip(&parallel.cells) {
            assert_eq!(s.benchmark, p.benchmark);
            assert_eq!(s.cycles, p.cycles, "cycles diverged under --jobs");
            assert_eq!(s.repartitions, p.repartitions);
            assert_eq!(s.llc_misses, p.llc_misses);
        }
    }

    #[test]
    fn mode_tokens_are_stable() {
        assert_eq!(PartMode::NoPartition.name(), "none");
        assert_eq!(PartMode::Static.name(), "static");
        assert_eq!(PartMode::Reuse.name(), "reuse");
    }
}
