//! Paper-style report rendering for sweeps and characterization runs,
//! plus the machine-readable JSON record (`sweep --json`).

use crate::exec::Variant;
use crate::sim::config::MachineConfig;
use crate::util::bench::Table;

use super::sweep::SweepResult;

/// Fig 6-style table: speedup of DUP and CCache relative to FGL per
/// working-set fraction. The title names the merge functions actually
/// installed so the merge identity is visible in text reports.
pub fn fig6_table(sweep: &SweepResult) -> Table {
    let merges = sweep.merge_fns();
    let title = if merges.is_empty() {
        format!("Fig 6 — {}: speedup vs FGL", sweep.name)
    } else {
        format!(
            "Fig 6 — {} [merge: {}]: speedup vs FGL",
            sweep.name,
            merges.join(", ")
        )
    };
    let mut t = Table::new(title, &["ws/LLC", "FGL", "DUP", "CCACHE"]);
    for p in &sweep.points {
        let dup = p
            .speedup_vs_fgl(Variant::Dup)
            .map(|s| format!("{s:.2}x"))
            .unwrap_or_else(|| "-".into());
        let cc = p
            .speedup_vs_fgl(Variant::CCache)
            .map(|s| format!("{s:.2}x"))
            .unwrap_or_else(|| "-".into());
        t.row(&[format!("{:.2}", p.frac), "1.00x".into(), dup, cc]);
    }
    t
}

/// Fig 8-style characterization table for a metric extractor.
pub fn fig8_table(
    sweep: &SweepResult,
    metric_name: &str,
    metric: impl Fn(&crate::exec::RunResult) -> f64,
) -> Table {
    let variants: Vec<Variant> = sweep
        .points
        .first()
        .map(|p| p.results.iter().map(|r| r.variant).collect())
        .unwrap_or_default();
    let mut header: Vec<String> = vec!["ws/LLC".into()];
    header.extend(variants.iter().map(|v| v.name().to_uppercase()));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        format!("Fig 8 — {}: {metric_name} per 1k cycles", sweep.name),
        &header_refs,
    );
    for p in &sweep.points {
        let mut row = vec![format!("{:.2}", p.frac)];
        for v in &variants {
            row.push(
                p.get(*v)
                    .map(|r| format!("{:.3}", metric(r)))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        t.row(&row);
    }
    t
}

/// Machine-readable sweep record: the per-cell cycles and merge/miss
/// stats plus the run's wall-clock, so the perf trajectory of the sweep
/// itself is recorded. Hand-rolled JSON — serde is unavailable offline.
pub fn sweep_json(sweep: &SweepResult, cfg: &MachineConfig) -> String {
    let mut out = String::with_capacity(4096);
    out.push_str("{\n");
    out.push_str(&format!("  \"benchmark\": {},\n", json_str(&sweep.name)));
    out.push_str(&format!("  \"cores\": {},\n", cfg.cores));
    out.push_str("  \"levels\": [");
    for (i, lv) in cfg.levels.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!(
            "{{\"name\": {}, \"size_bytes\": {}, \"ways\": {}, \"hit_cycles\": {}, \"shared\": {}}}",
            json_str(&cfg.level_name(i)),
            lv.size_bytes,
            lv.ways,
            lv.hit_cycles,
            lv.shared
        ));
    }
    out.push_str("],\n");
    out.push_str(&format!("  \"mem_cycles\": {},\n", cfg.timing.mem_cycles));
    out.push_str(&format!("  \"jobs\": {},\n", sweep.jobs));
    out.push_str(&format!(
        "  \"wall_clock_ms\": {:.3},\n",
        sweep.wall_clock_ms
    ));
    out.push_str("  \"cells\": [\n");
    let mut first = true;
    for p in &sweep.points {
        for r in &p.results {
            if !first {
                out.push_str(",\n");
            }
            first = false;
            let speedup = p
                .speedup_vs_fgl(r.variant)
                .filter(|s| s.is_finite())
                .map(|s| format!("{s:.4}"))
                .unwrap_or_else(|| "null".into());
            // quality is workload-defined (e.g. hll relative error) and
            // optional; a non-finite value would poison json.loads, so
            // both None and NaN/inf serialize as JSON null
            let quality = r
                .quality
                .filter(|q| q.is_finite())
                .map(|q| format!("{q:.6}"))
                .unwrap_or_else(|| "null".into());
            let merge_fns = r
                .merge_fns
                .iter()
                .map(|n| json_str(n))
                .collect::<Vec<_>>()
                .join(", ");
            out.push_str(&format!(
                "    {{\"frac\": {}, \"variant\": {}, \"merge_fns\": [{}], \
                 \"cycles\": {}, \
                 \"verified\": {}, \"merges\": {}, \"silent_drops\": {}, \
                 \"src_buf_evictions\": {}, \"ccache_l1_hits\": {}, \
                 \"ccache_fills\": {}, \"approx_drops\": {}, \
                 \"atomic_rmws\": {}, \"barriers\": {}, \"llc_misses\": {}, \
                 \"directory_msgs\": {}, \"invalidations\": {}, \
                 \"partition_ways_min\": {}, \"partition_ways_max\": {}, \
                 \"partition_ways_final\": {}, \"repartitions\": {}, \
                 \"quality\": {}, \"speedup_vs_fgl\": {}}}",
                p.frac,
                json_str(r.variant.name()),
                merge_fns,
                r.cycles(),
                r.verified,
                r.stats.merges,
                r.stats.silent_drops,
                r.stats.src_buf_evictions,
                r.stats.ccache_l1_hits,
                r.stats.ccache_fills,
                r.stats.approx_drops,
                r.stats.atomic_rmws,
                r.stats.barriers,
                r.stats.llc().misses,
                r.stats.directory_msgs,
                r.stats.invalidations,
                r.stats.partition_ways_min,
                r.stats.partition_ways_max,
                r.stats.partition_ways_final,
                r.stats.repartitions,
                quality,
                speedup
            ));
        }
    }
    out.push_str("\n  ]\n}\n");
    out
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sweep::run_sweep;
    use crate::sim::config::MachineConfig;

    #[test]
    fn tables_render_from_sweep() {
        let mut cfg = MachineConfig::test_small();
        cfg.cores = 2;
        let sweep = run_sweep("kvstore", &[Variant::Fgl, Variant::CCache], &[0.5], cfg, 1);
        let t = fig6_table(&sweep);
        assert!(t.render().contains("CCACHE"));
        assert!(
            t.render().contains("merge: add_u32"),
            "merge identity missing from the text report: {}",
            t.render()
        );
        let t8 = fig8_table(&sweep, "LLC misses", |r| r.stats.llc_misses_per_kc());
        assert!(t8.render().contains("LLC misses"));
    }

    #[test]
    fn json_record_has_cells_and_machine_shape() {
        let cfg = MachineConfig::test_small().with_cores(2);
        let sweep = run_sweep(
            "kvstore",
            &[Variant::Fgl, Variant::CCache],
            &[0.5],
            cfg.clone(),
            1,
        );
        let j = sweep_json(&sweep, &cfg);
        assert!(j.contains("\"benchmark\": \"kvstore\""), "{j}");
        assert!(j.contains("\"variant\": \"ccache\""), "{j}");
        // CCache cells name their installed merge function; FGL cells
        // carry an empty list
        assert!(j.contains("\"merge_fns\": [\"add_u32\"]"), "{j}");
        assert!(j.contains("\"merge_fns\": []"), "{j}");
        // the full CCache + synchronization counter set is part of every
        // cell record (regression: these five used to be omitted)
        for key in [
            "\"ccache_l1_hits\"",
            "\"ccache_fills\"",
            "\"approx_drops\"",
            "\"atomic_rmws\"",
            "\"barriers\"",
        ] {
            assert!(j.contains(key), "cell record missing {key}: {j}");
        }
        // LLC partition telemetry rides on every cell; an unpartitioned
        // sweep reports zeros, never omits the keys
        for key in [
            "\"partition_ways_min\": 0",
            "\"partition_ways_max\": 0",
            "\"partition_ways_final\": 0",
            "\"repartitions\": 0",
        ] {
            assert!(j.contains(key), "cell record missing {key}: {j}");
        }
        assert!(j.contains("\"wall_clock_ms\""), "{j}");
        assert!(j.contains("\"levels\""), "{j}");
        assert!(j.contains("\"LLC\""), "{j}");
        // the FGL baseline cell reports speedup 1.0
        assert!(j.contains("\"speedup_vs_fgl\": 1.0000"), "{j}");
        // kvstore is an exact workload: quality is None and must land
        // in the record as JSON null, not be omitted or mangled
        assert!(j.contains("\"quality\": null"), "{j}");
        // crude structural sanity: balanced braces/brackets
        assert_eq!(
            j.matches('{').count(),
            j.matches('}').count(),
            "unbalanced JSON"
        );
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn hll_sweep_json_carries_a_numeric_quality_cell() {
        let cfg = MachineConfig::test_small().with_cores(2);
        let sweep = run_sweep(
            "hll",
            &[Variant::Fgl, Variant::CCache],
            &[0.25],
            cfg.clone(),
            1,
        );
        let j = sweep_json(&sweep, &cfg);
        // hll's verify reports a relative-error quality on every cell;
        // it must serialize as a bare JSON number, never a string
        assert!(j.contains("\"quality\": 0."), "no numeric quality: {j}");
        assert!(!j.contains("\"quality\": \""), "quality quoted: {j}");
        assert_eq!(
            j.matches('{').count(),
            j.matches('}').count(),
            "unbalanced JSON"
        );
    }

    #[test]
    fn degenerate_quality_and_speedup_serialize_as_null() {
        use crate::coordinator::sweep::{SweepPoint, SweepResult};
        use crate::exec::RunResult;
        use crate::sim::stats::Stats;
        let mk = |v: Variant, cyc: u64, quality: Option<f64>| RunResult {
            benchmark: "synthetic".into(),
            variant: v,
            stats: {
                let mut s = Stats::new(1, 3);
                s.core_cycles = vec![cyc];
                s
            },
            verified: true,
            quality,
            merge_fns: Vec::new(),
            wall_secs: None,
        };
        // NaN quality and a zero-cycle cell: both degenerate paths must
        // land as JSON null so `json.loads` round-trips the record
        let sweep = SweepResult {
            name: "synthetic".into(),
            points: vec![SweepPoint {
                frac: 1.0,
                results: vec![
                    mk(Variant::Fgl, 100, Some(f64::NAN)),
                    mk(Variant::CCache, 0, Some(f64::INFINITY)),
                ],
            }],
            wall_clock_ms: 1.0,
            jobs: 1,
        };
        let cfg = MachineConfig::test_small();
        let j = sweep_json(&sweep, &cfg);
        assert!(j.contains("\"quality\": null"), "{j}");
        assert!(!j.contains("NaN"), "raw NaN leaked into JSON: {j}");
        assert!(!j.contains("inf"), "raw inf leaked into JSON: {j}");
        // the zero-cycle ccache cell has no finite speedup
        assert!(j.contains("\"speedup_vs_fgl\": null"), "{j}");
    }
}
