//! Paper-style report rendering for sweeps and characterization runs.

use crate::exec::Variant;
use crate::util::bench::Table;

use super::sweep::SweepResult;

/// Fig 6-style table: speedup of DUP and CCache relative to FGL per
/// working-set fraction.
pub fn fig6_table(sweep: &SweepResult) -> Table {
    let mut t = Table::new(
        format!("Fig 6 — {}: speedup vs FGL", sweep.name),
        &["ws/LLC", "FGL", "DUP", "CCACHE"],
    );
    for p in &sweep.points {
        let dup = p
            .speedup_vs_fgl(Variant::Dup)
            .map(|s| format!("{s:.2}x"))
            .unwrap_or_else(|| "-".into());
        let cc = p
            .speedup_vs_fgl(Variant::CCache)
            .map(|s| format!("{s:.2}x"))
            .unwrap_or_else(|| "-".into());
        t.row(&[format!("{:.2}", p.frac), "1.00x".into(), dup, cc]);
    }
    t
}

/// Fig 8-style characterization table for a metric extractor.
pub fn fig8_table(
    sweep: &SweepResult,
    metric_name: &str,
    metric: impl Fn(&crate::exec::RunResult) -> f64,
) -> Table {
    let variants: Vec<Variant> = sweep
        .points
        .first()
        .map(|p| p.results.iter().map(|r| r.variant).collect())
        .unwrap_or_default();
    let mut header: Vec<String> = vec!["ws/LLC".into()];
    header.extend(variants.iter().map(|v| v.name().to_uppercase()));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        format!("Fig 8 — {}: {metric_name} per 1k cycles", sweep.name),
        &header_refs,
    );
    for p in &sweep.points {
        let mut row = vec![format!("{:.2}", p.frac)];
        for v in &variants {
            row.push(
                p.get(*v)
                    .map(|r| format!("{:.3}", metric(r)))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        t.row(&row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sweep::run_sweep;
    use crate::sim::config::MachineConfig;

    #[test]
    fn tables_render_from_sweep() {
        let mut cfg = MachineConfig::test_small();
        cfg.cores = 2;
        let sweep = run_sweep("kvstore", &[Variant::Fgl, Variant::CCache], &[0.5], cfg, 1);
        let t = fig6_table(&sweep);
        assert!(t.render().contains("CCACHE"));
        let t8 = fig8_table(&sweep, "LLC misses", |r| r.stats.llc_misses_per_kc());
        assert!(t8.render().contains("LLC misses"));
    }
}
