//! Section 6.3 — flexible, software-defined merge functions.
//!
//! Runs the key-value store with three different merge functions (plain
//! add, saturating add, complex multiplication) and shows that CCache's
//! advantage holds across all of them — the paper's core argument
//! against fixed-function hardware (COUP). Custom parameters go through
//! the same [`Workload`] trait + driver as the registry benchmarks.
//!
//!     cargo run --release --example kvstore_merges

use ccache::coordinator::{run_verified, scaled_config};
use ccache::exec::{Variant, WorkloadHandle};
use ccache::util::bench::Table;
use ccache::workloads::kvstore::{KvMerge, KvParams, KvWorkload};

fn main() {
    let cfg = scaled_config();
    let keys = cfg.llc().size_bytes / 8; // WS ~ half the LLC
    let mut t = Table::new(
        "KV store: speedup vs FGL per merge function",
        &["merge fn", "FGL cycles", "DUP", "CCACHE"],
    );
    for merge in [KvMerge::Add, KvMerge::Sat { max: 12 }, KvMerge::Cmul] {
        let p = KvParams {
            keys: if merge == KvMerge::Cmul { keys / 2 } else { keys },
            accesses_per_key: 16,
            seed: 7,
            merge,
            zipf_theta: 0.0,
        };
        let bench = WorkloadHandle::new(KvWorkload::new(p));
        eprintln!("running {}...", bench.name());
        let fgl = run_verified(&bench, Variant::Fgl, &cfg);
        let dup = run_verified(&bench, Variant::Dup, &cfg);
        let cc = run_verified(&bench, Variant::CCache, &cfg);
        t.row(&[
            merge.name().to_string(),
            fgl.cycles().to_string(),
            format!("{:.2}x", fgl.cycles() as f64 / dup.cycles() as f64),
            format!("{:.2}x", fgl.cycles() as f64 / cc.cycles() as f64),
        ]);
    }
    t.print();
    println!(
        "CCache's benefit persists across arbitrary merge semantics —\n\
         saturating and complex-arithmetic updates would not fit a fixed\n\
         hardware operation set (Section 6.3 / COUP comparison)."
    );
}
